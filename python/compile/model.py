"""L2 — JAX compute graphs of the paper's containerized applications.

These are the *applications inside the container images* of the evaluation
(§V): the TensorFlow MNIST/CIFAR-10 trainers (Table I), the PyFR-like flux
solver (Table II) and the CUDA-SDK-style n-body simulation (Table V). Each
is a pure function lowered once by aot.py to HLO text; the rust coordinator
(shifter-rs) executes the artifacts through the PJRT CPU client so native
and containerized runs provably execute identical compiled bits.

Hot-spot compute goes through the L1 Pallas kernels (kernels/*): dense
layers via the tiled matmul, PyFR operators via the batched-operator kernel,
n-body forces via the all-pairs kernel. Convolutions stay on
lax.conv_general_dilated, which XLA fuses natively.
"""

import functools

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import (
    batched_operator,
    batched_operator_flops,
    matmul,
    matmul_flops,
    nbody_acc,
    nbody_flops,
)

# ---------------------------------------------------------------------------
# Shared NN building blocks
# ---------------------------------------------------------------------------


def conv2d(x, w, b, stride=1):
    """NHWC SAME convolution + bias."""
    out = lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out + b


def max_pool(x, window, stride):
    """NHWC SAME max-pool."""
    return lax.reduce_window(
        x,
        -jnp.inf,
        lax.max,
        window_dimensions=(1, window, window, 1),
        window_strides=(1, stride, stride, 1),
        padding="SAME",
    )


def dense(x, w, b):
    """Dense layer through the L1 Pallas matmul kernel."""
    return matmul(x, w) + b


def softmax_xent(logits, labels, num_classes):
    """Mean softmax cross-entropy with integer labels."""
    logp = jax.nn.log_softmax(logits)
    onehot = jax.nn.one_hot(labels, num_classes, dtype=logits.dtype)
    return -jnp.mean(jnp.sum(onehot * logp, axis=-1))


def _sgd(params, grads, lr):
    return tuple(p - lr * g for p, g in zip(params, grads))


# ---------------------------------------------------------------------------
# MNIST: LeNet-5-like CNN (TF community-models `convolutional.py`, Table I)
# ---------------------------------------------------------------------------

MNIST_BATCH = 64
MNIST_LR = 0.05
MNIST_PARAM_SHAPES = (
    ("conv1_w", (5, 5, 1, 32)),
    ("conv1_b", (32,)),
    ("conv2_w", (5, 5, 32, 64)),
    ("conv2_b", (64,)),
    ("fc1_w", (7 * 7 * 64, 512)),
    ("fc1_b", (512,)),
    ("fc2_w", (512, 10)),
    ("fc2_b", (10,)),
)


def mnist_init(rng):
    """He-initialized parameter tuple, ordered as MNIST_PARAM_SHAPES."""
    params = []
    for (_, shape), key in zip(
        MNIST_PARAM_SHAPES, jax.random.split(rng, len(MNIST_PARAM_SHAPES))
    ):
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(
                jax.random.normal(key, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
    return tuple(params)


def mnist_apply(params, x):
    """Forward pass: (B, 28, 28, 1) -> (B, 10) logits."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b = params
    h = jax.nn.relu(conv2d(x, c1w, c1b))
    h = max_pool(h, 2, 2)  # 14x14x32
    h = jax.nn.relu(conv2d(h, c2w, c2b))
    h = max_pool(h, 2, 2)  # 7x7x64
    h = h.reshape(h.shape[0], -1)  # (B, 3136)
    h = jax.nn.relu(dense(h, f1w, f1b))
    return dense(h, f2w, f2b)


def mnist_loss(params, x, y):
    return softmax_xent(mnist_apply(params, x), y, 10)


def mnist_train_step(*args):
    """One SGD step. args = (*params[8], x, y) -> (*new_params[8], loss)."""
    params, (x, y) = args[:8], args[8:]
    loss, grads = jax.value_and_grad(mnist_loss)(params, x, y)
    return (*_sgd(params, grads, MNIST_LR), loss)


def mnist_flops_per_step(batch=MNIST_BATCH):
    """Approximate FLOPs of one fwd+bwd train step (3x forward rule)."""
    fwd = (
        # conv1: B*28*28 out positions * 5*5*1*32 MACs * 2
        batch * 28 * 28 * 5 * 5 * 1 * 32 * 2
        + batch * 14 * 14 * 5 * 5 * 32 * 64 * 2
        + matmul_flops(batch, 3136, 512)
        + matmul_flops(batch, 512, 10)
    )
    return 3 * fwd


# ---------------------------------------------------------------------------
# CIFAR-10: Krizhevsky-style CNN (TF `deep_cnn` tutorial, Table I)
# ---------------------------------------------------------------------------

CIFAR_BATCH = 32
CIFAR_LR = 0.05
CIFAR_PARAM_SHAPES = (
    ("conv1_w", (5, 5, 3, 64)),
    ("conv1_b", (64,)),
    ("conv2_w", (5, 5, 64, 64)),
    ("conv2_b", (64,)),
    ("fc1_w", (6 * 6 * 64, 384)),
    ("fc1_b", (384,)),
    ("fc2_w", (384, 192)),
    ("fc2_b", (192,)),
    ("fc3_w", (192, 10)),
    ("fc3_b", (10,)),
)


def cifar_init(rng):
    """He-initialized parameter tuple, ordered as CIFAR_PARAM_SHAPES."""
    params = []
    for (_, shape), key in zip(
        CIFAR_PARAM_SHAPES, jax.random.split(rng, len(CIFAR_PARAM_SHAPES))
    ):
        if len(shape) == 1:
            params.append(jnp.zeros(shape, jnp.float32))
        else:
            fan_in = 1
            for d in shape[:-1]:
                fan_in *= d
            params.append(
                jax.random.normal(key, shape, jnp.float32)
                * jnp.sqrt(2.0 / fan_in)
            )
    return tuple(params)


def cifar_apply(params, x):
    """Forward pass: (B, 24, 24, 3) distorted crops -> (B, 10) logits."""
    c1w, c1b, c2w, c2b, f1w, f1b, f2w, f2b, f3w, f3b = params
    h = jax.nn.relu(conv2d(x, c1w, c1b))
    h = max_pool(h, 3, 2)  # 12x12x64
    h = jax.nn.relu(conv2d(h, c2w, c2b))
    h = max_pool(h, 3, 2)  # 6x6x64
    h = h.reshape(h.shape[0], -1)  # (B, 2304)
    h = jax.nn.relu(dense(h, f1w, f1b))
    h = jax.nn.relu(dense(h, f2w, f2b))
    return dense(h, f3w, f3b)


def cifar_loss(params, x, y):
    return softmax_xent(cifar_apply(params, x), y, 10)


def cifar_train_step(*args):
    """One SGD step. args = (*params[10], x, y) -> (*new_params[10], loss)."""
    params, (x, y) = args[:10], args[10:]
    loss, grads = jax.value_and_grad(cifar_loss)(params, x, y)
    return (*_sgd(params, grads, CIFAR_LR), loss)


def cifar_flops_per_step(batch=CIFAR_BATCH):
    fwd = (
        batch * 24 * 24 * 5 * 5 * 3 * 64 * 2
        + batch * 12 * 12 * 5 * 5 * 64 * 64 * 2
        + matmul_flops(batch, 2304, 384)
        + matmul_flops(batch, 384, 192)
        + matmul_flops(batch, 192, 10)
    )
    return 3 * fwd


# ---------------------------------------------------------------------------
# n-body: CUDA SDK benchmark analogue (Table V)
# ---------------------------------------------------------------------------

NBODY_N = 1024  # artifact size; Table V's 200k run is scaled by the L3
# device performance model using nbody_flops(n).
NBODY_DT = 1e-3


def nbody_step(pos4, vel, dt):
    """One leapfrog (kick-drift) step.

    pos4: (N, 4) [x, y, z, m]; vel: (N, 3); dt: f32 scalar.
    Returns (new_pos4, new_vel, potential_proxy) — the third output is a
    cheap scalar (mean |a|) the harness logs as an energy-drift proxy.
    """
    acc = nbody_acc(pos4)
    new_vel = vel + dt * acc
    new_pos = pos4[:, :3] + dt * new_vel
    new_pos4 = jnp.concatenate([new_pos, pos4[:, 3:4]], axis=1)
    return new_pos4, new_vel, jnp.mean(jnp.abs(acc))


# ---------------------------------------------------------------------------
# PyFR-like flux-reconstruction step (Table II)
# ---------------------------------------------------------------------------

PYFR_E = 2048  # elements in the artifact partition
PYFR_P = 8  # solution points per element
PYFR_V = 4  # conserved variables
PYFR_DT = 9.3558e-6  # the paper's T106D time step


def pyfr_flux(u):
    """Burgers-like nonlinear flux, per variable."""
    return 0.5 * u * u


def pyfr_step(u, op_div, dt):
    """One explicit flux-reconstruction update on a mesh partition.

    u:      (E, P, V) per-element solution
    op_div: (P, P) reference-element divergence operator
    dt:     f32 scalar
    Returns (u_new, residual_norm).
    """
    f = pyfr_flux(u)
    du = batched_operator(op_div, f)
    u_new = u - dt * du
    return u_new, jnp.sqrt(jnp.mean(du * du))


def pyfr_flops_per_step(e=PYFR_E, p=PYFR_P, v=PYFR_V):
    # flux eval (2 flops/point) + operator + update (2 flops/point)
    return batched_operator_flops(e, p, p, v) + 4 * e * p * v


__all__ = [
    "MNIST_BATCH",
    "MNIST_PARAM_SHAPES",
    "CIFAR_BATCH",
    "CIFAR_PARAM_SHAPES",
    "NBODY_N",
    "PYFR_E",
    "PYFR_P",
    "PYFR_V",
    "mnist_init",
    "mnist_apply",
    "mnist_loss",
    "mnist_train_step",
    "mnist_flops_per_step",
    "cifar_init",
    "cifar_apply",
    "cifar_loss",
    "cifar_train_step",
    "cifar_flops_per_step",
    "nbody_step",
    "pyfr_step",
    "pyfr_flops_per_step",
]
