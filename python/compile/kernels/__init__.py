"""L1 — Pallas kernels for the containerized applications' compute hot-spots.

All kernels run under interpret=True (CPU PJRT cannot execute Mosaic
custom-calls); structure, tiling and VMEM budgets are the TPU-performance
artifacts, validated in DESIGN.md §7. Each kernel has a pure-jnp oracle in
ref.py and a pytest/hypothesis sweep in python/tests/test_kernels.py.
"""

from .flux import batched_operator, batched_operator_flops
from .matmul import matmul, matmul_flops
from .nbody import nbody_acc, nbody_flops

__all__ = [
    "batched_operator",
    "batched_operator_flops",
    "matmul",
    "matmul_flops",
    "nbody_acc",
    "nbody_flops",
]
