"""Batched per-element operator kernel (Pallas) — PyFR's compute pattern.

PyFR's flux-reconstruction inner loop applies small, dense, *constant*
operator matrices (interpolation / differentiation over the reference
element) independently to every mesh element. On GPUs PyFR batches these
small GEMMs over threadblocks; here the element batch is tiled over the
Pallas grid and each step applies the operator to a (TE, P, V) block held in
VMEM (DESIGN.md §Hardware-Adaptation).

out[e] = op @ u[e]      op: (Q, P), u: (E, P, V)  ->  out: (E, Q, V)

The kernel is linear in `u`, so the custom VJP is the same kernel with the
transposed operator — keeping the Pallas path alive under jax.grad.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Elements per grid step. P, Q, V are small (tens); VMEM per step with
# TE = 512, P = Q = 8, V = 4 in f32: 512*8*4*4 B * 2 + op ~= 128 KiB.
DEFAULT_TE = 512


def _flux_kernel(op_ref, u_ref, o_ref):
    """Apply the shared operator to one tile of elements."""
    op = op_ref[...]  # (Q, P)
    u = u_ref[...]  # (TE, P, V)
    # einsum 'qp,epv->eqv' expressed as dot_general so it maps onto the MXU:
    # contract u's P axis (1) with op's P axis (1); batch over nothing,
    # giving (TE, V, Q)? -- keep it simple and exact instead:
    o_ref[...] = jnp.einsum(
        "qp,epv->eqv", op, u, preferred_element_type=o_ref.dtype
    )


def _ceil_to(value: int, multiple: int) -> int:
    return -(-value // multiple) * multiple


def _batched_operator_pallas(op, u, te):
    e, p, v = u.shape
    q, p2 = op.shape
    assert p == p2, f"operator/state mismatch: {p2} vs {p}"
    ep = _ceil_to(e, te)
    up = jnp.pad(u, ((0, ep - e), (0, 0), (0, 0)))
    grid = (ep // te,)
    out = pl.pallas_call(
        _flux_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((q, p), lambda i: (0, 0)),
            pl.BlockSpec((te, p, v), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((te, q, v), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((ep, q, v), u.dtype),
        interpret=True,
    )(op, up)
    return out[:e]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def batched_operator(op, u, te=DEFAULT_TE):
    """Differentiable batched operator application: out[e] = op @ u[e]."""
    return _batched_operator_pallas(op, u, te)


def _bop_fwd(op, u, te):
    return _batched_operator_pallas(op, u, te), (op, u)


def _bop_bwd(te, res, g):
    op, u = res
    # d/du (op @ u) . g = op^T @ g, elementwise over the batch.
    du = _batched_operator_pallas(op.T, g, te)
    # d/dop = sum_e g[e] @ u[e]^T
    dop = jnp.einsum("eqv,epv->qp", g, u)
    return dop, du


batched_operator.defvjp(_bop_fwd, _bop_bwd)


def batched_operator_flops(e: int, q: int, p: int, v: int) -> int:
    """FLOPs of one batched operator application."""
    return 2 * e * q * p * v
