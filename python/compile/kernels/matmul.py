"""Tiled Pallas matmul kernel — the CNN dense-layer / conv-as-GEMM workhorse.

TPU-shaped design (see DESIGN.md §Hardware-Adaptation):
  * the grid is (M/TM, N/TN, K/TK) with the K dimension innermost, so each
    (i, j) output tile stays resident while K-tiles stream HBM->VMEM;
  * default tiles are 128-multiples to match the MXU systolic array;
  * accumulation happens in the output ref across the sequential K steps
    (the canonical Pallas revisiting-output pattern).

`interpret=True` everywhere: the CPU PJRT client cannot execute Mosaic
custom-calls, and interpret-mode lowers the kernel to plain HLO which the
rust runtime then runs. Structure (not wallclock) is the TPU-perf artifact.

A `jax.custom_vjp` wrapper makes the kernel differentiable so it sits on the
L2 training path: dX = dY @ W^T and dW = X^T @ dY are themselves computed by
the same Pallas kernel.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-aligned default tiles. VMEM footprint per step with these defaults:
# x tile 128x128 f32 (64 KiB) + w tile 128x128 (64 KiB) + out tile 128x128
# (64 KiB) = 192 KiB  <<  16 MiB VMEM — leaves room for double buffering.
DEFAULT_TM = 128
DEFAULT_TK = 128
DEFAULT_TN = 128


def _matmul_kernel(x_ref, w_ref, o_ref, *, k_steps):
    """One (i, j, k) grid step: o[i, j] += x[i, k] @ w[k, j]."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )


def _ceil_to(value: int, multiple: int) -> int:
    return -(-value // multiple) * multiple


def _matmul_pallas(x, w, tm, tk, tn):
    """Pad-to-tile, run the Pallas grid, slice back to the true shape."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    mp, kp, np_ = _ceil_to(m, tm), _ceil_to(k, tk), _ceil_to(n, tn)
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    wp = jnp.pad(w, ((0, kp - k), (0, np_ - n)))
    grid = (mp // tm, np_ // tn, kp // tk)
    out = pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((tk, tn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        interpret=True,
    )(xp, wp)
    return out[:m, :n]


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3, 4))
def matmul(x, w, tm=DEFAULT_TM, tk=DEFAULT_TK, tn=DEFAULT_TN):
    """Differentiable tiled Pallas matmul: (M, K) @ (K, N) -> (M, N).

    Arbitrary shapes are supported via zero padding to tile multiples; the
    zeros contribute nothing to the contraction so the result is exact.
    """
    return _matmul_pallas(x, w, tm, tk, tn)


def _matmul_fwd(x, w, tm, tk, tn):
    return _matmul_pallas(x, w, tm, tk, tn), (x, w)


def _matmul_bwd(tm, tk, tn, res, g):
    x, w = res
    dx = _matmul_pallas(g, w.T, tm, tk, tn)
    dw = _matmul_pallas(x.T, g, tm, tk, tn)
    return dx, dw


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def matmul_flops(m: int, k: int, n: int) -> int:
    """FLOPs of one (M, K) @ (K, N) product (mul + add)."""
    return 2 * m * k * n
