"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness ground truth).

Every Pallas kernel in this package has an exact (up to float associativity)
counterpart here. pytest (python/tests/test_kernels.py) asserts allclose
between kernel and oracle across shape/dtype sweeps driven by hypothesis.
"""

import jax.numpy as jnp

# Softening constant shared with the n-body kernel (Plummer softening).
NBODY_SOFTENING = 1e-3


def matmul_ref(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Plain dense matmul oracle: (M, K) @ (K, N) -> (M, N)."""
    return jnp.matmul(x, w, preferred_element_type=x.dtype)


def nbody_acc_ref(pos4: jnp.ndarray) -> jnp.ndarray:
    """All-pairs gravitational acceleration oracle.

    pos4: (N, 4) rows of [x, y, z, mass].
    Returns (N, 3) accelerations with Plummer softening; G = 1.

    a_i = sum_j m_j * (p_j - p_i) / (|p_j - p_i|^2 + eps^2)^(3/2)
    (the self term vanishes because d = 0 and softening keeps it finite).
    """
    p = pos4[:, :3]
    m = pos4[:, 3]
    d = p[None, :, :] - p[:, None, :]  # (N, N, 3): d[i, j] = p_j - p_i
    r2 = jnp.sum(d * d, axis=-1) + jnp.asarray(NBODY_SOFTENING**2, pos4.dtype)
    inv_r3 = r2 ** jnp.asarray(-1.5, pos4.dtype)
    return jnp.sum(d * (m[None, :] * inv_r3)[:, :, None], axis=1)


def batched_operator_ref(op: jnp.ndarray, u: jnp.ndarray) -> jnp.ndarray:
    """PyFR-style per-element operator application oracle.

    op: (Q, P) operator matrix (shared across elements)
    u:  (E, P, V) per-element solution/flux values
    Returns (E, Q, V): out[e] = op @ u[e].
    """
    return jnp.einsum("qp,epv->eqv", op, u, preferred_element_type=u.dtype)
