"""Tiled all-pairs n-body interaction kernel (Pallas).

Reproduces the compute hot-spot of the CUDA SDK n-body benchmark the paper
uses for Table V, re-expressed for the TPU memory hierarchy instead of being
a mechanical CUDA port (DESIGN.md §Hardware-Adaptation):

  * CUDA version: each threadblock stages a tile of "source" bodies through
    shared memory; each thread accumulates one body's acceleration.
  * This version: the grid is (N/TI, N/TJ). For a fixed i-tile the j
    (source) tiles stream through VMEM via BlockSpec while the (TI, 3)
    acceleration tile is revisited and accumulated across the sequential j
    dimension — the same staging idea, expressed as an HBM->VMEM schedule
    rather than threadblock cooperation.

Body state is packed as (N, 4) rows of [x, y, z, mass] so one ref carries
both positions and masses (mirrors CUDA's float4 layout).

FLOP accounting (used by the Table V GF/s harness): 20 flops per pairwise
interaction, the convention used by the CUDA SDK benchmark itself.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import NBODY_SOFTENING

# Default tiles: TI x TJ interaction sub-matrix. VMEM per step:
# i-tile (256, 4) + j-tile (256, 4) + acc (256, 3) f32 ~= 11 KiB, plus the
# (TI, TJ, 3) displacement intermediate (768 KiB) — well under VMEM.
DEFAULT_TI = 256
DEFAULT_TJ = 256

FLOPS_PER_INTERACTION = 20  # CUDA SDK n-body convention


def _nbody_kernel(pi_ref, pj_ref, acc_ref):
    """Accumulate accelerations of the i-tile due to the j-tile."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    dtype = pi_ref.dtype
    pi = pi_ref[...]  # (TI, 4)
    pj = pj_ref[...]  # (TJ, 4)
    # d[a, b] = p_b - p_a for a in i-tile, b in j-tile
    d = pj[None, :, :3] - pi[:, None, :3]  # (TI, TJ, 3)
    r2 = jnp.sum(d * d, axis=-1) + jnp.asarray(NBODY_SOFTENING**2, dtype)
    inv_r3 = r2 ** jnp.asarray(-1.5, dtype)
    w = pj[None, :, 3] * inv_r3  # (TI, TJ): m_j / r^3
    acc_ref[...] += jnp.sum(d * w[:, :, None], axis=1)


def _ceil_to(value: int, multiple: int) -> int:
    return -(-value // multiple) * multiple


def nbody_acc(pos4, ti=DEFAULT_TI, tj=DEFAULT_TJ):
    """All-pairs accelerations: (N, 4) [x, y, z, m] -> (N, 3), G = 1.

    Padding bodies have mass 0 so they exert no force; padded *targets* are
    sliced away. Softening keeps the self-interaction finite and zero.
    """
    n = pos4.shape[0]
    np_ = _ceil_to(n, max(ti, tj))
    p = jnp.pad(pos4, ((0, np_ - n), (0, 0)))
    grid = (np_ // ti, np_ // tj)
    acc = pl.pallas_call(
        _nbody_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((ti, 4), lambda i, j: (i, 0)),
            pl.BlockSpec((tj, 4), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((ti, 3), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((np_, 3), pos4.dtype),
        interpret=True,
    )(p, p)
    return acc[:n]


def nbody_flops(n: int) -> int:
    """FLOPs of one all-pairs force evaluation over n bodies."""
    return FLOPS_PER_INTERACTION * n * n
