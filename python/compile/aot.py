"""AOT pipeline: lower every L2 model to HLO *text* + a JSON manifest.

This is the single place Python runs — `make artifacts` invokes it once and
the rust coordinator never touches Python again. Interchange is HLO text,
NOT `.serialize()`: jax >= 0.5 emits HloModuleProto with 64-bit instruction
ids which xla_extension 0.5.1 (the version behind the published `xla` 0.1.6
crate) rejects; the text parser reassigns ids and round-trips cleanly.

The manifest (artifacts/manifest.json) records, per artifact, the ordered
input/output signatures and a FLOP estimate per call, which the rust side
uses both to build PJRT literals and to drive the GPU device performance
model (DESIGN.md S15).

Usage: python -m compile.aot [--out-dir ../artifacts] [--only NAME]
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

jax.config.update("jax_enable_x64", True)  # n-body artifact is f64 (Table V)

from . import model  # noqa: E402

GENERATOR_VERSION = "shifter-rs-aot-1"

_DTYPE_NAMES = {
    jnp.dtype("float32"): "f32",
    jnp.dtype("float64"): "f64",
    jnp.dtype("int32"): "s32",
    jnp.dtype("int64"): "s64",
}


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _sig(name, spec):
    return {
        "name": name,
        "shape": list(spec.shape),
        "dtype": _DTYPE_NAMES[jnp.dtype(spec.dtype)],
    }


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _mnist_specs():
    ins = [
        (n, _spec(s, jnp.float32)) for n, s in model.MNIST_PARAM_SHAPES
    ] + [
        ("x", _spec((model.MNIST_BATCH, 28, 28, 1), jnp.float32)),
        ("y", _spec((model.MNIST_BATCH,), jnp.int32)),
    ]
    return ins


def _cifar_specs():
    ins = [
        (n, _spec(s, jnp.float32)) for n, s in model.CIFAR_PARAM_SHAPES
    ] + [
        ("x", _spec((model.CIFAR_BATCH, 24, 24, 3), jnp.float32)),
        ("y", _spec((model.CIFAR_BATCH,), jnp.int32)),
    ]
    return ins


def build_catalog():
    """name -> (fn, [(input_name, spec)], [output names], flops_per_call)."""
    mnist_in = _mnist_specs()
    cifar_in = _cifar_specs()
    nbody_in = [
        ("pos4", _spec((model.NBODY_N, 4), jnp.float64)),
        ("vel", _spec((model.NBODY_N, 3), jnp.float64)),
        ("dt", _spec((), jnp.float64)),
    ]
    pyfr_in = [
        ("u", _spec((model.PYFR_E, model.PYFR_P, model.PYFR_V), jnp.float32)),
        ("op_div", _spec((model.PYFR_P, model.PYFR_P), jnp.float32)),
        ("dt", _spec((), jnp.float32)),
    ]
    return {
        "mnist_train": (
            model.mnist_train_step,
            mnist_in,
            [n for n, _ in model.MNIST_PARAM_SHAPES] + ["loss"],
            model.mnist_flops_per_step(),
        ),
        "mnist_predict": (
            lambda *a: (model.mnist_apply(a[:8], a[8]),),
            mnist_in[:-1],
            ["logits"],
            model.mnist_flops_per_step() // 3,
        ),
        "cifar_train": (
            model.cifar_train_step,
            cifar_in,
            [n for n, _ in model.CIFAR_PARAM_SHAPES] + ["loss"],
            model.cifar_flops_per_step(),
        ),
        "nbody_step": (
            model.nbody_step,
            nbody_in,
            ["pos4", "vel", "acc_norm"],
            # force eval dominates; +12n for the integrator
            __import__("compile.kernels", fromlist=["nbody_flops"]).nbody_flops(
                model.NBODY_N
            )
            + 12 * model.NBODY_N,
        ),
        "pyfr_step": (
            model.pyfr_step,
            pyfr_in,
            ["u", "residual"],
            model.pyfr_flops_per_step(),
        ),
    }


def emit(out_dir: str, only: str | None = None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    catalog = build_catalog()
    manifest = {"generator": GENERATOR_VERSION, "artifacts": {}}
    for name, (fn, ins, out_names, flops) in catalog.items():
        if only is not None and name != only:
            continue
        specs = [s for _, s in ins]
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        out_avals = lowered.out_info
        # lowered.out_info is a pytree of ShapeDtypeStruct matching outputs
        flat_outs = jax.tree_util.tree_leaves(out_avals)
        manifest["artifacts"][name] = {
            "file": fname,
            "inputs": [_sig(n, s) for n, s in ins],
            "outputs": [
                _sig(out_names[i] if i < len(out_names) else f"out{i}", s)
                for i, s in enumerate(flat_outs)
            ],
            "flops_per_call": int(flops),
        }
        print(f"  {name}: {len(text)} chars, {len(ins)} in, "
              f"{len(flat_outs)} out, {flops:.3e} flops/call")
    # merge into an existing manifest when --only is used
    mpath = os.path.join(out_dir, "manifest.json")
    if only is not None and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old["artifacts"].update(manifest["artifacts"])
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {mpath}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None, help="emit a single artifact")
    args = ap.parse_args()
    emit(args.out_dir, args.only)


if __name__ == "__main__":
    main()
