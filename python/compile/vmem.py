"""L1 structural performance analysis: VMEM footprint + MXU utilization
estimates per Pallas kernel configuration.

interpret=True gives no TPU wallclock, so kernel performance is assessed
structurally (DESIGN.md §7): for each kernel's BlockSpec tiling this tool
computes the per-grid-step VMEM residency (operand blocks + output block +
large intermediates) and the MXU utilization proxy (fraction of the
128x128 systolic array a step's contraction shapes can fill).

Usage: python -m compile.vmem            # print the table
       (also imported by python/tests/test_vmem.py)
"""

from dataclasses import dataclass

VMEM_BYTES = 16 * 1024 * 1024  # per-core VMEM on contemporary TPUs
MXU_DIM = 128


@dataclass
class KernelFootprint:
    name: str
    config: str
    vmem_bytes: int
    mxu_utilization: float
    notes: str

    @property
    def vmem_fraction(self) -> float:
        return self.vmem_bytes / VMEM_BYTES


def _mxu_util(m: int, k: int, n: int) -> float:
    """Fraction of the systolic array filled by an (m,k)x(k,n) contraction."""
    return min(1.0, m / MXU_DIM) * min(1.0, n / MXU_DIM) * min(1.0, k / MXU_DIM)


def matmul_footprint(tm=128, tk=128, tn=128, dtype_bytes=4) -> KernelFootprint:
    """Tiled matmul: x-tile + w-tile + resident output tile (+ double
    buffering of the streamed operands)."""
    x_tile = tm * tk * dtype_bytes
    w_tile = tk * tn * dtype_bytes
    o_tile = tm * tn * dtype_bytes
    vmem = 2 * (x_tile + w_tile) + o_tile  # x/w double-buffered
    return KernelFootprint(
        name="matmul",
        config=f"TM={tm} TK={tk} TN={tn}",
        vmem_bytes=vmem,
        mxu_utilization=_mxu_util(tm, tk, tn),
        notes="K innermost/sequential; output revisited",
    )


def nbody_footprint(ti=256, tj=256, dtype_bytes=8) -> KernelFootprint:
    """All-pairs n-body: i-tile, streamed j-tile, acc tile, plus the
    (TI, TJ, 3) displacement intermediate that dominates."""
    i_tile = ti * 4 * dtype_bytes
    j_tile = tj * 4 * dtype_bytes
    acc = ti * 3 * dtype_bytes
    disp = ti * tj * 3 * dtype_bytes  # d, plus r2/inv_r3 at (TI,TJ)
    r2 = ti * tj * dtype_bytes * 2
    vmem = i_tile + 2 * j_tile + acc + disp + r2
    # the kernel is VPU-heavy (elementwise), MXU unused: report the VPU
    # lane fill proxy instead (8x128 lanes)
    util = min(1.0, tj / 128) * min(1.0, ti / 8)
    return KernelFootprint(
        name="nbody",
        config=f"TI={ti} TJ={tj} f64",
        vmem_bytes=vmem,
        mxu_utilization=util,
        notes="VPU-bound; j streamed, acc revisited",
    )


def flux_footprint(te=512, p=8, q=8, v=4, dtype_bytes=4) -> KernelFootprint:
    """Batched per-element operator: op + u-tile + out-tile."""
    op = q * p * dtype_bytes
    u_tile = te * p * v * dtype_bytes
    o_tile = te * q * v * dtype_bytes
    vmem = op + 2 * u_tile + o_tile
    # per-element GEMMs are tiny: MXU fill is (q/128)*(v/128)*(p/128)
    # unless the batch is blocked into the contraction — report the
    # batched-as-GEMM utilization (te*v as the N dimension)
    util = _mxu_util(q, p, min(te * v, 128))
    return KernelFootprint(
        name="batched_operator",
        config=f"TE={te} P={p} Q={q} V={v}",
        vmem_bytes=vmem,
        mxu_utilization=util,
        notes="element batch blocked over grid",
    )


def all_footprints() -> list[KernelFootprint]:
    return [matmul_footprint(), nbody_footprint(), flux_footprint()]


def render() -> str:
    rows = all_footprints()
    lines = [
        f"{'kernel':<18} {'config':<24} {'VMEM':>10} {'of 16MiB':>9} "
        f"{'MXU/VPU':>8}  notes"
    ]
    for r in rows:
        lines.append(
            f"{r.name:<18} {r.config:<24} {r.vmem_bytes/1024:>8.0f}Ki "
            f"{r.vmem_fraction*100:>8.1f}% {r.mxu_utilization*100:>7.0f}%  "
            f"{r.notes}"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    print(render())
