"""L2 model tests: shapes, train-step semantics, physics invariants."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


def _synthetic_mnist(seed, batch):
    """Class-separable synthetic digits: class-k blob at a class-specific spot."""
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=batch)
    x = rng.normal(0, 0.1, size=(batch, 28, 28, 1)).astype(np.float32)
    for i, cls in enumerate(y):
        r, c = 4 + 2 * (cls % 5), 6 + 3 * (cls // 5)
        x[i, r : r + 6, c : c + 6, 0] += 1.0
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


def _synthetic_cifar(seed, batch):
    rng = np.random.default_rng(seed)
    y = rng.integers(0, 10, size=batch)
    x = rng.normal(0, 0.1, size=(batch, 24, 24, 3)).astype(np.float32)
    for i, cls in enumerate(y):
        x[i, :, :, cls % 3] += 0.3 + 0.15 * cls
    return jnp.asarray(x), jnp.asarray(y, jnp.int32)


class TestMnist:
    def test_apply_shape(self):
        params = model.mnist_init(jax.random.PRNGKey(0))
        x, _ = _synthetic_mnist(0, model.MNIST_BATCH)
        logits = model.mnist_apply(params, x)
        assert logits.shape == (model.MNIST_BATCH, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_param_shapes_match_declaration(self):
        params = model.mnist_init(jax.random.PRNGKey(1))
        assert len(params) == len(model.MNIST_PARAM_SHAPES)
        for p, (_, s) in zip(params, model.MNIST_PARAM_SHAPES):
            assert p.shape == s

    def test_train_step_reduces_loss(self):
        params = model.mnist_init(jax.random.PRNGKey(2))
        x, y = _synthetic_mnist(1, model.MNIST_BATCH)
        step = jax.jit(model.mnist_train_step)
        losses = []
        for _ in range(8):
            out = step(*params, x, y)
            params, loss = out[:-1], out[-1]
            losses.append(float(loss))
        assert losses[-1] < losses[0], losses
        assert all(np.isfinite(losses))

    def test_train_step_output_arity(self):
        params = model.mnist_init(jax.random.PRNGKey(3))
        x, y = _synthetic_mnist(2, model.MNIST_BATCH)
        out = model.mnist_train_step(*params, x, y)
        assert len(out) == len(params) + 1
        for new_p, old_p in zip(out[:-1], params):
            assert new_p.shape == old_p.shape
            assert new_p.dtype == old_p.dtype

    def test_loss_is_chance_at_init_bias_zero(self):
        # zero-weight params -> uniform logits -> loss = ln(10)
        params = tuple(jnp.zeros_like(p) for p in model.mnist_init(jax.random.PRNGKey(4)))
        x, y = _synthetic_mnist(3, model.MNIST_BATCH)
        loss = model.mnist_loss(params, x, y)
        np.testing.assert_allclose(float(loss), np.log(10.0), rtol=1e-5)


class TestCifar:
    def test_apply_shape(self):
        params = model.cifar_init(jax.random.PRNGKey(0))
        x, _ = _synthetic_cifar(0, model.CIFAR_BATCH)
        logits = model.cifar_apply(params, x)
        assert logits.shape == (model.CIFAR_BATCH, 10)
        assert bool(jnp.all(jnp.isfinite(logits)))

    def test_train_step_reduces_loss(self):
        params = model.cifar_init(jax.random.PRNGKey(1))
        x, y = _synthetic_cifar(1, model.CIFAR_BATCH)
        step = jax.jit(model.cifar_train_step)
        out = step(*params, x, y)
        first = float(out[-1])
        params = out[:-1]
        for _ in range(6):
            out = step(*params, x, y)
            params = out[:-1]
        assert float(out[-1]) < first

    def test_flops_positive_and_scale_with_batch(self):
        assert model.cifar_flops_per_step(64) == 2 * model.cifar_flops_per_step(32)


class TestNbodyStep:
    def _state(self, seed, n=256):
        k1, k2, k3 = jax.random.split(jax.random.PRNGKey(seed), 3)
        pos = jax.random.normal(k1, (n, 3), jnp.float64)
        mass = jax.random.uniform(k2, (n,), jnp.float64, 0.5, 1.5)
        vel = 0.1 * jax.random.normal(k3, (n, 3), jnp.float64)
        return jnp.concatenate([pos, mass[:, None]], axis=1), vel

    def test_shapes_and_mass_preserved(self):
        pos4, vel = self._state(0)
        np4, nv, proxy = model.nbody_step(pos4, vel, jnp.float64(1e-3))
        assert np4.shape == pos4.shape and nv.shape == vel.shape
        np.testing.assert_array_equal(np4[:, 3], pos4[:, 3])
        assert proxy.shape == ()

    def test_momentum_conserved(self):
        pos4, vel = self._state(1)
        m = pos4[:, 3:4]
        p0 = jnp.sum(m * vel, axis=0)
        _, nv, _ = model.nbody_step(pos4, vel, jnp.float64(1e-3))
        p1 = jnp.sum(m * nv, axis=0)
        np.testing.assert_allclose(p1, p0, atol=1e-10)

    def test_zero_dt_is_identity_on_velocity(self):
        pos4, vel = self._state(2)
        np4, nv, _ = model.nbody_step(pos4, vel, jnp.float64(0.0))
        np.testing.assert_allclose(nv, vel, atol=0)
        np.testing.assert_allclose(np4[:, :3], pos4[:, :3], atol=0)


class TestPyfrStep:
    def test_shapes(self):
        u = jax.random.normal(jax.random.PRNGKey(0), (128, 8, 4), jnp.float32)
        op = jax.random.normal(jax.random.PRNGKey(1), (8, 8), jnp.float32) * 0.1
        un, res = model.pyfr_step(u, op, jnp.float32(1e-3))
        assert un.shape == u.shape
        assert res.shape == ()
        assert bool(jnp.isfinite(res))

    def test_zero_dt_identity(self):
        u = jax.random.normal(jax.random.PRNGKey(2), (64, 8, 4), jnp.float32)
        op = jax.random.normal(jax.random.PRNGKey(3), (8, 8), jnp.float32)
        un, _ = model.pyfr_step(u, op, jnp.float32(0.0))
        np.testing.assert_allclose(un, u, atol=0)

    def test_constant_state_with_null_row_operator(self):
        # operator with zero row sums annihilates constant fluxes:
        # f(u)=const per element -> du = op @ const = 0 when rows sum to 0
        op = jax.random.normal(jax.random.PRNGKey(4), (8, 8), jnp.float32)
        op = op - jnp.mean(op, axis=1, keepdims=True)
        u = jnp.ones((32, 8, 4), jnp.float32) * 2.0
        un, res = model.pyfr_step(u, op, jnp.float32(1e-2))
        np.testing.assert_allclose(un, u, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(float(res), 0.0, atol=1e-5)

    def test_residual_matches_manual(self):
        u = jax.random.normal(jax.random.PRNGKey(5), (16, 8, 4), jnp.float32)
        op = jax.random.normal(jax.random.PRNGKey(6), (8, 8), jnp.float32)
        _, res = model.pyfr_step(u, op, jnp.float32(1e-3))
        du = jnp.einsum("qp,epv->eqv", op, model.pyfr_flux(u))
        np.testing.assert_allclose(
            float(res), float(jnp.sqrt(jnp.mean(du * du))), rtol=1e-5
        )
