"""L1 correctness: every Pallas kernel vs its pure-jnp oracle.

hypothesis sweeps shapes (including non-tile-multiples, which exercise the
padding paths) and seeds; fixed-shape tests pin down the exact configurations
the AOT artifacts use.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import (
    batched_operator,
    batched_operator_flops,
    matmul,
    matmul_flops,
    nbody_acc,
    nbody_flops,
)
from compile.kernels.ref import (
    batched_operator_ref,
    matmul_ref,
    nbody_acc_ref,
)

HYP = dict(max_examples=25, deadline=None)


def _rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(jax.random.PRNGKey(key), shape, dtype)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


class TestMatmul:
    def test_exact_tile_multiple(self):
        x, w = _rand(0, (128, 256)), _rand(1, (256, 128))
        np.testing.assert_allclose(
            matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5
        )

    def test_needs_padding_all_dims(self):
        x, w = _rand(2, (65, 130)), _rand(3, (130, 5))
        np.testing.assert_allclose(
            matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5
        )

    def test_single_row_col(self):
        x, w = _rand(4, (1, 7)), _rand(5, (7, 1))
        np.testing.assert_allclose(
            matmul(x, w), matmul_ref(x, w), rtol=1e-5, atol=1e-5
        )

    def test_dense_layer_shapes_from_models(self):
        # the exact shapes the MNIST/CIFAR artifacts run through the kernel
        for m, k, n in [(64, 3136, 512), (64, 512, 10), (32, 2304, 384)]:
            x, w = _rand(6, (m, k)), _rand(7, (k, n))
            np.testing.assert_allclose(
                matmul(x, w), matmul_ref(x, w), rtol=2e-4, atol=2e-4
            )

    def test_small_tiles_multi_k_step(self):
        x, w = _rand(8, (32, 96)), _rand(9, (96, 16))
        got = matmul(x, w, 8, 16, 8)  # forces a 6-step K loop
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_grad_matches_reference(self):
        x, w = _rand(10, (16, 24)), _rand(11, (24, 12))
        c = _rand(12, (16, 12))

        def f_kernel(x, w):
            return jnp.sum(matmul(x, w, 8, 8, 8) * c)

        def f_ref(x, w):
            return jnp.sum(matmul_ref(x, w) * c)

        gx_k, gw_k = jax.grad(f_kernel, argnums=(0, 1))(x, w)
        gx_r, gw_r = jax.grad(f_ref, argnums=(0, 1))(x, w)
        np.testing.assert_allclose(gx_k, gx_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gw_k, gw_r, rtol=1e-5, atol=1e-5)

    def test_under_jit(self):
        x, w = _rand(13, (40, 40)), _rand(14, (40, 40))
        got = jax.jit(lambda a, b: matmul(a, b, 16, 16, 16))(x, w)
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_zero_inputs(self):
        x = jnp.zeros((9, 9), jnp.float32)
        w = jnp.zeros((9, 9), jnp.float32)
        assert jnp.all(matmul(x, w, 8, 8, 8) == 0)

    @given(
        m=st.integers(1, 48),
        k=st.integers(1, 48),
        n=st.integers(1, 48),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_hypothesis_shapes(self, m, k, n, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (m, k), jnp.float32)
        w = jax.random.normal(kw, (k, n), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, w, 16, 16, 16), matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    @given(seed=st.integers(0, 2**31 - 1))
    @settings(**HYP)
    def test_hypothesis_f64(self, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (17, 23), jnp.float64)
        w = jax.random.normal(kw, (23, 11), jnp.float64)
        np.testing.assert_allclose(
            matmul(x, w, 8, 8, 8), matmul_ref(x, w), rtol=1e-12, atol=1e-12
        )

    def test_flops_accounting(self):
        assert matmul_flops(2, 3, 4) == 48


# ---------------------------------------------------------------------------
# n-body
# ---------------------------------------------------------------------------


def _plummer(seed, n, dtype=jnp.float64):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    pos = jax.random.normal(k1, (n, 3), dtype)
    mass = jax.random.uniform(k2, (n,), dtype, 0.5, 1.5)
    return jnp.concatenate([pos, mass[:, None]], axis=1)


class TestNbody:
    @pytest.mark.parametrize("n", [1, 3, 17, 64, 256, 300])
    def test_matches_oracle(self, n):
        p = _plummer(0, n)
        got = nbody_acc(p, ti=64, tj=64)
        np.testing.assert_allclose(
            got, nbody_acc_ref(p), rtol=1e-10, atol=1e-10
        )

    def test_artifact_configuration(self):
        # exact shape/tiles the nbody_step artifact lowers with
        p = _plummer(1, 1024)
        np.testing.assert_allclose(
            nbody_acc(p), nbody_acc_ref(p), rtol=1e-10, atol=1e-10
        )

    def test_f32(self):
        p = _plummer(2, 128, jnp.float32)
        np.testing.assert_allclose(
            nbody_acc(p, ti=32, tj=32), nbody_acc_ref(p), rtol=1e-4, atol=1e-4
        )

    def test_newton_third_law(self):
        # total force sum_i m_i a_i = 0 for pair-symmetric softening
        p = _plummer(3, 200)
        a = nbody_acc(p, ti=64, tj=64)
        total = jnp.sum(p[:, 3:4] * a, axis=0)
        np.testing.assert_allclose(total, jnp.zeros(3), atol=1e-9)

    def test_two_body_analytic(self):
        # two unit masses at distance 2 along x: |a| = 1/(4+eps^2)^1.5
        p = jnp.array(
            [[-1.0, 0, 0, 1.0], [1.0, 0, 0, 1.0]], jnp.float64
        )
        a = nbody_acc(p, ti=8, tj=8)
        expect = (4.0 + 1e-6) ** -1.5 * 2.0  # d = 2 along x
        np.testing.assert_allclose(a[0, 0], expect, rtol=1e-12)
        np.testing.assert_allclose(a[1, 0], -expect, rtol=1e-12)
        np.testing.assert_allclose(a[:, 1:], jnp.zeros((2, 2)), atol=1e-15)

    def test_massless_body_exerts_nothing(self):
        p = _plummer(4, 32)
        ghost = jnp.array([[5.0, 5.0, 5.0, 0.0]], jnp.float64)
        a_without = nbody_acc_ref(p)
        a_with = nbody_acc(jnp.concatenate([p, ghost]), ti=16, tj=16)[:-1]
        np.testing.assert_allclose(a_with, a_without, rtol=1e-10, atol=1e-12)

    @given(n=st.integers(2, 130), seed=st.integers(0, 2**31 - 1))
    @settings(**HYP)
    def test_hypothesis_sizes(self, n, seed):
        p = _plummer(seed % 1000, n)
        np.testing.assert_allclose(
            nbody_acc(p, ti=32, tj=32),
            nbody_acc_ref(p),
            rtol=1e-9,
            atol=1e-9,
        )

    def test_flops_accounting(self):
        assert nbody_flops(1000) == 20 * 1000 * 1000


# ---------------------------------------------------------------------------
# batched operator (PyFR)
# ---------------------------------------------------------------------------


class TestBatchedOperator:
    @pytest.mark.parametrize(
        "e,q,p,v", [(1, 2, 2, 1), (7, 8, 8, 4), (512, 8, 8, 4), (1000, 4, 6, 5)]
    )
    def test_matches_oracle(self, e, q, p, v):
        op = _rand(0, (q, p))
        u = _rand(1, (e, p, v))
        np.testing.assert_allclose(
            batched_operator(op, u, 64),
            batched_operator_ref(op, u),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_artifact_configuration(self):
        op = _rand(2, (8, 8))
        u = _rand(3, (2048, 8, 4))
        np.testing.assert_allclose(
            batched_operator(op, u),
            batched_operator_ref(op, u),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_identity_operator(self):
        u = _rand(4, (33, 6, 3))
        got = batched_operator(jnp.eye(6), u, 16)
        np.testing.assert_allclose(got, u, rtol=1e-6, atol=1e-6)

    def test_linearity(self):
        op = _rand(5, (4, 4))
        u1, u2 = _rand(6, (20, 4, 2)), _rand(7, (20, 4, 2))
        lhs = batched_operator(op, 2.0 * u1 + 3.0 * u2, 8)
        rhs = 2.0 * batched_operator(op, u1, 8) + 3.0 * batched_operator(
            op, u2, 8
        )
        np.testing.assert_allclose(lhs, rhs, rtol=1e-5, atol=1e-5)

    def test_grad_matches_reference(self):
        op = _rand(8, (5, 4))
        u = _rand(9, (12, 4, 3))
        c = _rand(10, (12, 5, 3))

        def f_kernel(op, u):
            return jnp.sum(batched_operator(op, u, 8) * c)

        def f_ref(op, u):
            return jnp.sum(batched_operator_ref(op, u) * c)

        gop_k, gu_k = jax.grad(f_kernel, argnums=(0, 1))(op, u)
        gop_r, gu_r = jax.grad(f_ref, argnums=(0, 1))(op, u)
        np.testing.assert_allclose(gop_k, gop_r, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(gu_k, gu_r, rtol=1e-5, atol=1e-5)

    @given(
        e=st.integers(1, 80),
        q=st.integers(1, 12),
        p=st.integers(1, 12),
        v=st.integers(1, 6),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_hypothesis_shapes(self, e, q, p, v, seed):
        k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
        op = jax.random.normal(k1, (q, p), jnp.float32)
        u = jax.random.normal(k2, (e, p, v), jnp.float32)
        np.testing.assert_allclose(
            batched_operator(op, u, 32),
            batched_operator_ref(op, u),
            rtol=1e-4,
            atol=1e-4,
        )

    def test_flops_accounting(self):
        assert batched_operator_flops(10, 2, 3, 4) == 480


# ---------------------------------------------------------------------------
# cross-kernel edge cases
# ---------------------------------------------------------------------------


class TestTileGeometry:
    @given(
        tm=st.sampled_from([8, 16, 32]),
        tk=st.sampled_from([8, 16, 32]),
        tn=st.sampled_from([8, 16, 32]),
        seed=st.integers(0, 2**31 - 1),
    )
    @settings(**HYP)
    def test_matmul_rectangular_tiles(self, tm, tk, tn, seed):
        kx, kw = jax.random.split(jax.random.PRNGKey(seed))
        x = jax.random.normal(kx, (37, 53), jnp.float32)
        w = jax.random.normal(kw, (53, 29), jnp.float32)
        np.testing.assert_allclose(
            matmul(x, w, tm, tk, tn), matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )

    def test_nbody_asymmetric_tiles(self):
        p = _plummer(9, 100)
        np.testing.assert_allclose(
            nbody_acc(p, ti=16, tj=64),
            nbody_acc_ref(p),
            rtol=1e-9,
            atol=1e-9,
        )
        np.testing.assert_allclose(
            nbody_acc(p, ti=64, tj=16),
            nbody_acc_ref(p),
            rtol=1e-9,
            atol=1e-9,
        )

    def test_flux_tile_larger_than_batch(self):
        op = _rand(20, (6, 6))
        u = _rand(21, (5, 6, 2))  # e=5 < te=64: whole batch in one step
        np.testing.assert_allclose(
            batched_operator(op, u, 64),
            batched_operator_ref(op, u),
            rtol=1e-5,
            atol=1e-5,
        )

    def test_matmul_tile_exceeding_matrix(self):
        x, w = _rand(22, (10, 10)), _rand(23, (10, 10))
        got = matmul(x, w, 128, 128, 128)  # full pad-up path
        np.testing.assert_allclose(got, matmul_ref(x, w), rtol=1e-5, atol=1e-5)

    def test_matmul_extreme_aspect_ratio(self):
        x, w = _rand(24, (1, 300)), _rand(25, (300, 2))
        np.testing.assert_allclose(
            matmul(x, w, 8, 64, 8), matmul_ref(x, w), rtol=1e-4, atol=1e-4
        )
