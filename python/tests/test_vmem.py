"""L1 structural performance: every production kernel configuration must
fit VMEM with double-buffering headroom and keep the compute units fed."""

from compile import vmem


class TestVmemBudgets:
    def test_all_production_configs_fit_vmem(self):
        for fp in vmem.all_footprints():
            assert fp.vmem_fraction < 0.5, (
                f"{fp.name} ({fp.config}) uses {fp.vmem_fraction:.0%} of "
                "VMEM — no headroom for double buffering"
            )

    def test_matmul_tiles_are_mxu_aligned(self):
        fp = vmem.matmul_footprint()
        assert fp.mxu_utilization == 1.0  # full 128x128 systolic fill

    def test_matmul_footprint_scales_with_tiles(self):
        small = vmem.matmul_footprint(64, 64, 64)
        big = vmem.matmul_footprint(256, 256, 256)
        assert big.vmem_bytes == 16 * small.vmem_bytes
        assert small.mxu_utilization < 1.0  # 64-tiles underfill the MXU

    def test_nbody_dominated_by_displacement_intermediate(self):
        fp = vmem.nbody_footprint()
        disp = 256 * 256 * 3 * 8
        assert fp.vmem_bytes > disp  # intermediate accounted for
        assert fp.vmem_fraction < 0.25

    def test_nbody_tile_growth_is_quadratic(self):
        fp1 = vmem.nbody_footprint(ti=128, tj=128)
        fp2 = vmem.nbody_footprint(ti=512, tj=512)
        # the (TI, TJ) intermediates dominate -> ~16x
        assert 10 < fp2.vmem_bytes / fp1.vmem_bytes < 17

    def test_flux_batch_keeps_mxu_fed(self):
        fp = vmem.flux_footprint()
        # batched-as-GEMM fill: tiny per-element GEMMs still fill the lane
        # dimension when the batch is blocked in
        assert fp.mxu_utilization > 0.0
        assert fp.vmem_fraction < 0.05

    def test_render_prints_all_kernels(self):
        out = vmem.render()
        for name in ["matmul", "nbody", "batched_operator"]:
            assert name in out
