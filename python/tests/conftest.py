import jax

# The n-body artifact and Table V run in f64 (the paper's double-precision
# n-body test); enable x64 process-wide so f64 paths are testable.
jax.config.update("jax_enable_x64", True)
