"""AOT pipeline tests: catalog coverage, HLO text validity, manifest schema."""

import json
import os

import pytest

from compile import aot, model

EXPECTED_ARTIFACTS = {
    "mnist_train",
    "mnist_predict",
    "cifar_train",
    "nbody_step",
    "pyfr_step",
}


class TestCatalog:
    def test_covers_all_expected(self):
        assert set(aot.build_catalog().keys()) == EXPECTED_ARTIFACTS

    def test_input_signatures_are_ordered_and_typed(self):
        for name, (_, ins, outs, flops) in aot.build_catalog().items():
            assert len(ins) > 0 and len(outs) > 0
            assert flops > 0, name
            for in_name, spec in ins:
                assert isinstance(in_name, str)
                assert all(d > 0 for d in spec.shape)

    def test_mnist_train_signature(self):
        _, ins, outs, _ = aot.build_catalog()["mnist_train"]
        assert [n for n, _ in ins[:8]] == [
            n for n, _ in model.MNIST_PARAM_SHAPES
        ]
        assert ins[8][1].shape == (model.MNIST_BATCH, 28, 28, 1)
        assert ins[9][1].shape == (model.MNIST_BATCH,)
        assert outs[-1] == "loss"


class TestEmit:
    def test_emit_single_artifact(self, tmp_path):
        manifest = aot.emit(str(tmp_path), only="pyfr_step")
        assert set(manifest["artifacts"].keys()) == {"pyfr_step"}
        entry = manifest["artifacts"]["pyfr_step"]
        hlo_path = tmp_path / entry["file"]
        assert hlo_path.exists()
        text = hlo_path.read_text()
        assert "ENTRY" in text and "HloModule" in text
        # signature in manifest matches declared model constants
        assert entry["inputs"][0]["shape"] == [
            model.PYFR_E,
            model.PYFR_P,
            model.PYFR_V,
        ]
        assert entry["inputs"][0]["dtype"] == "f32"
        assert entry["outputs"][0]["name"] == "u"
        mf = json.loads((tmp_path / "manifest.json").read_text())
        assert mf["generator"] == aot.GENERATOR_VERSION

    def test_emit_only_merges_into_existing_manifest(self, tmp_path):
        aot.emit(str(tmp_path), only="pyfr_step")
        manifest = aot.emit(str(tmp_path), only="nbody_step")
        assert {"pyfr_step", "nbody_step"} <= set(manifest["artifacts"])

    def test_nbody_artifact_is_f64(self, tmp_path):
        manifest = aot.emit(str(tmp_path), only="nbody_step")
        entry = manifest["artifacts"]["nbody_step"]
        assert all(i["dtype"] == "f64" for i in entry["inputs"])
        assert entry["inputs"][0]["shape"] == [model.NBODY_N, 4]


class TestCheckedInArtifacts:
    """Validate the artifacts/ directory the Makefile builds (if present)."""

    ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")

    @pytest.fixture()
    def manifest(self):
        path = os.path.join(self.ART, "manifest.json")
        if not os.path.exists(path):
            pytest.skip("artifacts/ not built yet (run `make artifacts`)")
        with open(path) as f:
            return json.load(f)

    def test_manifest_complete(self, manifest):
        assert set(manifest["artifacts"].keys()) == EXPECTED_ARTIFACTS

    def test_all_hlo_files_exist_and_parse_shape(self, manifest):
        for name, entry in manifest["artifacts"].items():
            p = os.path.join(self.ART, entry["file"])
            assert os.path.exists(p), f"missing artifact for {name}"
            head = open(p).read(64)
            assert head.startswith("HloModule"), name

    def test_flops_recorded(self, manifest):
        for name, entry in manifest["artifacts"].items():
            assert entry["flops_per_call"] > 0, name
