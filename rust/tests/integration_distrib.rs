//! Integration: the distributed image-distribution subsystem end to end —
//! registry → sharded cluster → CAS → node caches → ShifterRuntime — and
//! its equivalence with the classic single-gateway path.

use shifter_rs::distrib::DistributionFabric;
use shifter_rs::gateway::{ImageSource, PullState};
use shifter_rs::image::builder::{self, ImageBuilder};
use shifter_rs::pfs::LustreFs;
use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

#[test]
fn container_from_fabric_matches_single_gateway() {
    let registry = Registry::dockerhub();
    let profile = SystemProfile::piz_daint();
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]);

    // classic path
    let mut gateway = ImageGateway::new(LustreFs::piz_daint());
    gateway.pull(&registry, "ubuntu:xenial").unwrap();
    let classic = rt.run(&gateway, &opts).unwrap();

    // distributed path
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let state = fabric
        .pull_blocking(&registry, "ubuntu:xenial", "alice")
        .unwrap();
    assert_eq!(state, PullState::Ready);
    let distributed = rt.run(&fabric, &opts).unwrap();

    // same image, same container contents, same env — only the fetch
    // model differs
    assert_eq!(classic.image, distributed.image);
    assert_eq!(
        classic.exec(&["cat", "/etc/os-release"]).unwrap(),
        distributed.exec(&["cat", "/etc/os-release"]).unwrap()
    );
    assert_eq!(classic.env, distributed.env);
    assert!(distributed.stage_log.completed());
}

#[test]
fn warm_node_restarts_much_faster() {
    let registry = Registry::dockerhub();
    let profile = SystemProfile::piz_daint();
    let rt = ShifterRuntime::new(&profile);
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    fabric
        .pull_blocking(&registry, "ubuntu:xenial", "alice")
        .unwrap();

    // 512-node job start: every node cold-fills from the PFS broadcast
    let cold_opts =
        RunOptions::new("ubuntu:xenial", &["true"]).on_nodes(3, 512);
    let cold = rt.run(&fabric, &cold_opts).unwrap();
    // second container start on the same node: squashfs already local
    let warm = rt.run(&fabric, &cold_opts).unwrap();
    assert!(
        cold.startup_overhead_secs() > 2.0 * warm.startup_overhead_secs(),
        "cold={}s warm={}s",
        cold.startup_overhead_secs(),
        warm.startup_overhead_secs()
    );
    assert!(fabric.node_has_image(3, "ubuntu:xenial"));
    let stats = fabric.cache_stats();
    assert_eq!((stats.hits, stats.misses), (1, 1));
}

#[test]
fn unpulled_reference_fails_like_the_classic_path() {
    let profile = SystemProfile::piz_daint();
    let rt = ShifterRuntime::new(&profile);
    let fabric = DistributionFabric::new(2, LustreFs::piz_daint());
    let err = rt
        .run(&fabric, &RunOptions::new("pynamic:1.3", &["true"]))
        .unwrap_err();
    assert!(err.to_string().contains("not pulled"));
}

#[test]
fn catalog_storm_spreads_images_across_shards() {
    let base = builder::ubuntu_xenial();
    let mut registry = Registry::dockerhub();
    let mut refs = Vec::new();
    for i in 0..12 {
        let name = format!("team-{i:02}/app:2.0");
        registry.push(
            ImageBuilder::from_image(&base, &name)
                .file(&format!("/opt/team-{i:02}/bin"), 30_000_000)
                .build(),
        );
        refs.push(name);
    }
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    for name in &refs {
        fabric.request(&registry, name, "ci").unwrap();
    }
    fabric.tick(&registry, 1e9);
    assert!(fabric.cluster().drained());

    // every image is resolvable through the facade afterwards
    for name in &refs {
        assert!(fabric.resolve(name).is_ok(), "{name} not resolvable");
    }
    // more than one shard did work, and the CAS deduped the shared base
    let busy = fabric
        .cluster()
        .cluster_status()
        .iter()
        .filter(|s| s.images > 0)
        .count();
    assert!(busy >= 2, "expected the storm to use >= 2 shards");
    let cas = fabric.cluster().cas();
    assert!(cas.stored_bytes() < cas.logical_bytes());
}

#[test]
fn fabric_pull_is_idempotent_per_reference() {
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    fabric
        .pull_blocking(&registry, "ubuntu:xenial", "alice")
        .unwrap();
    let logical_once = fabric.cluster().cas().logical_bytes();
    let state = fabric
        .pull_blocking(&registry, "ubuntu:xenial", "bob")
        .unwrap();
    assert_eq!(state, PullState::Ready);
    assert_eq!(
        fabric.cluster().cas().logical_bytes(),
        logical_once,
        "re-pulling must not re-register layers"
    );
}
