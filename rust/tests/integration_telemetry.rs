//! Integration: end-to-end telemetry (DESIGN.md S23) — a heterogeneous
//! `Site::launch` emits exactly one `job`-rooted span tree with
//! parent-child time containment and one injection span per activated
//! host extension; a multi-tenant storm's Chrome trace-event JSONL
//! parses line by line and its span tree covers >= 95% of every job's
//! reported wall time; a site built without telemetry records nothing.

use std::collections::BTreeMap;

use shifter_rs::launch::{JobSpec, RetryPolicy};
use shifter_rs::telemetry::SpanRecord;
use shifter_rs::util::json::Json;
use shifter_rs::{Site, StormSpec, SystemProfile};

const EPS: f64 = 1e-6;

/// Index spans by id and assert the tree invariants every trace must
/// hold: unique ids, existing parents, and child intervals contained in
/// their parent's interval.
fn assert_well_formed_tree(spans: &[SpanRecord]) {
    let mut by_id: BTreeMap<u64, &SpanRecord> = BTreeMap::new();
    for s in spans {
        assert!(
            by_id.insert(s.id, s).is_none(),
            "span id {} recorded twice",
            s.id
        );
    }
    for s in spans {
        let Some(pid) = s.parent else { continue };
        let parent = by_id
            .get(&pid)
            .unwrap_or_else(|| panic!("span {} orphaned: no parent {pid}", s.id));
        assert!(
            s.start_secs() >= parent.start_secs() - EPS,
            "span {} ({}) starts at {} before its parent {} ({}) at {}",
            s.id,
            s.name,
            s.start_secs(),
            parent.id,
            parent.name,
            parent.start_secs()
        );
        assert!(
            s.end_secs() <= parent.end_secs() + EPS,
            "span {} ({}) ends at {} after its parent {} ({}) at {}",
            s.id,
            s.name,
            s.end_secs(),
            parent.id,
            parent.name,
            parent.end_secs()
        );
    }
}

#[test]
fn hetero_launch_emits_one_rooted_contained_span_tree() {
    let mut site = Site::builder()
        .hetero_daint_linux(8)
        .telemetry(true)
        .build()
        .unwrap();
    let spec =
        JobSpec::new("nvidia/cuda-image:8.0", &["./deviceQuery"], 8)
            .with_gpus(1);
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 8);

    let spans = site.telemetry().spans();
    assert_well_formed_tree(&spans);

    // exactly one root, and it is the job span
    let roots: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.parent.is_none()).collect();
    assert_eq!(roots.len(), 1, "one launch => one root span");
    assert_eq!(roots[0].category, "job");
    assert!(roots[0].name.contains("cuda-image"));

    // the pull rides on the gateway track under the job root
    let pull = spans
        .iter()
        .find(|s| s.category == "pull")
        .expect("pull span");
    assert_eq!(pull.parent, Some(roots[0].id));
    assert_eq!(pull.track, "gateway");
    assert!(pull.dur_secs > 0.0);

    // one node span per slot, each parented on the job root
    let nodes: Vec<&SpanRecord> =
        spans.iter().filter(|s| s.category == "node").collect();
    assert_eq!(nodes.len(), 8);
    for n in &nodes {
        assert_eq!(n.parent, Some(roots[0].id));
        assert!(
            n.start_secs() >= pull.end_secs() - EPS,
            "node execution begins after the coalesced pull"
        );
    }

    // one injection span per activated extension, launch-report-exact
    for (ext, activations) in report.extension_counts() {
        let injects = spans
            .iter()
            .filter(|s| {
                s.category == "ext"
                    && s.name == format!("ext:{ext}:inject")
            })
            .count();
        assert_eq!(
            injects, activations,
            "extension {ext}: one inject span per activation"
        );
    }
    // the GPU extension really activated on every node of this job
    assert!(report
        .extension_counts()
        .iter()
        .any(|(ext, n)| *ext == "gpu" && *n == 8));
}

/// Sorted-merge union length of `intervals` clipped to `[lo, hi]`.
fn union_len(intervals: &[(f64, f64)], lo: f64, hi: f64) -> f64 {
    let mut clipped: Vec<(f64, f64)> = intervals
        .iter()
        .map(|&(a, b)| (a.max(lo), b.min(hi)))
        .filter(|&(a, b)| b > a)
        .collect();
    clipped.sort_by(|x, y| x.0.total_cmp(&y.0));
    let mut total = 0.0;
    let mut cur: Option<(f64, f64)> = None;
    for (a, b) in clipped {
        match cur {
            Some((cs, ce)) if a <= ce => cur = Some((cs, ce.max(b))),
            Some((cs, ce)) => {
                total += ce - cs;
                cur = Some((a, b));
            }
            None => cur = Some((a, b)),
        }
    }
    if let Some((cs, ce)) = cur {
        total += ce - cs;
    }
    total
}

#[test]
fn storm_trace_jsonl_covers_95_percent_of_every_job() {
    // the same shape `shifterimg trace --tenants 4 --jobs 32` replays
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(64)
        .telemetry(true)
        .retry_policy(RetryPolicy::strict())
        .build()
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(4).jobs(32))
        .unwrap();
    assert_eq!(report.failed(), 0);
    assert_well_formed_tree(&site.telemetry().spans());

    let jsonl = site.telemetry().chrome_trace_jsonl();
    struct Ev {
        ts: f64,
        dur: f64,
        parent: Option<u64>,
        cat: String,
    }
    let mut events: BTreeMap<u64, Ev> = BTreeMap::new();
    let (mut meta_lines, mut counter_lines) = (0usize, 0usize);
    for line in jsonl.lines() {
        let v = Json::parse(line).expect("every trace line is valid JSON");
        match v.get("ph").and_then(Json::as_str) {
            Some("M") => {
                meta_lines += 1;
                assert_eq!(
                    v.get("name").and_then(Json::as_str),
                    Some("thread_name")
                );
                assert!(v
                    .at(&["args", "name"])
                    .and_then(Json::as_str)
                    .is_some_and(|n| !n.is_empty()));
            }
            Some("C") => {
                counter_lines += 1;
                assert!(v
                    .at(&["args", "value"])
                    .and_then(Json::as_f64)
                    .is_some());
            }
            Some("X") => {
                let id = v
                    .at(&["args", "id"])
                    .and_then(Json::as_u64)
                    .expect("span event carries its id");
                let parent = match v.at(&["args", "parent"]) {
                    Some(Json::Null) | None => None,
                    Some(p) => Some(p.as_u64().expect("numeric parent")),
                };
                events.insert(
                    id,
                    Ev {
                        ts: v
                            .get("ts")
                            .and_then(Json::as_f64)
                            .expect("ts"),
                        dur: v
                            .get("dur")
                            .and_then(Json::as_f64)
                            .expect("dur"),
                        parent,
                        cat: v
                            .get("cat")
                            .and_then(Json::as_str)
                            .expect("cat")
                            .to_string(),
                    },
                );
            }
            other => panic!("unexpected event phase {other:?}"),
        }
    }
    assert!(meta_lines > 0, "thread_name metadata present");
    assert!(counter_lines > 0, "counter events present");

    // transitive children of each job root (spans nest at most a few
    // levels: job -> pull/wait/node/app -> run -> stage/ext)
    let mut children: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
    for (id, e) in &events {
        if let Some(p) = e.parent {
            children.entry(p).or_default().push(*id);
        }
    }
    let roots: Vec<u64> = events
        .iter()
        .filter(|(_, e)| e.parent.is_none() && e.cat == "job")
        .map(|(id, _)| *id)
        .collect();
    assert_eq!(roots.len(), 32, "one root span per storm job");

    for root in roots {
        let job = &events[&root];
        assert!(job.dur > 0.0, "job {root} has wall time");
        let mut intervals: Vec<(f64, f64)> = Vec::new();
        let mut stack: Vec<u64> = children
            .get(&root)
            .cloned()
            .unwrap_or_default();
        while let Some(id) = stack.pop() {
            let e = &events[&id];
            intervals.push((e.ts, e.ts + e.dur));
            if let Some(kids) = children.get(&id) {
                stack.extend(kids.iter().copied());
            }
        }
        assert!(
            !intervals.is_empty(),
            "job {root} has descendant spans"
        );
        let covered = union_len(&intervals, job.ts, job.ts + job.dur);
        let coverage = covered / job.dur;
        assert!(
            coverage >= 0.95,
            "job {root}: descendants cover {:.1}% of its wall time",
            coverage * 100.0
        );
    }
}

#[test]
fn disabled_telemetry_records_nothing_across_the_stack() {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(8)
        .build()
        .unwrap();
    site.pull("ubuntu:xenial").unwrap();
    site.launch(&JobSpec::new("ubuntu:xenial", &["true"], 8))
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(2).jobs(4))
        .unwrap();
    assert_eq!(report.failed(), 0);

    let tel = site.telemetry();
    assert!(!tel.enabled());
    assert_eq!(tel.span_count(), 0);
    assert!(tel.counters().is_empty());
    assert_eq!(tel.chrome_trace_jsonl(), "");
    let snap = tel.snapshot_json();
    assert_eq!(snap.get("spans").and_then(Json::as_u64), Some(0));
}
