//! Integration: the full §III.B user workflow across all three systems —
//! build → push → pull → run — and the §IV support paths through the
//! complete runtime stack (registry + gateway + WLM + shifter).

use shifter_rs::image::builder;
use shifter_rs::pfs::LustreFs;
use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::wlm::{GresRequest, Slurm};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn gateway_for(profile: &SystemProfile) -> ImageGateway {
    ImageGateway::new(profile.pfs.clone().unwrap_or_else(LustreFs::piz_daint))
}

#[test]
fn full_workflow_build_push_pull_run() {
    // 1–2: build + test on the "laptop" (the builder is the docker stand-in)
    let image = builder::pyfr_image();
    assert!(image.flatten().unwrap().exists("/usr/local/bin/pyfr"));

    // 3: push to the registry
    let mut registry = Registry::new();
    registry.push(image);

    // 4: pull into each HPC system with the gateway
    for profile in [SystemProfile::linux_cluster(), SystemProfile::piz_daint()] {
        let mut gw = gateway_for(&profile);
        let rep = gw.pull(&registry, "pyfr-image:1.5.0").unwrap();
        assert!(!rep.cached && rep.total_secs() > 0.0);

        // 5: run the container — same image, no modification
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("pyfr-image:1.5.0", &["true"]))
            .unwrap();
        assert!(c.stage_log.completed(), "{}", profile.name);
        assert!(c.rootfs.exists("/usr/local/bin/pyfr"));
    }
}

#[test]
fn os_release_example_identical_on_every_system() {
    let registry = Registry::dockerhub();
    let mut outputs = Vec::new();
    for profile in [
        SystemProfile::laptop(),
        SystemProfile::linux_cluster(),
        SystemProfile::piz_daint(),
    ] {
        let mut gw = gateway_for(&profile);
        gw.pull(&registry, "docker:ubuntu:xenial").unwrap();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(
                &gw,
                &RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]),
            )
            .unwrap();
        outputs.push(c.exec(&["cat", "/etc/os-release"]).unwrap());
    }
    // the container reports ITS OS regardless of the host OS
    assert!(outputs[0].contains("Xenial Xerus"));
    assert_eq!(outputs[0], outputs[1]);
    assert_eq!(outputs[1], outputs[2]);
}

#[test]
fn slurm_gres_drives_gpu_support_end_to_end() {
    // the §IV.A SLURM example: srun --gres=gpu:N shifter --image=cuda ...
    let profile = SystemProfile::linux_cluster();
    let registry = Registry::dockerhub();
    let mut gw = gateway_for(&profile);
    gw.pull(&registry, "nvidia/cuda-image:8.0").unwrap();

    let mut slurm = Slurm::new(&profile);
    let alloc = slurm.salloc(2).unwrap();
    let ranks = slurm
        .srun(&alloc, 2, Some(GresRequest { gpus_per_node: 2 }))
        .unwrap();

    let rt = ShifterRuntime::new(&profile);
    for rank in &ranks {
        let mut opts =
            RunOptions::new("nvidia/cuda-image:8.0", &["./deviceQuery"]);
        opts.env = rank.env.clone();
        opts.node = rank.node as usize;
        let c = rt.run(&gw, &opts).unwrap();
        let gpu = c.gpu.as_ref().expect("GRES must trigger GPU support");
        assert_eq!(gpu.host_devices, vec![0, 1]);
        assert_eq!(gpu.container_devices, vec![0, 1]); // renumbered from 0
        let boards = c.visible_gpus(&profile, rank.node as usize);
        assert_eq!(boards.len(), 2);
        assert_eq!(boards[0].name, "Tesla K40m");
        assert_eq!(boards[1].name, "Tesla K80");
    }
}

#[test]
fn srun_without_gres_runs_cpu_only() {
    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gw = gateway_for(&profile);
    gw.pull(&registry, "nvidia/cuda-image:8.0").unwrap();
    let mut slurm = Slurm::new(&profile);
    let alloc = slurm.salloc(1).unwrap();
    let ranks = slurm.srun(&alloc, 1, None).unwrap();
    let rt = ShifterRuntime::new(&profile);
    let mut opts = RunOptions::new("nvidia/cuda-image:8.0", &["true"]);
    opts.env = ranks[0].env.clone();
    let c = rt.run(&gw, &opts).unwrap();
    assert!(c.gpu.is_none(), "no GRES, no CUDA_VISIBLE_DEVICES, no GPU");
}

#[test]
fn mpi_swap_correct_on_both_hpc_systems() {
    let registry = Registry::dockerhub();
    for (profile, expect_host) in [
        (SystemProfile::linux_cluster(), "MVAPICH2 2.1.0"),
        (SystemProfile::piz_daint(), "Cray MPT 7.5.0"),
    ] {
        let mut gw = gateway_for(&profile);
        for image in [
            "osu-benchmarks:mpich-3.1.4",
            "osu-benchmarks:mvapich2-2.2",
            "osu-benchmarks:intelmpi-2017.1",
        ] {
            gw.pull(&registry, image).unwrap();
            let rt = ShifterRuntime::new(&profile);
            let c = rt
                .run(&gw, &RunOptions::new(image, &["osu_latency"]).with_mpi())
                .unwrap();
            let rep = c.mpi.as_ref().unwrap();
            assert_eq!(rep.host_mpi, expect_host, "{image}");
            // the swapped library is what the loader now resolves
            for (cpath, hpath) in &rep.swapped {
                assert_eq!(
                    c.mounts.effective(cpath).unwrap().source,
                    *hpath
                );
            }
            // and the effective MPI reaches the system fabric
            let eff = c.effective_mpi(&profile).unwrap();
            assert!(eff.supports_fabric(profile.fabric));
        }
    }
}

#[test]
fn gateway_is_idempotent_and_digest_aware() {
    let registry = Registry::dockerhub();
    let profile = SystemProfile::piz_daint();
    let mut gw = gateway_for(&profile);
    let first = gw.pull(&registry, "tensorflow/tensorflow:1.0.0-devel-gpu-py3").unwrap();
    let second = gw.pull(&registry, "tensorflow/tensorflow:1.0.0-devel-gpu-py3").unwrap();
    assert!(!first.cached && second.cached);
    assert_eq!(gw.list().len(), 1);
}

#[test]
fn same_container_env_across_systems() {
    // portability of the environment: image env vars arrive identically
    let registry = Registry::dockerhub();
    let mut envs = Vec::new();
    for profile in [SystemProfile::linux_cluster(), SystemProfile::piz_daint()] {
        let mut gw = gateway_for(&profile);
        gw.pull(&registry, "pyfr-image:1.5.0").unwrap();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("pyfr-image:1.5.0", &["true"]))
            .unwrap();
        envs.push(c.env.clone());
    }
    assert_eq!(envs[0].get("CUDA_HOME"), envs[1].get("CUDA_HOME"));
    assert_eq!(envs[0].get("PATH"), envs[1].get("PATH"));
}
