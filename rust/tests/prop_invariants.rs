//! Property-based tests over coordinator invariants. proptest is not in
//! the offline vendor set, so this uses the crate's deterministic PRNG to
//! drive randomized cases (hundreds per property, fixed seeds → fully
//! reproducible).

use shifter_rs::fabric::{link_for, FabricKind, Transport};
use shifter_rs::gpu::parse_cuda_visible_devices;
use shifter_rs::mpi::LibtoolAbi;
use shifter_rs::util::json::Json;
use shifter_rs::util::prng::Rng;
use shifter_rs::vfs::{normalize, VNode, VirtualFs};
use shifter_rs::wlm::{GresRequest, Slurm};
use shifter_rs::SystemProfile;

const CASES: usize = 300;

fn rand_path(rng: &mut Rng, max_depth: u64) -> String {
    let depth = 1 + rng.below(max_depth);
    let mut p = String::new();
    for _ in 0..depth {
        p.push('/');
        let len = 1 + rng.below(6);
        for _ in 0..len {
            p.push((b'a' + rng.below(26) as u8) as char);
        }
    }
    p
}

#[test]
fn prop_normalize_idempotent() {
    let mut rng = Rng::new(101);
    for _ in 0..CASES {
        let p = rand_path(&mut rng, 5);
        let n1 = normalize(&p).unwrap();
        let n2 = normalize(&n1).unwrap();
        assert_eq!(n1, n2, "normalize not idempotent for {p}");
        assert!(n1.starts_with('/'));
        assert!(!n1.contains("//"));
    }
}

#[test]
fn prop_vfs_insert_then_get() {
    let mut rng = Rng::new(202);
    for case in 0..CASES {
        let mut fs = VirtualFs::new();
        let n_files = 1 + rng.below(20);
        let mut inserted = Vec::new();
        for i in 0..n_files {
            let p = rand_path(&mut rng, 4);
            if fs.insert(&p, VNode::file(i, i)).is_ok() {
                inserted.push(p);
            }
        }
        for p in &inserted {
            assert!(fs.exists(p), "case {case}: lost {p}");
            // every ancestor is a directory or the node itself
            let norm = normalize(p).unwrap();
            let mut anc = String::new();
            for comp in norm.split('/').skip(1) {
                let parent = if anc.is_empty() { "/".to_string() } else { anc.clone() };
                assert!(fs.exists(&parent));
                anc = format!("{anc}/{comp}");
            }
        }
    }
}

#[test]
fn prop_vfs_graft_preserves_subtree() {
    let mut rng = Rng::new(303);
    for _ in 0..100 {
        let mut src = VirtualFs::new();
        let n = 1 + rng.below(15);
        for i in 0..n {
            let p = format!("/data{}", rand_path(&mut rng, 3));
            let _ = src.insert(&p, VNode::file(i, i));
        }
        let mut dst = VirtualFs::new();
        dst.graft(&src, "/data", "/mnt/data").unwrap();
        for (p, node) in src.walk("/data").unwrap() {
            let target = format!("/mnt/data{}", &p["/data".len()..]);
            assert_eq!(dst.get(&target), Some(&node), "{p}");
        }
    }
}

#[test]
fn prop_libtool_replacement_rules() {
    let mut rng = Rng::new(404);
    for _ in 0..CASES {
        let c_cur = rng.below(20) as u32;
        let c_age = rng.below((c_cur + 1) as u64) as u32;
        let h_cur = rng.below(20) as u32;
        let h_age = rng.below((h_cur + 1) as u64) as u32;
        let container = LibtoolAbi::new(c_cur, 0, c_age);
        let host = LibtoolAbi::new(h_cur, 0, h_age);
        let ok = host.host_can_replace(&container);
        // definition check: soname equal AND interface coverage
        let expect = host.soname_major() == container.soname_major()
            && c_cur >= h_cur - h_age
            && c_cur <= h_cur;
        assert_eq!(ok, expect, "host {host:?} container {container:?}");
        // reflexivity: any library can replace itself
        assert!(host.host_can_replace(&host));
    }
}

#[test]
fn prop_abi_string_roundtrip() {
    let mut rng = Rng::new(505);
    for _ in 0..CASES {
        let cur = rng.below(100) as u32;
        let abi = LibtoolAbi::new(
            cur,
            rng.below(100) as u32,
            rng.below((cur + 1) as u64) as u32,
        );
        assert_eq!(LibtoolAbi::parse(&abi.abi_string()), Some(abi));
    }
}

#[test]
fn prop_cuda_visible_devices_valid_lists_roundtrip() {
    let mut rng = Rng::new(606);
    for _ in 0..CASES {
        let n = 1 + rng.below(8);
        let devs: Vec<u32> = (0..n).map(|_| rng.below(16) as u32).collect();
        let value = devs
            .iter()
            .map(|d| d.to_string())
            .collect::<Vec<_>>()
            .join(",");
        assert_eq!(parse_cuda_visible_devices(&value), Some(devs));
    }
}

#[test]
fn prop_cuda_visible_devices_never_panics_on_junk() {
    let mut rng = Rng::new(707);
    for _ in 0..CASES {
        let len = rng.below(12);
        let junk: String = (0..len)
            .map(|_| {
                let c = rng.below(96) as u8 + 32;
                c as char
            })
            .collect();
        let _ = parse_cuda_visible_devices(&junk); // must not panic
    }
}

#[test]
fn prop_link_models_monotone_in_size() {
    for kind in [FabricKind::InfinibandEdr, FabricKind::CrayAries] {
        for transport in [Transport::Native, Transport::TcpFallback] {
            let link = link_for(kind, transport);
            let mut rng = Rng::new(808);
            for _ in 0..CASES {
                let a = 32 + rng.below(4 * 1024 * 1024);
                let b = a + 1 + rng.below(1024 * 1024);
                assert!(
                    link.latency_us(b) >= link.latency_us(a),
                    "{kind:?}/{transport:?}: {a} vs {b}"
                );
            }
        }
    }
}

#[test]
fn prop_slurm_placement_complete_and_bounded() {
    let pd = SystemProfile::piz_daint();
    let mut rng = Rng::new(909);
    for _ in 0..100 {
        let nodes = 1 + rng.below(64) as u32;
        let mut slurm = Slurm::new(&pd);
        let alloc = slurm.salloc(nodes).unwrap();
        let ntasks = 1 + rng.below(alloc.capacity() as u64) as u32;
        let gres = if rng.below(2) == 0 {
            Some(GresRequest { gpus_per_node: 1 })
        } else {
            None
        };
        let ranks = slurm.srun(&alloc, ntasks, gres).unwrap();
        assert_eq!(ranks.len(), ntasks as usize);
        // ranks are unique and placed on allocated nodes
        for (i, r) in ranks.iter().enumerate() {
            assert_eq!(r.rank, i as u32);
            assert!(alloc.nodes.contains(&r.node));
            assert_eq!(
                r.env.contains_key("CUDA_VISIBLE_DEVICES"),
                gres.is_some()
            );
        }
        // no node exceeds its core capacity
        for &node in &alloc.nodes {
            let on_node =
                ranks.iter().filter(|r| r.node == node).count() as u32;
            assert!(on_node <= alloc.cores_per_node);
        }
    }
}

#[test]
fn prop_json_roundtrip_random_values() {
    fn rand_json(rng: &mut Rng, depth: u64) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.below(2) == 0),
            2 => Json::Num((rng.below(2_000_000) as f64 - 1e6) / 8.0),
            3 => {
                let len = rng.below(10);
                Json::Str(
                    (0..len)
                        .map(|_| (b'a' + rng.below(26) as u8) as char)
                        .collect(),
                )
            }
            4 => Json::Arr(
                (0..rng.below(4)).map(|_| rand_json(rng, depth - 1)).collect(),
            ),
            _ => Json::Obj(
                (0..rng.below(4))
                    .map(|i| (format!("k{i}"), rand_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    let mut rng = Rng::new(1010);
    for _ in 0..CASES {
        let v = rand_json(&mut rng, 3);
        let text = v.to_string();
        assert_eq!(Json::parse(&text).unwrap(), v, "text: {text}");
    }
}

#[test]
fn prop_image_flatten_last_writer_wins() {
    use shifter_rs::image::{Image, ImageManifest, ImageRef, Layer};
    let mut rng = Rng::new(1111);
    for _ in 0..60 {
        let n_layers = 2 + rng.below(4) as usize;
        let shared = "/shared/file";
        let mut layers = Vec::new();
        let mut last_size = 0;
        for li in 0..n_layers {
            let mut t = VirtualFs::new();
            last_size = 100 + li as u64;
            t.add_file(shared, last_size, li as u64).unwrap();
            let p = rand_path(&mut rng, 3);
            let _ = t.insert(&p, VNode::file(1, 1));
            layers.push(Layer::new(t, vec![]));
        }
        let img = Image {
            reference: ImageRef::parse("prop:1").unwrap(),
            manifest: ImageManifest::default(),
            layers,
        };
        let flat = img.flatten().unwrap();
        assert_eq!(flat.get(shared).unwrap().size(), last_size);
    }
}

#[test]
fn prop_volume_spec_parse_roundtrip_and_reserved_rejection() {
    use shifter_rs::shifter::{VolumeError, VolumeSpec};
    let mut rng = Rng::new(1212);
    let mut host = VirtualFs::new();
    for _ in 0..CASES {
        let h = rand_path(&mut rng, 3);
        let c = format!("/data{}", rand_path(&mut rng, 2));
        host.mkdir_p(&h).unwrap();
        let ro = rng.below(2) == 0;
        let spec_str = format!("{h}:{c}{}", if ro { ":ro" } else { "" });
        let v = VolumeSpec::parse(&spec_str).unwrap();
        assert_eq!(v.host_path, h);
        assert_eq!(v.read_only, ro);
        assert!(v.validate(&host).is_ok(), "{spec_str}");
        // reserved targets always rejected, whatever the host path
        for reserved in ["/", "/etc", "/dev", "/usr"] {
            let bad = VolumeSpec::parse(&format!("{h}:{reserved}")).unwrap();
            assert!(matches!(
                bad.validate(&host),
                Err(VolumeError::ReservedTarget(_))
            ));
        }
    }
}

#[test]
fn prop_kernel_version_ordering_total() {
    use shifter_rs::shifter::preflight::KernelVersion;
    let mut rng = Rng::new(1313);
    for _ in 0..CASES {
        let a = KernelVersion::new(
            rng.below(6) as u32,
            rng.below(20) as u32,
            rng.below(100) as u32,
        );
        let b = KernelVersion::new(
            rng.below(6) as u32,
            rng.below(20) as u32,
            rng.below(100) as u32,
        );
        // antisymmetry + parse/format coherence
        if a < b {
            assert!(b > a);
        }
        let s = format!("{}.{}.{}", a.major, a.minor, a.patch);
        assert_eq!(KernelVersion::parse(&s), Some(a));
    }
}

// ---------------------------------------------------------------------------
// HostExtension invariants (DESIGN.md S22)
// ---------------------------------------------------------------------------

mod ext_props {
    use std::collections::BTreeSet;
    use std::sync::Arc;

    use shifter_rs::netfab::NetworkSupport;
    use shifter_rs::shifter::{
        ExtensionRegistry, GpuExtension, HostExtension, MpiExtension,
        RunOptions, ShifterRuntime,
    };
    use shifter_rs::util::prng::Rng;
    use shifter_rs::vfs::VirtualFs;
    use shifter_rs::{ImageGateway, Registry, SystemProfile};

    const IMAGE: &str = "osu-benchmarks:mpich-3.1.4";

    fn daint_gw() -> (SystemProfile, ImageGateway) {
        let profile = SystemProfile::piz_daint();
        let registry = Registry::dockerhub();
        let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
        gw.pull(&registry, IMAGE).unwrap();
        (profile, gw)
    }

    /// Randomize the trigger surface: CVD value, --mpi flag, SHIFTER_NET
    /// value, fallback veto.
    fn random_opts(rng: &mut Rng) -> RunOptions {
        let mut opts = RunOptions::new(IMAGE, &["osu_latency"]);
        match rng.below(4) {
            0 => {}
            1 => opts = opts.with_env("CUDA_VISIBLE_DEVICES", "0"),
            2 => opts = opts.with_env("CUDA_VISIBLE_DEVICES", "NoDevFiles"),
            _ => opts = opts.with_env("CUDA_VISIBLE_DEVICES", ""),
        }
        if rng.below(2) == 0 {
            opts = opts.with_mpi();
        }
        match rng.below(3) {
            0 => {}
            1 => opts = opts.with_env("SHIFTER_NET", "host"),
            _ => opts = opts.with_env("SHIFTER_NET", "bogus"),
        }
        if rng.below(3) == 0 {
            opts = opts.with_env("SHIFTER_NET_FALLBACK", "1");
        }
        opts
    }

    #[test]
    fn prop_extension_activation_deterministic_per_seed() {
        let (profile, gw) = daint_gw();
        let rt = ShifterRuntime::new(&profile);
        let mut rng = Rng::new(1414);
        for case in 0..60 {
            let opts = random_opts(&mut rng);
            let a = rt.run(&gw, &opts);
            let b = rt.run(&gw, &opts);
            match (a, b) {
                (Ok(ca), Ok(cb)) => {
                    assert_eq!(ca.mounts, cb.mounts, "case {case}");
                    assert_eq!(ca.env, cb.env, "case {case}");
                    assert_eq!(ca.extensions, cb.extensions, "case {case}");
                    assert_eq!(ca.gpu, cb.gpu, "case {case}");
                    assert_eq!(ca.mpi, cb.mpi, "case {case}");
                    assert_eq!(ca.net, cb.net, "case {case}");
                }
                (Err(ea), Err(eb)) => {
                    assert_eq!(
                        ea.to_string(),
                        eb.to_string(),
                        "case {case}"
                    );
                }
                (a, b) => panic!(
                    "case {case}: runs disagree: {:?} vs {:?}",
                    a.is_ok(),
                    b.is_ok()
                ),
            }
        }
    }

    #[test]
    fn prop_injection_idempotent_on_rerun() {
        // running the same fully-loaded request repeatedly must converge:
        // identical rootfs, identical mount multiset, identical reports
        let (profile, gw) = daint_gw();
        let rt = ShifterRuntime::new(&profile);
        let opts = RunOptions::new(IMAGE, &["osu_latency"])
            .with_mpi()
            .with_env("CUDA_VISIBLE_DEVICES", "0")
            .with_env("SHIFTER_NET", "host");
        let first = rt.run(&gw, &opts).unwrap();
        for _ in 0..3 {
            let again = rt.run(&gw, &opts).unwrap();
            assert_eq!(again.rootfs, first.rootfs);
            assert_eq!(again.mounts, first.mounts);
            assert_eq!(again.extensions, first.extensions);
        }
    }

    fn ext_by_index(i: usize) -> Box<dyn HostExtension> {
        match i {
            0 => Box::new(GpuExtension),
            1 => Box::new(MpiExtension),
            _ => Box::new(NetworkSupport),
        }
    }

    #[test]
    fn prop_registry_order_never_changes_the_mount_set() {
        // all 3! injection orders of {gpu, mpi, net}: the resulting mount
        // SET (source, target, origin) and the rootfs must be identical —
        // extension resources are disjoint, so order cannot matter
        let (profile, gw) = daint_gw();
        let opts = RunOptions::new(IMAGE, &["osu_latency"])
            .with_mpi()
            .with_env("CUDA_VISIBLE_DEVICES", "0")
            .with_env("SHIFTER_NET", "host");
        type MountSet = BTreeSet<(String, String, &'static str)>;
        let mut reference: Option<(MountSet, VirtualFs)> = None;
        for perm in [
            [0usize, 1, 2],
            [0, 2, 1],
            [1, 0, 2],
            [1, 2, 0],
            [2, 0, 1],
            [2, 1, 0],
        ] {
            let mut registry = ExtensionRegistry::empty();
            for i in perm {
                registry.register(ext_by_index(i));
            }
            let rt = ShifterRuntime::new(&profile)
                .with_extensions(Arc::new(registry));
            let c = rt.run(&gw, &opts).unwrap();
            assert_eq!(c.extensions.len(), 3, "{perm:?}");
            let mounts: MountSet = c
                .mounts
                .iter()
                .map(|m| (m.source.clone(), m.target.clone(), m.origin))
                .collect();
            match &reference {
                None => reference = Some((mounts, c.rootfs.clone())),
                Some((ref_mounts, ref_rootfs)) => {
                    assert_eq!(&mounts, ref_mounts, "order {perm:?}");
                    assert_eq!(&c.rootfs, ref_rootfs, "order {perm:?}");
                }
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Content-defined chunking invariants (DESIGN.md S25)
// ---------------------------------------------------------------------------

mod cdc_props {
    use shifter_rs::distrib::Chunker;
    use shifter_rs::util::prng::Rng;

    fn rand_bytes(rng: &mut Rng, len: usize) -> Vec<u8> {
        (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
    }

    #[test]
    fn prop_chunk_reassembly_roundtrips() {
        let mut rng = Rng::new(2020);
        for case in 0..40 {
            let len = 1 + rng.below(200_000) as usize;
            let target = 1u64 << (9 + rng.below(5)); // 512 B .. 8 KB
            let chunker = Chunker::new(target, rng.below(1 << 32));
            let buf = rand_bytes(&mut rng, len);
            let chunks = chunker.chunk(&buf);
            // the chunks partition the input: contiguous offsets, lengths
            // summing to the buffer, and concatenation reassembles it
            let mut cursor = 0u64;
            let mut rebuilt = Vec::with_capacity(len);
            for c in &chunks {
                assert_eq!(c.offset, cursor, "case {case}: gap in chunks");
                assert!(c.length > 0, "case {case}: empty chunk");
                let (s, e) = (c.offset as usize, (c.offset + c.length) as usize);
                rebuilt.extend_from_slice(&buf[s..e]);
                cursor += c.length;
            }
            assert_eq!(cursor, len as u64, "case {case}: lengths must cover");
            assert_eq!(rebuilt, buf, "case {case}: reassembly not byte-identical");
            // chunk digests are a pure function of the bytes: re-chunking
            // the reassembled buffer reproduces the exact sequence
            assert_eq!(chunker.chunk(&rebuilt), chunks, "case {case}");
        }
    }

    #[test]
    fn prop_boundaries_stable_under_midstream_edits() {
        let mut rng = Rng::new(2121);
        for case in 0..25u64 {
            let chunker = Chunker::new(4_096, 31 + case);
            let len = 300_000 + rng.below(100_000) as usize;
            let mut buf = rand_bytes(&mut rng, len);
            let before = chunker.chunk(&buf);

            // a same-length edit somewhere in the middle third
            let edit_len = 1 + rng.below(2_000) as usize;
            let start = len / 3 + rng.below((len / 3 - edit_len) as u64) as usize;
            for b in &mut buf[start..start + edit_len] {
                *b = b.wrapping_add(1);
            }
            let after = chunker.chunk(&buf);

            // cut points are content-local: everything outside a bounded
            // window around the edit re-aligns to the same chunks (same
            // offset, length, and digest — the CAS dedups them)
            let max_shared = before.len().min(after.len());
            let prefix = before
                .iter()
                .zip(&after)
                .take_while(|(a, b)| a == b)
                .count();
            let suffix = before
                .iter()
                .rev()
                .zip(after.iter().rev())
                .take_while(|(a, b)| a == b)
                .count()
                .min(max_shared - prefix);
            assert!(prefix > 0, "case {case}: no shared prefix chunk");
            assert!(suffix > 0, "case {case}: no shared suffix chunk");
            let changed: u64 = before[prefix..before.len() - suffix]
                .iter()
                .map(|c| c.length)
                .sum();
            let bound = edit_len as u64 + 8 * chunker.max_bytes();
            assert!(
                changed <= bound,
                "case {case}: a {edit_len} B edit rewrote {changed} B of \
                 chunks (bound {bound} B)"
            );
        }
    }

    #[test]
    fn prop_chunking_deterministic_per_seed() {
        let mut rng = Rng::new(2222);
        let cases = 25;
        let mut diverged = 0;
        for case in 0..cases {
            let buf = rand_bytes(&mut rng, 150_000);
            let seed = rng.below(1 << 48);
            let a = Chunker::new(4_096, seed).chunk(&buf);
            let b = Chunker::new(4_096, seed).chunk(&buf);
            assert_eq!(a, b, "case {case}: same seed must reproduce cuts");
            // synthetic chunks share the per-seed determinism guarantee
            let s1 = Chunker::new(1 << 20, seed)
                .synthetic_chunks(0xBEEF, 50_000_000);
            let s2 = Chunker::new(1 << 20, seed)
                .synthetic_chunks(0xBEEF, 50_000_000);
            assert_eq!(s1, s2, "case {case}");
            // a different seed keys a different gear table: cut points move
            let other = Chunker::new(4_096, seed ^ 0x5bd1_e995).chunk(&buf);
            let cuts = |v: &[shifter_rs::distrib::Chunk]| {
                v.iter().map(|c| c.offset).collect::<Vec<_>>()
            };
            if cuts(&a) != cuts(&other) {
                diverged += 1;
            }
        }
        assert!(
            diverged > cases / 2,
            "different seeds moved cuts in only {diverged}/{cases} cases"
        );
    }
}
