//! Integration: the multi-tenant workload layer (DESIGN.md S20), driven
//! through the `Site` facade (DESIGN.md S21) — a synthesized tenant
//! storm runs end to end over the hetero cluster and the shared fabric,
//! fair-share + backfill beats FIFO under contention, cross-job pulls
//! coalesce, warm caches survive across jobs, and the whole simulation
//! is deterministic.

use shifter_rs::launch::{JobSpec, RetryPolicy};
use shifter_rs::tenancy::{
    unique_image_refs, FairShare, Fifo, JobClass, SchedulingPolicy,
    TenancyReport, TenantJob, TrafficModel,
};
use shifter_rs::{Site, StormSpec};

fn hetero_site(nodes: u32) -> Site {
    // strict retry: deterministic per-node timings and exact cache/pull
    // accounting, matching the scheduler's own default
    Site::builder()
        .hetero_daint_linux(nodes)
        .gateway_shards(4)
        .retry_policy(RetryPolicy::strict())
        .build()
        .expect("valid test site")
}

/// Replay an explicit stream under `policy` on a fresh hetero site.
fn run_stream(
    nodes: u32,
    jobs: &[TenantJob],
    policy: impl SchedulingPolicy + 'static,
) -> TenancyReport {
    hetero_site(nodes)
        .run_storm(
            &StormSpec::new().job_stream(jobs.to_vec()).policy(policy),
        )
        .expect("storm runs")
}

fn small_storm(jobs: u32) -> TrafficModel {
    TrafficModel {
        tenants: 4,
        jobs,
        max_width: 32,
        ..TrafficModel::default()
    }
}

fn cpu_job(
    id: u32,
    tenant: u32,
    arrival: f64,
    width: u32,
    runtime: f64,
) -> TenantJob {
    TenantJob {
        id,
        tenant: format!("tenant-{tenant:02}"),
        tenant_idx: tenant,
        arrival_secs: arrival,
        runtime_secs: runtime,
        class: JobClass::Cpu,
        spec: JobSpec::new("ubuntu:xenial", &["true"], width),
    }
}

#[test]
fn tenant_storm_runs_end_to_end_on_the_hetero_cluster() {
    let site = hetero_site(64);
    let stream = small_storm(24).generate(site.cluster());
    assert_eq!(stream.len(), 24);
    let report = run_stream(64, &stream, FairShare::default());

    assert_eq!(report.completed(), 24, "every job must complete");
    assert_eq!(report.failed(), 0);
    // GPU/MPI/CPU classes all launch cleanly on both partitions
    assert!(report.records.iter().all(|r| r.failed_slots == 0));
    // one pull job per unique image across all concurrent jobs; the
    // stream reuses images across jobs, so the equality is a real
    // cross-job coalescing check
    let unique = unique_image_refs(&stream);
    assert!(stream.len() > unique.len());
    assert_eq!(report.coalescing.jobs, unique.len());
    assert_eq!(report.unique_images, unique.len());
    // the cluster did real work and the report accounts for it
    assert!(report.utilization() > 0.0 && report.utilization() <= 1.0);
    assert!(report.makespan_secs > 0.0);
    assert!(!report.tenants.is_empty());
    for t in &report.tenants {
        assert!(t.jobs > 0);
        assert!(t.stretch.worst >= 1.0);
        assert!(t.wait.p99 >= t.wait.p50);
    }
    // JSON artifact shape is consumable
    let json = report.to_json();
    assert_eq!(json.get("completed").unwrap().as_u64(), Some(24));
    let parsed =
        shifter_rs::util::json::Json::parse(&json.to_string()).unwrap();
    assert_eq!(
        parsed.get("jobs").unwrap().as_arr().unwrap().len(),
        24
    );
}

#[test]
fn backfill_beats_fifo_on_a_contended_stream() {
    // 16 nodes; a 12-wide long job, then a 16-wide job that must wait
    // for the whole machine, then narrow short jobs that FIFO strands
    // behind it but backfill slots into the 4-node hole.
    let jobs = vec![
        cpu_job(0, 0, 0.0, 12, 800.0),
        cpu_job(1, 1, 1.0, 16, 400.0),
        cpu_job(2, 2, 2.0, 4, 60.0),
        cpu_job(3, 3, 3.0, 4, 60.0),
        cpu_job(4, 0, 4.0, 2, 120.0),
    ];
    let fifo = run_stream(16, &jobs, Fifo);
    let fair = run_stream(16, &jobs, FairShare::default());
    assert_eq!(fifo.completed(), 5);
    assert_eq!(fair.completed(), 5);
    assert_eq!(fifo.backfilled_jobs, 0, "fifo never backfills");
    assert!(
        fair.backfilled_jobs >= 2,
        "the narrow jobs must ride the hole: {}",
        fair.backfilled_jobs
    );
    // narrow jobs start inside job 0's window instead of after job 1
    for idx in [2usize, 3] {
        assert!(
            fair.records[idx].start_secs + 1.0
                < fifo.records[idx].start_secs,
            "job {idx}: fair {} vs fifo {}",
            fair.records[idx].start_secs,
            fifo.records[idx].start_secs
        );
    }
    // the reserved wide job is not delayed by the backfills
    assert!(
        fair.records[1].start_secs
            <= fifo.records[1].start_secs + 1.0
    );
    assert!(fair.makespan_secs <= fifo.makespan_secs + 1e-9);
    assert!(fair.utilization() >= fifo.utilization() - 1e-12);
    assert!(fair.max_stretch() <= fifo.max_stretch() + 1e-9);
}

#[test]
fn aging_keeps_the_heavy_tenants_from_starving_anyone() {
    // tenant 0 floods the machine; tenant 1 submits one short job late.
    // With fair-share + aging the short job must not wait behind the
    // whole flood.
    let mut jobs: Vec<TenantJob> = (0..8)
        .map(|i| cpu_job(i, 0, f64::from(i) * 5.0, 16, 300.0))
        .collect();
    jobs.push(cpu_job(8, 1, 45.0, 4, 60.0));
    let report = run_stream(16, &jobs, FairShare::default());
    assert_eq!(report.completed(), 9);
    let light = &report.records[8];
    // the flood takes 8 * ~300s serially; the light job must cut far
    // ahead of the tail instead of waiting ~2300s
    assert!(
        light.wait_secs < 1000.0,
        "light tenant waited {}s behind the flood",
        light.wait_secs
    );
    assert!(report.starved_tenants(50.0).is_empty());
}

#[test]
fn warm_node_caches_survive_across_jobs_in_one_storm() {
    // two identical-image jobs, same tenant, arriving far apart so the
    // second reuses the nodes (and their caches) of the first
    let jobs = vec![
        cpu_job(0, 0, 0.0, 8, 100.0),
        cpu_job(1, 0, 500.0, 8, 100.0),
    ];
    let report = run_stream(16, &jobs, FairShare::default());
    assert_eq!(report.completed(), 2);
    // first job cold-fills 8 nodes; the second starts on the same free
    // prefix and hits all 8 caches
    assert_eq!(report.cache.misses, 8);
    assert_eq!(report.cache.hits, 8);
    // and the shared image coalesced onto one pull job
    assert_eq!(report.coalescing.jobs, 1);
}

#[test]
fn storm_simulation_is_deterministic() {
    let run = || {
        let site = hetero_site(32);
        let stream = small_storm(12).generate(site.cluster());
        run_stream(32, &stream, FairShare::default())
    };
    let a = run();
    let b = run();
    assert_eq!(a.makespan_secs, b.makespan_secs);
    assert_eq!(a.busy_node_secs, b.busy_node_secs);
    assert_eq!(a.backfilled_jobs, b.backfilled_jobs);
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.start_secs, y.start_secs);
        assert_eq!(x.end_secs, y.end_secs);
        assert_eq!(x.wait_secs, y.wait_secs);
    }
}

#[test]
fn site_default_policy_drives_storm_via_storm_spec() {
    // a `StormSpec` with no policy override runs under the builder's
    // policy, and unset knobs (seed, max width) inherit the site shape
    let mut site = Site::builder()
        .hetero_daint_linux(32)
        .gateway_shards(4)
        .scheduling_policy(Box::new(Fifo))
        .seed(11)
        .build()
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(3).jobs(8))
        .unwrap();
    assert_eq!(report.completed(), 8);
    assert_eq!(report.policy, "fifo");
    assert_eq!(report.backfilled_jobs, 0);
}
