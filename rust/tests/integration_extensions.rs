//! Integration tests for the extension features: the pluggable
//! `HostExtension` registry (trigger/check/inject lifecycle, preflight
//! ordering, specialized-network injection and its ABI gate), user
//! volumes, the ALPS workload manager, the gateway pull queue,
//! nvidia-docker/Shifter workflow parity, Environment Modules, and the
//! in-container commands.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use shifter_rs::config::UdiRootConfig;
use shifter_rs::docker::DockerRuntime;
use shifter_rs::fabric::Transport;
use shifter_rs::gateway::{PullQueue, PullState};
use shifter_rs::hostenv::{daint_catalog, ModuleSystem};
use shifter_rs::image::builder::{self, ImageBuilder};
use shifter_rs::netfab::{self, NetSupportError};
use shifter_rs::shifter::{
    Activation, Capability, ExtensionContext, ExtensionError,
    ExtensionPayload, ExtensionRegistry, ExtensionReport, HostExtension,
    MpiSupportError, RunOptions, ShifterError, ShifterRuntime, VolumeError,
};
use shifter_rs::vfs::{MountTable, VirtualFs};
use shifter_rs::wlm::{Alps, AprunRequest, SlurmWlm, WorkloadManager};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn daint_gw(images: &[&str]) -> (SystemProfile, ImageGateway) {
    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    for i in images {
        gw.pull(&registry, i).unwrap();
    }
    (profile, gw)
}

#[test]
fn user_volume_mounted_and_visible() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("ubuntu:xenial", &["true"])
        .with_volume("/scratch:/workdir");
    let c = rt.run(&gw, &opts).unwrap();
    assert!(c.rootfs.is_dir("/workdir"));
    let vol_mounts = c.mounts.by_origin("user volume");
    assert_eq!(vol_mounts.len(), 1);
    assert_eq!(vol_mounts[0].source, "/scratch");
}

#[test]
fn reserved_volume_target_refused() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts =
        RunOptions::new("ubuntu:xenial", &["true"]).with_volume("/scratch:/etc");
    match rt.run(&gw, &opts) {
        Err(ShifterError::Volume(VolumeError::ReservedTarget(t))) => {
            assert_eq!(t, "/etc")
        }
        other => panic!("expected reserved-target error, got {other:?}"),
    }
}

#[test]
fn missing_volume_host_path_refused() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("ubuntu:xenial", &["true"])
        .with_volume("/does/not/exist:/data");
    assert!(matches!(
        rt.run(&gw, &opts),
        Err(ShifterError::Volume(VolumeError::HostPathMissing(_)))
    ));
}

#[test]
fn every_container_gets_writable_tmpfs() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let c = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert!(c.rootfs.is_dir("/tmp"));
    assert!(c.rootfs.is_dir("/run"));
    assert!(c
        .mounts
        .iter()
        .any(|m| m.target == "/tmp"
            && matches!(m.kind, shifter_rs::vfs::MountKind::Tmpfs)));
}

#[test]
fn alps_launch_drives_gpu_support_like_slurm() {
    let (profile, gw) = daint_gw(&["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&profile);
    let mut alps = Alps::new(&profile);
    let ranks = alps
        .aprun(AprunRequest {
            ranks: 2,
            per_node: 1,
            gpus: true,
        })
        .unwrap();
    for rank in &ranks {
        let mut opts = RunOptions::new("nvidia/cuda-image:8.0", &["deviceQuery"]);
        opts.env = rank.env.clone();
        opts.node = rank.node as usize;
        let c = rt.run(&gw, &opts).unwrap();
        assert!(c.gpu.is_some(), "ALPS CVD export must trigger GPU support");
        let out = c.exec(&["deviceQuery"]).unwrap();
        assert!(out.contains("Result = PASS"));
    }
}

#[test]
fn wlm_trait_interchangeable_for_the_runtime() {
    let profile = SystemProfile::piz_daint();
    let mut wlms: Vec<Box<dyn WorkloadManager>> = vec![
        Box::new(SlurmWlm::new(&profile)),
        Box::new(Alps::new(&profile)),
    ];
    for wlm in wlms.iter_mut() {
        let ranks = wlm.launch(4, 2, 1).unwrap();
        assert_eq!(ranks.len(), 4);
        assert!(ranks
            .iter()
            .all(|r| r.env.contains_key("CUDA_VISIBLE_DEVICES")));
    }
}

#[test]
fn pull_queue_feeds_the_runtime() {
    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    let mut q = PullQueue::new();
    q.request(&gw, &registry, "ubuntu:xenial", "alice").unwrap();
    assert!(ShifterRuntime::new(&profile)
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .is_err()); // not ready yet
    q.tick(&mut gw, &registry, 1e6);
    assert_eq!(q.status("ubuntu:xenial").unwrap().state, PullState::Ready);
    let c = ShifterRuntime::new(&profile)
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert!(c.stage_log.completed());
}

#[test]
fn docker_and_shifter_expose_equivalent_cuda_containers() {
    // the §V.B.1 methodology: nvidia-docker on the laptop, Shifter on the
    // HPC systems — same image, both must expose working CUDA
    let laptop = SystemProfile::laptop();
    let mut docker = DockerRuntime::new(&laptop);
    docker.load_image(builder::cuda_image());
    let mut env = BTreeMap::new();
    env.insert("CUDA_VISIBLE_DEVICES".to_string(), "0".to_string());
    let dc = docker.run("nvidia/cuda-image:8.0", &env).unwrap();

    let (daint, gw) = daint_gw(&["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&daint);
    let sc = rt
        .run(
            &gw,
            &RunOptions::new("nvidia/cuda-image:8.0", &["./nbody"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();

    // both containers: one visible device, device files present, the
    // application binary from the image unchanged
    assert_eq!(dc.gpu_devices.len(), 1);
    assert_eq!(sc.gpu.as_ref().unwrap().host_devices.len(), 1);
    for c_exists in [
        dc.rootfs.exists("/usr/local/cuda/samples/bin/nbody"),
        sc.rootfs.exists("/usr/local/cuda/samples/bin/nbody"),
        dc.rootfs.exists("/dev/nvidia0"),
        sc.rootfs.exists("/dev/nvidia0"),
    ] {
        assert!(c_exists);
    }
    // key runtime-security difference the paper motivates: docker runs
    // root-by-default through a daemon; shifter keeps the user's uid
    assert_eq!(dc.uid, 0);
    assert_eq!(sc.privileges.effective_uid, 1000);
}

#[test]
fn modules_native_env_vs_container_independence() {
    // natively the T106D run needs three modules loaded; the container
    // run needs none — it carries its toolchain
    let mut modules = ModuleSystem::new(daint_catalog());
    modules.load("PrgEnv-gnu").unwrap();
    modules.load("cudatoolkit").unwrap();
    modules.load("cray-mpich").unwrap();
    assert_eq!(modules.loaded().len(), 3);

    let (profile, gw) = daint_gw(&["pyfr-image:1.5.0"]);
    let rt = ShifterRuntime::new(&profile);
    let c = rt
        .run(&gw, &RunOptions::new("pyfr-image:1.5.0", &["true"]))
        .unwrap();
    // container env has its own CUDA_HOME, no module paths leaked in
    assert!(c.env.get("CUDA_HOME").unwrap().starts_with("/usr/local/cuda"));
    assert!(!c.env.values().any(|v| v.contains("/opt/nvidia/cudatoolkit")));
}

#[test]
fn nvidia_smi_available_inside_gpu_containers_only() {
    let (profile, gw) = daint_gw(&["nvidia/cuda-image:8.0", "ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let with_gpu = rt
        .run(
            &gw,
            &RunOptions::new("nvidia/cuda-image:8.0", &["nvidia-smi"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();
    let out = with_gpu.exec(&["nvidia-smi"]).unwrap();
    assert!(out.contains("1 device(s)"));
    assert!(out.contains("7 driver libraries"));

    let without = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["nvidia-smi"]))
        .unwrap();
    assert!(without.exec(&["nvidia-smi"]).is_err());
}

// ---------------------------------------------------------------------------
// HostExtension API (DESIGN.md S22)
// ---------------------------------------------------------------------------

#[test]
fn net_support_end_to_end_on_daint() {
    let (profile, gw) = daint_gw(&["osu-benchmarks:mpich-3.1.4"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"])
        .with_env("SHIFTER_NET", "host");
    let c = rt.run(&gw, &opts).unwrap();

    let net = c.net.as_ref().expect("net support triggered");
    assert_eq!(net.transport, "gni");
    assert_eq!(net.fabric, "Cray Aries");
    assert!(c.rootfs.exists("/dev/kgni0"));
    assert!(c.rootfs.is_dir("/dev/hugepages"));
    assert!(c
        .rootfs
        .exists("/opt/cray/dmapp/default/lib64/libdmapp.so.1"));
    let net_mounts = c.mounts.by_origin("net support");
    assert_eq!(
        net_mounts.len(),
        net.libraries.len() + net.device_files.len()
    );
    // injection exported the transport into the container env
    assert_eq!(c.env.get("SHIFTER_NET_TRANSPORT").unwrap(), "gni");
    // the container now runs host-fabric, without the MPI swap
    assert!(c.mpi.is_none());
    assert_eq!(c.effective_transport(), Transport::Native);
    // the report surfaces in the stage log and the container
    assert_eq!(c.extensions.len(), 1);
    assert_eq!(c.stage_log.extensions()[0].extension, "net");
}

#[test]
fn net_fallback_knob_forces_tcp_path() {
    let (profile, gw) = daint_gw(&["osu-benchmarks:mpich-3.1.4"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"])
        .with_env("SHIFTER_NET", "host")
        .with_env("SHIFTER_NET_FALLBACK", "1");
    let c = rt.run(&gw, &opts).unwrap();
    assert!(c.net.is_none(), "SHIFTER_NET_FALLBACK must veto injection");
    assert!(c.extensions.is_empty());
    assert_eq!(c.effective_transport(), Transport::TcpFallback);
}

#[test]
fn loopback_host_refuses_net_request_in_preflight() {
    let profile = SystemProfile::laptop();
    let registry = Registry::dockerhub();
    let mut gw = ImageGateway::new(shifter_rs::pfs::LustreFs::piz_daint());
    gw.pull(&registry, "ubuntu:xenial").unwrap();
    let rt = ShifterRuntime::new(&profile);
    let err = rt
        .run(
            &gw,
            &RunOptions::new("ubuntu:xenial", &["true"])
                .with_env("SHIFTER_NET", "host"),
        )
        .unwrap_err();
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "net",
            source: ExtensionError::Net(NetSupportError::NoHostFabric),
        }
    ));
}

#[test]
fn abi_incompatible_host_fabric_rejected_full_stack() {
    // a fabric-aware image built against a uGNI generation the host
    // cannot serve: the label alone triggers the extension, and the ABI
    // gate refuses the run in preflight
    let mut registry = Registry::dockerhub();
    let too_new = ImageBuilder::new("fabric-app:gni-99")
        .exe("/usr/bin/fabric-app", 100_000)
        .with_net_transport("gni", 99)
        .build();
    registry.push(too_new);
    let wrong_family = ImageBuilder::new("fabric-app:verbs")
        .exe("/usr/bin/fabric-app", 100_000)
        .with_net_transport("verbs", 17)
        .build();
    registry.push(wrong_family);

    let profile = SystemProfile::piz_daint();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    gw.pull(&registry, "fabric-app:gni-99").unwrap();
    gw.pull(&registry, "fabric-app:verbs").unwrap();
    let rt = ShifterRuntime::new(&profile);

    let err = rt
        .run(&gw, &RunOptions::new("fabric-app:gni-99", &["true"]))
        .unwrap_err();
    match err {
        ShifterError::ExtensionCheck {
            extension: "net",
            source:
                ExtensionError::Net(NetSupportError::AbiIncompatible {
                    container_abi,
                    host_abi,
                }),
        } => {
            assert_eq!(container_abi, "gni:99");
            assert_eq!(host_abi, "gni:5");
        }
        other => panic!("wrong error: {other}"),
    }

    let err = rt
        .run(&gw, &RunOptions::new("fabric-app:verbs", &["true"]))
        .unwrap_err();
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "net",
            source: ExtensionError::Net(NetSupportError::FabricMismatch { .. }),
        }
    ));

    // a compatible fabric-aware image (gni, older generation) runs and
    // activates via its label alone — no SHIFTER_NET needed
    let mut registry2 = Registry::dockerhub();
    let ok_image = ImageBuilder::new("fabric-app:gni-3")
        .exe("/usr/bin/fabric-app", 100_000)
        .with_net_transport("gni", 3)
        .build();
    registry2.push(ok_image);
    let mut gw2 = ImageGateway::new(profile.pfs.clone().unwrap());
    gw2.pull(&registry2, "fabric-app:gni-3").unwrap();
    let c = rt
        .run(&gw2, &RunOptions::new("fabric-app:gni-3", &["true"]))
        .unwrap();
    assert!(c.net.is_some());
}

#[test]
fn netfab_check_is_the_negative_gate() {
    // direct negative coverage of the ABI comparison, independent of the
    // runtime plumbing
    let pd = SystemProfile::piz_daint();
    let mut labels = BTreeMap::new();
    labels.insert(
        "org.shifter.net.abi".to_string(),
        "gni:6".to_string(),
    );
    assert!(matches!(
        netfab::check(&labels, &pd).unwrap_err(),
        NetSupportError::AbiIncompatible { .. }
    ));
    labels.insert("org.shifter.net.abi".to_string(), "gni:5".to_string());
    assert_eq!(netfab::check(&labels, &pd).unwrap().abi_string(), "gni:5");
    assert!(matches!(
        netfab::check(&labels, &SystemProfile::laptop()).unwrap_err(),
        NetSupportError::NoHostFabric
    ));
}

/// A probe extension that counts lifecycle calls — used to pin the
/// trigger → check → inject ordering across the §III.A stages.
struct ProbeExtension {
    checks: Arc<AtomicUsize>,
    injects: Arc<AtomicUsize>,
}

impl HostExtension for ProbeExtension {
    fn name(&self) -> &'static str {
        "probe"
    }

    fn trigger(&self, _ctx: &ExtensionContext<'_>) -> Activation {
        Activation::Triggered("always on".to_string())
    }

    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError> {
        self.checks.fetch_add(1, Ordering::SeqCst);
        Ok(self.capability(ctx.profile, ctx.config))
    }

    fn capability(
        &self,
        _profile: &SystemProfile,
        _config: &UdiRootConfig,
    ) -> Capability {
        Capability {
            extension: "probe",
            available: true,
            detail: "test probe".to_string(),
        }
    }

    fn inject(
        &self,
        _ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        _env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError> {
        self.injects.fetch_add(1, Ordering::SeqCst);
        rootfs.mkdir_p("/opt/probe").ok();
        mounts.bind("/opt/probe", "/opt/probe", true, "probe");
        Ok(ExtensionReport {
            extension: "probe",
            detail: "probe injected".to_string(),
            mounts_added: 1,
            env_added: 0,
            payload: ExtensionPayload::Custom,
        })
    }
}

#[test]
fn failed_mpi_check_precedes_every_injection() {
    // regression for the S22 satellite: `--mpi` on an image with no MPI
    // labels must fail in preflight, BEFORE Stage::PrepareEnvironment —
    // a probe registered after mpi proves no injection ever started
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let checks = Arc::new(AtomicUsize::new(0));
    let injects = Arc::new(AtomicUsize::new(0));
    let registry = ExtensionRegistry::defaults().with(Box::new(
        ProbeExtension {
            checks: Arc::clone(&checks),
            injects: Arc::clone(&injects),
        },
    ));
    let rt = ShifterRuntime::new(&profile)
        .with_extensions(Arc::new(registry));

    let err = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]).with_mpi())
        .unwrap_err();
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "mpi",
            source: ExtensionError::Mpi(MpiSupportError::NoMpiInImage),
        }
    ));
    assert_eq!(
        injects.load(Ordering::SeqCst),
        0,
        "the mpi preflight failure must abort before any inject runs"
    );

    // the successful path pins the stage log: the probe injects during
    // PrepareEnvironment and its report lands on the log
    let c = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert_eq!(injects.load(Ordering::SeqCst), 1);
    // the probe's own check ran exactly once (on the successful run; the
    // failed run aborted at mpi, before the probe's turn)
    assert_eq!(checks.load(Ordering::SeqCst), 1);
    let prepare = &c.stage_log.records()[1];
    assert_eq!(prepare.stage.name(), "prepare-environment");
    let names: Vec<&str> = c
        .stage_log
        .extensions()
        .iter()
        .map(|r| r.extension)
        .collect();
    assert_eq!(names, ["probe"]);
    assert!(c.rootfs.is_dir("/opt/probe"));
    assert_eq!(c.mounts.by_origin("probe").len(), 1);
}

#[test]
fn runtime_without_extensions_never_injects() {
    let (profile, gw) = daint_gw(&["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&profile)
        .with_extensions(Arc::new(ExtensionRegistry::empty()));
    // CVD set, but no gpu extension registered: nothing triggers
    let c = rt
        .run(
            &gw,
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();
    assert!(c.gpu.is_none());
    assert!(c.extensions.is_empty());
    assert!(c.mounts.by_origin("gpu support").is_empty());
}

#[test]
fn capability_vectors_match_the_paper_inventory() {
    let registry = ExtensionRegistry::defaults();
    assert_eq!(registry.names(), ["gpu", "mpi", "net"]);
    for (profile, net_available) in [
        (SystemProfile::piz_daint(), true),
        (SystemProfile::linux_cluster(), true),
        (SystemProfile::laptop(), false),
    ] {
        let config = UdiRootConfig::for_profile(&profile);
        let caps = registry.capabilities(&profile, &config);
        assert!(caps[0].available && caps[1].available, "{}", profile.name);
        assert_eq!(caps[2].available, net_available, "{}", profile.name);
    }
}
