//! Integration tests for the extension features: user volumes, the ALPS
//! workload manager, the gateway pull queue, nvidia-docker/Shifter
//! workflow parity, Environment Modules, and the in-container commands.

use std::collections::BTreeMap;

use shifter_rs::docker::DockerRuntime;
use shifter_rs::gateway::{PullQueue, PullState};
use shifter_rs::hostenv::{daint_catalog, ModuleSystem};
use shifter_rs::image::builder;
use shifter_rs::shifter::{RunOptions, ShifterRuntime, VolumeError, ShifterError};
use shifter_rs::wlm::{Alps, AprunRequest, SlurmWlm, WorkloadManager};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn daint_gw(images: &[&str]) -> (SystemProfile, ImageGateway) {
    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    for i in images {
        gw.pull(&registry, i).unwrap();
    }
    (profile, gw)
}

#[test]
fn user_volume_mounted_and_visible() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("ubuntu:xenial", &["true"])
        .with_volume("/scratch:/workdir");
    let c = rt.run(&gw, &opts).unwrap();
    assert!(c.rootfs.is_dir("/workdir"));
    let vol_mounts = c.mounts.by_origin("user volume");
    assert_eq!(vol_mounts.len(), 1);
    assert_eq!(vol_mounts[0].source, "/scratch");
}

#[test]
fn reserved_volume_target_refused() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts =
        RunOptions::new("ubuntu:xenial", &["true"]).with_volume("/scratch:/etc");
    match rt.run(&gw, &opts) {
        Err(ShifterError::Volume(VolumeError::ReservedTarget(t))) => {
            assert_eq!(t, "/etc")
        }
        other => panic!("expected reserved-target error, got {other:?}"),
    }
}

#[test]
fn missing_volume_host_path_refused() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let opts = RunOptions::new("ubuntu:xenial", &["true"])
        .with_volume("/does/not/exist:/data");
    assert!(matches!(
        rt.run(&gw, &opts),
        Err(ShifterError::Volume(VolumeError::HostPathMissing(_)))
    ));
}

#[test]
fn every_container_gets_writable_tmpfs() {
    let (profile, gw) = daint_gw(&["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let c = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert!(c.rootfs.is_dir("/tmp"));
    assert!(c.rootfs.is_dir("/run"));
    assert!(c
        .mounts
        .iter()
        .any(|m| m.target == "/tmp"
            && matches!(m.kind, shifter_rs::vfs::MountKind::Tmpfs)));
}

#[test]
fn alps_launch_drives_gpu_support_like_slurm() {
    let (profile, gw) = daint_gw(&["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&profile);
    let mut alps = Alps::new(&profile);
    let ranks = alps
        .aprun(AprunRequest {
            ranks: 2,
            per_node: 1,
            gpus: true,
        })
        .unwrap();
    for rank in &ranks {
        let mut opts = RunOptions::new("nvidia/cuda-image:8.0", &["deviceQuery"]);
        opts.env = rank.env.clone();
        opts.node = rank.node as usize;
        let c = rt.run(&gw, &opts).unwrap();
        assert!(c.gpu.is_some(), "ALPS CVD export must trigger GPU support");
        let out = c.exec(&["deviceQuery"]).unwrap();
        assert!(out.contains("Result = PASS"));
    }
}

#[test]
fn wlm_trait_interchangeable_for_the_runtime() {
    let profile = SystemProfile::piz_daint();
    let mut wlms: Vec<Box<dyn WorkloadManager>> = vec![
        Box::new(SlurmWlm::new(&profile)),
        Box::new(Alps::new(&profile)),
    ];
    for wlm in wlms.iter_mut() {
        let ranks = wlm.launch(4, 2, 1).unwrap();
        assert_eq!(ranks.len(), 4);
        assert!(ranks
            .iter()
            .all(|r| r.env.contains_key("CUDA_VISIBLE_DEVICES")));
    }
}

#[test]
fn pull_queue_feeds_the_runtime() {
    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    let mut q = PullQueue::new();
    q.request(&gw, &registry, "ubuntu:xenial", "alice").unwrap();
    assert!(ShifterRuntime::new(&profile)
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .is_err()); // not ready yet
    q.tick(&mut gw, &registry, 1e6);
    assert_eq!(q.status("ubuntu:xenial").unwrap().state, PullState::Ready);
    let c = ShifterRuntime::new(&profile)
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert!(c.stage_log.completed());
}

#[test]
fn docker_and_shifter_expose_equivalent_cuda_containers() {
    // the §V.B.1 methodology: nvidia-docker on the laptop, Shifter on the
    // HPC systems — same image, both must expose working CUDA
    let laptop = SystemProfile::laptop();
    let mut docker = DockerRuntime::new(&laptop);
    docker.load_image(builder::cuda_image());
    let mut env = BTreeMap::new();
    env.insert("CUDA_VISIBLE_DEVICES".to_string(), "0".to_string());
    let dc = docker.run("nvidia/cuda-image:8.0", &env).unwrap();

    let (daint, gw) = daint_gw(&["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&daint);
    let sc = rt
        .run(
            &gw,
            &RunOptions::new("nvidia/cuda-image:8.0", &["./nbody"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();

    // both containers: one visible device, device files present, the
    // application binary from the image unchanged
    assert_eq!(dc.gpu_devices.len(), 1);
    assert_eq!(sc.gpu.as_ref().unwrap().host_devices.len(), 1);
    for c_exists in [
        dc.rootfs.exists("/usr/local/cuda/samples/bin/nbody"),
        sc.rootfs.exists("/usr/local/cuda/samples/bin/nbody"),
        dc.rootfs.exists("/dev/nvidia0"),
        sc.rootfs.exists("/dev/nvidia0"),
    ] {
        assert!(c_exists);
    }
    // key runtime-security difference the paper motivates: docker runs
    // root-by-default through a daemon; shifter keeps the user's uid
    assert_eq!(dc.uid, 0);
    assert_eq!(sc.privileges.effective_uid, 1000);
}

#[test]
fn modules_native_env_vs_container_independence() {
    // natively the T106D run needs three modules loaded; the container
    // run needs none — it carries its toolchain
    let mut modules = ModuleSystem::new(daint_catalog());
    modules.load("PrgEnv-gnu").unwrap();
    modules.load("cudatoolkit").unwrap();
    modules.load("cray-mpich").unwrap();
    assert_eq!(modules.loaded().len(), 3);

    let (profile, gw) = daint_gw(&["pyfr-image:1.5.0"]);
    let rt = ShifterRuntime::new(&profile);
    let c = rt
        .run(&gw, &RunOptions::new("pyfr-image:1.5.0", &["true"]))
        .unwrap();
    // container env has its own CUDA_HOME, no module paths leaked in
    assert!(c.env.get("CUDA_HOME").unwrap().starts_with("/usr/local/cuda"));
    assert!(!c.env.values().any(|v| v.contains("/opt/nvidia/cudatoolkit")));
}

#[test]
fn nvidia_smi_available_inside_gpu_containers_only() {
    let (profile, gw) = daint_gw(&["nvidia/cuda-image:8.0", "ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&profile);
    let with_gpu = rt
        .run(
            &gw,
            &RunOptions::new("nvidia/cuda-image:8.0", &["nvidia-smi"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();
    let out = with_gpu.exec(&["nvidia-smi"]).unwrap();
    assert!(out.contains("1 device(s)"));
    assert!(out.contains("7 driver libraries"));

    let without = rt
        .run(&gw, &RunOptions::new("ubuntu:xenial", &["nvidia-smi"]))
        .unwrap();
    assert!(without.exec(&["nvidia-smi"]).is_err());
}
