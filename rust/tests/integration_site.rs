//! Integration: the `Site` facade (DESIGN.md S21/S22) — builder
//! validation returns typed errors instead of panicking, `SiteError`
//! chains its layer-level causes via `std::error::Error::source()`, the
//! facade's config knob reaches node execution, and third-party
//! `SchedulingPolicy` / `HostExtension` implementations plug into the
//! storm scheduler and the runtime's injection registry.

use std::collections::BTreeMap;
use std::error::Error as _;

use shifter_rs::config::UdiRootConfig;
use shifter_rs::launch::{JobSpec, RetryPolicy};
use shifter_rs::shifter::{
    Activation, Capability, ExtensionContext, ExtensionError,
    ExtensionPayload, ExtensionReport, HostExtension, RunOptions,
};
use shifter_rs::tenancy::{
    FairShare, JobClass, SchedulingPolicy, TenantJob,
};
use shifter_rs::vfs::{MountTable, VirtualFs};
use shifter_rs::wlm::ShareLedger;
use shifter_rs::{Site, SiteError, StormSpec, SystemProfile};

// -- builder validation ---------------------------------------------------

#[test]
fn conflicting_knobs_return_typed_errors_not_panics() {
    assert!(matches!(
        Site::builder().gateway_shards(0).build(),
        Err(SiteError::NoShards)
    ));
    assert!(matches!(
        Site::builder().nodes(0).build(),
        Err(SiteError::EmptyCluster)
    ));
    assert!(matches!(
        Site::builder()
            .partition("empty", &SystemProfile::laptop(), 0)
            .build(),
        Err(SiteError::EmptyPartition(_))
    ));
    assert!(matches!(
        Site::builder().node_cache_bytes(0).build(),
        Err(SiteError::NodeCacheTooSmall { .. })
    ));
    let no_attempts = RetryPolicy {
        max_attempts: 0,
        ..RetryPolicy::default()
    };
    assert!(matches!(
        Site::builder().retry_policy(no_attempts).build(),
        Err(SiteError::BadRetryPolicy)
    ));
}

#[test]
fn every_builder_error_displays_something_actionable() {
    let cases: Vec<SiteError> = vec![
        Site::builder().gateway_shards(0).build().unwrap_err(),
        Site::builder().nodes(0).build().unwrap_err(),
        Site::builder().node_cache_bytes(1).build().unwrap_err(),
    ];
    for err in cases {
        let msg = err.to_string();
        assert!(!msg.is_empty());
        assert!(
            msg.contains("site") || msg.contains("node-cache"),
            "unhelpful message: {msg}"
        );
    }
}

#[test]
fn gpu_job_on_gpuless_site_fails_fast_and_typed() {
    let mut gpuless = SystemProfile::linux_cluster();
    gpuless.nodes[0].gpus.clear();
    let mut site = Site::builder().profile(gpuless).nodes(4).build().unwrap();
    let spec = JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 4)
        .with_gpus(2);
    match site.launch(&spec) {
        Err(SiteError::GpuUnavailable { gpus_per_node }) => {
            assert_eq!(gpus_per_node, 2)
        }
        other => panic!("expected GpuUnavailable, got {other:?}"),
    }
    // the same check guards explicit placements
    assert!(matches!(
        site.launch_on(&spec, &[0, 1, 2, 3]),
        Err(SiteError::GpuUnavailable { .. })
    ));
}

// -- error chaining -------------------------------------------------------

#[test]
fn launch_errors_chain_their_wlm_cause() {
    let mut site = Site::builder().nodes(2).build().unwrap();
    let err = site
        .launch(&JobSpec::new("ubuntu:xenial", &["true"], 99))
        .unwrap_err();
    assert!(matches!(err, SiteError::Launch(_)));
    // SiteError -> LaunchError (transparent over WlmError)
    let cause = err.source().expect("launch errors must chain");
    let msg = cause.to_string();
    assert!(
        msg.contains("99") && msg.contains("2"),
        "cause must carry the WLM detail: {msg}"
    );
}

#[test]
fn runtime_errors_chain_their_volume_cause() {
    let mut site = Site::builder().nodes(1).build().unwrap();
    site.pull("ubuntu:xenial").unwrap();
    let opts = RunOptions::new("ubuntu:xenial", &["true"])
        .with_volume("/scratch:/etc");
    let err = site.run(&opts).unwrap_err();
    assert!(matches!(err, SiteError::Runtime(_)));
    let cause = err.source().expect("runtime errors must chain");
    assert!(
        cause.to_string().contains("reserved"),
        "cause must carry the volume-policy detail: {}",
        cause
    );
}

#[test]
fn pull_failures_carry_the_gateway_detail() {
    let mut site = Site::builder().nodes(1).build().unwrap();
    match site.pull("nope:missing") {
        Err(SiteError::PullFailed { reference, detail }) => {
            assert_eq!(reference, "nope:missing");
            assert!(detail.contains("not found"), "{detail}");
        }
        other => panic!("expected PullFailed, got {other:?}"),
    }
}

// -- config knob ----------------------------------------------------------

#[test]
fn site_config_reaches_node_execution() {
    // a site-specific extra mount declared in udiRoot.conf must show up
    // in every container the site runs
    let mut config = UdiRootConfig::for_profile(&SystemProfile::piz_daint());
    config.site_mounts.push(shifter_rs::config::SiteMount {
        host_path: "/scratch".to_string(),
        container_path: "/site/scratch".to_string(),
        read_only: false,
    });
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(2)
        .config(config.clone())
        .build()
        .unwrap();
    assert_eq!(site.config(), &config);

    let c = site
        .run(&RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    assert!(c.rootfs.is_dir("/site/scratch"));

    // and the launch path (separate per-partition runtimes) honors it too
    let report = site
        .launch(&JobSpec::new("ubuntu:xenial", &["true"], 2))
        .unwrap();
    assert_eq!(report.succeeded(), 2);
}

#[test]
fn conf_text_round_trips_through_the_builder() {
    let conf = UdiRootConfig::for_profile(&SystemProfile::laptop()).to_conf();
    let site = Site::builder()
        .config_conf(&conf)
        .unwrap()
        .nodes(1)
        .build()
        .unwrap();
    assert_eq!(site.config().to_conf(), conf);
}

// -- third-party scheduling policy ---------------------------------------

/// A policy no builtin provides: shortest-job-first with head-of-line
/// blocking — exactly what the pluggable trait exists for.
struct ShortestFirst;

impl SchedulingPolicy for ShortestFirst {
    fn name(&self) -> &str {
        "shortest-first"
    }

    fn priority(
        &self,
        job: &TenantJob,
        _wait_secs: f64,
        _ledger: &ShareLedger,
    ) -> f64 {
        -job.runtime_secs
    }

    fn backfill(&self) -> bool {
        false
    }
}

fn cpu_job(id: u32, arrival: f64, width: u32, runtime: f64) -> TenantJob {
    TenantJob {
        id,
        tenant: format!("tenant-{id:02}"),
        tenant_idx: id,
        arrival_secs: arrival,
        runtime_secs: runtime,
        class: JobClass::Cpu,
        spec: JobSpec::new("ubuntu:xenial", &["true"], width),
    }
}

#[test]
fn a_custom_policy_plugs_into_the_storm_scheduler() {
    // 4 nodes; job 0 occupies the machine. Jobs 1 (long) and 2 (short)
    // queue behind it. FIFO starts the long one first; shortest-first
    // must start the short one first.
    let jobs = vec![
        cpu_job(0, 0.0, 4, 300.0),
        cpu_job(1, 1.0, 4, 500.0),
        cpu_job(2, 2.0, 4, 50.0),
    ];
    fn run(
        jobs: &[TenantJob],
        policy: impl SchedulingPolicy + 'static,
    ) -> shifter_rs::tenancy::TenancyReport {
        Site::builder()
            .profile(SystemProfile::piz_daint())
            .nodes(4)
            .build()
            .unwrap()
            .run_storm(
                &StormSpec::new()
                    .job_stream(jobs.to_vec())
                    .policy(policy),
            )
            .unwrap()
    }

    let sjf = run(&jobs, ShortestFirst);
    assert_eq!(sjf.completed(), 3);
    assert_eq!(sjf.policy, "shortest-first");
    assert!(
        sjf.records[2].start_secs < sjf.records[1].start_secs,
        "SJF must start the short job first: short {} vs long {}",
        sjf.records[2].start_secs,
        sjf.records[1].start_secs
    );

    // the builtin fair-share policy on the same stream keeps arrival
    // order (equal shares, aging dominated by arrival ties) — the custom
    // policy really changed the schedule
    let fair = run(&jobs, FairShare::default());
    assert!(
        fair.records[1].start_secs < fair.records[2].start_secs,
        "fair-share keeps the earlier arrival first here"
    );

    // a boxed custom policy also configures a site wholesale: a storm
    // synthesized from a traffic model runs under it by default
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(4)
        .scheduling_policy(Box::new(ShortestFirst))
        .build()
        .unwrap();
    assert_eq!(site.policy().name(), "shortest-first");
    let via_builder = site
        .run_storm(
            &StormSpec::new().tenants(2).jobs(4).max_width(2),
        )
        .unwrap();
    assert_eq!(via_builder.policy, "shortest-first");
    assert_eq!(via_builder.completed(), 4);
}

// -- third-party host extensions (S22) ------------------------------------

/// A site-defined extension: graft the site's licensed tool tree into
/// every container (the kind of injection a real center bolts on).
struct SiteToolsExtension;

impl HostExtension for SiteToolsExtension {
    fn name(&self) -> &'static str {
        "site-tools"
    }

    fn trigger(&self, _ctx: &ExtensionContext<'_>) -> Activation {
        Activation::Triggered("site policy: always on".to_string())
    }

    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError> {
        Ok(self.capability(ctx.profile, ctx.config))
    }

    fn capability(
        &self,
        _profile: &SystemProfile,
        _config: &UdiRootConfig,
    ) -> Capability {
        Capability {
            extension: "site-tools",
            available: true,
            detail: "licensed tool tree".to_string(),
        }
    }

    fn inject(
        &self,
        _ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError> {
        rootfs.mkdir_p("/opt/site-tools").ok();
        mounts.bind("/opt/site-tools", "/opt/site-tools", true, "site-tools");
        env.insert("SITE_TOOLS".to_string(), "/opt/site-tools".to_string());
        Ok(ExtensionReport {
            extension: "site-tools",
            detail: "tool tree grafted".to_string(),
            mounts_added: 1,
            env_added: 1,
            payload: ExtensionPayload::Custom,
        })
    }
}

#[test]
fn third_party_extension_reaches_stage_log_and_launch_report() {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(4)
        .with_extension(Box::new(SiteToolsExtension))
        .build()
        .unwrap();
    assert_eq!(
        site.extensions().names(),
        ["gpu", "mpi", "net", "site-tools"]
    );

    // single-node run: the extension shows up in the StageLog and the
    // container surface
    let c = site
        .run(&RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    let logged: Vec<&str> = c
        .stage_log
        .extensions()
        .iter()
        .map(|r| r.extension)
        .collect();
    assert_eq!(logged, ["site-tools"]);
    assert!(c.rootfs.is_dir("/opt/site-tools"));
    assert_eq!(c.env.get("SITE_TOOLS").unwrap(), "/opt/site-tools");
    assert_eq!(c.mounts.by_origin("site-tools").len(), 1);

    // cluster-scale launch: every node's result carries the extension,
    // and the report aggregates it
    let report = site
        .launch(&JobSpec::new("ubuntu:xenial", &["true"], 4))
        .unwrap();
    assert_eq!(report.succeeded(), 4);
    assert!(report
        .node_results
        .iter()
        .all(|r| r.extensions.contains(&"site-tools")));
    assert_eq!(report.extension_counts(), vec![("site-tools", 4)]);
    assert!(report.render().contains("site-tools on 4 node(s)"));

    // and the per-partition capability vector lists it
    let caps = site.capabilities();
    assert_eq!(caps.len(), 1);
    assert!(caps[0]
        .1
        .iter()
        .any(|c| c.extension == "site-tools" && c.available));
}

#[test]
fn without_default_extensions_disables_stock_injection() {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(1)
        .without_default_extensions()
        .build()
        .unwrap();
    assert!(site.extensions().is_empty());
    // CUDA_VISIBLE_DEVICES set, but no gpu extension registered
    let c = site
        .run(
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap();
    assert!(c.gpu.is_none());
    assert!(c.extensions.is_empty());
}

#[test]
fn net_extension_flows_through_site_launch() {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(2)
        .build()
        .unwrap();
    let spec = JobSpec::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"], 2)
        .with_env("SHIFTER_NET", "host");
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 2);
    assert_eq!(report.extension_counts(), vec![("net", 2)]);
}
