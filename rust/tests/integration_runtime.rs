//! Integration: the PJRT runtime against the real AOT artifacts — the
//! containerized applications' compute executed for real on the CPU
//! client. Skipped gracefully when artifacts/ has not been built.

use shifter_rs::apps::{nbody, pyfr, tf_trainer};
use shifter_rs::runtime::{default_artifact_dir, Executor, TensorValue};

fn executor() -> Option<Executor> {
    let dir = default_artifact_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("artifacts/ not built; skipping");
        return None;
    }
    Some(Executor::new(dir).unwrap())
}

#[test]
fn catalog_has_all_five_artifacts() {
    let Some(ex) = executor() else { return };
    let names = ex.catalog().names();
    for expected in [
        "cifar_train",
        "mnist_predict",
        "mnist_train",
        "nbody_step",
        "pyfr_step",
    ] {
        assert!(names.contains(&expected), "{expected} missing");
    }
}

#[test]
fn mnist_real_training_reduces_loss() {
    let Some(ex) = executor() else { return };
    let rep = tf_trainer::run_real_training(
        &ex,
        tf_trainer::TfWorkload::Mnist,
        8,
        123,
    )
    .unwrap();
    assert_eq!(rep.losses.len(), 8);
    assert!(rep.losses.iter().all(|l| l.is_finite()));
    assert!(rep.loss_decreased(), "{:?}", rep.losses);
    // initial loss ~ ln(10) for a fresh softmax classifier
    assert!((1.8..4.5).contains(&(rep.first_loss() as f64)));
}

#[test]
fn cifar_real_training_reduces_loss() {
    let Some(ex) = executor() else { return };
    let rep = tf_trainer::run_real_training(
        &ex,
        tf_trainer::TfWorkload::Cifar10,
        6,
        321,
    )
    .unwrap();
    assert!(rep.loss_decreased(), "{:?}", rep.losses);
}

#[test]
fn training_is_deterministic_same_seed() {
    let Some(ex) = executor() else { return };
    let a = tf_trainer::run_real_training(&ex, tf_trainer::TfWorkload::Mnist, 3, 7)
        .unwrap();
    let b = tf_trainer::run_real_training(&ex, tf_trainer::TfWorkload::Mnist, 3, 7)
        .unwrap();
    assert_eq!(a.losses, b.losses); // bit-identical: same compiled bits
    let c = tf_trainer::run_real_training(&ex, tf_trainer::TfWorkload::Mnist, 3, 8)
        .unwrap();
    assert_ne!(a.losses, c.losses);
}

#[test]
fn nbody_real_integration_is_stable() {
    let Some(ex) = executor() else { return };
    let rep = nbody::run_real_steps(&ex, 4, 55).unwrap();
    assert_eq!(rep.n_bodies, 1024);
    assert!(rep.final_acc_norm.is_finite() && rep.final_acc_norm > 0.0);
    assert!(rep.cpu_gflops > 0.0);
}

#[test]
fn nbody_momentum_conserved_through_artifact() {
    let Some(ex) = executor() else { return };
    let spec = ex.catalog().get("nbody_step").unwrap();
    let n = spec.inputs[0].shape[0];
    let mut pos4 = vec![0.0f64; n * 4];
    let mut vel = vec![0.0f64; n * 3];
    for i in 0..n {
        pos4[i * 4] = (i as f64).sin() * 3.0;
        pos4[i * 4 + 1] = (i as f64).cos() * 3.0;
        pos4[i * 4 + 2] = ((i * 7) as f64).sin() * 3.0;
        pos4[i * 4 + 3] = 1.0 + (i % 4) as f64 * 0.1;
        vel[i * 3] = 0.01 * (i as f64).cos();
    }
    let p_before: f64 = (0..n).map(|i| pos4[i * 4 + 3] * vel[i * 3]).sum();
    let res = ex
        .execute(
            "nbody_step",
            &[
                TensorValue::F64(pos4.clone()),
                TensorValue::F64(vel),
                TensorValue::F64(vec![1e-3]),
            ],
        )
        .unwrap();
    let new_vel = res.outputs[1].as_f64();
    let p_after: f64 = (0..n).map(|i| pos4[i * 4 + 3] * new_vel[i * 3]).sum();
    assert!(
        (p_after - p_before).abs() < 1e-9,
        "momentum drift: {p_before} -> {p_after}"
    );
}

#[test]
fn pyfr_conservation_with_null_row_operator() {
    let Some(ex) = executor() else { return };
    let rep = pyfr::run_real_partition(&ex, 10).unwrap();
    // the operator in run_real_partition has zero row sums and the initial
    // state is smooth: residuals stay bounded and finite
    assert!(rep.residuals.iter().all(|r| r.is_finite()));
    let min = rep.residuals.iter().cloned().fold(f32::MAX, f32::min);
    let max = rep.residuals.iter().cloned().fold(f32::MIN, f32::max);
    assert!(max / min.max(1e-12) < 1.5, "residual blew up: {min} -> {max}");
}

#[test]
fn mnist_predict_consumes_trained_params() {
    let Some(ex) = executor() else { return };
    // one train step, then predict with the updated params
    let train = ex.catalog().get("mnist_train").unwrap().clone();
    let n_params = train.inputs.len() - 2;
    let mut inputs: Vec<TensorValue> = train.inputs[..n_params]
        .iter()
        .map(|sig| TensorValue::F32(vec![0.01; sig.element_count()]))
        .collect();
    let batch = train.inputs[n_params].shape[0];
    inputs.push(TensorValue::F32(vec![0.5; batch * 784]));
    inputs.push(TensorValue::I32(vec![3; batch]));
    let step = ex.execute("mnist_train", &inputs).unwrap();

    let mut pinputs: Vec<TensorValue> = (0..n_params)
        .map(|i| TensorValue::F32(step.outputs[i].as_f32().to_vec()))
        .collect();
    pinputs.push(TensorValue::F32(vec![0.5; batch * 784]));
    let pred = ex.execute("mnist_predict", &pinputs).unwrap();
    let logits = pred.outputs[0].as_f32();
    assert_eq!(logits.len(), batch * 10);
    assert!(logits.iter().all(|l| l.is_finite()));
}

#[test]
fn executor_rejects_malformed_inputs() {
    let Some(ex) = executor() else { return };
    // wrong element count
    let bad = vec![
        TensorValue::F32(vec![0.0; 3]),
        TensorValue::F32(vec![0.0; 64]),
        TensorValue::F32(vec![0.0]),
    ];
    assert!(ex.execute("pyfr_step", &bad).is_err());
    // unknown artifact
    assert!(ex.execute("nonexistent", &[]).is_err());
}
