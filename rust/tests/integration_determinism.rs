//! Integration: bit-exact determinism of the virtual-time kernel
//! (DESIGN.md S24) — the same seed must produce byte-identical
//! `LaunchReport` / `TenancyReport` JSON artifacts and an identical
//! telemetry event stream on every run, regardless of how many host
//! threads the test harness uses (`--test-threads=1` and the default
//! parallel run must agree). Simulated time comes from one event queue,
//! never from the host clock or scheduler, so the whole trace replays
//! bit-for-bit.

use shifter_rs::distrib::{CascadeConfig, DistributionFabric};
use shifter_rs::gateway::ImageSource;
use shifter_rs::launch::JobSpec;
use shifter_rs::pfs::LustreFs;
use shifter_rs::util::json::Json;
use shifter_rs::{
    Federation, FederationStorm, Registry, Site, SiteBuilder, StormSpec,
    SystemProfile,
};

/// One traced hetero launch on a fresh site: the full pipeline — WLM
/// allocation, coalesced pull, per-node slot events, MPI swap — under
/// the *default* retry policy, so the seeded jitter/straggler noise is
/// exercised too. Returns the report JSON and the Chrome trace.
fn launch_once() -> (String, String) {
    let mut site = Site::builder()
        .hetero_daint_linux(16)
        .telemetry(true)
        .build()
        .unwrap();
    let spec =
        JobSpec::new("osu-benchmarks:mpich-3.1.4", &["./osu_bw"], 16)
            .with_mpi();
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 16);
    (
        report.to_json().to_string(),
        site.telemetry().chrome_trace_jsonl(),
    )
}

/// One traced storm on a fresh site: synthesized stream, fair-share
/// scheduling, completions via kernel events.
fn storm_once() -> (String, String) {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(16)
        .telemetry(true)
        .seed(13)
        .build()
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(3).jobs(10))
        .unwrap();
    assert_eq!(report.failed(), 0);
    (
        report.to_json().to_string(),
        site.telemetry().chrome_trace_jsonl(),
    )
}

#[test]
fn launch_report_and_trace_are_byte_identical_across_runs() {
    let (report_a, trace_a) = launch_once();
    let (report_b, trace_b) = launch_once();
    assert_eq!(report_a, report_b, "LaunchReport JSON must replay");
    assert_eq!(trace_a, trace_b, "telemetry event order must replay");
    assert!(!trace_a.is_empty());
}

#[test]
fn repeated_launches_on_one_site_replay_byte_identically() {
    // regression for the ordered-map migration in the launch scheduler
    // and executor: slot templates live in a BTreeMap keyed by
    // (image, config) and per-slot results re-assemble in node order,
    // so a cold launch AND a warm relaunch (coalesced pull, reused
    // fabric state) must replay byte-for-byte across fresh sites
    let once = || {
        let mut site = Site::builder()
            .hetero_daint_linux(16)
            .telemetry(true)
            .build()
            .unwrap();
        let spec =
            JobSpec::new("osu-benchmarks:mpich-3.1.4", &["./osu_bw"], 16)
                .with_mpi();
        let cold = site.launch(&spec).unwrap().to_json().to_string();
        let warm = site.launch(&spec).unwrap().to_json().to_string();
        (cold, warm)
    };
    let (cold_a, warm_a) = once();
    let (cold_b, warm_b) = once();
    assert_eq!(cold_a, cold_b, "cold launch must replay");
    assert_eq!(warm_a, warm_b, "warm relaunch must replay");
}

#[test]
fn tenancy_report_and_trace_are_byte_identical_across_runs() {
    let (report_a, trace_a) = storm_once();
    let (report_b, trace_b) = storm_once();
    assert_eq!(report_a, report_b, "TenancyReport JSON must replay");
    assert_eq!(trace_a, trace_b, "telemetry event order must replay");
    assert!(!trace_a.is_empty());
}

/// One cascade-fill storm with every distribution mechanism on: a raw
/// fabric (cascade + lazy pull + chunked CAS) filling 48 nodes, then a
/// site storm with the same knobs through the builder. Returns a
/// `BENCH_distrib.json`-shaped document concatenated with the tenancy
/// report, plus the site's Chrome trace.
fn distrib_once() -> (String, String) {
    // part 1: the raw fabric — plan replay, lazy splits, chunk counters
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint())
        .with_cascade(CascadeConfig {
            cabinet_nodes: 8,
            fanout: 3,
        })
        .with_lazy_pull(true)
        .with_chunking(1 << 20);
    fabric
        .pull_blocking(&registry, "ubuntu:xenial", "det")
        .unwrap();
    let mut rows = Vec::new();
    {
        let image = fabric.resolve("ubuntu:xenial").unwrap();
        for node in 0..48 {
            let (start, tail) =
                fabric.node_fetch_split(image, node, 48).unwrap();
            rows.push(Json::obj(vec![
                ("node", Json::Num(node as f64)),
                ("start_ready_secs", Json::num(start)),
                ("tail_secs", Json::num(tail)),
            ]));
        }
    }
    let stats = fabric.cascade_stats();
    let cas = fabric.cluster().cas();
    let doc = Json::obj(vec![
        ("bench", Json::str("distrib_cascade")),
        ("gateway_fills", Json::Num(stats.gateway_fills as f64)),
        ("peer_transfers", Json::Num(stats.peer_transfers as f64)),
        ("max_depth", Json::Num(stats.max_depth as f64)),
        (
            "lazy_deferred_bytes",
            Json::Num(fabric.cache_stats().lazy_deferred_bytes as f64),
        ),
        ("chunks_new", Json::Num(cas.chunks_new() as f64)),
        ("fills", Json::Arr(rows)),
    ]);
    let doc_text = doc.to_string();

    // part 2: the same mechanisms through the site builder, stormed
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(16)
        .cascade(8, 3)
        .lazy_pull(true)
        .chunk_target_bytes(1 << 20)
        .telemetry(true)
        .seed(17)
        .build()
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(3).jobs(8))
        .unwrap();
    assert_eq!(report.failed(), 0);
    let report_text = report.to_json().to_string();
    (
        format!("{doc_text}\n{report_text}"),
        site.telemetry().chrome_trace_jsonl(),
    )
}

#[test]
fn distrib_artifacts_are_byte_identical_across_runs() {
    let (doc_a, trace_a) = distrib_once();
    let (doc_b, trace_b) = distrib_once();
    assert_eq!(doc_a, doc_b, "distrib artifact + report must replay");
    assert_eq!(trace_a, trace_b, "telemetry event order must replay");
    assert!(!trace_a.is_empty());
}

#[test]
fn distrib_results_are_independent_of_host_thread_context() {
    // cascade plans, chunk digests, and lazy splits are keyed by fixed
    // seeds and replayed on the virtual-time kernel — concurrent host
    // threads must reproduce the main-thread bytes exactly
    let (doc_main, trace_main) = distrib_once();
    let handles: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(distrib_once))
        .collect();
    for h in handles {
        let (doc, trace) = h.join().expect("worker run");
        assert_eq!(doc, doc_main);
        assert_eq!(trace, trace_main);
    }
}

/// One traced federation storm on a fresh three-site fleet (DESIGN.md
/// S27) with burst overflow enabled: arrival routing, WAN replication,
/// and three member-site schedulers all share one virtual clock and
/// one telemetry recorder. Returns the report JSON and the merged
/// Chrome trace.
fn federation_once() -> (String, String) {
    let member = || {
        SiteBuilder::new()
            .profile(SystemProfile::piz_daint())
            .nodes(16)
            .seed(13)
    };
    let mut fed = Federation::builder()
        .site("alpha", member())
        .site("bravo", member())
        .site("charlie", member())
        .overflow_threshold_secs(60.0)
        .telemetry(true)
        .seed(13)
        .build()
        .unwrap();
    let report = fed
        .run_storm(&FederationStorm::new().tenants(3).jobs(12))
        .unwrap();
    assert_eq!(report.completed(), report.records.len());
    (
        report.to_json().to_string(),
        fed.telemetry().chrome_trace_jsonl(),
    )
}

#[test]
fn federation_artifacts_are_byte_identical_across_runs() {
    let (report_a, trace_a) = federation_once();
    let (report_b, trace_b) = federation_once();
    assert_eq!(report_a, report_b, "FederationReport JSON must replay");
    assert_eq!(trace_a, trace_b, "merged trace order must replay");
    assert!(!trace_a.is_empty());
}

#[test]
fn federation_results_are_independent_of_host_thread_context() {
    // the arrival replay, the replica index, and every member site's
    // scheduler run on seeded virtual time — concurrent host threads
    // must reproduce the main-thread bytes exactly
    let (report_main, trace_main) = federation_once();
    let handles: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(federation_once))
        .collect();
    for h in handles {
        let (report, trace) = h.join().expect("worker run");
        assert_eq!(report, report_main);
        assert_eq!(trace, trace_main);
    }
}

#[test]
fn results_are_independent_of_host_thread_context() {
    // virtual time never reads the host scheduler: the same storm run
    // from several OS threads at once — the worst case a parallel test
    // harness (`--test-threads=N`) can create — must agree byte for
    // byte with the main-thread run
    let (report_main, trace_main) = storm_once();
    let handles: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(storm_once))
        .collect();
    for h in handles {
        let (report, trace) = h.join().expect("worker run");
        assert_eq!(report, report_main);
        assert_eq!(trace, trace_main);
    }
}
