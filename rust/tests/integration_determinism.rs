//! Integration: bit-exact determinism of the virtual-time kernel
//! (DESIGN.md S24) — the same seed must produce byte-identical
//! `LaunchReport` / `TenancyReport` JSON artifacts and an identical
//! telemetry event stream on every run, regardless of how many host
//! threads the test harness uses (`--test-threads=1` and the default
//! parallel run must agree). Simulated time comes from one event queue,
//! never from the host clock or scheduler, so the whole trace replays
//! bit-for-bit.

use shifter_rs::launch::JobSpec;
use shifter_rs::{Site, StormSpec, SystemProfile};

/// One traced hetero launch on a fresh site: the full pipeline — WLM
/// allocation, coalesced pull, per-node slot events, MPI swap — under
/// the *default* retry policy, so the seeded jitter/straggler noise is
/// exercised too. Returns the report JSON and the Chrome trace.
fn launch_once() -> (String, String) {
    let mut site = Site::builder()
        .hetero_daint_linux(16)
        .telemetry(true)
        .build()
        .unwrap();
    let spec =
        JobSpec::new("osu-benchmarks:mpich-3.1.4", &["./osu_bw"], 16)
            .with_mpi();
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 16);
    (
        report.to_json().to_string(),
        site.telemetry().chrome_trace_jsonl(),
    )
}

/// One traced storm on a fresh site: synthesized stream, fair-share
/// scheduling, completions via kernel events.
fn storm_once() -> (String, String) {
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(16)
        .telemetry(true)
        .seed(13)
        .build()
        .unwrap();
    let report = site
        .run_storm(&StormSpec::new().tenants(3).jobs(10))
        .unwrap();
    assert_eq!(report.failed(), 0);
    (
        report.to_json().to_string(),
        site.telemetry().chrome_trace_jsonl(),
    )
}

#[test]
fn launch_report_and_trace_are_byte_identical_across_runs() {
    let (report_a, trace_a) = launch_once();
    let (report_b, trace_b) = launch_once();
    assert_eq!(report_a, report_b, "LaunchReport JSON must replay");
    assert_eq!(trace_a, trace_b, "telemetry event order must replay");
    assert!(!trace_a.is_empty());
}

#[test]
fn tenancy_report_and_trace_are_byte_identical_across_runs() {
    let (report_a, trace_a) = storm_once();
    let (report_b, trace_b) = storm_once();
    assert_eq!(report_a, report_b, "TenancyReport JSON must replay");
    assert_eq!(trace_a, trace_b, "telemetry event order must replay");
    assert!(!trace_a.is_empty());
}

#[test]
fn results_are_independent_of_host_thread_context() {
    // virtual time never reads the host scheduler: the same storm run
    // from several OS threads at once — the worst case a parallel test
    // harness (`--test-threads=N`) can create — must agree byte for
    // byte with the main-thread run
    let (report_main, trace_main) = storm_once();
    let handles: Vec<_> = (0..4)
        .map(|_| std::thread::spawn(storm_once))
        .collect();
    for h in handles {
        let (report, trace) = h.join().expect("worker run");
        assert_eq!(report, report_main);
        assert_eq!(trace, trace_main);
    }
}
