//! Integration: the cluster-scale launch orchestrator (DESIGN.md S19) —
//! heterogeneous partitions get per-node correct injected driver stacks,
//! an unsatisfiable MPI ABI fails only its own launch slots, the pull
//! storm coalesces into one gateway job, and queue-wait surfaces in the
//! report.

use shifter_rs::distrib::DistributionFabric;
use shifter_rs::launch::{
    JobSpec, LaunchCluster, LaunchScheduler, RetryPolicy,
};
use shifter_rs::mpi::MpiImpl;
use shifter_rs::pfs::LustreFs;
use shifter_rs::{Registry, SystemProfile};

fn strict_scheduler<'a>(
    cluster: &'a LaunchCluster,
    registry: &'a Registry,
) -> LaunchScheduler<'a> {
    LaunchScheduler::new(cluster, registry).with_policy(RetryPolicy::strict())
}

#[test]
fn heterogeneous_partitions_inject_their_own_driver_stacks() {
    // §IV.A across generations: P100 nodes run a 375.66 driver, the
    // K40m/K80 nodes a 367.48 driver — one job spanning both partitions
    // must see the right stack bind-mounted on every node
    let cluster = LaunchCluster::new()
        .with_partition("daint-xc50", &SystemProfile::piz_daint(), 4)
        .with_partition("linux-cluster", &SystemProfile::linux_cluster(), 4);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec =
        JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 8).with_gpus(1);
    let report = scheduler.launch(&mut fabric, &spec).unwrap();

    assert_eq!(report.succeeded(), 8);
    assert_eq!(report.failed(), 0);
    for r in &report.node_results {
        assert!(r.ok(), "node {}: {:?}", r.node, r.error);
        let (expected, wrong) = if r.node < 4 {
            ("libcuda.so.375.66", "libcuda.so.367.48")
        } else {
            ("libcuda.so.367.48", "libcuda.so.375.66")
        };
        assert!(
            r.gpu_libraries.iter().any(|l| l == expected),
            "node {} [{}] missing {expected}: {:?}",
            r.node,
            r.partition,
            r.gpu_libraries
        );
        assert!(
            !r.gpu_libraries.iter().any(|l| l == wrong),
            "node {} [{}] got the other partition's driver",
            r.node,
            r.partition
        );
    }
}

#[test]
fn unsatisfiable_mpi_abi_fails_its_slots_without_poisoning_others() {
    // partition B's host MPI never joined the MPICH ABI initiative: the
    // §IV.B swap must refuse it on B's nodes while A's nodes launch with
    // the Cray MPT swap intact
    let mut openmpi_host = SystemProfile::linux_cluster();
    openmpi_host.host_mpi = MpiImpl::openmpi_2_0();
    let cluster = LaunchCluster::new()
        .with_partition("daint-xc50", &SystemProfile::piz_daint(), 3)
        .with_partition("openmpi-island", &openmpi_host, 3);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec =
        JobSpec::new("osu-benchmarks:mpich-3.1.4", &["true"], 6).with_mpi();
    let report = scheduler.launch(&mut fabric, &spec).unwrap();

    assert_eq!(report.succeeded(), 3);
    assert_eq!(report.failed(), 3);
    for r in &report.node_results {
        if r.node < 3 {
            assert!(r.ok(), "daint node {} poisoned: {:?}", r.node, r.error);
            assert_eq!(r.host_mpi.as_deref(), Some("Cray MPT 7.5.0"));
        } else {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(
                err.contains("not ABI-compatible"),
                "node {}: wrong error {err:?}",
                r.node
            );
            // a permanent error must not burn retries
            assert_eq!(r.attempts, 1);
        }
    }
    let summary = report.failure_summary();
    assert_eq!(summary.len(), 1);
    assert_eq!(summary[0].1, 3);
}

#[test]
fn gres_shortfall_kills_only_the_gpuless_partition() {
    let mut gpuless = SystemProfile::linux_cluster();
    gpuless.nodes[0].gpus.clear();
    let cluster = LaunchCluster::new()
        .with_partition("daint-xc50", &SystemProfile::piz_daint(), 2)
        .with_partition("cpu-only", &gpuless, 2);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec =
        JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 4).with_gpus(1);
    let report = scheduler.launch(&mut fabric, &spec).unwrap();
    assert_eq!(report.succeeded(), 2);
    assert_eq!(report.failed(), 2);
    for r in &report.node_results {
        if r.node >= 2 {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("wlm"), "node {}: {err:?}", r.node);
            assert!(err.contains("CUDA devices"), "node {}: {err:?}", r.node);
        } else {
            assert!(r.ok());
            assert!(!r.gpu_libraries.is_empty());
        }
    }
}

#[test]
fn ancient_kernel_partition_fails_preflight_only_for_itself() {
    let mut ancient = SystemProfile::piz_daint();
    ancient.kernel = "2.6.18"; // predates squashfs (mainlined 2.6.29)
    let cluster = LaunchCluster::new()
        .with_partition("modern", &SystemProfile::piz_daint(), 2)
        .with_partition("museum", &ancient, 2);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
    let report = scheduler.launch(&mut fabric, &spec).unwrap();
    assert_eq!(report.succeeded(), 2);
    assert_eq!(report.failed(), 2);
    for r in &report.node_results {
        if r.node >= 2 {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("preflight"), "node {}: {err:?}", r.node);
            assert_eq!(r.attempts, 0, "dead slots never run");
        }
    }
}

#[test]
fn launch_storm_coalesces_into_one_pull_job() {
    let cluster = LaunchCluster::daint_linux_split(64);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 64);
    let report = scheduler.launch(&mut fabric, &spec).unwrap();
    assert_eq!(report.succeeded(), 64);
    let pull = report.pull.unwrap();
    assert_eq!(pull.jobs_total, 1, "64 nodes, one gateway job");
    assert_eq!(pull.requesters, 64);
    assert!(pull.turnaround_secs > 0.0);
    // every node cold-filled its own cache exactly once
    assert_eq!(report.cache.nodes, 64);
    assert_eq!(report.cache.misses, 64);
    assert_eq!(report.cache.hits, 0);
}

#[test]
fn launch_report_surfaces_queue_wait_behind_a_backlog() {
    // a huge unrelated pull is already queued on the (single) shard; the
    // job's coalesced pull must wait behind it and the report must say so
    let cluster =
        LaunchCluster::homogeneous(&SystemProfile::piz_daint(), 4);
    let registry = Registry::dockerhub();
    let mut fabric = DistributionFabric::new(1, LustreFs::piz_daint());
    fabric
        .request(&registry, "pynamic:1.3", "nightly-sync")
        .unwrap();
    let scheduler = strict_scheduler(&cluster, &registry);
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
    let report = scheduler.launch(&mut fabric, &spec).unwrap();
    assert_eq!(report.succeeded(), 4);
    let pull = report.pull.unwrap();
    assert!(
        pull.queue_wait_secs > 1.0,
        "queue wait {}s must cover the pynamic backlog",
        pull.queue_wait_secs
    );
    assert!(pull.turnaround_secs > pull.queue_wait_secs);
    // the fabric-level stats agree
    let wait = fabric.queue_wait_stats().unwrap();
    assert!((wait.worst - pull.queue_wait_secs).abs() < 1e-6);
}
