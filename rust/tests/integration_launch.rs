//! Integration: the cluster-scale launch orchestrator (DESIGN.md S19),
//! exercised exclusively through the `Site` facade (DESIGN.md S21) —
//! heterogeneous partitions get per-node correct injected driver stacks,
//! an unsatisfiable MPI ABI fails only its own launch slots, the pull
//! storm coalesces into one gateway job, and queue-wait surfaces in the
//! report.

use shifter_rs::launch::{JobSpec, RetryPolicy};
use shifter_rs::mpi::MpiImpl;
use shifter_rs::{Site, SiteBuilder, SystemProfile};

fn strict(builder: SiteBuilder) -> Site {
    builder
        .retry_policy(RetryPolicy::strict())
        .gateway_shards(4)
        .build()
        .expect("valid test site")
}

#[test]
fn heterogeneous_partitions_inject_their_own_driver_stacks() {
    // §IV.A across generations: P100 nodes run a 375.66 driver, the
    // K40m/K80 nodes a 367.48 driver — one job spanning both partitions
    // must see the right stack bind-mounted on every node
    let mut site = strict(
        Site::builder()
            .partition("daint-xc50", &SystemProfile::piz_daint(), 4)
            .partition("linux-cluster", &SystemProfile::linux_cluster(), 4),
    );
    let spec =
        JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 8).with_gpus(1);
    let report = site.launch(&spec).unwrap();

    assert_eq!(report.succeeded(), 8);
    assert_eq!(report.failed(), 0);
    for r in &report.node_results {
        assert!(r.ok(), "node {}: {:?}", r.node, r.error);
        let (expected, wrong) = if r.node < 4 {
            ("libcuda.so.375.66", "libcuda.so.367.48")
        } else {
            ("libcuda.so.367.48", "libcuda.so.375.66")
        };
        assert!(
            r.gpu_libraries.iter().any(|l| l == expected),
            "node {} [{}] missing {expected}: {:?}",
            r.node,
            r.partition,
            r.gpu_libraries
        );
        assert!(
            !r.gpu_libraries.iter().any(|l| l == wrong),
            "node {} [{}] got the other partition's driver",
            r.node,
            r.partition
        );
    }
}

#[test]
fn unsatisfiable_mpi_abi_fails_its_slots_without_poisoning_others() {
    // partition B's host MPI never joined the MPICH ABI initiative: the
    // §IV.B swap must refuse it on B's nodes while A's nodes launch with
    // the Cray MPT swap intact
    let mut openmpi_host = SystemProfile::linux_cluster();
    openmpi_host.host_mpi = MpiImpl::openmpi_2_0();
    let mut site = strict(
        Site::builder()
            .partition("daint-xc50", &SystemProfile::piz_daint(), 3)
            .partition("openmpi-island", &openmpi_host, 3),
    );
    let spec =
        JobSpec::new("osu-benchmarks:mpich-3.1.4", &["true"], 6).with_mpi();
    let report = site.launch(&spec).unwrap();

    assert_eq!(report.succeeded(), 3);
    assert_eq!(report.failed(), 3);
    for r in &report.node_results {
        if r.node < 3 {
            assert!(r.ok(), "daint node {} poisoned: {:?}", r.node, r.error);
            assert_eq!(r.host_mpi.as_deref(), Some("Cray MPT 7.5.0"));
        } else {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(
                err.contains("not ABI-compatible"),
                "node {}: wrong error {err:?}",
                r.node
            );
            // a permanent error must not burn retries
            assert_eq!(r.attempts, 1);
        }
    }
    let summary = report.failure_summary();
    assert_eq!(summary.len(), 1);
    assert_eq!(summary[0].1, 3);
}

#[test]
fn gres_shortfall_kills_only_the_gpuless_partition() {
    let mut gpuless = SystemProfile::linux_cluster();
    gpuless.nodes[0].gpus.clear();
    let mut site = strict(
        Site::builder()
            .partition("daint-xc50", &SystemProfile::piz_daint(), 2)
            .partition("cpu-only", &gpuless, 2),
    );
    let spec =
        JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 4).with_gpus(1);
    // the daint partition is GPU-capable, so the facade's fail-fast
    // check passes and the per-partition WLM shortfall surfaces per slot
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 2);
    assert_eq!(report.failed(), 2);
    for r in &report.node_results {
        if r.node >= 2 {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("wlm"), "node {}: {err:?}", r.node);
            assert!(err.contains("CUDA devices"), "node {}: {err:?}", r.node);
        } else {
            assert!(r.ok());
            assert!(!r.gpu_libraries.is_empty());
        }
    }
}

#[test]
fn ancient_kernel_partition_fails_preflight_only_for_itself() {
    let mut ancient = SystemProfile::piz_daint();
    ancient.kernel = "2.6.18"; // predates squashfs (mainlined 2.6.29)
    let mut site = strict(
        Site::builder()
            .partition("modern", &SystemProfile::piz_daint(), 2)
            .partition("museum", &ancient, 2),
    );
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 2);
    assert_eq!(report.failed(), 2);
    for r in &report.node_results {
        if r.node >= 2 {
            let err = r.error.as_deref().unwrap_or_default();
            assert!(err.contains("preflight"), "node {}: {err:?}", r.node);
            assert_eq!(r.attempts, 0, "dead slots never run");
        }
    }
}

#[test]
fn launch_storm_coalesces_into_one_pull_job() {
    let mut site = strict(Site::builder().hetero_daint_linux(64));
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 64);
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 64);
    let pull = report.pull.unwrap();
    assert_eq!(pull.jobs_total, 1, "64 nodes, one gateway job");
    assert_eq!(pull.requesters, 64);
    assert!(pull.turnaround_secs > 0.0);
    // every node cold-filled its own cache exactly once
    assert_eq!(report.cache.nodes, 64);
    assert_eq!(report.cache.misses, 64);
    assert_eq!(report.cache.hits, 0);
}

#[test]
fn launch_on_places_an_explicit_node_set_through_the_facade() {
    let mut site = strict(
        Site::builder().profile(SystemProfile::piz_daint()).nodes(16),
    );
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
    let nodes = [3u32, 7, 8, 15];
    let report = site.launch_on(&spec, &nodes).unwrap();
    assert_eq!(report.succeeded(), 4);
    let got: Vec<u32> =
        report.node_results.iter().map(|r| r.node).collect();
    assert_eq!(got, nodes);
    // the same nodes relaunch warm — their caches are keyed on the
    // global ids the explicit set named
    let warm = site.launch_on(&spec, &nodes).unwrap();
    assert_eq!(warm.cache.hits, 4);
}

#[test]
fn launch_report_surfaces_queue_wait_behind_a_backlog() {
    // a huge unrelated pull is already queued on the (single) shard; the
    // job's coalesced pull must wait behind it and the report must say so
    let mut site = Site::builder()
        .profile(SystemProfile::piz_daint())
        .nodes(4)
        .gateway_shards(1)
        .retry_policy(RetryPolicy::strict())
        .build()
        .unwrap();
    site.request("pynamic:1.3", "nightly-sync").unwrap();
    let spec = JobSpec::new("ubuntu:xenial", &["true"], 4);
    let report = site.launch(&spec).unwrap();
    assert_eq!(report.succeeded(), 4);
    let pull = report.pull.unwrap();
    assert!(
        pull.queue_wait_secs > 1.0,
        "queue wait {}s must cover the pynamic backlog",
        pull.queue_wait_secs
    );
    assert!(pull.turnaround_secs > pull.queue_wait_secs);
    // the fabric-level stats agree
    let wait = site.fabric().queue_wait_stats().unwrap();
    assert!((wait.worst - pull.queue_wait_secs).abs() < 1e-6);
}
