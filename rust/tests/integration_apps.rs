//! Integration: the shape-to-hold criteria of DESIGN.md §3 — every table
//! and figure's qualitative structure, asserted end to end through the
//! full stack (runtime containers feeding the application models).

use shifter_rs::apps::{nbody, osu, pyfr, pynamic, tf_trainer};
use shifter_rs::fabric::OSU_SIZES;
use shifter_rs::gpu::GpuModel;
use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

#[test]
fn table1_shape_daint_lt_cluster_lt_laptop() {
    use tf_trainer::{train_time_secs, TfWorkload};
    for wl in [TfWorkload::Mnist, TfWorkload::Cifar10] {
        let lap = train_time_secs(wl, &GpuModel::quadro_k110m());
        let clu = train_time_secs(wl, &GpuModel::tesla_k40m());
        let pd = train_time_secs(wl, &GpuModel::tesla_p100());
        assert!(pd < clu && clu < lap);
    }
    // MNIST paper ratios: laptop/daint ~ 17x, cluster/daint ~ 2.9x
    let r_lap = train_time_secs(TfWorkload::Mnist, &GpuModel::quadro_k110m())
        / train_time_secs(TfWorkload::Mnist, &GpuModel::tesla_p100());
    assert!((14.0..20.0).contains(&r_lap), "{r_lap}");
}

#[test]
fn table2_shape_linear_scaling_and_4x() {
    let pd = SystemProfile::piz_daint();
    let t1 = pyfr::wallclock_secs(&pyfr::PyfrRun::daint(1), &pd, &pd.host_mpi);
    let t2 = pyfr::wallclock_secs(&pyfr::PyfrRun::daint(2), &pd, &pd.host_mpi);
    let t4 = pyfr::wallclock_secs(&pyfr::PyfrRun::daint(4), &pd, &pd.host_mpi);
    let t8 = pyfr::wallclock_secs(&pyfr::PyfrRun::daint(8), &pd, &pd.host_mpi);
    for (n, t) in [(2.0, t2), (4.0, t4), (8.0, t8)] {
        let eff = t1 / (n * t);
        assert!(eff > 0.85, "{n}-GPU efficiency {eff}");
    }
    let cl = SystemProfile::linux_cluster();
    let c1 = pyfr::wallclock_secs(&pyfr::PyfrRun::cluster(1), &cl, &cl.host_mpi);
    assert!((3.5..4.7).contains(&(c1 / t1)));
}

#[test]
fn tables_3_4_shape_through_full_stack() {
    let registry = Registry::dockerhub();
    for (profile, disabled_lo, disabled_hi) in [
        (SystemProfile::linux_cluster(), 12.0, 55.0),
        (SystemProfile::piz_daint(), 1.2, 7.0),
    ] {
        let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
        gw.pull(&registry, "osu-benchmarks:mpich-3.1.4").unwrap();
        let rt = ShifterRuntime::new(&profile);
        let native = osu::run_native(&profile);

        let c_on = rt
            .run(
                &gw,
                &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"])
                    .with_mpi(),
            )
            .unwrap();
        let on = osu::run_container(&profile, &c_on, "it-on");
        let c_off = rt
            .run(
                &gw,
                &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"]),
            )
            .unwrap();
        let off = osu::run_container(&profile, &c_off, "it-off");

        for (i, &size) in OSU_SIZES.iter().enumerate() {
            let r_on = on[i].best_us / native[i].best_us;
            let r_off = off[i].best_us / native[i].best_us;
            assert!(
                (0.9..1.12).contains(&r_on),
                "{} size {size}: enabled {r_on}",
                profile.name
            );
            assert!(
                (disabled_lo..disabled_hi).contains(&r_off),
                "{} size {size}: disabled {r_off}",
                profile.name
            );
        }
    }
}

#[test]
fn table5_shape_container_equals_native() {
    for setup in [
        nbody::NbodySetup::laptop(),
        nbody::NbodySetup::cluster_single(),
        nbody::NbodySetup::cluster_dual(),
        nbody::NbodySetup::daint(),
    ] {
        let nat = nbody::benchmark_gflops(&setup, "native").best;
        let cont = nbody::benchmark_gflops(&setup, "container").best;
        assert!(((cont / nat) - 1.0).abs() < 0.005, "{}", setup.label);
    }
}

#[test]
fn fig3_shape_native_grows_shifter_flat() {
    let pd = SystemProfile::piz_daint();
    let mut prev_native = 0.0;
    for ranks in [48u64, 384, 3072] {
        let nat = pynamic::run(&pd, ranks, pynamic::Mode::Native);
        assert!(nat.import.mean > prev_native);
        prev_native = nat.import.mean;
    }
    let s48 = pynamic::run(&pd, 48, pynamic::Mode::Shifter);
    let s3072 = pynamic::run(&pd, 3072, pynamic::Mode::Shifter);
    assert!(s3072.import.mean < 1.5 * s48.import.mean);
    // the headline: a >3000-process python app deploys with far lower
    // overhead through Shifter
    let n3072 = pynamic::run(&pd, 3072, pynamic::Mode::Native);
    assert!(n3072.total_mean() > 5.0 * s3072.total_mean());
}

#[test]
fn startup_overhead_negligible_vs_app_runtime() {
    // the paper's "negligible overhead" claim, quantified end to end:
    // container preparation is milliseconds; the shortest benchmark run
    // (MNIST on Daint, 36 s) is still 100x longer.
    let registry = Registry::dockerhub();
    let profile = SystemProfile::piz_daint();
    let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
    gw.pull(&registry, "tensorflow/tensorflow:1.0.0-devel-gpu-py3")
        .unwrap();
    let rt = ShifterRuntime::new(&profile);
    let c = rt
        .run(
            &gw,
            &RunOptions::new(
                "tensorflow/tensorflow:1.0.0-devel-gpu-py3",
                &["python3"],
            ),
        )
        .unwrap();
    let overhead = c.startup_overhead_secs();
    let shortest_app = tf_trainer::train_time_secs(
        tf_trainer::TfWorkload::Mnist,
        &GpuModel::tesla_p100(),
    );
    assert!(
        overhead < shortest_app / 50.0,
        "overhead {overhead}s vs app {shortest_app}s"
    );
}
