//! Failure injection: every way the runtime must refuse or degrade
//! gracefully, exercised through the full stack.

use shifter_rs::config::UdiRootConfig;
use shifter_rs::hostenv::SystemProfile;
use shifter_rs::shifter::{
    ExtensionError, GpuSupportError, MpiSupportError, RunOptions,
    ShifterError, ShifterRuntime,
};
use shifter_rs::wlm::{GresRequest, Slurm, WlmError};
use shifter_rs::{ImageGateway, Registry};

fn gw(profile: &SystemProfile, images: &[&str]) -> ImageGateway {
    let registry = Registry::dockerhub();
    let mut g = ImageGateway::new(profile.pfs.clone().unwrap());
    for i in images {
        g.pull(&registry, i).unwrap();
    }
    g
}

#[test]
fn unpulled_image_refused_with_actionable_hint() {
    let pd = SystemProfile::piz_daint();
    let g = gw(&pd, &[]);
    let rt = ShifterRuntime::new(&pd);
    let err = rt
        .run(&g, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap_err();
    let msg = err.to_string();
    assert!(msg.contains("not pulled") && msg.contains("shifterimg pull"));
}

#[test]
fn invalid_cvd_degrades_to_no_gpu_not_an_error() {
    // §IV.A: invalid value -> support not triggered; the container still runs
    let pd = SystemProfile::piz_daint();
    let g = gw(&pd, &["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&pd);
    for bad in ["NoDevFiles", "-3", "a,b", ""] {
        let c = rt
            .run(
                &g,
                &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                    .with_env("CUDA_VISIBLE_DEVICES", bad),
            )
            .unwrap();
        assert!(c.gpu.is_none(), "bad value {bad:?} must not trigger");
        assert!(c.stage_log.completed());
    }
}

#[test]
fn out_of_range_device_is_a_hard_error() {
    let pd = SystemProfile::piz_daint(); // 1 GPU per node
    let g = gw(&pd, &["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&pd);
    let err = rt
        .run(
            &g,
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "0,1"),
        )
        .unwrap_err();
    // the gate refuses in preflight, before any environment work
    match err {
        ShifterError::ExtensionCheck {
            extension: "gpu",
            source:
                ExtensionError::Gpu(GpuSupportError::DeviceOutOfRange(1, 1)),
        } => {}
        other => panic!("wrong error: {other}"),
    }
}

#[test]
fn gpuless_host_cannot_activate_gpu_support() {
    // a synthetic CPU-only profile: laptop stripped of its GPU
    let mut profile = SystemProfile::laptop();
    profile.nodes[0].gpus.clear();
    profile.driver_version = None;
    let registry = Registry::dockerhub();
    let mut g = ImageGateway::new(shifter_rs::pfs::LustreFs::piz_daint());
    g.pull(&registry, "nvidia/cuda-image:8.0").unwrap();
    let rt = ShifterRuntime::new(&profile);
    let err = rt
        .run(
            &g,
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap_err();
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "gpu",
            source: ExtensionError::Gpu(GpuSupportError::DriverNotLoaded),
        }
    ));
}

#[test]
fn cuda8_container_refused_by_old_driver() {
    // host with a pre-CUDA-8 driver must refuse the CUDA 8 image
    let mut profile = SystemProfile::linux_cluster();
    profile.driver_version = Some((340, 29));
    let g = gw(&profile, &["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&profile);
    let err = rt
        .run(
            &g,
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "0"),
        )
        .unwrap_err();
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "gpu",
            source: ExtensionError::Gpu(
                GpuSupportError::CudaIncompatible { .. }
            ),
        }
    ));
}

#[test]
fn openmpi_container_swap_refused() {
    let pd = SystemProfile::piz_daint();
    let g = gw(&pd, &["osu-benchmarks:openmpi-2.0"]);
    let rt = ShifterRuntime::new(&pd);
    let err = rt
        .run(
            &g,
            &RunOptions::new("osu-benchmarks:openmpi-2.0", &["osu_latency"])
                .with_mpi(),
        )
        .unwrap_err();
    match err {
        ShifterError::ExtensionCheck {
            extension: "mpi",
            source:
                ExtensionError::Mpi(MpiSupportError::AbiIncompatible {
                    container_abi,
                    ..
                }),
        } => assert_eq!(container_abi, "40:0:20"),
        other => panic!("wrong error: {other}"),
    }
    // without --mpi the same container runs (TCP fallback)
    let c = rt
        .run(
            &g,
            &RunOptions::new("osu-benchmarks:openmpi-2.0", &["osu_latency"]),
        )
        .unwrap();
    assert!(c.mpi.is_none());
}

#[test]
fn mpi_flag_on_image_without_mpi_fails() {
    let pd = SystemProfile::piz_daint();
    let g = gw(&pd, &["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&pd);
    let err = rt
        .run(&g, &RunOptions::new("ubuntu:xenial", &["true"]).with_mpi())
        .unwrap_err();
    // regression (S22): the no-MPI-in-image check moved into
    // HostExtension::check — it must fail in preflight, not mid-prepare
    assert!(matches!(
        err,
        ShifterError::ExtensionCheck {
            extension: "mpi",
            source: ExtensionError::Mpi(MpiSupportError::NoMpiInImage),
        }
    ));
    assert!(err.to_string().contains("preflight"), "{err}");
}

#[test]
fn misconfigured_host_mpi_paths_detected() {
    // admin typo: config points at non-existent host libraries
    let pd = SystemProfile::piz_daint();
    let mut cfg = UdiRootConfig::for_profile(&pd);
    cfg.mpi_frontend_paths = vec![
        "/wrong/libmpi.so.12".into(),
        "/wrong/libmpicxx.so.12".into(),
        "/wrong/libmpifort.so.12".into(),
    ];
    let g = gw(&pd, &["osu-benchmarks:mpich-3.1.4"]);
    let rt = ShifterRuntime::with_config(&pd, cfg);
    let err = rt
        .run(
            &g,
            &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["osu_latency"])
                .with_mpi(),
        )
        .unwrap_err();
    // a missing host library only surfaces while injecting (the ABI gate
    // passed) — so this is an Extension error, not a preflight refusal
    assert!(matches!(
        err,
        ShifterError::Extension(ExtensionError::Mpi(
            MpiSupportError::MissingHostLibrary(_)
        ))
    ));
}

#[test]
fn wlm_rejects_impossible_requests() {
    let cl = SystemProfile::linux_cluster(); // 2 nodes, 3 CUDA devices each
    let mut slurm = Slurm::new(&cl);
    assert!(matches!(
        slurm.salloc(3),
        Err(WlmError::NotEnoughNodes { .. })
    ));
    let alloc = slurm.salloc(2).unwrap();
    assert!(matches!(
        slurm.srun(&alloc, 2, Some(GresRequest { gpus_per_node: 4 })),
        Err(WlmError::NotEnoughGpus { .. })
    ));
    assert!(matches!(
        slurm.srun(&alloc, 1000, None),
        Err(WlmError::TooManyTasks { .. })
    ));
}

#[test]
fn exec_of_missing_file_fails_cleanly() {
    let pd = SystemProfile::piz_daint();
    let g = gw(&pd, &["ubuntu:xenial"]);
    let rt = ShifterRuntime::new(&pd);
    let c = rt
        .run(&g, &RunOptions::new("ubuntu:xenial", &["true"]))
        .unwrap();
    let err = c.exec(&["cat", "/nonexistent"]).unwrap_err();
    assert!(err.to_string().contains("No such file"));
}

#[test]
fn bad_registry_reference_reported() {
    let registry = Registry::dockerhub();
    let pd = SystemProfile::piz_daint();
    let mut g = ImageGateway::new(pd.pfs.clone().unwrap());
    assert!(g.pull(&registry, "definitely-not-an-image:v9").is_err());
    assert!(g.pull(&registry, "").is_err());
}

#[test]
fn config_file_errors_are_line_accurate() {
    use shifter_rs::config::ConfigError;
    let text = "udiMount = /var/udiMount\nsiteFs broken-line\n";
    match UdiRootConfig::from_conf(text) {
        Err(ConfigError::BadLine(2)) => {}
        other => panic!("wrong: {other:?}"),
    }
}

#[test]
fn k80_only_gres_still_renumbers_from_zero() {
    // asking for device 2 only (the second K80 chip): container sees id 0
    let cl = SystemProfile::linux_cluster();
    let g = gw(&cl, &["nvidia/cuda-image:8.0"]);
    let rt = ShifterRuntime::new(&cl);
    let c = rt
        .run(
            &g,
            &RunOptions::new("nvidia/cuda-image:8.0", &["true"])
                .with_env("CUDA_VISIBLE_DEVICES", "2"),
        )
        .unwrap();
    let gpu = c.gpu.as_ref().unwrap();
    assert_eq!(gpu.host_devices, vec![2]);
    assert_eq!(gpu.container_devices, vec![0]); // §IV.A.3
    let boards = c.visible_gpus(&cl, 0);
    assert_eq!(boards[0].name, "Tesla K80");
}
