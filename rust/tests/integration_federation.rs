//! Integration: the multi-site federation (DESIGN.md S27) — capability
//! routing rejects what no site can run, burst overflow spills only to
//! *compatible* sites, and cross-site replication is paid (and
//! accounted) before a job may start, with concurrent arrivals
//! coalescing onto one WAN transfer.

use shifter_rs::federation::PinnedHome;
use shifter_rs::launch::JobSpec;
use shifter_rs::tenancy::{JobClass, TenantJob};
use shifter_rs::{
    Federation, FederationStorm, SiteBuilder, SystemProfile,
};

/// A CPU-class job that asks for the specialized-networking extension
/// (`SHIFTER_NET=host`) — eligible only on sites whose fabric supports
/// it (the laptop profile has no fabric, so it never qualifies).
fn net_job(id: u32, tenant_idx: u32, arrival: f64, width: u32) -> TenantJob {
    TenantJob {
        id,
        tenant: format!("tenant-{tenant_idx:02}"),
        tenant_idx,
        arrival_secs: arrival,
        runtime_secs: 600.0,
        class: JobClass::Cpu,
        spec: JobSpec::new("ubuntu:xenial", &["true"], width)
            .with_env("SHIFTER_NET", "host"),
    }
}

fn cpu_job(id: u32, tenant_idx: u32, arrival: f64, width: u32) -> TenantJob {
    TenantJob {
        id,
        tenant: format!("tenant-{tenant_idx:02}"),
        tenant_idx,
        arrival_secs: arrival,
        runtime_secs: 600.0,
        class: JobClass::Cpu,
        spec: JobSpec::new("ubuntu:xenial", &["true"], width),
    }
}

#[test]
fn capability_mismatch_rejects_with_a_reason_instead_of_failing_late() {
    // two laptop sites: GPU and MPI are available, but no fabric —
    // a net-requiring job has nowhere to go
    let mut fed = Federation::builder()
        .site(
            "laptop-a",
            SiteBuilder::new().profile(SystemProfile::laptop()).nodes(4),
        )
        .site(
            "laptop-b",
            SiteBuilder::new().profile(SystemProfile::laptop()).nodes(4),
        )
        .build()
        .unwrap();
    let report = fed
        .run_storm(&FederationStorm::new().job_stream(vec![
            net_job(0, 0, 0.0, 2),
            cpu_job(1, 0, 1.0, 2),
        ]))
        .unwrap();

    // the net job was rejected up front with a per-site reason...
    assert_eq!(report.rejections.len(), 1);
    let rejection = &report.rejections[0];
    assert_eq!(rejection.id, 0);
    assert!(
        rejection.reason.contains("net"),
        "the reason must name the missing capability: {}",
        rejection.reason
    );
    // ...while the plain CPU job from the same stream ran normally
    assert_eq!(report.records.len(), 1);
    assert_eq!(report.records[0].id, 1);
    assert_eq!(report.completed(), 1);
}

#[test]
fn burst_overflow_spills_only_to_capability_compatible_sites() {
    // one contended stream of net-requiring jobs, tiny threshold: the
    // home queue estimate crosses it almost immediately
    let stream: Vec<TenantJob> =
        (0..6).map(|i| net_job(i, 0, f64::from(i), 8)).collect();
    let storm = || {
        FederationStorm::new().job_stream(stream.clone())
    };
    let daint =
        || SiteBuilder::new().profile(SystemProfile::piz_daint()).nodes(8);

    // fleet A: the only net-capable site is the home — overflow has no
    // compatible alternative, so every job stays (and none is rejected)
    let mut capped = Federation::builder()
        .site("daint", daint())
        .site(
            "edge",
            SiteBuilder::new().profile(SystemProfile::laptop()).nodes(8),
        )
        .overflow_threshold_secs(1.0)
        .build()
        .unwrap();
    let capped_report = capped.run_storm(&storm()).unwrap();
    assert!(capped_report.rejections.is_empty());
    assert_eq!(capped_report.overflows, 0);
    assert_eq!(capped_report.completed(), stream.len());
    assert!(
        capped_report.records.iter().all(|r| r.site == "daint"),
        "net jobs may only run on the net-capable site"
    );

    // fleet B: replace the edge box with a second net-capable site —
    // the identical stream now spills
    let mut open = Federation::builder()
        .site("daint", daint())
        .site("alps", daint())
        .overflow_threshold_secs(1.0)
        .build()
        .unwrap();
    let open_report = open.run_storm(&storm()).unwrap();
    assert!(open_report.rejections.is_empty());
    assert!(
        open_report.overflows > 0,
        "with a compatible alternative the same stream must overflow"
    );
    assert_eq!(open_report.completed(), stream.len());
    assert!(open_report.records.iter().any(|r| r.site == "alps"));
}

#[test]
fn replication_is_paid_before_start_and_concurrent_pulls_coalesce() {
    let member =
        || SiteBuilder::new().profile(SystemProfile::piz_daint()).nodes(8);
    let mut fed = Federation::builder()
        .site("alpha", member())
        .site("bravo", member())
        // tenant 0 -> alpha, tenant 1 -> bravo
        .routing(Box::new(PinnedHome::new(2)))
        .build()
        .unwrap();

    // alpha sees three arrivals of one image: two inside the transfer
    // window (coalesce), one long after (warm replica); bravo pulls the
    // same image once — from its peer, not the origin
    let report = fed
        .run_storm(&FederationStorm::new().job_stream(vec![
            cpu_job(0, 0, 0.0, 2),
            cpu_job(1, 0, 0.2, 2),
            cpu_job(2, 0, 5000.0, 2),
            cpu_job(3, 1, 0.0, 2),
        ]))
        .unwrap();
    assert!(report.rejections.is_empty());
    assert_eq!(report.completed(), 4);

    // exactly one transfer per (site, image): alpha's two concurrent
    // arrivals share one, the warm third costs nothing
    assert_eq!(report.replications, 2);
    assert!(report.origin_bytes > 0, "alpha pulls from the origin");
    assert!(
        report.peer_bytes > 0,
        "bravo must source the replica from its peer (alpha committed \
         the index first), not the origin"
    );

    let rec = |id: u32| {
        report.records.iter().find(|r| r.id == id).expect("routed")
    };
    let (r0, r1, r2, r3) = (rec(0), rec(1), rec(2), rec(3));
    // the WAN delay is charged before the site queue sees the job
    assert!(r0.wan_wait_secs > 0.0);
    assert!(r3.wan_wait_secs > 0.0);
    // coalesced arrivals become ready at the same instant: job 1
    // piggybacks on job 0's in-flight transfer
    let ready = |r: &shifter_rs::federation::FedJobRecord| {
        r.arrival_secs + r.wan_wait_secs
    };
    assert!(r1.wan_wait_secs > 0.0 && r1.wan_wait_secs < r0.wan_wait_secs);
    assert!((ready(r0) - ready(r1)).abs() < 1e-9);
    // by job 2's arrival the replica is warm — no WAN wait at all
    assert_eq!(r2.wan_wait_secs, 0.0);
    // accounting is consistent: total = wan + site for every record
    for r in &report.records {
        assert!(
            (r.total_wait_secs - (r.wan_wait_secs + r.site_wait_secs))
                .abs()
                < 1e-9
        );
    }
}
