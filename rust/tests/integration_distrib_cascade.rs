//! Integration: the DESIGN.md S25 distribution mechanisms composed end
//! to end — topology-aware cascade fills never re-fetch into a cabinet,
//! lazy-start containers observe exactly the filesystem an eager pull
//! produces, and a dead peer degrades to a gateway fallback instead of
//! stalling the tree.

use std::collections::BTreeMap;

use shifter_rs::distrib::{CascadeConfig, DistributionFabric};
use shifter_rs::gateway::ImageSource;
use shifter_rs::pfs::LustreFs;
use shifter_rs::shifter::{Container, RunOptions, Stage};
use shifter_rs::{Registry, Site};

fn cascade_fabric(fanout: usize) -> (DistributionFabric, Registry) {
    let fabric = DistributionFabric::new(4, LustreFs::piz_daint())
        .with_cascade(CascadeConfig {
            cabinet_nodes: 8,
            fanout,
        });
    (fabric, Registry::dockerhub())
}

#[test]
fn cascade_fetches_each_image_into_a_cabinet_exactly_once() {
    let (mut fabric, registry) = cascade_fabric(2);
    fabric
        .pull_blocking(&registry, "ubuntu:xenial", "u")
        .unwrap();
    {
        let image = fabric.resolve("ubuntu:xenial").unwrap();
        for node in 0..64 {
            fabric.node_fetch_secs(image, node, 64).unwrap();
        }
    }
    let stats = fabric.cascade_stats();
    assert_eq!(stats.cascades, 1, "one storm, one plan");
    assert_eq!(stats.gateway_fills, 1, "one gateway read seeds the tree");
    assert_eq!(stats.gateway_fallbacks, 0, "all peers alive");
    assert_eq!(stats.peer_transfers, 63, "everyone else fetched a peer");
    assert!(stats.max_depth >= 3, "64 nodes at fan-out 2 take depth");

    // the cascade invariant: image data enters each cabinet exactly once
    // (the seed's gateway read, or one inter-cabinet transfer)
    let entries: BTreeMap<usize, u64> =
        fabric.cascade_cabinet_entries("ubuntu:xenial").unwrap();
    assert_eq!(entries.len(), 8, "8 cabinets of 8 nodes each");
    for (cabinet, n) in &entries {
        assert_eq!(*n, 1, "cabinet {cabinet} entered {n} times, want 1");
    }
}

#[test]
fn lazy_start_containers_see_the_same_filesystem_as_eager() {
    let build = |lazy: bool| {
        Site::builder()
            .nodes(16)
            .cascade(8, 3)
            .chunk_target_bytes(1 << 20)
            .lazy_pull(lazy)
            .seed(7)
            .build()
            .unwrap()
    };
    let opts = RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"])
        .on_nodes(0, 16);
    let mut eager_site = build(false);
    let mut lazy_site = build(true);
    let eager = eager_site.run(&opts).unwrap();
    let lazy = lazy_site.run(&opts).unwrap();

    // identical observable container state: same rootfs, same env, same
    // file contents — laziness must never leak into what the app sees
    assert_eq!(lazy.rootfs, eager.rootfs);
    assert_eq!(lazy.env, eager.env);
    assert_eq!(lazy.mounts, eager.mounts);
    let (a, b) = (
        lazy.read_file("/etc/os-release").expect("content-backed file"),
        eager.read_file("/etc/os-release").expect("content-backed file"),
    );
    assert_eq!(a, b);
    assert!(a.contains("Xenial"));

    // the cost moves, the work doesn't: preparation shrinks to the
    // start-ready head, execution absorbs the streamed tail
    let stage_secs = |c: &Container, stage: Stage| {
        c.stage_log
            .records()
            .iter()
            .find(|r| r.stage == stage)
            .map(|r| r.sim_secs)
            .expect("stage ran")
    };
    assert!(
        stage_secs(&lazy, Stage::PrepareEnvironment)
            < stage_secs(&eager, Stage::PrepareEnvironment),
        "lazy preparation must start before the full image lands"
    );
    assert!(
        stage_secs(&lazy, Stage::Execute) > stage_secs(&eager, Stage::Execute),
        "the deferred tail must be charged to execution"
    );
}

#[test]
fn dead_peer_falls_back_to_gateway_without_stalling() {
    let (mut fabric, registry) = cascade_fabric(2);
    // kill the node the planner would use as the gateway seed
    fabric.mark_node_dead(0);
    fabric
        .pull_blocking(&registry, "ubuntu:xenial", "u")
        .unwrap();
    let fills: Vec<f64> = {
        let image = fabric.resolve("ubuntu:xenial").unwrap();
        (0..32)
            .map(|n| fabric.node_fetch_secs(image, n, 32).unwrap())
            .collect()
    };
    for (node, f) in fills.iter().enumerate() {
        assert!(
            f.is_finite() && *f >= 0.0,
            "node {node} stalled on the dead peer: {f}"
        );
    }
    let stats = fabric.cascade_stats();
    assert!(
        stats.gateway_fallbacks >= 1,
        "orphaned children must time out and fall back to the gateway"
    );
    assert!(
        stats.peer_transfers > 0,
        "the rest of the tree still cascades"
    );

    // warm refetch: the storm left every cache populated
    let image = fabric.resolve("ubuntu:xenial").unwrap();
    let warm = fabric.node_fetch_secs(image, 1, 32).unwrap();
    assert!(warm < 1e-2, "second fetch must be a warm hit: {warm}s");
}
