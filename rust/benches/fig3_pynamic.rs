//! Fig. 3 — Pynamic on Piz Daint: start-up, import and visit times,
//! running natively on Lustre vs from a Shifter loop-mounted container,
//! across MPI job sizes 48…3072 (mean ± std over 30 runs).
//!
//! The paper reports the figure only (no numeric table); the shape that
//! must hold: native grows ~linearly with ranks (MDS saturation) while
//! Shifter stays nearly flat, with a large gap at 3072 ranks.

use shifter_rs::apps::pynamic::{self, Mode, FIG3_RANKS};
use shifter_rs::metrics::Table;
use shifter_rs::SystemProfile;

fn main() {
    let pd = SystemProfile::piz_daint();

    let mut t = Table::new(
        "Fig 3: Pynamic on Piz Daint (seconds, mean ± std of 30 runs)",
        &[
            "ranks",
            "nat-startup",
            "nat-import",
            "nat-visit",
            "shf-startup",
            "shf-import",
            "shf-visit",
            "speedup",
        ],
    );

    let fmt = |s: &shifter_rs::metrics::Stats| format!("{:.1}±{:.1}", s.mean, s.std);
    let mut native_imports = Vec::new();
    let mut shifter_imports = Vec::new();
    for &ranks in &FIG3_RANKS {
        let nat = pynamic::run(&pd, ranks, Mode::Native);
        let shf = pynamic::run(&pd, ranks, Mode::Shifter);
        t.row(&[
            ranks.to_string(),
            fmt(&nat.startup),
            fmt(&nat.import),
            fmt(&nat.visit),
            fmt(&shf.startup),
            fmt(&shf.import),
            fmt(&shf.visit),
            format!("{:.1}x", nat.total_mean() / shf.total_mean()),
        ]);
        native_imports.push(nat.import.mean);
        shifter_imports.push(shf.import.mean);
    }
    print!("{}", t.render());

    // shape assertions
    let n_first = native_imports[0];
    let n_last = *native_imports.last().unwrap();
    assert!(
        n_last > 8.0 * n_first,
        "native import must grow with ranks: {n_first} -> {n_last}"
    );
    let s_first = shifter_imports[0];
    let s_last = *shifter_imports.last().unwrap();
    assert!(
        s_last < 1.5 * s_first,
        "shifter import must stay flat: {s_first} -> {s_last}"
    );
    assert!(n_last > 5.0 * s_last, "gap at 3072 ranks");
    println!(
        "shape holds: native import grows {:.0}x over the sweep, shifter {:.2}x; \
         {:.0}x faster at 3072 ranks ✓",
        n_last / n_first,
        s_last / s_first,
        n_last / s_last
    );
}
