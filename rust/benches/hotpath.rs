//! Hot-path micro-benchmarks (§Perf baseline): real wall-clock timing of
//! the L3 operations that sit on the container execution path, plus the
//! PJRT dispatch overhead. Criterion is not in the offline vendor set, so
//! this uses a median-of-N protocol with warmup (same discipline).

use std::sync::Arc;
use std::time::Instant;

use shifter_rs::runtime::{Executor, TensorValue};
use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::util::json::Json;
use shifter_rs::{ImageGateway, Registry, SystemProfile, Telemetry};

/// Median-of-N timing with warmup.
fn time_op<F: FnMut()>(name: &str, n: usize, mut f: F) -> f64 {
    for _ in 0..(n / 10).max(2) {
        f();
    }
    let mut samples: Vec<f64> = (0..n)
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    samples.sort_by(f64::total_cmp);
    let median = samples[n / 2];
    let p90 = samples[(n * 9) / 10];
    println!(
        "  {name:<44} median {:>10.1} µs   p90 {:>10.1} µs",
        median * 1e6,
        p90 * 1e6
    );
    median
}

fn main() {
    println!("== L3 hot-path micro-benchmarks (real wall-clock) ==");
    let daint = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(daint.pfs.clone().unwrap());
    gateway.pull(&registry, "ubuntu:xenial").unwrap();
    gateway.pull(&registry, "osu-benchmarks:mpich-3.1.4").unwrap();
    gateway.pull(&registry, "nvidia/cuda-image:8.0").unwrap();
    let runtime = ShifterRuntime::new(&daint);

    // full container preparation pipeline (the paper's overhead claim
    // rests on this path being cheap relative to application runtime)
    let plain = RunOptions::new("ubuntu:xenial", &["true"]);
    let t_plain = time_op("runtime.run: plain container", 30, || {
        let c = runtime.run(&gateway, &plain).unwrap();
        std::hint::black_box(c.mounts.len());
    });

    let gpu = RunOptions::new("nvidia/cuda-image:8.0", &["true"])
        .with_env("CUDA_VISIBLE_DEVICES", "0");
    time_op("runtime.run: + GPU support", 30, || {
        let c = runtime.run(&gateway, &gpu).unwrap();
        std::hint::black_box(c.gpu.is_some());
    });

    let mpi = RunOptions::new("osu-benchmarks:mpich-3.1.4", &["true"]).with_mpi();
    time_op("runtime.run: + MPI swap", 30, || {
        let c = runtime.run(&gateway, &mpi).unwrap();
        std::hint::black_box(c.mpi.is_some());
    });

    // telemetry tax on the container hot path (DESIGN.md S23): a
    // disabled recorder must be free, an enabled one must stay in the
    // single-digit-percent range
    let off = ShifterRuntime::new(&daint)
        .with_telemetry(Arc::new(Telemetry::disabled()));
    let t_off = time_op("runtime.run: telemetry disabled", 30, || {
        let c = off.run(&gateway, &plain).unwrap();
        std::hint::black_box(c.mounts.len());
    });
    let recorder = Arc::new(Telemetry::new(true));
    let on = ShifterRuntime::new(&daint)
        .with_telemetry(Arc::clone(&recorder));
    let t_on = time_op("runtime.run: telemetry enabled", 30, || {
        let c = on.run(&gateway, &plain).unwrap();
        std::hint::black_box(c.mounts.len());
    });
    assert!(recorder.span_count() > 0, "enabled recorder captured spans");
    let disabled_tax = (t_off / t_plain - 1.0) * 100.0;
    let enabled_tax = (t_on / t_plain - 1.0) * 100.0;
    println!(
        "  telemetry tax vs baseline: disabled {disabled_tax:+.1}%, \
         enabled {enabled_tax:+.1}%"
    );
    // generous bounds — this is a wall-clock bench on shared hardware,
    // so the assert catches regressions in kind, not scheduler jitter
    assert!(
        t_off < t_plain * 1.5 + 100e-6,
        "a disabled recorder must cost ~nothing (baseline {:.1}µs, \
         disabled {:.1}µs)",
        t_plain * 1e6,
        t_off * 1e6
    );
    assert!(
        t_on < t_plain * 2.0 + 200e-6,
        "an enabled recorder must stay far below 2x (baseline {:.1}µs, \
         enabled {:.1}µs)",
        t_plain * 1e6,
        t_on * 1e6
    );

    // gateway pull cache hit (idempotence path)
    time_op("gateway.pull: digest-cache hit", 100, || {
        let r = gateway.pull(&registry, "ubuntu:xenial").unwrap();
        std::hint::black_box(r.cached);
    });

    // manifest JSON parse
    let manifest_path = shifter_rs::runtime::default_artifact_dir().join("manifest.json");
    if let Ok(text) = std::fs::read_to_string(&manifest_path) {
        time_op("json: parse artifacts manifest", 200, || {
            std::hint::black_box(Json::parse(&text).unwrap());
        });
    }

    // PJRT dispatch overhead: smallest artifact, repeated execution
    if let Ok(ex) = Executor::new(shifter_rs::runtime::default_artifact_dir()) {
        let spec = ex.catalog().get("pyfr_step").unwrap();
        let u = vec![0.5f32; spec.inputs[0].element_count()];
        let op = vec![0.1f32; spec.inputs[1].element_count()];
        let inputs = [
            TensorValue::F32(u),
            TensorValue::F32(op),
            TensorValue::F32(vec![0.0]),
        ];
        // first call compiles; time steady-state dispatch+compute
        ex.execute("pyfr_step", &inputs).unwrap();
        time_op("executor.execute: pyfr_step (2048 elems)", 50, || {
            std::hint::black_box(ex.execute("pyfr_step", &inputs).unwrap());
        });
    }

    println!(
        "\ncontainer preparation costs {:.1} µs of real L3 work vs minutes-to-hours \
         of application runtime — the L3 runtime is not the bottleneck",
        t_plain * 1e6
    );
}
