//! Table II — PyFR T106D wall-clock times (seconds) on Shifter with GPU +
//! MPI support, 1–8 GPUs, Linux Cluster and Piz Daint.
//!
//! Paper values: Cluster 9906 / 4961 / 2509, Daint 2391 / 1223 / 620 / 322.

use shifter_rs::apps::pyfr::{self, PyfrRun};
use shifter_rs::metrics::Table;
use shifter_rs::runtime::Executor;
use shifter_rs::SystemProfile;

fn main() {
    let paper_cluster = [(1usize, 9906.0), (2, 4961.0), (4, 2509.0)];
    let paper_daint = [(1usize, 2391.0), (2, 1223.0), (4, 620.0), (8, 322.0)];

    let mut t = Table::new(
        "Table II: PyFR wall-clock times on Shifter (s)",
        &["system", "gpus", "paper", "measured", "ratio"],
    );
    let mut worst: f64 = 0.0;

    let cl = SystemProfile::linux_cluster();
    for (gpus, p) in paper_cluster {
        let m = pyfr::wallclock_secs(&PyfrRun::cluster(gpus), &cl, &cl.host_mpi);
        worst = worst.max((m / p - 1.0).abs());
        t.row(&[
            "Cluster".into(),
            gpus.to_string(),
            format!("{p:.0}"),
            format!("{m:.0}"),
            format!("{:.3}", m / p),
        ]);
    }
    let pd = SystemProfile::piz_daint();
    for (gpus, p) in paper_daint {
        let m = pyfr::wallclock_secs(&PyfrRun::daint(gpus), &pd, &pd.host_mpi);
        worst = worst.max((m / p - 1.0).abs());
        t.row(&[
            "Piz Daint".into(),
            gpus.to_string(),
            format!("{p:.0}"),
            format!("{m:.0}"),
            format!("{:.3}", m / p),
        ]);
    }
    print!("{}", t.render());
    println!("max deviation from paper: {:.1}%", worst * 100.0);

    // shape assertions: near-linear scaling + P100 ~ 4x K40m
    let d1 = pyfr::wallclock_secs(&PyfrRun::daint(1), &pd, &pd.host_mpi);
    let d8 = pyfr::wallclock_secs(&PyfrRun::daint(8), &pd, &pd.host_mpi);
    assert!(d1 / (8.0 * d8) > 0.85, "daint 8-GPU efficiency");
    let c1 = pyfr::wallclock_secs(&PyfrRun::cluster(1), &cl, &cl.host_mpi);
    let ratio = c1 / d1;
    assert!((3.5..4.7).contains(&ratio), "P100/K40m ratio {ratio}");
    println!("P100 is {ratio:.2}x faster than K40m (paper: ~4x) ✓");

    if let Ok(ex) = Executor::new(shifter_rs::runtime::default_artifact_dir()) {
        let start = std::time::Instant::now();
        let rep = pyfr::run_real_partition(&ex, 25).unwrap();
        println!(
            "\nreal-substrate check: {} elements x {} iters, residual {:.3e} -> {:.3e} ({:.1}s)",
            rep.elements,
            rep.iters,
            rep.residuals[0],
            rep.residuals.last().unwrap(),
            start.elapsed().as_secs_f64()
        );
        assert!(rep.residuals.iter().all(|r| r.is_finite()));
    }
}
