//! gateway_scale — the distributed image-distribution benchmark
//! (DESIGN.md S18): a 10 000-concurrent-node pull storm against the
//! sharded gateway cluster, cold vs warm node caches, at 1/4/16 shards.
//!
//! Reported (and asserted, like the paper-table benches):
//!   * cold-storm makespan/throughput for a 32-image catalog at each shard
//!     count — 16 shards must beat 1 shard by >= 4x;
//!   * per-node pull latency percentiles (p50/p95/p99) for cold vs warm
//!     node caches — warm p99 must be >= 10x lower than cold;
//!   * content-addressed-store dedup: bytes stored < the sum of per-image
//!     bytes (the catalog shares one ubuntu base).

use shifter_rs::distrib::DistributionFabric;
use shifter_rs::gateway::ImageSource;
use shifter_rs::image::builder::{self, ImageBuilder};
use shifter_rs::metrics::{Stats, Table};
use shifter_rs::pfs::LustreFs;
use shifter_rs::registry::Registry;
use shifter_rs::util::prng::Rng;

/// srun job width of the storm (paper scale: "thousands of compute
/// nodes"). Overridable via `GATEWAY_SCALE_NODES` for the CI smoke run.
const DEFAULT_NODES: usize = 10_000;

fn storm_nodes() -> usize {
    std::env::var("GATEWAY_SCALE_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_NODES)
        .max(1)
}
/// Distinct images in the catalog storm.
const CATALOG: usize = 32;
/// Fixed app-layer size: identical job cost per image, so the shard
/// speedup measures scheduling, not image-size luck.
const APP_LAYER_BYTES: u64 = 80_000_000;
/// The flagship image all 10k nodes pull (1 GB model weights on top of
/// the shared base).
const FLAGSHIP_LAYER_BYTES: u64 = 1_000_000_000;

/// Registry with one shared base, 32 derived service images, and the
/// flagship — the shape of a production site's catalog.
fn storm_registry() -> (Registry, Vec<String>) {
    let base = builder::ubuntu_xenial();
    let mut registry = Registry::new();
    registry.push(base.clone());
    let mut refs = Vec::new();
    for i in 0..CATALOG {
        let name = format!("svc-{i:02}:1.0");
        registry.push(
            ImageBuilder::from_image(&base, &name)
                .file(&format!("/opt/svc-{i:02}/app.bin"), APP_LAYER_BYTES)
                .build(),
        );
        refs.push(name);
    }
    registry.push(
        ImageBuilder::from_image(&base, "mega-app:1.0")
            .file("/opt/mega/model.bin", FLAGSHIP_LAYER_BYTES)
            .build(),
    );
    (registry, refs)
}

fn main() {
    let pfs = LustreFs::piz_daint();
    let (registry, catalog_refs) = storm_registry();

    // -- phase 1: catalog cold storm at 1/4/16 shards ---------------------
    let mut table = Table::new(
        &format!("{CATALOG}-image cold storm (catalog sync)"),
        &["shards", "makespan", "imgs/min", "speedup"],
    );
    let mut makespans = Vec::new();
    let mut dedup_report = None;
    for &shards in &[1usize, 4, 16] {
        let mut fabric = DistributionFabric::new(shards, pfs.clone());
        for name in &catalog_refs {
            fabric.request(&registry, name, "storm").unwrap();
        }
        fabric.tick(&registry, 1e9);
        assert!(fabric.cluster().drained());
        let makespan = fabric.cluster().makespan_secs();
        table.row(&[
            shards.to_string(),
            format!("{makespan:.1}s"),
            format!("{:.1}", CATALOG as f64 / makespan * 60.0),
            format!("{:.1}x", makespans.first().unwrap_or(&makespan) / makespan),
        ]);
        makespans.push(makespan);
        if shards == 16 {
            let cas = fabric.cluster().cas();
            dedup_report = Some((
                cas.stored_bytes(),
                cas.logical_bytes(),
                cas.dedup_ratio(),
            ));
        }
    }
    print!("{}", table.render());

    let (serial, sharded) = (makespans[0], makespans[2]);
    assert!(
        serial >= 4.0 * sharded,
        "16-shard cold-storm throughput must be >= 4x the 1-shard \
         configuration: 1-shard={serial:.1}s 16-shard={sharded:.1}s"
    );

    let (stored, logical, ratio) = dedup_report.unwrap();
    println!(
        "layer dedup: {:.1} MB stored for {:.1} MB of per-image layers \
         ({ratio:.2}x)",
        stored as f64 / 1e6,
        logical as f64 / 1e6,
    );
    assert!(
        stored < logical,
        "CAS must store less than the sum of per-image bytes"
    );

    // -- phase 2: 10k nodes pull the flagship, cold then warm -------------
    let nodes = storm_nodes();
    let mut fabric = DistributionFabric::new(16, pfs.clone());
    for node in 0..nodes {
        fabric
            .request(&registry, "mega-app:1.0", &format!("node-{node:05}"))
            .unwrap();
    }
    fabric.tick(&registry, 1e9);
    let job = fabric.cluster().status("mega-app:1.0").unwrap();
    assert_eq!(job.requesters.len(), nodes, "storm coalesces into one job");
    let ready_secs =
        job.completed_at.expect("storm job completed").as_secs_f64();
    let image = fabric.resolve("mega-app:1.0").unwrap();

    let node_latencies = |mode: &str, queue_secs: f64| -> Stats {
        let samples: Vec<f64> = (0..nodes)
            .map(|node| {
                let fetch = fabric
                    .node_fetch_secs(image, node, nodes as u64)
                    .expect("fabric always models the node fetch");
                let noise = Rng::from_tags(&[
                    "gateway-scale",
                    mode,
                    &node.to_string(),
                ])
                .lognormal_noise(0.05);
                (queue_secs + fetch) * noise
            })
            .collect();
        Stats::from_samples(&samples)
    };

    // cold: every node waits for the shared job, then joins the broadcast
    let cold = node_latencies("cold", ready_secs);
    // warm: the image is READY and node-local — a lookup plus a stat
    let warm = node_latencies("warm", fabric.resolve_latency_secs());

    let mut lat = Table::new(
        &format!("per-node pull latency, {nodes} nodes (16 shards)"),
        &["cache", "p50", "p95", "p99", "mean"],
    );
    let fmt = |s: &Stats| -> Vec<String> {
        [s.p50, s.p95, s.p99, s.mean]
            .iter()
            .map(|v| {
                if *v < 1.0 {
                    format!("{:.1}ms", v * 1e3)
                } else {
                    format!("{v:.1}s")
                }
            })
            .collect()
    };
    let mut cold_row = vec!["cold".to_string()];
    cold_row.extend(fmt(&cold));
    lat.row(&cold_row);
    let mut warm_row = vec!["warm".to_string()];
    warm_row.extend(fmt(&warm));
    lat.row(&warm_row);
    print!("{}", lat.render());

    let stats = fabric.cache_stats();
    assert_eq!(stats.nodes, nodes);
    assert_eq!(stats.misses, nodes as u64); // one cold fill per node
    assert_eq!(stats.hits, nodes as u64); // one warm hit per node

    assert!(
        warm.p99 * 10.0 <= cold.p99,
        "warm-cache p99 must be >= 10x lower than cold: \
         warm={:.4}s cold={:.1}s",
        warm.p99,
        cold.p99
    );
    println!(
        "shape holds: 16-shard storm {:.1}x faster than 1 shard, warm p99 \
         {:.0}x below cold, dedup {ratio:.2}x ✓",
        serial / sharded,
        cold.p99 / warm.p99
    );
}
