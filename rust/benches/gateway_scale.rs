//! gateway_scale — the distributed image-distribution benchmark
//! (DESIGN.md S18, S25): a 10 000-concurrent-node pull storm against the
//! sharded gateway cluster, cold vs warm node caches, at 1/4/16 shards,
//! plus the distribution-fabric mechanisms layered on top.
//!
//! Reported (and asserted, like the paper-table benches):
//!   * cold-storm makespan/throughput for a 32-image catalog at each shard
//!     count — 16 shards must beat 1 shard by >= 4x;
//!   * per-node pull latency percentiles (p50/p95/p99) for cold vs warm
//!     node caches — warm p99 must be >= 10x lower than cold;
//!   * content-addressed-store dedup: bytes stored < the sum of per-image
//!     bytes (the catalog shares one ubuntu base);
//!   * cascade fills: cold pull-storm fill time growing sub-linearly in
//!     node count vs the linear Lustre broadcast baseline;
//!   * lazy pull: container start-ready p99 >= 5x below the eager fill;
//!   * chunked CAS: a derived image re-pull transfers only new chunks.
//!
//! The deterministic cascade/lazy/chunk metrics land in
//! `BENCH_distrib.json` (`BENCH_DISTRIB_JSON` overrides the path) for
//! the CI regression gate.

use shifter_rs::distrib::{CascadeConfig, DistributionFabric};
use shifter_rs::gateway::ImageSource;
use shifter_rs::image::builder::{self, ImageBuilder};
use shifter_rs::image::{ImageRef, Layer};
use shifter_rs::metrics::{Stats, Table};
use shifter_rs::pfs::LustreFs;
use shifter_rs::registry::Registry;
use shifter_rs::util::json::Json;
use shifter_rs::util::prng::Rng;

/// srun job width of the storm (paper scale: "thousands of compute
/// nodes"). Overridable via `GATEWAY_SCALE_NODES` for the CI smoke run.
const DEFAULT_NODES: usize = 10_000;

fn storm_nodes() -> usize {
    std::env::var("GATEWAY_SCALE_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(DEFAULT_NODES)
        .max(1)
}
/// Distinct images in the catalog storm.
const CATALOG: usize = 32;
/// Fixed app-layer size: identical job cost per image, so the shard
/// speedup measures scheduling, not image-size luck.
const APP_LAYER_BYTES: u64 = 80_000_000;
/// The flagship image all 10k nodes pull (1 GB model weights on top of
/// the shared base).
const FLAGSHIP_LAYER_BYTES: u64 = 1_000_000_000;

/// Registry with one shared base, 32 derived service images, and the
/// flagship — the shape of a production site's catalog.
fn storm_registry() -> (Registry, Vec<String>) {
    let base = builder::ubuntu_xenial();
    let mut registry = Registry::new();
    registry.push(base.clone());
    let mut refs = Vec::new();
    for i in 0..CATALOG {
        let name = format!("svc-{i:02}:1.0");
        registry.push(
            ImageBuilder::from_image(&base, &name)
                .file(&format!("/opt/svc-{i:02}/app.bin"), APP_LAYER_BYTES)
                .build(),
        );
        refs.push(name);
    }
    registry.push(
        ImageBuilder::from_image(&base, "mega-app:1.0")
            .file("/opt/mega/model.bin", FLAGSHIP_LAYER_BYTES)
            .build(),
    );
    (registry, refs)
}

fn main() {
    let pfs = LustreFs::piz_daint();
    let (registry, catalog_refs) = storm_registry();

    // -- phase 1: catalog cold storm at 1/4/16 shards ---------------------
    let mut table = Table::new(
        &format!("{CATALOG}-image cold storm (catalog sync)"),
        &["shards", "makespan", "imgs/min", "speedup"],
    );
    let mut makespans = Vec::new();
    let mut dedup_report = None;
    for &shards in &[1usize, 4, 16] {
        let mut fabric = DistributionFabric::new(shards, pfs.clone());
        for name in &catalog_refs {
            fabric.request(&registry, name, "storm").unwrap();
        }
        fabric.tick(&registry, 1e9);
        assert!(fabric.cluster().drained());
        let makespan = fabric.cluster().makespan_secs();
        table.row(&[
            shards.to_string(),
            format!("{makespan:.1}s"),
            format!("{:.1}", CATALOG as f64 / makespan * 60.0),
            format!("{:.1}x", makespans.first().unwrap_or(&makespan) / makespan),
        ]);
        makespans.push(makespan);
        if shards == 16 {
            let cas = fabric.cluster().cas();
            dedup_report = Some((
                cas.stored_bytes(),
                cas.logical_bytes(),
                cas.dedup_ratio(),
            ));
        }
    }
    print!("{}", table.render());

    let (serial, sharded) = (makespans[0], makespans[2]);
    assert!(
        serial >= 4.0 * sharded,
        "16-shard cold-storm throughput must be >= 4x the 1-shard \
         configuration: 1-shard={serial:.1}s 16-shard={sharded:.1}s"
    );

    let (stored, logical, ratio) = dedup_report.unwrap();
    println!(
        "layer dedup: {:.1} MB stored for {:.1} MB of per-image layers \
         ({ratio:.2}x)",
        stored as f64 / 1e6,
        logical as f64 / 1e6,
    );
    assert!(
        stored < logical,
        "CAS must store less than the sum of per-image bytes"
    );

    // -- phase 2: 10k nodes pull the flagship, cold then warm -------------
    let nodes = storm_nodes();
    let mut fabric = DistributionFabric::new(16, pfs.clone());
    for node in 0..nodes {
        fabric
            .request(&registry, "mega-app:1.0", &format!("node-{node:05}"))
            .unwrap();
    }
    fabric.tick(&registry, 1e9);
    let job = fabric.cluster().status("mega-app:1.0").unwrap();
    assert_eq!(job.requesters.len(), nodes, "storm coalesces into one job");
    let ready_secs =
        job.completed_at.expect("storm job completed").as_secs_f64();
    let image = fabric.resolve("mega-app:1.0").unwrap();

    let node_latencies = |mode: &str, queue_secs: f64| -> Stats {
        let samples: Vec<f64> = (0..nodes)
            .map(|node| {
                let fetch = fabric
                    .node_fetch_secs(image, node, nodes as u64)
                    .expect("fabric always models the node fetch");
                let noise = Rng::from_tags(&[
                    "gateway-scale",
                    mode,
                    &node.to_string(),
                ])
                .lognormal_noise(0.05);
                (queue_secs + fetch) * noise
            })
            .collect();
        Stats::from_samples(&samples)
    };

    // cold: every node waits for the shared job, then joins the broadcast
    let cold = node_latencies("cold", ready_secs);
    // warm: the image is READY and node-local — a lookup plus a stat
    let warm = node_latencies("warm", fabric.resolve_latency_secs());

    let mut lat = Table::new(
        &format!("per-node pull latency, {nodes} nodes (16 shards)"),
        &["cache", "p50", "p95", "p99", "mean"],
    );
    let fmt = |s: &Stats| -> Vec<String> {
        [s.p50, s.p95, s.p99, s.mean]
            .iter()
            .map(|v| {
                if *v < 1.0 {
                    format!("{:.1}ms", v * 1e3)
                } else {
                    format!("{v:.1}s")
                }
            })
            .collect()
    };
    let mut cold_row = vec!["cold".to_string()];
    cold_row.extend(fmt(&cold));
    lat.row(&cold_row);
    let mut warm_row = vec!["warm".to_string()];
    warm_row.extend(fmt(&warm));
    lat.row(&warm_row);
    print!("{}", lat.render());

    let stats = fabric.cache_stats();
    assert_eq!(stats.nodes, nodes);
    assert_eq!(stats.misses, nodes as u64); // one cold fill per node
    assert_eq!(stats.hits, nodes as u64); // one warm hit per node

    assert!(
        warm.p99 * 10.0 <= cold.p99,
        "warm-cache p99 must be >= 10x lower than cold: \
         warm={:.4}s cold={:.1}s",
        warm.p99,
        cold.p99
    );
    println!(
        "shape holds: 16-shard storm {:.1}x faster than 1 shard, warm p99 \
         {:.0}x below cold, dedup {ratio:.2}x ✓",
        serial / sharded,
        cold.p99 / warm.p99
    );

    // -- phase 3: cascade fills vs the Lustre broadcast -------------------
    let cascade_cfg = CascadeConfig {
        cabinet_nodes: 64,
        fanout: 3,
    };
    let widths = fill_widths(nodes);
    let mut fill_table = Table::new(
        "cold pull-storm fill: broadcast vs cascade",
        &["nodes", "broadcast", "cascade", "gw fills", "peer xfers", "depth"],
    );
    let mut fill_rows: Vec<Json> = Vec::new();
    let mut cascade_makespans: Vec<f64> = Vec::new();
    let mut broadcast_makespans: Vec<f64> = Vec::new();
    let mut eager_fills: Vec<f64> = Vec::new();
    for &w in &widths {
        let broadcast = storm_fill(&pfs, &registry, None, w);
        let cascade = storm_fill(&pfs, &registry, Some(cascade_cfg), w);
        assert_eq!(
            cascade.stats.gateway_fills, 1,
            "one gateway read per all-live cascade storm"
        );
        assert_eq!(cascade.stats.peer_transfers as usize, w - 1);
        fill_table.row(&[
            w.to_string(),
            format!("{:.1}s", broadcast.makespan_secs),
            format!("{:.2}s", cascade.makespan_secs),
            cascade.stats.gateway_fills.to_string(),
            cascade.stats.peer_transfers.to_string(),
            cascade.stats.max_depth.to_string(),
        ]);
        fill_rows.push(Json::obj(vec![
            ("nodes", Json::Num(w as f64)),
            (
                "broadcast_makespan_secs",
                Json::num(broadcast.makespan_secs),
            ),
            ("cascade_makespan_secs", Json::num(cascade.makespan_secs)),
            ("gateway_fills", Json::Num(cascade.stats.gateway_fills as f64)),
            (
                "peer_transfers",
                Json::Num(cascade.stats.peer_transfers as f64),
            ),
            ("max_depth", Json::Num(cascade.stats.max_depth as f64)),
        ]));
        cascade_makespans.push(cascade.makespan_secs);
        broadcast_makespans.push(broadcast.makespan_secs);
        if w == nodes {
            eager_fills = cascade.fills;
        }
    }
    print!("{}", fill_table.render());

    if widths.len() >= 2 {
        let span = *widths.last().unwrap() as f64 / widths[0] as f64;
        let (first, last) =
            (cascade_makespans[0], *cascade_makespans.last().unwrap());
        assert!(
            last <= 4.0 * first,
            "cascade fill must grow sub-linearly: {span:.0}x the nodes \
             cost {first:.2}s -> {last:.2}s (> 4x)"
        );
    }
    let (b_max, c_max) = (
        *broadcast_makespans.last().unwrap(),
        *cascade_makespans.last().unwrap(),
    );
    // decisive-win regime: the broadcast shares the OST array's 80 GB/s
    // aggregate, so it only falls >= 4x behind the tree once the storm
    // outruns it (~2000 nodes). At the reduced CI cap (500) the tree
    // merely beats it; below that the regimes cross and no win holds
    if nodes >= 2000 {
        assert!(
            c_max * 4.0 <= b_max,
            "cascade must beat the broadcast by >= 4x at {nodes} nodes: \
             cascade={c_max:.2}s broadcast={b_max:.1}s"
        );
    } else if nodes >= 500 {
        assert!(
            c_max < b_max,
            "cascade must beat the broadcast at {nodes} nodes: \
             cascade={c_max:.2}s broadcast={b_max:.1}s"
        );
    }
    println!(
        "cascade beats the {nodes}-node broadcast {:.1}x ✓",
        b_max / c_max
    );

    // -- phase 4: lazy pull + chunked CAS ---------------------------------
    let eager = Stats::from_samples(&eager_fills);
    let (lazy_doc, chunks_doc) =
        lazy_chunk_phase(&pfs, cascade_cfg, nodes, &eager);

    write_artifact(nodes, cascade_cfg, fill_rows, lazy_doc, chunks_doc);
}

/// Storm widths for the fill-scaling sweep: ~1/16 and ~1/4 of the cap
/// (floored at 32 nodes), then the cap itself.
fn fill_widths(nodes: usize) -> Vec<usize> {
    let step = |div: usize| nodes.div_ceil(div).clamp(32.min(nodes), nodes);
    let mut widths = vec![step(16), step(4), nodes];
    widths.dedup();
    widths
}

/// One cold fill storm: `width` nodes materialize the flagship squashfs
/// simultaneously, with or without cascade fills.
struct StormFill {
    /// Slowest node's fill — the storm's fill makespan.
    makespan_secs: f64,
    /// Per-node fill durations, node order.
    fills: Vec<f64>,
    /// Cascade accounting (zeroes for the broadcast baseline).
    stats: shifter_rs::distrib::CascadeStats,
}

fn storm_fill(
    pfs: &LustreFs,
    registry: &Registry,
    cascade: Option<CascadeConfig>,
    width: usize,
) -> StormFill {
    let mut fabric = DistributionFabric::new(16, pfs.clone());
    if let Some(cfg) = cascade {
        fabric = fabric.with_cascade(cfg);
    }
    fabric
        .pull_blocking(registry, "mega-app:1.0", "storm")
        .unwrap();
    let image = fabric.resolve("mega-app:1.0").unwrap();
    let fills: Vec<f64> = (0..width)
        .map(|node| {
            fabric
                .node_fetch_secs(image, node, width as u64)
                .expect("fabric always models the node fetch")
        })
        .collect();
    StormFill {
        makespan_secs: fills.iter().copied().fold(0.0, f64::max),
        fills,
        stats: fabric.cascade_stats(),
    }
}

/// Phase 4: one fabric with all three S25 mechanisms on. Measures the
/// lazy start-ready split against the eager cascade fill, then re-pulls
/// a one-file-changed derivative of the flagship to show chunk-level
/// dedup collapsing the transfer. Returns the artifact's "lazy" and
/// "chunks" documents.
fn lazy_chunk_phase(
    pfs: &LustreFs,
    cfg: CascadeConfig,
    nodes: usize,
    eager: &Stats,
) -> (Json, Json) {
    let (mut registry, _) = storm_registry();
    // mega-app:2.0 = 1.0 plus one 4 KB config file in the model layer:
    // a different layer digest, but almost every chunk is unchanged
    let mut v2 = registry.lookup("mega-app:1.0").unwrap().clone();
    let mut tree = v2.layers.last().unwrap().tree.clone();
    tree.add_file("/opt/mega/patch.cfg", 4_096, 0xFEED_FACE)
        .unwrap();
    *v2.layers.last_mut().unwrap() = Layer::new(tree, vec![]);
    v2.reference = ImageRef::parse("mega-app:2.0").unwrap();
    v2.manifest.layer_digests =
        v2.layers.iter().map(|l| l.digest).collect();
    registry.push(v2);

    let mut fabric = DistributionFabric::new(16, pfs.clone())
        .with_chunking(4 << 20)
        .with_cascade(cfg)
        .with_lazy_pull(true);
    fabric
        .pull_blocking(&registry, "mega-app:1.0", "storm")
        .unwrap();
    let t1 = turnaround_secs(&fabric, "mega-app:1.0");

    let (start, tail) = {
        let image = fabric.resolve("mega-app:1.0").unwrap();
        let splits: Vec<(f64, f64)> = (0..nodes)
            .map(|node| {
                fabric
                    .node_fetch_split(image, node, nodes as u64)
                    .expect("fabric always models the node fetch")
            })
            .collect();
        let starts: Vec<f64> = splits.iter().map(|s| s.0).collect();
        let tails: Vec<f64> = splits.iter().map(|s| s.1).collect();
        (Stats::from_samples(&starts), Stats::from_samples(&tails))
    };
    let deferred = fabric.cache_stats().lazy_deferred_bytes;
    assert!(deferred > 0, "lazy pull must defer bytes past start");
    assert!(
        start.p99 * 5.0 <= eager.p99,
        "lazy start-ready p99 must be >= 5x below the eager fill: \
         lazy={:.3}s eager={:.2}s",
        start.p99,
        eager.p99
    );
    println!(
        "lazy pull: start-ready p99 {:.3}s vs eager {:.2}s \
         ({:.1} MB/node deferred to execution) ✓",
        start.p99,
        eager.p99,
        deferred as f64 / nodes as f64 / 1e6
    );

    // the derivative re-pull: only new chunks cross the wire
    fabric
        .pull_blocking(&registry, "mega-app:2.0", "storm")
        .unwrap();
    let t2 = turnaround_secs(&fabric, "mega-app:2.0");
    let cas = fabric.cluster().cas();
    assert!(
        t2 < 0.8 * t1,
        "chunk dedup must collapse the derivative pull: \
         v1={t1:.1}s v2={t2:.1}s"
    );
    assert!(cas.chunks_shared() > 0, "derivative must share chunks");
    assert!(cas.stored_bytes() < cas.logical_bytes());
    println!(
        "chunked CAS: derivative pull {t2:.1}s vs {t1:.1}s cold \
         ({} chunks shared, hit ratio {:.2}) ✓",
        cas.chunks_shared(),
        cas.chunk_hit_ratio()
    );

    (
        Json::obj(vec![
            ("eager_p99_secs", Json::num(eager.p99)),
            ("start_ready_p99_secs", Json::num(start.p99)),
            ("tail_p99_secs", Json::num(tail.p99)),
            ("deferred_bytes", Json::Num(deferred as f64)),
        ]),
        Json::obj(vec![
            ("v1_turnaround_secs", Json::num(t1)),
            ("v2_turnaround_secs", Json::num(t2)),
            ("chunks_new", Json::Num(cas.chunks_new() as f64)),
            ("chunks_shared", Json::Num(cas.chunks_shared() as f64)),
            ("chunk_hit_ratio", Json::num(cas.chunk_hit_ratio())),
            ("stored_bytes", Json::Num(cas.stored_bytes() as f64)),
            ("logical_bytes", Json::Num(cas.logical_bytes() as f64)),
            ("dedup_ratio", Json::num(cas.dedup_ratio())),
        ]),
    )
}

/// Enqueue-to-READY turnaround of a completed pull job.
fn turnaround_secs(fabric: &DistributionFabric, reference: &str) -> f64 {
    let job = fabric
        .cluster()
        .status(reference)
        .expect("job exists after pull_blocking");
    job.completed_at.expect("job is terminal") - job.enqueued_at
}

/// Write the deterministic distribution metrics CI gates on.
fn write_artifact(
    nodes: usize,
    cfg: CascadeConfig,
    fill: Vec<Json>,
    lazy: Json,
    chunks: Json,
) {
    let doc = Json::obj(vec![
        ("bench", Json::str("distrib_cascade")),
        ("max_nodes", Json::Num(nodes as f64)),
        ("cabinet_nodes", Json::Num(cfg.cabinet_nodes as f64)),
        ("fanout", Json::Num(cfg.fanout as f64)),
        ("fill", Json::Arr(fill)),
        ("lazy", lazy),
        ("chunks", chunks),
    ]);
    let path = std::env::var("BENCH_DISTRIB_JSON")
        .unwrap_or_else(|_| "BENCH_distrib.json".to_string());
    std::fs::write(&path, doc.to_string())
        .expect("write BENCH_distrib.json");
    println!("wrote {path}");
}
