//! Table IV — OSU latency on Piz Daint: native Cray MPT 7.5.0 over Aries
//! vs containers A/B/C with Shifter MPI support enabled and disabled.
//! Paper: enabled 0.98–1.06, disabled 1.4–6.2x.

mod osu_common;

use shifter_rs::SystemProfile;

fn main() {
    let pd = SystemProfile::piz_daint();
    let result = osu_common::run_system(&pd);
    print!(
        "{}",
        osu_common::render(
            "Table IV: OSU_latency on Piz Daint (ratios vs native)",
            &result
        )
    );
    osu_common::assert_shape(&result, (1.2, 7.0));
    println!("shape holds: enabled ≈ 1.0x, disabled 1.4–6.2x (paper Table IV) ✓");

    let paper_native = [1.1, 1.1, 1.1, 1.6, 4.1, 6.5, 16.4, 56.1, 215.7];
    let max_dev = result
        .native
        .iter()
        .zip(paper_native)
        .map(|(r, p)| (r.best_us / p - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!("native column max deviation from paper: {:.1}%", max_dev * 100.0);
}
