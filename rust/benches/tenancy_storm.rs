//! tenancy_storm — the multi-tenant job-storm benchmark (DESIGN.md S20):
//! synthesize a Poisson stream of competing GPU/MPI/CPU jobs from many
//! tenants, run it twice over a 1024-node heterogeneous cluster — once
//! under strict FIFO, once under fair-share + conservative backfill —
//! and compare. The cluster is one `SiteBuilder` declaration (DESIGN.md
//! S21); each policy runs via `Site::run_storm` (one `StormSpec`
//! replaying the same explicit stream) on a fresh site.
//!
//! Asserted (the ISSUE 3 acceptance criteria):
//!   * every job completes and **no tenant starves**: the worst stretch
//!     any tenant sees stays under a fixed bound;
//!   * **backfill beats FIFO on the same stream**: jobs ride backfill
//!     holes and aggregate queue wait drops at any contended scale; at
//!     the full acceptance scale (64 jobs / 1024 nodes) utilization
//!     rises and the makespan shrinks outright;
//!   * the gateway performs **exactly one pull job per unique image
//!     reference** across all concurrent jobs — cross-job coalescing
//!     holds under multi-tenant pressure.
//!
//! Both reports land in `BENCH_tenancy.json` so CI tracks the scheduling
//! trajectory per PR. Knobs: `TENANCY_STORM_JOBS` caps the stream length,
//! `TENANCY_STORM_NODES` the cluster width (CI runs reduced values).

use shifter_rs::tenancy::{
    unique_image_refs, FairShare, Fifo, SchedulingPolicy, TenancyReport,
    TenantJob, TrafficModel,
};
use shifter_rs::util::json::Json;
use shifter_rs::{Site, StormSpec};

const SHARDS: usize = 8;
const TENANTS: u32 = 8;
const FULL_JOBS: u32 = 64;
const FULL_NODES: u32 = 1024;
/// Starvation bound: no tenant's worst slowdown may exceed this.
const STRETCH_BOUND: f64 = 100.0;

fn env_u32(name: &str, full: u32) -> u32 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(full)
        .max(1)
}

fn make_site(nodes: u32) -> Site {
    Site::builder()
        .hetero_daint_linux(nodes)
        .gateway_shards(SHARDS)
        // strict retry: exact pull/coalescing accounting, no
        // straggler noise in the policy comparison
        .retry_policy(shifter_rs::launch::RetryPolicy::strict())
        // the artifact embeds the fair-share run's counter snapshot
        .telemetry(true)
        .build()
        .expect("valid bench site")
}

/// Replay `stream` under `policy` on a fresh site (same declaration, so
/// the fabrics start cold).
fn run_policy(
    nodes: u32,
    stream: &[TenantJob],
    policy: impl SchedulingPolicy + 'static,
) -> (TenancyReport, Json) {
    let mut site = make_site(nodes);
    let report = site
        .run_storm(
            &StormSpec::new().job_stream(stream.to_vec()).policy(policy),
        )
        .expect("storm runs");
    (report, site.telemetry().snapshot_json())
}

fn main() {
    let nodes = env_u32("TENANCY_STORM_NODES", FULL_NODES).max(2);
    let jobs = env_u32("TENANCY_STORM_JOBS", FULL_JOBS);

    // one stream, scheduled twice — the comparison below is only valid
    // because both policies see the identical jobs.
    let stream = {
        let site = make_site(nodes);
        TrafficModel {
            tenants: TENANTS,
            jobs,
            max_width: nodes / 2,
            ..TrafficModel::default()
        }
        .generate(site.cluster())
    };
    assert_eq!(stream.len() as u32, jobs, "uncapped stream generates all");
    let unique = unique_image_refs(&stream);
    assert!(
        stream.len() > unique.len(),
        "the stream must reuse images across jobs ({} jobs over {} \
         images), or the coalescing check below tests nothing",
        stream.len(),
        unique.len()
    );

    let (fifo, _) = run_policy(nodes, &stream, Fifo);
    let (fair, fair_telemetry) =
        run_policy(nodes, &stream, FairShare::default());

    for (name, report) in [("fifo", &fifo), ("fair-share", &fair)] {
        print!("{}", report.render());
        assert_eq!(
            report.completed() as u32,
            jobs,
            "{name}: every job in the stream must complete"
        );
        // cross-job coalescing: many jobs share few images (asserted
        // above), yet the gateway performed exactly one pull job per
        // unique reference
        assert_eq!(
            report.coalescing.jobs,
            unique.len(),
            "{name}: the gateway must perform exactly one pull job per \
             unique image reference across all concurrent jobs"
        );
        assert_eq!(report.unique_images, unique.len());
    }

    // bounded starvation under fair-share + aging
    let starved = fair.starved_tenants(STRETCH_BOUND);
    assert!(
        starved.is_empty(),
        "no tenant may starve (stretch > {STRETCH_BOUND}): {starved:?} \
         (max stretch {:.1})",
        fair.max_stretch()
    );

    // backfill vs FIFO on the same stream. Aggregate queue wait drops at
    // every contended scale; the utilization/makespan wins are asserted
    // at the acceptance scale (a reduced smoke run can land on a stream
    // whose critical path is identical under both policies).
    if jobs >= 16 {
        assert!(
            fair.backfilled_jobs > 0,
            "the contended stream must exercise backfill"
        );
        let total_wait = |r: &TenancyReport| -> f64 {
            r.records
                .iter()
                .filter(|x| x.ok())
                .map(|x| x.wait_secs)
                .sum()
        };
        assert!(
            total_wait(&fair) < total_wait(&fifo),
            "backfill must cut aggregate queue wait: fair {:.0}s vs \
             fifo {:.0}s",
            total_wait(&fair),
            total_wait(&fifo)
        );
    }
    if jobs >= FULL_JOBS && nodes >= FULL_NODES {
        assert!(
            fair.utilization() > fifo.utilization(),
            "backfill must lift utilization: fair {:.4} vs fifo {:.4}",
            fair.utilization(),
            fifo.utilization()
        );
        assert!(
            fair.makespan_secs < fifo.makespan_secs,
            "backfill must shorten the makespan: fair {:.0}s vs fifo {:.0}s",
            fair.makespan_secs,
            fifo.makespan_secs
        );
    }

    println!(
        "storm: {} jobs / {} tenants / {} nodes — utilization fifo \
         {:.1}% vs fair-share {:.1}%, makespan {:.0}s vs {:.0}s, {} \
         backfilled, max stretch {:.1}",
        jobs,
        TENANTS,
        nodes,
        fifo.utilization() * 100.0,
        fair.utilization() * 100.0,
        fifo.makespan_secs,
        fair.makespan_secs,
        fair.backfilled_jobs,
        fair.max_stretch(),
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("tenancy_storm")),
        ("nodes", Json::Num(f64::from(nodes))),
        ("jobs", Json::Num(f64::from(jobs))),
        ("tenants", Json::Num(f64::from(TENANTS))),
        ("shards", Json::Num(SHARDS as f64)),
        ("fifo", fifo.to_json()),
        ("fair_share", fair.to_json()),
        ("telemetry", fair_telemetry),
    ]);
    let path = std::env::var("BENCH_TENANCY_JSON")
        .unwrap_or_else(|_| "BENCH_tenancy.json".to_string());
    std::fs::write(&path, doc.to_string()).expect("write BENCH_tenancy.json");
    println!("wrote {path}");
}
