//! Extension-overhead bench (DESIGN.md S22): what each host extension
//! costs at container start, and what the specialized-network extension
//! buys at the wire.
//!
//! Part 1 — per-extension inject cost: run the same image at widths
//! 1/64/1024 concurrent nodes with exactly one extension triggered at a
//! time, and charge each extension the start-up delta over a bare run at
//! the same width (the fetch/mount baseline cancels out — the delta is
//! purely the extension's bind mounts).
//!
//! Part 2 — host-fabric vs TCP-fallback ablation: the same OSU message
//! sizes Tables III/IV report, priced on the Aries link model through
//! `Container::effective_transport()` — `SHIFTER_NET=host` puts the
//! container on the native path, `SHIFTER_NET_FALLBACK=1` forces TCP.
//!
//! Writes `BENCH_extensions.json` (CI bench-smoke artifact). Knobs:
//! `EXTENSION_OVERHEAD_NODES` caps the width sweep,
//! `BENCH_EXTENSIONS_JSON` overrides the artifact path.

use std::sync::Arc;

use shifter_rs::fabric::{link_for, Transport, OSU_SIZES};
use shifter_rs::shifter::RunOptions;
use shifter_rs::util::json::Json;
use shifter_rs::{
    ImageGateway, Registry, ShifterRuntime, SystemProfile, Telemetry,
};

const IMAGE: &str = "osu-benchmarks:mpich-3.1.4";
const WIDTHS: [u32; 3] = [1, 64, 1024];

fn main() {
    let cap: u32 = std::env::var("EXTENSION_OVERHEAD_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1024);
    let widths: Vec<u32> =
        WIDTHS.iter().copied().filter(|w| *w <= cap.max(1)).collect();

    let profile = SystemProfile::piz_daint();
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(profile.pfs.clone().unwrap());
    gateway.pull(&registry, IMAGE).unwrap();
    // recording on: the artifact embeds the run/extension counters
    let recorder = Arc::new(Telemetry::new(true));
    let runtime = ShifterRuntime::new(&profile)
        .with_telemetry(Arc::clone(&recorder));

    // -- part 1: per-extension inject cost over the bare baseline --------
    println!("per-extension inject cost on {} ({IMAGE})", profile.name);
    let mut inject_rows: Vec<Json> = Vec::new();
    for &width in &widths {
        let base_opts =
            RunOptions::new(IMAGE, &["osu_latency"]).on_nodes(0, width);
        let base = runtime.run(&gateway, &base_opts).unwrap();
        assert!(base.extensions.is_empty());
        let base_secs = base.startup_overhead_secs();

        let variants: [(&str, RunOptions); 3] = [
            (
                "gpu",
                base_opts.clone().with_env("CUDA_VISIBLE_DEVICES", "0"),
            ),
            ("mpi", base_opts.clone().with_mpi()),
            ("net", base_opts.clone().with_env("SHIFTER_NET", "host")),
        ];
        for (name, opts) in variants {
            let c = runtime.run(&gateway, &opts).unwrap();
            assert_eq!(c.extensions.len(), 1, "{name} must trigger alone");
            assert_eq!(c.extensions[0].extension, name);
            let delta = c.startup_overhead_secs() - base_secs;
            assert!(
                delta > 0.0,
                "{name} inject must cost time at width {width}"
            );
            println!(
                "  {name:<4} @ {width:>4} node(s): +{:.1} µs \
                 ({} mounts)",
                delta * 1e6,
                c.extensions[0].mounts_added,
            );
            inject_rows.push(Json::obj(vec![
                ("extension", Json::str(name)),
                ("nodes", Json::Num(width as f64)),
                ("inject_secs", Json::Num(delta)),
                (
                    "mounts",
                    Json::Num(c.extensions[0].mounts_added as f64),
                ),
            ]));
        }
    }

    // -- part 2: host-fabric vs TCP-fallback OSU latency split -----------
    let host_opts = RunOptions::new(IMAGE, &["osu_latency"])
        .with_env("SHIFTER_NET", "host");
    let host_run = runtime.run(&gateway, &host_opts).unwrap();
    assert_eq!(host_run.effective_transport(), Transport::Native);

    let fallback_opts = RunOptions::new(IMAGE, &["osu_latency"])
        .with_env("SHIFTER_NET", "host")
        .with_env("SHIFTER_NET_FALLBACK", "1");
    let fallback_run = runtime.run(&gateway, &fallback_opts).unwrap();
    assert_eq!(fallback_run.effective_transport(), Transport::TcpFallback);

    let native_link =
        link_for(profile.fabric, host_run.effective_transport());
    let tcp_link =
        link_for(profile.fabric, fallback_run.effective_transport());
    println!(
        "osu_latency ablation on {} ({}): host-fabric vs TCP fallback",
        profile.name,
        profile.fabric.name()
    );
    let mut osu_rows: Vec<Json> = Vec::new();
    for size in OSU_SIZES {
        let native_us = native_link.latency_us(size);
        let tcp_us = tcp_link.latency_us(size);
        let ratio = tcp_us / native_us;
        // the Daint band of Table IV: the fallback must be measurably
        // slower at every size
        assert!(
            ratio > 1.2,
            "fallback must be slower at size {size}: {ratio}"
        );
        println!(
            "  {size:>8} B: native {native_us:>8.2} µs, \
             tcp {tcp_us:>8.2} µs ({ratio:.2}x)"
        );
        osu_rows.push(Json::obj(vec![
            ("size_bytes", Json::Num(size as f64)),
            ("host_fabric_us", Json::Num(native_us)),
            ("tcp_fallback_us", Json::Num(tcp_us)),
            ("ratio", Json::Num(ratio)),
        ]));
    }

    // -- artifact ---------------------------------------------------------
    let doc = Json::obj(vec![
        ("bench", Json::str("extension_overhead")),
        ("image", Json::str(IMAGE)),
        ("system", Json::str(profile.name)),
        ("max_nodes", Json::Num(cap as f64)),
        ("inject_cost", Json::Arr(inject_rows)),
        ("osu_net_split", Json::Arr(osu_rows)),
        ("telemetry", recorder.snapshot_json()),
    ]);
    let path = std::env::var("BENCH_EXTENSIONS_JSON")
        .unwrap_or_else(|_| "BENCH_extensions.json".to_string());
    std::fs::write(&path, doc.to_string())
        .expect("write BENCH_extensions.json");
    println!("wrote {path}");
}
