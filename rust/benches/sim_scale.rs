//! sim_scale — the virtual-time kernel scale proof (DESIGN.md S24):
//! schedule a week-long, million-job storm over a 100k-node cluster
//! entirely in virtual time and demand it completes in *seconds* of
//! wall time. This is the acceptance bench of the discrete-event
//! kernel: the old wall-clock worker pool could never replay a week of
//! cluster time faster than real time, the event queue replays it at
//! whatever rate the host can pop events.
//!
//! Asserted:
//!   * every synthesized job completes — the kernel drains the full
//!     arrival/completion event stream with nothing stranded;
//!   * the virtual horizon really is week-scale while wall time stays
//!     under `SIM_SCALE_BUDGET_SECS` (default 60 s);
//!   * the virtual-over-wall speedup is large (> 1000x) — the bench is
//!     meaningless if the simulation merely keeps pace with reality.
//!
//! Artifacts land in `BENCH_simkernel.json`: wait/turnaround latency
//! percentiles plus binned utilization and throughput curves over the
//! week, computed directly from the job records (a million-record JSON
//! tree would dwarf the numbers we care about). Knobs:
//! `SIM_SCALE_NODES`, `SIM_SCALE_JOBS`, `SIM_SCALE_BUDGET_SECS` (CI
//! runs a reduced job count under the same node scale).

use std::time::Instant;

use shifter_rs::tenancy::TrafficModel;
use shifter_rs::util::json::Json;
use shifter_rs::{Site, StormSpec};

const SHARDS: usize = 8;
const TENANTS: u32 = 32;
const FULL_NODES: u32 = 100_000;
const FULL_JOBS: u32 = 1_000_000;
/// The nominal virtual horizon: one week of cluster time.
const WEEK_SECS: f64 = 604_800.0;
/// Widest synthesized job. Small widths keep the storm arrival-bound
/// (~4k busy nodes of 100k), which is exactly the regime that stresses
/// the event queue rather than the packing heuristics.
const MAX_WIDTH: u32 = 4;
/// Utilization/throughput curve resolution.
const BINS: usize = 56;

fn env_u32(name: &str, full: u32) -> u32 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(full)
        .max(1)
}

fn env_f64(name: &str, full: f64) -> f64 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(full)
}

/// Percentile of a pre-sorted sample (nearest-rank).
fn pctl(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let rank = ((sorted.len() as f64) * q).ceil() as usize;
    sorted[rank.clamp(1, sorted.len()) - 1]
}

fn pctl_json(sorted: &[f64]) -> Json {
    Json::obj(vec![
        ("p50_secs", Json::Num(pctl(sorted, 0.50))),
        ("p90_secs", Json::Num(pctl(sorted, 0.90))),
        ("p99_secs", Json::Num(pctl(sorted, 0.99))),
        ("worst_secs", Json::Num(sorted.last().copied().unwrap_or(0.0))),
    ])
}

fn main() {
    let nodes = env_u32("SIM_SCALE_NODES", FULL_NODES).max(MAX_WIDTH);
    let jobs = env_u32("SIM_SCALE_JOBS", FULL_JOBS);
    let budget = env_f64("SIM_SCALE_BUDGET_SECS", 60.0);
    // spread the whole stream over the week: ~99.2 jobs/min at full scale
    let rate_per_min = f64::from(jobs) / (WEEK_SECS / 60.0);

    let mut site = Site::builder()
        .nodes(nodes)
        .gateway_shards(SHARDS)
        // strict retry: the bench compares against a fixed budget, so
        // per-slot timings must be deterministic
        .retry_policy(shifter_rs::launch::RetryPolicy::strict())
        .build()
        .expect("valid bench site");

    let spec = StormSpec::new().traffic(TrafficModel {
        tenants: TENANTS,
        jobs,
        arrival_rate_per_min: rate_per_min,
        max_width: MAX_WIDTH,
        ..TrafficModel::default()
    });

    let wall_start = Instant::now();
    let report = site.run_storm(&spec).expect("storm runs");
    let wall_secs = wall_start.elapsed().as_secs_f64();

    assert_eq!(
        report.completed() as u32,
        jobs,
        "the kernel must drain every job's arrival and completion"
    );
    let virtual_secs = report.makespan_secs;
    // the arrival rate spreads any job count over the week, so the
    // horizon is week-scale at every knob setting
    assert!(
        virtual_secs > WEEK_SECS * 0.5,
        "the virtual horizon must be commensurate with the configured \
         week ({virtual_secs:.0}s simulated)"
    );
    assert!(
        wall_secs < budget,
        "virtual-time replay must fit the wall budget: {wall_secs:.1}s \
         wall vs {budget:.0}s allowed ({jobs} jobs / {nodes} nodes)"
    );
    let speedup = virtual_secs / wall_secs.max(1e-9);
    assert!(
        speedup > 1000.0,
        "simulating slower than 1000x real time defeats the kernel: \
         {speedup:.0}x"
    );

    // latency curves, straight from the records
    let mut waits: Vec<f64> = Vec::with_capacity(report.records.len());
    let mut turnarounds: Vec<f64> = Vec::with_capacity(report.records.len());
    for r in report.records.iter().filter(|r| r.ok()) {
        waits.push(r.wait_secs);
        turnarounds.push(r.end_secs - r.arrival_secs);
    }
    waits.sort_by(f64::total_cmp);
    turnarounds.sort_by(f64::total_cmp);

    // binned utilization (busy node-seconds / capacity) and completion
    // throughput over the virtual horizon
    let bin_w = (virtual_secs / BINS as f64).max(1e-9);
    let mut busy = vec![0.0f64; BINS];
    let mut done = vec![0u32; BINS];
    for r in report.records.iter().filter(|r| r.ok()) {
        let (s, e) = (r.start_secs, r.end_secs);
        let first = ((s / bin_w) as usize).min(BINS - 1);
        let last = ((e / bin_w) as usize).min(BINS - 1);
        for (b, slot) in busy.iter_mut().enumerate().take(last + 1).skip(first)
        {
            let lo = s.max(b as f64 * bin_w);
            let hi = e.min((b + 1) as f64 * bin_w);
            if hi > lo {
                *slot += f64::from(r.width) * (hi - lo);
            }
        }
        done[last] += 1;
    }
    let capacity_per_bin = f64::from(nodes) * bin_w;
    let utilization: Vec<Json> = busy
        .iter()
        .map(|b| Json::Num(b / capacity_per_bin))
        .collect();
    let throughput: Vec<Json> = done
        .iter()
        .map(|d| Json::Num(f64::from(*d) / (bin_w / 3600.0)))
        .collect();

    println!(
        "sim_scale: {jobs} jobs / {nodes} nodes — {virtual_secs:.0}s \
         virtual in {wall_secs:.2}s wall ({speedup:.0}x), wait p50 \
         {:.1}s p99 {:.1}s, utilization {:.2}%",
        pctl(&waits, 0.50),
        pctl(&waits, 0.99),
        report.utilization() * 100.0,
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("sim_scale")),
        ("nodes", Json::Num(f64::from(nodes))),
        ("jobs", Json::Num(f64::from(jobs))),
        ("tenants", Json::Num(f64::from(TENANTS))),
        ("shards", Json::Num(SHARDS as f64)),
        ("virtual_secs", Json::Num(virtual_secs)),
        ("wall_secs", Json::Num(wall_secs)),
        ("budget_secs", Json::Num(budget)),
        ("speedup", Json::Num(speedup)),
        ("utilization_overall", Json::Num(report.utilization())),
        ("wait", pctl_json(&waits)),
        ("turnaround", pctl_json(&turnarounds)),
        ("bin_secs", Json::Num(bin_w)),
        ("utilization_curve", Json::Arr(utilization)),
        ("throughput_jobs_per_hour", Json::Arr(throughput)),
    ]);
    let path = std::env::var("BENCH_SIMKERNEL_JSON")
        .unwrap_or_else(|_| "BENCH_simkernel.json".to_string());
    std::fs::write(&path, doc.to_string())
        .expect("write BENCH_simkernel.json");
    println!("wrote {path}");
}
