//! launch_scale — the cluster-scale job-launch storm benchmark
//! (DESIGN.md S19): drive `JobSpec`s across 1/64/1024/4096 simulated
//! nodes through the full orchestrator — WLM allocation, one coalesced
//! gateway pull, per-node Shifter stage execution on a thread pool —
//! for homogeneous (Piz Daint) and heterogeneous (Piz Daint + Linux
//! Cluster) partitions, cold vs warm node caches. Each configuration is
//! one `SiteBuilder` declaration (DESIGN.md S21); launches go through
//! `Site::launch`.
//!
//! Reported (and asserted, like the paper-table benches):
//!   * per-node launch percentiles (p50/p95/p99) per configuration;
//!   * coalescing at launch scale: exactly one gateway pull job per
//!     unique image reference, even with 4096 requesters;
//!   * warm relaunch p99 >= 10x below the cold launch p99 at storm width;
//!   * straggler/retry accounting under the default policy.
//!
//! The full result set is written to `BENCH_launch.json` so CI can track
//! the perf trajectory per PR. Set `LAUNCH_SCALE_NODES` to cap the storm
//! width (the CI bench-smoke job runs with a reduced cap).

use shifter_rs::launch::{JobSpec, LaunchReport};
use shifter_rs::metrics::Table;
use shifter_rs::util::json::Json;
use shifter_rs::{Site, SystemProfile};

/// The §IV.A-style job every configuration launches: the CUDA image with
/// one GPU per node (CUDA_VISIBLE_DEVICES injected via GRES).
const IMAGE: &str = "nvidia/cuda-image:8.0";
const SHARDS: usize = 8;
const FULL_NODES: u32 = 4096;

fn max_nodes() -> u32 {
    std::env::var("LAUNCH_SCALE_NODES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(FULL_NODES)
        .max(1)
}

fn site_for(hetero: bool, nodes: u32) -> Site {
    // telemetry on: the artifact embeds the counter/histogram snapshot
    // of the largest configuration (DESIGN.md S23)
    let builder = Site::builder().gateway_shards(SHARDS).telemetry(true);
    let builder = if hetero && nodes >= 2 {
        builder.hetero_daint_linux(nodes)
    } else {
        builder.profile(SystemProfile::piz_daint()).nodes(nodes)
    };
    builder.build().expect("valid bench site")
}

fn fmt_secs(v: f64) -> String {
    if v < 1.0 {
        format!("{:.1}ms", v * 1e3)
    } else {
        format!("{v:.2}s")
    }
}

fn config_json(
    partitions: &str,
    nodes: u32,
    phase: &str,
    report: &LaunchReport,
) -> Json {
    Json::obj(vec![
        ("partitions", Json::str(partitions)),
        ("nodes", Json::Num(nodes as f64)),
        ("phase", Json::str(phase)),
        ("report", report.to_json()),
    ])
}

fn main() {
    let cap = max_nodes();
    let mut node_counts: Vec<u32> = [1u32, 64, 1024, FULL_NODES]
        .iter()
        .copied()
        .filter(|n| *n <= cap)
        .collect();
    if node_counts.is_empty() || *node_counts.last().unwrap() < cap {
        node_counts.push(cap);
    }

    let mut table = Table::new(
        &format!("launch storm, {SHARDS}-shard fabric, image {IMAGE}"),
        &[
            "partitions", "nodes", "cache", "p50", "p99", "worst",
            "retries", "queue-wait",
        ],
    );
    let mut json_configs: Vec<Json> = Vec::new();
    let mut largest_hetero: Option<(u32, LaunchReport, LaunchReport)> = None;
    let mut telemetry_snapshot = Json::Null;

    for hetero in [false, true] {
        let partitions = if hetero { "hetero" } else { "homog" };
        for &nodes in &node_counts {
            if hetero && nodes < 2 {
                continue;
            }
            let mut site = site_for(hetero, nodes);
            let spec = JobSpec::new(IMAGE, &["deviceQuery"], nodes).with_gpus(1);

            // cold: every node cache is empty, the broadcast storm runs
            let cold = site.launch(&spec).expect("cold launch failed");
            // warm: same site, every node already holds the squashfs
            let warm = site.launch(&spec).expect("warm launch failed");

            for (phase, report) in [("cold", &cold), ("warm", &warm)] {
                assert_eq!(
                    report.succeeded() as u32,
                    nodes,
                    "{partitions}/{nodes}/{phase}: every slot must launch"
                );
                let pull = report.pull.expect("pull summary present");
                assert_eq!(
                    pull.jobs_total, 1,
                    "{partitions}/{nodes}/{phase}: coalescing must hold — \
                     exactly one gateway pull job per unique image reference"
                );
                let total = report.total_stats().expect("launch totals");
                table.row(&[
                    partitions.to_string(),
                    nodes.to_string(),
                    phase.to_string(),
                    fmt_secs(total.p50),
                    fmt_secs(total.p99),
                    fmt_secs(total.worst),
                    report.retries().to_string(),
                    fmt_secs(pull.queue_wait_secs),
                ]);
                json_configs.push(config_json(partitions, nodes, phase, report));
            }
            // last (largest) configuration wins: cold + warm counters
            telemetry_snapshot = site.telemetry().snapshot_json();
            if hetero && nodes == *node_counts.last().unwrap() {
                largest_hetero = Some((nodes, cold, warm));
            }
        }
    }
    print!("{}", table.render());

    // -- acceptance: the largest heterogeneous cold-cache launch ----------
    let Some((nodes, cold, warm)) = largest_hetero else {
        // only reachable with LAUNCH_SCALE_NODES=1 (no room for two
        // partitions); the storm assertions need at least 2 nodes
        write_artifact(cap, json_configs, telemetry_snapshot);
        return;
    };
    let pull = cold.pull.expect("pull summary");
    assert_eq!(
        pull.jobs_total, 1,
        "{nodes}-node heterogeneous cold launch must coalesce into exactly \
         one gateway pull job for the one unique image reference"
    );
    assert_eq!(pull.requesters as u32, nodes);
    let cold_total = cold.total_stats().unwrap();
    assert!(
        cold_total.p99 >= cold_total.p50 && cold_total.p50 > 0.0,
        "p99 stage timings must be reported and ordered"
    );
    for (stage, stats) in cold.stage_stats() {
        assert!(
            stats.p99 >= stats.p50,
            "stage {stage}: p99 {} < p50 {}",
            stats.p99,
            stats.p50
        );
    }
    // both partitions really launched their halves
    let daint_ok = cold
        .node_results
        .iter()
        .filter(|r| r.ok() && r.partition == "daint-xc50")
        .count();
    let cluster_ok = cold
        .node_results
        .iter()
        .filter(|r| r.ok() && r.partition == "linux-cluster")
        .count();
    assert_eq!(daint_ok as u32 + cluster_ok as u32, nodes);
    if nodes >= 2 {
        assert!(daint_ok > 0 && cluster_ok > 0);
    }
    // warm relaunch collapses the broadcast at storm width (at narrow
    // widths the fixed mount/exec cost dominates and the ratio shrinks)
    if nodes >= 512 {
        let warm_p99 = warm.total_stats().unwrap().p99;
        assert!(
            warm_p99 * 10.0 <= cold_total.p99,
            "warm p99 {warm_p99}s must be >= 10x below cold {}s",
            cold_total.p99
        );
    }
    println!(
        "largest hetero launch: {nodes} nodes cold p99 {} (warm {}), \
         {} retries / {} stragglers, queue wait {}",
        fmt_secs(cold_total.p99),
        fmt_secs(warm.total_stats().unwrap().p99),
        cold.retries(),
        cold.stragglers(),
        fmt_secs(pull.queue_wait_secs),
    );

    write_artifact(cap, json_configs, telemetry_snapshot);
}

/// Write the perf-trajectory artifact CI uploads per PR.
fn write_artifact(cap: u32, json_configs: Vec<Json>, telemetry: Json) {
    let doc = Json::obj(vec![
        ("bench", Json::str("launch_scale")),
        ("image", Json::str(IMAGE)),
        ("shards", Json::Num(SHARDS as f64)),
        ("max_nodes", Json::Num(cap as f64)),
        ("configs", Json::Arr(json_configs)),
        ("telemetry", telemetry),
    ]);
    let path = std::env::var("BENCH_LAUNCH_JSON")
        .unwrap_or_else(|_| "BENCH_launch.json".to_string());
    std::fs::write(&path, doc.to_string())
        .expect("write BENCH_launch.json");
    println!("wrote {path}");
}
