//! Shared harness for the Table III / Table IV OSU latency benches.

use shifter_rs::apps::osu::{self, LatencyRow};
use shifter_rs::fabric::OSU_SIZES;
use shifter_rs::metrics::Table;
use shifter_rs::shifter::{RunOptions, ShifterRuntime};
use shifter_rs::{ImageGateway, Registry, SystemProfile};

pub const CONTAINERS: [(&str, &str); 3] = [
    ("A", "osu-benchmarks:mpich-3.1.4"),
    ("B", "osu-benchmarks:mvapich2-2.2"),
    ("C", "osu-benchmarks:intelmpi-2017.1"),
];

pub struct OsuTableResult {
    pub native: Vec<LatencyRow>,
    /// per container: (enabled ratios, disabled ratios)
    pub containers: Vec<(Vec<f64>, Vec<f64>)>,
}

/// Run the full table protocol on one system.
pub fn run_system(profile: &SystemProfile) -> OsuTableResult {
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(profile.pfs.clone().unwrap());
    for (_, image) in CONTAINERS {
        gateway.pull(&registry, image).unwrap();
    }
    let runtime = ShifterRuntime::new(profile);
    let native = osu::run_native(profile);

    let mut containers = Vec::new();
    for (tag, image) in CONTAINERS {
        let c_on = runtime
            .run(&gateway, &RunOptions::new(image, &["osu_latency"]).with_mpi())
            .unwrap();
        assert!(c_on.mpi.is_some(), "swap must succeed for {image}");
        let on = osu::run_container(profile, &c_on, &format!("{tag}-enabled"));
        let c_off = runtime
            .run(&gateway, &RunOptions::new(image, &["osu_latency"]))
            .unwrap();
        assert!(c_off.mpi.is_none());
        let off = osu::run_container(profile, &c_off, &format!("{tag}-disabled"));
        containers.push((osu::relative(&on, &native), osu::relative(&off, &native)));
    }
    OsuTableResult { native, containers }
}

/// Render the paper-shaped table.
pub fn render(title: &str, result: &OsuTableResult) -> String {
    let mut t = Table::new(
        title,
        &[
            "Size", "Native", "A-on", "B-on", "C-on", "A-off", "B-off", "C-off",
        ],
    );
    for (i, &size) in OSU_SIZES.iter().enumerate() {
        t.row(&[
            osu::size_label(size),
            format!("{:.1}", result.native[i].best_us),
            format!("{:.2}", result.containers[0].0[i]),
            format!("{:.2}", result.containers[1].0[i]),
            format!("{:.2}", result.containers[2].0[i]),
            format!("{:.1}", result.containers[0].1[i]),
            format!("{:.1}", result.containers[1].1[i]),
            format!("{:.1}", result.containers[2].1[i]),
        ]);
    }
    t.render()
}

/// The shape that must hold: enabled ≈ 1.0, disabled within band.
pub fn assert_shape(result: &OsuTableResult, disabled_band: (f64, f64)) {
    for (on, off) in &result.containers {
        for (i, r) in on.iter().enumerate() {
            assert!(
                (0.88..1.15).contains(r),
                "enabled ratio out of band at size {}: {r}",
                OSU_SIZES[i]
            );
        }
        for (i, r) in off.iter().enumerate() {
            assert!(
                (disabled_band.0..disabled_band.1).contains(r),
                "disabled ratio out of band at size {}: {r}",
                OSU_SIZES[i]
            );
        }
    }
}
