//! federation_burst — the multi-site federation benchmark (DESIGN.md
//! S27): replay one Zipf-skewed multi-tenant storm across a federation
//! of identical 48-node sites under four routing configurations on the
//! same stream and compare:
//!
//!   * **pinned** — `PinnedHome`, overflow disabled: every tenant's
//!     jobs run at its home site — the no-federation baseline;
//!   * **burst** — `PinnedHome` plus burst overflow: jobs spill to a
//!     compatible peer (paying replication first) when the home site's
//!     queue-wait estimate crosses the threshold;
//!   * **locality** — `DataLocality` routing: replicas concentrate
//!     where images already live;
//!   * **random** — seeded `RandomPlacement`: the scatter-everything
//!     placement baseline.
//!
//! Asserted (the ISSUE 10 acceptance criteria):
//!   * **burst overflow cuts the aggregate p99 end-to-end wait** versus
//!     pinned-to-home on the same contended stream, and overflow
//!     actually fires;
//!   * **data-locality routing moves fewer WAN replication bytes** than
//!     random placement;
//!   * the artifact and the shared Chrome trace are **byte-identical
//!     across runs** — the federation inherits the stack's determinism.
//!
//! All four reports land in `BENCH_federation.json` so CI tracks the
//! federation trajectory per PR. Knobs: `FEDERATION_JOBS` caps the
//! stream length, `FEDERATION_SITES` the fleet size (2–4; CI runs
//! reduced values), `BENCH_FEDERATION_JSON` the artifact path.

use shifter_rs::federation::{
    DataLocality, PinnedHome, RandomPlacement, RoutingPolicy,
};
use shifter_rs::launch::RetryPolicy;
use shifter_rs::util::json::Json;
use shifter_rs::{
    Federation, FederationReport, FederationStorm, SiteBuilder,
    SystemProfile,
};

const SHARDS: usize = 4;
/// Few tenants + Zipf skew 1.0 concentrate ~60% of the stream on the
/// first tenant's home site — the contended regime burst overflow is
/// for.
const TENANTS: u32 = 4;
const FULL_JOBS: u32 = 96;
const FULL_SITES: u32 = 3;
const NODES_PER_SITE: u32 = 48;
const MAX_WIDTH: u32 = 16;
const ARRIVAL_RATE_PER_MIN: f64 = 1.8;
/// Burst threshold: spill when the home queue estimate exceeds this.
const OVERFLOW_THRESHOLD_SECS: f64 = 120.0;
const SEED: u64 = 13;
const SITE_NAMES: [&str; 4] = ["alpha", "bravo", "charlie", "delta"];

fn env_u32(name: &str, full: u32) -> u32 {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(full)
        .max(1)
}

/// A fleet of `sites` identical GPU-capable member sites (every
/// generated job class is eligible everywhere, so the routing
/// comparison is pure placement, not capability filtering).
fn make_fed(
    sites: u32,
    routing: Box<dyn RoutingPolicy>,
    threshold: Option<f64>,
) -> Federation {
    let mut builder = Federation::builder()
        .routing(routing)
        .seed(SEED)
        .telemetry(true);
    for name in SITE_NAMES.iter().take(sites as usize) {
        builder = builder.site(
            name,
            SiteBuilder::new()
                .profile(SystemProfile::piz_daint())
                .nodes(NODES_PER_SITE)
                .gateway_shards(SHARDS)
                // strict retry: exact replication/wait accounting, no
                // straggler noise in the routing comparison
                .retry_policy(RetryPolicy::strict())
                .seed(SEED),
        );
    }
    if let Some(secs) = threshold {
        builder = builder.overflow_threshold_secs(secs);
    }
    builder.build().expect("valid bench federation")
}

fn storm(jobs: u32) -> FederationStorm {
    FederationStorm::new()
        .tenants(TENANTS)
        .jobs(jobs)
        .arrival_rate_per_min(ARRIVAL_RATE_PER_MIN)
        .max_width(MAX_WIDTH)
        .seed(SEED)
}

/// Run one routing configuration on a fresh federation (same
/// declaration, same storm seed — every config sees the identical
/// stream) and return its report plus the shared Chrome trace.
fn run_config(
    sites: u32,
    jobs: u32,
    routing: Box<dyn RoutingPolicy>,
    threshold: Option<f64>,
) -> (FederationReport, String) {
    let mut fed = make_fed(sites, routing, threshold);
    let report = fed.run_storm(&storm(jobs)).expect("federation storm runs");
    let trace = fed.telemetry().chrome_trace_jsonl();
    (report, trace)
}

fn p99_wait(report: &FederationReport) -> f64 {
    report
        .total_wait_stats()
        .expect("completed jobs exist")
        .p99
}

fn main() {
    let sites = env_u32("FEDERATION_SITES", FULL_SITES).clamp(2, 4);
    let jobs = env_u32("FEDERATION_JOBS", FULL_JOBS);

    let pinned_policy = || Box::new(PinnedHome::new(sites as usize));
    let (pinned, _) = run_config(sites, jobs, pinned_policy(), None);
    let (burst, burst_trace) = run_config(
        sites,
        jobs,
        pinned_policy(),
        Some(OVERFLOW_THRESHOLD_SECS),
    );
    let (locality, _) =
        run_config(sites, jobs, Box::new(DataLocality), None);
    let (random, _) = run_config(
        sites,
        jobs,
        Box::new(RandomPlacement::new(SEED)),
        None,
    );

    for (name, report) in [
        ("pinned", &pinned),
        ("burst", &burst),
        ("locality", &locality),
        ("random", &random),
    ] {
        print!("{}", report.render());
        assert!(
            report.rejections.is_empty(),
            "{name}: the uniform GPU fleet accepts every generated job \
             class, so nothing may be rejected: {:?}",
            report.rejections
        );
        assert_eq!(
            report.records.len() as u32,
            jobs,
            "{name}: every generated job must be routed"
        );
        assert_eq!(
            report.completed() as u32,
            jobs,
            "{name}: every routed job must complete on its site"
        );
    }

    // data locality vs scatter: both configs replicate over the same
    // WAN, but locality concentrates each image where it already lives
    // while random placement copies it to multiple sites.
    if jobs >= 16 {
        assert!(
            locality.replication_bytes() < random.replication_bytes(),
            "data-locality routing must move fewer WAN bytes than \
             random placement: {} vs {}",
            locality.replication_bytes(),
            random.replication_bytes()
        );
    }

    // burst overflow vs pinned-to-home on the same stream. The tail
    // claim needs the contended regime: at least three sites (so the
    // overloaded home has idle peers) and enough jobs to build a
    // queue — a reduced smoke run can land on a stream where spilling
    // cannot beat staying (and with two sites the pinned split is too
    // even for overflow to pay for its replication).
    if sites >= 3 && jobs >= 32 {
        assert!(
            burst.overflows > 0,
            "the contended stream must trigger burst overflow"
        );
        assert!(
            p99_wait(&burst) < p99_wait(&pinned),
            "burst overflow must cut the aggregate p99 end-to-end wait: \
             burst {:.0}s vs pinned {:.0}s",
            p99_wait(&burst),
            p99_wait(&pinned)
        );
    }

    // determinism: an identical second burst run must reproduce both
    // the artifact document and the shared Chrome trace byte for byte.
    let (burst2, burst2_trace) = run_config(
        sites,
        jobs,
        pinned_policy(),
        Some(OVERFLOW_THRESHOLD_SECS),
    );
    assert_eq!(
        burst.to_json().to_string(),
        burst2.to_json().to_string(),
        "federation artifact must be byte-identical across runs"
    );
    assert_eq!(
        burst_trace, burst2_trace,
        "federation Chrome trace must be byte-identical across runs"
    );

    println!(
        "federation: {} jobs / {} tenants / {} x {}-node sites — p99 \
         wait pinned {:.0}s vs burst {:.0}s ({} overflows, {:.1}% rate), \
         replication locality {} B vs random {} B",
        jobs,
        TENANTS,
        sites,
        NODES_PER_SITE,
        p99_wait(&pinned),
        p99_wait(&burst),
        burst.overflows,
        burst.overflow_rate() * 100.0,
        locality.replication_bytes(),
        random.replication_bytes(),
    );

    let doc = Json::obj(vec![
        ("bench", Json::str("federation_burst")),
        ("sites", Json::num(f64::from(sites))),
        ("nodes_per_site", Json::num(f64::from(NODES_PER_SITE))),
        (
            "max_nodes",
            Json::num(f64::from(sites * NODES_PER_SITE)),
        ),
        ("jobs", Json::num(f64::from(jobs))),
        ("tenants", Json::num(f64::from(TENANTS))),
        (
            "overflow_threshold_secs",
            Json::num(OVERFLOW_THRESHOLD_SECS),
        ),
        ("pinned", pinned.to_json()),
        ("burst", burst.to_json()),
        ("locality", locality.to_json()),
        ("random", random.to_json()),
    ]);
    let path = std::env::var("BENCH_FEDERATION_JSON")
        .unwrap_or_else(|_| "BENCH_federation.json".to_string());
    std::fs::write(&path, doc.to_string())
        .expect("write BENCH_federation.json");
    println!("wrote {path}");
}
