//! Table III — OSU latency on the Linux Cluster: native MVAPICH2 2.1 over
//! EDR InfiniBand vs containers A/B/C with Shifter MPI support enabled and
//! disabled. Paper: enabled 0.98–1.08, disabled ~15–50x.

mod osu_common;

use shifter_rs::SystemProfile;

fn main() {
    let cl = SystemProfile::linux_cluster();
    let result = osu_common::run_system(&cl);
    print!(
        "{}",
        osu_common::render(
            "Table III: OSU_latency on the Linux Cluster (ratios vs native)",
            &result
        )
    );
    osu_common::assert_shape(&result, (12.0, 55.0));
    println!("shape holds: enabled ≈ 1.0x, disabled 15–50x (paper Table III) ✓");

    // paper's native column for reference
    let paper_native = [1.2, 1.3, 1.8, 2.4, 4.5, 12.1, 56.8, 141.5, 480.8];
    let max_dev = result
        .native
        .iter()
        .zip(paper_native)
        .map(|(r, p)| (r.best_us / p - 1.0).abs())
        .fold(0.0f64, f64::max);
    println!("native column max deviation from paper: {:.1}%", max_dev * 100.0);
}
