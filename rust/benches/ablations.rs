//! Ablation benches (DESIGN.md §6): the design choices behind the paper's
//! architecture, quantified.
//!
//! A1 flatten-vs-layered: why the Gateway flattens + squashes images.
//! A2 ABI-check on/off: why the swap verifies libtool strings.
//! A3 loop-mount vs PFS-direct: Fig. 3's mechanism isolated.
//! A4 eager/rendezvous threshold: the fabric protocol crossover.

use shifter_rs::apps::pynamic::{self, Mode};
use shifter_rs::fabric::AnalyticLink;
use shifter_rs::image::builder;
use shifter_rs::metrics::Table;
use shifter_rs::mpi::{swap_compatible, MpiImpl};
use shifter_rs::pfs::{LustreFs, NodeLocalFs};
use shifter_rs::vfs::SquashFs;
use shifter_rs::SystemProfile;

fn a1_flatten_vs_layered() {
    println!("== A1: flattened squashfs vs layered overlay start-up ==");
    let pfs = LustreFs::piz_daint();
    let image = builder::tensorflow_image();
    let layers = image.layers.len() as u64;
    let flat = image.flatten().unwrap();
    let sq = SquashFs::create(&flat);
    let nodes = 256u64;

    // flattened: 1 MDS lookup + 1 compressed stream per node
    let flat_secs = pfs.mds.storm_secs(nodes, 1)
        + pfs.bulk_read_secs(sq.compressed_bytes, nodes);
    // layered: L lookups + L separate (less compressible) streams + the
    // runtime resolving every file through the layer stack
    let layered_bytes: u64 = image.layers.iter().map(|l| l.compressed_bytes()).sum();
    let files = flat.file_count() as u64;
    let layered_secs = pfs.mds.storm_secs(nodes, layers)
        + pfs.bulk_read_secs(layered_bytes, nodes)
        + pfs.mds.storm_secs(nodes, files * layers / 4) * 0.0 // resolution is local after fetch
        + files as f64 * layers as f64 * 0.4e-6; // overlay path walk

    println!(
        "  {} layers, {} files, {:.0} MiB flat / {:.0} MiB layered transfer",
        layers,
        files,
        sq.compressed_bytes as f64 / (1 << 20) as f64,
        layered_bytes as f64 / (1 << 20) as f64
    );
    println!(
        "  start-up on {nodes} nodes: flattened {flat_secs:.1}s, layered {layered_secs:.1}s \
         ({:.2}x)",
        layered_secs / flat_secs
    );
    assert!(layered_secs > flat_secs);
}

fn a2_abi_check() {
    println!("\n== A2: MPI ABI check on/off ==");
    let host = MpiImpl::cray_mpt_7_5_host();
    let good = MpiImpl::mpich_3_1_4_container();
    let bad = MpiImpl::openmpi_2_0();
    let legacy = MpiImpl::cray_mpt_6_legacy();

    // what the check costs (time a million comparisons)
    let start = std::time::Instant::now();
    let mut acc = 0u64;
    for _ in 0..1_000_000 {
        acc += swap_compatible(std::hint::black_box(&good), std::hint::black_box(&host)) as u64;
    }
    let per_check = start.elapsed().as_nanos() as f64 / 1e6;
    println!("  check cost: {per_check:.1} ns/swap (x{acc} ok)");

    // what the check prevents
    for (name, container) in [("Open MPI 2.0", &bad), ("Cray MPT 6.3 (pre-initiative)", &legacy)] {
        let ok = swap_compatible(container, &host);
        println!(
            "  {} vs host {}: {}",
            name,
            host.version_string(),
            if ok {
                "ACCEPTED (would crash at dlopen)"
            } else {
                "rejected ✓ (soname/interface mismatch caught before exec)"
            }
        );
        assert!(!ok);
    }
}

fn a3_loopmount_vs_pfs() {
    println!("\n== A3: loop-mount vs PFS-direct DLL loading (768 ranks) ==");
    let pd = SystemProfile::piz_daint();
    let native = pynamic::run(&pd, 768, Mode::Native);
    let shifter = pynamic::run(&pd, 768, Mode::Shifter);
    println!(
        "  import phase: PFS-direct {:.1}s vs loop-mount {:.1}s ({:.0}x)",
        native.import.mean,
        shifter.import.mean,
        native.import.mean / shifter.import.mean
    );
    // per-open cost decomposition
    let local = NodeLocalFs::squashfs_loop_mount();
    let pfs = pd.pfs.as_ref().unwrap();
    println!(
        "  per-open metadata: MDS {:.0} µs (unloaded) vs local dcache {:.1} µs",
        pfs.mds.base_latency_us, local.stat_latency_us
    );
    assert!(native.import.mean > shifter.import.mean);
}

fn a4_eager_threshold() {
    println!("\n== A4: eager/rendezvous threshold sweep (analytic fabric) ==");
    let mut t = Table::new(
        "one-way latency (µs) of a 16 KiB message",
        &["threshold", "latency"],
    );
    for thresh_kib in [1u64, 4, 8, 16, 32, 64] {
        let link = AnalyticLink {
            base_latency_us: 1.1,
            bandwidth_gbps: 9.7,
            eager_threshold: thresh_kib * 1024,
            rendezvous_overhead_us: 2.4,
        };
        t.row(&[
            format!("{thresh_kib}K"),
            format!("{:.2}", link.latency_us(16 * 1024)),
        ]);
    }
    print!("{}", t.render());
    // crossover: the 16K message pays the rendezvous penalty only when the
    // threshold is below its size
    let low = AnalyticLink {
        base_latency_us: 1.1,
        bandwidth_gbps: 9.7,
        eager_threshold: 8 * 1024,
        rendezvous_overhead_us: 2.4,
    };
    let high = AnalyticLink {
        eager_threshold: 32 * 1024,
        ..low.clone()
    };
    assert!(low.latency_us(16 * 1024) > high.latency_us(16 * 1024));
    println!("crossover falls at the message-size = threshold boundary ✓");
}

fn main() {
    a1_flatten_vs_layered();
    a2_abi_check();
    a3_loopmount_vs_pfs();
    a4_eager_threshold();
}
