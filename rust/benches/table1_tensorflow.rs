//! Table I — containerized TensorFlow run times (seconds) for MNIST and
//! CIFAR-10 on all three test systems, plus a real-substrate check: a
//! short genuine training run through the AOT artifacts.
//!
//! Paper values: MNIST 613 / 105 / 36, CIFAR-10 23359 / 8905 / 6246.

use shifter_rs::apps::tf_trainer::{self, TfWorkload};
use shifter_rs::gpu::GpuModel;
use shifter_rs::metrics::Table;
use shifter_rs::runtime::Executor;

fn main() {
    let boards = [
        ("Laptop", GpuModel::quadro_k110m()),
        ("Cluster", GpuModel::tesla_k40m()),
        ("Piz Daint", GpuModel::tesla_p100()),
    ];
    let paper: [(&str, [f64; 3]); 2] = [
        ("MNIST", [613.0, 105.0, 36.0]),
        ("CIFAR-10", [23359.0, 8905.0, 6246.0]),
    ];

    let mut t = Table::new(
        "Table I: containerized TensorFlow run times (s)",
        &["workload", "system", "paper", "measured", "ratio"],
    );
    let mut worst: f64 = 0.0;
    for (wl, (name, paper_row)) in
        [TfWorkload::Mnist, TfWorkload::Cifar10].iter().zip(paper)
    {
        for ((sys, board), p) in boards.iter().zip(paper_row) {
            let m = tf_trainer::train_time_secs(*wl, board);
            worst = worst.max((m / p - 1.0).abs());
            t.row(&[
                name.to_string(),
                sys.to_string(),
                format!("{p:.0}"),
                format!("{m:.0}"),
                format!("{:.3}", m / p),
            ]);
        }
    }
    print!("{}", t.render());
    println!("max deviation from paper: {:.1}%", worst * 100.0);

    // ordering assertion (the shape that must hold)
    for wl in [TfWorkload::Mnist, TfWorkload::Cifar10] {
        let times: Vec<f64> = boards
            .iter()
            .map(|(_, b)| tf_trainer::train_time_secs(wl, b))
            .collect();
        assert!(times[2] < times[1] && times[1] < times[0], "{wl:?}");
    }

    // real-substrate check (skipped if artifacts are not built)
    if let Ok(ex) = Executor::new(shifter_rs::runtime::default_artifact_dir()) {
        println!("\nreal-substrate check (PJRT CPU, 10 steps each):");
        for wl in [TfWorkload::Mnist, TfWorkload::Cifar10] {
            let start = std::time::Instant::now();
            let rep = tf_trainer::run_real_training(&ex, wl, 10, 7).unwrap();
            println!(
                "  {:<9} loss {:.3} -> {:.3} ({}), {:.2} GF/s, {:.1}s",
                wl.name(),
                rep.first_loss(),
                rep.last_loss(),
                if rep.loss_decreased() { "ok" } else { "FLAT" },
                rep.cpu_gflops,
                start.elapsed().as_secs_f64(),
            );
        }
    } else {
        println!("\n(artifacts/ not built — run `make artifacts` for the real-substrate check)");
    }
}
