//! Table V — CUDA SDK n-body (200,000 bodies, double precision): GF/s for
//! native execution vs the containerized application with Shifter GPU
//! support, across the four hardware setups.
//!
//! Paper: 18.34 / 858.09 / 1895.32 / 2733.01 GF/s, container == native.

use shifter_rs::apps::nbody::{self, NbodySetup};
use shifter_rs::metrics::Table;
use shifter_rs::runtime::Executor;
use shifter_rs::shifter::RunOptions;
use shifter_rs::{ImageGateway, Registry, ShifterRuntime, SystemProfile};

fn main() {
    // the container actually goes through the runtime: GPU support must
    // trigger on each system before we report containerized numbers
    let registry = Registry::dockerhub();
    for (profile, cvd) in [
        (SystemProfile::linux_cluster(), "0,1,2"),
        (SystemProfile::piz_daint(), "0"),
    ] {
        let mut gw = ImageGateway::new(profile.pfs.clone().unwrap());
        gw.pull(&registry, "nvidia/cuda-image:8.0").unwrap();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(
                &gw,
                &RunOptions::new("nvidia/cuda-image:8.0", &["./nbody"])
                    .with_env("CUDA_VISIBLE_DEVICES", cvd),
            )
            .unwrap();
        assert!(c.gpu.is_some(), "GPU support must trigger on {}", profile.name);
    }

    let cases = [
        (NbodySetup::laptop(), "Laptop", 18.34),
        (NbodySetup::cluster_single(), "Cluster", 858.09),
        (NbodySetup::cluster_dual(), "Cluster", 1895.32),
        (NbodySetup::daint(), "Piz Daint", 2733.01),
    ];

    let mut t = Table::new(
        "Table V: n-body GF/s (200k bodies, fp64), best of 30",
        &["system", "gpus", "paper", "native", "container", "cont/nat"],
    );
    for (setup, system, paper) in &cases {
        let native = nbody::benchmark_gflops(setup, "native").best;
        let container = nbody::benchmark_gflops(setup, "container").best;
        t.row(&[
            system.to_string(),
            setup.label.to_string(),
            format!("{paper:.2}"),
            format!("{native:.2}"),
            format!("{container:.2}"),
            format!("{:.4}", container / native),
        ]);
        assert!((native / paper - 1.0).abs() < 0.02, "{}", setup.label);
        assert!((container / native - 1.0).abs() < 0.005, "{}", setup.label);
    }
    print!("{}", t.render());
    println!("container == native within 0.5% on every setup ✓");

    if let Ok(ex) = Executor::new(shifter_rs::runtime::default_artifact_dir()) {
        let start = std::time::Instant::now();
        let rep = nbody::run_real_steps(&ex, 5, 99).unwrap();
        println!(
            "\nreal-substrate check: {} bodies x {} steps on CPU PJRT: \
             {:.2} GF/s, |a| proxy {:.4e} ({:.1}s)",
            rep.n_bodies,
            rep.steps,
            rep.cpu_gflops,
            rep.final_acc_norm,
            start.elapsed().as_secs_f64()
        );
        assert!(rep.final_acc_norm.is_finite());
    }
}
