//! Image builder + the catalog of container images the paper's evaluation
//! uses (§V.B/§V.C). Building happens "on the user's workstation with
//! Docker"; here the builder produces the same artifact: layered images
//! with env, labels and entrypoints.
//!
//! Discovery convention: image-resident software that Shifter must reason
//! about (the container's MPI, the CUDA toolkit it was built against) is
//! described by OCI-style labels, standing in for what the real runtime
//! reads from the ELF headers / libtool strings of the contained libraries.

use std::collections::BTreeMap;

use super::{Image, ImageManifest, ImageRef, Layer};
use crate::mpi::MpiImpl;
use crate::util::prng::Rng;
use crate::vfs::VirtualFs;

pub const LABEL_MPI_VENDOR: &str = "org.shifter.mpi.vendor";
pub const LABEL_MPI_VERSION: &str = "org.shifter.mpi.version";
pub const LABEL_MPI_ABI: &str = "org.shifter.mpi.abi";
pub const LABEL_CUDA_VERSION: &str = "org.shifter.cuda.version";
/// Transport family a fabric-aware image was built against ("gni",
/// "verbs"); portable TCP builds carry no label.
pub const LABEL_NET_FABRIC: &str = "org.shifter.net.fabric";
/// Transport ABI string (`transport:major`) of a fabric-aware build —
/// gated against the host by `netfab::check`.
pub const LABEL_NET_ABI: &str = "org.shifter.net.abi";
pub const LABEL_APP: &str = "org.shifter.app";

pub struct ImageBuilder {
    reference: ImageRef,
    layers: Vec<Layer>,
    env: Vec<(String, String)>,
    labels: BTreeMap<String, String>,
    entrypoint: Vec<String>,
    files_content: BTreeMap<String, String>,
    pending: VirtualFs,
    pending_whiteouts: Vec<String>,
    rng: Rng,
}

/// The builder's constructor contract: references are compile-time
/// literals in the catalog, so a malformed one is a caller bug — panic
/// with the offending string rather than unwrapping opaquely.
fn parse_ref(reference: &str) -> ImageRef {
    match ImageRef::parse(reference) {
        Some(r) => r,
        None => panic!("ImageBuilder: invalid image reference {reference:?}"),
    }
}

/// Builder staging writes share one failure contract: a path collision in
/// the pending layer is a bug in the Dockerfile-style recipe driving the
/// builder — report it with the path, explicitly.
fn stage(result: Result<(), crate::vfs::VfsError>, path: &str) {
    if let Err(e) = result {
        panic!("ImageBuilder: cannot stage {path:?} into the pending layer: {e}");
    }
}

impl ImageBuilder {
    pub fn new(reference: &str) -> ImageBuilder {
        ImageBuilder {
            reference: parse_ref(reference),
            layers: Vec::new(),
            env: vec![(
                "PATH".to_string(),
                "/usr/local/sbin:/usr/local/bin:/usr/sbin:/usr/bin:/sbin:/bin"
                    .to_string(),
            )],
            labels: BTreeMap::new(),
            entrypoint: vec![],
            files_content: BTreeMap::new(),
            pending: VirtualFs::new(),
            pending_whiteouts: Vec::new(),
            rng: Rng::from_tags(&["image-builder", reference]),
        }
    }

    /// `FROM <base>`: start from an existing image's layers and config, the
    /// way a Dockerfile derives app images from a common base. Derived
    /// images share the base's layer digests byte-for-byte — which is what
    /// lets the content-addressed store (distrib::cas) dedup them.
    pub fn from_image(base: &Image, reference: &str) -> ImageBuilder {
        ImageBuilder {
            reference: parse_ref(reference),
            layers: base.layers.clone(),
            env: base.manifest.env.clone(),
            labels: base.manifest.labels.clone(),
            entrypoint: base.manifest.entrypoint.clone(),
            files_content: base.manifest.files_content.clone(),
            pending: VirtualFs::new(),
            pending_whiteouts: Vec::new(),
            rng: Rng::from_tags(&["image-builder", reference]),
        }
    }

    /// Seal the pending filesystem delta into a layer (Dockerfile step).
    pub fn commit_layer(mut self) -> Self {
        if !self.pending.is_empty() || !self.pending_whiteouts.is_empty() {
            let tree = std::mem::take(&mut self.pending);
            let wh = std::mem::take(&mut self.pending_whiteouts);
            self.layers.push(Layer::new(tree, wh));
            self.pending = VirtualFs::new();
        }
        self
    }

    pub fn file(mut self, path: &str, size: u64) -> Self {
        let digest = self.rng.next_u64();
        stage(self.pending.add_file(path, size, digest), path);
        self
    }

    pub fn exe(mut self, path: &str, size: u64) -> Self {
        let digest = self.rng.next_u64();
        stage(
            self.pending.insert(path, crate::vfs::VNode::exe(size, digest)),
            path,
        );
        self
    }

    /// Small text file with retrievable content (e.g. /etc/os-release).
    pub fn text_file(mut self, path: &str, content: &str) -> Self {
        let digest = self.rng.next_u64();
        stage(self.pending.add_file(path, content.len() as u64, digest), path);
        self.files_content.insert(path.to_string(), content.to_string());
        self
    }

    /// `count` files of ~`avg_size` bytes under `dir` (bulk content like a
    /// Python stdlib or TensorFlow source tree).
    pub fn bulk_files(mut self, dir: &str, count: u32, avg_size: u64) -> Self {
        for i in 0..count {
            let size =
                (avg_size as f64 * self.rng.range(0.5, 1.5)) as u64;
            let digest = self.rng.next_u64();
            let path = format!("{dir}/f{i:04}");
            stage(self.pending.add_file(&path, size, digest), &path);
        }
        self
    }

    pub fn whiteout(mut self, path: &str) -> Self {
        self.pending_whiteouts.push(path.to_string());
        self
    }

    pub fn env(mut self, k: &str, v: &str) -> Self {
        self.env.push((k.to_string(), v.to_string()));
        self
    }

    pub fn label(mut self, k: &str, v: &str) -> Self {
        self.labels.insert(k.to_string(), v.to_string());
        self
    }

    pub fn entrypoint(mut self, argv: &[&str]) -> Self {
        self.entrypoint = argv.iter().map(|s| s.to_string()).collect();
        self
    }

    /// Base OS layer: os-release + a representative root filesystem.
    pub fn base_os(self, name: &str, version: &str, pretty: &str, id: &str, codename: &str) -> Self {
        let os_release = format!(
            "NAME=\"{name}\"\nVERSION=\"{version}\"\nID={id}\n\
             ID_LIKE=debian\nPRETTY_NAME=\"{pretty}\"\n\
             VERSION_ID=\"{}\"\nHOME_URL=\"http://www.{id}.com/\"\n\
             SUPPORT_URL=\"http://help.{id}.com/\"\n\
             BUG_REPORT_URL=\"http://bugs.launchpad.net/{id}/\"\n\
             VERSION_CODENAME={codename}\nUBUNTU_CODENAME={codename}\n",
            version.split(' ').next().unwrap_or(version),
        );
        self.text_file("/etc/os-release", &os_release)
            .exe("/bin/sh", 120_000)
            .exe("/bin/bash", 1_000_000)
            .exe("/bin/cat", 52_000)
            .exe("/bin/ls", 126_000)
            .file("/etc/passwd", 1200)
            .file("/etc/group", 800)
            .bulk_files("/usr/lib", 150, 400_000)
            .bulk_files("/usr/share", 80, 60_000)
            .commit_layer()
    }

    /// Install an MPI implementation into the image (container-side build:
    /// TCP-only transports) and label it for the runtime's ABI check.
    pub fn with_mpi(self, mpi: &MpiImpl, prefix: &str) -> Self {
        let abi = mpi.abi.abi_string();
        let vendor = mpi.vendor.name().to_string();
        let version = format!(
            "{}.{}.{}",
            mpi.version.0, mpi.version.1, mpi.version.2
        );
        let mut b = self;
        for lib in mpi.frontend_libraries() {
            b = b.file(&format!("{prefix}/lib/{lib}"), 4_500_000);
        }
        b = b
            .exe(&format!("{prefix}/bin/mpiexec"), 900_000)
            .exe(&format!("{prefix}/bin/mpicc"), 30_000)
            .file(&format!("{prefix}/etc/mpiexec.conf"), 400);
        b.label(LABEL_MPI_VENDOR, &vendor)
            .label(LABEL_MPI_VERSION, &version)
            .label(LABEL_MPI_ABI, &abi)
            .commit_layer()
    }

    /// Declare the specialized-network transport this image was built
    /// against (a fabric-aware build, e.g. an MPI compiled with uGNI
    /// support); triggers and gates `netfab` injection.
    pub fn with_net_transport(self, transport: &str, abi_major: u32) -> Self {
        let abi = format!("{transport}:{abi_major}");
        self.label(LABEL_NET_FABRIC, transport)
            .label(LABEL_NET_ABI, &abi)
    }

    /// Install a CUDA toolkit (container side: toolkit + stubs, NOT the
    /// driver libraries — those only exist on GPU hosts).
    pub fn with_cuda_toolkit(self, version: (u32, u32)) -> Self {
        let v = format!("{}.{}", version.0, version.1);
        let prefix = format!("/usr/local/cuda-{v}");
        self.file(&format!("{prefix}/lib64/libcudart.so.{v}"), 500_000)
            .file(&format!("{prefix}/lib64/libcublas.so.{v}"), 60_000_000)
            .file(&format!("{prefix}/lib64/libcufft.so.{v}"), 40_000_000)
            .file(&format!("{prefix}/lib64/libcudnn.so.5.1.5"), 80_000_000)
            .exe(&format!("{prefix}/bin/nvcc"), 20_000_000)
            .label(LABEL_CUDA_VERSION, &v)
            .env("CUDA_HOME", &prefix)
            .commit_layer()
    }

    pub fn build(self) -> Image {
        let b = self.commit_layer();
        let manifest = ImageManifest {
            env: b.env,
            entrypoint: b.entrypoint,
            labels: b.labels,
            layer_digests: b.layers.iter().map(|l| l.digest).collect(),
            files_content: b.files_content,
        };
        Image {
            reference: b.reference,
            manifest,
            layers: b.layers,
        }
    }
}

// ---------------------------------------------------------------------------
// Canned images: the §V evaluation catalog
// ---------------------------------------------------------------------------

/// The exact os-release the §III.B example prints on the Cray XC50.
pub const UBUNTU_XENIAL_OS_RELEASE: &str = "NAME=\"Ubuntu\"\n\
VERSION=\"16.04.2 LTS (Xenial Xerus)\"\n\
ID=ubuntu\n\
ID_LIKE=debian\n\
PRETTY_NAME=\"Ubuntu 16.04.2 LTS\"\n\
VERSION_ID=\"16.04\"\n\
HOME_URL=\"http://www.ubuntu.com/\"\n\
SUPPORT_URL=\"http://help.ubuntu.com/\"\n\
BUG_REPORT_URL=\"http://bugs.launchpad.net/ubuntu/\"\n\
VERSION_CODENAME=xenial\n\
UBUNTU_CODENAME=xenial\n";

/// `docker:ubuntu:xenial` — the §III.B workflow example.
pub fn ubuntu_xenial() -> Image {
    ImageBuilder::new("ubuntu:xenial")
        .base_os(
            "Ubuntu",
            "16.04.2 LTS (Xenial Xerus)",
            "Ubuntu 16.04.2 LTS",
            "ubuntu",
            "xenial",
        )
        .text_file("/etc/os-release", UBUNTU_XENIAL_OS_RELEASE)
        .commit_layer()
        .build()
}

/// NVIDIA's official CUDA image with the SDK samples (Table V: `nbody` is
/// "already available as part of the container image").
pub fn cuda_image() -> Image {
    ImageBuilder::new("nvidia/cuda-image:8.0")
        .base_os(
            "Ubuntu",
            "16.04.2 LTS (Xenial Xerus)",
            "Ubuntu 16.04.2 LTS",
            "ubuntu",
            "xenial",
        )
        .with_cuda_toolkit((8, 0))
        .exe("/usr/local/cuda/samples/bin/deviceQuery", 600_000)
        .exe("/usr/local/cuda/samples/bin/nbody", 800_000)
        .label(LABEL_APP, "cuda-samples")
        .commit_layer()
        .build()
}

/// `tensorflow/tensorflow:1.0.0-devel-gpu-py3` (Table I): Ubuntu 14.04,
/// Python 3.4.3, CUDA 8.0.44, cuDNN 5.1.5, Bazel + TF source.
pub fn tensorflow_image() -> Image {
    ImageBuilder::new("tensorflow/tensorflow:1.0.0-devel-gpu-py3")
        .base_os(
            "Ubuntu",
            "14.04.5 LTS, Trusty Tahr",
            "Ubuntu 14.04.5 LTS",
            "ubuntu",
            "trusty",
        )
        .with_cuda_toolkit((8, 0))
        .bulk_files("/usr/lib/python3.4", 900, 18_000)
        .bulk_files("/usr/local/lib/python3.4/dist-packages/tensorflow", 1200, 90_000)
        .bulk_files("/tensorflow", 800, 25_000)
        .exe("/usr/local/bin/bazel", 90_000_000)
        .exe("/usr/bin/python3", 4_000_000)
        .label(LABEL_APP, "tensorflow-1.0.0")
        .entrypoint(&["/usr/bin/python3"])
        .commit_layer()
        .build()
}

/// The PyFR 1.5.0 image the authors built on the laptop (Table II):
/// Ubuntu 16.04 + Python 3.5.2 + CUDA 8.0.44 + MPICH 3.1.4 + Metis + PyFR.
pub fn pyfr_image() -> Image {
    ImageBuilder::new("pyfr-image:1.5.0")
        .base_os(
            "Ubuntu",
            "16.04.2 LTS (Xenial Xerus)",
            "Ubuntu 16.04.2 LTS",
            "ubuntu",
            "xenial",
        )
        .with_cuda_toolkit((8, 0))
        .with_mpi(&MpiImpl::mpich_3_1_4_container(), "/usr/local/mpich-3.1.4")
        .bulk_files("/usr/lib/python3.5", 950, 18_000)
        .bulk_files("/usr/local/lib/python3.5/dist-packages/pyfr", 220, 30_000)
        .file("/usr/local/lib/libmetis.so.5", 1_800_000)
        .exe("/usr/bin/python3", 4_200_000)
        .exe("/usr/local/bin/pyfr", 3_000)
        .label(LABEL_APP, "pyfr-1.5.0")
        .commit_layer()
        .build()
}

/// OSU micro-benchmark containers A/B/C (Table III/IV): CentOS 7 base,
/// an MPI built from source, OSU 5.3.2 linked against it.
pub fn osu_image(mpi: &MpiImpl, tag: &str) -> Image {
    ImageBuilder::new(&format!("osu-benchmarks:{tag}"))
        .base_os(
            "CentOS Linux",
            "7 (Core)",
            "CentOS Linux 7 (Core)",
            "centos",
            "core",
        )
        .with_mpi(mpi, "/usr/local/mpi")
        .exe("/usr/local/osu/osu_latency", 250_000)
        .exe("/usr/local/osu/osu_bw", 250_000)
        .label(LABEL_APP, "osu-micro-benchmarks-5.3.2")
        .commit_layer()
        .build()
}

/// Container A: MPICH 3.1.4.
pub fn osu_image_a() -> Image {
    osu_image(&MpiImpl::mpich_3_1_4_container(), "mpich-3.1.4")
}

/// Container B: MVAPICH2 2.2.
pub fn osu_image_b() -> Image {
    osu_image(&MpiImpl::mvapich2_2_2_container(), "mvapich2-2.2")
}

/// Container C: Intel MPI 2017 update 1.
pub fn osu_image_c() -> Image {
    osu_image(&MpiImpl::intel_2017_1_container(), "intelmpi-2017.1")
}

/// Pynamic 1.3 image (Fig. 3): python:2.7-slim (Debian Jessie) + MPICH
/// 3.1.4 + the generated shared objects: 495 test modules + 215 utility
/// libraries, ~1850 functions each.
pub fn pynamic_image() -> Image {
    ImageBuilder::new("pynamic:1.3")
        .base_os(
            "Debian GNU/Linux",
            "8 (jessie)",
            "Debian GNU/Linux 8 (jessie)",
            "debian",
            "jessie",
        )
        .with_mpi(&MpiImpl::mpich_3_1_4_container(), "/usr/local/mpich-3.1.4")
        .bulk_files("/usr/lib/python2.7", 700, 15_000)
        .bulk_files(
            "/opt/pynamic/modules",
            crate::apps::pynamic::PYNAMIC_MODULES,
            1_800_000,
        )
        .bulk_files(
            "/opt/pynamic/utils",
            crate::apps::pynamic::PYNAMIC_UTILS,
            1_700_000,
        )
        .exe("/usr/bin/python2.7", 3_800_000)
        .exe("/opt/pynamic/pynamic-pyMPI", 5_200_000)
        .label(LABEL_APP, "pynamic-1.3")
        .commit_layer()
        .build()
}

/// Open MPI image — NOT MPICH-ABI compatible; used by failure-injection
/// tests to show the swap precondition rejecting it.
pub fn openmpi_image() -> Image {
    ImageBuilder::new("osu-benchmarks:openmpi-2.0")
        .base_os(
            "CentOS Linux",
            "7 (Core)",
            "CentOS Linux 7 (Core)",
            "centos",
            "core",
        )
        .with_mpi(&MpiImpl::openmpi_2_0(), "/usr/local/openmpi")
        .exe("/usr/local/osu/osu_latency", 250_000)
        .commit_layer()
        .build()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ubuntu_xenial_prints_paper_os_release() {
        let img = ubuntu_xenial();
        let content = img
            .manifest
            .files_content
            .get("/etc/os-release")
            .expect("os-release content");
        assert!(content.contains("VERSION=\"16.04.2 LTS (Xenial Xerus)\""));
        assert!(content.contains("UBUNTU_CODENAME=xenial"));
    }

    #[test]
    fn osu_containers_carry_their_mpi_labels() {
        let a = osu_image_a();
        assert_eq!(a.label(LABEL_MPI_VENDOR), Some("MPICH"));
        assert_eq!(a.label(LABEL_MPI_VERSION), Some("3.1.4"));
        assert_eq!(a.label(LABEL_MPI_ABI), Some("12:0:0"));
        let b = osu_image_b();
        assert_eq!(b.label(LABEL_MPI_VENDOR), Some("MVAPICH2"));
        let c = osu_image_c();
        assert_eq!(c.label(LABEL_MPI_VENDOR), Some("Intel MPI"));
    }

    #[test]
    fn images_flatten_with_expected_content() {
        let img = pyfr_image();
        let flat = img.flatten().unwrap();
        assert!(flat.exists("/usr/local/mpich-3.1.4/lib/libmpi.so.12"));
        assert!(flat.exists("/usr/local/bin/pyfr"));
        assert!(flat.exists("/usr/local/cuda-8.0/bin/nvcc"));
        assert!(flat.total_size() > 100_000_000);
    }

    #[test]
    fn cuda_image_ships_nbody() {
        let flat = cuda_image().flatten().unwrap();
        assert!(flat.exists("/usr/local/cuda/samples/bin/nbody"));
        assert_eq!(cuda_image().label(LABEL_CUDA_VERSION), Some("8.0"));
    }

    #[test]
    fn pynamic_image_has_710_shared_objects() {
        let flat = pynamic_image().flatten().unwrap();
        let modules = flat.list_dir("/opt/pynamic/modules").unwrap();
        let utils = flat.list_dir("/opt/pynamic/utils").unwrap();
        assert_eq!(modules.len(), 495);
        assert_eq!(utils.len(), 215);
    }

    #[test]
    fn builder_is_deterministic() {
        let a = ubuntu_xenial();
        let b = ubuntu_xenial();
        assert_eq!(a.manifest.layer_digests, b.manifest.layer_digests);
    }

    #[test]
    fn derived_images_share_base_layer_digests() {
        let base = ubuntu_xenial();
        let app_a = ImageBuilder::from_image(&base, "app-a:1.0")
            .bulk_files("/opt/app-a", 40, 2_000_000)
            .build();
        let app_b = ImageBuilder::from_image(&base, "app-b:1.0")
            .bulk_files("/opt/app-b", 40, 2_000_000)
            .build();
        // base layers are shared byte-for-byte ...
        let n_base = base.layers.len();
        for (i, l) in base.layers.iter().enumerate() {
            assert_eq!(app_a.layers[i].digest, l.digest);
            assert_eq!(app_b.layers[i].digest, l.digest);
        }
        // ... the app layers are not
        assert_eq!(app_a.layers.len(), n_base + 1);
        assert_ne!(
            app_a.layers[n_base].digest,
            app_b.layers[n_base].digest
        );
        // derived config carries over
        let flat = app_a.flatten().unwrap();
        assert!(flat.exists("/etc/os-release"));
        assert!(flat.exists("/opt/app-a/f0000"));
    }
}
