//! Docker-style container images (DESIGN.md S5): references, layers with
//! whiteouts, manifests with env/labels/entrypoint, and flattening —
//! "all layers but the last one are discarded" is implemented faithfully
//! as last-writer-wins per path after applying every layer in order.

pub mod builder;

use std::collections::BTreeMap;

use crate::vfs::{VirtualFs, VfsError};

/// `name:tag` image reference. Accepts the `docker:` transport prefix the
/// paper's `shifterimg pull docker:ubuntu:xenial` example uses.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ImageRef {
    pub name: String,
    pub tag: String,
}

impl ImageRef {
    pub fn parse(s: &str) -> Option<ImageRef> {
        let s = s.strip_prefix("docker:").unwrap_or(s);
        if s.is_empty() {
            return None;
        }
        let (name, tag) = match s.rsplit_once(':') {
            Some((n, t)) if !n.is_empty() && !t.is_empty() && !t.contains('/') => {
                (n.to_string(), t.to_string())
            }
            Some(_) => return None,
            None => (s.to_string(), "latest".to_string()),
        };
        Some(ImageRef { name, tag })
    }

    pub fn canonical(&self) -> String {
        format!("{}:{}", self.name, self.tag)
    }
}

/// One image layer: a filesystem delta plus whiteouts (paths the layer
/// deletes from the view assembled so far).
#[derive(Debug, Clone, PartialEq)]
pub struct Layer {
    pub digest: u64,
    pub tree: VirtualFs,
    pub whiteouts: Vec<String>,
}

impl Layer {
    pub fn new(tree: VirtualFs, whiteouts: Vec<String>) -> Layer {
        let mut digest: u64 = 0x811c9dc5811c9dc5;
        for p in tree.paths() {
            for b in p.as_bytes() {
                digest ^= *b as u64;
                digest = digest.wrapping_mul(0x100000001b3);
            }
        }
        for w in &whiteouts {
            for b in w.as_bytes() {
                digest ^= (*b as u64) << 1;
                digest = digest.wrapping_mul(0x100000001b3);
            }
        }
        digest ^= tree.total_size();
        Layer {
            digest,
            tree,
            whiteouts,
        }
    }

    /// Transfer size of the layer (tar.gz over the wire).
    pub fn compressed_bytes(&self) -> u64 {
        (self.tree.total_size() as f64 * 0.5) as u64
    }
}

/// Image metadata (the Docker manifest + config surface we need).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ImageManifest {
    pub env: Vec<(String, String)>,
    pub entrypoint: Vec<String>,
    pub labels: BTreeMap<String, String>,
    pub layer_digests: Vec<u64>,
    /// Retrievable content of small text files (e.g. /etc/os-release) —
    /// the simulation's stand-in for actual file data.
    pub files_content: BTreeMap<String, String>,
}

/// A complete image: manifest + ordered layers (base first).
#[derive(Debug, Clone, PartialEq)]
pub struct Image {
    pub reference: ImageRef,
    pub manifest: ImageManifest,
    pub layers: Vec<Layer>,
}

impl Image {
    /// Apply all layers in order (whiteouts delete), producing the
    /// flattened root filesystem the Gateway converts to squashfs.
    pub fn flatten(&self) -> Result<VirtualFs, VfsError> {
        let mut root = VirtualFs::new();
        for layer in &self.layers {
            for w in &layer.whiteouts {
                // deleting a path that a previous layer never created is
                // legal in the tar format; ignore it.
                let _ = root.remove(w);
            }
            root.graft(&layer.tree, "/", "/")?;
        }
        Ok(root)
    }

    /// Total compressed transfer size (what a pull downloads).
    pub fn transfer_bytes(&self) -> u64 {
        self.layers.iter().map(|l| l.compressed_bytes()).sum()
    }

    pub fn label(&self, key: &str) -> Option<&str> {
        self.manifest.labels.get(key).map(|s| s.as_str())
    }

    /// Environment as the image config declares it.
    pub fn env_map(&self) -> BTreeMap<String, String> {
        self.manifest.env.iter().cloned().collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_references() {
        let r = ImageRef::parse("ubuntu:xenial").unwrap();
        assert_eq!((r.name.as_str(), r.tag.as_str()), ("ubuntu", "xenial"));
        let r = ImageRef::parse("docker:ubuntu:xenial").unwrap();
        assert_eq!(r.canonical(), "ubuntu:xenial");
        let r = ImageRef::parse("tensorflow/tensorflow:1.0.0-devel-gpu-py3")
            .unwrap();
        assert_eq!(r.name, "tensorflow/tensorflow");
        let r = ImageRef::parse("alpine").unwrap();
        assert_eq!(r.tag, "latest");
        assert!(ImageRef::parse("").is_none());
        assert!(ImageRef::parse(":xenial").is_none());
    }

    fn layer_with(files: &[(&str, u64)]) -> Layer {
        let mut t = VirtualFs::new();
        for (i, (p, s)) in files.iter().enumerate() {
            t.add_file(p, *s, i as u64 + 1).unwrap();
        }
        Layer::new(t, vec![])
    }

    #[test]
    fn flatten_is_last_writer_wins() {
        let base = layer_with(&[("/etc/os-release", 100), ("/bin/sh", 50)]);
        let top = layer_with(&[("/etc/os-release", 200)]);
        let img = Image {
            reference: ImageRef::parse("t:1").unwrap(),
            manifest: ImageManifest::default(),
            layers: vec![base, top],
        };
        let flat = img.flatten().unwrap();
        assert_eq!(flat.get("/etc/os-release").unwrap().size(), 200);
        assert!(flat.exists("/bin/sh"));
    }

    #[test]
    fn whiteouts_delete_from_earlier_layers() {
        let base = layer_with(&[("/opt/tool/bin", 10), ("/opt/tool/doc", 5)]);
        let mut top_tree = VirtualFs::new();
        top_tree.add_file("/opt/replacement", 7, 99).unwrap();
        let top = Layer::new(top_tree, vec!["/opt/tool".to_string()]);
        let img = Image {
            reference: ImageRef::parse("t:2").unwrap(),
            manifest: ImageManifest::default(),
            layers: vec![base, top],
        };
        let flat = img.flatten().unwrap();
        assert!(!flat.exists("/opt/tool/bin"));
        assert!(flat.exists("/opt/replacement"));
    }

    #[test]
    fn layer_digests_differ_by_content() {
        let a = layer_with(&[("/a", 1)]);
        let b = layer_with(&[("/b", 1)]);
        assert_ne!(a.digest, b.digest);
    }

    #[test]
    fn transfer_bytes_sum_layers() {
        let img = Image {
            reference: ImageRef::parse("t:3").unwrap(),
            manifest: ImageManifest::default(),
            layers: vec![
                layer_with(&[("/a", 1000)]),
                layer_with(&[("/b", 3000)]),
            ],
        };
        assert_eq!(img.transfer_bytes(), 2000);
    }
}
