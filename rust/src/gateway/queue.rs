//! Image Gateway pull queue — the daemon side of `shifterimg pull`.
//!
//! The real Gateway is an asynchronous service: requests are enqueued,
//! deduplicated (two users pulling the same image share one job), and a
//! worker advances each job through PULLING → EXPANDING → CONVERTING →
//! TRANSFERRING → READY while `shifterimg lookup` reports progress. This
//! module models that lifecycle deterministically: `tick(dt)` advances
//! simulated time, and stage durations come from the same cost models the
//! synchronous `ImageGateway::pull` uses.

use std::collections::{BTreeMap, BTreeSet};

use crate::image::ImageRef;
use crate::registry::Registry;
use crate::sim::{SimClock, SimTime};

use super::{GatewayError, ImageGateway};

/// Lifecycle of a pull job.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PullState {
    /// Waiting for the shard worker.
    Enqueued,
    /// Downloading layers from the registry.
    Pulling,
    /// Expanding and flattening the layer tars.
    Expanding,
    /// Converting the flattened tree to squashfs.
    Converting,
    /// Storing the squashfs on the parallel filesystem.
    Transferring,
    /// Terminal: the image is materialized and servable.
    Ready,
    /// Terminal: the pull failed (see `PullJob::error`).
    Failed,
}

impl PullState {
    /// CLI-facing uppercase state name.
    pub fn name(&self) -> &'static str {
        match self {
            PullState::Enqueued => "ENQUEUED",
            PullState::Pulling => "PULLING",
            PullState::Expanding => "EXPANDING",
            PullState::Converting => "CONVERTING",
            PullState::Transferring => "TRANSFERRING",
            PullState::Ready => "READY",
            PullState::Failed => "FAILED",
        }
    }

    /// Whether the state is final (READY or FAILED).
    pub fn terminal(&self) -> bool {
        matches!(self, PullState::Ready | PullState::Failed)
    }
}

/// One deduplicated pull job: all requesters of a reference share it.
#[derive(Debug, Clone)]
pub struct PullJob {
    /// The image reference being pulled.
    pub reference: ImageRef,
    /// Current lifecycle state.
    pub state: PullState,
    /// Users waiting on this job (dedup: all requesters share it), in
    /// arrival order.
    pub requesters: Vec<String>,
    /// Membership index over `requesters` — keeps absorbing a 10k-node
    /// pull storm O(log n) per request instead of a linear rescan.
    requester_set: BTreeSet<String>,
    /// Remaining seconds in the current stage.
    remaining: f64,
    /// Per-stage durations, computed at enqueue.
    durations: [f64; 4], // pulling, expanding, converting, transferring
    /// Why the job failed, when terminal-failed.
    pub error: Option<String>,
    /// Queue clock instant when the job was first requested.
    pub enqueued_at: SimTime,
    /// Queue clock instant when the worker picked the job up (Enqueued →
    /// Pulling transition; exact within a tick). Fast-failed jobs never
    /// wait.
    pub started_at: Option<SimTime>,
    /// Queue clock instant when the job reached a terminal state (exact
    /// within a tick — the transition moment, not the tick boundary).
    pub completed_at: Option<SimTime>,
}

impl PullJob {
    /// Simulated seconds spent so far across completed stages.
    pub fn stage_durations(&self) -> &[f64; 4] {
        &self.durations
    }

    /// Enqueue-to-READY latency, once terminal.
    pub fn turnaround_secs(&self) -> Option<f64> {
        self.completed_at.map(|t| t - self.enqueued_at)
    }

    /// Time the job sat behind other work before its worker started it —
    /// the queue-wait component of the turnaround, surfaced by
    /// `cluster-status` and the launch report.
    pub fn queue_wait_secs(&self) -> Option<f64> {
        self.started_at.map(|t| t - self.enqueued_at)
    }
}

/// The queued gateway daemon: wraps the synchronous gateway and holds the
/// job table. One worker: jobs run one at a time in FIFO order (the real
/// gateway serializes conversions to bound PFS load).
pub struct PullQueue {
    jobs: BTreeMap<ImageRef, PullJob>,
    fifo: Vec<ImageRef>,
    clock: SimClock,
    /// Every `request()` ever made (absorbed ones included) — the
    /// numerator of the coalescing ratio.
    requests: u64,
}

impl Default for PullQueue {
    fn default() -> Self {
        Self::new()
    }
}

impl PullQueue {
    /// Empty queue at simulated time zero.
    pub fn new() -> PullQueue {
        PullQueue {
            jobs: BTreeMap::new(),
            fifo: Vec::new(),
            clock: SimClock::new(),
            requests: 0,
        }
    }

    /// Current simulated clock instant.
    pub fn now(&self) -> SimTime {
        self.clock.now()
    }

    /// Exact simulated seconds of worker time left until every queued
    /// job is terminal (one FIFO worker: the sum over non-terminal
    /// jobs of their remaining stage work). This is the drain size the
    /// virtual-time kernel ticks by instead of a magic huge constant.
    pub fn pending_secs(&self) -> f64 {
        self.fifo
            .iter()
            .map(|r| {
                let j = &self.jobs[r];
                match j.state {
                    PullState::Enqueued => j.durations.iter().sum(),
                    PullState::Pulling => {
                        j.remaining
                            + j.durations[1]
                            + j.durations[2]
                            + j.durations[3]
                    }
                    PullState::Expanding => {
                        j.remaining + j.durations[2] + j.durations[3]
                    }
                    PullState::Converting => j.remaining + j.durations[3],
                    PullState::Transferring => j.remaining,
                    PullState::Ready | PullState::Failed => 0.0,
                }
            })
            .sum()
    }

    /// Enqueue a pull request from `user`. Dedup: an in-flight or READY
    /// job for the same reference absorbs the request.
    pub fn request(
        &mut self,
        gateway: &ImageGateway,
        registry: &Registry,
        reference: &str,
        user: &str,
    ) -> Result<PullState, GatewayError> {
        self.request_with_dedup(gateway, registry, reference, user, 0.0)
    }

    /// [`PullQueue::request`] with a content-dedup discount: when the
    /// caller (the sharded cluster's chunked CAS) already stores
    /// `shared_fraction` of the image's bytes, the registry download and
    /// the PFS transfer shrink to the miss fraction — only new chunks
    /// cross the wire. Expansion and conversion still touch every byte
    /// (the squashfs is rebuilt whole). `shared_fraction` is clamped to
    /// `[0, 1]`; 0.0 reproduces the classic full-transfer pull exactly.
    pub fn request_with_dedup(
        &mut self,
        gateway: &ImageGateway,
        registry: &Registry,
        reference: &str,
        user: &str,
        shared_fraction: f64,
    ) -> Result<PullState, GatewayError> {
        let r = ImageRef::parse(reference)
            .ok_or_else(|| GatewayError::NotPulled(reference.to_string()))?;
        self.requests += 1;
        if let Some(job) = self.jobs.get_mut(&r) {
            if job.requester_set.insert(user.to_string()) {
                job.requesters.push(user.to_string());
            }
            return Ok(job.state);
        }
        // validate against the registry now — a missing image fails fast
        let image = match registry.lookup(reference) {
            Ok(i) => i,
            Err(e) => {
                let job = PullJob {
                    reference: r.clone(),
                    state: PullState::Failed,
                    requesters: vec![user.to_string()],
                    requester_set: BTreeSet::from([user.to_string()]),
                    remaining: 0.0,
                    durations: [0.0; 4],
                    error: Some(e.to_string()),
                    enqueued_at: self.clock.now(),
                    started_at: Some(self.clock.now()),
                    completed_at: Some(self.clock.now()),
                };
                self.jobs.insert(r.clone(), job);
                return Ok(PullState::Failed);
            }
        };
        let flat_bytes = image
            .flatten()
            .map(|f| f.total_size())
            .unwrap_or_default();
        let miss = 1.0 - shared_fraction.clamp(0.0, 1.0);
        let durations = [
            registry.download_secs(image, &[]) * miss,
            flat_bytes as f64 / 300e6,
            flat_bytes as f64 / 150e6,
            gateway
                .pfs()
                .bulk_read_secs((flat_bytes as f64 * 0.45) as u64, 1)
                * miss,
        ];
        let job = PullJob {
            reference: r.clone(),
            state: PullState::Enqueued,
            requesters: vec![user.to_string()],
            requester_set: BTreeSet::from([user.to_string()]),
            remaining: 0.0,
            durations,
            error: None,
            enqueued_at: self.clock.now(),
            started_at: None,
            completed_at: None,
        };
        self.jobs.insert(r.clone(), job);
        self.fifo.push(r);
        Ok(PullState::Enqueued)
    }

    /// Advance simulated time by `dt` seconds, progressing the active job
    /// through its stages; when a job completes, the image materializes on
    /// the gateway via the synchronous path.
    pub fn tick(
        &mut self,
        gateway: &mut ImageGateway,
        registry: &Registry,
        mut dt: f64,
    ) {
        self.clock.advance(dt);
        while dt > 0.0 {
            // find the first non-terminal job in FIFO order
            let Some(r) = self
                .fifo
                .iter()
                .find(|r| !self.jobs[r].state.terminal())
                .cloned()
            else {
                return;
            };
            // The find above indexed self.jobs[r], so the key is present.
            let Some(job) = self.jobs.get_mut(&r) else {
                return;
            };
            if job.state == PullState::Enqueued {
                job.state = PullState::Pulling;
                job.remaining = job.durations[0];
                // `dt` of the tick budget is unspent, so the worker picked
                // the job up exactly at clock - dt.
                job.started_at = Some(self.clock.now() - dt);
            }
            if dt < job.remaining {
                job.remaining -= dt;
                return;
            }
            dt -= job.remaining;
            job.remaining = 0.0;
            job.state = match job.state {
                PullState::Pulling => {
                    job.remaining = job.durations[1];
                    PullState::Expanding
                }
                PullState::Expanding => {
                    job.remaining = job.durations[2];
                    PullState::Converting
                }
                PullState::Converting => {
                    job.remaining = job.durations[3];
                    PullState::Transferring
                }
                PullState::Transferring => {
                    // materialize on the gateway; `dt` of the budget is
                    // still unspent, so the transition happened exactly at
                    // clock - dt.
                    job.completed_at = Some(self.clock.now() - dt);
                    match gateway.pull(registry, &r.canonical()) {
                        Ok(_) => PullState::Ready,
                        Err(e) => {
                            job.error = Some(e.to_string());
                            PullState::Failed
                        }
                    }
                }
                s => s,
            };
        }
    }

    /// `shifterimg lookup` — job status.
    pub fn status(&self, reference: &str) -> Option<&PullJob> {
        let r = ImageRef::parse(reference)?;
        self.jobs.get(&r)
    }

    /// Jobs in a given state.
    pub fn in_state(&self, state: PullState) -> Vec<&PullJob> {
        self.jobs.values().filter(|j| j.state == state).collect()
    }

    /// All jobs (terminal and in-flight), in reference order.
    pub fn jobs(&self) -> impl Iterator<Item = &PullJob> {
        self.jobs.values()
    }

    /// How many `request()` calls this queue has absorbed over its
    /// lifetime, coalesced or not. Together with `jobs().count()` this
    /// yields the dedup ratio: N requesters per unique reference collapse
    /// into one job.
    pub fn request_count(&self) -> u64 {
        self.requests
    }

    /// Jobs the worker has not finished yet (the shard's backlog depth).
    pub fn backlog(&self) -> usize {
        self.jobs.values().filter(|j| !j.state.terminal()).count()
    }

    /// The job the single worker is currently advancing, if any.
    pub fn active(&self) -> Option<&PullJob> {
        self.fifo
            .iter()
            .find(|r| !self.jobs[*r].state.terminal())
            .map(|r| &self.jobs[r])
    }

    /// True when every enqueued job has reached a terminal state.
    pub fn drained(&self) -> bool {
        self.jobs.values().all(|j| j.state.terminal())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pfs::LustreFs;

    fn setup() -> (ImageGateway, Registry, PullQueue) {
        (
            ImageGateway::new(LustreFs::piz_daint()),
            Registry::dockerhub(),
            PullQueue::new(),
        )
    }

    #[test]
    fn job_walks_the_full_lifecycle() {
        let (mut gw, reg, mut q) = setup();
        let s = q.request(&gw, &reg, "ubuntu:xenial", "alice").unwrap();
        assert_eq!(s, PullState::Enqueued);
        // tiny ticks: observe intermediate states
        let mut seen = vec![s];
        for _ in 0..10_000 {
            q.tick(&mut gw, &reg, 0.05);
            let st = q.status("ubuntu:xenial").unwrap().state;
            if seen.last() != Some(&st) {
                seen.push(st);
            }
            if st.terminal() {
                break;
            }
        }
        // the observed states are an ordered subsequence of the lifecycle
        // (very short stages — e.g. the PFS transfer of a small image —
        // can complete within one tick and go unobserved)
        let lifecycle = [
            PullState::Enqueued,
            PullState::Pulling,
            PullState::Expanding,
            PullState::Converting,
            PullState::Transferring,
            PullState::Ready,
        ];
        let mut cursor = 0;
        for st in &seen {
            cursor += lifecycle[cursor..]
                .iter()
                .position(|l| l == st)
                .expect("state out of lifecycle order");
        }
        assert_eq!(*seen.last().unwrap(), PullState::Ready);
        assert!(seen.len() >= 4, "observed too few states: {seen:?}");
        // every stage had a positive modeled duration
        let job = q.status("ubuntu:xenial").unwrap();
        assert!(job.stage_durations().iter().all(|d| *d > 0.0));
        // image is now usable by the runtime
        assert!(gw.lookup("ubuntu:xenial").is_ok());
    }

    #[test]
    fn concurrent_requests_deduplicate() {
        let (mut gw, reg, mut q) = setup();
        q.request(&gw, &reg, "ubuntu:xenial", "alice").unwrap();
        q.request(&gw, &reg, "ubuntu:xenial", "bob").unwrap();
        q.request(&gw, &reg, "ubuntu:xenial", "alice").unwrap();
        let job = q.status("ubuntu:xenial").unwrap();
        assert_eq!(job.requesters, vec!["alice", "bob"]);
        q.tick(&mut gw, &reg, 1e6);
        assert_eq!(q.status("ubuntu:xenial").unwrap().state, PullState::Ready);
        assert_eq!(gw.list().len(), 1); // processed once
    }

    #[test]
    fn dedup_both_users_observe_the_same_lifecycle() {
        // Two users pulling the same reference share one job: the state
        // transitions each observes via `shifterimg lookup` are identical,
        // and the backend processes the image exactly once.
        let (mut gw, reg, mut q) = setup();
        let s_alice = q.request(&gw, &reg, "ubuntu:xenial", "alice").unwrap();
        let s_bob = q.request(&gw, &reg, "ubuntu:xenial", "bob").unwrap();
        assert_eq!(s_alice, PullState::Enqueued);
        assert_eq!(s_bob, PullState::Enqueued); // absorbed into the same job
        assert_eq!(q.backlog(), 1);

        let mut alice_saw = vec![s_alice];
        let mut bob_saw = vec![s_bob];
        for _ in 0..10_000 {
            q.tick(&mut gw, &reg, 0.05);
            // both poll the same reference, as the CLI would
            let st = q.status("ubuntu:xenial").unwrap().state;
            if alice_saw.last() != Some(&st) {
                alice_saw.push(st);
            }
            let st = q.status("ubuntu:xenial").unwrap().state;
            if bob_saw.last() != Some(&st) {
                bob_saw.push(st);
            }
            if st.terminal() {
                break;
            }
        }
        assert_eq!(alice_saw, bob_saw);
        assert_eq!(*alice_saw.last().unwrap(), PullState::Ready);
        assert!(alice_saw.len() >= 4, "observed too few states: {alice_saw:?}");

        let job = q.status("ubuntu:xenial").unwrap();
        assert_eq!(job.requesters, vec!["alice", "bob"]);
        assert_eq!(gw.list().len(), 1); // one job, one materialization
        // both waited the same turnaround — the job's, not per-user
        let turnaround = job.turnaround_secs().unwrap();
        assert!(turnaround > 0.0);
        assert!(job.completed_at.unwrap() <= q.now());
        assert!(q.drained());
    }

    #[test]
    fn completion_time_is_exact_within_a_coarse_tick() {
        // one huge tick: completed_at must be the transition moment (the
        // sum of the stage durations), not the tick boundary
        let (mut gw, reg, mut q) = setup();
        q.request(&gw, &reg, "ubuntu:xenial", "u").unwrap();
        q.tick(&mut gw, &reg, 1e6);
        let job = q.status("ubuntu:xenial").unwrap();
        let expected: f64 = job.stage_durations().iter().sum();
        let got = job.completed_at.unwrap().as_secs_f64();
        assert!(
            (got - expected).abs() < 1e-6,
            "completed_at={got} expected={expected}"
        );
        assert_eq!(q.now().as_secs_f64(), 1e6);
    }

    #[test]
    fn fifo_ordering_one_worker() {
        let (mut gw, reg, mut q) = setup();
        q.request(&gw, &reg, "ubuntu:xenial", "u").unwrap();
        q.request(&gw, &reg, "pynamic:1.3", "u").unwrap();
        // advance enough to finish the first but not the (huge) second
        q.tick(&mut gw, &reg, 3.0);
        assert_eq!(q.status("ubuntu:xenial").unwrap().state, PullState::Ready);
        assert!(!q.status("pynamic:1.3").unwrap().state.terminal());
        q.tick(&mut gw, &reg, 1e6);
        assert_eq!(q.status("pynamic:1.3").unwrap().state, PullState::Ready);
    }

    #[test]
    fn queue_wait_reflects_fifo_position() {
        let (mut gw, reg, mut q) = setup();
        q.request(&gw, &reg, "ubuntu:xenial", "u").unwrap();
        q.request(&gw, &reg, "pynamic:1.3", "u").unwrap();
        q.tick(&mut gw, &reg, 1e6);
        let first = q.status("ubuntu:xenial").unwrap();
        let second = q.status("pynamic:1.3").unwrap();
        // the first job starts immediately; the second waits exactly as
        // long as the first took end to end (one worker, FIFO)
        assert!(first.queue_wait_secs().unwrap().abs() < 1e-9);
        let first_total: f64 = first.stage_durations().iter().sum();
        let wait = second.queue_wait_secs().unwrap();
        assert!(
            (wait - first_total).abs() < 1e-6,
            "wait={wait} expected={first_total}"
        );
        // wait + own processing = turnaround
        let own: f64 = second.stage_durations().iter().sum();
        let turnaround = second.turnaround_secs().unwrap();
        assert!((turnaround - (wait + own)).abs() < 1e-6);
    }

    #[test]
    fn missing_image_fails_fast_with_error() {
        let (gw, reg, mut q) = setup();
        let s = q.request(&gw, &reg, "nope:missing", "u").unwrap();
        assert_eq!(s, PullState::Failed);
        let job = q.status("nope:missing").unwrap();
        assert!(job.error.as_ref().unwrap().contains("not found"));
    }

    #[test]
    fn state_names_for_cli() {
        assert_eq!(PullState::Converting.name(), "CONVERTING");
        assert!(PullState::Ready.terminal());
        assert!(!PullState::Pulling.terminal());
    }
}
