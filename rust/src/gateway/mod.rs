//! The Image Gateway (§III, DESIGN.md S4): pulls images from a remote
//! registry, expands and flattens them, converts to squashfs and stores
//! the result on the parallel filesystem, "in a location accessible
//! system wide". Pulls are idempotent per content digest; the gateway can
//! be queried for available images.

pub mod queue;

pub use queue::{PullJob, PullQueue, PullState};

use std::collections::BTreeMap;

use crate::image::{ImageManifest, ImageRef};
use crate::pfs::LustreFs;
use crate::registry::{Registry, RegistryError};
use crate::vfs::SquashFs;

/// What can go wrong between a pull request and a runnable image.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum GatewayError {
    /// The remote registry rejected the request (unknown image, …).
    #[error(transparent)]
    Registry(#[from] RegistryError),
    /// The runtime asked for an image nobody pulled yet.
    #[error("image not pulled: {0} (run `shifterimg pull {0}`)")]
    NotPulled(String),
    /// Layer flattening failed while expanding the image.
    #[error("flatten failed: {0}")]
    Flatten(#[from] crate::vfs::VfsError),
}

/// A gateway-processed image, ready for the Runtime.
#[derive(Debug, Clone)]
pub struct GatewayImage {
    /// Parsed image reference (name + tag).
    pub reference: ImageRef,
    /// Docker-style manifest carried over from the registry.
    pub manifest: ImageManifest,
    /// The flattened, squashfs-converted filesystem.
    pub squashfs: SquashFs,
    /// PFS path where the squashfs file lives.
    pub pfs_path: String,
}

/// Timing breakdown of one pull (reported by `shifterimg pull`).
#[derive(Debug, Clone, PartialEq)]
pub struct PullReport {
    /// Canonical reference that was pulled.
    pub reference: String,
    /// true if the pull was satisfied from the digest cache.
    pub cached: bool,
    /// Registry download time (layer-cache-aware).
    pub download_secs: f64,
    /// Tar expansion + flatten time.
    pub expand_secs: f64,
    /// mksquashfs conversion time.
    pub convert_secs: f64,
    /// PFS store time.
    pub store_secs: f64,
}

impl PullReport {
    /// End-to-end pull latency (sum of the four stages).
    pub fn total_secs(&self) -> f64 {
        self.download_secs + self.expand_secs + self.convert_secs + self.store_secs
    }
}

/// Rates for the gateway's local processing steps.
const EXPAND_BYTES_PER_SEC: f64 = 300e6; // tar extraction
const SQUASH_BYTES_PER_SEC: f64 = 150e6; // mksquashfs compression

/// Anything the Shifter runtime can resolve images against: the single
/// synchronous `ImageGateway`, or `distrib::DistributionFabric`. The
/// runtime stays agnostic of where the squashfs actually lives.
pub trait ImageSource {
    /// Look up a processed image by reference.
    fn resolve(&self, reference: &str) -> Result<&GatewayImage, GatewayError>;

    /// Metadata round-trip cost of the resolution (MDS lookup or shard
    /// index query), charged to the ResolveImage stage.
    fn resolve_latency_secs(&self) -> f64;

    /// Node-side cost of materializing the squashfs on `node` with
    /// `concurrent_nodes` peers starting simultaneously. `None` defers to
    /// the runtime's host-profile PFS model (the classic single-gateway
    /// path); a distributed source answers from its node-cache model.
    fn node_fetch_secs(
        &self,
        image: &GatewayImage,
        node: usize,
        concurrent_nodes: u64,
    ) -> Option<f64>;

    /// Lazy-pull split of the node fetch: `(start_ready_secs,
    /// streamed_tail_secs)`. The first half blocks the container's
    /// prepare stage (metadata + first-read chunks); the second streams
    /// during execution and is charged to the execute stage. Sources
    /// without lazy pulling charge everything up front — the default
    /// returns `(node_fetch_secs, 0.0)`.
    fn node_fetch_split(
        &self,
        image: &GatewayImage,
        node: usize,
        concurrent_nodes: u64,
    ) -> Option<(f64, f64)> {
        self.node_fetch_secs(image, node, concurrent_nodes)
            .map(|secs| (secs, 0.0))
    }
}

/// The single synchronous Image Gateway (§III): pulls, flattens,
/// converts and stores images, then serves lookups to the Runtime.
///
/// ```
/// use shifter_rs::pfs::LustreFs;
/// use shifter_rs::{ImageGateway, Registry};
///
/// let registry = Registry::dockerhub();
/// let mut gateway = ImageGateway::new(LustreFs::piz_daint());
/// let report = gateway.pull(&registry, "docker:ubuntu:xenial").unwrap();
/// assert!(!report.cached && report.total_secs() > 0.0);
/// assert!(gateway.lookup("ubuntu:xenial").is_ok());
/// ```
pub struct ImageGateway {
    images: BTreeMap<ImageRef, GatewayImage>,
    /// Content-addressed layer cache (digests already downloaded).
    layer_cache: Vec<u64>,
    pfs: LustreFs,
}

impl ImageGateway {
    /// Gateway storing to (and costing against) the given PFS.
    pub fn new(pfs: LustreFs) -> ImageGateway {
        ImageGateway {
            images: BTreeMap::new(),
            layer_cache: Vec::new(),
            pfs,
        }
    }

    /// `shifterimg pull <ref>` — the full §III.A first stage.
    pub fn pull(
        &mut self,
        registry: &Registry,
        reference: &str,
    ) -> Result<PullReport, GatewayError> {
        let image = registry.lookup(reference)?;
        let key = image.reference.clone();

        // idempotence: same layer digests already processed -> cache hit
        if let Some(existing) = self.images.get(&key) {
            if existing.manifest.layer_digests == image.manifest.layer_digests {
                return Ok(PullReport {
                    reference: key.canonical(),
                    cached: true,
                    download_secs: 0.0,
                    expand_secs: 0.0,
                    convert_secs: 0.0,
                    store_secs: 0.0,
                });
            }
        }

        let download_secs = registry.download_secs(image, &self.layer_cache);
        for l in &image.layers {
            if !self.layer_cache.contains(&l.digest) {
                self.layer_cache.push(l.digest);
            }
        }

        // expand + flatten ("all layers but the last one are discarded")
        let flat = image.flatten()?;
        let raw_bytes = flat.total_size();
        let expand_secs = raw_bytes as f64 / EXPAND_BYTES_PER_SEC;

        // convert to squashfs
        let squashfs = SquashFs::create(&flat);
        let convert_secs = raw_bytes as f64 / SQUASH_BYTES_PER_SEC;

        // store on the parallel filesystem
        let store_secs = self.pfs.bulk_read_secs(squashfs.compressed_bytes, 1);
        let pfs_path = format!(
            "/pfs/shifter/images/{}-{:016x}.squashfs",
            key.name.replace('/', "_"),
            squashfs.digest
        );

        self.images.insert(
            key.clone(),
            GatewayImage {
                reference: key.clone(),
                manifest: image.manifest.clone(),
                squashfs,
                pfs_path,
            },
        );

        Ok(PullReport {
            reference: key.canonical(),
            cached: false,
            download_secs,
            expand_secs,
            convert_secs,
            store_secs,
        })
    }

    /// `shifterimg images` — list processed images.
    pub fn list(&self) -> Vec<String> {
        self.images.keys().map(|r| r.canonical()).collect()
    }

    /// Look up an image for the Runtime.
    pub fn lookup(&self, reference: &str) -> Result<&GatewayImage, GatewayError> {
        let r = ImageRef::parse(reference)
            .ok_or_else(|| GatewayError::NotPulled(reference.to_string()))?;
        self.images
            .get(&r)
            .ok_or_else(|| GatewayError::NotPulled(r.canonical()))
    }

    /// The parallel filesystem this gateway stores to.
    pub fn pfs(&self) -> &LustreFs {
        &self.pfs
    }
}

impl ImageSource for ImageGateway {
    fn resolve(&self, reference: &str) -> Result<&GatewayImage, GatewayError> {
        self.lookup(reference)
    }

    fn resolve_latency_secs(&self) -> f64 {
        self.pfs.mds.base_latency_us * 1e-6
    }

    fn node_fetch_secs(
        &self,
        _image: &GatewayImage,
        _node: usize,
        _concurrent_nodes: u64,
    ) -> Option<f64> {
        None // runtime applies its host-profile PFS contention model
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::registry::Registry;

    fn gw() -> ImageGateway {
        ImageGateway::new(LustreFs::piz_daint())
    }

    #[test]
    fn pull_processes_and_lists() {
        let reg = Registry::dockerhub();
        let mut g = gw();
        let rep = g.pull(&reg, "docker:ubuntu:xenial").unwrap();
        assert!(!rep.cached);
        assert!(rep.download_secs > 0.0);
        assert!(rep.convert_secs > 0.0);
        assert_eq!(g.list(), vec!["ubuntu:xenial"]);
        let gi = g.lookup("ubuntu:xenial").unwrap();
        assert!(gi.squashfs.file_count() > 100);
        assert!(gi.pfs_path.starts_with("/pfs/shifter/images/"));
    }

    #[test]
    fn second_pull_is_cached() {
        let reg = Registry::dockerhub();
        let mut g = gw();
        g.pull(&reg, "ubuntu:xenial").unwrap();
        let rep = g.pull(&reg, "ubuntu:xenial").unwrap();
        assert!(rep.cached);
        assert_eq!(rep.total_secs(), 0.0);
    }

    #[test]
    fn updated_tag_is_reprocessed() {
        let mut reg = Registry::dockerhub();
        let mut g = gw();
        g.pull(&reg, "ubuntu:xenial").unwrap();
        // author pushes an updated image under the same tag
        let mut img = crate::image::builder::ubuntu_xenial();
        let mut extra = crate::vfs::VirtualFs::new();
        extra.add_file("/etc/new-file", 10, 42).unwrap();
        img.layers.push(crate::image::Layer::new(extra, vec![]));
        img.manifest.layer_digests =
            img.layers.iter().map(|l| l.digest).collect();
        reg.push(img);
        let rep = g.pull(&reg, "ubuntu:xenial").unwrap();
        assert!(!rep.cached);
        // shared base layers came from the cache: only the delta downloads
        assert!(rep.download_secs < 0.5, "{}", rep.download_secs);
    }

    #[test]
    fn lookup_unpulled_fails_with_hint() {
        let g = gw();
        let err = g.lookup("ubuntu:xenial").unwrap_err();
        assert!(err.to_string().contains("shifterimg pull"));
    }

    #[test]
    fn squashfs_is_smaller_than_flat_image() {
        let reg = Registry::dockerhub();
        let mut g = gw();
        g.pull(&reg, "pyfr-image:1.5.0").unwrap();
        let gi = g.lookup("pyfr-image:1.5.0").unwrap();
        assert!(gi.squashfs.compressed_bytes < gi.squashfs.original_bytes);
    }
}
