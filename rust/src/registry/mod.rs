//! Remote Docker registry model (DESIGN.md S5) — the hub.docker.com
//! stand-in. Holds pushed images keyed by reference; pulls are digest-aware
//! (unchanged layers are not re-downloaded) and metered by a WAN bandwidth
//! model so the Gateway's pull reports carry realistic transfer times.

use std::collections::BTreeMap;

use crate::image::{builder, Image, ImageRef};

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum RegistryError {
    #[error("image not found in registry: {0}")]
    NotFound(String),
    #[error("invalid image reference: {0}")]
    BadReference(String),
}

/// The remote registry.
#[derive(Debug, Default)]
pub struct Registry {
    images: BTreeMap<ImageRef, Image>,
    /// WAN bandwidth between the HPC center and the registry (bytes/s).
    pub download_bytes_per_sec: f64,
    /// Per-layer round-trip overhead (manifest + blob HEAD requests).
    pub per_layer_overhead_s: f64,
}

impl Registry {
    pub fn new() -> Registry {
        Registry {
            images: BTreeMap::new(),
            download_bytes_per_sec: 80e6, // ~640 Mbit/s center uplink
            per_layer_overhead_s: 0.35,
        }
    }

    /// A registry preloaded with every image the paper's evaluation pulls.
    pub fn dockerhub() -> Registry {
        let mut r = Registry::new();
        for img in [
            builder::ubuntu_xenial(),
            builder::cuda_image(),
            builder::tensorflow_image(),
            builder::pyfr_image(),
            builder::osu_image_a(),
            builder::osu_image_b(),
            builder::osu_image_c(),
            builder::pynamic_image(),
            builder::openmpi_image(),
        ] {
            r.push(img);
        }
        r
    }

    /// `docker push`: overwrite-by-reference, as Docker Hub does for tags.
    pub fn push(&mut self, image: Image) {
        self.images.insert(image.reference.clone(), image);
    }

    pub fn lookup(&self, reference: &str) -> Result<&Image, RegistryError> {
        let r = ImageRef::parse(reference)
            .ok_or_else(|| RegistryError::BadReference(reference.into()))?;
        self.images
            .get(&r)
            .ok_or_else(|| RegistryError::NotFound(r.canonical()))
    }

    pub fn list(&self) -> Vec<String> {
        self.images.keys().map(|r| r.canonical()).collect()
    }

    pub fn len(&self) -> usize {
        self.images.len()
    }

    pub fn is_empty(&self) -> bool {
        self.images.is_empty()
    }

    /// Seconds to download the layers of `image` that are not already in
    /// `have_layers` (content-addressed cache).
    pub fn download_secs(&self, image: &Image, have_layers: &[u64]) -> f64 {
        let mut secs = 0.0;
        for layer in &image.layers {
            if have_layers.contains(&layer.digest) {
                continue;
            }
            secs += self.per_layer_overhead_s
                + layer.compressed_bytes() as f64 / self.download_bytes_per_sec;
        }
        secs
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dockerhub_has_the_evaluation_catalog() {
        let r = Registry::dockerhub();
        assert!(r.lookup("ubuntu:xenial").is_ok());
        assert!(r.lookup("docker:ubuntu:xenial").is_ok()); // transport prefix
        assert!(r.lookup("tensorflow/tensorflow:1.0.0-devel-gpu-py3").is_ok());
        assert!(r.lookup("pyfr-image:1.5.0").is_ok());
        assert!(r.lookup("osu-benchmarks:mpich-3.1.4").is_ok());
        assert!(r.lookup("pynamic:1.3").is_ok());
        assert!(r.lookup("nope:missing").is_err());
    }

    #[test]
    fn push_then_lookup() {
        let mut r = Registry::new();
        assert!(r.is_empty());
        r.push(builder::ubuntu_xenial());
        assert_eq!(r.len(), 1);
        assert_eq!(
            r.lookup("ubuntu:xenial").unwrap().reference.canonical(),
            "ubuntu:xenial"
        );
    }

    #[test]
    fn download_time_scales_with_size_and_caching() {
        let r = Registry::dockerhub();
        let tf = r.lookup("tensorflow/tensorflow:1.0.0-devel-gpu-py3").unwrap();
        let full = r.download_secs(tf, &[]);
        assert!(full > 1.0, "tf image should take seconds: {full}");
        // all layers cached -> free
        let digests: Vec<u64> = tf.layers.iter().map(|l| l.digest).collect();
        assert_eq!(r.download_secs(tf, &digests), 0.0);
        // partial cache: cheaper than full
        let partial = r.download_secs(tf, &digests[..1]);
        assert!(partial < full);
    }

    #[test]
    fn bad_reference_rejected() {
        let r = Registry::dockerhub();
        assert!(matches!(
            r.lookup(""),
            Err(RegistryError::BadReference(_))
        ));
    }
}
