//! NVIDIA driver model: device files, driver libraries, CUDA compatibility.
//!
//! §IV.A's two prerequisites — "the host system needs to have CUDA-enabled
//! GPUs, and the nvidia-uvm GPU driver has to be loaded prior to Shifter's
//! execution" — are modeled here, plus the driver-library inventory the
//! runtime bind-mounts into containers and the PTX forward-compatibility
//! rule (§II-B2) that makes container CUDA code runnable against a newer
//! host driver.

use super::device::GpuModel;

/// The driver libraries §IV.A enumerates for bind-mounting.
pub const DRIVER_LIBRARIES: [&str; 7] = [
    "libcuda.so",
    "libnvidia-compiler.so",
    "libnvidia-ptxjitcompiler.so",
    "libnvidia-encode.so",
    "libnvidia-ml.so",
    "libnvidia-fatbinaryloader.so",
    "libnvidia-opencl.so",
];

/// NVIDIA binaries brought into the container (§IV.A: "at this stage only
/// ... nvidia-smi").
pub const DRIVER_BINARIES: [&str; 1] = ["nvidia-smi"];

#[derive(Debug, Clone)]
pub struct NvidiaDriver {
    /// e.g. (375, 66)
    pub version: (u32, u32),
    /// nvidia-uvm kernel module loaded? (prerequisite for GPU support)
    pub uvm_loaded: bool,
    /// Boards installed on the node, in enumeration order.
    pub boards: Vec<GpuModel>,
}

impl NvidiaDriver {
    pub fn new(version: (u32, u32), boards: Vec<GpuModel>) -> Self {
        NvidiaDriver {
            version,
            uvm_loaded: true,
            boards,
        }
    }

    /// Total CUDA devices exposed (a K80 board exposes 2).
    pub fn cuda_device_count(&self) -> u32 {
        self.boards.iter().map(|b| b.chips).sum()
    }

    /// CUDA devices in enumeration order: (global_id, board, chip_of_board).
    pub fn enumerate(&self) -> Vec<(u32, &GpuModel, u32)> {
        let mut out = Vec::new();
        let mut id = 0;
        for b in &self.boards {
            for chip in 0..b.chips {
                out.push((id, b, chip));
                id += 1;
            }
        }
        out
    }

    /// Device files the runtime must expose inside the container.
    pub fn device_files(&self, visible: &[u32]) -> Vec<String> {
        let mut files: Vec<String> = visible
            .iter()
            .map(|id| format!("/dev/nvidia{id}"))
            .collect();
        files.push("/dev/nvidiactl".to_string());
        files.push("/dev/nvidia-uvm".to_string());
        files
    }

    /// Versioned library file names as they exist on the host
    /// (e.g. `libcuda.so.375.66`).
    pub fn library_files(&self) -> Vec<String> {
        DRIVER_LIBRARIES
            .iter()
            .map(|l| format!("{l}.{}.{}", self.version.0, self.version.1))
            .collect()
    }

    /// Minimum driver major version required by a CUDA toolkit (the table
    /// behind PTX forward compatibility: a container built with CUDA X runs
    /// iff the host driver is new enough for X).
    pub fn min_driver_for_cuda(cuda: (u32, u32)) -> u32 {
        match cuda {
            (8, _) => 367,
            (7, 5) => 352,
            (7, 0) => 346,
            (6, 5) => 340,
            (6, 0) => 331,
            _ => 304,
        }
    }

    /// PTX forward compatibility: can a container built against `cuda`
    /// toolkit run on this driver?
    pub fn supports_cuda(&self, cuda: (u32, u32)) -> bool {
        self.version.0 >= Self::min_driver_for_cuda(cuda)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::GpuModel;

    fn cluster_node() -> NvidiaDriver {
        NvidiaDriver::new(
            (352, 99),
            vec![GpuModel::tesla_k40m(), GpuModel::tesla_k80()],
        )
    }

    #[test]
    fn k80_contributes_two_devices() {
        let d = cluster_node();
        assert_eq!(d.cuda_device_count(), 3);
        let e = d.enumerate();
        assert_eq!(e.len(), 3);
        assert_eq!(e[0].1.name, "Tesla K40m");
        assert_eq!(e[1].1.name, "Tesla K80");
        assert_eq!(e[2].1.name, "Tesla K80");
        assert_eq!((e[1].2, e[2].2), (0, 1));
    }

    #[test]
    fn device_files_cover_visible_plus_control() {
        let d = cluster_node();
        let files = d.device_files(&[0, 2]);
        assert!(files.contains(&"/dev/nvidia0".to_string()));
        assert!(files.contains(&"/dev/nvidia2".to_string()));
        assert!(files.contains(&"/dev/nvidiactl".to_string()));
        assert!(files.contains(&"/dev/nvidia-uvm".to_string()));
        assert_eq!(files.len(), 4);
    }

    #[test]
    fn versioned_library_names() {
        let d = cluster_node();
        let libs = d.library_files();
        assert_eq!(libs.len(), DRIVER_LIBRARIES.len());
        assert!(libs.contains(&"libcuda.so.352.99".to_string()));
    }

    #[test]
    fn ptx_forward_compat() {
        // CUDA 7.5 container on a 352 driver: ok. CUDA 8.0 container: no.
        let d = cluster_node();
        assert!(d.supports_cuda((7, 5)));
        assert!(!d.supports_cuda((8, 0)));
        // Daint's 375 driver runs CUDA 8.0 containers.
        let daint = NvidiaDriver::new((375, 66), vec![GpuModel::tesla_p100()]);
        assert!(daint.supports_cuda((8, 0)));
        // and older-toolkit containers keep working (forward compat)
        assert!(daint.supports_cuda((7, 5)));
    }
}
