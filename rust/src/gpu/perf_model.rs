//! Device performance model: translates workload demand (FLOPs, bytes,
//! cells) into simulated wall-clock on the paper's GPUs.
//!
//! We have no NVIDIA hardware (reproduction band 0), so per DESIGN.md §4
//! the *numeric work* runs for real on the CPU PJRT client while the
//! *device wall-clock* is modeled here. Efficiency factors are calibrated
//! once against the paper's measured tables and then held fixed across
//! native and containerized runs — which is exactly the paper's claim: the
//! container runs the same bits, so any container/native delta comes from
//! the runtime, not the device.

use super::device::{GpuArch, GpuModel};

/// Workload classes with distinct achieved-efficiency profiles.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum WorkloadClass {
    /// CUDA SDK n-body, fp64 all-pairs (Table V): compute-bound, high eff.
    NbodyFp64,
    /// TensorFlow MNIST LeNet (Table I): small model, launch-latency bound.
    MnistTrain,
    /// TensorFlow CIFAR CNN (Table I): input-pipeline bound.
    CifarTrain,
    /// PyFR flux reconstruction, fp32 (Table II): bandwidth-bound.
    PyfrFp32,
}

/// Fraction of peak a workload achieves on an architecture.
///
/// Calibration sources (EXPERIMENTS.md records the arithmetic):
///  * NbodyFp64: Table V native GF/s ÷ board fp64 peak.
///  * MnistTrain/CifarTrain: Table I wall-clock ÷ model FLOPs.
///  * PyfrFp32: Table II single-GPU wall-clock ÷ partition FLOPs.
pub fn efficiency(class: WorkloadClass, arch: GpuArch) -> f64 {
    use GpuArch::*;
    use WorkloadClass::*;
    match (class, arch) {
        (NbodyFp64, KeplerGk208) => 0.815,
        (NbodyFp64, KeplerGk110) => 0.600,
        (NbodyFp64, KeplerGk210) => 0.556,
        (NbodyFp64, Pascal) => 0.5815,

        (MnistTrain, KeplerGk208) => 0.13331,
        (MnistTrain, KeplerGk110) => 0.09814,
        (MnistTrain, KeplerGk210) => 0.09750,
        (MnistTrain, Pascal) => 0.13206,

        (CifarTrain, KeplerGk208) => 0.02806,
        (CifarTrain, KeplerGk110) => 0.00928,
        (CifarTrain, KeplerGk210) => 0.00920,
        (CifarTrain, Pascal) => 0.00610,

        (PyfrFp32, KeplerGk208) => 0.05995,
        (PyfrFp32, KeplerGk110) => 0.05995,
        // paper §V.B obs. III: each K80 chip performs like a K40m on this
        // workload — calibrate the per-chip achieved rate to match
        (PyfrFp32, KeplerGk210) => 0.09186,
        (PyfrFp32, Pascal) => 0.11460,
    }
}

/// Kernel-launch overhead per step (seconds); matters for tiny kernels.
pub fn launch_overhead_s(arch: GpuArch) -> f64 {
    match arch {
        GpuArch::Pascal => 5e-6,
        _ => 8e-6,
    }
}

/// Achieved GFLOP/s of `class` on one *chip* of `board`.
pub fn achieved_gflops_per_chip(
    class: WorkloadClass,
    board: &GpuModel,
) -> f64 {
    let peak = match class {
        WorkloadClass::NbodyFp64 => board.fp64_gflops_per_chip(),
        _ => board.fp32_gflops_per_chip(),
    };
    efficiency(class, board.arch) * peak
}

/// Achieved GFLOP/s of `class` using every chip of `board`.
pub fn achieved_gflops_board(class: WorkloadClass, board: &GpuModel) -> f64 {
    achieved_gflops_per_chip(class, board) * board.chips as f64
}

/// Simulated wall-clock for `flops` of work of `class` on one chip.
pub fn time_on_chip_s(
    class: WorkloadClass,
    board: &GpuModel,
    flops: f64,
    steps: u64,
) -> f64 {
    flops / (achieved_gflops_per_chip(class, board) * 1e9)
        + steps as f64 * launch_overhead_s(board.arch)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::device::GpuModel;

    #[test]
    fn nbody_matches_paper_table5_native() {
        // Table V native GF/s: 18.34 / 858.09 / (858+1037 dual) / 2733.01
        let gf_laptop = achieved_gflops_board(
            WorkloadClass::NbodyFp64,
            &GpuModel::quadro_k110m(),
        );
        assert!((gf_laptop - 18.34).abs() / 18.34 < 0.01, "{gf_laptop}");

        let gf_k40 = achieved_gflops_board(
            WorkloadClass::NbodyFp64,
            &GpuModel::tesla_k40m(),
        );
        assert!((gf_k40 - 858.0).abs() / 858.0 < 0.01, "{gf_k40}");

        let gf_p100 = achieved_gflops_board(
            WorkloadClass::NbodyFp64,
            &GpuModel::tesla_p100(),
        );
        assert!((gf_p100 - 2733.0).abs() / 2733.0 < 0.01, "{gf_p100}");

        let dual = gf_k40
            + achieved_gflops_board(
                WorkloadClass::NbodyFp64,
                &GpuModel::tesla_k80(),
            );
        assert!((dual - 1895.0).abs() / 1895.0 < 0.02, "{dual}");
    }

    #[test]
    fn table1_device_ordering_holds() {
        // Daint < Cluster < Laptop wall-clock for both ML workloads
        for class in [WorkloadClass::MnistTrain, WorkloadClass::CifarTrain] {
            let lap =
                achieved_gflops_per_chip(class, &GpuModel::quadro_k110m());
            let k40 = achieved_gflops_per_chip(class, &GpuModel::tesla_k40m());
            let p100 = achieved_gflops_per_chip(class, &GpuModel::tesla_p100());
            assert!(p100 > k40 && k40 > lap, "{class:?}");
        }
    }

    #[test]
    fn pyfr_p100_about_4x_k40m() {
        // paper §V.B observation II
        let k40 = achieved_gflops_per_chip(
            WorkloadClass::PyfrFp32,
            &GpuModel::tesla_k40m(),
        );
        let p100 = achieved_gflops_per_chip(
            WorkloadClass::PyfrFp32,
            &GpuModel::tesla_p100(),
        );
        let ratio = p100 / k40;
        assert!((3.6..4.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn time_includes_launch_overhead() {
        let b = GpuModel::tesla_p100();
        let t0 = time_on_chip_s(WorkloadClass::NbodyFp64, &b, 1e9, 0);
        let t1 = time_on_chip_s(WorkloadClass::NbodyFp64, &b, 1e9, 1000);
        assert!(t1 > t0);
    }
}
