//! GPU substrate: device models, driver model, CUDA compatibility rules and
//! the device performance model (DESIGN.md S10/S15).

pub mod device;
pub mod driver;
pub mod perf_model;

pub use device::{GpuArch, GpuModel};
pub use driver::{NvidiaDriver, DRIVER_BINARIES, DRIVER_LIBRARIES};
pub use perf_model::{
    achieved_gflops_board, achieved_gflops_per_chip, efficiency,
    launch_overhead_s, time_on_chip_s, WorkloadClass,
};

/// Parse and validate a `CUDA_VISIBLE_DEVICES` value per §IV.A: "a valid
/// comma-separated list of positive integers or device unique identifiers".
/// Returns the ordered device list, or None if the value is invalid or
/// empty — in which case Shifter "does not trigger its GPU support".
pub fn parse_cuda_visible_devices(value: &str) -> Option<Vec<u32>> {
    if value.trim().is_empty() {
        return None;
    }
    let mut out = Vec::new();
    for tok in value.split(',') {
        let tok = tok.trim();
        if let Some(uuid) = tok.strip_prefix("GPU-") {
            // device unique identifier form: GPU-<hex uuid>; we map the
            // uuid deterministically onto an ordinal for the simulation.
            if uuid.is_empty()
                || !uuid
                    .chars()
                    .all(|c| c.is_ascii_hexdigit() || c == '-')
            {
                return None;
            }
            let ord = uuid
                .bytes()
                .fold(0u32, |a, b| a.wrapping_mul(31).wrapping_add(b as u32))
                % 16;
            out.push(ord);
        } else {
            match tok.parse::<i64>() {
                Ok(v) if v >= 0 => out.push(v as u32),
                _ => return None,
            }
        }
    }
    if out.is_empty() {
        None
    } else {
        Some(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accepts_paper_example() {
        // §IV.A example: export CUDA_VISIBLE_DEVICES=0,2
        assert_eq!(parse_cuda_visible_devices("0,2"), Some(vec![0, 2]));
    }

    #[test]
    fn accepts_single_device() {
        assert_eq!(parse_cuda_visible_devices("3"), Some(vec![3]));
    }

    #[test]
    fn accepts_uuid_form() {
        let v = parse_cuda_visible_devices("GPU-8a56a4bc");
        assert!(v.is_some());
        assert_eq!(v.unwrap().len(), 1);
    }

    #[test]
    fn rejects_invalid_values() {
        // §IV.A: invalid value -> GPU support not triggered
        assert_eq!(parse_cuda_visible_devices(""), None);
        assert_eq!(parse_cuda_visible_devices("  "), None);
        assert_eq!(parse_cuda_visible_devices("-1"), None);
        assert_eq!(parse_cuda_visible_devices("0,-2"), None);
        assert_eq!(parse_cuda_visible_devices("abc"), None);
        assert_eq!(parse_cuda_visible_devices("0,abc"), None);
        assert_eq!(parse_cuda_visible_devices("NoDevFiles"), None);
        assert_eq!(parse_cuda_visible_devices("GPU-"), None);
        assert_eq!(parse_cuda_visible_devices("GPU-zz!"), None);
    }

    #[test]
    fn preserves_order() {
        assert_eq!(
            parse_cuda_visible_devices("2,0,1"),
            Some(vec![2, 0, 1])
        );
    }
}
