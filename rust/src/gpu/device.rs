//! GPU device models for the four boards the paper evaluates (§V.A).
//!
//! Public spec-sheet numbers (SM count, clocks, peak FLOPs, memory) are the
//! ground truth; per-workload *achieved* efficiency factors live in
//! `perf_model.rs` and are calibrated against the paper's measured tables
//! (documented in EXPERIMENTS.md).

/// GPU microarchitecture generation (drives CUDA-compatibility checks).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum GpuArch {
    /// GK208 (laptop Quadro)
    KeplerGk208,
    /// GK110B (Tesla K40m)
    KeplerGk110,
    /// GK210 ×2 (Tesla K80 board)
    KeplerGk210,
    /// GP100 (Tesla P100)
    Pascal,
}

impl GpuArch {
    /// CUDA compute capability.
    pub fn compute_capability(&self) -> (u32, u32) {
        match self {
            GpuArch::KeplerGk208 => (3, 5),
            GpuArch::KeplerGk110 => (3, 5),
            GpuArch::KeplerGk210 => (3, 7),
            GpuArch::Pascal => (6, 0),
        }
    }

    /// Minimum CUDA toolkit major.minor able to generate code for this arch.
    pub fn min_cuda(&self) -> (u32, u32) {
        match self {
            GpuArch::KeplerGk208 | GpuArch::KeplerGk110 => (5, 0),
            GpuArch::KeplerGk210 => (6, 5),
            GpuArch::Pascal => (8, 0),
        }
    }
}

/// A physical GPU board as enumerated by the driver.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuModel {
    pub name: &'static str,
    pub arch: GpuArch,
    /// CUDA devices this board exposes (the K80 exposes two GK210 chips).
    pub chips: u32,
    pub sm_per_chip: u32,
    pub boost_clock_mhz: u32,
    /// Peak single-precision GFLOP/s for the whole board.
    pub fp32_gflops_peak: f64,
    /// Peak double-precision GFLOP/s for the whole board.
    pub fp64_gflops_peak: f64,
    pub mem_gib: u32,
    pub mem_bw_gbps: f64,
}

impl GpuModel {
    /// Lenovo W540 laptop GPU (§V.A "Workstation Laptop").
    pub fn quadro_k110m() -> GpuModel {
        GpuModel {
            name: "Quadro K110M",
            arch: GpuArch::KeplerGk208,
            chips: 1,
            sm_per_chip: 2,
            boost_clock_mhz: 705,
            fp32_gflops_peak: 541.0,
            fp64_gflops_peak: 22.5, // 1/24 fp32 on GK208
            mem_gib: 2,
            mem_bw_gbps: 14.4,
        }
    }

    /// Linux Cluster node GPU #1.
    pub fn tesla_k40m() -> GpuModel {
        GpuModel {
            name: "Tesla K40m",
            arch: GpuArch::KeplerGk110,
            chips: 1,
            sm_per_chip: 15,
            boost_clock_mhz: 875,
            fp32_gflops_peak: 4290.0,
            fp64_gflops_peak: 1430.0,
            mem_gib: 12,
            mem_bw_gbps: 288.0,
        }
    }

    /// Linux Cluster node GPU #2 (dual-chip board).
    pub fn tesla_k80() -> GpuModel {
        GpuModel {
            name: "Tesla K80",
            arch: GpuArch::KeplerGk210,
            chips: 2,
            sm_per_chip: 13,
            boost_clock_mhz: 875,
            fp32_gflops_peak: 5600.0,
            fp64_gflops_peak: 1864.0,
            mem_gib: 24,
            mem_bw_gbps: 480.0,
        }
    }

    /// Piz Daint XC50 hybrid-node GPU.
    pub fn tesla_p100() -> GpuModel {
        GpuModel {
            name: "Tesla P100",
            arch: GpuArch::Pascal,
            chips: 1,
            sm_per_chip: 56,
            boost_clock_mhz: 1480,
            fp32_gflops_peak: 9300.0,
            fp64_gflops_peak: 4700.0,
            mem_gib: 16,
            mem_bw_gbps: 732.0,
        }
    }

    /// Per-chip fp64 peak (the K80's chips are scheduled independently —
    /// the paper's observation III: "each of the two chips on the K80 GPU
    /// board have the same architecture of a K40m GPU").
    pub fn fp64_gflops_per_chip(&self) -> f64 {
        self.fp64_gflops_peak / self.chips as f64
    }

    pub fn fp32_gflops_per_chip(&self) -> f64 {
        self.fp32_gflops_peak / self.chips as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn four_boards_have_distinct_specs() {
        let boards = [
            GpuModel::quadro_k110m(),
            GpuModel::tesla_k40m(),
            GpuModel::tesla_k80(),
            GpuModel::tesla_p100(),
        ];
        for w in boards.windows(2) {
            assert_ne!(w[0].name, w[1].name);
        }
        // paper's single-GPU ranking (Table V): P100 > K80 > K40m > K110M
        assert!(boards[3].fp64_gflops_peak > boards[2].fp64_gflops_peak);
        assert!(boards[2].fp64_gflops_peak > boards[1].fp64_gflops_peak);
        assert!(boards[1].fp64_gflops_peak > boards[0].fp64_gflops_peak);
    }

    #[test]
    fn k80_chip_is_k40m_class() {
        // paper §V.B observation III
        let k80 = GpuModel::tesla_k80();
        let k40 = GpuModel::tesla_k40m();
        let ratio = k80.fp64_gflops_per_chip() / k40.fp64_gflops_peak;
        assert!((0.5..1.3).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn pascal_needs_cuda8() {
        assert_eq!(GpuModel::tesla_p100().arch.min_cuda(), (8, 0));
        assert_eq!(GpuModel::tesla_p100().arch.compute_capability(), (6, 0));
    }
}
