//! Virtual filesystem substrate (DESIGN.md S6): in-memory trees, squashfs
//! images with loop mounts, and the ordered mount table the Shifter
//! runtime builds container environments with.

pub mod mount;
pub mod squashfs;
pub mod tree;

pub use mount::{Mount, MountKind, MountTable};
pub use squashfs::{SquashFs, SQUASHFS_RATIO};
pub use tree::{normalize, VNode, VfsError, VirtualFs};
