//! In-memory virtual filesystem tree.
//!
//! Every filesystem the simulation touches — container image roots, host
//! system roots, the assembled container environment — is a `VirtualFs`:
//! a normalized-path → node map with POSIX-ish semantics (implicit parent
//! directories are made explicit, devices and symlinks are first-class).

use std::collections::BTreeMap;

#[derive(Debug, Clone, PartialEq)]
pub enum VNode {
    Dir,
    File {
        size: u64,
        /// content digest (used for dedup and layer flattening)
        digest: u64,
        executable: bool,
    },
    Device {
        major: u32,
        minor: u32,
    },
    Symlink {
        target: String,
    },
}

impl VNode {
    pub fn file(size: u64, digest: u64) -> VNode {
        VNode::File {
            size,
            digest,
            executable: false,
        }
    }

    pub fn exe(size: u64, digest: u64) -> VNode {
        VNode::File {
            size,
            digest,
            executable: true,
        }
    }

    pub fn size(&self) -> u64 {
        match self {
            VNode::File { size, .. } => *size,
            _ => 0,
        }
    }
}

/// Normalize an absolute path: collapse `//`, strip trailing `/`, resolve
/// `.` components (`..` is rejected — container paths are already clean).
pub fn normalize(path: &str) -> Result<String, VfsError> {
    if !path.starts_with('/') {
        return Err(VfsError::NotAbsolute(path.to_string()));
    }
    let mut parts: Vec<&str> = Vec::new();
    for comp in path.split('/') {
        match comp {
            "" | "." => {}
            ".." => return Err(VfsError::DotDot(path.to_string())),
            c => parts.push(c),
        }
    }
    Ok(if parts.is_empty() {
        "/".to_string()
    } else {
        format!("/{}", parts.join("/"))
    })
}

fn parent(path: &str) -> Option<String> {
    if path == "/" {
        return None;
    }
    match path.rfind('/') {
        Some(0) => Some("/".to_string()),
        Some(i) => Some(path[..i].to_string()),
        None => None,
    }
}

#[derive(Debug, thiserror::Error, Clone, PartialEq)]
#[non_exhaustive]
pub enum VfsError {
    #[error("path is not absolute: {0}")]
    NotAbsolute(String),
    #[error("'..' not allowed: {0}")]
    DotDot(String),
    #[error("no such path: {0}")]
    NotFound(String),
    #[error("not a directory: {0}")]
    NotADirectory(String),
    #[error("already exists and is not a directory: {0}")]
    Occupied(String),
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct VirtualFs {
    nodes: BTreeMap<String, VNode>,
}

impl VirtualFs {
    pub fn new() -> VirtualFs {
        let mut fs = VirtualFs {
            nodes: BTreeMap::new(),
        };
        fs.nodes.insert("/".to_string(), VNode::Dir);
        fs
    }

    pub fn len(&self) -> usize {
        self.nodes.len()
    }

    pub fn is_empty(&self) -> bool {
        self.nodes.len() <= 1
    }

    pub fn get(&self, path: &str) -> Option<&VNode> {
        let p = normalize(path).ok()?;
        self.nodes.get(&p)
    }

    pub fn exists(&self, path: &str) -> bool {
        self.get(path).is_some()
    }

    pub fn is_dir(&self, path: &str) -> bool {
        matches!(self.get(path), Some(VNode::Dir))
    }

    /// Create a directory and all missing parents.
    pub fn mkdir_p(&mut self, path: &str) -> Result<(), VfsError> {
        let p = normalize(path)?;
        let mut chain = vec![p.clone()];
        let mut cur = p;
        while let Some(par) = parent(&cur) {
            chain.push(par.clone());
            cur = par;
        }
        for dir in chain.into_iter().rev() {
            match self.nodes.get(&dir) {
                None => {
                    self.nodes.insert(dir, VNode::Dir);
                }
                Some(VNode::Dir) => {}
                Some(_) => return Err(VfsError::Occupied(dir)),
            }
        }
        Ok(())
    }

    /// Insert a node, creating parent directories. Overwrites files
    /// (bind-mount-over semantics) but refuses to replace a directory
    /// with a non-directory.
    pub fn insert(&mut self, path: &str, node: VNode) -> Result<(), VfsError> {
        let p = normalize(path)?;
        if p == "/" {
            return match node {
                VNode::Dir => Ok(()),
                _ => Err(VfsError::Occupied(p)),
            };
        }
        if let Some(par) = parent(&p) {
            // §Perf L3-3: fast path — most inserts land in directories that
            // already exist; checking one map entry avoids allocating and
            // walking the whole ancestor chain.
            if !matches!(self.nodes.get(&par), Some(VNode::Dir)) {
                self.mkdir_p(&par)?;
            }
        }
        if matches!(self.nodes.get(&p), Some(VNode::Dir))
            && !matches!(node, VNode::Dir)
        {
            return Err(VfsError::Occupied(p));
        }
        self.nodes.insert(p, node);
        Ok(())
    }

    pub fn add_file(
        &mut self,
        path: &str,
        size: u64,
        digest: u64,
    ) -> Result<(), VfsError> {
        self.insert(path, VNode::file(size, digest))
    }

    pub fn remove(&mut self, path: &str) -> Result<(), VfsError> {
        let p = normalize(path)?;
        if !self.nodes.contains_key(&p) {
            return Err(VfsError::NotFound(p));
        }
        // remove the subtree
        let prefix = if p == "/" { p.clone() } else { format!("{p}/") };
        self.nodes.retain(|k, _| k != &p && !k.starts_with(&prefix));
        if p == "/" {
            self.nodes.insert("/".to_string(), VNode::Dir);
        }
        Ok(())
    }

    /// Immediate children of a directory.
    pub fn list_dir(&self, path: &str) -> Result<Vec<String>, VfsError> {
        let p = normalize(path)?;
        match self.nodes.get(&p) {
            Some(VNode::Dir) => {}
            Some(_) => return Err(VfsError::NotADirectory(p)),
            None => return Err(VfsError::NotFound(p)),
        }
        let prefix = if p == "/" { "/".to_string() } else { format!("{p}/") };
        let mut out = Vec::new();
        for k in self.nodes.keys() {
            if let Some(rest) = k.strip_prefix(&prefix) {
                if !rest.is_empty() && !rest.contains('/') {
                    out.push(k.clone());
                }
            }
        }
        Ok(out)
    }

    /// All (path, node) pairs under a subtree, subtree root excluded.
    pub fn walk(&self, root: &str) -> Result<Vec<(String, VNode)>, VfsError> {
        let p = normalize(root)?;
        if !self.nodes.contains_key(&p) {
            return Err(VfsError::NotFound(p));
        }
        let prefix = if p == "/" { "/".to_string() } else { format!("{p}/") };
        Ok(self
            .nodes
            .iter()
            .filter(|(k, _)| k.starts_with(&prefix) && *k != &p)
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect())
    }

    /// Every path in the filesystem (sorted).
    pub fn paths(&self) -> impl Iterator<Item = &String> {
        self.nodes.keys()
    }

    /// Total file bytes.
    pub fn total_size(&self) -> u64 {
        self.nodes.values().map(|n| n.size()).sum()
    }

    /// Count of file nodes.
    pub fn file_count(&self) -> usize {
        self.nodes
            .values()
            .filter(|n| matches!(n, VNode::File { .. }))
            .count()
    }

    /// Graft `other`'s subtree at `src` into `self` at `dst`
    /// (the mechanics of a bind mount / layer application).
    pub fn graft(
        &mut self,
        other: &VirtualFs,
        src: &str,
        dst: &str,
    ) -> Result<usize, VfsError> {
        let s = normalize(src)?;
        let d = normalize(dst)?;
        let src_node = other
            .nodes
            .get(&s)
            .ok_or_else(|| VfsError::NotFound(s.clone()))?;
        match src_node {
            VNode::Dir => {
                self.mkdir_p(&d)?;
                let mut n = 0;
                for (k, v) in other.walk(&s)? {
                    // keep the leading '/' on the relative part ("/" source
                    // paths start right after the root slash)
                    let rel = if s == "/" { &k[..] } else { &k[s.len()..] };
                    let target = if d == "/" {
                        k.clone()
                    } else {
                        format!("{d}{rel}")
                    };
                    self.insert(&target, v)?;
                    n += 1;
                }
                Ok(n)
            }
            node => {
                self.insert(&d, node.clone())?;
                Ok(1)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_paths() {
        assert_eq!(normalize("/a//b/./c/").unwrap(), "/a/b/c");
        assert_eq!(normalize("/").unwrap(), "/");
        assert!(normalize("relative").is_err());
        assert!(normalize("/a/../b").is_err());
    }

    #[test]
    fn mkdir_p_creates_chain() {
        let mut fs = VirtualFs::new();
        fs.mkdir_p("/usr/lib/x86_64").unwrap();
        assert!(fs.is_dir("/usr"));
        assert!(fs.is_dir("/usr/lib"));
        assert!(fs.is_dir("/usr/lib/x86_64"));
    }

    #[test]
    fn insert_makes_parents() {
        let mut fs = VirtualFs::new();
        fs.add_file("/etc/os-release", 120, 0xabc).unwrap();
        assert!(fs.is_dir("/etc"));
        assert_eq!(fs.get("/etc/os-release").unwrap().size(), 120);
    }

    #[test]
    fn file_overwrite_allowed_dir_protected() {
        let mut fs = VirtualFs::new();
        fs.add_file("/lib/libmpi.so.12", 100, 1).unwrap();
        fs.add_file("/lib/libmpi.so.12", 200, 2).unwrap(); // mount-over
        assert_eq!(fs.get("/lib/libmpi.so.12").unwrap().size(), 200);
        assert!(fs.insert("/lib", VNode::file(1, 1)).is_err());
    }

    #[test]
    fn list_dir_immediate_children_only() {
        let mut fs = VirtualFs::new();
        fs.add_file("/a/b/c", 1, 1).unwrap();
        fs.add_file("/a/d", 1, 2).unwrap();
        let ls = fs.list_dir("/a").unwrap();
        assert_eq!(ls, vec!["/a/b", "/a/d"]);
        assert!(fs.list_dir("/a/d").is_err()); // not a directory
        assert!(fs.list_dir("/zzz").is_err());
    }

    #[test]
    fn remove_subtree() {
        let mut fs = VirtualFs::new();
        fs.add_file("/a/b/c", 1, 1).unwrap();
        fs.add_file("/ab", 1, 2).unwrap();
        fs.remove("/a").unwrap();
        assert!(!fs.exists("/a"));
        assert!(!fs.exists("/a/b/c"));
        assert!(fs.exists("/ab")); // prefix sibling survives
    }

    #[test]
    fn graft_subtree() {
        let mut host = VirtualFs::new();
        host.add_file("/opt/cray/lib/libmpi.so.12", 5000, 7).unwrap();
        host.add_file("/opt/cray/lib/libmpifort.so.12", 3000, 8).unwrap();
        let mut container = VirtualFs::new();
        let n = container
            .graft(&host, "/opt/cray/lib", "/usr/lib/host-mpi")
            .unwrap();
        assert_eq!(n, 2);
        assert!(container.exists("/usr/lib/host-mpi/libmpi.so.12"));
        assert_eq!(
            container.get("/usr/lib/host-mpi/libmpifort.so.12").unwrap().size(),
            3000
        );
    }

    #[test]
    fn graft_single_file() {
        let mut host = VirtualFs::new();
        host.insert("/dev/nvidia0", VNode::Device { major: 195, minor: 0 })
            .unwrap();
        let mut c = VirtualFs::new();
        c.graft(&host, "/dev/nvidia0", "/dev/nvidia0").unwrap();
        assert!(matches!(
            c.get("/dev/nvidia0"),
            Some(VNode::Device { major: 195, minor: 0 })
        ));
    }

    #[test]
    fn walk_and_sizes() {
        let mut fs = VirtualFs::new();
        fs.add_file("/x/a", 10, 1).unwrap();
        fs.add_file("/x/y/b", 20, 2).unwrap();
        assert_eq!(fs.walk("/x").unwrap().len(), 3); // a, y, y/b
        assert_eq!(fs.total_size(), 30);
        assert_eq!(fs.file_count(), 2);
    }
}
