//! Mount table: the ordered record of everything the runtime grafts into a
//! container environment (site directories, GPU devices, driver libraries,
//! host MPI). Ordering is part of correctness — a later mount may shadow an
//! earlier one (that is how the MPI swap overrides the container's libmpi),
//! and the audit log the stage machine prints reflects this order.

use std::fmt;

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MountKind {
    /// Bind a host path into the container.
    Bind { read_only: bool },
    /// Loop-mount a squashfs image.
    Loop,
    /// Fresh tmpfs.
    Tmpfs,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Mount {
    pub source: String,
    pub target: String,
    pub kind: MountKind,
    /// Why this mount exists ("site config", "gpu support", "mpi swap"…)
    pub origin: &'static str,
}

impl fmt::Display for Mount {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let k = match &self.kind {
            MountKind::Bind { read_only: true } => "bind,ro",
            MountKind::Bind { read_only: false } => "bind,rw",
            MountKind::Loop => "loop",
            MountKind::Tmpfs => "tmpfs",
        };
        write!(f, "{} -> {} [{}] ({})", self.source, self.target, k, self.origin)
    }
}

#[derive(Debug, Clone, Default, PartialEq)]
pub struct MountTable {
    mounts: Vec<Mount>,
}

impl MountTable {
    pub fn new() -> MountTable {
        MountTable { mounts: Vec::new() }
    }

    pub fn push(&mut self, m: Mount) {
        self.mounts.push(m);
    }

    pub fn bind(
        &mut self,
        source: &str,
        target: &str,
        read_only: bool,
        origin: &'static str,
    ) {
        self.push(Mount {
            source: source.to_string(),
            target: target.to_string(),
            kind: MountKind::Bind { read_only },
            origin,
        });
    }

    pub fn len(&self) -> usize {
        self.mounts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.mounts.is_empty()
    }

    pub fn iter(&self) -> impl Iterator<Item = &Mount> {
        self.mounts.iter()
    }

    /// Mounts contributed by a given subsystem.
    pub fn by_origin(&self, origin: &str) -> Vec<&Mount> {
        self.mounts.iter().filter(|m| m.origin == origin).collect()
    }

    /// The effective mount at a target (the *last* one wins).
    pub fn effective(&self, target: &str) -> Option<&Mount> {
        self.mounts.iter().rev().find(|m| m.target == target)
    }

    /// Targets that are shadowed by a later mount on the same path.
    pub fn shadowed(&self) -> Vec<&Mount> {
        let mut out = Vec::new();
        for (i, m) in self.mounts.iter().enumerate() {
            if self.mounts[i + 1..].iter().any(|n| n.target == m.target) {
                out.push(m);
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ordering_preserved_and_last_wins() {
        let mut t = MountTable::new();
        t.bind("/image/lib/libmpi.so.12", "/lib/libmpi.so.12", true, "image");
        t.bind("/opt/cray/libmpi.so.12", "/lib/libmpi.so.12", true, "mpi swap");
        assert_eq!(t.len(), 2);
        assert_eq!(
            t.effective("/lib/libmpi.so.12").unwrap().source,
            "/opt/cray/libmpi.so.12"
        );
        assert_eq!(t.shadowed().len(), 1);
        assert_eq!(t.shadowed()[0].origin, "image");
    }

    #[test]
    fn by_origin_filters() {
        let mut t = MountTable::new();
        t.bind("/dev/nvidia0", "/dev/nvidia0", false, "gpu support");
        t.bind("/usr/lib/libcuda.so", "/usr/lib/libcuda.so", true, "gpu support");
        t.bind("/scratch", "/scratch", false, "site config");
        assert_eq!(t.by_origin("gpu support").len(), 2);
        assert_eq!(t.by_origin("site config").len(), 1);
    }

    #[test]
    fn display_is_informative() {
        let mut t = MountTable::new();
        t.bind("/a", "/b", true, "test");
        let s = format!("{}", t.iter().next().unwrap());
        assert!(s.contains("/a -> /b"));
        assert!(s.contains("bind,ro"));
    }
}
