//! squashfs model: "a compressed read-only file system for Linux" (§III.A).
//!
//! The Image Gateway converts flattened Docker images to squashfs so a
//! container start-up costs one PFS lookup (the image file) instead of one
//! per member file — the mechanism behind Fig. 3. We model the format as a
//! sealed file table plus size bookkeeping under a fixed compression model.

use super::tree::{VNode, VfsError, VirtualFs};

/// Compression ratio for typical image content (ELF + text under gzip-level
/// squashfs compression).
pub const SQUASHFS_RATIO: f64 = 0.45;

#[derive(Debug, Clone, PartialEq)]
pub struct SquashFs {
    /// Digest of the file table (identity of the image).
    pub digest: u64,
    /// Uncompressed content bytes.
    pub original_bytes: u64,
    /// On-disk (PFS) bytes.
    pub compressed_bytes: u64,
    /// The sealed, read-only file table.
    tree: VirtualFs,
}

impl SquashFs {
    /// `mksquashfs`: seal a filesystem tree into an image.
    pub fn create(tree: &VirtualFs) -> SquashFs {
        let original = tree.total_size();
        let mut digest: u64 = 0xcbf29ce484222325;
        for p in tree.paths() {
            for b in p.as_bytes() {
                digest ^= *b as u64;
                digest = digest.wrapping_mul(0x100000001b3);
            }
            if let Some(VNode::File { digest: d, size, .. }) = tree.get(p) {
                digest ^= d ^ size.rotate_left(17);
                digest = digest.wrapping_mul(0x100000001b3);
            }
        }
        SquashFs {
            digest,
            original_bytes: original,
            compressed_bytes: (original as f64 * SQUASHFS_RATIO) as u64,
            tree: tree.clone(),
        }
    }

    pub fn file_count(&self) -> usize {
        self.tree.file_count()
    }

    /// Loop-mount the image: graft its (read-only) tree at `mountpoint`.
    /// Returns the number of nodes exposed.
    pub fn loop_mount(
        &self,
        target: &mut VirtualFs,
        mountpoint: &str,
    ) -> Result<usize, VfsError> {
        target.mkdir_p(mountpoint)?;
        self.tree.graft_into(target, mountpoint)
    }

    /// Read-only view of the sealed tree.
    pub fn tree(&self) -> &VirtualFs {
        &self.tree
    }
}

impl VirtualFs {
    /// Helper used by loop_mount: graft this entire fs under `mountpoint`
    /// of `target`.
    pub fn graft_into(
        &self,
        target: &mut VirtualFs,
        mountpoint: &str,
    ) -> Result<usize, VfsError> {
        target.graft(self, "/", mountpoint)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_tree() -> VirtualFs {
        let mut fs = VirtualFs::new();
        fs.add_file("/bin/bash", 1_000_000, 1).unwrap();
        fs.add_file("/etc/os-release", 200, 2).unwrap();
        fs.add_file("/usr/lib/libpython3.5.so", 3_500_000, 3).unwrap();
        fs
    }

    #[test]
    fn create_compresses() {
        let sq = SquashFs::create(&sample_tree());
        assert_eq!(sq.original_bytes, 4_500_200);
        assert!(sq.compressed_bytes < sq.original_bytes);
        assert_eq!(sq.file_count(), 3);
    }

    #[test]
    fn digest_is_content_sensitive() {
        let a = SquashFs::create(&sample_tree());
        let mut t2 = sample_tree();
        t2.add_file("/etc/extra", 1, 9).unwrap();
        let b = SquashFs::create(&t2);
        assert_ne!(a.digest, b.digest);
        // and deterministic
        assert_eq!(a.digest, SquashFs::create(&sample_tree()).digest);
    }

    #[test]
    fn loop_mount_exposes_tree() {
        let sq = SquashFs::create(&sample_tree());
        let mut node_fs = VirtualFs::new();
        let n = sq.loop_mount(&mut node_fs, "/var/udiMount").unwrap();
        assert!(n >= 3);
        assert!(node_fs.exists("/var/udiMount/etc/os-release"));
        assert!(node_fs.exists("/var/udiMount/bin/bash"));
    }

    #[test]
    fn mount_at_root() {
        let sq = SquashFs::create(&sample_tree());
        let mut fs = VirtualFs::new();
        sq.loop_mount(&mut fs, "/").unwrap();
        assert!(fs.exists("/etc/os-release"));
    }
}
