//! The multi-tenant fair-share backfill scheduler (DESIGN.md S20): a
//! discrete-event simulation that drives the re-entrant
//! [`LaunchScheduler`] with a whole stream of competing jobs over one
//! shared [`DistributionFabric`].
//!
//! Event loop (DESIGN.md S24): the storm is the virtual-time kernel's
//! first native client. Every arrival seeds the [`crate::sim::SimKernel`]
//! up front, each start schedules its own completion event, and the run
//! loop is a pure event drain — one scheduling pass per simultaneity
//! batch. At every batch the queue is re-ordered by the active
//! [`SchedulingPolicy`] (a pluggable trait object — see
//! [`super::policy`]) and a scheduling pass decides who starts *now*:
//!
//! * priorities come from [`SchedulingPolicy::priority`] (the builtin
//!   [`super::policy::Fifo`] keeps strict arrival order; the builtin
//!   [`super::policy::FairShare`] uses the [`ShareLedger`]'s SLURM-style
//!   `2^(-U/S)` fair-share factor plus linear aging);
//! * when [`SchedulingPolicy::backfill`] is `false`, head-of-line
//!   blocking applies: if the highest-priority job does not fit, nothing
//!   behind it may start;
//! * when it is `true`, **conservative backfill** runs: every queued job
//!   gets a reservation on a count-based availability timeline, and a
//!   lower-priority job may start early only if its reservation already
//!   begins now — so backfilling never delays any higher-priority
//!   reservation. With the fair-share builtin, aging bounds starvation:
//!   a waiting job's priority grows without bound, while the share term
//!   is capped at 1.0.
//!
//! Jobs that start in the same pass batch-prefetch their images through
//! the fabric first, so concurrent distinct references queue behind each
//! other on the gateway shards (pull-storm interference), while identical
//! references coalesce into the one existing pull job.

use std::collections::BTreeSet;
use std::sync::Arc;

use crate::config::UdiRootConfig;
use crate::distrib::DistributionFabric;
use crate::launch::{LaunchCluster, LaunchScheduler, RetryPolicy};
use crate::registry::Registry;
use crate::shifter::ExtensionRegistry;
use crate::sim::{SimKernel, SimTime};
use crate::telemetry::{SpanDraft, Telemetry, TraceCtx};
use crate::wlm::fairshare::ShareLedger;

use super::policy::{SchedulingPolicy, DEFAULT_POLICY};
use super::report::{JobRecord, TenancyReport};
use super::traffic::TenantJob;

/// Time-comparison slack for coincident events (the simultaneity window
/// handed to [`SimKernel::pop_batch`]).
const EPS: f64 = 1e-9;

/// Events on the storm kernel (DESIGN.md S24).
enum StormEvent {
    /// The stream job at this index joins the queue.
    Arrival(usize),
    /// The stream job at this index releases its nodes.
    Completion(usize),
}

/// A job currently occupying nodes.
struct Running {
    idx: usize,
    nodes: Vec<u32>,
    end_secs: f64,
}

/// A reservation (or running occupancy) on the count-based availability
/// timeline: `width` nodes busy over `[start, end)`.
#[derive(Clone, Copy)]
struct Interval {
    start: f64,
    end: f64,
    width: u32,
}

/// The multi-tenant storm scheduler — the `tenancy` entry point.
///
/// ```
/// use shifter_rs::distrib::DistributionFabric;
/// use shifter_rs::launch::LaunchCluster;
/// use shifter_rs::pfs::LustreFs;
/// use shifter_rs::tenancy::{FairShareScheduler, TrafficModel};
/// use shifter_rs::{Registry, SystemProfile};
///
/// let cluster = LaunchCluster::homogeneous(&SystemProfile::piz_daint(), 8);
/// let registry = Registry::dockerhub();
/// let mut fabric = DistributionFabric::new(2, LustreFs::piz_daint());
/// let jobs = TrafficModel {
///     tenants: 2,
///     jobs: 5,
///     max_width: 4,
///     ..TrafficModel::default()
/// }
/// .generate(&cluster);
/// let report = FairShareScheduler::new(&cluster, &registry)
///     .run(&mut fabric, &jobs);
/// assert_eq!(report.completed(), jobs.len());
/// assert!(report.utilization() > 0.0);
/// ```
pub struct FairShareScheduler<'a> {
    cluster: &'a LaunchCluster,
    registry: &'a Registry,
    policy: &'a dyn SchedulingPolicy,
    retry: RetryPolicy,
    config: Option<UdiRootConfig>,
    extensions: Option<Arc<ExtensionRegistry>>,
    telemetry: Option<Arc<Telemetry>>,
}

impl<'a> FairShareScheduler<'a> {
    /// Fair-share scheduler over `cluster` with default knobs
    /// (fair-share + backfill policy with the stock aging weight, strict
    /// launch retry policy for deterministic per-node timings).
    pub fn new(
        cluster: &'a LaunchCluster,
        registry: &'a Registry,
    ) -> FairShareScheduler<'a> {
        FairShareScheduler {
            cluster,
            registry,
            policy: &DEFAULT_POLICY,
            retry: RetryPolicy::strict(),
            config: None,
            extensions: None,
            telemetry: None,
        }
    }

    /// Select the queue policy — any [`SchedulingPolicy`] object (the
    /// storm bench runs the two builtins on the same stream and compares
    /// utilization; custom policies plug in the same way).
    pub fn with_policy(
        mut self,
        policy: &'a dyn SchedulingPolicy,
    ) -> FairShareScheduler<'a> {
        self.policy = policy;
        self
    }

    /// Straggler/retry policy forwarded to every per-job launch.
    pub fn with_retry_policy(
        mut self,
        retry: RetryPolicy,
    ) -> FairShareScheduler<'a> {
        self.retry = retry;
        self
    }

    /// Site `udiRoot.conf` forwarded to every per-job launch (otherwise
    /// each partition derives its stock config from its profile).
    pub fn with_config(
        mut self,
        config: UdiRootConfig,
    ) -> FairShareScheduler<'a> {
        self.config = Some(config);
        self
    }

    /// Host-extension registry forwarded to every per-job launch (the
    /// site's GPU/MPI/network set plus any site-defined extensions).
    pub fn with_extensions(
        mut self,
        extensions: Arc<ExtensionRegistry>,
    ) -> FairShareScheduler<'a> {
        self.extensions = Some(extensions);
        self
    }

    /// Share a telemetry recorder (see DESIGN.md S23): the storm emits
    /// one `job` root span per tenant job (arrival → completion) with
    /// `wait`/`node`/`app` children, instant `pass` spans on the
    /// scheduler track, and the `tenancy.*` decision counters
    /// (starts, backfills, starvation, wait histogram). The recorder is
    /// forwarded to the per-job launches, so node/stage/extension spans
    /// stitch under each job's root.
    pub fn with_telemetry(
        mut self,
        telemetry: Arc<Telemetry>,
    ) -> FairShareScheduler<'a> {
        self.telemetry = Some(telemetry);
        self
    }

    /// Run the whole `jobs` stream to completion over `fabric` and
    /// aggregate the outcome. Jobs may arrive in any order; the stream is
    /// processed by arrival time.
    pub fn run(
        &self,
        fabric: &mut DistributionFabric,
        jobs: &[TenantJob],
    ) -> TenancyReport {
        let mut launcher = LaunchScheduler::new(self.cluster, self.registry)
            .with_policy(self.retry);
        if let Some(config) = &self.config {
            launcher = launcher.with_config(config.clone());
        }
        if let Some(extensions) = &self.extensions {
            launcher = launcher.with_extensions(Arc::clone(extensions));
        }
        if let Some(telemetry) = &self.telemetry {
            launcher = launcher.with_telemetry(Arc::clone(telemetry));
        }
        let mut order: Vec<usize> = (0..jobs.len()).collect();
        order.sort_by(|&a, &b| {
            jobs[a]
                .arrival_secs
                .total_cmp(&jobs[b].arrival_secs)
                .then(a.cmp(&b))
        });

        // seed every arrival as a kernel event; ties pop in stream order
        // because the seeding follows `order` and seq breaks ties
        let mut kernel: SimKernel<StormEvent> = SimKernel::new();
        for &idx in &order {
            kernel.schedule_at(
                SimTime::from_secs(jobs[idx].arrival_secs),
                StormEvent::Arrival(idx),
            );
        }

        let mut queue: Vec<usize> = Vec::new();
        let mut running: Vec<Running> = Vec::new();
        let mut free: BTreeSet<u32> =
            (0..self.cluster.total_nodes()).collect();
        let mut ledger = ShareLedger::new();
        for j in jobs {
            ledger.ensure(&j.tenant);
        }
        let mut records: Vec<Option<JobRecord>> = vec![None; jobs.len()];

        let mut t = 0.0;
        while !kernel.is_empty() {
            // -- drain one simultaneity batch -----------------------------
            let batch = kernel.pop_batch(EPS);
            t = batch[0].0.as_secs_f64();
            for (_, event) in batch {
                match event {
                    StormEvent::Completion(idx) => {
                        if let Some(pos) =
                            running.iter().position(|r| r.idx == idx)
                        {
                            let done = running.swap_remove(pos);
                            free.extend(done.nodes);
                        }
                    }
                    StormEvent::Arrival(idx) => queue.push(idx),
                }
            }
            // -- scheduling pass ------------------------------------------
            self.schedule_pass(
                t,
                jobs,
                &launcher,
                fabric,
                &mut kernel,
                &mut queue,
                &mut running,
                &mut free,
                &mut ledger,
                &mut records,
            );
        }
        // nothing left to fire, yet jobs queue: they can never start
        // (defensive — the pass drops too-wide jobs itself)
        for idx in queue.drain(..) {
            records[idx] = Some(failed_record(
                &jobs[idx],
                t,
                "unschedulable: wider than the cluster",
            ));
        }

        let records: Vec<JobRecord> = records
            .into_iter()
            .enumerate()
            .map(|(idx, r)| {
                r.unwrap_or_else(|| {
                    failed_record(&jobs[idx], t, "never scheduled")
                })
            })
            .collect();
        TenancyReport::from_records(
            self.policy.name(),
            self.cluster.total_nodes(),
            records,
            fabric.coalescing(),
            fabric.queue_wait_stats(),
            fabric.cache_stats(),
        )
    }

    /// Order the queue by the active policy: FIFO by arrival, fair-share
    /// by descending ledger priority (ties: older first, then id).
    fn ordered_queue(
        &self,
        t: f64,
        queue: &[usize],
        jobs: &[TenantJob],
        ledger: &ShareLedger,
    ) -> Vec<usize> {
        let mut keyed: Vec<(f64, f64, u32, usize)> = queue
            .iter()
            .map(|&idx| {
                let j = &jobs[idx];
                let prio =
                    self.policy.priority(j, t - j.arrival_secs, ledger);
                (prio, j.arrival_secs, j.id, idx)
            })
            .collect();
        keyed.sort_by(|a, b| {
            b.0.total_cmp(&a.0)
                .then(a.1.total_cmp(&b.1))
                .then(a.2.cmp(&b.2))
        });
        keyed.into_iter().map(|(_, _, _, idx)| idx).collect()
    }

    /// Decide who starts at time `t` and execute those launches,
    /// scheduling each start's completion back onto the kernel.
    #[allow(clippy::too_many_arguments)]
    fn schedule_pass(
        &self,
        t: f64,
        jobs: &[TenantJob],
        launcher: &LaunchScheduler<'_>,
        fabric: &mut DistributionFabric,
        kernel: &mut SimKernel<StormEvent>,
        queue: &mut Vec<usize>,
        running: &mut Vec<Running>,
        free: &mut BTreeSet<u32>,
        ledger: &mut ShareLedger,
        records: &mut [Option<JobRecord>],
    ) {
        let capacity = self.cluster.total_nodes();
        let tele = self.telemetry.as_ref().filter(|x| x.enabled());
        if let Some(x) = tele {
            x.count("tenancy.passes", 1);
            x.span(SpanDraft {
                parent: None,
                category: "sched",
                name: "pass",
                track: "scheduler",
                start: SimTime::from_secs(t),
                dur_secs: 0.0,
            });
        }
        let ordered = self.ordered_queue(t, queue, jobs, ledger);

        // drop jobs that can never run anywhere
        let mut dropped: BTreeSet<usize> = BTreeSet::new();
        for &idx in &ordered {
            if jobs[idx].spec.nodes > capacity {
                records[idx] = Some(failed_record(
                    &jobs[idx],
                    t,
                    "unschedulable: wider than the cluster",
                ));
                dropped.insert(idx);
            }
        }

        // plan: who starts now, and was it a backfill?
        let mut to_start: Vec<(usize, bool)> = Vec::new();
        if !self.policy.backfill() {
            let mut avail = free.len() as u32;
            for &idx in &ordered {
                if dropped.contains(&idx) {
                    continue;
                }
                let width = jobs[idx].spec.nodes;
                if width > avail {
                    break; // head-of-line blocking
                }
                avail -= width;
                to_start.push((idx, false));
            }
        } else {
            // count-based availability timeline seeded with the
            // currently running jobs
            let mut resv: Vec<Interval> = running
                .iter()
                .map(|r| Interval {
                    start: t,
                    end: r.end_secs,
                    width: jobs[r.idx].spec.nodes,
                })
                .collect();
            let mut blocked_seen = false;
            for &idx in &ordered {
                if dropped.contains(&idx) {
                    continue;
                }
                let width = jobs[idx].spec.nodes;
                // estimated occupancy: the synthetic runtime (launch
                // overhead is seconds against minutes and every pass
                // recomputes from actual completions)
                let est = jobs[idx].runtime_secs.max(1.0);
                let tau = earliest_start(t, est, width, capacity, &resv);
                resv.push(Interval {
                    start: tau,
                    end: tau + est,
                    width,
                });
                if tau <= t + EPS {
                    to_start.push((idx, blocked_seen));
                } else {
                    blocked_seen = true;
                }
            }
        }
        queue.retain(|idx| {
            !dropped.contains(idx)
                && !to_start.iter().any(|(s, _)| s == idx)
        });
        if to_start.is_empty() {
            return;
        }

        // align the fabric's shard clocks to storm time so pulls enqueue
        // at `t` on the one kernel clock, then batch-prefetch every image
        // starting this pass — concurrent distinct references contend on
        // the shard queues while identical ones coalesce — and drain the
        // batch to completion in exact event time
        fabric.advance_to(self.registry, SimTime::from_secs(t));
        for &(idx, _) in &to_start {
            let j = &jobs[idx];
            let _ = fabric.request(
                self.registry,
                &j.spec.image,
                &format!("{}-job-{:04}", j.tenant, j.id),
            );
        }
        fabric.drain(self.registry);

        // execute the launches on explicit node sets
        for (idx, backfilled) in to_start {
            let j = &jobs[idx];
            let width = j.spec.nodes as usize;
            let nodes: Vec<u32> = free.iter().copied().take(width).collect();
            debug_assert_eq!(nodes.len(), width, "planner over-committed");
            for n in &nodes {
                free.remove(n);
            }
            // the job's root span is reserved up front so the launch's
            // node spans (and the runtime's stage spans below them)
            // parent under it; it is recorded once the completion time
            // is known
            let root = tele.and_then(|x| x.reserve_id());
            let launched = launcher.launch_on_traced(
                fabric,
                &j.spec,
                &nodes,
                TraceCtx {
                    parent: root,
                    start: SimTime::from_secs(t),
                },
            );
            match launched {
                Ok(launch) => {
                    let overhead =
                        launch.total_stats().map_or(0.0, |s| s.worst);
                    let service = j.runtime_secs + overhead;
                    ledger.charge(&j.tenant, f64::from(j.spec.nodes) * service);
                    records[idx] = Some(JobRecord {
                        id: j.id,
                        tenant: j.tenant.clone(),
                        tenant_idx: j.tenant_idx,
                        class: j.class,
                        image: j.spec.image.clone(),
                        width: j.spec.nodes,
                        arrival_secs: j.arrival_secs,
                        start_secs: t,
                        end_secs: t + service,
                        service_secs: service,
                        wait_secs: t - j.arrival_secs,
                        backfilled,
                        failed_slots: launch.failed(),
                        error: None,
                    });
                    if let (Some(x), Some(root_id)) = (tele, root) {
                        self.emit_job_spans(
                            x, root_id, j, t, overhead, service, backfilled,
                        );
                    }
                    running.push(Running {
                        idx,
                        nodes,
                        end_secs: t + service,
                    });
                    kernel.schedule_at(
                        SimTime::from_secs(t + service),
                        StormEvent::Completion(idx),
                    );
                }
                Err(e) => {
                    free.extend(nodes);
                    if let Some(x) = tele {
                        x.count("tenancy.failed_jobs", 1);
                    }
                    records[idx] =
                        Some(failed_record(j, t, &e.to_string()));
                }
            }
        }
    }

    /// Record one started job's span family: the `job` root spanning
    /// arrival → completion on its tenant's track, a `wait` child over
    /// the queueing interval, and an `app` child over the application's
    /// own runtime (which begins once the worst node finished its stage
    /// pipeline) — together with the launch's node spans these tile the
    /// root, so trace coverage of reported wall time is complete.
    #[allow(clippy::too_many_arguments)]
    fn emit_job_spans(
        &self,
        tele: &Telemetry,
        root_id: u64,
        j: &TenantJob,
        t: f64,
        overhead: f64,
        service: f64,
        backfilled: bool,
    ) {
        let track = format!("tenant:{}", j.tenant);
        let wait = t - j.arrival_secs;
        tele.span_as(
            root_id,
            SpanDraft {
                parent: None,
                category: "job",
                name: &format!("job:{}/{:04}", j.tenant, j.id),
                track: &track,
                start: SimTime::from_secs(j.arrival_secs),
                dur_secs: wait + service,
            },
        );
        tele.annotate(root_id, "image", &j.spec.image);
        tele.annotate(root_id, "width", &j.spec.nodes.to_string());
        if backfilled {
            tele.annotate(root_id, "backfilled", "true");
        }
        if wait > EPS {
            tele.span(SpanDraft {
                parent: Some(root_id),
                category: "wait",
                name: "wait",
                track: &track,
                start: SimTime::from_secs(j.arrival_secs),
                dur_secs: wait,
            });
        }
        tele.span(SpanDraft {
            parent: Some(root_id),
            category: "app",
            name: &format!("app:{}", j.spec.image),
            track: &track,
            start: SimTime::from_secs(t + overhead),
            dur_secs: service - overhead,
        });
        tele.count("tenancy.starts", 1);
        if backfilled {
            tele.count("tenancy.backfills", 1);
        }
        tele.observe("tenancy.wait_secs", wait);
        // SLURM-style starvation signal: stretch = turnaround / service
        if service > EPS && (wait + service) / service > 10.0 {
            tele.count("tenancy.starvation", 1);
        }
    }
}

/// Earliest `tau >= t` at which `width` nodes are continuously free for
/// `est` seconds, given the reservation timeline. Candidates are `t` and
/// every reservation end; after the last reservation the cluster is
/// empty, so a fitting candidate always exists (given `width <=
/// capacity`).
fn earliest_start(
    t: f64,
    est: f64,
    width: u32,
    capacity: u32,
    resv: &[Interval],
) -> f64 {
    let mut candidates: Vec<f64> = vec![t];
    candidates.extend(resv.iter().map(|r| r.end).filter(|e| *e > t));
    candidates.sort_by(f64::total_cmp);
    let used_at = |p: f64| -> u32 {
        resv.iter()
            .filter(|r| r.start <= p + EPS && r.end > p + EPS)
            .map(|r| r.width)
            .sum()
    };
    // The last candidate is the latest reservation end: past it the
    // timeline is empty, so that instant always fits and the find below
    // cannot come back empty.
    let empty_tail = candidates.last().copied().unwrap_or(t);
    candidates
        .iter()
        .copied()
        .find(|&tau| {
            let window_end = tau + est;
            let mut points: Vec<f64> = vec![tau];
            points.extend(
                resv.iter()
                    .map(|r| r.start)
                    .filter(|s| *s > tau && *s < window_end),
            );
            points.into_iter().all(|p| used_at(p) + width <= capacity)
        })
        .unwrap_or(empty_tail)
}

/// A record for a job that never launched.
fn failed_record(job: &TenantJob, t: f64, reason: &str) -> JobRecord {
    JobRecord {
        id: job.id,
        tenant: job.tenant.clone(),
        tenant_idx: job.tenant_idx,
        class: job.class,
        image: job.spec.image.clone(),
        width: job.spec.nodes,
        arrival_secs: job.arrival_secs,
        start_secs: t,
        end_secs: t,
        service_secs: 0.0,
        wait_secs: t - job.arrival_secs,
        backfilled: false,
        failed_slots: job.spec.nodes as usize,
        error: Some(reason.to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;
    use crate::launch::JobSpec;
    use crate::pfs::LustreFs;
    use crate::tenancy::policy::{FairShare, Fifo};
    use crate::tenancy::traffic::JobClass;

    fn job(
        id: u32,
        tenant: u32,
        arrival: f64,
        width: u32,
        runtime: f64,
    ) -> TenantJob {
        TenantJob {
            id,
            tenant: format!("tenant-{tenant:02}"),
            tenant_idx: tenant,
            arrival_secs: arrival,
            runtime_secs: runtime,
            class: JobClass::Cpu,
            spec: JobSpec::new("ubuntu:xenial", &["true"], width),
        }
    }

    fn setup(nodes: u32) -> (LaunchCluster, Registry, DistributionFabric) {
        (
            LaunchCluster::homogeneous(&SystemProfile::piz_daint(), nodes),
            Registry::dockerhub(),
            DistributionFabric::new(2, LustreFs::piz_daint()),
        )
    }

    #[test]
    fn empty_stream_yields_empty_report() {
        let (cluster, registry, mut fabric) = setup(4);
        let report = FairShareScheduler::new(&cluster, &registry)
            .run(&mut fabric, &[]);
        assert_eq!(report.completed(), 0);
        assert_eq!(report.utilization(), 0.0);
    }

    #[test]
    fn uncontended_jobs_start_on_arrival() {
        let (cluster, registry, mut fabric) = setup(16);
        let jobs =
            vec![job(0, 0, 0.0, 4, 100.0), job(1, 1, 10.0, 4, 100.0)];
        let report = FairShareScheduler::new(&cluster, &registry)
            .run(&mut fabric, &jobs);
        assert_eq!(report.completed(), 2);
        for r in &report.records {
            assert!(r.wait_secs < EPS, "job {} waited {}", r.id, r.wait_secs);
            assert!(!r.backfilled);
        }
        assert!((report.max_stretch() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn fifo_blocks_head_of_line_but_backfill_fills_the_hole() {
        // 8 nodes. Job 0 takes 6 of them for 1000s. Job 1 (width 8) must
        // wait for the whole machine. Job 2 (width 2, 100s) arrives last:
        // FIFO blocks it behind job 1; conservative backfill starts it in
        // the 2-node hole immediately, because it finishes long before
        // job 1's reservation and so cannot delay it.
        let jobs = vec![
            job(0, 0, 0.0, 6, 1000.0),
            job(1, 1, 1.0, 8, 1000.0),
            job(2, 2, 2.0, 2, 100.0),
        ];
        let run = |policy: &dyn SchedulingPolicy| {
            let (cluster, registry, mut fabric) = setup(8);
            FairShareScheduler::new(&cluster, &registry)
                .with_policy(policy)
                .run(&mut fabric, &jobs)
        };
        let fifo = run(&Fifo);
        let fair = run(&FairShare::default());
        assert_eq!(fifo.completed(), 3);
        assert_eq!(fair.completed(), 3);

        let fifo_j2 = &fifo.records[2];
        let fair_j2 = &fair.records[2];
        // FIFO: job 2 waits for both wide jobs
        assert!(fifo_j2.start_secs > 1900.0, "{}", fifo_j2.start_secs);
        assert!(!fifo_j2.backfilled);
        assert_eq!(fifo.backfilled_jobs, 0);
        // backfill: job 2 rides along during job 0 or job 1, well before
        // the second wide job completes
        assert!(fair_j2.start_secs < 1100.0, "{}", fair_j2.start_secs);
        assert!(fair_j2.backfilled);
        assert_eq!(fair.backfilled_jobs, 1);
        // and the backfilled run never delays the reserved wide job
        assert!(
            fair.records[1].start_secs <= fifo.records[1].start_secs + 1.0
        );
        // total work is identical, so the shorter makespan means higher
        // utilization
        assert!(fair.makespan_secs <= fifo.makespan_secs + EPS);
        assert!(fair.utilization() >= fifo.utilization() - 1e-12);
    }

    #[test]
    fn fair_share_prefers_the_light_tenant() {
        // tenant 0 hogs the machine first; then one job from the hog and
        // one from an idle tenant wait together — the idle tenant's job
        // must start first even though it arrived later
        let jobs = vec![
            job(0, 0, 0.0, 8, 500.0),
            job(1, 0, 1.0, 8, 100.0),
            job(2, 1, 2.0, 8, 100.0),
        ];
        let (cluster, registry, mut fabric) = setup(8);
        let report = FairShareScheduler::new(&cluster, &registry)
            .run(&mut fabric, &jobs);
        assert_eq!(report.completed(), 3);
        let hog_second = &report.records[1];
        let light = &report.records[2];
        assert!(
            light.start_secs < hog_second.start_secs,
            "light tenant {} must beat the hog's second job {}",
            light.start_secs,
            hog_second.start_secs
        );
    }

    #[test]
    fn impossible_width_fails_instead_of_wedging() {
        let (cluster, registry, mut fabric) = setup(4);
        let jobs = vec![job(0, 0, 0.0, 64, 100.0), job(1, 1, 1.0, 2, 50.0)];
        let report = FairShareScheduler::new(&cluster, &registry)
            .run(&mut fabric, &jobs);
        assert_eq!(report.completed(), 1);
        assert_eq!(report.failed(), 1);
        let wide = &report.records[0];
        assert!(wide
            .error
            .as_deref()
            .unwrap()
            .contains("wider than the cluster"));
    }

    #[test]
    fn telemetry_stitches_job_wait_node_and_app_spans() {
        let (cluster, registry, _) = setup(8);
        let tel = Arc::new(Telemetry::new(true));
        let mut fabric = DistributionFabric::new(2, LustreFs::piz_daint())
            .with_telemetry(Arc::clone(&tel));
        // same contention shape as the backfill test: job 2 backfills
        let jobs = vec![
            job(0, 0, 0.0, 6, 1000.0),
            job(1, 1, 1.0, 8, 1000.0),
            job(2, 2, 2.0, 2, 100.0),
        ];
        let report = FairShareScheduler::new(&cluster, &registry)
            .with_telemetry(Arc::clone(&tel))
            .run(&mut fabric, &jobs);
        assert_eq!(report.completed(), 3);

        let spans = tel.spans();
        let roots: Vec<_> =
            spans.iter().filter(|s| s.category == "job").collect();
        assert_eq!(roots.len(), 3, "exactly one root span per tenant job");
        for rec in &report.records {
            let root = roots
                .iter()
                .find(|s| s.name == format!("job:{}/{:04}", rec.tenant, rec.id))
                .expect("root span for every record");
            assert_eq!(root.parent, None);
            assert!((root.start_secs() - rec.arrival_secs).abs() < 1e-9);
            assert!((root.end_secs() - rec.end_secs).abs() < 1e-6);
            let children: Vec<_> = spans
                .iter()
                .filter(|s| s.parent == Some(root.id))
                .collect();
            // node spans for every slot, an app span, and (for queued
            // jobs) a wait span
            assert_eq!(
                children.iter().filter(|s| s.category == "node").count(),
                rec.width as usize
            );
            assert_eq!(
                children.iter().filter(|s| s.category == "app").count(),
                1
            );
            if rec.wait_secs > 1.0 {
                assert!(children.iter().any(|s| s.category == "wait"));
            }
        }
        assert_eq!(tel.counter("tenancy.starts"), 3);
        assert_eq!(tel.counter("tenancy.backfills"), 1);
        assert!(tel.counter("tenancy.passes") >= 3);
        assert_eq!(tel.histogram("tenancy.wait_secs").unwrap().count, 3);
        // scheduler decisions land on their own track as instant spans
        assert!(spans
            .iter()
            .any(|s| s.category == "sched" && s.track == "scheduler"));
    }

    #[test]
    fn shared_images_coalesce_across_concurrent_jobs() {
        // four jobs, two distinct images, all start in the same pass
        let (cluster, registry, mut fabric) = setup(16);
        let mut jobs: Vec<TenantJob> = (0..4)
            .map(|i| job(i, i, 0.0, 4, 100.0))
            .collect();
        jobs[1].spec.image = "pyfr-image:1.5.0".to_string();
        jobs[3].spec.image = "pyfr-image:1.5.0".to_string();
        let report = FairShareScheduler::new(&cluster, &registry)
            .run(&mut fabric, &jobs);
        assert_eq!(report.completed(), 4);
        assert_eq!(report.unique_images, 2);
        assert_eq!(
            report.coalescing.jobs, 2,
            "exactly one pull job per unique image reference"
        );
        // exact request accounting: one batch-prefetch per job plus one
        // request per node slot (4 jobs x 4 slots), all onto two jobs
        assert_eq!(report.coalescing.requests, 4 + 16);
    }
}
