//! Synthetic multi-tenant traffic (DESIGN.md S20): Poisson job arrivals
//! from a population of simulated tenants, with a configurable mix of
//! GPU/MPI/CPU job classes, Zipf-skewed tenant activity (a few heavy
//! users, a long tail), and Zipf-skewed image popularity — the shape that
//! actually stresses the distribution fabric's dedup and coalescing.
//!
//! Everything is keyed on the deterministic [`crate::util::prng::Rng`], so
//! a `(TrafficModel, seed)` pair regenerates the identical job stream on
//! every run — the property the FIFO-vs-backfill comparison in
//! `benches/tenancy_storm.rs` depends on.

use std::collections::BTreeSet;

use crate::launch::{JobSpec, LaunchCluster};
use crate::util::prng::Rng;

/// Workload class of a synthesized job — decides the image catalog and
/// the GPU/MPI launch flags.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum JobClass {
    /// Plain CPU container (no GRES, no MPI swap).
    Cpu,
    /// CUDA container launched with `--gres=gpu:1` (§IV.A).
    Gpu,
    /// MPI container launched with `--mpi` (§IV.B ABI swap).
    Mpi,
}

impl JobClass {
    /// Stable lowercase name for reports and JSON.
    pub fn name(&self) -> &'static str {
        match self {
            JobClass::Cpu => "cpu",
            JobClass::Gpu => "gpu",
            JobClass::Mpi => "mpi",
        }
    }
}

/// Images each class draws from, most popular rank first. Every entry is
/// in `Registry::dockerhub()` and launches cleanly on both stock
/// partitions (the MPI entries are all MPICH-ABI members, so the §IV.B
/// swap succeeds against Cray MPT and MVAPICH2 hosts alike).
const CPU_IMAGES: [&str; 3] =
    ["ubuntu:xenial", "pynamic:1.3", "pyfr-image:1.5.0"];
const GPU_IMAGES: [&str; 2] = [
    "nvidia/cuda-image:8.0",
    "tensorflow/tensorflow:1.0.0-devel-gpu-py3",
];
const MPI_IMAGES: [&str; 3] = [
    "osu-benchmarks:mpich-3.1.4",
    "osu-benchmarks:mvapich2-2.2",
    "osu-benchmarks:intelmpi-2017.1",
];

/// Zipf(s) sampler over ranks `0..n`: rank `r` is drawn with probability
/// proportional to `1/(r+1)^s`. `s = 0` is uniform; larger `s`
/// concentrates mass on the low ranks.
#[derive(Debug, Clone)]
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    /// Sampler over `n >= 1` ranks with skew exponent `s >= 0`.
    pub fn new(n: usize, s: f64) -> Zipf {
        assert!(n >= 1, "zipf needs at least one rank");
        assert!(s >= 0.0, "zipf skew must be non-negative");
        let weights: Vec<f64> =
            (0..n).map(|r| 1.0 / ((r + 1) as f64).powf(s)).collect();
        let total: f64 = weights.iter().sum();
        let mut acc = 0.0;
        let cdf = weights
            .iter()
            .map(|w| {
                acc += w / total;
                acc
            })
            .collect();
        Zipf { cdf }
    }

    /// Number of ranks.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// Always false (the constructor requires at least one rank).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }

    /// Draw one rank in `0..len()`.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.uniform();
        self.cdf
            .iter()
            .position(|c| u < *c)
            .unwrap_or(self.cdf.len() - 1)
    }
}

/// One synthesized job: who submits it, when, and what it launches.
#[derive(Debug, Clone)]
pub struct TenantJob {
    /// Submission-order id, unique within one generated stream.
    pub id: u32,
    /// Owning tenant name (`tenant-00` …).
    pub tenant: String,
    /// Owning tenant index in `0..TrafficModel::tenants`.
    pub tenant_idx: u32,
    /// Simulated submission time, seconds from the start of the storm.
    pub arrival_secs: f64,
    /// Application runtime once the container is up (the scheduler adds
    /// the measured launch overhead on top).
    pub runtime_secs: f64,
    /// Workload class the job was drawn from.
    pub class: JobClass,
    /// The launchable spec: image, command, width, GPU/MPI flags.
    pub spec: JobSpec,
}

/// Generator for a multi-tenant job stream.
///
/// All fields are public so call sites can literal-update a default
/// (`TrafficModel { tenants: 16, ..TrafficModel::default() }`).
#[derive(Debug, Clone)]
pub struct TrafficModel {
    /// Number of simulated tenants.
    pub tenants: u32,
    /// Number of jobs to synthesize (the stream may stop earlier if
    /// `duration_secs` is exceeded first).
    pub jobs: u32,
    /// Aggregate Poisson arrival rate, jobs per minute.
    pub arrival_rate_per_min: f64,
    /// Stop generating once arrivals pass this horizon (seconds).
    /// `f64::INFINITY` disables the cap.
    pub duration_secs: f64,
    /// Zipf skew over tenant activity (0 = all tenants equally active).
    pub tenant_skew: f64,
    /// Zipf skew over each class's image catalog (0 = uniform).
    pub image_skew: f64,
    /// Widths are powers of two in `1..=max_width` (clamped to the
    /// cluster size at generation time).
    pub max_width: u32,
    /// Mean application runtime in seconds (lognormal around this).
    pub mean_runtime_secs: f64,
    /// Floor on the sampled runtime.
    pub min_runtime_secs: f64,
    /// Lognormal sigma of the runtime distribution.
    pub runtime_sigma: f64,
    /// Relative weight of CPU-class jobs in the mix.
    pub cpu_weight: f64,
    /// Relative weight of GPU-class jobs in the mix.
    pub gpu_weight: f64,
    /// Relative weight of MPI-class jobs in the mix.
    pub mpi_weight: f64,
    /// PRNG seed: same seed, same stream.
    pub seed: u64,
}

impl Default for TrafficModel {
    fn default() -> TrafficModel {
        TrafficModel {
            tenants: 8,
            jobs: 64,
            arrival_rate_per_min: 2.4,
            duration_secs: f64::INFINITY,
            tenant_skew: 1.0,
            image_skew: 1.1,
            max_width: 512,
            mean_runtime_secs: 600.0,
            min_runtime_secs: 60.0,
            runtime_sigma: 0.6,
            cpu_weight: 0.5,
            gpu_weight: 0.3,
            mpi_weight: 0.2,
            seed: 7,
        }
    }
}

impl TrafficModel {
    /// Synthesize the job stream for `cluster`, sorted by arrival time.
    ///
    /// Widths are clamped so every job fits the cluster; the per-class
    /// image catalogs only name images that launch successfully on the
    /// stock profiles, so a generated stream runs to completion.
    pub fn generate(&self, cluster: &LaunchCluster) -> Vec<TenantJob> {
        assert!(self.tenants >= 1, "need at least one tenant");
        assert!(
            self.arrival_rate_per_min > 0.0,
            "arrival rate must be positive"
        );
        let class_total = self.cpu_weight + self.gpu_weight + self.mpi_weight;
        assert!(class_total > 0.0, "job mix weights must sum positive");

        let mut rng =
            Rng::from_tags(&["tenancy-traffic", &self.seed.to_string()]);
        let tenant_zipf = Zipf::new(self.tenants as usize, self.tenant_skew);
        let cpu_zipf = Zipf::new(CPU_IMAGES.len(), self.image_skew);
        let gpu_zipf = Zipf::new(GPU_IMAGES.len(), self.image_skew);
        let mpi_zipf = Zipf::new(MPI_IMAGES.len(), self.image_skew);

        let max_width = self.max_width.min(cluster.total_nodes()).max(1);
        let log2_max = 31 - max_width.leading_zeros(); // floor(log2)
        let rate_per_sec = self.arrival_rate_per_min / 60.0;

        let mut t = 0.0;
        let mut out: Vec<TenantJob> = Vec::with_capacity(self.jobs as usize);
        for id in 0..self.jobs {
            // exponential inter-arrival; 1 - U is in (0, 1]
            t += -(1.0 - rng.uniform()).ln() / rate_per_sec;
            if t > self.duration_secs {
                break;
            }
            let tenant_idx = tenant_zipf.sample(&mut rng) as u32;
            let class = {
                let x = rng.uniform() * class_total;
                if x < self.cpu_weight {
                    JobClass::Cpu
                } else if x < self.cpu_weight + self.gpu_weight {
                    JobClass::Gpu
                } else {
                    JobClass::Mpi
                }
            };
            let image = match class {
                JobClass::Cpu => CPU_IMAGES[cpu_zipf.sample(&mut rng)],
                JobClass::Gpu => GPU_IMAGES[gpu_zipf.sample(&mut rng)],
                JobClass::Mpi => MPI_IMAGES[mpi_zipf.sample(&mut rng)],
            };
            let width = 1u32 << rng.below(u64::from(log2_max) + 1);
            let runtime = (self.mean_runtime_secs
                * rng.lognormal_noise(self.runtime_sigma))
            .max(self.min_runtime_secs);
            let mut spec = match class {
                JobClass::Cpu => JobSpec::new(image, &["true"], width),
                JobClass::Gpu => {
                    JobSpec::new(image, &["deviceQuery"], width).with_gpus(1)
                }
                JobClass::Mpi => {
                    JobSpec::new(image, &["true"], width).with_mpi()
                }
            };
            spec.invoking_uid = 1000 + tenant_idx;
            spec.invoking_gid = 1000 + tenant_idx;
            out.push(TenantJob {
                id,
                tenant: format!("tenant-{tenant_idx:02}"),
                tenant_idx,
                arrival_secs: t,
                runtime_secs: runtime,
                class,
                spec,
            });
        }
        out
    }
}

/// Distinct image references a job stream pulls — the denominator of the
/// "exactly one pull job per unique reference" acceptance check.
pub fn unique_image_refs(jobs: &[TenantJob]) -> BTreeSet<String> {
    jobs.iter().map(|j| j.spec.image.clone()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    fn cluster() -> LaunchCluster {
        LaunchCluster::homogeneous(&SystemProfile::piz_daint(), 64)
    }

    #[test]
    fn generation_is_deterministic() {
        let model = TrafficModel::default();
        let a = model.generate(&cluster());
        let b = model.generate(&cluster());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(&b) {
            assert_eq!(x.id, y.id);
            assert_eq!(x.tenant, y.tenant);
            assert_eq!(x.spec.image, y.spec.image);
            assert_eq!(x.arrival_secs, y.arrival_secs);
            assert_eq!(x.runtime_secs, y.runtime_secs);
        }
        // a different seed produces a different stream
        let c = TrafficModel {
            seed: 8,
            ..TrafficModel::default()
        }
        .generate(&cluster());
        assert!(a
            .iter()
            .zip(&c)
            .any(|(x, y)| x.arrival_secs != y.arrival_secs));
    }

    #[test]
    fn arrivals_are_sorted_and_widths_fit() {
        let jobs = TrafficModel::default().generate(&cluster());
        assert_eq!(jobs.len(), 64);
        let mut last = 0.0;
        for j in &jobs {
            assert!(j.arrival_secs >= last);
            last = j.arrival_secs;
            assert!((1..=64).contains(&j.spec.nodes));
            assert!(j.spec.nodes.is_power_of_two());
            assert!(j.runtime_secs >= 60.0);
        }
    }

    #[test]
    fn class_flags_match_the_class() {
        let jobs = TrafficModel {
            jobs: 200,
            ..TrafficModel::default()
        }
        .generate(&cluster());
        let mut seen = [false; 3];
        for j in &jobs {
            match j.class {
                JobClass::Cpu => {
                    seen[0] = true;
                    assert_eq!(j.spec.gpus_per_node, 0);
                    assert!(!j.spec.mpi);
                }
                JobClass::Gpu => {
                    seen[1] = true;
                    assert_eq!(j.spec.gpus_per_node, 1);
                    assert!(!j.spec.mpi);
                }
                JobClass::Mpi => {
                    seen[2] = true;
                    assert!(j.spec.mpi);
                    assert!(j.spec.image.starts_with("osu-benchmarks:"));
                }
            }
            // tenant identity propagates into the launch credentials
            assert_eq!(j.spec.invoking_uid, 1000 + j.tenant_idx);
        }
        assert!(seen.iter().all(|s| *s), "200 jobs must hit every class");
    }

    #[test]
    fn tenant_skew_concentrates_activity() {
        let jobs = TrafficModel {
            jobs: 300,
            tenant_skew: 1.2,
            ..TrafficModel::default()
        }
        .generate(&cluster());
        let count = |idx: u32| {
            jobs.iter().filter(|j| j.tenant_idx == idx).count()
        };
        assert!(
            count(0) > count(7) * 2,
            "rank-0 tenant must dominate the tail: {} vs {}",
            count(0),
            count(7)
        );
    }

    #[test]
    fn image_popularity_is_skewed_for_dedup() {
        let jobs = TrafficModel {
            jobs: 300,
            ..TrafficModel::default()
        }
        .generate(&cluster());
        let unique = unique_image_refs(&jobs);
        assert!(unique.len() >= 4, "the mix must exercise several images");
        assert!(
            (unique.len() as u32) < 300,
            "many jobs share few images — dedup is exercised"
        );
    }

    #[test]
    fn duration_cap_truncates_the_stream() {
        let full = TrafficModel::default().generate(&cluster());
        let capped = TrafficModel {
            duration_secs: full[10].arrival_secs,
            ..TrafficModel::default()
        }
        .generate(&cluster());
        assert_eq!(capped.len(), 11, "arrivals after the horizon are cut");
    }

    #[test]
    fn zipf_rank_zero_dominates() {
        let z = Zipf::new(10, 1.0);
        assert_eq!(z.len(), 10);
        let mut rng = Rng::new(3);
        let mut counts = [0usize; 10];
        for _ in 0..10_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[9] * 3);
        // uniform when s = 0
        let u = Zipf::new(4, 0.0);
        let mut counts = [0usize; 4];
        for _ in 0..10_000 {
            counts[u.sample(&mut rng)] += 1;
        }
        assert!(counts.iter().all(|c| *c > 2000));
    }
}
