//! Pluggable queue-ordering policies for the multi-tenant scheduler.
//!
//! PR 3 hard-wired the scheduling discipline as a two-variant enum
//! matched inside [`super::scheduler::FairShareScheduler`]; this module
//! extracts that decision into a trait so a policy is an *object* a site
//! can configure (`SiteBuilder::scheduling_policy(Box<dyn
//! SchedulingPolicy>)`) and third-party scenarios can implement without
//! touching the scheduler's event loop.
//!
//! A policy answers exactly two questions:
//!
//! * **Ordering** — [`SchedulingPolicy::priority`]: the sort key of a
//!   queued job at the current scheduling pass (higher starts first;
//!   ties break by arrival time, then job id — the scheduler owns the
//!   tie-break so every policy is deterministic).
//! * **Hole-filling** — [`SchedulingPolicy::backfill`]: whether a
//!   lower-priority job may start ahead of a blocked higher-priority one
//!   through the conservative-backfill reservation timeline (`true`), or
//!   head-of-line blocking applies (`false`).
//!
//! The two builtins reproduce PR 3's behavior exactly: [`Fifo`] (strict
//! arrival order, head-of-line blocking) and [`FairShare`] (SLURM-style
//! `2^(-U/S)` fair-share factor plus linear aging, with conservative
//! backfill).

use crate::wlm::fairshare::ShareLedger;

use super::traffic::TenantJob;

/// A queue-ordering and hole-filling discipline for the storm scheduler.
///
/// Implementations must be deterministic: the scheduler calls
/// [`Self::priority`] once per queued job per scheduling pass and sorts
/// by the returned key (descending), breaking ties by arrival time and
/// job id. `Send + Sync` so a boxed policy can live inside a
/// [`crate::Site`] that is shared across launch worker threads.
pub trait SchedulingPolicy: Send + Sync {
    /// Stable lowercase policy name for reports and JSON artifacts
    /// (e.g. `"fifo"`, `"fair-share"`).
    fn name(&self) -> &str;

    /// Sort key (descending — higher starts first) for `job`, which has
    /// been queued for `wait_secs` simulated seconds. `ledger` carries
    /// the per-tenant share accounting the fair-share factor reads;
    /// policies that do not care about tenancy may ignore it.
    fn priority(
        &self,
        job: &TenantJob,
        wait_secs: f64,
        ledger: &ShareLedger,
    ) -> f64;

    /// Whether lower-priority jobs may start ahead of a blocked
    /// higher-priority job via conservative backfill (`true`), or strict
    /// head-of-line blocking applies (`false`). Backfill never delays a
    /// higher-priority reservation either way — the scheduler enforces
    /// that invariant, the policy only opts in.
    fn backfill(&self) -> bool;
}

/// Strict arrival order with head-of-line blocking: when the oldest job
/// does not fit, nothing behind it may start. The baseline the storm
/// bench compares against.
#[derive(Debug, Clone, Copy, Default)]
pub struct Fifo;

impl SchedulingPolicy for Fifo {
    fn name(&self) -> &str {
        "fifo"
    }

    /// All jobs share priority 0.0 — the scheduler's arrival-time
    /// tie-break then yields exact submission order.
    fn priority(&self, _: &TenantJob, _: f64, _: &ShareLedger) -> f64 {
        0.0
    }

    fn backfill(&self) -> bool {
        false
    }
}

/// SLURM-style fair-share priority with linear aging and conservative
/// backfill (see [`ShareLedger::priority`]): the share term is capped at
/// 1.0 while the aging term grows without bound, so no waiting job
/// starves.
#[derive(Debug, Clone, Copy)]
pub struct FairShare {
    // private: positivity is the bounded-starvation invariant, and only
    // [`FairShare::new`] / [`Default`] can construct the policy
    aging_per_hour: f64,
}

impl FairShare {
    /// Fair-share policy with an explicit aging weight (> 0 — the
    /// bounded-starvation guarantee needs the aging term to grow).
    pub fn new(aging_per_hour: f64) -> FairShare {
        assert!(
            aging_per_hour > 0.0,
            "aging must be positive to bound starvation"
        );
        FairShare { aging_per_hour }
    }

    /// Priority points one hour of queue wait is worth.
    pub fn aging_per_hour(&self) -> f64 {
        self.aging_per_hour
    }
}

impl Default for FairShare {
    /// The stock aging weight (2.0 priority points per queued hour).
    fn default() -> FairShare {
        FairShare { aging_per_hour: 2.0 }
    }
}

/// The scheduler's default policy instance (fair-share, stock aging).
pub(crate) static DEFAULT_POLICY: FairShare = FairShare { aging_per_hour: 2.0 };

impl SchedulingPolicy for FairShare {
    fn name(&self) -> &str {
        "fair-share"
    }

    fn priority(
        &self,
        job: &TenantJob,
        wait_secs: f64,
        ledger: &ShareLedger,
    ) -> f64 {
        ledger.priority(&job.tenant, wait_secs, self.aging_per_hour)
    }

    fn backfill(&self) -> bool {
        true
    }
}

/// Resolve a CLI policy name (`fifo`, `fair`, `fair-share`) to a boxed
/// builtin policy. Returns `None` for unknown names.
pub fn policy_by_name(name: &str) -> Option<Box<dyn SchedulingPolicy>> {
    match name {
        "fifo" => Some(Box::new(Fifo)),
        "fair" | "fair-share" => Some(Box::new(FairShare::default())),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::launch::JobSpec;
    use crate::tenancy::traffic::JobClass;

    fn job(tenant: u32, runtime: f64) -> TenantJob {
        TenantJob {
            id: 0,
            tenant: format!("tenant-{tenant:02}"),
            tenant_idx: tenant,
            arrival_secs: 0.0,
            runtime_secs: runtime,
            class: JobClass::Cpu,
            spec: JobSpec::new("ubuntu:xenial", &["true"], 1),
        }
    }

    #[test]
    fn fifo_is_flat_and_blocking() {
        let ledger = ShareLedger::new();
        assert_eq!(Fifo.priority(&job(0, 10.0), 1e6, &ledger), 0.0);
        assert!(!Fifo.backfill());
        assert_eq!(Fifo.name(), "fifo");
    }

    #[test]
    fn fair_share_ages_and_backfills() {
        let mut ledger = ShareLedger::new();
        ledger.ensure("tenant-00");
        let fair = FairShare::default();
        let fresh = fair.priority(&job(0, 10.0), 0.0, &ledger);
        let aged = fair.priority(&job(0, 10.0), 3600.0, &ledger);
        assert!(
            (aged - fresh - fair.aging_per_hour()).abs() < 1e-12,
            "one queued hour is worth exactly the aging weight"
        );
        assert!(fair.backfill());
        assert_eq!(fair.name(), "fair-share");
    }

    #[test]
    fn heavy_tenant_ranks_below_idle_tenant() {
        let mut ledger = ShareLedger::new();
        ledger.ensure("tenant-00");
        ledger.ensure("tenant-01");
        ledger.charge("tenant-00", 1e6);
        let fair = FairShare::default();
        let hog = fair.priority(&job(0, 10.0), 0.0, &ledger);
        let idle = fair.priority(&job(1, 10.0), 0.0, &ledger);
        assert!(idle > hog);
    }

    #[test]
    fn builtin_policies_resolve_by_name() {
        assert_eq!(policy_by_name("fifo").unwrap().name(), "fifo");
        assert_eq!(policy_by_name("fair").unwrap().name(), "fair-share");
        assert_eq!(policy_by_name("fair-share").unwrap().name(), "fair-share");
        assert!(policy_by_name("srtf").is_none());
    }
}
