//! Multi-tenant storm aggregation (DESIGN.md S20): per-tenant
//! queue-wait/stretch percentiles, starvation detection, cluster
//! utilization, backfill accounting, and the gateway-side interference
//! counters (pull queue waits, cross-job coalescing, node caches) —
//! rendered for the CLI and serialized as `BENCH_tenancy.json`.

use std::collections::BTreeMap;

use crate::distrib::{CacheStats, CoalescingStats};
use crate::metrics::{Stats, Table};
use crate::util::json::Json;

use super::traffic::JobClass;

/// One job's scheduling outcome.
#[derive(Debug, Clone)]
pub struct JobRecord {
    /// Submission-order id from the traffic stream.
    pub id: u32,
    /// Owning tenant name.
    pub tenant: String,
    /// Owning tenant index.
    pub tenant_idx: u32,
    /// Workload class the job was drawn from.
    pub class: JobClass,
    /// Image reference the job launched.
    pub image: String,
    /// Node width.
    pub width: u32,
    /// Submission time (storm seconds).
    pub arrival_secs: f64,
    /// Time the scheduler dispatched the job.
    pub start_secs: f64,
    /// Time the job released its nodes.
    pub end_secs: f64,
    /// Occupancy duration: application runtime plus measured launch
    /// overhead (0.0 when the launch failed outright).
    pub service_secs: f64,
    /// Queue wait (`start - arrival`).
    pub wait_secs: f64,
    /// The job started while a higher-priority job was still blocked —
    /// it ran in a backfill hole.
    pub backfilled: bool,
    /// Node slots that failed inside an otherwise-running job.
    pub failed_slots: usize,
    /// Whole-job failure (WLM rejection, pull failure, unschedulable).
    pub error: Option<String>,
}

impl JobRecord {
    /// True when the job launched (individual slots may still have
    /// failed; see `failed_slots`).
    pub fn ok(&self) -> bool {
        self.error.is_none()
    }

    /// Slowdown factor `(wait + service) / service` — 1.0 is a job that
    /// started the moment it arrived. `None` for failed jobs.
    pub fn stretch(&self) -> Option<f64> {
        (self.ok() && self.service_secs > 0.0)
            .then(|| (self.wait_secs + self.service_secs) / self.service_secs)
    }
}

/// Aggregates for one tenant.
#[derive(Debug, Clone)]
pub struct TenantStats {
    /// Tenant name.
    pub tenant: String,
    /// Jobs the tenant completed.
    pub jobs: usize,
    /// Node-seconds the tenant consumed.
    pub node_secs: f64,
    /// Queue-wait distribution over the tenant's completed jobs.
    pub wait: Stats,
    /// Stretch distribution over the tenant's completed jobs.
    pub stretch: Stats,
}

/// What a multi-tenant storm run produces — the S20 counterpart of the
/// single-job `LaunchReport`.
#[derive(Debug, Clone)]
pub struct TenancyReport {
    /// Scheduling policy that produced this run (`fifo`, `fair-share`).
    pub policy: String,
    /// Cluster width the storm ran on.
    pub total_nodes: u32,
    /// Per-job outcomes, in submission order.
    pub records: Vec<JobRecord>,
    /// Per-tenant aggregates (tenants with at least one completed job),
    /// in tenant-name order.
    pub tenants: Vec<TenantStats>,
    /// Time from storm start until the last job released its nodes.
    pub makespan_secs: f64,
    /// Node-seconds of occupancy summed over all completed jobs.
    pub busy_node_secs: f64,
    /// Jobs that started in a backfill hole.
    pub backfilled_jobs: usize,
    /// Distinct image references the stream pulled.
    pub unique_images: usize,
    /// Cross-job pull coalescing counters from the fabric.
    pub coalescing: CoalescingStats,
    /// Gateway queue-wait distribution across all pull jobs (None when
    /// nothing was ever pulled).
    pub pull_queue_wait: Option<Stats>,
    /// Node-cache counters across the fabric after the storm.
    pub cache: CacheStats,
}

impl TenancyReport {
    /// Assemble a report from per-job records plus the fabric-side
    /// counters captured after the storm drained.
    pub fn from_records(
        policy: &str,
        total_nodes: u32,
        records: Vec<JobRecord>,
        coalescing: CoalescingStats,
        pull_queue_wait: Option<Stats>,
        cache: CacheStats,
    ) -> TenancyReport {
        let makespan_secs = records
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.end_secs)
            .fold(0.0, f64::max);
        let busy_node_secs = records
            .iter()
            .filter(|r| r.ok())
            .map(|r| f64::from(r.width) * r.service_secs)
            .sum();
        let backfilled_jobs =
            records.iter().filter(|r| r.ok() && r.backfilled).count();
        let unique_images = records
            .iter()
            .map(|r| r.image.as_str())
            .collect::<std::collections::BTreeSet<_>>()
            .len();
        let mut by_tenant: BTreeMap<&str, Vec<&JobRecord>> = BTreeMap::new();
        for r in records.iter().filter(|r| r.ok()) {
            by_tenant.entry(r.tenant.as_str()).or_default().push(r);
        }
        let tenants = by_tenant
            .into_iter()
            .map(|(tenant, rs)| {
                let waits: Vec<f64> = rs.iter().map(|r| r.wait_secs).collect();
                let mut stretches: Vec<f64> =
                    rs.iter().filter_map(|r| r.stretch()).collect();
                if stretches.is_empty() {
                    // zero-service completed jobs only — nothing waited
                    stretches.push(1.0);
                }
                TenantStats {
                    tenant: tenant.to_string(),
                    jobs: rs.len(),
                    node_secs: rs
                        .iter()
                        .map(|r| f64::from(r.width) * r.service_secs)
                        .sum(),
                    wait: Stats::from_samples(&waits),
                    stretch: Stats::from_samples(&stretches),
                }
            })
            .collect();
        TenancyReport {
            policy: policy.to_string(),
            total_nodes,
            records,
            tenants,
            makespan_secs,
            busy_node_secs,
            backfilled_jobs,
            unique_images,
            coalescing,
            pull_queue_wait,
            cache,
        }
    }

    /// Jobs that launched.
    pub fn completed(&self) -> usize {
        self.records.iter().filter(|r| r.ok()).count()
    }

    /// Jobs that failed outright.
    pub fn failed(&self) -> usize {
        self.records.len() - self.completed()
    }

    /// Fraction of the cluster kept busy over the storm's makespan,
    /// in [0, 1].
    pub fn utilization(&self) -> f64 {
        if self.total_nodes == 0 || self.makespan_secs <= 0.0 {
            return 0.0;
        }
        self.busy_node_secs
            / (f64::from(self.total_nodes) * self.makespan_secs)
    }

    /// Worst stretch any completed job saw (1.0 when nothing waited).
    pub fn max_stretch(&self) -> f64 {
        self.records
            .iter()
            .filter_map(|r| r.stretch())
            .fold(1.0, f64::max)
    }

    /// Starvation detection: tenants whose worst stretch exceeds
    /// `stretch_bound`. An empty result is the bounded-starvation
    /// guarantee the storm bench asserts.
    pub fn starved_tenants(&self, stretch_bound: f64) -> Vec<String> {
        self.tenants
            .iter()
            .filter(|t| t.stretch.worst > stretch_bound)
            .map(|t| t.tenant.clone())
            .collect()
    }

    /// Queue-wait distribution over all completed jobs.
    pub fn wait_stats(&self) -> Option<Stats> {
        let waits: Vec<f64> = self
            .records
            .iter()
            .filter(|r| r.ok())
            .map(|r| r.wait_secs)
            .collect();
        if waits.is_empty() {
            None
        } else {
            Some(Stats::from_samples(&waits))
        }
    }

    /// Render the per-tenant table plus the cluster/gateway summary the
    /// `shifterimg storm` subcommand prints.
    pub fn render(&self) -> String {
        let fmt = |v: f64| format!("{v:.1}s");
        let mut table = Table::new(
            &format!(
                "tenancy storm [{}]: {} jobs ({} ok, {} failed) from {} \
                 tenants on {} nodes",
                self.policy,
                self.records.len(),
                self.completed(),
                self.failed(),
                self.tenants.len(),
                self.total_nodes
            ),
            &[
                "tenant", "jobs", "node-secs", "wait-p50", "wait-p99",
                "stretch-p50", "stretch-max",
            ],
        );
        for t in &self.tenants {
            table.row(&[
                t.tenant.clone(),
                t.jobs.to_string(),
                format!("{:.0}", t.node_secs),
                fmt(t.wait.p50),
                fmt(t.wait.p99),
                format!("{:.2}", t.stretch.p50),
                format!("{:.2}", t.stretch.worst),
            ]);
        }
        let mut out = table.render();
        out.push_str(&format!(
            "cluster: {:.1}% utilization over {:.0}s makespan, {} \
             backfilled job(s)\n",
            self.utilization() * 100.0,
            self.makespan_secs,
            self.backfilled_jobs,
        ));
        out.push_str(&format!(
            "gateway: {} pull requests coalesced into {} job(s) for {} \
             unique image(s) ({:.1}x dedup)\n",
            self.coalescing.requests,
            self.coalescing.jobs,
            self.unique_images,
            self.coalescing.ratio(),
        ));
        if let Some(wait) = &self.pull_queue_wait {
            out.push_str(&format!(
                "pull interference: queue wait p50 {:.2}s, p99 {:.2}s, \
                 worst {:.2}s across {} pull job(s)\n",
                wait.p50, wait.p99, wait.worst, wait.n,
            ));
        }
        out.push_str(&format!(
            "node caches: {} hits / {} misses / {} evictions on {} nodes\n",
            self.cache.hits,
            self.cache.misses,
            self.cache.evictions,
            self.cache.nodes,
        ));
        out
    }

    /// JSON shape for `BENCH_tenancy.json` (the CI bench-smoke artifact).
    pub fn to_json(&self) -> Json {
        let tenants: Vec<Json> = self
            .tenants
            .iter()
            .map(|t| {
                Json::obj(vec![
                    ("tenant", Json::str(t.tenant.as_str())),
                    ("jobs", Json::Num(t.jobs as f64)),
                    ("node_secs", Json::Num(t.node_secs)),
                    ("wait_secs", t.wait.to_json()),
                    ("stretch", t.stretch.to_json()),
                ])
            })
            .collect();
        let jobs: Vec<Json> = self
            .records
            .iter()
            .map(|r| {
                Json::obj(vec![
                    ("id", Json::Num(f64::from(r.id))),
                    ("tenant", Json::str(r.tenant.as_str())),
                    ("class", Json::str(r.class.name())),
                    ("image", Json::str(r.image.as_str())),
                    ("width", Json::Num(f64::from(r.width))),
                    ("arrival_secs", Json::Num(r.arrival_secs)),
                    ("start_secs", Json::Num(r.start_secs)),
                    ("end_secs", Json::Num(r.end_secs)),
                    ("wait_secs", Json::Num(r.wait_secs)),
                    (
                        "stretch",
                        r.stretch().map_or(Json::Null, Json::Num),
                    ),
                    ("backfilled", Json::Bool(r.backfilled)),
                    ("ok", Json::Bool(r.ok())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("policy", Json::str(self.policy.as_str())),
            ("total_nodes", Json::Num(f64::from(self.total_nodes))),
            ("completed", Json::Num(self.completed() as f64)),
            ("failed", Json::Num(self.failed() as f64)),
            ("makespan_secs", Json::Num(self.makespan_secs)),
            ("busy_node_secs", Json::Num(self.busy_node_secs)),
            ("utilization", Json::Num(self.utilization())),
            ("backfilled_jobs", Json::Num(self.backfilled_jobs as f64)),
            ("max_stretch", Json::Num(self.max_stretch())),
            ("unique_images", Json::Num(self.unique_images as f64)),
            (
                "coalescing",
                Json::obj(vec![
                    (
                        "requests",
                        Json::Num(self.coalescing.requests as f64),
                    ),
                    ("jobs", Json::Num(self.coalescing.jobs as f64)),
                    ("ratio", Json::Num(self.coalescing.ratio())),
                ]),
            ),
            (
                "pull_queue_wait",
                self.pull_queue_wait
                    .as_ref()
                    .map_or(Json::Null, |s| s.to_json()),
            ),
            (
                "node_caches",
                Json::obj(vec![
                    ("nodes", Json::Num(self.cache.nodes as f64)),
                    ("hits", Json::Num(self.cache.hits as f64)),
                    ("misses", Json::Num(self.cache.misses as f64)),
                    ("evictions", Json::Num(self.cache.evictions as f64)),
                ]),
            ),
            ("tenants", Json::Arr(tenants)),
            ("jobs", Json::Arr(jobs)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(
        id: u32,
        tenant: &str,
        width: u32,
        arrival: f64,
        start: f64,
        service: f64,
    ) -> JobRecord {
        JobRecord {
            id,
            tenant: tenant.to_string(),
            tenant_idx: 0,
            class: JobClass::Cpu,
            image: "ubuntu:xenial".to_string(),
            width,
            arrival_secs: arrival,
            start_secs: start,
            end_secs: start + service,
            service_secs: service,
            wait_secs: start - arrival,
            backfilled: false,
            failed_slots: 0,
            error: None,
        }
    }

    fn report(records: Vec<JobRecord>) -> TenancyReport {
        TenancyReport::from_records(
            "fair-share",
            16,
            records,
            CoalescingStats {
                requests: 24,
                jobs: 1,
            },
            None,
            CacheStats::default(),
        )
    }

    #[test]
    fn utilization_and_stretch_roll_up() {
        // two jobs: 8 nodes x 100s back to back on a 16-node cluster
        let rep = report(vec![
            record(0, "a", 8, 0.0, 0.0, 100.0),
            record(1, "b", 8, 0.0, 100.0, 100.0),
        ]);
        assert_eq!(rep.completed(), 2);
        assert_eq!(rep.makespan_secs, 200.0);
        // 1600 busy node-secs over 16 * 200 available
        assert!((rep.utilization() - 0.5).abs() < 1e-12);
        // job 1 waited 100s for a 100s job: stretch 2.0
        assert!((rep.max_stretch() - 2.0).abs() < 1e-12);
        assert_eq!(rep.tenants.len(), 2);
        assert!(rep.starved_tenants(10.0).is_empty());
        assert_eq!(rep.starved_tenants(1.5), vec!["b".to_string()]);
    }

    #[test]
    fn failed_jobs_are_excluded_from_aggregates() {
        let mut bad = record(2, "a", 4, 0.0, 0.0, 0.0);
        bad.error = Some("pull failed".to_string());
        let rep = report(vec![record(0, "a", 8, 0.0, 0.0, 100.0), bad]);
        assert_eq!(rep.completed(), 1);
        assert_eq!(rep.failed(), 1);
        assert_eq!(rep.tenants[0].jobs, 1);
        assert_eq!(rep.makespan_secs, 100.0);
        assert!(rep.render().contains("1 failed"));
    }

    #[test]
    fn json_round_trips() {
        let rep = report(vec![record(0, "a", 8, 0.0, 5.0, 100.0)]);
        let json = rep.to_json();
        assert_eq!(json.get("completed").unwrap().as_u64(), Some(1));
        assert_eq!(
            json.at(&["coalescing", "jobs"]).unwrap().as_u64(),
            Some(1)
        );
        let back = Json::parse(&json.to_string()).unwrap();
        assert_eq!(
            back.get("policy").unwrap().as_str(),
            Some("fair-share")
        );
        assert_eq!(back.get("jobs").unwrap().as_arr().unwrap().len(), 1);
    }
}
