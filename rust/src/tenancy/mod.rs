//! Multi-tenant workload layer (DESIGN.md S20): the paper's promise is
//! that containers let *many independent researchers* deploy software
//! onto shared supercomputers (§I) — this module actually exercises that
//! claim at cluster scale, where PR 2's orchestrator launched exactly one
//! job at a time.
//!
//! Three pieces:
//!
//! * [`traffic::TrafficModel`] — synthesizes the competing-job stream:
//!   Poisson arrivals, a tenant population with Zipf-skewed activity, a
//!   GPU/MPI/CPU class mix, and Zipf-skewed image popularity so the
//!   distribution fabric's dedup/coalescing is genuinely stressed.
//! * [`scheduler::FairShareScheduler`] — a discrete-event simulation
//!   that extends `wlm::` with per-tenant share accounting
//!   ([`crate::wlm::fairshare::ShareLedger`]), priority aging, and
//!   conservative backfill over the partition slot map, dispatching each
//!   placed job through the re-entrant
//!   [`crate::launch::LaunchScheduler::launch_on`] against one shared
//!   [`crate::distrib::DistributionFabric`]. The queue discipline is a
//!   pluggable [`policy::SchedulingPolicy`] trait object ([`policy::Fifo`]
//!   and [`policy::FairShare`] are the builtins; sites select one via
//!   [`crate::SiteBuilder::scheduling_policy`]).
//! * [`report::TenancyReport`] — per-tenant queue-wait/stretch
//!   percentiles, starvation detection, backfill and cross-job pull
//!   coalescing accounting, cluster utilization; serialized to
//!   `BENCH_tenancy.json` by `benches/tenancy_storm.rs`.
//!
//! CLI: `shifterimg storm --tenants=8 --jobs=64 --arrival-rate=2.4`.

pub mod policy;
pub mod report;
pub mod scheduler;
pub mod traffic;

pub use policy::{policy_by_name, FairShare, Fifo, SchedulingPolicy};
pub use report::{JobRecord, TenancyReport, TenantStats};
pub use scheduler::FairShareScheduler;
pub use traffic::{unique_image_refs, JobClass, TenantJob, TrafficModel, Zipf};
