//! Native specialized-network support — the third host-resource injection
//! alongside §IV.A (GPU) and §IV.B (MPI).
//!
//! Activation trigger (see [`NetworkSupport::trigger`]): the
//! `SHIFTER_NET` launch variable (`host`/`native`/`1`), or a fabric label
//! on the image itself; `SHIFTER_NET_FALLBACK` vetoes both and keeps the
//! container on the TCP path. When triggered, two operations run:
//!
//!   1. bind mount the host's fabric transport libraries at their host
//!      paths (uGNI/DMAPP on Aries, verbs/RDMA on InfiniBand) — mirroring
//!      how §IV.B mounts the host MPI's transport dependencies;
//!   2. graft the fabric device files into the container (`/dev/kgni0` +
//!      `/dev/hugepages` on Aries, `/dev/infiniband/*` on InfiniBand) —
//!      mirroring how §IV.A grafts `/dev/nvidia*`.
//!
//! The compatibility gate ([`check`]) mirrors the §IV.B libtool ABI
//! comparison via [`NetAbi`].

use std::collections::BTreeMap;

use crate::config::UdiRootConfig;
use crate::hostenv::SystemProfile;
use crate::image::builder::{LABEL_NET_ABI, LABEL_NET_FABRIC};
use crate::shifter::extension::{
    Activation, Capability, ExtensionContext, ExtensionError,
    ExtensionPayload, ExtensionReport, HostExtension,
};
use crate::vfs::{MountTable, VirtualFs};

use super::NetAbi;

/// Failures of the specialized-network support procedure.
#[derive(Debug, thiserror::Error, Clone, PartialEq)]
#[non_exhaustive]
pub enum NetSupportError {
    /// The host has no specialized fabric to expose (loopback only).
    #[error("host system has no specialized network fabric (loopback only)")]
    NoHostFabric,
    /// The image was built for a different transport family than the
    /// host fabric provides.
    #[error(
        "container was built for transport '{container}' but the host \
         fabric provides '{host}'"
    )]
    FabricMismatch {
        /// Transport family the image declares.
        container: String,
        /// Transport family the host fabric provides.
        host: String,
    },
    /// The transport ABI comparison refused the injection (same rule
    /// shape as the §IV.B libtool check).
    #[error(
        "container transport ABI {container_abi} is newer than the host's \
         {host_abi}"
    )]
    AbiIncompatible {
        /// The container's declared transport ABI string.
        container_abi: String,
        /// The host's transport ABI string.
        host_abi: String,
    },
    /// The image's net ABI label could not be parsed.
    #[error("container net ABI label is unparsable: {0}")]
    BadAbiMetadata(String),
    /// A host transport library named by `udiRoot.conf` is absent on
    /// the host filesystem.
    #[error("host transport library missing: {0}")]
    MissingHostLibrary(String),
    /// A fabric device file named by `udiRoot.conf` is absent on the
    /// host filesystem.
    #[error("host fabric device missing: {0}")]
    MissingHostDevice(String),
    /// Grafting a host node into the container rootfs failed (path
    /// conflict inside the image tree).
    #[error("container rootfs graft failed: {0}")]
    Rootfs(#[from] crate::vfs::VfsError),
}

/// What specialized-network support did to the container.
#[derive(Debug, Clone, PartialEq)]
pub struct NetSupportReport {
    /// Host fabric name (e.g. "Cray Aries").
    pub fabric: String,
    /// Transport family injected ("gni" / "verbs").
    pub transport: String,
    /// The host's transport ABI string.
    pub host_abi: String,
    /// Transport libraries bind-mounted at their host paths.
    pub libraries: Vec<String>,
    /// Fabric device files grafted into the container.
    pub device_files: Vec<String>,
}

/// The compatibility gate: resolve the host's transport ABI and compare
/// it against the image's declared transport labels (when present — a
/// portable TCP build carries none and passes vacuously). Mirrors the
/// §IV.B libtool ABI-string comparison.
pub fn check(
    image_labels: &BTreeMap<String, String>,
    profile: &SystemProfile,
) -> Result<NetAbi, NetSupportError> {
    let host_abi = profile.net_abi().ok_or(NetSupportError::NoHostFabric)?;
    if let Some(fabric) = image_labels.get(LABEL_NET_FABRIC) {
        if *fabric != host_abi.transport {
            return Err(NetSupportError::FabricMismatch {
                container: fabric.clone(),
                host: host_abi.transport.clone(),
            });
        }
    }
    if let Some(abi_str) = image_labels.get(LABEL_NET_ABI) {
        let container = NetAbi::parse(abi_str)
            .ok_or_else(|| NetSupportError::BadAbiMetadata(abi_str.clone()))?;
        if container.transport != host_abi.transport {
            return Err(NetSupportError::FabricMismatch {
                container: container.transport,
                host: host_abi.transport.clone(),
            });
        }
        if !host_abi.host_can_serve(&container) {
            return Err(NetSupportError::AbiIncompatible {
                container_abi: container.abi_string(),
                host_abi: host_abi.abi_string(),
            });
        }
    }
    Ok(host_abi)
}

/// Perform the injection during environment preparation: transport
/// libraries at their host paths, fabric device files into `/dev`.
/// Idempotent — re-running overwrites the same nodes with identical
/// content and re-binds the same targets.
pub fn inject(
    profile: &SystemProfile,
    config: &UdiRootConfig,
    host_fs: &VirtualFs,
    rootfs: &mut VirtualFs,
    mounts: &mut MountTable,
) -> Result<NetSupportReport, NetSupportError> {
    let host_abi = profile.net_abi().ok_or(NetSupportError::NoHostFabric)?;

    // 1. bind mount the transport libraries at their host paths
    let mut libraries = Vec::new();
    for lib in &config.net_transport_paths {
        let node = host_fs
            .get(lib)
            .cloned()
            .ok_or_else(|| NetSupportError::MissingHostLibrary(lib.clone()))?;
        rootfs.insert(lib, node)?;
        mounts.bind(lib, lib, true, "net support");
        libraries.push(lib.clone());
    }

    // 2. graft the fabric device files (directories like /dev/hugepages
    // come along as directories, device nodes as device nodes)
    let mut device_files = Vec::new();
    for dev in &config.net_device_paths {
        if host_fs.is_dir(dev) {
            rootfs.mkdir_p(dev).ok();
        } else {
            let node = host_fs.get(dev).cloned().ok_or_else(|| {
                NetSupportError::MissingHostDevice(dev.clone())
            })?;
            rootfs.insert(dev, node)?;
        }
        mounts.bind(dev, dev, false, "net support");
        device_files.push(dev.clone());
    }

    Ok(NetSupportReport {
        fabric: profile.fabric.name().to_string(),
        transport: host_abi.transport.clone(),
        host_abi: host_abi.abi_string(),
        libraries,
        device_files,
    })
}

/// The specialized-networking [`HostExtension`] — the paper's missing
/// third resource, registered by default after GPU and MPI support.
#[derive(Debug, Clone, Copy, Default)]
pub struct NetworkSupport;

/// `SHIFTER_NET` values that request the host fabric.
const NET_TRIGGER_VALUES: [&str; 3] = ["host", "native", "1"];

impl HostExtension for NetworkSupport {
    fn name(&self) -> &'static str {
        "net"
    }

    fn trigger_description(&self) -> String {
        format!(
            "SHIFTER_NET={} in the launch env, or image label {} \
             (SHIFTER_NET_FALLBACK vetoes)",
            NET_TRIGGER_VALUES.join("|"),
            LABEL_NET_FABRIC
        )
    }

    fn trigger(&self, ctx: &ExtensionContext<'_>) -> Activation {
        // the veto is value-aware, like SHIFTER_NET itself: "0"/"false"/
        // empty mean "no veto", so `SHIFTER_NET_FALLBACK=0` cannot
        // silently force the TCP path
        let vetoed = matches!(
            ctx.env().get("SHIFTER_NET_FALLBACK"),
            Some(v) if !v.is_empty() && v != "0" && v != "false"
        );
        if vetoed {
            return Activation::Skipped(
                "SHIFTER_NET_FALLBACK forces the TCP path".to_string(),
            );
        }
        if let Some(v) = ctx.env().get("SHIFTER_NET") {
            if NET_TRIGGER_VALUES.contains(&v.as_str()) {
                return Activation::Triggered(format!("SHIFTER_NET={v}"));
            }
            // mirror §IV.A: an invalid value does not trigger the env
            // path — but it must NOT bypass the label path below, or an
            // unrelated env value would skip the ABI gate a fabric-aware
            // image's label enforces
        }
        if let Some(fabric) = ctx.manifest.labels.get(LABEL_NET_FABRIC) {
            return Activation::Triggered(format!(
                "image label {LABEL_NET_FABRIC}={fabric}"
            ));
        }
        Activation::Skipped(
            "no valid SHIFTER_NET request and the image carries no fabric \
             label"
                .to_string(),
        )
    }

    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError> {
        let host_abi = check(&ctx.manifest.labels, ctx.profile)
            .map_err(ExtensionError::Net)?;
        Ok(Capability {
            extension: self.name(),
            available: true,
            detail: format!(
                "{} via {} (host ABI {})",
                ctx.profile.fabric.name(),
                host_abi.transport,
                host_abi.abi_string()
            ),
        })
    }

    fn capability(
        &self,
        profile: &SystemProfile,
        config: &UdiRootConfig,
    ) -> Capability {
        match profile.net_abi() {
            Some(abi) => Capability {
                extension: self.name(),
                available: true,
                detail: format!(
                    "{} via {} (host ABI {}, {} transport libs)",
                    profile.fabric.name(),
                    abi.transport,
                    abi.abi_string(),
                    config.net_transport_paths.len()
                ),
            },
            None => Capability {
                extension: self.name(),
                available: false,
                detail: "no specialized fabric (loopback host)".to_string(),
            },
        }
    }

    fn inject(
        &self,
        ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError> {
        let before = mounts.len();
        let report =
            inject(ctx.profile, ctx.config, ctx.host_fs, rootfs, mounts)
                .map_err(ExtensionError::Net)?;
        env.insert(
            "SHIFTER_NET_TRANSPORT".to_string(),
            report.transport.clone(),
        );
        Ok(ExtensionReport {
            extension: self.name(),
            detail: format!(
                "{} via {}: {} transport libs, {} device files",
                report.fabric,
                report.transport,
                report.libraries.len(),
                report.device_files.len()
            ),
            mounts_added: mounts.len() - before,
            env_added: 1,
            payload: ExtensionPayload::Net(report),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(
        profile: &SystemProfile,
    ) -> (UdiRootConfig, VirtualFs, VirtualFs, MountTable) {
        (
            UdiRootConfig::for_profile(profile),
            profile.host_fs(),
            VirtualFs::new(),
            MountTable::new(),
        )
    }

    #[test]
    fn daint_injection_grafts_gni_stack() {
        let pd = SystemProfile::piz_daint();
        let (cfg, host_fs, mut rootfs, mut mounts) = setup(&pd);
        let rep =
            inject(&pd, &cfg, &host_fs, &mut rootfs, &mut mounts).unwrap();
        assert_eq!(rep.transport, "gni");
        assert_eq!(rep.fabric, "Cray Aries");
        assert!(rootfs.exists("/opt/cray/ugni/default/lib64/libugni.so.0"));
        assert!(rootfs.exists("/opt/cray/dmapp/default/lib64/libdmapp.so.1"));
        assert!(rootfs.exists("/dev/kgni0"));
        assert!(rootfs.is_dir("/dev/hugepages"));
        assert_eq!(
            mounts.by_origin("net support").len(),
            rep.libraries.len() + rep.device_files.len()
        );
    }

    #[test]
    fn cluster_injection_grafts_verbs_stack() {
        let cl = SystemProfile::linux_cluster();
        let (cfg, host_fs, mut rootfs, mut mounts) = setup(&cl);
        let rep =
            inject(&cl, &cfg, &host_fs, &mut rootfs, &mut mounts).unwrap();
        assert_eq!(rep.transport, "verbs");
        assert!(rootfs.exists("/usr/lib64/libibverbs.so.1"));
        assert!(rootfs.exists("/dev/infiniband/uverbs0"));
    }

    #[test]
    fn loopback_host_refused() {
        let lap = SystemProfile::laptop();
        let (cfg, host_fs, mut rootfs, mut mounts) = setup(&lap);
        assert_eq!(
            inject(&lap, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err(),
            NetSupportError::NoHostFabric
        );
        assert_eq!(
            check(&BTreeMap::new(), &lap).unwrap_err(),
            NetSupportError::NoHostFabric
        );
    }

    #[test]
    fn abi_gate_mirrors_libtool_rules() {
        let pd = SystemProfile::piz_daint();
        let mut labels = BTreeMap::new();
        // unlabeled (portable TCP build): passes vacuously
        assert!(check(&labels, &pd).is_ok());
        // matching family, older interface: served
        labels.insert(LABEL_NET_ABI.to_string(), "gni:3".to_string());
        assert!(check(&labels, &pd).is_ok());
        // newer than the host: refused
        labels.insert(LABEL_NET_ABI.to_string(), "gni:99".to_string());
        assert!(matches!(
            check(&labels, &pd).unwrap_err(),
            NetSupportError::AbiIncompatible { .. }
        ));
        // wrong family: refused
        labels.insert(LABEL_NET_ABI.to_string(), "verbs:17".to_string());
        assert!(matches!(
            check(&labels, &pd).unwrap_err(),
            NetSupportError::FabricMismatch { .. }
        ));
        // unparsable metadata: refused
        labels.insert(LABEL_NET_ABI.to_string(), "gni-five".to_string());
        assert!(matches!(
            check(&labels, &pd).unwrap_err(),
            NetSupportError::BadAbiMetadata(_)
        ));
    }

    #[test]
    fn fabric_label_alone_gates_too() {
        let pd = SystemProfile::piz_daint();
        let mut labels = BTreeMap::new();
        labels.insert(LABEL_NET_FABRIC.to_string(), "verbs".to_string());
        assert!(matches!(
            check(&labels, &pd).unwrap_err(),
            NetSupportError::FabricMismatch { .. }
        ));
        labels.insert(LABEL_NET_FABRIC.to_string(), "gni".to_string());
        assert!(check(&labels, &pd).is_ok());
    }

    #[test]
    fn missing_host_transport_library_reported() {
        let pd = SystemProfile::piz_daint();
        let (cfg, mut host_fs, mut rootfs, mut mounts) = setup(&pd);
        host_fs
            .remove("/opt/cray/dmapp/default/lib64/libdmapp.so.1")
            .unwrap();
        assert!(matches!(
            inject(&pd, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err(),
            NetSupportError::MissingHostLibrary(_)
        ));
    }

    #[test]
    fn missing_fabric_device_named_as_a_device() {
        let pd = SystemProfile::piz_daint();
        let (cfg, mut host_fs, mut rootfs, mut mounts) = setup(&pd);
        host_fs.remove("/dev/kgni0").unwrap();
        let err = inject(&pd, &cfg, &host_fs, &mut rootfs, &mut mounts)
            .unwrap_err();
        assert_eq!(
            err,
            NetSupportError::MissingHostDevice("/dev/kgni0".to_string())
        );
        assert!(err.to_string().contains("device"), "{err}");
    }

    #[test]
    fn falsy_fallback_values_do_not_veto() {
        use crate::shifter::RunOptions;

        let pd = SystemProfile::piz_daint();
        let config = UdiRootConfig::for_profile(&pd);
        let host_fs = pd.host_fs();
        let manifest = crate::image::builder::ubuntu_xenial().manifest;
        let ext = NetworkSupport;
        for (fallback, triggered) in
            [("0", true), ("false", true), ("", true), ("1", false)]
        {
            let opts = RunOptions::new("ubuntu:xenial", &["true"])
                .with_env("SHIFTER_NET", "host")
                .with_env("SHIFTER_NET_FALLBACK", fallback);
            let ctx = ExtensionContext {
                opts: &opts,
                manifest: &manifest,
                profile: &pd,
                config: &config,
                host_fs: &host_fs,
            };
            assert_eq!(
                ext.trigger(&ctx).is_triggered(),
                triggered,
                "SHIFTER_NET_FALLBACK={fallback:?}"
            );
        }
    }

    #[test]
    fn invalid_shifter_net_does_not_bypass_the_label_gate() {
        use crate::image::builder::ImageBuilder;
        use crate::shifter::RunOptions;

        let pd = SystemProfile::piz_daint();
        let config = UdiRootConfig::for_profile(&pd);
        let host_fs = pd.host_fs();
        let manifest = ImageBuilder::new("fabric-app:verbs")
            .exe("/usr/bin/app", 1_000)
            .with_net_transport("verbs", 17)
            .build()
            .manifest;
        let ext = NetworkSupport;

        let opts = RunOptions::new("fabric-app:verbs", &["true"])
            .with_env("SHIFTER_NET", "tcp");
        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &pd,
            config: &config,
            host_fs: &host_fs,
        };
        // an unrecognized env value falls through to the label trigger…
        assert!(ext.trigger(&ctx).is_triggered());
        // …and the label's fabric gate still refuses the run
        assert!(ext.check(&ctx).is_err());

        // the explicit veto remains the only bypass
        let opts = opts.with_env("SHIFTER_NET_FALLBACK", "1");
        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &pd,
            config: &config,
            host_fs: &host_fs,
        };
        assert!(!ext.trigger(&ctx).is_triggered());
    }

    #[test]
    fn injection_is_idempotent_on_the_rootfs() {
        let pd = SystemProfile::piz_daint();
        let (cfg, host_fs, mut rootfs, mut mounts) = setup(&pd);
        inject(&pd, &cfg, &host_fs, &mut rootfs, &mut mounts).unwrap();
        let snapshot = rootfs.clone();
        inject(&pd, &cfg, &host_fs, &mut rootfs, &mut mounts).unwrap();
        assert_eq!(rootfs, snapshot);
    }
}
