//! Specialized-network injection substrate (DESIGN.md S22) — the paper's
//! *third* host resource.
//!
//! The paper's abstract promises "a mechanism to access GPU accelerators
//! **and specialized networking** from the host system"; §IV delivers the
//! GPU and MPI halves and leaves the interconnect to the MPI swap. This
//! module completes the triad: it models the host's fabric *transport
//! stack* — the uGNI/DMAPP user-space libraries and `/dev/kgni0` +
//! `/dev/hugepages` device files on a Cray Aries machine, the verbs/RDMA
//! libraries and `/dev/infiniband/*` nodes on an InfiniBand cluster — and
//! grafts it into containers the same way §IV.A grafts the NVIDIA driver
//! stack.
//!
//! Like the §IV.B MPI swap, injection is gated by an ABI comparison: a
//! fabric-aware image declares the transport it was built against via
//! OCI-style labels (`org.shifter.net.fabric`, `org.shifter.net.abi`),
//! and the host refuses to serve an incompatible build instead of letting
//! it crash at first RDMA. Portable TCP-only images carry no labels and
//! opt in at run time through `SHIFTER_NET=host`; `SHIFTER_NET_FALLBACK`
//! vetoes injection for ablations (EXPERIMENTS.md knob table) — note
//! that a `--mpi`-swapped container stays on the native path regardless,
//! since the §IV.B swap itself brings the fabric-capable host MPI.
//!
//! The [`NetworkSupport`] type plugs this substrate into the runtime's
//! [`crate::shifter::HostExtension`] registry alongside the GPU and MPI
//! extensions.

mod support;

pub use support::{
    check, inject, NetSupportError, NetSupportReport, NetworkSupport,
};

/// A fabric transport ABI: the user-space transport family plus its
/// interface major version — the netfab analog of the §IV.B libtool
/// string. `"gni:5"` reads "uGNI interface generation 5".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct NetAbi {
    /// Transport family ("gni" on Cray Aries, "verbs" on InfiniBand).
    pub transport: String,
    /// Interface major version of the transport library.
    pub major: u32,
}

impl NetAbi {
    /// Build an ABI literal.
    pub fn new(transport: &str, major: u32) -> NetAbi {
        NetAbi {
            transport: transport.to_string(),
            major,
        }
    }

    /// Parse a `transport:major` label value (e.g. `gni:5`).
    pub fn parse(s: &str) -> Option<NetAbi> {
        let (transport, major) = s.split_once(':')?;
        if transport.is_empty() {
            return None;
        }
        Some(NetAbi {
            transport: transport.to_string(),
            major: major.parse().ok()?,
        })
    }

    /// The `transport:major` string form (inverse of [`NetAbi::parse`]).
    pub fn abi_string(&self) -> String {
        format!("{}:{}", self.transport, self.major)
    }

    /// Mirror of the §IV.B libtool rule: the host transport can serve a
    /// container built against `container` iff the families match and the
    /// host's interface generation is at least as new.
    pub fn host_can_serve(&self, container: &NetAbi) -> bool {
        self.transport == container.transport && self.major >= container.major
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abi_string_roundtrip() {
        for abi in [NetAbi::new("gni", 5), NetAbi::new("verbs", 17)] {
            assert_eq!(NetAbi::parse(&abi.abi_string()), Some(abi));
        }
        assert_eq!(NetAbi::parse("gni"), None);
        assert_eq!(NetAbi::parse(":5"), None);
        assert_eq!(NetAbi::parse("gni:x"), None);
    }

    #[test]
    fn host_serves_same_or_older_containers_only() {
        let host = NetAbi::new("gni", 5);
        assert!(host.host_can_serve(&NetAbi::new("gni", 5)));
        assert!(host.host_can_serve(&NetAbi::new("gni", 3)));
        assert!(!host.host_can_serve(&NetAbi::new("gni", 6)));
        assert!(!host.host_can_serve(&NetAbi::new("verbs", 5)));
    }
}
