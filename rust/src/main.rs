//! `shifter` — the Runtime CLI (§III.B).
//!
//! Usage mirrors the paper:
//! ```text
//! shifter --system=daint --image=ubuntu:xenial cat /etc/os-release
//! shifter --system=daint --image=cuda-image --gpus=0,2 ./deviceQuery
//! shifter --system=daint --image=osu --mpi osu_latency
//! ```
//! `--system` selects one of the three §V.A host profiles (we are not
//! actually on a Cray); the rest is the real Shifter surface.

use shifter_rs::shifter::RunOptions;
use shifter_rs::util::cli::CliSpec;
use shifter_rs::{Site, SystemProfile};

fn usage() -> ! {
    eprintln!(
        "usage: shifter [--system=laptop|cluster|daint] --image=<ref> \
         [--mpi] [--gpus=LIST] [--verbose] <command…>"
    );
    std::process::exit(2);
}

fn main() {
    let spec = CliSpec::new(
        &[
            ("system", true),
            ("image", true),
            ("mpi", false),
            ("gpus", true),
            ("volume", true),
            ("verbose", false),
        ],
        true,
    );
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifter: {e}");
            usage();
        }
    };
    let Some(image) = parsed.get("image") else {
        eprintln!("shifter: --image is required");
        usage();
    };
    if parsed.positionals.is_empty() {
        eprintln!("shifter: no command given");
        usage();
    }

    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        other => {
            eprintln!("shifter: unknown system '{other}'");
            usage();
        }
    };

    // a single-node site wired through the facade — `Site::run` pulls
    // the image on demand (`shifterimg` is the real pull interface)
    let mut site = match Site::builder().profile(profile).nodes(1).build() {
        Ok(site) => site,
        Err(e) => {
            eprintln!("shifter: invalid site: {e}");
            std::process::exit(2);
        }
    };

    let cmd: Vec<&str> = parsed.positionals.iter().map(|s| s.as_str()).collect();
    let mut opts = RunOptions::new(image, &cmd);
    opts.mpi = parsed.has("mpi");
    if let Some(gpus) = parsed.get("gpus") {
        opts = opts.with_env("CUDA_VISIBLE_DEVICES", gpus);
    }
    if let Some(vol) = parsed.get("volume") {
        match shifter_rs::shifter::VolumeSpec::parse(vol) {
            Ok(v) => opts.volumes.push(v),
            Err(e) => {
                eprintln!("shifter: {e}");
                std::process::exit(2);
            }
        }
    }

    match site.run(&opts) {
        Ok(container) => {
            if parsed.has("verbose") {
                eprint!("{}", container.stage_log.render());
                for m in container.mounts.iter() {
                    eprintln!("mount: {m}");
                }
            }
            match container.exec(&cmd) {
                Ok(out) => {
                    print!("{out}");
                    if !out.is_empty() && !out.ends_with('\n') {
                        println!();
                    }
                }
                Err(e) => {
                    eprintln!("shifter: {e}");
                    std::process::exit(1);
                }
            }
        }
        Err(e) => {
            eprintln!("shifter: {e}");
            std::process::exit(1);
        }
    }
}
