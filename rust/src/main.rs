//! `shifter` — the Runtime CLI (§III.B).
//!
//! Usage mirrors the paper:
//! ```text
//! shifter --system=daint --image=ubuntu:xenial cat /etc/os-release
//! shifter --system=daint --image=cuda-image --gpus=0,2 ./deviceQuery
//! shifter --system=daint --image=osu --mpi osu_latency
//! shifter --system=daint --image=osu --net osu_latency
//! shifter --system=daint --extensions
//! ```
//! `--system` selects one of the three §V.A host profiles (we are not
//! actually on a Cray); the rest is the real Shifter surface.
//! `--extensions` lists the registered host extensions with their
//! triggers and this system's capability verdict, then exits.
//! `--trace=<path>` (or `SHIFTER_TRACE=<path>`) records the run's span
//! tree and writes it as Chrome trace-event JSONL — load it in
//! Perfetto / `chrome://tracing` (DESIGN.md S23).

use shifter_rs::config::UdiRootConfig;
use shifter_rs::shifter::{preflight, ExtensionRegistry, RunOptions};
use shifter_rs::util::cli::CliSpec;
use shifter_rs::{Site, SystemProfile};

fn usage() -> ! {
    eprintln!(
        "usage: shifter [--system=laptop|cluster|daint] --image=<ref> \
         [--mpi] [--net] [--gpus=LIST] [--verbose] \
         [--trace=<trace.jsonl>] <command…>\n\
         \x20      shifter [--system=...] --extensions"
    );
    std::process::exit(2);
}

/// Print a typed error with its full `source()` chain and exit nonzero.
fn die(err: &dyn std::error::Error) -> ! {
    shifter_rs::util::cli::die("shifter", err)
}

fn main() {
    let spec = CliSpec::new(
        &[
            ("system", true),
            ("image", true),
            ("mpi", false),
            ("net", false),
            ("gpus", true),
            ("volume", true),
            ("verbose", false),
            ("extensions", false),
            ("trace", true),
        ],
        true,
    );
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifter: {e}");
            usage();
        }
    };

    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        other => {
            eprintln!("shifter: unknown system '{other}'");
            usage();
        }
    };

    // `--extensions`: the full host preflight — kernel facilities plus
    // the extension capability vector — and exit (no image needed)
    if parsed.has("extensions") {
        let registry = ExtensionRegistry::defaults();
        let config = UdiRootConfig::for_profile(&profile);
        let host = preflight::preflight_with_extensions(
            &profile, &config, &registry,
        );
        println!(
            "host preflight on {}: kernel {} ({})",
            profile.name,
            profile.kernel,
            if host.kernel.ok() { "ok" } else { "missing features" },
        );
        println!("extensions (injection order):");
        for (ext, cap) in registry.iter().zip(&host.capabilities) {
            let verdict = if cap.available {
                "available"
            } else {
                "unavailable"
            };
            println!(
                "  {:<6} {verdict:<12} {}\n         trigger: {}",
                ext.name(),
                cap.detail,
                ext.trigger_description(),
            );
        }
        return;
    }

    let Some(image) = parsed.get("image") else {
        eprintln!("shifter: --image is required");
        usage();
    };
    if parsed.positionals.is_empty() {
        eprintln!("shifter: no command given");
        usage();
    }

    // `--trace=<path>` wins over the SHIFTER_TRACE environment knob
    let trace = parsed
        .get("trace")
        .map(String::from)
        .or_else(|| std::env::var("SHIFTER_TRACE").ok());

    // a single-node site wired through the facade — `Site::run` pulls
    // the image on demand (`shifterimg` is the real pull interface)
    let mut site = match Site::builder()
        .profile(profile)
        .nodes(1)
        .telemetry(trace.is_some())
        .build()
    {
        Ok(site) => site,
        Err(e) => {
            eprintln!("shifter: invalid site: {e}");
            std::process::exit(2);
        }
    };

    let cmd: Vec<&str> = parsed.positionals.iter().map(|s| s.as_str()).collect();
    let mut opts = RunOptions::new(image, &cmd);
    opts.mpi = parsed.has("mpi");
    if parsed.has("net") {
        opts = opts.with_env("SHIFTER_NET", "host");
    }
    if let Some(gpus) = parsed.get("gpus") {
        opts = opts.with_env("CUDA_VISIBLE_DEVICES", gpus);
    }
    if let Some(vol) = parsed.get("volume") {
        match shifter_rs::shifter::VolumeSpec::parse(vol) {
            Ok(v) => opts.volumes.push(v),
            Err(e) => {
                eprintln!("shifter: {e}");
                std::process::exit(2);
            }
        }
    }

    match site.run(&opts) {
        Ok(container) => {
            if parsed.has("verbose") {
                eprint!("{}", container.stage_log.render());
                for m in container.mounts.iter() {
                    eprintln!("mount: {m}");
                }
            }
            match container.exec(&cmd) {
                Ok(out) => {
                    print!("{out}");
                    if !out.is_empty() && !out.ends_with('\n') {
                        println!();
                    }
                }
                Err(e) => die(&e),
            }
            if let Some(path) = trace {
                let jsonl = site.telemetry().chrome_trace_jsonl();
                if let Err(e) = std::fs::write(&path, jsonl) {
                    eprintln!("shifter: cannot write trace {path}: {e}");
                    std::process::exit(1);
                }
                eprintln!(
                    "trace: {} spans -> {path} (open in Perfetto)",
                    site.telemetry().span_count()
                );
            }
        }
        Err(e) => die(&e),
    }
}
