//! The Shifter Runtime (§III.A, §IV): orchestrates the execution stages,
//! building a container environment from "the user-specified image and the
//! parts of the host system Shifter has been configured to source". The
//! paper's GPU/MPI/network support runs through the ordered
//! [`ExtensionRegistry`] (see [`super::extension`]): every triggered
//! extension is compatibility-checked in preflight and injected during
//! environment preparation.

use std::collections::BTreeMap;
use std::sync::Arc;

use crate::config::UdiRootConfig;
use crate::fabric::Transport;
use crate::gateway::{GatewayError, ImageSource};
use crate::gpu::GpuModel;
use crate::hostenv::SystemProfile;
use crate::image::ImageManifest;
use crate::mpi::MpiImpl;
use crate::netfab::NetSupportReport;
use crate::vfs::{Mount, MountKind, MountTable, VirtualFs};

use super::extension::{
    Activation, ExtensionContext, ExtensionError, ExtensionPayload,
    ExtensionRegistry, ExtensionReport, HostExtension,
};
use super::gpu_support::GpuSupportReport;
use super::mpi_support::{self, MpiSupportReport};
use super::stages::{PrivilegeState, Stage, StageError, StageLog};
use super::volume::{VolumeError, VolumeSpec, TMPFS_DIRS};
use crate::sim::SimTime;
use crate::telemetry::{SpanDraft, Telemetry};

/// Everything that can fail between `shifter --image=<ref> <cmd>` and a
/// prepared container: image resolution, the host extensions, the
/// stage machine, volume policy, or in-container execution.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum ShifterError {
    /// Image resolution against the gateway/fabric failed.
    #[error(transparent)]
    Gateway(#[from] GatewayError),
    /// A triggered extension's compatibility gate refused the run in
    /// preflight — before `Stage::PrepareEnvironment` performed a single
    /// mount (driver/ABI/fabric incompatibility).
    #[error("extension '{extension}' failed preflight: {source}")]
    ExtensionCheck {
        /// Which extension refused.
        extension: &'static str,
        /// The typed cause (chained via `source()`).
        #[source]
        source: ExtensionError,
    },
    /// A host extension failed while injecting its resources during
    /// `Stage::PrepareEnvironment` (e.g. a host library named by the
    /// site config is missing).
    #[error(transparent)]
    Extension(#[from] ExtensionError),
    /// The §III.A stage machine rejected an execution step.
    #[error(transparent)]
    Stage(#[from] StageError),
    /// A user volume violated site policy.
    #[error(transparent)]
    Volume(#[from] VolumeError),
    /// The containerized command itself failed.
    #[error("command failed in container: {0}")]
    Exec(String),
}

/// `shifter --image=<image> [--mpi] <command…>` plus launch context.
#[derive(Debug, Clone)]
pub struct RunOptions {
    /// Image reference to run.
    pub image: String,
    /// Command executed inside the container.
    pub command: Vec<String>,
    /// `--mpi`: activate the §IV.B library swap.
    pub mpi: bool,
    /// Numeric uid of the invoking user (privileges drop to this).
    pub invoking_uid: u32,
    /// Numeric gid of the invoking user.
    pub invoking_gid: u32,
    /// Process environment at launch (user shell or WLM-injected).
    pub env: BTreeMap<String, String>,
    /// `--volume=/host:/container[:ro]` user mounts.
    pub volumes: Vec<VolumeSpec>,
    /// Nodes starting this container simultaneously (srun job width) —
    /// drives the PFS fetch-contention model.
    pub concurrent_nodes: u32,
    /// Which node of the system we execute on.
    pub node: usize,
    /// Telemetry span this run's spans parent under, when the caller
    /// (the launch orchestrator's node slot) is tracing. See
    /// [`crate::telemetry`] / DESIGN.md S23.
    pub trace_parent: Option<u64>,
    /// Virtual-time instant this run starts at on the caller's timeline
    /// (the unified [`crate::sim`] kernel clock); the runtime only knows
    /// relative stage costs, so span placement is offset from here.
    pub trace_start: SimTime,
    /// Pre-computed node fetch cost, when the caller already charged the
    /// distribution fabric for this attempt's squashfs fetch (the launch
    /// orchestrator's slot-template fast path). `None` means the runtime
    /// asks the image source itself — exactly one fetch per attempt
    /// either way.
    pub fetch_override: Option<f64>,
}

impl RunOptions {
    /// Options for `shifter --image=<image> <command…>` with default
    /// credentials (uid/gid 1000), no extensions, node 0.
    pub fn new(image: &str, command: &[&str]) -> RunOptions {
        RunOptions {
            image: image.to_string(),
            command: command.iter().map(|s| s.to_string()).collect(),
            mpi: false,
            invoking_uid: 1000,
            invoking_gid: 1000,
            env: BTreeMap::new(),
            volumes: Vec::new(),
            concurrent_nodes: 1,
            node: 0,
            trace_parent: None,
            trace_start: SimTime::ZERO,
            fetch_override: None,
        }
    }

    /// Place this run on the caller's trace timeline (see
    /// [`crate::TraceCtx`]): spans parent under `ctx.parent` and start
    /// at the virtual-time instant `ctx.start`.
    pub fn traced(mut self, ctx: crate::telemetry::TraceCtx) -> RunOptions {
        self.trace_parent = ctx.parent;
        self.trace_start = ctx.start;
        self
    }

    /// Add a `--volume` mount (parsed and validated at run time).
    ///
    /// # Panics
    ///
    /// Panics if `spec` is not a valid `--volume` string; builder
    /// callers pass literals, so a bad spec is a programming error.
    pub fn with_volume(mut self, spec: &str) -> RunOptions {
        let parsed = match VolumeSpec::parse(spec) {
            Ok(v) => v,
            Err(e) => panic!("with_volume: bad --volume spec {spec:?}: {e}"),
        };
        self.volumes.push(parsed);
        self
    }

    /// `--mpi`: activate the §IV.B library swap.
    pub fn with_mpi(mut self) -> RunOptions {
        self.mpi = true;
        self
    }

    /// Set one launch-environment variable (e.g. `CUDA_VISIBLE_DEVICES`,
    /// the §IV.A GPU-support trigger).
    pub fn with_env(mut self, k: &str, v: &str) -> RunOptions {
        self.env.insert(k.to_string(), v.to_string());
        self
    }

    /// Place the run on node `node` with `concurrent` peers starting the
    /// same container simultaneously (drives the PFS contention model).
    pub fn on_nodes(mut self, node: usize, concurrent: u32) -> RunOptions {
        self.node = node;
        self.concurrent_nodes = concurrent;
        self
    }
}

/// A fully prepared container, post-Execute stage.
#[derive(Debug, Clone)]
pub struct Container {
    /// Canonical reference of the image this container runs.
    pub image: String,
    /// The container's filesystem tree after all grafts and mounts.
    pub rootfs: VirtualFs,
    /// Every mount the preparation stage performed, with its origin.
    pub mounts: MountTable,
    /// The exported container environment (image env + allowlisted host
    /// vars).
    pub env: BTreeMap<String, String>,
    /// §IV.A GPU-support report, when the trigger variable activated it.
    pub gpu: Option<GpuSupportReport>,
    /// §IV.B MPI-swap report, when `--mpi` activated it.
    pub mpi: Option<MpiSupportReport>,
    /// Specialized-network report, when the net extension activated.
    pub net: Option<NetSupportReport>,
    /// Every extension that injected into this container, in registry
    /// order (includes site-defined extensions the typed fields above
    /// cannot name).
    pub extensions: Vec<ExtensionReport>,
    /// Docker-style manifest carried over from the image.
    pub manifest: ImageManifest,
    /// Auditable log of the executed §III.A stages with simulated costs.
    pub stage_log: StageLog,
    /// Final uid/gid state (privileges dropped to the invoking user).
    pub privileges: PrivilegeState,
}

impl Container {
    /// Total simulated runtime overhead (everything but the application).
    pub fn startup_overhead_secs(&self) -> f64 {
        self.stage_log.total_sim_secs()
    }

    /// Read a small text file from inside the container (content-backed
    /// files only — e.g. /etc/os-release).
    pub fn read_file(&self, path: &str) -> Option<&str> {
        if !self.rootfs.exists(path) {
            return None;
        }
        self.manifest.files_content.get(path).map(|s| s.as_str())
    }

    /// Execute a toy in-container command (`cat`, `ls`, `true`) — enough
    /// for the §III.B workflow example and the integration tests.
    pub fn exec(&self, argv: &[&str]) -> Result<String, ShifterError> {
        match argv {
            ["cat", path] => self
                .read_file(path)
                .map(|s| s.to_string())
                .ok_or_else(|| ShifterError::Exec(format!("cat: {path}: No such file"))),
            ["ls", path] => self
                .rootfs
                .list_dir(path)
                .map(|v| v.join("\n"))
                .map_err(|e| ShifterError::Exec(e.to_string())),
            ["true"] => Ok(String::new()),
            ["./deviceQuery"] | ["deviceQuery"] => match &self.gpu {
                Some(rep) => {
                    let mut out = String::new();
                    for cid in &rep.container_devices {
                        out.push_str(&format!(
                            "Device {cid}: CUDA-capable (host device {})\n",
                            rep.host_devices[*cid as usize]
                        ));
                    }
                    out.push_str(&format!(
                        "deviceQuery: {} CUDA device(s) found\nResult = PASS\n",
                        rep.container_devices.len()
                    ));
                    Ok(out)
                }
                None => Err(ShifterError::Exec(
                    "deviceQuery: CUDA driver version is insufficient \
                     (no GPU support in this container)"
                        .into(),
                )),
            },
            ["nvidia-smi"] => {
                if !self.rootfs.exists("/usr/bin/nvidia-smi")
                    && !self
                        .rootfs
                        .exists("/opt/cray/nvidia/default/bin/nvidia-smi")
                {
                    return Err(ShifterError::Exec(
                        "nvidia-smi: command not found".into(),
                    ));
                }
                let rep = self.gpu.as_ref().ok_or_else(|| {
                    ShifterError::Exec(
                        "NVIDIA-SMI has failed: no devices visible".into(),
                    )
                })?;
                Ok(format!(
                    "NVIDIA-SMI: {} device(s), {} driver libraries mounted\n",
                    rep.container_devices.len(),
                    rep.libraries.len()
                ))
            }
            other => Err(ShifterError::Exec(format!(
                "unsupported container command: {other:?}"
            ))),
        }
    }

    /// The MPI implementation the containerized application actually links
    /// against at run time: the host's (fabric-capable) library if the
    /// swap happened, the image's own (TCP-only) build otherwise.
    pub fn effective_mpi(
        &self,
        profile: &SystemProfile,
    ) -> Option<MpiImpl> {
        if self.mpi.is_some() {
            Some(profile.host_mpi.clone())
        } else {
            mpi_support::container_mpi_from_labels(&self.manifest.labels)
                .ok()
                .flatten()
        }
    }

    /// The transport path this container's communication actually uses:
    /// the native fabric when the network extension grafted the host
    /// transport stack in (or the §IV.B swap brought the fabric-capable
    /// host MPI), the TCP fallback otherwise — the knob behind the
    /// paper's enabled/disabled OSU latency split.
    pub fn effective_transport(&self) -> Transport {
        if self.net.is_some() || self.mpi.is_some() {
            Transport::Native
        } else {
            Transport::TcpFallback
        }
    }

    /// GPU chips visible inside the container, in container-id order
    /// (resolved through the node's driver enumeration).
    pub fn visible_gpus(&self, profile: &SystemProfile, node: usize) -> Vec<GpuModel> {
        let Some(ref rep) = self.gpu else {
            return vec![];
        };
        let Some(driver) = profile.driver(node) else {
            return vec![];
        };
        let enumeration = driver.enumerate();
        rep.host_devices
            .iter()
            .filter_map(|id| {
                enumeration
                    .iter()
                    .find(|(gid, _, _)| gid == id)
                    .map(|(_, board, _)| (*board).clone())
            })
            .collect()
    }
}

/// The runtime itself, configured for one host system.
///
/// The profile lives behind an `Arc` so the runtime is cheaply cloneable
/// and shareable across worker threads — the launch orchestrator
/// (`crate::launch`) drives one runtime per partition from a thread pool,
/// and `run` only ever takes `&self`.
///
/// ```
/// use shifter_rs::pfs::LustreFs;
/// use shifter_rs::shifter::RunOptions;
/// use shifter_rs::{ImageGateway, Registry, ShifterRuntime, SystemProfile};
///
/// let registry = Registry::dockerhub();
/// let mut gateway = ImageGateway::new(LustreFs::piz_daint());
/// gateway.pull(&registry, "ubuntu:xenial").unwrap();
///
/// let runtime = ShifterRuntime::new(&SystemProfile::piz_daint());
/// let container = runtime
///     .run(&gateway, &RunOptions::new("ubuntu:xenial", &["true"]))
///     .unwrap();
/// assert!(container.startup_overhead_secs() > 0.0);
/// assert!(container.read_file("/etc/os-release").is_some());
/// ```
#[derive(Clone)]
pub struct ShifterRuntime {
    profile: Arc<SystemProfile>,
    /// The site `udiRoot.conf` this runtime was configured with.
    pub config: UdiRootConfig,
    host_fs: VirtualFs,
    /// The ordered host-extension registry `run` drives (stock set:
    /// GPU, MPI, network; replaceable via
    /// [`ShifterRuntime::with_extensions`]).
    extensions: Arc<ExtensionRegistry>,
    /// Shared recorder (disabled by default): `run` emits one span per
    /// stage and per extension check/inject. See DESIGN.md S23.
    telemetry: Arc<Telemetry>,
}

// stage cost constants (seconds) — calibrated to typical mount/namespace
// syscall costs; see EXPERIMENTS.md §Perf for the measured end-to-end cost
const LOOP_MOUNT_SECS: f64 = 5e-3;
const BIND_MOUNT_SECS: f64 = 120e-6;
const CHROOT_SECS: f64 = 400e-6;
const SETUID_SECS: f64 = 5e-6;
const ENV_VAR_SECS: f64 = 1e-6;
const FORK_EXEC_SECS: f64 = 4e-3;
const CLEANUP_SECS: f64 = 8e-3;
const LOCAL_DISK_BYTES_PER_SEC: f64 = 500e6;

impl ShifterRuntime {
    /// Runtime for `profile` with the stock per-profile `udiRoot.conf`.
    pub fn new(profile: &SystemProfile) -> ShifterRuntime {
        Self::shared(Arc::new(profile.clone()))
    }

    /// Runtime for `profile` with an explicit site `udiRoot.conf`.
    pub fn with_config(
        profile: &SystemProfile,
        config: UdiRootConfig,
    ) -> ShifterRuntime {
        Self::shared_with_config(Arc::new(profile.clone()), config)
    }

    /// Build from an already-shared profile without a deep clone — the
    /// path the launch orchestrator uses for its per-partition runtimes.
    pub fn shared(profile: Arc<SystemProfile>) -> ShifterRuntime {
        let config = UdiRootConfig::for_profile(&profile);
        Self::shared_with_config(profile, config)
    }

    /// [`ShifterRuntime::shared`] with an explicit site `udiRoot.conf`.
    pub fn shared_with_config(
        profile: Arc<SystemProfile>,
        config: UdiRootConfig,
    ) -> ShifterRuntime {
        let host_fs = profile.host_fs();
        ShifterRuntime {
            profile,
            config,
            host_fs,
            extensions: Arc::new(ExtensionRegistry::defaults()),
            telemetry: Arc::new(Telemetry::disabled()),
        }
    }

    /// Share a telemetry recorder with this runtime (see DESIGN.md S23);
    /// [`crate::SiteBuilder`] and the launch orchestrator wire the
    /// site-wide recorder here so every node run reports into one trace.
    pub fn with_telemetry(
        mut self,
        telemetry: Arc<Telemetry>,
    ) -> ShifterRuntime {
        self.telemetry = telemetry;
        self
    }

    /// Replace the host-extension registry this runtime drives — the
    /// wiring point [`crate::SiteBuilder::with_extension`] /
    /// [`crate::SiteBuilder::without_default_extensions`] reach node
    /// execution through. The registry lives behind an `Arc` so a launch
    /// orchestrator's per-partition runtimes share one instance.
    pub fn with_extensions(
        mut self,
        extensions: Arc<ExtensionRegistry>,
    ) -> ShifterRuntime {
        self.extensions = extensions;
        self
    }

    /// The host-extension registry this runtime drives.
    pub fn extensions(&self) -> &ExtensionRegistry {
        &self.extensions
    }

    /// The host profile this runtime executes on.
    pub fn profile(&self) -> &SystemProfile {
        &self.profile
    }

    /// The host filesystem model site mounts and support libraries come
    /// from.
    pub fn host_fs(&self) -> &VirtualFs {
        &self.host_fs
    }

    /// Run the full §III.A stage pipeline and return the container.
    ///
    /// Generic over the image source: pass the classic `&ImageGateway` or a
    /// `&distrib::DistributionFabric` — the stage pipeline is identical,
    /// only image resolution and the node-side squashfs fetch differ.
    pub fn run<S: ImageSource>(
        &self,
        source: &S,
        opts: &RunOptions,
    ) -> Result<Container, ShifterError> {
        let mut log = StageLog::new();
        let mut privs =
            PrivilegeState::setuid_start(opts.invoking_uid, opts.invoking_gid);

        // -- resolve image ------------------------------------------------
        let gw_image = source.resolve(&opts.image)?;
        log.record(
            Stage::ResolveImage,
            &privs,
            format!("{} on {}", gw_image.reference.canonical(), gw_image.pfs_path),
            source.resolve_latency_secs(),
        )?;

        // -- extension preflight -------------------------------------------
        // trigger + check every registered extension BEFORE environment
        // preparation begins: an incompatible driver, MPI ABI or fabric
        // transport refuses the run here, before a single mount happens
        let ctx = ExtensionContext {
            opts,
            manifest: &gw_image.manifest,
            profile: &self.profile,
            config: &self.config,
            host_fs: &self.host_fs,
        };
        let mut triggered: Vec<&dyn HostExtension> = Vec::new();
        for ext in self.extensions.iter() {
            if let Activation::Triggered(_) = ext.trigger(&ctx) {
                ext.check(&ctx).map_err(|source| {
                    ShifterError::ExtensionCheck {
                        extension: ext.name(),
                        source,
                    }
                })?;
                triggered.push(ext);
            }
        }

        // -- prepare environment -------------------------------------------
        let mut mounts = MountTable::new();
        let mut prepare_secs = 0.0;

        // fetch the squashfs to the node and loop mount it; a distributed
        // source answers from its node-cache model, the single gateway
        // defers to the host profile's PFS contention model. A lazy
        // source splits the fetch: only the start-ready head blocks
        // prepare, the streamed tail is charged to execution below.
        let image_bytes = gw_image.squashfs.compressed_bytes;
        let concurrent = opts.concurrent_nodes.max(1) as u64;
        let (fetch_secs, lazy_tail_secs) = match opts.fetch_override {
            Some(secs) => (secs, 0.0),
            None => match source.node_fetch_split(
                gw_image,
                opts.node,
                concurrent,
            ) {
                Some(split) => split,
                None => {
                    let secs = match &self.profile.pfs {
                        Some(pfs) => {
                            pfs.bulk_read_secs(image_bytes, concurrent)
                        }
                        None => {
                            image_bytes as f64 / LOCAL_DISK_BYTES_PER_SEC
                        }
                    };
                    (secs, 0.0)
                }
            },
        };
        prepare_secs += fetch_secs + LOOP_MOUNT_SECS;
        let mut rootfs = gw_image.squashfs.tree().clone();
        mounts.push(Mount {
            source: gw_image.pfs_path.clone(),
            target: self.config.udi_mount_point.clone(),
            kind: MountKind::Loop,
            origin: "image",
        });

        // site-specific mounts
        for m in &self.config.site_mounts {
            if self.host_fs.exists(&m.host_path) {
                rootfs
                    .graft(&self.host_fs, &m.host_path, &m.container_path)
                    .ok();
                mounts.bind(
                    &m.host_path,
                    &m.container_path,
                    m.read_only,
                    "site config",
                );
                prepare_secs += BIND_MOUNT_SECS;
            }
        }

        // tmpfs-backed writable dirs (the image itself is read-only)
        for dir in TMPFS_DIRS {
            rootfs.mkdir_p(dir).ok();
            mounts.push(Mount {
                source: "tmpfs".to_string(),
                target: dir.to_string(),
                kind: MountKind::Tmpfs,
                origin: "runtime",
            });
            prepare_secs += BIND_MOUNT_SECS;
        }

        // user-requested volumes (validated against site policy)
        for vol in &opts.volumes {
            vol.validate(&self.host_fs)?;
            rootfs
                .graft(&self.host_fs, &vol.host_path, &vol.container_path)
                .ok();
            mounts.bind(
                &vol.host_path,
                &vol.container_path,
                vol.read_only,
                "user volume",
            );
            prepare_secs += BIND_MOUNT_SECS;
        }

        // host-extension injection, in registry order (§IV.A GPU support,
        // §IV.B MPI swap, specialized networking, site-defined additions)
        let mut ext_env: BTreeMap<String, String> = BTreeMap::new();
        let mut ext_reports: Vec<ExtensionReport> = Vec::new();
        for ext in &triggered {
            let before = mounts.len();
            let report = ext
                .inject(&ctx, &mut rootfs, &mut mounts, &mut ext_env)
                .map_err(ShifterError::Extension)?;
            prepare_secs +=
                BIND_MOUNT_SECS * (mounts.len() - before) as f64;
            ext_reports.push(report);
        }
        let gpu = ext_reports.iter().find_map(|r| match &r.payload {
            ExtensionPayload::Gpu(rep) => Some(rep.clone()),
            _ => None,
        });
        let mpi = ext_reports.iter().find_map(|r| match &r.payload {
            ExtensionPayload::Mpi(rep) => Some(rep.clone()),
            _ => None,
        });
        let net = ext_reports.iter().find_map(|r| match &r.payload {
            ExtensionPayload::Net(rep) => Some(rep.clone()),
            _ => None,
        });

        log.record(
            Stage::PrepareEnvironment,
            &privs,
            format!(
                "{} mounts (gpu: {}, mpi: {}, net: {})",
                mounts.len(),
                gpu.is_some(),
                mpi.is_some(),
                net.is_some()
            ),
            prepare_secs,
        )?;
        log.attach_extensions(&ext_reports);

        // -- chroot jail ---------------------------------------------------
        log.record(
            Stage::ChrootJail,
            &privs,
            format!("chroot {}", self.config.udi_mount_point),
            CHROOT_SECS,
        )?;

        // -- drop privileges -----------------------------------------------
        log.record(
            Stage::DropPrivileges,
            &privs,
            format!(
                "setegid({}) seteuid({})",
                opts.invoking_gid, opts.invoking_uid
            ),
            SETUID_SECS,
        )?;
        privs.drop_privileges();

        // -- export environment ----------------------------------------------
        // image env first, then the allowlisted host variables (§III.A:
        // "selected variables from the host system are also added"), then
        // whatever the extensions exported during injection
        let mut env: BTreeMap<String, String> =
            gw_image.manifest.env.iter().cloned().collect();
        let image_vars = env.len();
        let mut exported = 0u32;
        for key in &self.config.host_env_allowlist {
            if let Some(v) = opts.env.get(key) {
                env.insert(key.clone(), v.clone());
                exported += 1;
            }
        }
        let ext_vars = ext_env.len();
        env.extend(ext_env);
        log.record(
            Stage::ExportEnvironment,
            &privs,
            format!(
                "{image_vars} image vars + {exported} host vars + \
                 {ext_vars} extension vars"
            ),
            env.len() as f64 * ENV_VAR_SECS,
        )?;

        // -- execute ----------------------------------------------------------
        // a lazily pulled image streams its remaining chunks on demand
        // while the workload runs: the tail lands on the execute stage
        let exec_detail = if lazy_tail_secs > 0.0 {
            format!(
                "exec {:?} as uid {} (streaming {:.3}s lazy tail)",
                opts.command, privs.effective_uid, lazy_tail_secs
            )
        } else {
            format!("exec {:?} as uid {}", opts.command, privs.effective_uid)
        };
        log.record(
            Stage::Execute,
            &privs,
            exec_detail,
            FORK_EXEC_SECS + lazy_tail_secs,
        )?;

        // -- cleanup ------------------------------------------------------------
        log.record(Stage::Cleanup, &privs, "release mounts", CLEANUP_SECS)?;

        self.emit_run_spans(opts, &log, &triggered, &ext_reports);

        Ok(Container {
            image: gw_image.reference.canonical(),
            rootfs,
            mounts,
            env,
            gpu,
            mpi,
            net,
            extensions: ext_reports,
            manifest: gw_image.manifest.clone(),
            stage_log: log,
            privileges: privs,
        })
    }

    /// Reconstruct the run's span tree after the stage pipeline
    /// completes (see DESIGN.md S23): the pipeline is strictly
    /// sequential, so absolute placement is the running prefix sum of
    /// stage costs from `opts.trace_start`. Extension checks land
    /// as instants at the preflight point (end of resolve); injections
    /// fill the tail of prepare-environment, each `BIND_MOUNT_SECS` per
    /// mount it added. No-op unless a recorder is installed and enabled.
    fn emit_run_spans(
        &self,
        opts: &RunOptions,
        log: &StageLog,
        triggered: &[&dyn HostExtension],
        ext_reports: &[ExtensionReport],
    ) {
        let tel = &self.telemetry;
        if !tel.enabled() {
            return;
        }
        let track = format!("node-{:05}", opts.node);
        let base = opts.trace_start.as_secs_f64();
        let total = log.total_sim_secs();
        let run_id = tel.span(SpanDraft {
            parent: opts.trace_parent,
            category: "run",
            name: &format!("run:{}", opts.image),
            track: &track,
            start: opts.trace_start,
            dur_secs: total,
        });
        let mut cursor = base;
        let mut resolve_end = base;
        let mut prepare = (base, 0.0);
        for rec in log.records() {
            tel.span(SpanDraft {
                parent: run_id,
                category: "stage",
                name: rec.stage.name(),
                track: &track,
                start: SimTime::from_secs(cursor),
                dur_secs: rec.sim_secs,
            });
            cursor += rec.sim_secs;
            match rec.stage {
                Stage::ResolveImage => resolve_end = cursor,
                Stage::PrepareEnvironment => {
                    prepare = (cursor - rec.sim_secs, rec.sim_secs);
                }
                _ => {}
            }
        }
        for ext in triggered {
            tel.span(SpanDraft {
                parent: run_id,
                category: "ext",
                name: &format!("ext:{}:check", ext.name()),
                track: &track,
                start: SimTime::from_secs(resolve_end),
                dur_secs: 0.0,
            });
        }
        let inject_total: f64 = ext_reports
            .iter()
            .map(|r| BIND_MOUNT_SECS * r.mounts_added as f64)
            .sum();
        let (prep_start, prep_dur) = prepare;
        let mut inject_cursor =
            (prep_start + prep_dur - inject_total).max(prep_start);
        for report in ext_reports {
            let dur = BIND_MOUNT_SECS * report.mounts_added as f64;
            tel.span(SpanDraft {
                parent: run_id,
                category: "ext",
                name: &format!("ext:{}:inject", report.extension),
                track: &track,
                start: SimTime::from_secs(inject_cursor),
                dur_secs: dur,
            });
            inject_cursor += dur;
        }
        tel.count("runtime.runs", 1);
        tel.count("runtime.extensions_injected", ext_reports.len() as u64);
        tel.observe("runtime.startup_secs", total);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::ImageGateway;
    use crate::pfs::LustreFs;
    use crate::registry::Registry;

    fn daint_setup() -> (SystemProfile, ImageGateway) {
        let profile = SystemProfile::piz_daint();
        let registry = Registry::dockerhub();
        let mut gw = ImageGateway::new(LustreFs::piz_daint());
        for img in [
            "ubuntu:xenial",
            "nvidia/cuda-image:8.0",
            "osu-benchmarks:mpich-3.1.4",
        ] {
            gw.pull(&registry, img).unwrap();
        }
        (profile, gw)
    }

    #[test]
    fn paper_section3_example_runs() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let opts =
            RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]);
        let c = rt.run(&gw, &opts).unwrap();
        assert!(c.stage_log.completed());
        let out = c.exec(&["cat", "/etc/os-release"]).unwrap();
        assert!(out.contains("16.04.2 LTS (Xenial Xerus)"));
        assert!(out.contains("UBUNTU_CODENAME=xenial"));
        // ran as the user, not root
        assert_eq!(c.privileges.effective_uid, 1000);
    }

    #[test]
    fn gpu_support_activates_via_env() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let opts = RunOptions::new("nvidia/cuda-image:8.0", &["true"])
            .with_env("CUDA_VISIBLE_DEVICES", "0");
        let c = rt.run(&gw, &opts).unwrap();
        let gpu = c.gpu.as_ref().expect("gpu support triggered");
        assert_eq!(gpu.host_devices, vec![0]);
        let gpus = c.visible_gpus(&profile, 0);
        assert_eq!(gpus.len(), 1);
        assert_eq!(gpus[0].name, "Tesla P100");
        // env carried into the container
        assert_eq!(c.env.get("CUDA_VISIBLE_DEVICES").unwrap(), "0");
    }

    #[test]
    fn no_cvd_no_gpu_support() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("nvidia/cuda-image:8.0", &["true"]))
            .unwrap();
        assert!(c.gpu.is_none());
        assert!(c.visible_gpus(&profile, 0).is_empty());
    }

    #[test]
    fn mpi_flag_swaps_to_host_library() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let opts = RunOptions::new("osu-benchmarks:mpich-3.1.4", &["true"])
            .with_mpi();
        let c = rt.run(&gw, &opts).unwrap();
        let rep = c.mpi.as_ref().unwrap();
        assert_eq!(rep.host_mpi, "Cray MPT 7.5.0");
        let eff = c.effective_mpi(&profile).unwrap();
        assert!(eff.supports_fabric(crate::fabric::FabricKind::CrayAries));
    }

    #[test]
    fn without_mpi_flag_container_keeps_its_own() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(
                &gw,
                &RunOptions::new("osu-benchmarks:mpich-3.1.4", &["true"]),
            )
            .unwrap();
        assert!(c.mpi.is_none());
        let eff = c.effective_mpi(&profile).unwrap();
        assert_eq!(eff.version_string(), "MPICH 3.1.4");
        assert!(!eff.supports_fabric(crate::fabric::FabricKind::CrayAries));
    }

    #[test]
    fn net_support_activates_via_env() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let opts = RunOptions::new("ubuntu:xenial", &["true"])
            .with_env("SHIFTER_NET", "host");
        let c = rt.run(&gw, &opts).unwrap();
        let net = c.net.as_ref().expect("net support triggered");
        assert_eq!(net.transport, "gni");
        assert!(c.rootfs.exists("/dev/kgni0"));
        assert!(c.rootfs.is_dir("/dev/hugepages"));
        assert_eq!(c.env.get("SHIFTER_NET_TRANSPORT").unwrap(), "gni");
        assert_eq!(c.effective_transport(), Transport::Native);
        assert_eq!(c.extensions.len(), 1);
        assert_eq!(c.extensions[0].extension, "net");
        assert_eq!(c.stage_log.extensions().len(), 1);
    }

    #[test]
    fn plain_container_falls_back_to_tcp() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        assert!(c.net.is_none());
        assert!(c.extensions.is_empty());
        assert_eq!(c.effective_transport(), Transport::TcpFallback);
    }

    #[test]
    fn site_mounts_present() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        assert!(!c.mounts.by_origin("site config").is_empty());
        assert!(c.rootfs.is_dir("/scratch"));
    }

    #[test]
    fn startup_overhead_is_small_and_positive() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let c = rt
            .run(&gw, &RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        let t = c.startup_overhead_secs();
        assert!(t > 0.0 && t < 5.0, "overhead={t}");
    }

    #[test]
    fn telemetry_records_stage_and_extension_spans() {
        use crate::telemetry::{Telemetry, TraceCtx};
        let (profile, gw) = daint_setup();
        let tel = Arc::new(Telemetry::new(true));
        let rt =
            ShifterRuntime::new(&profile).with_telemetry(Arc::clone(&tel));
        let opts = RunOptions::new("nvidia/cuda-image:8.0", &["true"])
            .with_env("CUDA_VISIBLE_DEVICES", "0")
            .traced(TraceCtx {
                parent: None,
                start: SimTime::from_secs(10.0),
            });
        let c = rt.run(&gw, &opts).unwrap();

        let spans = tel.spans();
        let run = spans.iter().find(|s| s.category == "run").unwrap();
        assert_eq!(run.start_secs(), 10.0);
        assert!((run.dur_secs - c.startup_overhead_secs()).abs() < 1e-12);
        // the seven §III.A stages tile the run span exactly
        let stages: Vec<_> =
            spans.iter().filter(|s| s.category == "stage").collect();
        assert_eq!(stages.len(), 7);
        let sum: f64 = stages.iter().map(|s| s.dur_secs).sum();
        assert!((sum - run.dur_secs).abs() < 1e-12);
        assert!(stages.iter().all(|s| s.parent == Some(run.id)));
        // one check + one inject span for the activated gpu extension
        assert!(spans.iter().any(|s| s.name == "ext:gpu:check"));
        let inject =
            spans.iter().find(|s| s.name == "ext:gpu:inject").unwrap();
        assert_eq!(inject.parent, Some(run.id));
        assert!(inject.dur_secs > 0.0);
        assert!(inject.end_secs() <= run.end_secs() + 1e-12);
        assert_eq!(tel.counter("runtime.runs"), 1);
        assert_eq!(tel.counter("runtime.extensions_injected"), 1);
    }

    #[test]
    fn unpulled_image_fails() {
        let (profile, gw) = daint_setup();
        let rt = ShifterRuntime::new(&profile);
        let err = rt
            .run(&gw, &RunOptions::new("pynamic:1.3", &["true"]))
            .unwrap_err();
        assert!(err.to_string().contains("not pulled"));
    }
}
