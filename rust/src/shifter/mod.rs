//! The paper's system contribution (DESIGN.md S1–S3, S22): the Shifter
//! Runtime stage machine with user-transparent host-resource injection —
//! native GPU support (§IV.A), MPI ABI-swap support (§IV.B) and
//! specialized networking (`crate::netfab`) — behind the pluggable
//! [`HostExtension`] registry.

pub mod extension;
pub mod gpu_support;
pub mod mpi_support;
pub mod preflight;
pub mod runtime;
pub mod stages;
pub mod volume;

pub use extension::{
    Activation, Capability, ExtensionContext, ExtensionError,
    ExtensionPayload, ExtensionRegistry, ExtensionReport, GpuExtension,
    HostExtension, MpiExtension,
};
pub use gpu_support::{GpuSupportError, GpuSupportReport, CONTAINER_GPU_LIB_DIR};
pub use mpi_support::{MpiSupportError, MpiSupportReport};
pub use runtime::{Container, RunOptions, ShifterError, ShifterRuntime};
pub use stages::{PrivilegeState, Stage, StageError, StageLog, StageRecord};
pub use volume::{VolumeError, VolumeSpec};
