//! The paper's system contribution (DESIGN.md S1–S3): the Shifter Runtime
//! stage machine with user-transparent native GPU support (§IV.A) and MPI
//! ABI-swap support (§IV.B).

pub mod gpu_support;
pub mod mpi_support;
pub mod preflight;
pub mod runtime;
pub mod stages;
pub mod volume;

pub use gpu_support::{GpuSupportError, GpuSupportReport, CONTAINER_GPU_LIB_DIR};
pub use mpi_support::{MpiSupportError, MpiSupportReport};
pub use runtime::{Container, RunOptions, ShifterError, ShifterRuntime};
pub use stages::{PrivilegeState, Stage, StageError, StageLog, StageRecord};
pub use volume::{VolumeError, VolumeSpec};
