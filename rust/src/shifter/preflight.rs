//! Host preflight checks — §III's design goal 3: "maintaining
//! compatibility with older Linux kernels".
//!
//! Shifter deliberately avoids kernel features that HPC sites' old
//! enterprise kernels lack (user namespaces, overlayfs): its requirements
//! are only chroot(2), loop devices, squashfs, and setuid — all present
//! since 2.6.32-era kernels. This module validates a host profile against
//! that requirement set and explains what a newer-kernel runtime (Docker)
//! would additionally demand.

use crate::config::UdiRootConfig;
use crate::hostenv::SystemProfile;

use super::extension::{Capability, ExtensionRegistry};

/// A kernel version, parsed from "3.12.60"-style strings.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub struct KernelVersion {
    /// Major version (the `3` in 3.12.60).
    pub major: u32,
    /// Minor version (the `12` in 3.12.60).
    pub minor: u32,
    /// Patch level (the `60` in 3.12.60; 0 when absent).
    pub patch: u32,
}

impl KernelVersion {
    /// Parse a `3.12.60` / `3.10.0-514`-style version string.
    pub fn parse(s: &str) -> Option<KernelVersion> {
        let mut it = s.split(['.', '-']).map(|p| p.parse::<u32>().ok());
        Some(KernelVersion {
            major: it.next()??,
            minor: it.next()??,
            patch: it.next().flatten().unwrap_or(0),
        })
    }

    /// Build a version literal.
    pub const fn new(major: u32, minor: u32, patch: u32) -> KernelVersion {
        KernelVersion {
            major,
            minor,
            patch,
        }
    }
}

/// Kernel facilities container runtimes may depend on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelFeature {
    /// chroot(2) — ancient.
    Chroot,
    /// loop block devices — ancient.
    LoopDevice,
    /// squashfs (mainlined 2.6.29).
    Squashfs,
    /// user namespaces (stable ~3.8; many enterprise kernels disable them).
    UserNamespaces,
    /// overlayfs (mainlined 3.18).
    OverlayFs,
}

impl KernelFeature {
    /// First mainline kernel providing the feature.
    pub fn since(&self) -> KernelVersion {
        match self {
            KernelFeature::Chroot => KernelVersion::new(2, 0, 0),
            KernelFeature::LoopDevice => KernelVersion::new(2, 0, 0),
            KernelFeature::Squashfs => KernelVersion::new(2, 6, 29),
            KernelFeature::UserNamespaces => KernelVersion::new(3, 8, 0),
            KernelFeature::OverlayFs => KernelVersion::new(3, 18, 0),
        }
    }
}

/// What Shifter needs from the kernel (design goal 3: no namespaces, no
/// overlayfs — hence the old-kernel compatibility).
pub const SHIFTER_REQUIREMENTS: [KernelFeature; 3] = [
    KernelFeature::Chroot,
    KernelFeature::LoopDevice,
    KernelFeature::Squashfs,
];

/// What a Docker-style runtime of the era needed.
pub const DOCKER_REQUIREMENTS: [KernelFeature; 4] = [
    KernelFeature::Chroot,
    KernelFeature::LoopDevice,
    KernelFeature::UserNamespaces,
    KernelFeature::OverlayFs,
];

/// Outcome of checking a requirement set against a host kernel.
#[derive(Debug, Clone)]
pub struct PreflightReport {
    /// The host kernel that was checked.
    pub kernel: KernelVersion,
    /// Requirements the kernel provides.
    pub satisfied: Vec<KernelFeature>,
    /// Requirements the kernel lacks (empty means the host can run).
    pub missing: Vec<KernelFeature>,
}

impl PreflightReport {
    /// Whether every requirement is satisfied.
    pub fn ok(&self) -> bool {
        self.missing.is_empty()
    }
}

/// Check a requirement set against a host kernel.
pub fn check(
    kernel: KernelVersion,
    requirements: &[KernelFeature],
) -> PreflightReport {
    let (satisfied, missing) = requirements
        .iter()
        .partition(|f| kernel >= f.since());
    PreflightReport {
        kernel,
        satisfied,
        missing,
    }
}

/// Preflight a system profile for Shifter.
pub fn preflight(profile: &SystemProfile) -> PreflightReport {
    let kernel = KernelVersion::parse(profile.kernel)
        .unwrap_or(KernelVersion::new(0, 0, 0));
    check(kernel, &SHIFTER_REQUIREMENTS)
}

/// Kernel preflight plus the host-extension capability vector: what a
/// host can run (kernel facilities) and what it can *offer* (S22
/// `HostExtension::capability` per registered extension).
#[derive(Debug, Clone)]
pub struct HostPreflight {
    /// The kernel-facility check.
    pub kernel: PreflightReport,
    /// One capability verdict per registered extension, in registry
    /// order.
    pub capabilities: Vec<Capability>,
}

/// Preflight a profile against both the kernel requirement set and an
/// extension registry's capability checks — the full host verdict
/// `shifter --extensions` prints (`shifterimg cluster-status` surfaces
/// the same capability vector per partition via
/// [`crate::Site::capabilities`]).
pub fn preflight_with_extensions(
    profile: &SystemProfile,
    config: &UdiRootConfig,
    registry: &ExtensionRegistry,
) -> HostPreflight {
    HostPreflight {
        kernel: preflight(profile),
        capabilities: registry.capabilities(profile, config),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_versions() {
        assert_eq!(
            KernelVersion::parse("3.12.60"),
            Some(KernelVersion::new(3, 12, 60))
        );
        assert_eq!(
            KernelVersion::parse("3.10.0-514"),
            Some(KernelVersion::new(3, 10, 0))
        );
        assert_eq!(KernelVersion::parse("4.4"), Some(KernelVersion::new(4, 4, 0)));
        assert_eq!(KernelVersion::parse("garbage"), None);
    }

    #[test]
    fn ordering() {
        assert!(KernelVersion::new(3, 10, 0) > KernelVersion::new(2, 6, 32));
        assert!(KernelVersion::new(3, 8, 0) < KernelVersion::new(3, 12, 60));
    }

    #[test]
    fn all_three_paper_systems_pass_shifter_preflight() {
        for profile in [
            SystemProfile::laptop(),
            SystemProfile::linux_cluster(),
            SystemProfile::piz_daint(),
        ] {
            let rep = preflight(&profile);
            assert!(rep.ok(), "{}: missing {:?}", profile.name, rep.missing);
            assert_eq!(rep.satisfied.len(), 3);
        }
    }

    #[test]
    fn the_papers_kernels_would_fail_docker_era_requirements() {
        // the design point: 3.10/3.12 enterprise kernels predate overlayfs
        for profile in [SystemProfile::linux_cluster(), SystemProfile::piz_daint()]
        {
            let kernel = KernelVersion::parse(profile.kernel).unwrap();
            let rep = check(kernel, &DOCKER_REQUIREMENTS);
            assert!(
                rep.missing.contains(&KernelFeature::OverlayFs),
                "{}",
                profile.name
            );
        }
    }

    #[test]
    fn extension_capabilities_ride_along_with_preflight() {
        let profile = SystemProfile::piz_daint();
        let config = UdiRootConfig::for_profile(&profile);
        let registry = ExtensionRegistry::defaults();
        let full = preflight_with_extensions(&profile, &config, &registry);
        assert!(full.kernel.ok());
        assert_eq!(full.capabilities.len(), 3);
        assert!(full.capabilities.iter().all(|c| c.available));

        let laptop = SystemProfile::laptop();
        let config = UdiRootConfig::for_profile(&laptop);
        let full = preflight_with_extensions(&laptop, &config, &registry);
        assert!(full.kernel.ok());
        // the laptop can run shifter but offers no fabric transport
        assert!(!full.capabilities[2].available);
    }

    #[test]
    fn ancient_kernel_fails_squashfs() {
        let rep = check(KernelVersion::new(2, 6, 18), &SHIFTER_REQUIREMENTS);
        assert!(!rep.ok());
        assert!(rep.missing.contains(&KernelFeature::Squashfs));
        assert!(rep.satisfied.contains(&KernelFeature::Chroot));
    }
}
