//! Native MPI support (§IV.B) — the second half of the paper's
//! contribution: swap the container's MPICH-ABI MPI for the host's
//! fabric-optimized implementation.
//!
//! "The MPI library that is used by a container image … is swapped by
//! Shifter Runtime and replaced by the ABI-compatible equivalent available
//! on the host system. … Shifter also checks that the MPI library to be
//! replaced is compatible with the host's MPI library: this is done by
//! comparing the libtool ABI string of both libraries."

use std::collections::BTreeMap;

use crate::config::UdiRootConfig;
use crate::image::builder::{LABEL_MPI_ABI, LABEL_MPI_VENDOR, LABEL_MPI_VERSION};
use crate::mpi::{LibtoolAbi, MpiImpl, MpiVendor, MPICH_ABI_SONAME};
use crate::vfs::{MountTable, VirtualFs};

/// Failures of the §IV.B MPI library swap.
#[derive(Debug, thiserror::Error, PartialEq)]
#[non_exhaustive]
pub enum MpiSupportError {
    /// `--mpi` was passed but the image carries no MPI library.
    #[error("--mpi requested but the image contains no MPI library")]
    NoMpiInImage,
    /// The image's MPI ABI labels could not be parsed.
    #[error("container MPI has unparsable ABI metadata: {0}")]
    BadAbiMetadata(String),
    /// The libtool ABI-string comparison refused the swap.
    #[error(
        "container MPI ({container}) is not ABI-compatible with host MPI \
         ({host}): libtool strings {container_abi} vs {host_abi}"
    )]
    AbiIncompatible {
        /// The container MPI's version string.
        container: String,
        /// The host MPI's version string.
        host: String,
        /// The container MPI's libtool ABI string.
        container_abi: String,
        /// The host MPI's libtool ABI string.
        host_abi: String,
    },
    /// A host MPI library/config path named by `udiRoot.conf` is absent.
    #[error("host MPI library missing on this system: {0}")]
    MissingHostLibrary(String),
    /// Grafting a host node into the container rootfs failed (path
    /// conflict inside the image tree).
    #[error("container rootfs graft failed: {0}")]
    Rootfs(#[from] crate::vfs::VfsError),
}

/// What the MPI swap did.
#[derive(Debug, Clone, PartialEq)]
pub struct MpiSupportReport {
    /// The container's own MPI (version string).
    pub container_mpi: String,
    /// The host MPI swapped in (version string).
    pub host_mpi: String,
    /// (container path shadowed, host path mounted over it)
    pub swapped: Vec<(String, String)>,
    /// Host transport libraries mounted at their host paths.
    pub dependencies: Vec<String>,
    /// Host MPI configuration files/folders mounted in.
    pub config_files: Vec<String>,
}

/// Reconstruct the container's MPI identity from the image labels (the
/// simulation's stand-in for reading the libtool string out of the ELF).
pub fn container_mpi_from_labels(
    labels: &BTreeMap<String, String>,
) -> Result<Option<MpiImpl>, MpiSupportError> {
    let vendor = match labels.get(LABEL_MPI_VENDOR) {
        Some(v) => v,
        None => return Ok(None),
    };
    let abi_str = labels
        .get(LABEL_MPI_ABI)
        .ok_or_else(|| MpiSupportError::BadAbiMetadata("missing abi label".into()))?;
    let abi = LibtoolAbi::parse(abi_str)
        .ok_or_else(|| MpiSupportError::BadAbiMetadata(abi_str.clone()))?;
    let version = labels
        .get(LABEL_MPI_VERSION)
        .map(|v| {
            let mut it = v.split('.').map(|p| p.parse::<u32>().unwrap_or(0));
            (
                it.next().unwrap_or(0),
                it.next().unwrap_or(0),
                it.next().unwrap_or(0),
            )
        })
        .unwrap_or((0, 0, 0));
    let vendor = match vendor.as_str() {
        "MPICH" => MpiVendor::Mpich,
        "MVAPICH2" => MpiVendor::Mvapich2,
        "Intel MPI" => MpiVendor::IntelMpi,
        "Cray MPT" => MpiVendor::CrayMpt,
        "IBM MPI" => MpiVendor::IbmMpi,
        _ => MpiVendor::OpenMpi,
    };
    Ok(Some(MpiImpl {
        vendor,
        version,
        abi,
        native_fabrics: vec![], // container builds are portable/TCP-only
    }))
}

/// The §IV.B compatibility gate, separated from the mutation so the
/// `HostExtension` lifecycle can refuse a run in preflight, before
/// `Stage::PrepareEnvironment` begins: the image must carry an MPI, its
/// ABI metadata must parse, and the libtool ABI-string comparison (plus
/// MPICH-ABI-initiative membership) must accept the swap. Returns the
/// container's MPI identity.
pub fn check(
    image_labels: &BTreeMap<String, String>,
    host_mpi: &MpiImpl,
) -> Result<MpiImpl, MpiSupportError> {
    let container_mpi = container_mpi_from_labels(image_labels)?
        .ok_or(MpiSupportError::NoMpiInImage)?;

    // the libtool ABI-string comparison (+ initiative membership)
    let compatible = container_mpi.mpich_abi_member()
        && host_mpi.mpich_abi_member()
        && host_mpi.abi.host_can_replace(&container_mpi.abi)
        && container_mpi.abi.soname_major() == MPICH_ABI_SONAME;
    if !compatible {
        return Err(MpiSupportError::AbiIncompatible {
            container: container_mpi.version_string(),
            host: host_mpi.version_string(),
            container_abi: container_mpi.abi.abi_string(),
            host_abi: host_mpi.abi.abi_string(),
        });
    }
    Ok(container_mpi)
}

/// Perform the §IV.B swap during environment preparation ([`check`]
/// followed by the [`inject`] mutation). Only invoked when the user
/// passed `--mpi`.
pub fn activate(
    image_labels: &BTreeMap<String, String>,
    host_mpi: &MpiImpl,
    config: &UdiRootConfig,
    host_fs: &VirtualFs,
    rootfs: &mut VirtualFs,
    mounts: &mut MountTable,
) -> Result<MpiSupportReport, MpiSupportError> {
    let container_mpi = check(image_labels, host_mpi)?;
    inject(&container_mpi, host_mpi, config, host_fs, rootfs, mounts)
}

/// The §IV.B mutation: shadow the container's MPI frontends with the
/// host's, then mount the host MPI's transport dependencies and config
/// files. `container_mpi` must already have passed [`check`].
pub fn inject(
    container_mpi: &MpiImpl,
    host_mpi: &MpiImpl,
    config: &UdiRootConfig,
    host_fs: &VirtualFs,
    rootfs: &mut VirtualFs,
    mounts: &mut MountTable,
) -> Result<MpiSupportReport, MpiSupportError> {
    // locate the container's frontend libraries in the image rootfs.
    // §Perf L3-2: one pass over the (large) rootfs path set matching all
    // three names, instead of one full scan per library.
    let frontends = container_mpi.frontend_libraries();
    let suffixes: Vec<String> =
        frontends.iter().map(|l| format!("/{l}")).collect();
    let mut found: Vec<Option<String>> = vec![None; frontends.len()];
    for p in rootfs.paths() {
        for (i, suffix) in suffixes.iter().enumerate() {
            if found[i].is_none() && p.ends_with(suffix.as_str()) {
                found[i] = Some(p.clone());
            }
        }
    }
    let mut container_paths: Vec<(String, String)> = Vec::new(); // (libname, path)
    for (lib, path) in frontends.iter().zip(found) {
        match path {
            Some(p) => container_paths.push((lib.clone(), p)),
            None => return Err(MpiSupportError::NoMpiInImage),
        }
    }

    // bind mount host frontends over the container's (shadowing them)
    let mut swapped = Vec::new();
    for (lib, container_path) in &container_paths {
        let host_path = config
            .mpi_frontend_paths
            .iter()
            .find(|p| p.ends_with(&format!("/{lib}")))
            .cloned()
            .ok_or_else(|| MpiSupportError::MissingHostLibrary(lib.clone()))?;
        let node = host_fs
            .get(&host_path)
            .cloned()
            .ok_or_else(|| MpiSupportError::MissingHostLibrary(host_path.clone()))?;
        rootfs.insert(container_path, node)?;
        mounts.bind(&host_path, container_path, true, "mpi swap");
        swapped.push((container_path.clone(), host_path));
    }

    // mount the host MPI's own dependencies at their host paths
    let mut dependencies = Vec::new();
    for dep in &config.mpi_dependency_paths {
        let node = host_fs
            .get(dep)
            .cloned()
            .ok_or_else(|| MpiSupportError::MissingHostLibrary(dep.clone()))?;
        rootfs.insert(dep, node)?;
        mounts.bind(dep, dep, true, "mpi swap");
        dependencies.push(dep.clone());
    }

    // and its configuration files/folders
    let mut config_files = Vec::new();
    for cfg in &config.mpi_config_paths {
        let node = host_fs
            .get(cfg)
            .cloned()
            .ok_or_else(|| MpiSupportError::MissingHostLibrary(cfg.clone()))?;
        rootfs.insert(cfg, node)?;
        mounts.bind(cfg, cfg, true, "mpi swap");
        config_files.push(cfg.clone());
    }

    Ok(MpiSupportReport {
        container_mpi: container_mpi.version_string(),
        host_mpi: host_mpi.version_string(),
        swapped,
        dependencies,
        config_files,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UdiRootConfig;
    use crate::hostenv::SystemProfile;
    use crate::image::builder;

    fn setup(
        image: crate::image::Image,
        profile: &SystemProfile,
    ) -> (
        BTreeMap<String, String>,
        MpiImpl,
        UdiRootConfig,
        VirtualFs,
        VirtualFs,
        MountTable,
    ) {
        let labels = image.manifest.labels.clone();
        let rootfs = image.flatten().unwrap();
        (
            labels,
            profile.host_mpi.clone(),
            UdiRootConfig::for_profile(profile),
            profile.host_fs(),
            rootfs,
            MountTable::new(),
        )
    }

    #[test]
    fn swap_on_daint_mounts_cray_mpt_over_container_mpich() {
        let pd = SystemProfile::piz_daint();
        let (labels, host, cfg, host_fs, mut rootfs, mut mounts) =
            setup(builder::osu_image_a(), &pd);
        let rep = activate(&labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts)
            .unwrap();
        assert_eq!(rep.container_mpi, "MPICH 3.1.4");
        assert_eq!(rep.host_mpi, "Cray MPT 7.5.0");
        assert_eq!(rep.swapped.len(), 3);
        // the container path is now backed by the host library
        let (cpath, hpath) = &rep.swapped[0];
        assert!(cpath.starts_with("/usr/local/mpi/lib/"));
        assert!(hpath.starts_with(pd.mpi_prefix));
        assert_eq!(mounts.effective(cpath).unwrap().source, *hpath);
        // cray transport deps are present in the container now
        assert!(rootfs.exists("/opt/cray/ugni/default/lib64/libugni.so.0"));
        assert!(rootfs.exists("/etc/opt/cray/wlm_detect/active_wlm"));
    }

    #[test]
    fn all_three_containers_swap_on_cluster() {
        let cl = SystemProfile::linux_cluster();
        for img in [
            builder::osu_image_a(),
            builder::osu_image_b(),
            builder::osu_image_c(),
        ] {
            let name = img.reference.canonical();
            let (labels, host, cfg, host_fs, mut rootfs, mut mounts) =
                setup(img, &cl);
            let rep = activate(
                &labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts,
            )
            .unwrap();
            assert_eq!(rep.host_mpi, "MVAPICH2 2.1.0", "{name}");
            assert!(rootfs.exists("/usr/lib64/libibverbs.so.1"));
        }
    }

    #[test]
    fn openmpi_image_rejected_with_abi_detail() {
        let pd = SystemProfile::piz_daint();
        let (labels, host, cfg, host_fs, mut rootfs, mut mounts) =
            setup(builder::openmpi_image(), &pd);
        let err =
            activate(&labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err();
        match err {
            MpiSupportError::AbiIncompatible {
                container_abi,
                host_abi,
                ..
            } => {
                assert_eq!(container_abi, "40:0:20");
                assert_eq!(host_abi, "12:7:0");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn image_without_mpi_rejected() {
        let pd = SystemProfile::piz_daint();
        let (labels, host, cfg, host_fs, mut rootfs, mut mounts) =
            setup(builder::ubuntu_xenial(), &pd);
        let err =
            activate(&labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err();
        assert_eq!(err, MpiSupportError::NoMpiInImage);
    }

    #[test]
    fn corrupt_abi_label_rejected() {
        let pd = SystemProfile::piz_daint();
        let (mut labels, host, cfg, host_fs, mut rootfs, mut mounts) =
            setup(builder::osu_image_a(), &pd);
        labels.insert(LABEL_MPI_ABI.to_string(), "not-an-abi".to_string());
        let err =
            activate(&labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err();
        assert!(matches!(err, MpiSupportError::BadAbiMetadata(_)));
    }

    #[test]
    fn missing_host_dependency_reported() {
        let pd = SystemProfile::piz_daint();
        let (labels, host, cfg, mut host_fs, mut rootfs, mut mounts) =
            setup(builder::osu_image_a(), &pd);
        host_fs
            .remove("/opt/cray/ugni/default/lib64/libugni.so.0")
            .unwrap();
        let err =
            activate(&labels, &host, &cfg, &host_fs, &mut rootfs, &mut mounts)
                .unwrap_err();
        assert!(matches!(err, MpiSupportError::MissingHostLibrary(_)));
    }
}
