//! The pluggable `HostExtension` API (DESIGN.md S22): one trait for every
//! host-resource injection the runtime performs.
//!
//! The paper's contribution is "an extension to the container runtime …
//! that provides containerized applications with a mechanism to access
//! GPU accelerators and specialized networking from the host system".
//! Instead of hard-coding each resource as an ad-hoc call inside
//! [`super::ShifterRuntime::run`], every injection — §IV.A GPU support,
//! §IV.B MPI swap, the specialized-network graft
//! ([`crate::netfab::NetworkSupport`]), and any site-defined addition —
//! implements [`HostExtension`] and registers in an ordered
//! [`ExtensionRegistry`]. The runtime then drives one uniform lifecycle
//! per run:
//!
//! 1. **trigger** — after image resolution, each extension inspects the
//!    run (launch env, CLI flags, image labels) and declares whether it
//!    activates. Absent or invalid triggers skip silently (§IV.A: an
//!    invalid `CUDA_VISIBLE_DEVICES` "does not trigger" support).
//! 2. **check** — every triggered extension's compatibility gate runs
//!    *before environment preparation begins*: driver versions, libtool
//!    ABI strings, fabric transport ABIs. An incompatible run fails in
//!    preflight, before a single mount happens.
//! 3. **inject** — inside `Stage::PrepareEnvironment`, each triggered
//!    extension grafts its host resources into the container rootfs,
//!    records its mounts, and may export environment variables. Each
//!    returns an [`ExtensionReport`] aggregated into the
//!    [`super::StageLog`], the [`super::Container`], and the launch
//!    orchestrator's per-node results.
//!
//! ```
//! use shifter_rs::shifter::ExtensionRegistry;
//! use shifter_rs::{SystemProfile, UdiRootConfig};
//!
//! let registry = ExtensionRegistry::defaults();
//! assert_eq!(registry.names(), ["gpu", "mpi", "net"]);
//! let profile = SystemProfile::laptop();
//! let config = UdiRootConfig::for_profile(&profile);
//! let caps = registry.capabilities(&profile, &config);
//! // the laptop has a GPU and an ABI-compatible MPI, but no fabric
//! assert!(caps[0].available && caps[1].available && !caps[2].available);
//! ```

use std::collections::BTreeMap;

use crate::config::UdiRootConfig;
use crate::hostenv::SystemProfile;
use crate::image::ImageManifest;
use crate::netfab::{NetSupportError, NetSupportReport, NetworkSupport};
use crate::vfs::{MountTable, VirtualFs};

use super::gpu_support::{self, GpuSupportError, GpuSupportReport};
use super::mpi_support::{self, MpiSupportError, MpiSupportReport};
use super::runtime::RunOptions;

/// Everything an extension may inspect when deciding to trigger, gating
/// compatibility, or injecting: the run request, the resolved image's
/// manifest, and the host side (profile, site config, host filesystem).
pub struct ExtensionContext<'a> {
    /// The run being prepared (flags, launch env, target node).
    pub opts: &'a RunOptions,
    /// Manifest of the resolved image — labels drive triggers and ABI
    /// gates (the simulation's stand-in for reading ELF metadata).
    pub manifest: &'a ImageManifest,
    /// Host profile of the partition this run executes on.
    pub profile: &'a SystemProfile,
    /// The site `udiRoot.conf` (host library/device paths).
    pub config: &'a UdiRootConfig,
    /// Host filesystem extensions bind-mount resources from.
    pub host_fs: &'a VirtualFs,
}

impl ExtensionContext<'_> {
    /// The launch environment (trigger variables live here).
    pub fn env(&self) -> &BTreeMap<String, String> {
        &self.opts.env
    }

    /// The node this run executes on (drives per-node driver lookup).
    pub fn node(&self) -> usize {
        self.opts.node
    }
}

/// Outcome of an extension's activation trigger.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Activation {
    /// The extension activates for this run; the detail names the
    /// trigger that fired (env var, CLI flag, image label).
    Triggered(String),
    /// The extension stays inactive; the detail explains why (for the
    /// audit trail — a skip is never an error).
    Skipped(String),
}

impl Activation {
    /// Whether the trigger fired.
    pub fn is_triggered(&self) -> bool {
        matches!(self, Activation::Triggered(_))
    }
}

/// A host-side compatibility verdict: can this host provide the
/// extension's resource at all? Feeds preflight, `shifter --extensions`
/// and the per-partition capability vectors of `shifterimg
/// cluster-status`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Capability {
    /// The extension this verdict is about.
    pub extension: &'static str,
    /// Whether the host can provide the resource.
    pub available: bool,
    /// Human-readable justification (driver/ABI/fabric inventory).
    pub detail: String,
}

/// One typed error surface for every host-resource injection: the
/// formerly free-standing GPU/MPI error enums become sourced variants,
/// and the network extension joins them.
#[derive(Debug, thiserror::Error, PartialEq)]
#[non_exhaustive]
pub enum ExtensionError {
    /// The §IV.A GPU support procedure failed.
    #[error(transparent)]
    Gpu(#[from] GpuSupportError),
    /// The §IV.B MPI library swap failed.
    #[error(transparent)]
    Mpi(#[from] MpiSupportError),
    /// The specialized-network injection failed.
    #[error(transparent)]
    Net(#[from] NetSupportError),
    /// A (possibly site-defined) extension rejected the run.
    #[error("extension '{extension}' rejected this run: {reason}")]
    Incompatible {
        /// Which extension refused.
        extension: &'static str,
        /// Why it refused.
        reason: String,
    },
}

/// The extension-specific half of an [`ExtensionReport`]: the typed
/// reports the GPU/MPI/network procedures always produced, preserved
/// bit-for-bit behind the uniform API.
#[derive(Debug, Clone, PartialEq)]
#[non_exhaustive]
pub enum ExtensionPayload {
    /// §IV.A GPU support report.
    Gpu(GpuSupportReport),
    /// §IV.B MPI swap report.
    Mpi(MpiSupportReport),
    /// Specialized-network injection report.
    Net(NetSupportReport),
    /// A site-defined extension without a typed report.
    Custom,
}

/// What one extension's injection did to the container — aggregated into
/// the [`super::StageLog`], the [`super::Container`], and the launch
/// report's per-node results.
#[derive(Debug, Clone, PartialEq)]
pub struct ExtensionReport {
    /// Which extension ran.
    pub extension: &'static str,
    /// Human-readable summary of the injection.
    pub detail: String,
    /// Mounts the injection added to the mount table.
    pub mounts_added: usize,
    /// Environment variables the injection exported into the container.
    pub env_added: usize,
    /// The extension-specific typed report.
    pub payload: ExtensionPayload,
}

/// A pluggable host-resource injection. Implementations must be
/// stateless with respect to individual runs (the same registry is
/// shared across worker threads by the launch orchestrator) and fully
/// deterministic: trigger/check/inject may depend only on the
/// [`ExtensionContext`].
pub trait HostExtension: Send + Sync {
    /// Stable short name ("gpu", "mpi", "net") used in logs, reports and
    /// error messages.
    fn name(&self) -> &'static str;

    /// One-line description of the activation trigger, for the
    /// `shifter --extensions` listing.
    fn trigger_description(&self) -> String {
        "extension-specific trigger".to_string()
    }

    /// Decide whether this extension activates for the run. Absent or
    /// invalid triggers return [`Activation::Skipped`] — never an error.
    fn trigger(&self, ctx: &ExtensionContext<'_>) -> Activation;

    /// Compatibility gate for a triggered run, executed in preflight
    /// *before* `Stage::PrepareEnvironment`: driver/ABI/fabric checks
    /// that must refuse the run before any mount happens.
    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError>;

    /// Host-side capability probe without a concrete run — what a
    /// partition can provide in principle. Feeds preflight listings and
    /// `shifterimg cluster-status`.
    fn capability(
        &self,
        profile: &SystemProfile,
        config: &UdiRootConfig,
    ) -> Capability;

    /// Graft the host resources into the container during
    /// `Stage::PrepareEnvironment`: mutate the rootfs, record mounts,
    /// optionally export environment variables.
    fn inject(
        &self,
        ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError>;
}

/// The ordered set of extensions a runtime applies. Order is the
/// injection order (later extensions may shadow earlier mounts, exactly
/// like the mount table itself); the stock order is GPU, MPI, network.
#[derive(Default)]
pub struct ExtensionRegistry {
    extensions: Vec<Box<dyn HostExtension>>,
    /// True only for the untouched [`ExtensionRegistry::defaults`] set —
    /// the launch orchestrator's slot-template fast path keys on this
    /// (stock triggers are rank-invariant within a partition; a
    /// site-defined extension may not be).
    stock: bool,
}

impl ExtensionRegistry {
    /// An empty registry (pair with
    /// [`crate::SiteBuilder::without_default_extensions`] to opt out of
    /// the stock set).
    pub fn empty() -> ExtensionRegistry {
        ExtensionRegistry::default()
    }

    /// The stock registry: §IV.A GPU support, §IV.B MPI swap, and the
    /// specialized-network injection, in that order.
    pub fn defaults() -> ExtensionRegistry {
        let mut reg = ExtensionRegistry::empty()
            .with(Box::new(GpuExtension))
            .with(Box::new(MpiExtension))
            .with(Box::new(NetworkSupport));
        reg.stock = true;
        reg
    }

    /// Whether this is the untouched stock GPU/MPI/net set. `false` the
    /// moment anything registers (or for [`ExtensionRegistry::empty`]).
    pub fn is_stock(&self) -> bool {
        self.stock
    }

    /// Append an extension to the injection order.
    pub fn register(&mut self, extension: Box<dyn HostExtension>) {
        self.stock = false;
        self.extensions.push(extension);
    }

    /// Builder-style [`ExtensionRegistry::register`].
    pub fn with(mut self, extension: Box<dyn HostExtension>) -> Self {
        self.register(extension);
        self
    }

    /// The extensions in injection order.
    pub fn iter(&self) -> impl Iterator<Item = &dyn HostExtension> {
        self.extensions.iter().map(|e| e.as_ref())
    }

    /// Number of registered extensions.
    pub fn len(&self) -> usize {
        self.extensions.len()
    }

    /// Whether no extensions are registered.
    pub fn is_empty(&self) -> bool {
        self.extensions.is_empty()
    }

    /// Extension names in injection order.
    pub fn names(&self) -> Vec<&'static str> {
        self.extensions.iter().map(|e| e.name()).collect()
    }

    /// The host-side capability vector of this registry on a given
    /// profile — one [`Capability`] per extension, in injection order.
    pub fn capabilities(
        &self,
        profile: &SystemProfile,
        config: &UdiRootConfig,
    ) -> Vec<Capability> {
        self.extensions
            .iter()
            .map(|e| e.capability(profile, config))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// §IV.A GPU support behind the trait
// ---------------------------------------------------------------------------

/// §IV.A native GPU support as a [`HostExtension`]: triggered by a valid
/// `CUDA_VISIBLE_DEVICES`, gated on the host driver and PTX forward
/// compatibility, injecting device files + driver libraries + binaries.
#[derive(Debug, Clone, Copy, Default)]
pub struct GpuExtension;

impl GpuExtension {
    fn requested(ctx: &ExtensionContext<'_>) -> Option<Vec<u32>> {
        ctx.env()
            .get("CUDA_VISIBLE_DEVICES")
            .and_then(|v| crate::gpu::parse_cuda_visible_devices(v))
    }
}

impl HostExtension for GpuExtension {
    fn name(&self) -> &'static str {
        "gpu"
    }

    fn trigger_description(&self) -> String {
        "CUDA_VISIBLE_DEVICES=<list> in the launch env (WLM GRES export)"
            .to_string()
    }

    fn trigger(&self, ctx: &ExtensionContext<'_>) -> Activation {
        match ctx.env().get("CUDA_VISIBLE_DEVICES") {
            None => Activation::Skipped(
                "CUDA_VISIBLE_DEVICES not set".to_string(),
            ),
            Some(v) => match crate::gpu::parse_cuda_visible_devices(v) {
                Some(devs) => Activation::Triggered(format!(
                    "CUDA_VISIBLE_DEVICES={v} ({} device(s))",
                    devs.len()
                )),
                None => Activation::Skipped(format!(
                    "CUDA_VISIBLE_DEVICES={v} is invalid — support not \
                     triggered"
                )),
            },
        }
    }

    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError> {
        let Some(requested) = Self::requested(ctx) else {
            // not triggered: report the host-side capability only
            return Ok(self.capability(ctx.profile, ctx.config));
        };
        let driver = ctx.profile.driver(ctx.node());
        let driver = gpu_support::check(
            &requested,
            driver.as_ref(),
            &ctx.manifest.labels,
        )
        .map_err(ExtensionError::Gpu)?;
        Ok(Capability {
            extension: self.name(),
            available: true,
            detail: format!(
                "driver {}.{}, {} of {} device(s) requested",
                driver.version.0,
                driver.version.1,
                requested.len(),
                driver.cuda_device_count()
            ),
        })
    }

    fn capability(
        &self,
        profile: &SystemProfile,
        _config: &UdiRootConfig,
    ) -> Capability {
        match profile.driver(0) {
            Some(d) if d.uvm_loaded => Capability {
                extension: self.name(),
                available: true,
                detail: format!(
                    "driver {}.{}, {} CUDA device(s)/node",
                    d.version.0,
                    d.version.1,
                    d.cuda_device_count()
                ),
            },
            _ => Capability {
                extension: self.name(),
                available: false,
                detail: "no loaded NVIDIA driver".to_string(),
            },
        }
    }

    fn inject(
        &self,
        ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        _env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError> {
        let before = mounts.len();
        let requested = Self::requested(ctx).ok_or_else(|| {
            ExtensionError::Incompatible {
                extension: self.name(),
                reason: "inject called without an active trigger"
                    .to_string(),
            }
        })?;
        // the preflight gate already ran; re-validate cheaply so a direct
        // inject call outside the runtime lifecycle cannot index a device
        // the host does not have
        let driver = ctx.profile.driver(ctx.node());
        let driver = gpu_support::check(
            &requested,
            driver.as_ref(),
            &ctx.manifest.labels,
        )
        .map_err(ExtensionError::Gpu)?;
        let report = gpu_support::inject(
            &requested,
            driver,
            ctx.config,
            ctx.host_fs,
            rootfs,
            mounts,
        )
        .map_err(ExtensionError::Gpu)?;
        Ok(ExtensionReport {
            extension: self.name(),
            detail: format!(
                "{} device(s), {} driver libraries, {} binaries",
                report.host_devices.len(),
                report.libraries.len(),
                report.binaries.len()
            ),
            mounts_added: mounts.len() - before,
            env_added: 0,
            payload: ExtensionPayload::Gpu(report),
        })
    }
}

// ---------------------------------------------------------------------------
// §IV.B MPI swap behind the trait
// ---------------------------------------------------------------------------

/// §IV.B MPI ABI-swap support as a [`HostExtension`]: triggered by the
/// `--mpi` flag, gated on the libtool ABI-string comparison, swapping the
/// container's MPI frontends for the host's fabric-capable build.
#[derive(Debug, Clone, Copy, Default)]
pub struct MpiExtension;

impl HostExtension for MpiExtension {
    fn name(&self) -> &'static str {
        "mpi"
    }

    fn trigger_description(&self) -> String {
        "--mpi CLI flag (JobSpec::with_mpi at launch scale)".to_string()
    }

    fn trigger(&self, ctx: &ExtensionContext<'_>) -> Activation {
        if ctx.opts.mpi {
            Activation::Triggered("--mpi flag".to_string())
        } else {
            Activation::Skipped("--mpi not requested".to_string())
        }
    }

    fn check(
        &self,
        ctx: &ExtensionContext<'_>,
    ) -> Result<Capability, ExtensionError> {
        if !ctx.opts.mpi {
            return Ok(self.capability(ctx.profile, ctx.config));
        }
        let container =
            mpi_support::check(&ctx.manifest.labels, &ctx.profile.host_mpi)
                .map_err(ExtensionError::Mpi)?;
        Ok(Capability {
            extension: self.name(),
            available: true,
            detail: format!(
                "{} -> {} (libtool {} -> {})",
                container.version_string(),
                ctx.profile.host_mpi.version_string(),
                container.abi.abi_string(),
                ctx.profile.host_mpi.abi.abi_string()
            ),
        })
    }

    fn capability(
        &self,
        profile: &SystemProfile,
        _config: &UdiRootConfig,
    ) -> Capability {
        let host = &profile.host_mpi;
        if host.mpich_abi_member() {
            Capability {
                extension: self.name(),
                available: true,
                detail: format!(
                    "{} (libtool ABI {})",
                    host.version_string(),
                    host.abi.abi_string()
                ),
            }
        } else {
            Capability {
                extension: self.name(),
                available: false,
                detail: format!(
                    "{} predates the MPICH ABI initiative",
                    host.version_string()
                ),
            }
        }
    }

    fn inject(
        &self,
        ctx: &ExtensionContext<'_>,
        rootfs: &mut VirtualFs,
        mounts: &mut MountTable,
        _env: &mut BTreeMap<String, String>,
    ) -> Result<ExtensionReport, ExtensionError> {
        let before = mounts.len();
        // re-derive the container identity (cheap label parse; the ABI
        // gate already passed in preflight) and run the mutation half
        let container =
            mpi_support::check(&ctx.manifest.labels, &ctx.profile.host_mpi)
                .map_err(ExtensionError::Mpi)?;
        let report = mpi_support::inject(
            &container,
            &ctx.profile.host_mpi,
            ctx.config,
            ctx.host_fs,
            rootfs,
            mounts,
        )
        .map_err(ExtensionError::Mpi)?;
        Ok(ExtensionReport {
            extension: self.name(),
            detail: format!(
                "{} -> {}",
                report.container_mpi, report.host_mpi
            ),
            mounts_added: mounts.len() - before,
            env_added: 0,
            payload: ExtensionPayload::Mpi(report),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::builder;

    fn manifest_of(image: crate::image::Image) -> ImageManifest {
        image.manifest
    }

    #[test]
    fn default_registry_order_is_gpu_mpi_net() {
        let reg = ExtensionRegistry::defaults();
        assert_eq!(reg.names(), ["gpu", "mpi", "net"]);
        assert_eq!(reg.len(), 3);
        assert!(!reg.is_empty());
        assert!(ExtensionRegistry::empty().is_empty());
        // stockness tracks registry provenance exactly
        assert!(reg.is_stock());
        assert!(!ExtensionRegistry::empty().is_stock());
        assert!(!ExtensionRegistry::defaults()
            .with(Box::new(GpuExtension))
            .is_stock());
    }

    #[test]
    fn capability_matrix_matches_the_three_hosts() {
        let reg = ExtensionRegistry::defaults();
        for (profile, expect_net) in [
            (SystemProfile::piz_daint(), true),
            (SystemProfile::linux_cluster(), true),
            (SystemProfile::laptop(), false),
        ] {
            let config = UdiRootConfig::for_profile(&profile);
            let caps = reg.capabilities(&profile, &config);
            assert_eq!(caps.len(), 3, "{}", profile.name);
            assert!(caps[0].available, "{} gpu", profile.name);
            assert!(caps[1].available, "{} mpi", profile.name);
            assert_eq!(caps[2].available, expect_net, "{} net", profile.name);
        }
    }

    #[test]
    fn gpu_trigger_mirrors_cvd_semantics() {
        let profile = SystemProfile::piz_daint();
        let config = UdiRootConfig::for_profile(&profile);
        let host_fs = profile.host_fs();
        let manifest = manifest_of(builder::ubuntu_xenial());
        let mut opts = RunOptions::new("ubuntu:xenial", &["true"]);
        let ext = GpuExtension;

        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &profile,
            config: &config,
            host_fs: &host_fs,
        };
        assert!(!ext.trigger(&ctx).is_triggered());

        opts = opts.with_env("CUDA_VISIBLE_DEVICES", "NoDevFiles");
        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &profile,
            config: &config,
            host_fs: &host_fs,
        };
        assert!(!ext.trigger(&ctx).is_triggered());

        opts = opts.with_env("CUDA_VISIBLE_DEVICES", "0");
        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &profile,
            config: &config,
            host_fs: &host_fs,
        };
        assert!(ext.trigger(&ctx).is_triggered());
        assert!(ext.check(&ctx).unwrap().available);
    }

    #[test]
    fn mpi_check_fails_preflight_on_unlabeled_image() {
        let profile = SystemProfile::piz_daint();
        let config = UdiRootConfig::for_profile(&profile);
        let host_fs = profile.host_fs();
        let manifest = manifest_of(builder::ubuntu_xenial());
        let opts = RunOptions::new("ubuntu:xenial", &["true"]).with_mpi();
        let ctx = ExtensionContext {
            opts: &opts,
            manifest: &manifest,
            profile: &profile,
            config: &config,
            host_fs: &host_fs,
        };
        let ext = MpiExtension;
        assert!(ext.trigger(&ctx).is_triggered());
        assert_eq!(
            ext.check(&ctx).unwrap_err(),
            ExtensionError::Mpi(MpiSupportError::NoMpiInImage)
        );
    }
}
