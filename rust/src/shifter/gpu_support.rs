//! Native GPU support (§IV.A) — the first half of the paper's contribution.
//!
//! Activation trigger: `CUDA_VISIBLE_DEVICES` present in the environment
//! with a valid value. When triggered, four operations run:
//!   1. verify CUDA_VISIBLE_DEVICES is present and valid;
//!   2. add the GPU device files to the container;
//!   3. bind mount the CUDA driver libraries (cuda, nvidia-compiler,
//!      nvidia-ptxjitcompiler, nvidia-encode, nvidia-ml,
//!      nvidia-fatbinaryloader, nvidia-opencl);
//!   4. bind mount NVIDIA binaries (nvidia-smi).
//!
//! Plus the §IV.A.3 renumbering guarantee: exposed devices are addressable
//! from 0 inside the container regardless of their host ids.

use std::collections::BTreeMap;

use crate::config::UdiRootConfig;
use crate::gpu::{parse_cuda_visible_devices, NvidiaDriver, DRIVER_BINARIES, DRIVER_LIBRARIES};
use crate::image::builder::LABEL_CUDA_VERSION;
use crate::vfs::{MountTable, VNode, VirtualFs};

/// Where driver libraries land inside the container (prepended to the
/// container's library search path via ld.so.conf injection).
pub const CONTAINER_GPU_LIB_DIR: &str = "/usr/lib64/shifter-gpu";
/// Where NVIDIA binaries (nvidia-smi) land inside the container.
pub const CONTAINER_GPU_BIN_DIR: &str = "/usr/bin";

/// Failures of the §IV.A GPU support procedure (the trigger variable was
/// present and valid, but activation could not complete).
#[derive(Debug, thiserror::Error, PartialEq)]
#[non_exhaustive]
pub enum GpuSupportError {
    /// The host has no loaded nvidia-uvm kernel driver.
    #[error("nvidia-uvm driver is not loaded on the host")]
    DriverNotLoaded,
    /// CUDA_VISIBLE_DEVICES named a device id the host does not have.
    #[error("CUDA_VISIBLE_DEVICES requests device {0} but host has {1} devices")]
    DeviceOutOfRange(u32, u32),
    /// The container's CUDA toolkit is newer than the host driver
    /// supports (§II-B2 PTX forward-compatibility).
    #[error(
        "container was built for CUDA {wanted_major}.{wanted_minor} but host \
         driver {driver_major}.{driver_minor} is too old"
    )]
    CudaIncompatible {
        /// CUDA major version the image was built for.
        wanted_major: u32,
        /// CUDA minor version the image was built for.
        wanted_minor: u32,
        /// Host driver major version.
        driver_major: u32,
        /// Host driver minor version.
        driver_minor: u32,
    },
    /// A driver library or binary named by the config is absent on the
    /// host filesystem.
    #[error("host driver library missing: {0}")]
    MissingHostLibrary(String),
    /// Grafting a host node into the container rootfs failed (path
    /// conflict inside the image tree).
    #[error("container rootfs graft failed: {0}")]
    Rootfs(#[from] crate::vfs::VfsError),
}

/// What GPU support did to the container.
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSupportReport {
    /// Host CUDA device ids made visible (CUDA_VISIBLE_DEVICES order).
    pub host_devices: Vec<u32>,
    /// Container-side ids: always 0..n (§IV.A.3).
    pub container_devices: Vec<u32>,
    /// Driver libraries bind-mounted in.
    pub libraries: Vec<String>,
    /// Binaries bind-mounted in.
    pub binaries: Vec<String>,
    /// Device files added.
    pub device_files: Vec<String>,
}

/// The §IV.A compatibility gate, separated from the mutation so the
/// `HostExtension` lifecycle can refuse a run in preflight, before any
/// mount happens: nvidia-uvm must be loaded, every requested device must
/// exist, and the container's CUDA toolkit must be within the host
/// driver's PTX forward-compatibility window. Returns the validated
/// driver.
pub fn check<'d>(
    requested: &[u32],
    driver: Option<&'d NvidiaDriver>,
    image_labels: &BTreeMap<String, String>,
) -> Result<&'d NvidiaDriver, GpuSupportError> {
    // prerequisites (§IV.A.1): CUDA-capable host with nvidia-uvm loaded
    let driver = match driver {
        Some(d) if d.uvm_loaded => d,
        _ => return Err(GpuSupportError::DriverNotLoaded),
    };
    let have = driver.cuda_device_count();
    for &d in requested {
        if d >= have {
            return Err(GpuSupportError::DeviceOutOfRange(d, have));
        }
    }

    // PTX forward-compatibility: a container built against a newer CUDA
    // toolkit than the host driver supports cannot run (§II-B2).
    if let Some(cuda) = image_labels.get(LABEL_CUDA_VERSION) {
        let mut it = cuda.split('.').map(|p| p.parse::<u32>().unwrap_or(0));
        let wanted = (it.next().unwrap_or(0), it.next().unwrap_or(0));
        if !driver.supports_cuda(wanted) {
            return Err(GpuSupportError::CudaIncompatible {
                wanted_major: wanted.0,
                wanted_minor: wanted.1,
                driver_major: driver.version.0,
                driver_minor: driver.version.1,
            });
        }
    }
    Ok(driver)
}

/// Attempt GPU support activation during environment preparation:
/// trigger validation, the [`check`] gate, then the [`inject`] mutation.
///
/// Returns Ok(None) when the trigger condition is absent or invalid —
/// §IV.A: "If, for any reason, the workload manager does not set
/// CUDA_VISIBLE_DEVICES or assigns it an invalid value, Shifter does not
/// trigger its GPU support procedure."
pub fn activate(
    env: &BTreeMap<String, String>,
    driver: Option<&NvidiaDriver>,
    config: &UdiRootConfig,
    host_fs: &VirtualFs,
    image_labels: &BTreeMap<String, String>,
    rootfs: &mut VirtualFs,
    mounts: &mut MountTable,
) -> Result<Option<GpuSupportReport>, GpuSupportError> {
    // 1. verify the trigger variable
    let value = match env.get("CUDA_VISIBLE_DEVICES") {
        Some(v) => v,
        None => return Ok(None),
    };
    let requested = match parse_cuda_visible_devices(value) {
        Some(r) => r,
        None => return Ok(None), // invalid value -> not triggered
    };

    let driver = check(&requested, driver, image_labels)?;
    inject(&requested, driver, config, host_fs, rootfs, mounts).map(Some)
}

/// The §IV.A mutation: add device files, bind mount the driver
/// libraries and NVIDIA binaries. `requested` and `driver` must already
/// have passed [`check`].
pub fn inject(
    requested: &[u32],
    driver: &NvidiaDriver,
    config: &UdiRootConfig,
    host_fs: &VirtualFs,
    rootfs: &mut VirtualFs,
    mounts: &mut MountTable,
) -> Result<GpuSupportReport, GpuSupportError> {
    // 2. add GPU device files
    let device_files = driver.device_files(requested);
    for f in &device_files {
        let node = host_fs
            .get(f)
            .cloned()
            .unwrap_or(VNode::Device { major: 195, minor: 0 });
        rootfs.insert(f, node)?;
        mounts.bind(f, f, false, "gpu support");
    }

    // 3. bind mount the driver libraries
    let mut libraries = Vec::new();
    for (stem, versioned) in
        DRIVER_LIBRARIES.iter().zip(driver.library_files())
    {
        let host_path = format!("{}/{versioned}", config.gpu_lib_dir);
        let node = host_fs
            .get(&host_path)
            .cloned()
            .ok_or_else(|| GpuSupportError::MissingHostLibrary(host_path.clone()))?;
        let target = format!("{CONTAINER_GPU_LIB_DIR}/{versioned}");
        rootfs.insert(&target, node)?;
        // plus the unversioned dev symlink CUDA apps dlopen
        rootfs.insert(
            &format!("{CONTAINER_GPU_LIB_DIR}/{stem}"),
            VNode::Symlink {
                target: target.clone(),
            },
        )?;
        mounts.bind(&host_path, &target, true, "gpu support");
        libraries.push(versioned);
    }

    // 4. bind mount NVIDIA binaries
    let mut binaries = Vec::new();
    for bin in DRIVER_BINARIES {
        let host_path = format!("{}/{bin}", config.gpu_bin_dir);
        let node = host_fs
            .get(&host_path)
            .cloned()
            .ok_or_else(|| GpuSupportError::MissingHostLibrary(host_path.clone()))?;
        let target = format!("{CONTAINER_GPU_BIN_DIR}/{bin}");
        rootfs.insert(&target, node)?;
        mounts.bind(&host_path, &target, true, "gpu support");
        binaries.push(bin.to_string());
    }

    let n = requested.len() as u32;
    Ok(GpuSupportReport {
        host_devices: requested.to_vec(),
        container_devices: (0..n).collect(),
        libraries,
        binaries,
        device_files,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::UdiRootConfig;
    use crate::hostenv::SystemProfile;

    fn setup(
        cvd: Option<&str>,
    ) -> (
        BTreeMap<String, String>,
        NvidiaDriver,
        UdiRootConfig,
        VirtualFs,
        BTreeMap<String, String>,
    ) {
        let profile = SystemProfile::linux_cluster();
        let mut env = BTreeMap::new();
        if let Some(v) = cvd {
            env.insert("CUDA_VISIBLE_DEVICES".to_string(), v.to_string());
        }
        let driver = profile.driver(0).unwrap();
        let config = UdiRootConfig::for_profile(&profile);
        let host_fs = profile.host_fs();
        let labels = BTreeMap::new();
        (env, driver, config, host_fs, labels)
    }

    #[test]
    fn paper_example_exposes_devices_0_and_2() {
        let (env, driver, config, host_fs, labels) = setup(Some("0,2"));
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let rep = activate(
            &env, Some(&driver), &config, &host_fs, &labels, &mut rootfs,
            &mut mounts,
        )
        .unwrap()
        .unwrap();
        assert_eq!(rep.host_devices, vec![0, 2]);
        // §IV.A.3: container numbering starts at 0
        assert_eq!(rep.container_devices, vec![0, 1]);
        assert!(rootfs.exists("/dev/nvidia0"));
        assert!(rootfs.exists("/dev/nvidia2"));
        assert!(rootfs.exists("/dev/nvidiactl"));
        assert!(rootfs.exists("/dev/nvidia-uvm"));
        assert_eq!(rep.libraries.len(), DRIVER_LIBRARIES.len());
        assert!(rootfs
            .exists(&format!("{CONTAINER_GPU_LIB_DIR}/libcuda.so.367.48")));
        assert!(rootfs.exists("/usr/bin/nvidia-smi"));
        assert_eq!(mounts.by_origin("gpu support").len(), 4 + 7 + 1);
    }

    #[test]
    fn absent_or_invalid_cvd_does_not_trigger() {
        for cvd in [None, Some(""), Some("NoDevFiles"), Some("-1")] {
            let (env, driver, config, host_fs, labels) = setup(cvd);
            let mut rootfs = VirtualFs::new();
            let mut mounts = MountTable::new();
            let r = activate(
                &env, Some(&driver), &config, &host_fs, &labels, &mut rootfs,
                &mut mounts,
            )
            .unwrap();
            assert!(r.is_none(), "cvd={cvd:?}");
            assert_eq!(mounts.len(), 0);
        }
    }

    #[test]
    fn out_of_range_device_errors() {
        let (env, driver, config, host_fs, labels) = setup(Some("0,7"));
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let err = activate(
            &env, Some(&driver), &config, &host_fs, &labels, &mut rootfs,
            &mut mounts,
        )
        .unwrap_err();
        assert_eq!(err, GpuSupportError::DeviceOutOfRange(7, 3));
    }

    #[test]
    fn missing_driver_errors() {
        let (env, _driver, config, host_fs, labels) = setup(Some("0"));
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let err = activate(
            &env, None, &config, &host_fs, &labels, &mut rootfs, &mut mounts,
        )
        .unwrap_err();
        assert_eq!(err, GpuSupportError::DriverNotLoaded);
    }

    #[test]
    fn unloaded_uvm_errors() {
        let (env, mut driver, config, host_fs, labels) = setup(Some("0"));
        driver.uvm_loaded = false;
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let err = activate(
            &env, Some(&driver), &config, &host_fs, &labels, &mut rootfs,
            &mut mounts,
        )
        .unwrap_err();
        assert_eq!(err, GpuSupportError::DriverNotLoaded);
    }

    #[test]
    fn too_new_cuda_container_rejected() {
        let (env, _d, config, host_fs, mut labels) = setup(Some("0"));
        // an old 340 driver cannot run a CUDA 8 container
        let old = NvidiaDriver::new(
            (340, 29),
            vec![crate::gpu::GpuModel::tesla_k40m()],
        );
        labels.insert(LABEL_CUDA_VERSION.to_string(), "8.0".to_string());
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let err = activate(
            &env, Some(&old), &config, &host_fs, &labels, &mut rootfs,
            &mut mounts,
        )
        .unwrap_err();
        assert!(matches!(err, GpuSupportError::CudaIncompatible { .. }));
    }

    #[test]
    fn missing_host_library_reported() {
        let (env, driver, config, mut host_fs, labels) = setup(Some("0"));
        // simulate a broken install: remove one driver library
        host_fs
            .remove(&format!("{}/libcuda.so.367.48", config.gpu_lib_dir))
            .unwrap();
        let mut rootfs = VirtualFs::new();
        let mut mounts = MountTable::new();
        let err = activate(
            &env, Some(&driver), &config, &host_fs, &labels, &mut rootfs,
            &mut mounts,
        )
        .unwrap_err();
        assert!(matches!(err, GpuSupportError::MissingHostLibrary(_)));
    }
}
