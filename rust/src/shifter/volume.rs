//! User-requested volume mounts (`shifter --volume=/host:/container[:ro]`).
//!
//! Shifter lets users bind additional host directories into their
//! containers, subject to site policy: the host path must exist, and the
//! container target must not shadow system-critical paths (the runtime's
//! own mounts, /etc, /dev, …) — a containment rule the real runtime
//! enforces to keep the setuid stage safe.

use crate::vfs::{normalize, VirtualFs};

/// One parsed `--volume=/host:/container[:ro]` user mount request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VolumeSpec {
    /// Host directory to bind into the container.
    pub host_path: String,
    /// Mount target inside the container.
    pub container_path: String,
    /// Whether the bind is read-only (`:ro`).
    pub read_only: bool,
}

/// User-volume parse and site-policy failures.
#[derive(Debug, thiserror::Error, PartialEq)]
#[non_exhaustive]
pub enum VolumeError {
    /// The spec did not match `/host:/container[:ro|:rw]`.
    #[error("malformed volume spec '{0}' (expected /host:/container[:ro])")]
    Malformed(String),
    /// The named host directory does not exist.
    #[error("volume host path does not exist: {0}")]
    HostPathMissing(String),
    /// The target would shadow a system-critical container path.
    #[error("volume target {0} is reserved and cannot be mounted over")]
    ReservedTarget(String),
    /// A path was relative or not normalizable.
    #[error("volume path is not absolute or not normalized: {0}")]
    BadPath(String),
}

/// Container paths a user volume may never shadow.
pub const RESERVED_TARGETS: [&str; 8] = [
    "/", "/etc", "/dev", "/proc", "/sys", "/bin", "/sbin", "/usr",
];

impl VolumeSpec {
    /// Parse `"/host:/container"` or `"/host:/container:ro"`.
    pub fn parse(s: &str) -> Result<VolumeSpec, VolumeError> {
        let parts: Vec<&str> = s.split(':').collect();
        let (host, container, ro) = match parts.as_slice() {
            [h, c] => (*h, *c, false),
            [h, c, "ro"] => (*h, *c, true),
            [h, c, "rw"] => (*h, *c, false),
            _ => return Err(VolumeError::Malformed(s.to_string())),
        };
        let host_path = normalize(host)
            .map_err(|_| VolumeError::BadPath(host.to_string()))?;
        let container_path = normalize(container)
            .map_err(|_| VolumeError::BadPath(container.to_string()))?;
        Ok(VolumeSpec {
            host_path,
            container_path,
            read_only: ro,
        })
    }

    /// Site-policy validation against the host filesystem.
    pub fn validate(&self, host_fs: &VirtualFs) -> Result<(), VolumeError> {
        if !host_fs.exists(&self.host_path) {
            return Err(VolumeError::HostPathMissing(self.host_path.clone()));
        }
        for reserved in RESERVED_TARGETS {
            if self.container_path == reserved {
                return Err(VolumeError::ReservedTarget(
                    self.container_path.clone(),
                ));
            }
        }
        Ok(())
    }
}

/// Writable scratch directories every container gets (the squashfs image
/// is read-only; these are tmpfs-backed).
pub const TMPFS_DIRS: [&str; 2] = ["/tmp", "/run"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_forms() {
        let v = VolumeSpec::parse("/scratch/data:/data").unwrap();
        assert_eq!(v.host_path, "/scratch/data");
        assert_eq!(v.container_path, "/data");
        assert!(!v.read_only);
        assert!(VolumeSpec::parse("/a:/b:ro").unwrap().read_only);
        assert!(!VolumeSpec::parse("/a:/b:rw").unwrap().read_only);
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(matches!(
            VolumeSpec::parse("justapath"),
            Err(VolumeError::Malformed(_))
        ));
        assert!(matches!(
            VolumeSpec::parse("/a:/b:ro:extra"),
            Err(VolumeError::Malformed(_))
        ));
        assert!(matches!(
            VolumeSpec::parse("rel:/b"),
            Err(VolumeError::BadPath(_))
        ));
        assert!(matches!(
            VolumeSpec::parse("/a:../b"),
            Err(VolumeError::BadPath(_))
        ));
    }

    #[test]
    fn normalizes_paths() {
        let v = VolumeSpec::parse("/scratch//data/:/data/./sub").unwrap();
        assert_eq!(v.host_path, "/scratch/data");
        assert_eq!(v.container_path, "/data/sub");
    }

    #[test]
    fn validation_checks_host_and_reserved() {
        let mut host = VirtualFs::new();
        host.mkdir_p("/scratch/data").unwrap();
        let ok = VolumeSpec::parse("/scratch/data:/data").unwrap();
        assert!(ok.validate(&host).is_ok());

        let missing = VolumeSpec::parse("/nope:/data").unwrap();
        assert_eq!(
            missing.validate(&host),
            Err(VolumeError::HostPathMissing("/nope".into()))
        );

        for target in ["/etc", "/dev", "/usr", "/"] {
            let bad =
                VolumeSpec::parse(&format!("/scratch/data:{target}")).unwrap();
            assert!(
                matches!(bad.validate(&host), Err(VolumeError::ReservedTarget(_))),
                "{target}"
            );
        }
        // subdirectories of reserved paths are fine
        let mut h2 = VirtualFs::new();
        h2.mkdir_p("/opt/tools").unwrap();
        let sub = VolumeSpec::parse("/opt/tools:/usr/local/tools").unwrap();
        assert!(sub.validate(&h2).is_ok());
    }
}
