//! The Shifter Runtime execution stages (§III.A) and the privilege model.
//!
//! "The execution of a container on a host system through Shifter can be
//! broken down into several stages": pulling/reformatting (Image Gateway),
//! then — Runtime-side — preparation of the software environment, chroot
//! jail, change to user/group privileges, export of environment variables,
//! container application execution, cleanup. The stage machine records an
//! auditable log with simulated cost per stage; the privilege state machine
//! enforces that everything after the chroot runs without elevated ids.

use std::fmt;

use super::extension::ExtensionReport;

/// One of the §III.A execution stages, in pipeline order.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Stage {
    /// Runtime entry: resolve image on the gateway.
    ResolveImage,
    /// Copy squashfs to the node, loop mount, graft site resources,
    /// GPU/MPI support injection.
    PrepareEnvironment,
    /// Change the container's root to the prepared directory.
    ChrootJail,
    /// setegid()/seteuid() back to the invoking user.
    DropPrivileges,
    /// Image env + selected host env into the container environment.
    ExportEnvironment,
    /// Run the application as the end user.
    Execute,
    /// Release environment resources.
    Cleanup,
}

impl Stage {
    /// The §III.A order.
    pub const ORDER: [Stage; 7] = [
        Stage::ResolveImage,
        Stage::PrepareEnvironment,
        Stage::ChrootJail,
        Stage::DropPrivileges,
        Stage::ExportEnvironment,
        Stage::Execute,
        Stage::Cleanup,
    ];

    /// Stable kebab-case stage name for logs and reports.
    pub fn name(&self) -> &'static str {
        match self {
            Stage::ResolveImage => "resolve-image",
            Stage::PrepareEnvironment => "prepare-environment",
            Stage::ChrootJail => "chroot-jail",
            Stage::DropPrivileges => "drop-privileges",
            Stage::ExportEnvironment => "export-environment",
            Stage::Execute => "execute",
            Stage::Cleanup => "cleanup",
        }
    }

    /// Stages that require elevated privileges (§III.A: "Shifter has
    /// completed the steps that require additional system privileges,
    /// namely the setup of the container environment and the change of
    /// its root directory").
    pub fn needs_privileges(&self) -> bool {
        matches!(
            self,
            Stage::ResolveImage
                | Stage::PrepareEnvironment
                | Stage::ChrootJail
                | Stage::DropPrivileges // performs the drop itself
        )
    }
}

impl fmt::Display for Stage {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Effective/real uid-gid state. The shifter binary is setuid-root: it
/// starts with euid 0 and must drop to the invoking user before Execute.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PrivilegeState {
    /// Real uid of the invoking user.
    pub real_uid: u32,
    /// Real gid of the invoking user.
    pub real_gid: u32,
    /// Effective uid (0 until the DropPrivileges stage).
    pub effective_uid: u32,
    /// Effective gid (0 until the DropPrivileges stage).
    pub effective_gid: u32,
}

impl PrivilegeState {
    /// Launch state of the setuid binary invoked by `uid:gid`.
    pub fn setuid_start(uid: u32, gid: u32) -> PrivilegeState {
        PrivilegeState {
            real_uid: uid,
            real_gid: gid,
            effective_uid: 0,
            effective_gid: 0,
        }
    }

    /// Whether the process still runs with the setuid-root euid while
    /// invoked by a non-root user.
    pub fn is_elevated(&self) -> bool {
        self.effective_uid == 0 && self.real_uid != 0
    }

    /// `setegid(rgid); seteuid(ruid)` — §III.A's order (gid first: once
    /// euid drops, setegid would no longer be permitted).
    pub fn drop_privileges(&mut self) {
        self.effective_gid = self.real_gid;
        self.effective_uid = self.real_uid;
    }
}

/// One executed stage with its audit detail and simulated wall-clock cost.
#[derive(Debug, Clone)]
pub struct StageRecord {
    /// Which stage ran.
    pub stage: Stage,
    /// Audit detail (what the stage actually did).
    pub detail: String,
    /// Simulated wall-clock cost of the stage in seconds.
    pub sim_secs: f64,
}

/// Ordered log of executed stages, plus the host-extension reports of
/// the PrepareEnvironment stage (so a stage audit names exactly which
/// injections ran and what they mounted).
#[derive(Debug, Clone, Default)]
pub struct StageLog {
    records: Vec<StageRecord>,
    extensions: Vec<ExtensionReport>,
}

/// Violations of the §III.A stage order or the privilege discipline.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum StageError {
    /// A stage ran outside the §III.A pipeline order.
    #[error("stage {got} executed out of order (expected {expected})")]
    OutOfOrder {
        /// The stage that was attempted.
        got: Stage,
        /// The stage the pipeline order expected next.
        expected: Stage,
    },
    /// A root-only stage ran after privileges were already dropped.
    #[error("stage {0} requires privileges but effective uid is {1}")]
    NotPrivileged(Stage, u32),
    /// A user stage ran while the effective uid was still 0.
    #[error("stage {0} must not run with elevated privileges")]
    StillPrivileged(Stage),
}

impl StageLog {
    /// An empty stage log.
    pub fn new() -> StageLog {
        StageLog::default()
    }

    /// Record a completed stage, enforcing the §III.A order and the
    /// privilege discipline.
    pub fn record(
        &mut self,
        stage: Stage,
        priv_state: &PrivilegeState,
        detail: impl Into<String>,
        sim_secs: f64,
    ) -> Result<(), StageError> {
        let expected = Stage::ORDER[self.records.len().min(Stage::ORDER.len() - 1)];
        if stage != expected {
            return Err(StageError::OutOfOrder {
                got: stage,
                expected,
            });
        }
        // privilege discipline: root-only stages need euid 0; user stages
        // must NOT have euid 0 (for non-root invokers)
        if stage.needs_privileges() && priv_state.effective_uid != 0 {
            return Err(StageError::NotPrivileged(
                stage,
                priv_state.effective_uid,
            ));
        }
        if !stage.needs_privileges() && priv_state.is_elevated() {
            return Err(StageError::StillPrivileged(stage));
        }
        self.records.push(StageRecord {
            stage,
            detail: detail.into(),
            sim_secs,
        });
        Ok(())
    }

    /// The executed stages, in order.
    pub fn records(&self) -> &[StageRecord] {
        &self.records
    }

    /// Attach the host-extension reports of the PrepareEnvironment stage
    /// (called once by the runtime after injection).
    pub fn attach_extensions(&mut self, reports: &[ExtensionReport]) {
        self.extensions = reports.to_vec();
    }

    /// The host extensions that injected into this container, in
    /// registry order (empty when none triggered).
    pub fn extensions(&self) -> &[ExtensionReport] {
        &self.extensions
    }

    /// Total simulated cost across all recorded stages.
    pub fn total_sim_secs(&self) -> f64 {
        self.records.iter().map(|r| r.sim_secs).sum()
    }

    /// Whether every §III.A stage ran (the container reached Cleanup).
    pub fn completed(&self) -> bool {
        self.records.len() == Stage::ORDER.len()
    }

    /// Human-readable audit table (`shifter --verbose`).
    pub fn render(&self) -> String {
        let mut s = String::new();
        for r in &self.records {
            s.push_str(&format!(
                "[{:>20}] {:<40} {:.3} ms\n",
                r.stage.name(),
                r.detail,
                r.sim_secs * 1e3
            ));
        }
        for e in &self.extensions {
            let tag = format!("ext:{}", e.extension);
            s.push_str(&format!(
                "[{tag:>20}] {:<40} +{} mounts, +{} env\n",
                e.detail, e.mounts_added, e.env_added,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn run_all() -> (StageLog, PrivilegeState) {
        let mut log = StageLog::new();
        let mut ps = PrivilegeState::setuid_start(1000, 100);
        for stage in Stage::ORDER {
            if stage == Stage::DropPrivileges {
                // the drop happens within its stage
                log.record(stage, &ps, "setegid+seteuid", 0.0).unwrap();
                ps.drop_privileges();
            } else {
                log.record(stage, &ps, stage.name(), 0.001).unwrap();
            }
        }
        (log, ps)
    }

    #[test]
    fn full_pipeline_in_order() {
        let (log, ps) = run_all();
        assert!(log.completed());
        assert_eq!(ps.effective_uid, 1000);
        assert_eq!(ps.effective_gid, 100);
        assert!((log.total_sim_secs() - 0.006).abs() < 1e-12);
    }

    #[test]
    fn out_of_order_rejected() {
        let mut log = StageLog::new();
        let ps = PrivilegeState::setuid_start(1000, 100);
        let err = log.record(Stage::Execute, &ps, "", 0.0).unwrap_err();
        assert!(matches!(err, StageError::OutOfOrder { .. }));
    }

    #[test]
    fn execute_with_elevated_privileges_rejected() {
        let mut log = StageLog::new();
        let mut ps = PrivilegeState::setuid_start(1000, 100);
        for stage in [
            Stage::ResolveImage,
            Stage::PrepareEnvironment,
            Stage::ChrootJail,
        ] {
            log.record(stage, &ps, "", 0.0).unwrap();
        }
        log.record(Stage::DropPrivileges, &ps, "", 0.0).unwrap();
        // "forget" to actually drop -> ExportEnvironment must fail
        let err = log
            .record(Stage::ExportEnvironment, &ps, "", 0.0)
            .unwrap_err();
        assert!(matches!(err, StageError::StillPrivileged(_)));
        // now drop and it proceeds
        ps.drop_privileges();
        log.record(Stage::ExportEnvironment, &ps, "", 0.0).unwrap();
    }

    #[test]
    fn prepare_without_privileges_rejected() {
        let mut log = StageLog::new();
        let mut ps = PrivilegeState::setuid_start(1000, 100);
        log.record(Stage::ResolveImage, &ps, "", 0.0).unwrap();
        ps.drop_privileges(); // dropped too early
        let err = log
            .record(Stage::PrepareEnvironment, &ps, "", 0.0)
            .unwrap_err();
        assert!(matches!(err, StageError::NotPrivileged(..)));
    }

    #[test]
    fn root_invoker_is_never_elevated() {
        let ps = PrivilegeState::setuid_start(0, 0);
        assert!(!ps.is_elevated());
    }

    #[test]
    fn gid_dropped_before_uid() {
        // after drop, both match the real ids (setegid-then-seteuid works)
        let mut ps = PrivilegeState::setuid_start(500, 500);
        ps.drop_privileges();
        assert_eq!(ps.effective_uid, 500);
        assert_eq!(ps.effective_gid, 500);
    }
}
