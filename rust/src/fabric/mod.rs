//! Interconnect fabric models (DESIGN.md S8).
//!
//! We have no InfiniBand EDR or Cray Aries hardware, so each fabric is a
//! latency model with two paths:
//!
//!  * **native**: the vendor MPI driving the hardware directly. Calibrated
//!    point-for-point to the paper's *native* columns of Tables III/IV
//!    (one-way osu_latency, best of 30), log-log interpolated between the
//!    measured sizes.
//!  * **tcp fallback**: what a container's stock MPI falls back to when
//!    Shifter's MPI support is *disabled* and the vendor transport is
//!    invisible — TCP over IPoIB on the cluster, TCP over the Aries IP
//!    stack on Daint. Calibrated from the paper's disabled-ratio columns.
//!
//! An analytic eager/rendezvous model (`AnalyticLink`) backs the A4
//! ablation, showing where the protocol crossover falls.

/// Interconnect technology of a system (§V.A).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FabricKind {
    /// Linux Cluster: EDR InfiniBand.
    InfinibandEdr,
    /// Piz Daint: Cray Aries, Dragonfly topology.
    CrayAries,
    /// Laptop: no fabric; shared-memory/loopback only.
    Loopback,
}

impl FabricKind {
    pub fn name(&self) -> &'static str {
        match self {
            FabricKind::InfinibandEdr => "InfiniBand EDR",
            FabricKind::CrayAries => "Cray Aries",
            FabricKind::Loopback => "loopback",
        }
    }
}

/// Table-calibrated link: (message bytes, one-way latency µs) points with
/// log-log interpolation, linear-in-size extrapolation past the last point.
#[derive(Debug, Clone)]
pub struct LinkModel {
    pub points: Vec<(u64, f64)>,
}

impl LinkModel {
    pub fn new(points: &[(u64, f64)]) -> LinkModel {
        assert!(points.len() >= 2);
        assert!(points.windows(2).all(|w| w[0].0 < w[1].0), "sizes ascending");
        LinkModel {
            points: points.to_vec(),
        }
    }

    /// One-way latency in µs for a `size`-byte message.
    pub fn latency_us(&self, size: u64) -> f64 {
        let pts = &self.points;
        if size <= pts[0].0 {
            return pts[0].1;
        }
        if size >= pts[pts.len() - 1].0 {
            // extrapolate with the bandwidth implied by the last segment
            let (s0, l0) = pts[pts.len() - 2];
            let (s1, l1) = pts[pts.len() - 1];
            let per_byte = (l1 - l0) / (s1 - s0) as f64;
            return l1 + per_byte * (size - s1) as f64;
        }
        let i = pts.partition_point(|(s, _)| *s <= size) - 1;
        let (s0, l0) = pts[i];
        let (s1, l1) = pts[i + 1];
        // log-log interpolation
        let t = ((size as f64).ln() - (s0 as f64).ln())
            / ((s1 as f64).ln() - (s0 as f64).ln());
        (l0.ln() + t * (l1.ln() - l0.ln())).exp()
    }

    /// Effective bandwidth at a message size (GB/s).
    pub fn bandwidth_gbps(&self, size: u64) -> f64 {
        size as f64 / (self.latency_us(size) * 1e-6) / 1e9
    }
}

/// The OSU message sizes Tables III/IV report.
pub const OSU_SIZES: [u64; 9] = [
    32,
    128,
    512,
    2 * 1024,
    8 * 1024,
    32 * 1024,
    128 * 1024,
    512 * 1024,
    2 * 1024 * 1024,
];

/// Native path, Linux Cluster (Table III "Nat" column).
pub fn ib_edr_native() -> LinkModel {
    LinkModel::new(&[
        (32, 1.2),
        (128, 1.3),
        (512, 1.8),
        (2048, 2.4),
        (8192, 4.5),
        (32768, 12.1),
        (131072, 56.8),
        (524288, 141.5),
        (2097152, 480.8),
    ])
}

/// TCP-over-IPoIB fallback, Linux Cluster (Table III disabled × native).
pub fn ib_edr_tcp() -> LinkModel {
    LinkModel::new(&[
        (32, 24.5),
        (128, 24.4),
        (512, 27.0),
        (2048, 71.3),
        (8192, 217.4),
        (32768, 417.5),
        (131072, 1482.0),
        (524288, 4712.0),
        (2097152, 18222.0),
    ])
}

/// Native path, Piz Daint (Table IV "Native" column).
pub fn aries_native() -> LinkModel {
    LinkModel::new(&[
        (32, 1.1),
        (128, 1.1),
        (512, 1.1),
        (2048, 1.6),
        (8192, 4.1),
        (32768, 6.5),
        (131072, 16.4),
        (524288, 56.1),
        (2097152, 215.7),
    ])
}

/// TCP-over-Aries fallback, Piz Daint (Table IV disabled × native).
pub fn aries_tcp() -> LinkModel {
    LinkModel::new(&[
        (32, 4.79),
        (128, 4.80),
        (512, 4.92),
        (2048, 7.46),
        (8192, 8.90),
        (32768, 13.65),
        (131072, 43.1),
        (524288, 125.1),
        (2097152, 435.7),
    ])
}

/// Laptop loopback (shared memory) — MPICH ch3:nemesis on one node.
pub fn loopback() -> LinkModel {
    LinkModel::new(&[
        (32, 0.45),
        (2048, 0.9),
        (32768, 4.2),
        (2097152, 300.0),
    ])
}

/// The two software paths over a physical fabric.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Transport {
    /// Vendor MPI with direct hardware access.
    Native,
    /// Portable MPI falling back to the IP stack.
    TcpFallback,
}

/// Link model for (fabric, transport).
pub fn link_for(kind: FabricKind, transport: Transport) -> LinkModel {
    match (kind, transport) {
        (FabricKind::InfinibandEdr, Transport::Native) => ib_edr_native(),
        (FabricKind::InfinibandEdr, Transport::TcpFallback) => ib_edr_tcp(),
        (FabricKind::CrayAries, Transport::Native) => aries_native(),
        (FabricKind::CrayAries, Transport::TcpFallback) => aries_tcp(),
        (FabricKind::Loopback, _) => loopback(),
    }
}

/// Analytic eager/rendezvous model for the A4 ablation: exposes where the
/// protocol switch falls rather than interpolating measurements.
#[derive(Debug, Clone)]
pub struct AnalyticLink {
    pub base_latency_us: f64,
    pub bandwidth_gbps: f64,
    pub eager_threshold: u64,
    pub rendezvous_overhead_us: f64,
}

impl AnalyticLink {
    pub fn latency_us(&self, size: u64) -> f64 {
        let wire = size as f64 / (self.bandwidth_gbps * 1e3); // µs
        let rndv = if size > self.eager_threshold {
            self.rendezvous_overhead_us
        } else {
            0.0
        };
        self.base_latency_us + wire + rndv
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn native_tables_reproduce_calibration_points() {
        let ib = ib_edr_native();
        assert!((ib.latency_us(32) - 1.2).abs() < 1e-9);
        assert!((ib.latency_us(2097152) - 480.8).abs() < 1e-9);
        let ar = aries_native();
        assert!((ar.latency_us(8192) - 4.1).abs() < 1e-9);
    }

    #[test]
    fn interpolation_is_monotone_between_points() {
        let ib = ib_edr_native();
        let mid = ib.latency_us(64);
        assert!(mid > 1.2 && mid < 1.3, "mid={mid}");
        let mid2 = ib.latency_us(1024 * 1024);
        assert!(mid2 > 141.5 && mid2 < 480.8, "{mid2}");
    }

    #[test]
    fn extrapolates_past_largest_size() {
        let ib = ib_edr_native();
        let l4m = ib.latency_us(4 * 1024 * 1024);
        assert!(l4m > 480.8 && l4m < 4.0 * 480.8, "{l4m}");
    }

    #[test]
    fn tcp_is_always_slower_than_native() {
        for kind in [FabricKind::InfinibandEdr, FabricKind::CrayAries] {
            let nat = link_for(kind, Transport::Native);
            let tcp = link_for(kind, Transport::TcpFallback);
            for s in OSU_SIZES {
                assert!(
                    tcp.latency_us(s) > nat.latency_us(s),
                    "{kind:?} size {s}"
                );
            }
        }
    }

    #[test]
    fn disabled_ratio_shapes_match_paper() {
        // Cluster: 15–50x across sizes; Daint: 1.4–6.5x.
        let nat = ib_edr_native();
        let tcp = ib_edr_tcp();
        for s in OSU_SIZES {
            let r = tcp.latency_us(s) / nat.latency_us(s);
            assert!((14.0..51.0).contains(&r), "cluster size {s}: {r}");
        }
        let nat = aries_native();
        let tcp = aries_tcp();
        for s in OSU_SIZES {
            let r = tcp.latency_us(s) / nat.latency_us(s);
            assert!((1.3..6.5).contains(&r), "daint size {s}: {r}");
        }
    }

    #[test]
    fn aries_beats_ib_at_large_messages() {
        // Daint's 2M native latency (215.7) vs cluster's (480.8)
        assert!(
            aries_native().latency_us(2097152)
                < ib_edr_native().latency_us(2097152)
        );
    }

    #[test]
    fn analytic_link_shows_rendezvous_step() {
        let l = AnalyticLink {
            base_latency_us: 1.0,
            bandwidth_gbps: 10.0,
            eager_threshold: 8192,
            rendezvous_overhead_us: 2.0,
        };
        let below = l.latency_us(8192);
        let above = l.latency_us(8193);
        assert!(above - below > 1.9, "step={}", above - below);
    }

    #[test]
    fn bandwidth_converges_at_large_sizes() {
        let ib = ib_edr_native();
        let bw = ib.bandwidth_gbps(2097152);
        assert!((3.0..6.0).contains(&bw), "bw={bw}"); // ~4.4 GB/s effective
    }
}
