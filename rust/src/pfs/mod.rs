//! Parallel filesystem substrate: a Lustre-like MDS/OST queueing model
//! (DESIGN.md S7).
//!
//! This is the mechanism behind Fig. 3: "for each DLL operation the compute
//! node needs to request the location of the shared object to the Lustre
//! Metadata server (MDS) and then fetch the memory block with the shared
//! object from the Object Storage Target (OST). The main cause of the long
//! start-up time are the repeated accesses to the MDS." Shifter avoids the
//! storm because the squashfs image is loop-mounted locally: one MDS
//! lookup per compute node, then block reads go straight to the OSTs and
//! metadata operations are served by the local kernel.

pub mod lustre;

pub use lustre::{LustreFs, Mds, NodeLocalFs, Ost};
