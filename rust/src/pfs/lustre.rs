//! Lustre-like parallel filesystem: MDS queueing + striped OST reads, plus
//! the node-local filesystem model used for loop-mounted squashfs images.

/// Metadata server: a single service center with bounded throughput.
/// Under a metadata storm (N clients × M ops each, issued concurrently)
/// the makespan is dominated by total_ops / throughput.
#[derive(Debug, Clone)]
pub struct Mds {
    /// Sustained metadata operations per second (lookup/open/getattr).
    pub ops_per_sec: f64,
    /// Unloaded per-op round-trip latency (µs).
    pub base_latency_us: f64,
}

impl Mds {
    /// Makespan (seconds) for `clients` concurrent clients issuing
    /// `ops_per_client` metadata ops each.
    ///
    /// M/D/1-flavored: at low load the ops pipeline (latency-bound), at
    /// high load the shared server saturates (throughput-bound).
    pub fn storm_secs(&self, clients: u64, ops_per_client: u64) -> f64 {
        let total_ops = (clients * ops_per_client) as f64;
        let throughput_bound = total_ops / self.ops_per_sec;
        // each client's own ops serialize on its side:
        let latency_bound = ops_per_client as f64 * self.base_latency_us * 1e-6;
        throughput_bound.max(latency_bound)
    }
}

/// One object storage target.
#[derive(Debug, Clone)]
pub struct Ost {
    pub bandwidth_gbps: f64,
}

/// The filesystem: one MDS (the Lustre architecture's scaling bottleneck)
/// plus an array of OSTs over which files are striped.
#[derive(Debug, Clone)]
pub struct LustreFs {
    pub mds: Mds,
    pub osts: Vec<Ost>,
    /// Stripe size in bytes.
    pub stripe_bytes: u64,
}

impl LustreFs {
    /// The Piz Daint scratch filesystem model (Sonexion; §V.A).
    pub fn piz_daint() -> LustreFs {
        LustreFs {
            mds: Mds {
                ops_per_sec: 25_000.0,
                base_latency_us: 450.0,
            },
            osts: (0..40)
                .map(|_| Ost {
                    bandwidth_gbps: 2.0,
                })
                .collect(),
            stripe_bytes: 1 << 20,
        }
    }

    /// The two-node Linux cluster's smaller storage.
    pub fn linux_cluster() -> LustreFs {
        LustreFs {
            mds: Mds {
                ops_per_sec: 8_000.0,
                base_latency_us: 600.0,
            },
            osts: (0..4)
                .map(|_| Ost {
                    bandwidth_gbps: 1.2,
                })
                .collect(),
            stripe_bytes: 1 << 20,
        }
    }

    pub fn aggregate_bandwidth_gbps(&self) -> f64 {
        self.osts.iter().map(|o| o.bandwidth_gbps).sum()
    }

    /// Seconds to read `bytes` of file data with `concurrent_readers`
    /// nodes pulling simultaneously (shared OST bandwidth), ignoring
    /// metadata (account for that separately via the MDS).
    pub fn bulk_read_secs(&self, bytes: u64, concurrent_readers: u64) -> f64 {
        let stripes = (bytes / self.stripe_bytes).max(1);
        let usable = self
            .aggregate_bandwidth_gbps()
            .min(stripes as f64 * self.osts[0].bandwidth_gbps);
        // total demand across readers shares the OST array
        (bytes as f64 * concurrent_readers as f64) / (usable * 1e9)
    }

    /// The full cost of every client opening+reading a small file (a DLL):
    /// MDS storm + per-node OST fetch (page cache: one fetch per node).
    pub fn dll_load_storm_secs(
        &self,
        ranks: u64,
        ranks_per_node: u64,
        files: u64,
        stats_per_open: u64,
        file_bytes: u64,
    ) -> f64 {
        let nodes = ranks.div_ceil(ranks_per_node).max(1);
        let mds = self
            .mds
            .storm_secs(ranks, files * stats_per_open);
        let ost = self.bulk_read_secs(files * file_bytes, nodes);
        mds + ost
    }
}

/// Node-local filesystem (RAM-backed page cache / local disk) — what a
/// loop-mounted squashfs image reads resolve against after the single
/// PFS lookup.
#[derive(Debug, Clone)]
pub struct NodeLocalFs {
    /// Local metadata op latency (µs) — kernel dcache hit.
    pub stat_latency_us: f64,
    /// Local read bandwidth (GB/s) — decompression-bound for squashfs.
    pub read_bandwidth_gbps: f64,
}

impl NodeLocalFs {
    pub fn squashfs_loop_mount() -> NodeLocalFs {
        NodeLocalFs {
            stat_latency_us: 2.5,
            read_bandwidth_gbps: 1.1,
        }
    }

    /// Per-rank cost of opening+reading `files` local files. Ranks on a
    /// node share the page cache, so file data is read once per node; the
    /// stat cost is per-rank but parallel across ranks (they proceed
    /// independently) — the makespan is the slowest rank.
    pub fn dll_load_secs(
        &self,
        files: u64,
        stats_per_open: u64,
        file_bytes: u64,
    ) -> f64 {
        let stats = (files * stats_per_open) as f64 * self.stat_latency_us * 1e-6;
        let reads = (files * file_bytes) as f64 / (self.read_bandwidth_gbps * 1e9);
        stats + reads
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mds_storm_saturates_at_scale() {
        let mds = Mds {
            ops_per_sec: 10_000.0,
            base_latency_us: 500.0,
        };
        // low client count: latency-bound
        let t_small = mds.storm_secs(1, 100);
        assert!((t_small - 0.05).abs() < 1e-9);
        // thousands of clients: throughput-bound, grows linearly
        let t_1k = mds.storm_secs(1000, 100);
        let t_2k = mds.storm_secs(2000, 100);
        assert!((t_2k / t_1k - 2.0).abs() < 1e-9);
        assert!((t_1k - 10.0).abs() < 1e-9); // 100k ops / 10k ops/s
    }

    #[test]
    fn bulk_read_shares_ost_bandwidth() {
        let fs = LustreFs::piz_daint();
        let one = fs.bulk_read_secs(1 << 30, 1);
        let many = fs.bulk_read_secs(1 << 30, 16);
        assert!((many / one - 16.0).abs() < 1e-9);
    }

    #[test]
    fn small_file_limited_by_stripe_parallelism() {
        let fs = LustreFs::piz_daint();
        // a 64 KiB file only touches one OST
        let t = fs.bulk_read_secs(64 * 1024, 1);
        let expected = (64.0 * 1024.0) / (2.0e9);
        assert!((t - expected).abs() / expected < 1e-9);
    }

    #[test]
    fn native_dll_storm_dwarfs_local_loads() {
        // the Fig. 3 mechanism at 3072 ranks / 12 per node
        let fs = LustreFs::piz_daint();
        let native = fs.dll_load_storm_secs(3072, 12, 710, 4, 1_800_000);
        let local = NodeLocalFs::squashfs_loop_mount()
            .dll_load_secs(710, 4, 1_800_000);
        assert!(
            native > 20.0 * local,
            "native={native:.1}s local={local:.3}s"
        );
    }

    #[test]
    fn native_storm_grows_with_ranks_local_flat() {
        let fs = LustreFs::piz_daint();
        let n48 = fs.dll_load_storm_secs(48, 12, 710, 4, 1_800_000);
        let n3072 = fs.dll_load_storm_secs(3072, 12, 710, 4, 1_800_000);
        assert!(n3072 > 10.0 * n48);
        let l = NodeLocalFs::squashfs_loop_mount();
        // local cost does not depend on rank count at all
        assert_eq!(
            l.dll_load_secs(710, 4, 1_800_000),
            l.dll_load_secs(710, 4, 1_800_000)
        );
    }

    #[test]
    fn cluster_fs_slower_than_daint() {
        let d = LustreFs::piz_daint();
        let c = LustreFs::linux_cluster();
        assert!(
            c.mds.storm_secs(100, 100) > d.mds.storm_secs(100, 100)
        );
        assert!(c.aggregate_bandwidth_gbps() < d.aggregate_bandwidth_gbps());
    }
}
