//! Deterministic PRNG (SplitMix64 + xoshiro256**) used throughout the
//! simulation substrates.
//!
//! No external `rand` dependency is available offline, and determinism is a
//! feature here anyway: every benchmark repetition protocol seeds a stream
//! from `(experiment, system, repetition)` so paper tables regenerate
//! bit-identically.

/// SplitMix64: used for seeding and cheap hashing of seed material.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// xoshiro256** — the main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed from a single u64 via SplitMix64 (the reference seeding scheme).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Derive a seed from string material — lets substrates key noise
    /// streams on `(system, implementation, message size, rep)` tuples.
    pub fn from_tags(tags: &[&str]) -> Self {
        let mut h: u64 = 0xcbf29ce484222325; // FNV-1a offset basis
        for t in tags {
            for b in t.as_bytes() {
                h ^= *b as u64;
                h = h.wrapping_mul(0x100000001b3);
            }
            h ^= 0x1f; // tag separator
            h = h.wrapping_mul(0x100000001b3);
        }
        Self::new(h)
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        // multiply-shift; bias negligible for simulation purposes
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-300);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Log-normal multiplicative noise with given sigma, mean ~1.
    /// Used for measurement-noise models in the repetition protocol.
    pub fn lognormal_noise(&mut self, sigma: f64) -> f64 {
        (sigma * self.normal()).exp()
    }

    /// Fill a f32 slice with He-style normal(0, scale) values (used by the
    /// e2e example to initialize CNN weights host-side).
    pub fn fill_normal_f32(&mut self, out: &mut [f32], scale: f32) {
        for v in out.iter_mut() {
            *v = self.normal() as f32 * scale;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn tags_separate_streams() {
        let a = Rng::from_tags(&["osu", "daint", "32"]).next_u64();
        let b = Rng::from_tags(&["osu", "daint", "64"]).next_u64();
        let c = Rng::from_tags(&["osu", "da", "int32"]).next_u64();
        assert_ne!(a, b);
        assert_ne!(a, c); // separator prevents concat collisions
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(17);
        for _ in 0..10_000 {
            assert!(r.below(7) < 7);
        }
    }

    #[test]
    fn lognormal_noise_centered_near_one() {
        let mut r = Rng::new(19);
        let n = 50_000;
        let mean: f64 =
            (0..n).map(|_| r.lognormal_noise(0.03)).sum::<f64>() / n as f64;
        assert!((mean - 1.0).abs() < 0.01, "mean={mean}");
    }
}
