//! Dependency-free utility layer: deterministic PRNG, JSON, CLI parsing,
//! and human-readable byte formatting.

pub mod cli;
pub mod json;
pub mod prng;
pub mod sync;

/// Format a byte count the way the tables/logs print sizes (powers of two).
pub fn human_bytes(n: u64) -> String {
    const UNITS: [&str; 5] = ["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = n as f64;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{n} B")
    } else {
        format!("{v:.1} {}", UNITS[u])
    }
}

/// Format seconds for report tables: ms below 1 s, "s" otherwise.
pub fn human_secs(s: f64) -> String {
    if s < 1e-3 {
        format!("{:.1} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{s:.2} s")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bytes_formatting() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.0 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024), "3.0 MiB");
    }

    #[test]
    fn secs_formatting() {
        assert_eq!(human_secs(0.5), "500.00 ms");
        assert_eq!(human_secs(2.0), "2.00 s");
        assert_eq!(human_secs(5e-6), "5.0 µs");
    }
}
