//! Poison-tolerant locking (the S26 `lock-poison` convention).
//!
//! Every shared structure in this crate (telemetry registries, the
//! scheduler's template cache, distribution caches) is read-mostly and
//! internally consistent at every instruction boundary: writers mutate a
//! single field or perform an insert, never a multi-step transaction. A
//! panic while such a guard is held therefore cannot leave the data in a
//! half-written state — which means propagating the poison flag to every
//! *later* reader (what `.lock().unwrap()` does) converts one failed job
//! into a site-wide cascade for no integrity benefit.
//!
//! `lock_unpoisoned` encodes that policy in one place: take the guard,
//! recovering it from the poison wrapper if a previous holder panicked.
//! shifter-lint forbids `.lock().unwrap()`/`.expect()` in library code and
//! points here.

use std::sync::{Mutex, MutexGuard, PoisonError};

/// Lock `mutex`, recovering the guard if the mutex was poisoned.
///
/// See the module docs for why poison recovery is sound in this crate.
/// Prefer this over `.lock().unwrap()` everywhere outside tests.
pub fn lock_unpoisoned<T>(mutex: &Mutex<T>) -> MutexGuard<'_, T> {
    mutex.lock().unwrap_or_else(PoisonError::into_inner)
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Mutex;

    #[test]
    fn locks_a_healthy_mutex() {
        let m = Mutex::new(7u32);
        assert_eq!(*lock_unpoisoned(&m), 7);
    }

    #[test]
    fn recovers_a_poisoned_mutex() {
        let m = Mutex::new(vec![1u32]);
        // Poison the mutex: panic while holding the guard.
        let caught = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _guard = m.lock().expect("first lock is healthy");
            panic!("poison the guard");
        }));
        assert!(caught.is_err());
        assert!(m.is_poisoned());
        let mut guard = lock_unpoisoned(&m);
        guard.push(2);
        assert_eq!(*guard, vec![1, 2]);
    }
}
