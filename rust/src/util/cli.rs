//! Tiny command-line parser (clap is not in the offline vendor set).
//!
//! Supports the option grammar the `shifter` / `shifterimg` CLIs need:
//! `--flag`, `--key=value`, `--key value`, positional arguments, and a
//! trailing command after the option section (everything after the first
//! non-option token belongs to the containerized command, mirroring
//! Shifter's real CLI where `shifter --image=X cmd args...`).

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct ParsedArgs {
    pub flags: BTreeMap<String, String>,
    pub positionals: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum CliError {
    #[error("unknown option --{0}")]
    UnknownOption(String),
    #[error("option --{0} requires a value")]
    MissingValue(String),
}

pub struct CliSpec {
    /// (name, takes_value)
    opts: Vec<(&'static str, bool)>,
    /// stop option parsing at the first positional (shifter-style)
    stop_at_positional: bool,
}

impl CliSpec {
    pub fn new(opts: &[(&'static str, bool)], stop_at_positional: bool) -> Self {
        Self {
            opts: opts.to_vec(),
            stop_at_positional,
        }
    }

    pub fn parse<I: IntoIterator<Item = String>>(
        &self,
        args: I,
    ) -> Result<ParsedArgs, CliError> {
        let mut out = ParsedArgs::default();
        let mut it = args.into_iter().peekable();
        let mut options_done = false;
        while let Some(arg) = it.next() {
            if !options_done && arg == "--" {
                options_done = true;
                continue;
            }
            if !options_done && arg.starts_with("--") {
                let body = &arg[2..];
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let spec = self
                    .opts
                    .iter()
                    .find(|(n, _)| *n == name)
                    .ok_or_else(|| CliError::UnknownOption(name.clone()))?;
                if spec.1 {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .ok_or_else(|| CliError::MissingValue(name.clone()))?,
                    };
                    out.flags.insert(name, v);
                } else {
                    out.flags.insert(name, "true".to_string());
                }
            } else {
                out.positionals.push(arg);
                if self.stop_at_positional {
                    options_done = true;
                }
            }
        }
        Ok(out)
    }
}

impl ParsedArgs {
    pub fn has(&self, name: &str) -> bool {
        self.flags.contains_key(name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }
}

/// Print a typed error and its full `source()` chain prefixed with the
/// program name, then exit with status 1 — the shared error exit of the
/// `shifter` and `shifterimg` binaries.
pub fn die(prog: &str, err: &dyn std::error::Error) -> ! {
    eprintln!("{prog}: {err}");
    let mut source = err.source();
    while let Some(cause) = source {
        eprintln!("{prog}:   caused by: {cause}");
        source = cause.source();
    }
    std::process::exit(1);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CliSpec {
        CliSpec::new(&[("image", true), ("mpi", false), ("verbose", false)], true)
    }

    fn args(v: &[&str]) -> Vec<String> {
        v.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_shifter_style_invocation() {
        let p = spec()
            .parse(args(&["--image=ubuntu:xenial", "--mpi", "cat", "--version"]))
            .unwrap();
        assert_eq!(p.get("image"), Some("ubuntu:xenial"));
        assert!(p.has("mpi"));
        // "--version" after the command is a positional, not an option
        assert_eq!(p.positionals, vec!["cat", "--version"]);
    }

    #[test]
    fn space_separated_value() {
        let p = spec().parse(args(&["--image", "cuda-image", "run"])).unwrap();
        assert_eq!(p.get("image"), Some("cuda-image"));
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(matches!(
            spec().parse(args(&["--bogus"])),
            Err(CliError::UnknownOption(_))
        ));
    }

    #[test]
    fn missing_value_rejected() {
        assert!(matches!(
            spec().parse(args(&["--image"])),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn double_dash_ends_options() {
        let p = spec().parse(args(&["--mpi", "--", "--image"])).unwrap();
        assert!(p.has("mpi"));
        assert_eq!(p.positionals, vec!["--image"]);
    }
}
