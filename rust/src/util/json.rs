//! Minimal JSON value model, parser and writer.
//!
//! serde/serde_json are not available in the offline vendor set, and the
//! JSON this project consumes is narrow and fully under our control: the
//! AOT `artifacts/manifest.json` and Docker-style image/registry manifests.
//! This module implements exactly the JSON we need (RFC 8259 subset: no
//! surrogate-pair escapes beyond \uXXXX BMP, numbers as f64/i64).

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, thiserror::Error)]
#[error("json parse error at byte {pos}: {msg}")]
pub struct JsonError {
    pub pos: usize,
    pub msg: String,
}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().filter(|n| *n >= 0.0).map(|n| n as u64)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|m| m.get(key))
    }

    /// Path accessor: `j.at(&["artifacts", "mnist_train", "file"])`.
    pub fn at(&self, path: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in path {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    // -- builders ---------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: impl Into<f64>) -> Json {
        Json::Num(n.into())
    }
}

impl fmt::Display for Json {
    /// Compact serialization (stable: object keys are BTreeMap-ordered).
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => write_escaped(f, s),
            Json::Arr(v) => {
                write!(f, "[")?;
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
            Json::Obj(m) => {
                write!(f, "{{")?;
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write_escaped(f, k)?;
                    write!(f, ":{v}")?;
                }
                write!(f, "}}")
            }
        }
    }
}

fn write_escaped(f: &mut fmt::Formatter<'_>, s: &str) -> fmt::Result {
    write!(f, "\"")?;
    for c in s.chars() {
        match c {
            '"' => write!(f, "\\\"")?,
            '\\' => write!(f, "\\\\")?,
            '\n' => write!(f, "\\n")?,
            '\r' => write!(f, "\\r")?,
            '\t' => write!(f, "\\t")?,
            c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
            c => write!(f, "{c}")?,
        }
    }
    write!(f, "\"")
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError {
            pos: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect_byte(&mut self, b: u8) -> Result<(), JsonError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, JsonError> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{lit}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect_byte(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.expect_byte(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.skip_ws();
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.expect_byte(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(
                                &self.bytes[self.pos + 1..self.pos + 5],
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(
                                char::from_u32(code)
                                    .ok_or_else(|| self.err("bad codepoint"))?,
                            );
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let s = std::str::from_utf8(&self.bytes[start..])
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let Some(c) = s.chars().next() else {
                        return Err(self.err("truncated utf-8"));
                    };
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(b'0'..=b'9')) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid utf-8 in number"))?;
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            Json::parse("\"a\\nb\"").unwrap(),
            Json::Str("a\nb".into())
        );
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(j.at(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("'single'").is_err());
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"s"],"nested":{"k":true},"z":null}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn escapes_on_write() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.to_string(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(
            Json::parse(r#""é""#).unwrap(),
            Json::Str("é".into())
        );
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "generator": "shifter-rs-aot-1",
          "artifacts": {
            "x": {"file": "x.hlo.txt",
                   "inputs": [{"name":"a","shape":[2,3],"dtype":"f32"}],
                   "outputs": [{"name":"o","shape":[],"dtype":"f32"}],
                   "flops_per_call": 123}
          }
        }"#;
        let j = Json::parse(src).unwrap();
        assert_eq!(
            j.at(&["artifacts", "x", "flops_per_call"]).unwrap().as_u64(),
            Some(123)
        );
        let shape = j.at(&["artifacts", "x", "inputs"]).unwrap().as_arr().unwrap()
            [0]
        .get("shape")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|v| v.as_u64().unwrap())
        .collect::<Vec<_>>();
        assert_eq!(shape, vec![2, 3]);
    }
}
