//! OSU Micro-Benchmarks model (§V.C.1, Tables III/IV): osu_latency
//! ping-pong between two ranks on two nodes, best of 30 repetitions.
//!
//! Three run modes per system:
//!  * native        — the benchmark built against the host MPI;
//!  * enabled       — container run with Shifter MPI support (library
//!                    swapped, vendor transport visible);
//!  * disabled      — container run without the swap: "the containerized
//!                    application does not benefit from the hardware
//!                    acceleration" and falls back to TCP.

use crate::fabric::OSU_SIZES;
use crate::hostenv::SystemProfile;
use crate::metrics::{repeat, Stats};
use crate::mpi::{Communicator, MpiImpl};
use crate::shifter::Container;
use crate::util::prng::Rng;

/// One table row: message size + best one-way latency (µs).
#[derive(Debug, Clone, PartialEq)]
pub struct LatencyRow {
    pub size: u64,
    pub best_us: f64,
    pub stats: Stats,
}

/// Run osu_latency for `mpi` on `profile`'s fabric: 30 reps per size,
/// best-of protocol. `tag` keys the deterministic noise stream.
pub fn osu_latency(
    profile: &SystemProfile,
    mpi: &MpiImpl,
    tag: &str,
) -> Vec<LatencyRow> {
    OSU_SIZES
        .iter()
        .map(|&size| {
            let comm = Communicator::new(mpi, profile.fabric, 2);
            let stats = repeat(|rep| {
                let mut rng = Rng::from_tags(&[
                    "osu",
                    profile.name,
                    tag,
                    &size.to_string(),
                    &rep.to_string(),
                ]);
                comm.osu_latency_sample_us(size, &mut rng)
            });
            LatencyRow {
                size,
                best_us: stats.best,
                stats,
            }
        })
        .collect()
}

/// Native rows: benchmark linked against the host MPI.
pub fn run_native(profile: &SystemProfile) -> Vec<LatencyRow> {
    osu_latency(profile, &profile.host_mpi, "native")
}

/// Containerized rows: the effective MPI is whatever the Shifter run left
/// the container with (host library if support was enabled, the image's
/// own TCP build otherwise).
pub fn run_container(
    profile: &SystemProfile,
    container: &Container,
    tag: &str,
) -> Vec<LatencyRow> {
    let Some(mpi) = container.effective_mpi(profile) else {
        panic!("osu benchmark container carries no MPI library");
    };
    osu_latency(profile, &mpi, tag)
}

/// Relative-performance column: container latency / native latency per
/// size (the paper's A/B/C columns).
pub fn relative(container: &[LatencyRow], native: &[LatencyRow]) -> Vec<f64> {
    container
        .iter()
        .zip(native)
        .map(|(c, n)| c.best_us / n.best_us)
        .collect()
}

/// Format a size the way the paper's tables label rows (32, 2K, 2M…).
pub fn size_label(size: u64) -> String {
    if size >= 1024 * 1024 {
        format!("{}M", size / (1024 * 1024))
    } else if size >= 1024 {
        format!("{}K", size / 1024)
    } else {
        size.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;
    use crate::mpi::MpiImpl;

    #[test]
    fn native_best_tracks_calibration_table() {
        let cl = SystemProfile::linux_cluster();
        let rows = run_native(&cl);
        assert_eq!(rows.len(), 9);
        // best-of-30 squeezes below the model value but stays within noise
        let row32 = &rows[0];
        assert!(row32.size == 32);
        assert!((row32.best_us / 1.2 - 1.0).abs() < 0.15, "{}", row32.best_us);
        let row2m = rows.last().unwrap();
        assert!((row2m.best_us / 480.8 - 1.0).abs() < 0.15);
    }

    #[test]
    fn enabled_container_matches_native_within_noise() {
        let daint = SystemProfile::piz_daint();
        let native = run_native(&daint);
        // an enabled container's effective MPI IS the host MPI
        let cont = osu_latency(&daint, &daint.host_mpi, "containerA");
        for (r, sz) in relative(&cont, &native).iter().zip(OSU_SIZES) {
            assert!((0.9..1.12).contains(r), "size {sz}: ratio {r}");
        }
    }

    #[test]
    fn disabled_container_shows_paper_slowdowns() {
        let cl = SystemProfile::linux_cluster();
        let native = run_native(&cl);
        let cont =
            osu_latency(&cl, &MpiImpl::mpich_3_1_4_container(), "disabledA");
        let ratios = relative(&cont, &native);
        // paper Table III disabled: 15–50x across sizes
        for (r, sz) in ratios.iter().zip(OSU_SIZES) {
            assert!((12.0..55.0).contains(r), "size {sz}: ratio {r}");
        }

        let daint = SystemProfile::piz_daint();
        let native = run_native(&daint);
        let cont =
            osu_latency(&daint, &MpiImpl::mpich_3_1_4_container(), "disabledA");
        // paper Table IV disabled: 1.4–6.2x
        for (r, sz) in relative(&cont, &native).iter().zip(OSU_SIZES) {
            assert!((1.2..7.0).contains(r), "size {sz}: ratio {r}");
        }
    }

    #[test]
    fn determinism() {
        let cl = SystemProfile::linux_cluster();
        let a = run_native(&cl);
        let b = run_native(&cl);
        assert_eq!(a, b);
    }

    #[test]
    fn size_labels() {
        assert_eq!(size_label(32), "32");
        assert_eq!(size_label(2048), "2K");
        assert_eq!(size_label(2 * 1024 * 1024), "2M");
    }
}
