//! Containerized application models (DESIGN.md S14) — the five workloads
//! of the paper's evaluation: TensorFlow trainers (Table I), PyFR
//! (Table II), OSU micro-benchmarks (Tables III/IV), the CUDA SDK n-body
//! simulation (Table V) and Pynamic (Fig. 3).

pub mod nbody;
pub mod osu;
pub mod pyfr;
pub mod pynamic;
pub mod tf_trainer;
