//! CUDA SDK n-body benchmark model (§V.C.2, Table V): all-pairs
//! gravitational simulation of n = 200,000 bodies in double precision,
//! best GF/s over 30 repetitions, native vs containerized-with-GPU-support.
//!
//! Two layers of fidelity:
//!  * the *device* numbers (Table V) come from the GPU performance model
//!    over the board specs — we have no NVIDIA hardware;
//!  * the *computation itself* runs for real through the `nbody_step`
//!    AOT artifact on the CPU PJRT client (`run_real_steps`), proving the
//!    container executes the same bits natively and in Shifter.

use crate::gpu::{achieved_gflops_board, GpuModel, WorkloadClass};
use crate::metrics::{repeat, Stats};
use crate::runtime::{ExecError, Executor, TensorValue};
use crate::util::prng::Rng;

/// The paper's test case.
pub const NBODY_N: u64 = 200_000;
/// FLOPs per interaction (CUDA SDK accounting convention).
pub const FLOPS_PER_INTERACTION: u64 = 20;

pub fn total_flops(n: u64) -> f64 {
    (FLOPS_PER_INTERACTION * n * n) as f64
}

/// A Table V hardware setup: the boards one process can reach.
#[derive(Debug, Clone)]
pub struct NbodySetup {
    pub label: &'static str,
    pub boards: Vec<GpuModel>,
}

impl NbodySetup {
    pub fn laptop() -> NbodySetup {
        NbodySetup {
            label: "K110M",
            boards: vec![GpuModel::quadro_k110m()],
        }
    }

    pub fn cluster_single() -> NbodySetup {
        NbodySetup {
            label: "K40m",
            boards: vec![GpuModel::tesla_k40m()],
        }
    }

    pub fn cluster_dual() -> NbodySetup {
        NbodySetup {
            label: "K40m & K80",
            boards: vec![GpuModel::tesla_k40m(), GpuModel::tesla_k80()],
        }
    }

    pub fn daint() -> NbodySetup {
        NbodySetup {
            label: "P100",
            boards: vec![GpuModel::tesla_p100()],
        }
    }

    /// Model GF/s for this setup (multi-GPU: boards sum, as the SDK
    /// benchmark splits the body set across devices).
    pub fn model_gflops(&self) -> f64 {
        self.boards
            .iter()
            .map(|b| achieved_gflops_board(WorkloadClass::NbodyFp64, b))
            .sum()
    }
}

/// Best-of-30 GF/s with measurement noise, `mode` ∈ {"native","container"}.
/// The container adds no per-step cost (same binary, same driver-matched
/// libraries after GPU support injection) — exactly the paper's claim —
/// so the only difference between modes is the independent noise stream.
pub fn benchmark_gflops(setup: &NbodySetup, mode: &str) -> Stats {
    let base = setup.model_gflops();
    let stats = repeat(|rep| {
        let mut rng =
            Rng::from_tags(&["nbody", setup.label, mode, &rep.to_string()]);
        // one-sided noise: the calibrated model value is the best
        // achievable rate; interference only slows runs down
        base * (-0.002 * rng.normal().abs()).exp()
    });
    // best GF/s = max sample; Stats.best is the min, so rebuild
    Stats {
        best: stats.worst,
        worst: stats.best,
        ..stats
    }
}

/// Result of a *real* n-body integration through the AOT artifact.
#[derive(Debug)]
pub struct RealNbodyReport {
    pub steps: u32,
    pub n_bodies: usize,
    pub cpu_gflops: f64,
    /// mean |acceleration| proxy from the last step (finite => sane orbit)
    pub final_acc_norm: f64,
    pub total_wall_secs: f64,
}

/// Integrate the 1024-body artifact `steps` steps on the CPU PJRT client,
/// feeding outputs back as inputs (the container/native "same bits" run).
pub fn run_real_steps(
    executor: &Executor,
    steps: u32,
    seed: u64,
) -> Result<RealNbodyReport, ExecError> {
    let spec = executor.catalog().get("nbody_step")?;
    let n = spec.inputs[0].shape[0];
    let mut rng = Rng::new(seed);
    let mut pos4 = vec![0.0f64; n * 4];
    for i in 0..n {
        // Plummer-ish cluster
        pos4[i * 4] = rng.normal() * 5.0;
        pos4[i * 4 + 1] = rng.normal() * 5.0;
        pos4[i * 4 + 2] = rng.normal() * 5.0;
        pos4[i * 4 + 3] = rng.range(0.5, 1.5);
    }
    let mut vel = vec![0.0f64; n * 3];
    for v in vel.iter_mut() {
        *v = rng.normal() * 0.05;
    }

    let mut total_wall = 0.0;
    let mut acc_norm = 0.0;
    let mut flops = 0u64;
    for _ in 0..steps {
        let res = executor.execute(
            "nbody_step",
            &[
                TensorValue::F64(pos4.clone()),
                TensorValue::F64(vel.clone()),
                TensorValue::F64(vec![1e-3]),
            ],
        )?;
        pos4 = res.outputs[0].as_f64().to_vec();
        vel = res.outputs[1].as_f64().to_vec();
        acc_norm = res.outputs[2].as_f64()[0];
        total_wall += res.wall.as_secs_f64();
        flops += res.flops;
    }
    Ok(RealNbodyReport {
        steps,
        n_bodies: n,
        cpu_gflops: flops as f64 / total_wall / 1e9,
        final_acc_norm: acc_norm,
        total_wall_secs: total_wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table5_native_values_reproduced() {
        // paper: 18.34 / 858.09 / 1895.32 / 2733.01
        let cases = [
            (NbodySetup::laptop(), 18.34),
            (NbodySetup::cluster_single(), 858.09),
            (NbodySetup::cluster_dual(), 1895.32),
            (NbodySetup::daint(), 2733.01),
        ];
        for (setup, paper) in cases {
            let got = benchmark_gflops(&setup, "native").best;
            let err = (got - paper).abs() / paper;
            assert!(err < 0.02, "{}: {got:.2} vs paper {paper}", setup.label);
        }
    }

    #[test]
    fn container_equals_native_within_half_percent() {
        for setup in [
            NbodySetup::laptop(),
            NbodySetup::cluster_single(),
            NbodySetup::cluster_dual(),
            NbodySetup::daint(),
        ] {
            let nat = benchmark_gflops(&setup, "native").best;
            let cont = benchmark_gflops(&setup, "container").best;
            assert!(
                ((cont / nat) - 1.0).abs() < 0.005,
                "{}: {cont} vs {nat}",
                setup.label
            );
        }
    }

    #[test]
    fn ranking_matches_paper() {
        assert!(
            NbodySetup::daint().model_gflops()
                > NbodySetup::cluster_dual().model_gflops()
        );
        assert!(
            NbodySetup::cluster_dual().model_gflops()
                > NbodySetup::cluster_single().model_gflops()
        );
        assert!(
            NbodySetup::cluster_single().model_gflops()
                > NbodySetup::laptop().model_gflops()
        );
    }

    #[test]
    fn total_flops_accounting() {
        assert_eq!(total_flops(200_000), 20.0 * 4e10);
    }
}
