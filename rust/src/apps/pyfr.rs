//! PyFR flux-reconstruction solver model (§V.B.2, Table II): the T106D
//! low-pressure turbine blade case — 114,265 hexahedral cells, single
//! precision, dt = 9.3558e-6 s, 3,206 iterations, one MPI rank per GPU.
//!
//! Per-rank wall-clock = compute (device performance model, strong-scaling
//! launch overhead included) + halo exchange (fabric model through the
//! container's effective MPI). The solver mathematics runs for real via
//! the `pyfr_step` artifact (`run_real_partition`).

use crate::gpu::{
    achieved_gflops_per_chip, launch_overhead_s, GpuModel, WorkloadClass,
};
use crate::hostenv::SystemProfile;
use crate::mpi::{Communicator, MpiImpl};
use crate::runtime::{ExecError, Executor, TensorValue};

/// The paper's test case parameters.
pub const T106D_CELLS: u64 = 114_265;
pub const T106D_POINTS: u64 = 1_154_120;
pub const T106D_ITERS: u64 = 3_206;
pub const T106D_DT: f64 = 9.3558e-6;

/// Calibrated compute demand per cell per iteration (FLOPs) — from the
/// Daint single-GPU wall-clock (EXPERIMENTS.md records the arithmetic).
pub const FLOPS_PER_CELL_ITER: f64 = 6.9e6;
/// GPU kernel launches per iteration (4-stage RK, many small kernels) —
/// this is what bends strong scaling away from ideal.
pub const KERNEL_LAUNCHES_PER_ITER: f64 = 1400.0;

/// One MPI rank's device assignment.
#[derive(Debug, Clone)]
pub struct RankDevice {
    pub board: GpuModel,
}

/// A Table II run configuration.
#[derive(Debug, Clone)]
pub struct PyfrRun {
    pub system: &'static str,
    pub devices: Vec<RankDevice>,
}

impl PyfrRun {
    /// Piz Daint: one P100 per node, `n` nodes.
    pub fn daint(n: usize) -> PyfrRun {
        PyfrRun {
            system: "Piz Daint",
            devices: vec![
                RankDevice {
                    board: GpuModel::tesla_p100()
                };
                n
            ],
        }
    }

    /// Linux Cluster per the paper's §V.B.2 device split:
    /// 1 GPU: one K40m; 2 GPUs: two K40m (one per node);
    /// 4 GPUs: two K40m + one K80 chip on each node.
    pub fn cluster(n: usize) -> PyfrRun {
        let devices = match n {
            1 => vec![RankDevice {
                board: GpuModel::tesla_k40m(),
            }],
            2 => vec![
                RankDevice {
                    board: GpuModel::tesla_k40m(),
                },
                RankDevice {
                    board: GpuModel::tesla_k40m(),
                },
            ],
            4 => vec![
                RankDevice {
                    board: GpuModel::tesla_k40m(),
                },
                RankDevice {
                    board: GpuModel::tesla_k40m(),
                },
                RankDevice {
                    board: GpuModel::tesla_k80(),
                },
                RankDevice {
                    board: GpuModel::tesla_k80(),
                },
            ],
            other => panic!("paper has no {other}-GPU cluster configuration"),
        };
        PyfrRun {
            system: "Linux Cluster",
            devices,
        }
    }

    pub fn ranks(&self) -> usize {
        self.devices.len()
    }
}

/// Modeled wall-clock for the full T106D run.
///
/// Cells split evenly (Metis partitioning); the slowest rank bounds each
/// iteration; the halo exchange goes through `mpi` on the system fabric.
pub fn wallclock_secs(
    run: &PyfrRun,
    profile: &SystemProfile,
    mpi: &MpiImpl,
) -> f64 {
    let ranks = run.ranks() as f64;
    let cells_per_rank = T106D_CELLS as f64 / ranks;
    // slowest rank = weakest device (per chip: one rank drives one chip)
    let per_iter_compute = run
        .devices
        .iter()
        .map(|d| {
            let achieved = achieved_gflops_per_chip(
                WorkloadClass::PyfrFp32,
                &d.board,
            ) * 1e9;
            cells_per_rank * FLOPS_PER_CELL_ITER / achieved
                + KERNEL_LAUNCHES_PER_ITER * launch_overhead_s(d.board.arch)
        })
        .fold(0.0f64, f64::max);

    let per_iter_comm = if run.ranks() > 1 {
        let comm = Communicator::new(mpi, profile.fabric, run.ranks() as u32);
        // interface data per neighbor: ~(cells/rank)^(2/3) faces x 8
        // points x 4 vars x 4 bytes, exchanged every RK stage
        let msg = (cells_per_rank.powf(2.0 / 3.0) * 8.0 * 4.0 * 4.0) as u64;
        4.0 * comm.halo_exchange_us(msg, 2) * 1e-6
    } else {
        0.0
    };

    T106D_ITERS as f64 * (per_iter_compute + per_iter_comm)
}

/// A real mesh-partition integration through the `pyfr_step` artifact.
#[derive(Debug)]
pub struct RealPyfrReport {
    pub iters: u32,
    pub elements: usize,
    pub residuals: Vec<f32>,
    pub wall_secs: f64,
}

/// Run `iters` real flux-reconstruction steps on the AOT artifact with a
/// smooth initial condition and a conservative divergence operator.
pub fn run_real_partition(
    executor: &Executor,
    iters: u32,
) -> Result<RealPyfrReport, ExecError> {
    let spec = executor.catalog().get("pyfr_step")?;
    let (e, p, v) = (
        spec.inputs[0].shape[0],
        spec.inputs[0].shape[1],
        spec.inputs[0].shape[2],
    );
    // smooth initial solution
    let mut u = vec![0.0f32; e * p * v];
    for (i, x) in u.iter_mut().enumerate() {
        *x = 1.0 + 0.1 * ((i as f32) * 0.037).sin();
    }
    // divergence-like operator with zero row sums (conservation)
    let mut op = vec![0.0f32; p * p];
    for r in 0..p {
        let mut row_sum = 0.0;
        for c in 0..p {
            if r != c {
                let val = ((r * p + c) as f32 * 0.11).sin() * 0.5;
                op[r * p + c] = val;
                row_sum += val;
            }
        }
        op[r * p + r] = -row_sum;
    }

    let mut residuals = Vec::with_capacity(iters as usize);
    let mut wall = 0.0;
    for _ in 0..iters {
        let res = executor.execute(
            "pyfr_step",
            &[
                TensorValue::F32(u.clone()),
                TensorValue::F32(op.clone()),
                TensorValue::F32(vec![T106D_DT as f32]),
            ],
        )?;
        u = res.outputs[0].as_f32().to_vec();
        residuals.push(res.outputs[1].as_f32()[0]);
        wall += res.wall.as_secs_f64();
    }
    Ok(RealPyfrReport {
        iters,
        elements: e,
        residuals,
        wall_secs: wall,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    fn daint_time(gpus: usize) -> f64 {
        let pd = SystemProfile::piz_daint();
        wallclock_secs(&PyfrRun::daint(gpus), &pd, &pd.host_mpi)
    }

    fn cluster_time(gpus: usize) -> f64 {
        let cl = SystemProfile::linux_cluster();
        wallclock_secs(&PyfrRun::cluster(gpus), &cl, &cl.host_mpi)
    }

    #[test]
    fn table2_wallclock_within_5_percent() {
        // paper Table II: Cluster 9906/4961/2509, Daint 2391/1223/620/322
        let cases: [(f64, f64); 7] = [
            (cluster_time(1), 9906.0),
            (cluster_time(2), 4961.0),
            (cluster_time(4), 2509.0),
            (daint_time(1), 2391.0),
            (daint_time(2), 1223.0),
            (daint_time(4), 620.0),
            (daint_time(8), 322.0),
        ];
        for (got, paper) in cases {
            let err = (got - paper).abs() / paper;
            assert!(err < 0.05, "{got:.0}s vs paper {paper}s");
        }
    }

    #[test]
    fn scaling_is_near_linear() {
        // paper obs I: "execution times scale linearly"
        let e1 = daint_time(1) / (2.0 * daint_time(2));
        let e8 = daint_time(1) / (8.0 * daint_time(8));
        assert!(e1 > 0.9, "2-GPU efficiency {e1}");
        assert!(e8 > 0.85, "8-GPU efficiency {e8}");
    }

    #[test]
    fn p100_about_4x_k40m() {
        let ratio = cluster_time(1) / daint_time(1);
        assert!((3.7..4.6).contains(&ratio), "ratio={ratio}");
    }

    #[test]
    fn heterogeneous_4gpu_close_to_linear() {
        // paper obs III: K80 chip ~ K40m, so 4 GPUs ~ 1/4 of 1 GPU
        let eff = cluster_time(1) / (4.0 * cluster_time(4));
        assert!(eff > 0.9, "4-GPU heterogeneous efficiency {eff}");
    }

    #[test]
    fn tcp_fallback_would_slow_multinode_runs() {
        let pd = SystemProfile::piz_daint();
        let native = wallclock_secs(&PyfrRun::daint(4), &pd, &pd.host_mpi);
        let tcp = wallclock_secs(
            &PyfrRun::daint(4),
            &pd,
            &crate::mpi::MpiImpl::mpich_3_1_4_container(),
        );
        assert!(tcp > native);
    }
}
