//! Containerized TensorFlow trainer model (§V.B.1, Table I): the MNIST
//! LeNet-5-like tutorial and the CIFAR-10 CNN tutorial, single node,
//! single GPU, across the three systems.
//!
//! Wall-clock on the paper's GPUs comes from the device performance model;
//! the *training computation itself* is real — `run_real_training` drives
//! the `mnist_train`/`cifar_train` AOT artifacts through PJRT with
//! synthetic class-separable data and returns a genuine loss curve (the
//! e2e example and EXPERIMENTS.md record it).

use crate::gpu::{achieved_gflops_per_chip, launch_overhead_s, GpuModel, WorkloadClass};
use crate::runtime::{ExecError, Executor, TensorValue};
use crate::util::prng::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TfWorkload {
    Mnist,
    Cifar10,
}

impl TfWorkload {
    /// Training steps the paper's test cases run.
    pub fn steps(&self) -> u64 {
        match self {
            // convolutional.py: 10 epochs x 60000/64 per epoch
            TfWorkload::Mnist => 9375,
            // "we run the training for 100,000 steps"
            TfWorkload::Cifar10 => 100_000,
        }
    }

    /// FLOPs per train step (fwd+bwd; matches python/compile/model.py).
    pub fn flops_per_step(&self) -> f64 {
        match self {
            TfWorkload::Mnist => 4.713e9,
            TfWorkload::Cifar10 => 3.546e9,
        }
    }

    pub fn workload_class(&self) -> WorkloadClass {
        match self {
            TfWorkload::Mnist => WorkloadClass::MnistTrain,
            TfWorkload::Cifar10 => WorkloadClass::CifarTrain,
        }
    }

    pub fn artifact(&self) -> &'static str {
        match self {
            TfWorkload::Mnist => "mnist_train",
            TfWorkload::Cifar10 => "cifar_train",
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            TfWorkload::Mnist => "MNIST",
            TfWorkload::Cifar10 => "CIFAR-10",
        }
    }
}

/// Modeled wall-clock (seconds) for the full training run on one GPU chip.
pub fn train_time_secs(workload: TfWorkload, board: &GpuModel) -> f64 {
    let achieved =
        achieved_gflops_per_chip(workload.workload_class(), board) * 1e9;
    let compute = workload.steps() as f64 * workload.flops_per_step() / achieved;
    compute + workload.steps() as f64 * launch_overhead_s(board.arch)
}

/// A real PJRT training run's outcome.
#[derive(Debug)]
pub struct TrainReport {
    pub workload: TfWorkload,
    pub steps: u32,
    pub losses: Vec<f32>,
    pub wall_secs: f64,
    pub cpu_gflops: f64,
}

impl TrainReport {
    pub fn first_loss(&self) -> f32 {
        match self.losses.first() {
            Some(l) => *l,
            None => panic!("TrainReport records no losses"),
        }
    }

    pub fn last_loss(&self) -> f32 {
        match self.losses.last() {
            Some(l) => *l,
            None => panic!("TrainReport records no losses"),
        }
    }

    pub fn loss_decreased(&self) -> bool {
        self.last_loss() < self.first_loss()
    }
}

/// He-style init for a parameter tensor signature (biases zero).
fn init_param(shape: &[usize], rng: &mut Rng) -> Vec<f32> {
    let count: usize = shape.iter().product();
    let mut v = vec![0.0f32; count];
    if shape.len() > 1 {
        let fan_in: usize = shape[..shape.len() - 1].iter().product();
        let scale = (2.0 / fan_in as f64).sqrt() as f32;
        rng.fill_normal_f32(&mut v, scale);
    }
    v
}

/// Synthetic MNIST batch: class-k digits are bright blobs at class-specific
/// positions (same recipe as python/tests/test_models.py, so the loss curve
/// is meaningfully learnable).
fn synthetic_mnist(batch: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let mut x = vec![0.0f32; batch * 28 * 28];
    rng.fill_normal_f32(&mut x, 0.1);
    let mut y = vec![0i32; batch];
    for (i, label) in y.iter_mut().enumerate() {
        let cls = rng.below(10) as i32;
        *label = cls;
        let (r0, c0) = (4 + 2 * (cls as usize % 5), 6 + 3 * (cls as usize / 5));
        for r in r0..r0 + 6 {
            for c in c0..c0 + 6 {
                x[i * 784 + r * 28 + c] += 1.0;
            }
        }
    }
    (x, y)
}

/// Synthetic CIFAR batch: class tint in a channel.
fn synthetic_cifar(batch: usize, rng: &mut Rng) -> (Vec<f32>, Vec<i32>) {
    let hw = 24 * 24;
    let mut x = vec![0.0f32; batch * hw * 3];
    rng.fill_normal_f32(&mut x, 0.1);
    let mut y = vec![0i32; batch];
    for (i, label) in y.iter_mut().enumerate() {
        let cls = rng.below(10) as i32;
        *label = cls;
        let ch = cls as usize % 3;
        for p in 0..hw {
            x[i * hw * 3 + p * 3 + ch] += 0.3 + 0.15 * cls as f32;
        }
    }
    (x, y)
}

/// Drive the real AOT train-step artifact for `steps` steps, feeding the
/// updated parameters back each iteration. Returns the loss curve.
pub fn run_real_training(
    executor: &Executor,
    workload: TfWorkload,
    steps: u32,
    seed: u64,
) -> Result<TrainReport, ExecError> {
    let spec = executor.catalog().get(workload.artifact())?.clone();
    let n_params = spec.inputs.len() - 2; // params…, x, y
    let mut rng = Rng::new(seed);

    let mut params: Vec<Vec<f32>> = spec.inputs[..n_params]
        .iter()
        .map(|sig| init_param(&sig.shape, &mut rng))
        .collect();
    let batch = spec.inputs[n_params].shape[0];

    let mut losses = Vec::with_capacity(steps as usize);
    let mut wall = 0.0;
    let mut flops = 0u64;
    for _ in 0..steps {
        let (x, y) = match workload {
            TfWorkload::Mnist => synthetic_mnist(batch, &mut rng),
            TfWorkload::Cifar10 => synthetic_cifar(batch, &mut rng),
        };
        let mut inputs: Vec<TensorValue> =
            params.iter().map(|p| TensorValue::F32(p.clone())).collect();
        inputs.push(TensorValue::F32(x));
        inputs.push(TensorValue::I32(y));
        let res = executor.execute(workload.artifact(), &inputs)?;
        for (i, p) in params.iter_mut().enumerate() {
            *p = res.outputs[i].as_f32().to_vec();
        }
        losses.push(res.outputs[n_params].as_f32()[0]);
        wall += res.wall.as_secs_f64();
        flops += res.flops;
    }
    Ok(TrainReport {
        workload,
        steps,
        losses,
        wall_secs: wall,
        cpu_gflops: flops as f64 / wall / 1e9,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuModel;

    #[test]
    fn table1_wallclock_reproduced() {
        // paper Table I (seconds): MNIST 613/105/36, CIFAR 23359/8905/6246
        let cases = [
            (TfWorkload::Mnist, GpuModel::quadro_k110m(), 613.0),
            (TfWorkload::Mnist, GpuModel::tesla_k40m(), 105.0),
            (TfWorkload::Mnist, GpuModel::tesla_p100(), 36.0),
            (TfWorkload::Cifar10, GpuModel::quadro_k110m(), 23359.0),
            (TfWorkload::Cifar10, GpuModel::tesla_k40m(), 8905.0),
            (TfWorkload::Cifar10, GpuModel::tesla_p100(), 6246.0),
        ];
        for (wl, board, paper) in cases {
            let got = train_time_secs(wl, &board);
            let err = (got - paper).abs() / paper;
            assert!(
                err < 0.03,
                "{} on {}: {got:.0}s vs paper {paper}",
                wl.name(),
                board.name
            );
        }
    }

    #[test]
    fn ordering_daint_fastest_laptop_slowest() {
        for wl in [TfWorkload::Mnist, TfWorkload::Cifar10] {
            let lap = train_time_secs(wl, &GpuModel::quadro_k110m());
            let k40 = train_time_secs(wl, &GpuModel::tesla_k40m());
            let p100 = train_time_secs(wl, &GpuModel::tesla_p100());
            assert!(p100 < k40 && k40 < lap);
        }
    }

    #[test]
    fn synthetic_batches_are_class_dependent() {
        let mut rng = Rng::new(1);
        let (x, y) = synthetic_mnist(8, &mut rng);
        assert_eq!(x.len(), 8 * 784);
        assert_eq!(y.len(), 8);
        assert!(y.iter().all(|&c| (0..10).contains(&c)));
        // blob energy present
        assert!(x.iter().cloned().fold(f32::MIN, f32::max) > 0.8);
        let (xc, _) = synthetic_cifar(4, &mut rng);
        assert_eq!(xc.len(), 4 * 24 * 24 * 3);
    }
}
