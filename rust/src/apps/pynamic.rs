//! Pynamic benchmark model (§V.C.3, Fig. 3): the Python dynamic-linking
//! stress test, native-on-Lustre vs Shifter-loop-mounted, on Piz Daint.
//!
//! Build parameters from the paper: 495 shared-object test modules, 215
//! math-library-like utility files, ~1850 functions each. Three measured
//! phases: start-up (interpreter + pyMPI launch), import (the DLL storm),
//! visit (calling into every imported module — compute, no filesystem).
//!
//! Mechanism (§V.C.3): natively, every rank's every import hits the Lustre
//! MDS then an OST; with Shifter, each compute node issues ONE metadata
//! request for the squashfs image and every subsequent open/stat resolves
//! against the node-local loop mount.

use crate::hostenv::SystemProfile;
use crate::metrics::{repeat, Stats};
use crate::pfs::{LustreFs, NodeLocalFs};
use crate::util::prng::Rng;

pub const PYNAMIC_MODULES: u32 = 495;
pub const PYNAMIC_UTILS: u32 = 215;
pub const AVG_FUNCS_PER_MODULE: u32 = 1850;
/// Average generated shared-object size (bytes).
pub const AVG_SO_BYTES: u64 = 1_800_000;
/// Python interpreter + stdlib files touched before pyMPI starts.
pub const STARTUP_FILES: u64 = 700;
pub const STARTUP_FILE_BYTES: u64 = 15_000;
/// sys.path probing: stats per import on a parallel FS.
pub const STATS_PER_OPEN: u64 = 4;
/// Wall time to call one generated function (µs) — visit phase.
pub const VISIT_US_PER_FUNC: f64 = 0.8;

/// The job sizes Fig. 3 sweeps.
pub const FIG3_RANKS: [u64; 7] = [48, 96, 192, 384, 768, 1536, 3072];

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Mode {
    Native,
    Shifter,
}

/// Per-phase statistics over the 30-run protocol (Fig. 3 reports mean and
/// stddev as error bars).
#[derive(Debug, Clone)]
pub struct PynamicResult {
    pub ranks: u64,
    pub mode: Mode,
    pub startup: Stats,
    pub import: Stats,
    pub visit: Stats,
}

impl PynamicResult {
    pub fn total_mean(&self) -> f64 {
        self.startup.mean + self.import.mean + self.visit.mean
    }
}

fn phase_model(
    profile: &SystemProfile,
    pfs: &LustreFs,
    ranks: u64,
    mode: Mode,
) -> (f64, f64, f64) {
    let rpn = profile.ranks_per_node() as u64;
    let nodes = ranks.div_ceil(rpn);
    let local = NodeLocalFs::squashfs_loop_mount();
    let total_dlls = (PYNAMIC_MODULES + PYNAMIC_UTILS) as u64;

    let (startup, import) = match mode {
        Mode::Native => {
            let startup = pfs.dll_load_storm_secs(
                ranks,
                rpn,
                STARTUP_FILES,
                STATS_PER_OPEN,
                STARTUP_FILE_BYTES,
            );
            let import = pfs.dll_load_storm_secs(
                ranks,
                rpn,
                total_dlls,
                STATS_PER_OPEN,
                AVG_SO_BYTES,
            );
            (startup, import)
        }
        Mode::Shifter => {
            // one MDS lookup per node + image block fetch, then local I/O
            let image_bytes = (total_dlls * AVG_SO_BYTES
                + STARTUP_FILES * STARTUP_FILE_BYTES)
                as f64
                * crate::vfs::SQUASHFS_RATIO;
            let mount = pfs.mds.storm_secs(nodes, 1)
                + pfs.bulk_read_secs(image_bytes as u64, nodes);
            let startup = mount
                + local.dll_load_secs(
                    STARTUP_FILES,
                    STATS_PER_OPEN,
                    STARTUP_FILE_BYTES,
                );
            let import =
                local.dll_load_secs(total_dlls, STATS_PER_OPEN, AVG_SO_BYTES);
            (startup, import)
        }
    };

    // visit: pure compute, identical in both modes
    let visit = (PYNAMIC_MODULES as f64)
        * (AVG_FUNCS_PER_MODULE as f64)
        * VISIT_US_PER_FUNC
        * 1e-6;
    (startup, import, visit)
}

/// Run the Fig. 3 protocol: 30 repetitions with measurement noise,
/// mean ± std per phase.
pub fn run(profile: &SystemProfile, ranks: u64, mode: Mode) -> PynamicResult {
    let Some(pfs) = profile.pfs.as_ref() else {
        panic!("pynamic needs a profile with a parallel filesystem");
    };
    let (s0, i0, v0) = phase_model(profile, pfs, ranks, mode);
    let tag = match mode {
        Mode::Native => "native",
        Mode::Shifter => "shifter",
    };
    let noisy = |phase: &str, base: f64| {
        repeat(|rep| {
            let mut rng = Rng::from_tags(&[
                "pynamic",
                profile.name,
                tag,
                phase,
                &ranks.to_string(),
                &rep.to_string(),
            ]);
            base * rng.lognormal_noise(0.05)
        })
    };
    PynamicResult {
        ranks,
        mode,
        startup: noisy("startup", s0),
        import: noisy("import", i0),
        visit: noisy("visit", v0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    #[test]
    fn shifter_much_faster_at_scale() {
        let pd = SystemProfile::piz_daint();
        let native = run(&pd, 3072, Mode::Native);
        let shifter = run(&pd, 3072, Mode::Shifter);
        assert!(
            native.total_mean() > 5.0 * shifter.total_mean(),
            "native {:.1}s vs shifter {:.1}s",
            native.total_mean(),
            shifter.total_mean()
        );
    }

    #[test]
    fn native_grows_with_ranks_shifter_nearly_flat() {
        let pd = SystemProfile::piz_daint();
        let n48 = run(&pd, 48, Mode::Native).import.mean;
        let n3072 = run(&pd, 3072, Mode::Native).import.mean;
        assert!(n3072 > 8.0 * n48, "native import {n48} -> {n3072}");
        let s48 = run(&pd, 48, Mode::Shifter).import.mean;
        let s3072 = run(&pd, 3072, Mode::Shifter).import.mean;
        assert!(s3072 < 1.5 * s48, "shifter import {s48} -> {s3072}");
    }

    #[test]
    fn visit_phase_mode_independent() {
        let pd = SystemProfile::piz_daint();
        let native = run(&pd, 768, Mode::Native).visit.mean;
        let shifter = run(&pd, 768, Mode::Shifter).visit.mean;
        assert!((native / shifter - 1.0).abs() < 0.05);
    }

    #[test]
    fn stats_carry_error_bars() {
        let pd = SystemProfile::piz_daint();
        let r = run(&pd, 384, Mode::Native);
        assert_eq!(r.import.n, 30);
        assert!(r.import.std > 0.0);
    }

    #[test]
    fn determinism() {
        let pd = SystemProfile::piz_daint();
        let a = run(&pd, 192, Mode::Shifter);
        let b = run(&pd, 192, Mode::Shifter);
        assert_eq!(a.import.mean, b.import.mean);
    }
}
