//! # shifter-rs — Portable, high-performance containers for HPC
//!
//! A full reproduction of *Benedicic, Cruz, Madonna, Mariotti: "Portable,
//! high-performance containers for HPC" (CSCS, 2017)* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Shifter container runtime with the
//!   paper's native GPU-support (§IV.A) and MPI ABI-swap (§IV.B)
//!   extensions, plus every substrate the evaluation depends on: Docker
//!   images/registry, the Image Gateway, a virtual filesystem with
//!   squashfs loop mounts, a Lustre-like parallel filesystem, InfiniBand
//!   EDR / Cray Aries fabric models, an MPI implementation catalog with
//!   libtool-ABI compatibility, GPU device/driver models, a SLURM-like
//!   workload manager, and the three §V.A host-system profiles.
//! * **Layer 2 (python/compile, build time)** — the containerized
//!   applications' compute graphs in JAX, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the compute hot-spots (all-pairs n-body, tiled matmul, batched
//!   flux operators), interpret-mode so the CPU PJRT client runs them.
//!
//! Python never executes at run time: `rust/src/runtime` loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and the
//! containerized applications execute the identical compiled bits natively
//! and inside Shifter — the paper's performance-portability claim,
//! reproduced end to end. See DESIGN.md and EXPERIMENTS.md.

pub mod apps;
pub mod config;
pub mod distrib;
pub mod docker;
pub mod fabric;
pub mod gateway;
pub mod gpu;
pub mod hostenv;
pub mod image;
pub mod launch;
pub mod metrics;
pub mod mpi;
pub mod pfs;
pub mod registry;
pub mod runtime;
pub mod shifter;
pub mod util;
pub mod vfs;
pub mod wlm;

pub use distrib::DistributionFabric;
pub use gateway::{ImageGateway, ImageSource};
pub use hostenv::SystemProfile;
pub use launch::{JobSpec, LaunchCluster, LaunchReport, LaunchScheduler};
pub use registry::Registry;
pub use shifter::{Container, RunOptions, ShifterRuntime};
