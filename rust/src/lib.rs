//! # shifter-rs — Portable, high-performance containers for HPC
//!
//! A full reproduction of *Benedicic, Cruz, Madonna, Mariotti: "Portable,
//! high-performance containers for HPC" (CSCS, 2017)* as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **Layer 3 (this crate)** — the Shifter container runtime with the
//!   paper's host-resource injections — native GPU support (§IV.A), MPI
//!   ABI-swap (§IV.B), and specialized networking (`netfab`) — behind
//!   one pluggable [`HostExtension`] registry, plus every substrate the
//!   evaluation depends on: Docker
//!   images/registry, the Image Gateway, a virtual filesystem with
//!   squashfs loop mounts, a Lustre-like parallel filesystem, InfiniBand
//!   EDR / Cray Aries fabric models, an MPI implementation catalog with
//!   libtool-ABI compatibility, GPU device/driver models, a SLURM-like
//!   workload manager, and the three §V.A host-system profiles.
//! * **Layer 2 (python/compile, build time)** — the containerized
//!   applications' compute graphs in JAX, AOT-lowered to HLO text.
//! * **Layer 1 (python/compile/kernels, build time)** — Pallas kernels for
//!   the compute hot-spots (all-pairs n-body, tiled matmul, batched
//!   flux operators), interpret-mode so the CPU PJRT client runs them.
//!
//! Python never executes at run time: `rust/src/runtime` loads
//! `artifacts/*.hlo.txt` through the PJRT C API (`xla` crate) and the
//! containerized applications execute the identical compiled bits natively
//! and inside Shifter — the paper's performance-portability claim,
//! reproduced end to end.
//!
//! The typed entry point over the whole stack is the [`Site`] facade
//! (`site::`): a [`SiteBuilder`] validates the operator's knobs once and
//! returns a handle with `pull` / `run` / `launch` / `storm` operations,
//! so user workflows never hand-wire the layers; the [`Federation`]
//! facade (`federation::`) composes many such sites behind cross-site
//! replication, capability routing, and burst overflow. Repo-level
//! docs: `README.md` (orientation and quickstart), `DESIGN.md`
//! (S1–S27 architecture), `EXPERIMENTS.md` (bench → paper-table
//! matrix, knobs, artifacts).

// The rustdoc pass proceeds module by module: `launch`, `distrib`,
// `gateway`, `tenancy`, `site`, `shifter`, `telemetry` and `config` are
// fully documented and enforced; the substrate modules below opt out
// until their own pass lands.
#![warn(missing_docs)]

#[allow(missing_docs)]
pub mod apps;
pub mod config;
pub mod distrib;
#[allow(missing_docs)]
pub mod docker;
#[allow(missing_docs)]
pub mod fabric;
pub mod federation;
pub mod gateway;
#[allow(missing_docs)]
pub mod gpu;
#[allow(missing_docs)]
pub mod hostenv;
#[allow(missing_docs)]
pub mod image;
pub mod launch;
#[allow(missing_docs)]
pub mod metrics;
#[allow(missing_docs)]
pub mod mpi;
pub mod netfab;
#[allow(missing_docs)]
pub mod pfs;
#[allow(missing_docs)]
pub mod registry;
#[allow(missing_docs)]
pub mod runtime;
pub mod shifter;
pub mod sim;
pub mod site;
pub mod telemetry;
pub mod tenancy;
#[allow(missing_docs)]
pub mod util;
#[allow(missing_docs)]
pub mod vfs;
#[allow(missing_docs)]
pub mod wlm;

pub use config::UdiRootConfig;
pub use distrib::DistributionFabric;
pub use federation::{
    Federation, FederationBuilder, FederationError, FederationReport,
    FederationStorm, RoutingPolicy,
};
pub use gateway::{ImageGateway, ImageSource};
pub use hostenv::SystemProfile;
pub use launch::{JobSpec, LaunchCluster, LaunchReport, LaunchScheduler};
pub use netfab::NetworkSupport;
pub use registry::Registry;
pub use shifter::{
    Capability, Container, ExtensionRegistry, HostExtension, RunOptions,
    ShifterRuntime,
};
pub use sim::{SimClock, SimKernel, SimTime};
pub use site::{PullOutcome, Site, SiteBuilder, SiteError, StormSpec};
pub use telemetry::{Telemetry, TraceCtx};
pub use tenancy::{
    FairShareScheduler, SchedulingPolicy, TenancyReport, TrafficModel,
};
