//! PJRT artifact runtime (DESIGN.md S13): catalog of AOT-compiled HLO
//! artifacts + the executor that runs them on the CPU PJRT client.
//! Python never runs here — `make artifacts` produced the HLO once.

pub mod artifact;
pub mod executor;
pub mod xla_shim;

pub use artifact::{ArtifactCatalog, ArtifactError, ArtifactSpec, Dtype, TensorSig};
pub use executor::{ExecError, ExecResult, Executor, TensorValue};

/// Default artifact directory relative to the repo root.
pub fn default_artifact_dir() -> std::path::PathBuf {
    std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}
