//! Offline stand-in for the `xla` PJRT bindings.
//!
//! The original executor linked the `xla` crate (xla_extension 0.5.1, a C
//! library with its own PJRT CPU client). That toolchain is not part of the
//! offline vendor set, so this module mirrors the small API surface
//! `runtime::executor` uses. Construction, artifact loading and input
//! staging all work (so the catalog/validation layers are fully exercised);
//! `PjRtClient::compile` reports that the native backend is unavailable.
//! Swapping this module back for the real crate is a one-line change in
//! `executor.rs` — the call sites are identical by design. See DESIGN.md
//! S13.

use std::fmt;

/// Error type mirroring `xla::Error` (the executor only ever formats it).
#[derive(Debug, Clone)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

const UNAVAILABLE: &str = "PJRT backend unavailable: built against the \
     offline xla shim (xla_extension not in the vendor set)";

/// Marker for element types a literal's raw bytes may be reinterpreted as.
/// Restricting `Literal::to_vec` to these keeps the byte transmute sound:
/// every bit pattern is a valid value for each of them (unlike e.g. `bool`
/// or reference types, which would make the cast undefined behavior).
pub trait PlainScalar: Copy {}
impl PlainScalar for f32 {}
impl PlainScalar for f64 {}
impl PlainScalar for i32 {}
impl PlainScalar for i64 {}
impl PlainScalar for u8 {}

/// Element types the artifacts use.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    F32,
    F64,
    S32,
}

/// A host-side literal: typed, shaped bytes.
#[derive(Debug, Clone)]
pub struct Literal {
    ty: ElementType,
    shape: Vec<usize>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        shape: &[usize],
        bytes: &[u8],
    ) -> Result<Literal, Error> {
        Ok(Literal {
            ty,
            shape: shape.to_vec(),
            bytes: bytes.to_vec(),
        })
    }

    pub fn element_type(&self) -> ElementType {
        self.ty
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }

    /// Reinterpret the raw bytes as a typed vector.
    pub fn to_vec<T: PlainScalar>(&self) -> Result<Vec<T>, Error> {
        let sz = std::mem::size_of::<T>();
        if sz == 0 || self.bytes.len() % sz != 0 {
            return Err(Error(format!(
                "literal of {} bytes does not reinterpret as {}-byte elements",
                self.bytes.len(),
                sz
            )));
        }
        let n = self.bytes.len() / sz;
        let mut out: Vec<T> = Vec::with_capacity(n);
        // copy_nonoverlapping handles the (possibly unaligned) byte buffer
        unsafe {
            std::ptr::copy_nonoverlapping(
                self.bytes.as_ptr(),
                out.as_mut_ptr() as *mut u8,
                self.bytes.len(),
            );
            out.set_len(n);
        }
        Ok(out)
    }
}

/// Parsed HLO module (text form only — protos are never serialized here).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    text: String,
}

impl HloModuleProto {
    pub fn from_text_file(
        path: impl AsRef<std::path::Path>,
    ) -> Result<HloModuleProto, Error> {
        let text = std::fs::read_to_string(path.as_ref())
            .map_err(|e| Error(format!("{}: {e}", path.as_ref().display())))?;
        Ok(HloModuleProto { text })
    }

    pub fn text(&self) -> &str {
        &self.text
    }
}

/// An HLO computation handle.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            _text: proto.text.clone(),
        }
    }
}

/// PJRT client handle. `cpu()` succeeds so catalogs load and inputs
/// validate; only `compile` requires the native backend.
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient, Error> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "cpu-shim".to_string()
    }

    pub fn compile(
        &self,
        _computation: &XlaComputation,
    ) -> Result<PjRtLoadedExecutable, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// A compiled executable (unreachable through the shim's `compile`).
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

/// A device buffer returned by `execute`.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal, Error> {
        Err(Error(UNAVAILABLE.to_string()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_bytes() {
        let data: Vec<f32> = vec![1.0, 2.5, -3.0, 4.0];
        let bytes: Vec<u8> =
            data.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[2, 2],
            &bytes,
        )
        .unwrap();
        assert_eq!(lit.shape(), &[2, 2]);
        assert_eq!(lit.element_type(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), data);
    }

    #[test]
    fn misaligned_reinterpret_rejected() {
        let lit = Literal::create_from_shape_and_untyped_data(
            ElementType::F32,
            &[3],
            &[0u8; 3],
        )
        .unwrap();
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn client_constructs_but_compile_reports_shim() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "cpu-shim");
        let comp = XlaComputation::from_proto(&HloModuleProto {
            text: "HloModule m".to_string(),
        });
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("shim"));
    }
}
