//! PJRT executor (DESIGN.md S13): loads AOT HLO-text artifacts and runs
//! them on the CPU PJRT client via the `xla` crate.
//!
//! This is the "same bits" guarantee of the reproduction: native runs and
//! containerized runs execute the *identical* compiled executable — any
//! performance delta is runtime overhead, which is what the paper measures.
//!
//! Pattern from /opt/xla-example/load_hlo: HLO text (never serialized
//! protos — xla_extension 0.5.1 rejects jax≥0.5's 64-bit ids) →
//! `HloModuleProto::from_text_file` → compile → execute, outputs are a
//! 1-tuple (return_tuple=True at lowering) decomposed with `to_tuple`.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::Path;
use std::time::Duration;

use crate::sim::{SimClock, SimTime};

use super::artifact::{ArtifactCatalog, ArtifactError, ArtifactSpec, Dtype};
// Offline builds resolve the `xla` API against the in-crate shim; restoring
// the real bindings is a matter of deleting this alias and re-adding the
// `xla` dependency (the call sites are API-identical).
use super::xla_shim as xla;

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum ExecError {
    #[error(transparent)]
    Artifact(#[from] ArtifactError),
    #[error("xla error: {0}")]
    Xla(String),
    #[error("artifact {0}: expected {1} inputs, got {2}")]
    Arity(String, usize, usize),
    #[error("artifact {artifact}: input {index} dtype mismatch")]
    DtypeMismatch { artifact: String, index: usize },
}

impl From<xla::Error> for ExecError {
    fn from(e: xla::Error) -> Self {
        ExecError::Xla(e.to_string())
    }
}

/// A host-side tensor to feed an artifact.
#[derive(Debug, Clone)]
pub enum TensorValue {
    F32(Vec<f32>),
    F64(Vec<f64>),
    I32(Vec<i32>),
}

impl TensorValue {
    pub fn len(&self) -> usize {
        match self {
            TensorValue::F32(v) => v.len(),
            TensorValue::F64(v) => v.len(),
            TensorValue::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn dtype(&self) -> Dtype {
        match self {
            TensorValue::F32(_) => Dtype::F32,
            TensorValue::F64(_) => Dtype::F64,
            TensorValue::I32(_) => Dtype::S32,
        }
    }

    fn to_literal(&self, shape: &[usize]) -> Result<xla::Literal, ExecError> {
        // §Perf L3-1: single-copy literal creation. The obvious
        // `Literal::vec1(v).reshape(&dims)` copies the host buffer twice
        // (once into the rank-1 literal, once in reshape); building from
        // untyped bytes with the final shape copies exactly once.
        fn as_bytes<T>(v: &[T]) -> &[u8] {
            unsafe {
                std::slice::from_raw_parts(
                    v.as_ptr() as *const u8,
                    std::mem::size_of_val(v),
                )
            }
        }
        let (ty, bytes) = match self {
            TensorValue::F32(v) => (xla::ElementType::F32, as_bytes(v)),
            TensorValue::F64(v) => (xla::ElementType::F64, as_bytes(v)),
            TensorValue::I32(v) => (xla::ElementType::S32, as_bytes(v)),
        };
        Ok(xla::Literal::create_from_shape_and_untyped_data(
            ty, shape, bytes,
        )?)
    }

    pub fn as_f32(&self) -> &[f32] {
        match self {
            TensorValue::F32(v) => v,
            _ => panic!("not f32"),
        }
    }

    pub fn as_f64(&self) -> &[f64] {
        match self {
            TensorValue::F64(v) => v,
            _ => panic!("not f64"),
        }
    }
}

/// One artifact execution's result: decomposed outputs + the *virtual*
/// wall time charged by the S24 cost model (see [`exec_cost_secs`]).
#[derive(Debug)]
pub struct ExecResult {
    pub outputs: Vec<TensorValue>,
    pub wall: Duration,
    pub flops: u64,
}

impl ExecResult {
    /// Modeled GFLOP/s of this execution (flops over virtual wall time).
    pub fn achieved_gflops(&self) -> f64 {
        self.flops as f64 / self.wall.as_secs_f64() / 1e9
    }
}

/// Nominal single-core CPU throughput the cost model charges against.
const NOMINAL_CPU_GFLOPS: f64 = 40.0;

/// Fixed per-dispatch overhead (argument marshalling, PJRT launch).
const EXEC_DISPATCH_SECS: f64 = 25e-6;

/// Virtual seconds one execution of an artifact with `flops` FLOPs costs.
///
/// A pure function of the artifact spec, so executor timing is identical
/// across runs, hosts and thread counts — the byte-exact report guarantee
/// (DESIGN.md S24) extends through the execute path. The dispatch floor
/// keeps the cost strictly positive even for zero-FLOP artifacts.
pub fn exec_cost_secs(flops: u64) -> f64 {
    EXEC_DISPATCH_SECS + flops as f64 / (NOMINAL_CPU_GFLOPS * 1e9)
}

/// The executor: a PJRT CPU client + compile cache over the catalog.
///
/// Timing is virtual: executions advance an internal [`SimClock`] by the
/// [`exec_cost_secs`] cost model instead of reading host clocks, so a
/// sequence of executions yields a deterministic timeline.
pub struct Executor {
    client: xla::PjRtClient,
    catalog: ArtifactCatalog,
    compiled: RefCell<BTreeMap<String, xla::PjRtLoadedExecutable>>,
    clock: RefCell<SimClock>,
}

impl Executor {
    pub fn new(artifact_dir: impl AsRef<Path>) -> Result<Executor, ExecError> {
        let catalog = ArtifactCatalog::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Executor {
            client,
            catalog,
            compiled: RefCell::new(BTreeMap::new()),
            clock: RefCell::new(SimClock::new()),
        })
    }

    /// The executor's virtual clock: total modeled execution time so far.
    pub fn virtual_now(&self) -> SimTime {
        self.clock.borrow().now()
    }

    pub fn catalog(&self) -> &ArtifactCatalog {
        &self.catalog
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn ensure_compiled(&self, name: &str) -> Result<(), ExecError> {
        if self.compiled.borrow().contains_key(name) {
            return Ok(());
        }
        let spec = self.catalog.get(name)?;
        let path = spec.hlo_path.to_string_lossy().to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        self.compiled.borrow_mut().insert(name.to_string(), exe);
        Ok(())
    }

    fn validate(
        &self,
        spec: &ArtifactSpec,
        inputs: &[TensorValue],
    ) -> Result<(), ExecError> {
        if inputs.len() != spec.inputs.len() {
            return Err(ExecError::Arity(
                spec.name.clone(),
                spec.inputs.len(),
                inputs.len(),
            ));
        }
        for (i, (val, sig)) in inputs.iter().zip(&spec.inputs).enumerate() {
            if val.dtype() != sig.dtype {
                return Err(ExecError::DtypeMismatch {
                    artifact: spec.name.clone(),
                    index: i,
                });
            }
            if val.len() != sig.element_count() {
                return Err(ArtifactError::ShapeMismatch {
                    artifact: spec.name.clone(),
                    index: i,
                    name: sig.name.clone(),
                    expected: sig.element_count(),
                    got: val.len(),
                }
                .into());
            }
        }
        Ok(())
    }

    /// Execute an artifact with validated inputs; returns decomposed
    /// outputs plus the virtual wall time charged by [`exec_cost_secs`]
    /// (the executor clock advances by the same amount).
    pub fn execute(
        &self,
        name: &str,
        inputs: &[TensorValue],
    ) -> Result<ExecResult, ExecError> {
        let spec = self.catalog.get(name)?.clone();
        self.validate(&spec, inputs)?;
        self.ensure_compiled(name)?;

        let literals: Vec<xla::Literal> = inputs
            .iter()
            .zip(&spec.inputs)
            .map(|(v, sig)| v.to_literal(&sig.shape))
            .collect::<Result<_, _>>()?;

        let compiled = self.compiled.borrow();
        let Some(exe) = compiled.get(name) else {
            return Err(ExecError::Xla(format!(
                "artifact {name} vanished from the compile cache"
            )));
        };
        let result = exe.execute::<xla::Literal>(&literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let wall_secs = exec_cost_secs(spec.flops_per_call);
        self.clock.borrow_mut().advance(wall_secs);
        let wall = Duration::from_secs_f64(wall_secs);
        drop(compiled);

        let parts = tuple.to_tuple()?;
        let mut outputs = Vec::with_capacity(parts.len());
        for (lit, sig) in parts.into_iter().zip(&spec.outputs) {
            let v = match sig.dtype {
                Dtype::F32 => TensorValue::F32(lit.to_vec::<f32>()?),
                Dtype::F64 => TensorValue::F64(lit.to_vec::<f64>()?),
                Dtype::S32 | Dtype::S64 => TensorValue::I32(lit.to_vec::<i32>()?),
            };
            outputs.push(v);
        }
        Ok(ExecResult {
            outputs,
            wall,
            flops: spec.flops_per_call,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifact_dir() -> Option<PathBuf> {
        let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        dir.join("manifest.json").exists().then_some(dir)
    }

    #[test]
    fn exec_cost_is_positive_deterministic_and_monotonic() {
        // The dispatch floor keeps even zero-FLOP artifacts strictly
        // positive, so `ExecResult::wall` never divides by zero.
        assert!(exec_cost_secs(0) > 0.0);
        assert_eq!(exec_cost_secs(1 << 20), exec_cost_secs(1 << 20));
        assert!(exec_cost_secs(1 << 30) > exec_cost_secs(1 << 20));
        // A 40-GFLOP artifact models about a second of execution.
        let one_sec = exec_cost_secs(40_000_000_000);
        assert!((one_sec - 1.0).abs() < 0.01, "got {one_sec}");
    }

    #[test]
    fn pyfr_step_executes_and_zero_dt_is_identity() {
        let Some(dir) = artifact_dir() else { return };
        let ex = Executor::new(dir).unwrap();
        let spec = ex.catalog().get("pyfr_step").unwrap();
        let n_u = spec.inputs[0].element_count();
        let n_op = spec.inputs[1].element_count();
        let u: Vec<f32> = (0..n_u).map(|i| (i % 17) as f32 * 0.1).collect();
        let op: Vec<f32> = (0..n_op).map(|i| (i % 5) as f32 * 0.01).collect();
        let res = ex
            .execute(
                "pyfr_step",
                &[
                    TensorValue::F32(u.clone()),
                    TensorValue::F32(op),
                    TensorValue::F32(vec![0.0]),
                ],
            )
            .unwrap();
        assert_eq!(res.outputs.len(), 2);
        assert_eq!(res.outputs[0].as_f32(), &u[..]); // dt=0 identity
        assert!(res.wall.as_secs_f64() > 0.0);
    }

    #[test]
    fn arity_and_dtype_validation() {
        let Some(dir) = artifact_dir() else { return };
        let ex = Executor::new(dir).unwrap();
        let err = ex.execute("pyfr_step", &[]).unwrap_err();
        assert!(matches!(err, ExecError::Arity(..)));
        let spec = ex.catalog().get("pyfr_step").unwrap();
        let bad = vec![
            TensorValue::F64(vec![0.0; spec.inputs[0].element_count()]),
            TensorValue::F32(vec![0.0; spec.inputs[1].element_count()]),
            TensorValue::F32(vec![0.0]),
        ];
        let err = ex.execute("pyfr_step", &bad).unwrap_err();
        assert!(matches!(err, ExecError::DtypeMismatch { .. }));
    }

    #[test]
    fn nbody_step_conserves_mass_column() {
        let Some(dir) = artifact_dir() else { return };
        let ex = Executor::new(dir).unwrap();
        let spec = ex.catalog().get("nbody_step").unwrap();
        let n = spec.inputs[0].shape[0];
        let mut pos4 = vec![0.0f64; n * 4];
        for i in 0..n {
            pos4[i * 4] = (i as f64 * 0.37).sin() * 10.0;
            pos4[i * 4 + 1] = (i as f64 * 0.73).cos() * 10.0;
            pos4[i * 4 + 2] = (i as f64 * 1.31).sin() * 10.0;
            pos4[i * 4 + 3] = 1.0 + (i % 3) as f64 * 0.25;
        }
        let vel = vec![0.0f64; n * 3];
        let res = ex
            .execute(
                "nbody_step",
                &[
                    TensorValue::F64(pos4.clone()),
                    TensorValue::F64(vel),
                    TensorValue::F64(vec![1e-3]),
                ],
            )
            .unwrap();
        let new_pos4 = res.outputs[0].as_f64();
        for i in 0..n {
            assert_eq!(new_pos4[i * 4 + 3], pos4[i * 4 + 3], "mass {i}");
        }
        assert!(res.achieved_gflops() > 0.0);
    }
}
