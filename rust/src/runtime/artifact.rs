//! AOT artifact catalog: parses `artifacts/manifest.json` (emitted by
//! `python/compile/aot.py`) into typed signatures the executor validates
//! inputs against, and the FLOP counts the device performance model uses.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    F64,
    S32,
    S64,
}

impl Dtype {
    pub fn parse(s: &str) -> Option<Dtype> {
        match s {
            "f32" => Some(Dtype::F32),
            "f64" => Some(Dtype::F64),
            "s32" => Some(Dtype::S32),
            "s64" => Some(Dtype::S64),
            _ => None,
        }
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::S32 => 4,
            Dtype::F64 | Dtype::S64 => 8,
        }
    }
}

#[derive(Debug, Clone, PartialEq)]
pub struct TensorSig {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
}

impl TensorSig {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub name: String,
    pub hlo_path: PathBuf,
    pub inputs: Vec<TensorSig>,
    pub outputs: Vec<TensorSig>,
    pub flops_per_call: u64,
}

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum ArtifactError {
    #[error("cannot read {0}: {1}")]
    Io(PathBuf, std::io::Error),
    #[error("manifest parse error: {0}")]
    Parse(String),
    #[error("artifact not in catalog: {0}")]
    Unknown(String),
    #[error("artifact {artifact}: input {index} ({name}) expects {expected} elements, got {got}")]
    ShapeMismatch {
        artifact: String,
        index: usize,
        name: String,
        expected: usize,
        got: usize,
    },
}

#[derive(Debug, Default)]
pub struct ArtifactCatalog {
    artifacts: BTreeMap<String, ArtifactSpec>,
}

fn parse_sig(j: &Json) -> Result<TensorSig, ArtifactError> {
    let name = j
        .get("name")
        .and_then(Json::as_str)
        .ok_or_else(|| ArtifactError::Parse("sig missing name".into()))?;
    let dtype = j
        .get("dtype")
        .and_then(Json::as_str)
        .and_then(Dtype::parse)
        .ok_or_else(|| ArtifactError::Parse(format!("bad dtype for {name}")))?;
    let shape = j
        .get("shape")
        .and_then(Json::as_arr)
        .ok_or_else(|| ArtifactError::Parse(format!("bad shape for {name}")))?
        .iter()
        .map(|v| v.as_u64().map(|u| u as usize))
        .collect::<Option<Vec<_>>>()
        .ok_or_else(|| ArtifactError::Parse(format!("bad dims for {name}")))?;
    Ok(TensorSig {
        name: name.to_string(),
        shape,
        dtype,
    })
}

impl ArtifactCatalog {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<ArtifactCatalog, ArtifactError> {
        let dir = dir.as_ref();
        let mpath = dir.join("manifest.json");
        let text = std::fs::read_to_string(&mpath)
            .map_err(|e| ArtifactError::Io(mpath.clone(), e))?;
        Self::from_manifest_json(&text, dir)
    }

    pub fn from_manifest_json(
        text: &str,
        dir: &Path,
    ) -> Result<ArtifactCatalog, ArtifactError> {
        let j = Json::parse(text).map_err(|e| ArtifactError::Parse(e.to_string()))?;
        let arts = j
            .get("artifacts")
            .and_then(Json::as_obj)
            .ok_or_else(|| ArtifactError::Parse("no artifacts key".into()))?;
        let mut catalog = ArtifactCatalog::default();
        for (name, entry) in arts {
            let file = entry
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| ArtifactError::Parse(format!("{name}: no file")))?;
            let inputs = entry
                .get("inputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| ArtifactError::Parse(format!("{name}: no inputs")))?
                .iter()
                .map(parse_sig)
                .collect::<Result<Vec<_>, _>>()?;
            let outputs = entry
                .get("outputs")
                .and_then(Json::as_arr)
                .ok_or_else(|| ArtifactError::Parse(format!("{name}: no outputs")))?
                .iter()
                .map(parse_sig)
                .collect::<Result<Vec<_>, _>>()?;
            let flops = entry
                .get("flops_per_call")
                .and_then(Json::as_u64)
                .unwrap_or(0);
            catalog.artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    name: name.clone(),
                    hlo_path: dir.join(file),
                    inputs,
                    outputs,
                    flops_per_call: flops,
                },
            );
        }
        Ok(catalog)
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactSpec, ArtifactError> {
        self.artifacts
            .get(name)
            .ok_or_else(|| ArtifactError::Unknown(name.to_string()))
    }

    pub fn names(&self) -> Vec<&str> {
        self.artifacts.keys().map(|s| s.as_str()).collect()
    }

    pub fn len(&self) -> usize {
        self.artifacts.len()
    }

    pub fn is_empty(&self) -> bool {
        self.artifacts.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "generator": "shifter-rs-aot-1",
      "artifacts": {
        "pyfr_step": {
          "file": "pyfr_step.hlo.txt",
          "inputs": [
            {"name": "u", "shape": [2048, 8, 4], "dtype": "f32"},
            {"name": "op_div", "shape": [8, 8], "dtype": "f32"},
            {"name": "dt", "shape": [], "dtype": "f32"}
          ],
          "outputs": [
            {"name": "u", "shape": [2048, 8, 4], "dtype": "f32"},
            {"name": "residual", "shape": [], "dtype": "f32"}
          ],
          "flops_per_call": 1310720
        }
      }
    }"#;

    #[test]
    fn parses_sample_manifest() {
        let c =
            ArtifactCatalog::from_manifest_json(SAMPLE, Path::new("/tmp/a"))
                .unwrap();
        assert_eq!(c.len(), 1);
        let spec = c.get("pyfr_step").unwrap();
        assert_eq!(spec.inputs.len(), 3);
        assert_eq!(spec.inputs[0].element_count(), 2048 * 8 * 4);
        assert_eq!(spec.inputs[2].shape.len(), 0); // scalar
        assert_eq!(spec.outputs[1].name, "residual");
        assert_eq!(spec.flops_per_call, 1_310_720);
        assert_eq!(spec.hlo_path, Path::new("/tmp/a/pyfr_step.hlo.txt"));
    }

    #[test]
    fn unknown_artifact_rejected() {
        let c =
            ArtifactCatalog::from_manifest_json(SAMPLE, Path::new("/tmp/a"))
                .unwrap();
        assert!(matches!(c.get("nope"), Err(ArtifactError::Unknown(_))));
    }

    #[test]
    fn dtype_parsing() {
        assert_eq!(Dtype::parse("f32"), Some(Dtype::F32));
        assert_eq!(Dtype::parse("f64"), Some(Dtype::F64));
        assert_eq!(Dtype::parse("s32"), Some(Dtype::S32));
        assert_eq!(Dtype::parse("bf16"), None);
        assert_eq!(Dtype::F64.size_bytes(), 8);
    }

    #[test]
    fn real_checked_in_manifest_loads_if_present() {
        let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        if dir.join("manifest.json").exists() {
            let c = ArtifactCatalog::load(&dir).unwrap();
            for name in
                ["mnist_train", "cifar_train", "nbody_step", "pyfr_step"]
            {
                let spec = c.get(name).unwrap();
                assert!(spec.hlo_path.exists(), "{name} hlo missing");
                assert!(spec.flops_per_call > 0);
            }
        }
    }
}
