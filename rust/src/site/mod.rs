//! The `Site` facade (DESIGN.md S21): one typed entry point over the
//! whole stack.
//!
//! The paper's deployment model is that a *site operator* configures
//! Shifter once — `udiRoot.conf`, the host profiles of §V.A, the Image
//! Gateway — and every user workflow (`shifterimg pull`, `shifter
//! --image`, an `srun`-wide batch launch) goes through that one
//! configured surface. This module is that surface for the simulation:
//! a declarative [`SiteBuilder`] validates the operator's knobs once and
//! wires profile → [`crate::distrib::DistributionFabric`] →
//! [`crate::launch::LaunchCluster`] → [`crate::ShifterRuntime`] /
//! [`crate::tenancy::FairShareScheduler`], returning a [`Site`] handle
//! whose typed operations replace the hand-wiring every caller used to
//! repeat:
//!
//! * [`Site::pull`] — synchronous image pull through the sharded fabric
//!   (plus [`Site::request`] / [`Site::tick`] / [`Site::pull_status`]
//!   for the asynchronous gateway-daemon lifecycle);
//! * [`Site::run`] — one container on one node, §III.B style;
//! * [`Site::launch`] / [`Site::launch_on`] — a cluster-scale job
//!   through the launch orchestrator;
//! * [`Site::run_storm`] — a multi-tenant job storm described by one
//!   typed [`StormSpec`] (traffic knobs, policy override, explicit job
//!   stream, optional Chrome-trace artifact) under the site's
//!   (pluggable) [`SchedulingPolicy`].
//!
//! Every operation reports through the single [`SiteError`] enum, whose
//! `std::error::Error::source()` chain preserves the layer-level cause.
//! All timing flows from the virtual-time kernel (`crate::sim`,
//! DESIGN.md S24): blocking pulls drain the gateway shards event by
//! event, and storms replay on a deterministic event queue.

mod builder;
mod error;

pub use builder::{SiteBuilder, MIN_NODE_CACHE_BYTES};
pub use error::SiteError;

use std::path::PathBuf;
use std::sync::Arc;

use crate::config::UdiRootConfig;
use crate::distrib::DistributionFabric;
use crate::gateway::{PullJob, PullState};
use crate::launch::{
    JobSpec, LaunchCluster, LaunchReport, LaunchScheduler, RetryPolicy,
};
use crate::registry::Registry;
use crate::shifter::{
    Capability, Container, ExtensionRegistry, RunOptions, ShifterRuntime,
};
use crate::sim::SimTime;
use crate::telemetry::{SpanDraft, Telemetry};
use crate::tenancy::{
    FairShareScheduler, SchedulingPolicy, TenancyReport, TenantJob,
    TrafficModel,
};

/// What [`Site::pull`] reports back: the terminal gateway-job timings of
/// a successful pull, shaped like the classic `shifterimg pull` output.
#[derive(Debug, Clone, PartialEq)]
pub struct PullOutcome {
    /// Canonical reference that was pulled.
    pub reference: String,
    /// PFS path of the materialized squashfs.
    pub pfs_path: String,
    /// Enqueue → worker-pickup wait on the owning shard.
    pub queue_wait_secs: f64,
    /// Enqueue → READY end-to-end latency.
    pub turnaround_secs: f64,
    /// Registry download time.
    pub download_secs: f64,
    /// Tar expansion + flatten time.
    pub expand_secs: f64,
    /// mksquashfs conversion time.
    pub convert_secs: f64,
    /// PFS store time.
    pub store_secs: f64,
    /// Users/nodes whose requests coalesced onto this pull job so far.
    pub requesters: usize,
}

/// A typed description of one multi-tenant storm, consumed by
/// [`Site::run_storm`].
///
/// Every storm knob lives here, and every knob left unset inherits
/// the site's shape — `max_width` defaults to half the cluster, `seed`
/// to the site's seed, the policy to the site's configured
/// [`SchedulingPolicy`].
///
/// ```
/// use shifter_rs::{Site, StormSpec};
///
/// let mut site = Site::builder().nodes(8).build().unwrap();
/// let report = site
///     .run_storm(&StormSpec::new().tenants(4).jobs(32).seed(7))
///     .unwrap();
/// assert_eq!(report.records.len(), 32);
/// ```
#[derive(Default)]
pub struct StormSpec {
    /// Full base-model override; unset knobs below fall back to it (or
    /// to the site-shaped default when it is `None`).
    traffic: Option<TrafficModel>,
    tenants: Option<u32>,
    jobs: Option<u32>,
    arrival_rate_per_min: Option<f64>,
    duration_secs: Option<f64>,
    mean_runtime_secs: Option<f64>,
    max_width: Option<u32>,
    seed: Option<u64>,
    stream: Option<Vec<TenantJob>>,
    policy: Option<Box<dyn SchedulingPolicy>>,
    trace_path: Option<PathBuf>,
}

impl StormSpec {
    /// An empty spec: synthesize the site's default traffic under the
    /// site's policy, no trace artifact.
    pub fn new() -> StormSpec {
        StormSpec::default()
    }

    /// Number of competing tenants to synthesize.
    pub fn tenants(mut self, tenants: u32) -> StormSpec {
        self.tenants = Some(tenants);
        self
    }

    /// Number of jobs in the synthesized stream.
    pub fn jobs(mut self, jobs: u32) -> StormSpec {
        self.jobs = Some(jobs);
        self
    }

    /// Mean Poisson arrival rate, jobs per simulated minute.
    pub fn arrival_rate_per_min(mut self, rate: f64) -> StormSpec {
        self.arrival_rate_per_min = Some(rate);
        self
    }

    /// Stop synthesizing arrivals past this horizon (seconds;
    /// `f64::INFINITY` disables the cap).
    pub fn duration_secs(mut self, secs: f64) -> StormSpec {
        self.duration_secs = Some(secs);
        self
    }

    /// Mean application runtime (log-normal median), seconds.
    pub fn mean_runtime_secs(mut self, secs: f64) -> StormSpec {
        self.mean_runtime_secs = Some(secs);
        self
    }

    /// Widest job width to synthesize, in nodes. Defaults to half the
    /// site's cluster (at least one node).
    pub fn max_width(mut self, width: u32) -> StormSpec {
        self.max_width = Some(width);
        self
    }

    /// Deterministic seed for the synthesized stream. Defaults to the
    /// site's seed.
    pub fn seed(mut self, seed: u64) -> StormSpec {
        self.seed = Some(seed);
        self
    }

    /// Replace the whole base [`TrafficModel`] (skew exponents, class
    /// weights, runtime spread, …). Knob setters above still override
    /// individual fields on top of it.
    pub fn traffic(mut self, traffic: TrafficModel) -> StormSpec {
        self.traffic = Some(traffic);
        self
    }

    /// Schedule this explicit pre-generated job stream instead of
    /// synthesizing one — the form benches use to replay the *same*
    /// stream under two policies. Synthesis knobs are ignored.
    pub fn job_stream(mut self, jobs: Vec<TenantJob>) -> StormSpec {
        self.stream = Some(jobs);
        self
    }

    /// Run under this policy instead of the site's configured one.
    pub fn policy(
        mut self,
        policy: impl SchedulingPolicy + 'static,
    ) -> StormSpec {
        self.policy = Some(Box::new(policy));
        self
    }

    /// After the storm, export the site's telemetry as a Chrome
    /// trace-event JSONL file at this path (requires the site to be
    /// built with [`SiteBuilder::telemetry`] for the trace to be
    /// non-empty).
    pub fn trace_path(
        mut self,
        path: impl Into<PathBuf>,
    ) -> StormSpec {
        self.trace_path = Some(path.into());
        self
    }

    /// Resolve the synthesis model this spec describes for `site`:
    /// explicit base model (or the site-shaped default), then the
    /// individual knob overrides.
    fn resolve_traffic(&self, site: &Site) -> TrafficModel {
        let mut t = self
            .traffic
            .clone()
            .unwrap_or_else(|| site.site_traffic());
        if let Some(tenants) = self.tenants {
            t.tenants = tenants;
        }
        if let Some(jobs) = self.jobs {
            t.jobs = jobs;
        }
        if let Some(rate) = self.arrival_rate_per_min {
            t.arrival_rate_per_min = rate;
        }
        if let Some(secs) = self.duration_secs {
            t.duration_secs = secs;
        }
        if let Some(secs) = self.mean_runtime_secs {
            t.mean_runtime_secs = secs;
        }
        if let Some(width) = self.max_width {
            t.max_width = width;
        }
        if let Some(seed) = self.seed {
            t.seed = seed;
        }
        t
    }
}

/// A fully wired, validated site — the one handle user workflows need.
///
/// Built exclusively through [`Site::builder`]; see [`SiteBuilder`] for
/// the knobs and a runnable end-to-end example.
pub struct Site {
    pub(crate) cluster: LaunchCluster,
    pub(crate) registry: Registry,
    pub(crate) fabric: DistributionFabric,
    /// One runtime per partition, index-aligned with
    /// `cluster.partitions()` — [`Site::run`] dispatches on the
    /// partition owning the requested node.
    pub(crate) runtimes: Vec<ShifterRuntime>,
    pub(crate) config_override: Option<UdiRootConfig>,
    /// `None` keeps the historical per-layer defaults: launches retry
    /// with `RetryPolicy::default()`, storms run strict.
    pub(crate) retry: Option<RetryPolicy>,
    pub(crate) policy: Box<dyn SchedulingPolicy>,
    pub(crate) seed: u64,
    pub(crate) workers: Option<usize>,
    /// The ordered host-extension registry every run/launch/storm of
    /// this site drives (stock GPU/MPI/network plus
    /// [`SiteBuilder::with_extension`] additions).
    pub(crate) extensions: Arc<ExtensionRegistry>,
    /// The telemetry recorder shared by every layer of this site
    /// (disabled — a no-op — unless [`SiteBuilder::telemetry`] was set).
    pub(crate) telemetry: Arc<Telemetry>,
}

impl Site {
    /// Start declaring a site. See [`SiteBuilder`].
    pub fn builder() -> SiteBuilder {
        SiteBuilder::new()
    }

    // -- introspection ----------------------------------------------------

    /// The machine this site launches onto (partitions in node-id order).
    pub fn cluster(&self) -> &LaunchCluster {
        &self.cluster
    }

    /// The image registry this site resolves references against.
    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    /// The distribution fabric behind the facade (shards, CAS, caches).
    pub fn fabric(&self) -> &DistributionFabric {
        &self.fabric
    }

    /// Mutable fabric access, for driving the asynchronous pull queue
    /// directly (most callers want [`Site::request`] / [`Site::tick`]).
    pub fn fabric_mut(&mut self) -> &mut DistributionFabric {
        &mut self.fabric
    }

    /// The effective `udiRoot.conf` of the site's primary partition.
    pub fn config(&self) -> &UdiRootConfig {
        &self.runtimes[0].config
    }

    /// The scheduling policy storms run under by default.
    pub fn policy(&self) -> &dyn SchedulingPolicy {
        self.policy.as_ref()
    }

    /// The host-extension registry this site drives (injection order).
    pub fn extensions(&self) -> &ExtensionRegistry {
        &self.extensions
    }

    /// Per-partition extension capability vectors: for every partition,
    /// each registered extension's host-compatibility verdict — what
    /// `shifterimg cluster-status` prints.
    pub fn capabilities(&self) -> Vec<(String, Vec<Capability>)> {
        self.cluster
            .partitions()
            .iter()
            .zip(&self.runtimes)
            .map(|(p, rt)| {
                (
                    p.name().to_string(),
                    self.extensions.capabilities(p.profile(), &rt.config),
                )
            })
            .collect()
    }

    /// The site's deterministic seed for synthesized workloads.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// The telemetry recorder behind every operation this site runs —
    /// spans, counters, and histograms accumulate across `pull` / `run`
    /// / `launch` / `storm` calls (DESIGN.md S23). Disabled (and empty)
    /// unless the site was built with [`SiteBuilder::telemetry`].
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// Canonical references of every image materialized on any gateway
    /// shard, in sorted order (`shifterimg images`).
    pub fn images(&self) -> Vec<String> {
        let mut refs: Vec<String> = self
            .fabric
            .cluster()
            .shards()
            .flat_map(|s| s.gateway.list())
            .collect();
        refs.sort();
        refs
    }

    /// The site-shaped synthesis defaults (`StormSpec` knobs left unset
    /// resolve against this).
    fn site_traffic(&self) -> TrafficModel {
        TrafficModel {
            max_width: (self.cluster.total_nodes() / 2).max(1),
            seed: self.seed,
            ..TrafficModel::default()
        }
    }

    // -- pull -------------------------------------------------------------

    /// `shifterimg pull <ref>` — synchronous pull through the sharded
    /// fabric: enqueue, drain the shard workers to a terminal state, and
    /// report the job's timing breakdown. Re-pulling a READY reference
    /// is idempotent: the request coalesces onto the existing job and
    /// the shard clocks do not advance (same short-circuit as
    /// `DistributionFabric::pull_blocking`).
    pub fn pull(&mut self, reference: &str) -> Result<PullOutcome, SiteError> {
        let (_, state) = self
            .fabric
            .request(&self.registry, reference, "site-operator")
            .map_err(|e| SiteError::Pull {
                reference: reference.to_string(),
                source: e,
            })?;
        if !state.terminal() {
            self.fabric.drain(&self.registry);
        }

        let Some(job) = self.fabric.cluster().status(reference) else {
            return Err(SiteError::PullFailed {
                reference: reference.to_string(),
                detail: "pull was never enqueued".to_string(),
            });
        };
        if job.state != PullState::Ready {
            return Err(SiteError::PullFailed {
                reference: reference.to_string(),
                detail: job
                    .error
                    .clone()
                    .unwrap_or_else(|| {
                        format!("terminal state {}", job.state.name())
                    }),
            });
        }
        let durations = *job.stage_durations();
        let (queue_wait, turnaround, requesters) = (
            job.queue_wait_secs().unwrap_or(0.0),
            job.turnaround_secs().unwrap_or(0.0),
            job.requesters.len(),
        );
        let image =
            self.fabric.cluster().lookup(reference).map_err(|e| {
                SiteError::Pull {
                    reference: reference.to_string(),
                    source: e,
                }
            })?;
        if self.telemetry.enabled() {
            let span = self.telemetry.span(SpanDraft {
                parent: None,
                category: "pull",
                name: &format!("pull:{reference}"),
                track: "gateway",
                start: SimTime::ZERO,
                dur_secs: turnaround,
            });
            if let Some(id) = span {
                self.telemetry.annotate(
                    id,
                    "requesters",
                    &requesters.to_string(),
                );
            }
        }
        Ok(PullOutcome {
            reference: image.reference.canonical(),
            pfs_path: image.pfs_path.clone(),
            queue_wait_secs: queue_wait,
            turnaround_secs: turnaround,
            download_secs: durations[0],
            expand_secs: durations[1],
            convert_secs: durations[2],
            store_secs: durations[3],
            requesters,
        })
    }

    /// Enqueue an asynchronous pull (the gateway-daemon lifecycle):
    /// returns the job state as observed by this requester; advance the
    /// workers with [`Site::tick`] and poll [`Site::pull_status`].
    pub fn request(
        &mut self,
        reference: &str,
        user: &str,
    ) -> Result<PullState, SiteError> {
        let (_, state) = self
            .fabric
            .request(&self.registry, reference, user)
            .map_err(|e| SiteError::Pull {
                reference: reference.to_string(),
                source: e,
            })?;
        Ok(state)
    }

    /// Advance every gateway shard worker by `dt` simulated seconds.
    pub fn tick(&mut self, dt: f64) {
        self.fabric.tick(&self.registry, dt);
    }

    /// Status of the pull job for `reference`, if one was ever requested.
    pub fn pull_status(&self, reference: &str) -> Option<&PullJob> {
        self.fabric.cluster().status(reference)
    }

    /// Enqueue a pull for every reference in `refs` (a site's nightly
    /// catalog sync), then drain the shard workers once so distinct
    /// references contend on the shard queues exactly as a storm would.
    /// Returns the references whose *enqueue* failed; terminal pull
    /// failures are visible per job via [`Site::pull_status`].
    pub fn prefetch(
        &mut self,
        refs: &[String],
    ) -> Vec<(String, SiteError)> {
        let mut failures = Vec::new();
        for reference in refs {
            if let Err(e) =
                self.fabric
                    .request(&self.registry, reference, "site-operator")
            {
                failures.push((
                    reference.clone(),
                    SiteError::Pull {
                        reference: reference.clone(),
                        source: e,
                    },
                ));
            }
        }
        self.fabric.drain(&self.registry);
        failures
    }

    // -- run --------------------------------------------------------------

    /// `shifter --image=<ref> <cmd…>` — run one container on the node
    /// named by `opts.node`, pulling the image through the fabric first
    /// if no shard holds it yet.
    pub fn run(
        &mut self,
        opts: &RunOptions,
    ) -> Result<Container, SiteError> {
        if self.fabric.cluster().lookup(&opts.image).is_err() {
            self.pull(&opts.image)?;
        }
        let node = opts.node as u32;
        let pidx = self
            .cluster
            .partitions()
            .iter()
            .position(|p| p.contains(node))
            .ok_or(SiteError::UnknownNode(node))?;
        Ok(self.runtimes[pidx].run(&self.fabric, opts)?)
    }

    // -- launch -----------------------------------------------------------

    /// One cluster-scale containerized job, end to end: WLM allocation,
    /// one coalesced pull, per-node stage execution, percentile report.
    /// Slots fill from the lowest global node id upward.
    pub fn launch(
        &mut self,
        spec: &JobSpec,
    ) -> Result<LaunchReport, SiteError> {
        self.check_gpus(spec)?;
        let scheduler = wired_launch_scheduler(
            &self.cluster,
            &self.registry,
            self.retry.unwrap_or_default(),
            &self.config_override,
            self.workers,
            &self.extensions,
            &self.telemetry,
        );
        Ok(scheduler.launch(&mut self.fabric, spec)?)
    }

    /// Like [`Site::launch`], but place the job on an explicit (possibly
    /// partition-spanning) set of global node ids.
    pub fn launch_on(
        &mut self,
        spec: &JobSpec,
        nodes: &[u32],
    ) -> Result<LaunchReport, SiteError> {
        self.check_gpus(spec)?;
        let scheduler = wired_launch_scheduler(
            &self.cluster,
            &self.registry,
            self.retry.unwrap_or_default(),
            &self.config_override,
            self.workers,
            &self.extensions,
            &self.telemetry,
        );
        Ok(scheduler.launch_on(&mut self.fabric, spec, nodes)?)
    }

    // -- storm ------------------------------------------------------------

    /// Run the multi-tenant storm described by `spec` (see
    /// [`StormSpec`]): synthesize or replay the job stream, schedule it
    /// on the virtual-time kernel under the spec's (or the site's)
    /// policy, and optionally export the Chrome trace artifact.
    pub fn run_storm(
        &mut self,
        spec: &StormSpec,
    ) -> Result<TenancyReport, SiteError> {
        let report = match &spec.stream {
            Some(jobs) => self.storm_impl(jobs, spec.policy.as_deref()),
            None => {
                let jobs =
                    spec.resolve_traffic(self).generate(&self.cluster);
                self.storm_impl(&jobs, spec.policy.as_deref())
            }
        };
        if let Some(path) = &spec.trace_path {
            let trace = self.telemetry.chrome_trace_jsonl();
            std::fs::write(path, trace).map_err(|source| {
                SiteError::Trace {
                    path: path.display().to_string(),
                    source,
                }
            })?;
        }
        Ok(report)
    }

    // -- internals --------------------------------------------------------

    fn storm_impl(
        &mut self,
        jobs: &[TenantJob],
        policy: Option<&dyn SchedulingPolicy>,
    ) -> TenancyReport {
        let policy = match policy {
            Some(p) => p,
            None => self.policy.as_ref(),
        };
        // storms default to strict retry — the multi-tenant scheduler's
        // own deterministic default — unless the site set the knob
        let mut scheduler =
            FairShareScheduler::new(&self.cluster, &self.registry)
                .with_policy(policy)
                .with_retry_policy(
                    self.retry.unwrap_or_else(RetryPolicy::strict),
                )
                .with_extensions(Arc::clone(&self.extensions))
                .with_telemetry(Arc::clone(&self.telemetry));
        if let Some(config) = &self.config_override {
            scheduler = scheduler.with_config(config.clone());
        }
        scheduler.run(&mut self.fabric, jobs)
    }

    fn check_gpus(&self, spec: &JobSpec) -> Result<(), SiteError> {
        if spec.gpus_per_node > 0
            && !self
                .cluster
                .partitions()
                .iter()
                .any(|p| p.profile().gpu_capable())
        {
            return Err(SiteError::GpuUnavailable {
                gpus_per_node: spec.gpus_per_node,
            });
        }
        Ok(())
    }
}

/// Assemble a launch scheduler from a site's knobs. A free function (not
/// a `&self` method) so callers can keep `&mut self.fabric` available:
/// direct field borrows split, a whole-`self` borrow would not.
#[allow(clippy::too_many_arguments)]
fn wired_launch_scheduler<'a>(
    cluster: &'a LaunchCluster,
    registry: &'a Registry,
    retry: RetryPolicy,
    config: &Option<UdiRootConfig>,
    workers: Option<usize>,
    extensions: &Arc<ExtensionRegistry>,
    telemetry: &Arc<Telemetry>,
) -> LaunchScheduler<'a> {
    let mut scheduler = LaunchScheduler::new(cluster, registry)
        .with_policy(retry)
        .with_extensions(Arc::clone(extensions))
        .with_telemetry(Arc::clone(telemetry));
    if let Some(config) = config {
        scheduler = scheduler.with_config(config.clone());
    }
    if let Some(workers) = workers {
        scheduler = scheduler.with_workers(workers);
    }
    scheduler
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    #[test]
    fn pull_run_launch_through_one_handle() {
        let mut site = Site::builder()
            .profile(SystemProfile::piz_daint())
            .nodes(4)
            .gateway_shards(2)
            .build()
            .unwrap();
        let pull = site.pull("ubuntu:xenial").unwrap();
        assert_eq!(pull.reference, "ubuntu:xenial");
        assert!(pull.turnaround_secs > 0.0);
        assert!(pull.pfs_path.contains("squashfs"));
        assert_eq!(site.images(), vec!["ubuntu:xenial".to_string()]);

        let c = site
            .run(&RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        assert!(c.stage_log.completed());

        let report = site
            .launch(&JobSpec::new("ubuntu:xenial", &["true"], 4))
            .unwrap();
        assert_eq!(report.succeeded(), 4);
    }

    #[test]
    fn run_auto_pulls_once_and_coalesces() {
        let mut site = Site::builder().nodes(2).build().unwrap();
        // no explicit pull: run must materialize the image itself
        site.run(&RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        let before = site.fabric().coalescing();
        assert_eq!(before.jobs, 1);
        // a second run coalesces onto the existing READY job
        site.run(&RunOptions::new("ubuntu:xenial", &["true"]))
            .unwrap();
        assert_eq!(site.fabric().coalescing().jobs, 1);
    }

    #[test]
    fn pull_of_missing_image_is_a_typed_failure() {
        let mut site = Site::builder().nodes(1).build().unwrap();
        let err = site.pull("nope:missing").unwrap_err();
        match err {
            SiteError::PullFailed { reference, detail } => {
                assert_eq!(reference, "nope:missing");
                assert!(detail.contains("not found"), "{detail}");
            }
            other => panic!("wrong error: {other}"),
        }
    }

    #[test]
    fn run_on_unknown_node_is_rejected() {
        let mut site = Site::builder().nodes(2).build().unwrap();
        site.pull("ubuntu:xenial").unwrap();
        let opts =
            RunOptions::new("ubuntu:xenial", &["true"]).on_nodes(99, 1);
        assert!(matches!(
            site.run(&opts).unwrap_err(),
            SiteError::UnknownNode(99)
        ));
    }

    #[test]
    fn async_pull_lifecycle_via_the_facade() {
        let mut site = Site::builder().nodes(1).build().unwrap();
        let state = site.request("pynamic:1.3", "cscs-user").unwrap();
        assert_eq!(state, PullState::Enqueued);
        let mut ticks = 0;
        while !site.pull_status("pynamic:1.3").unwrap().state.terminal() {
            site.tick(2.0);
            ticks += 1;
            assert!(ticks < 10_000, "pull must terminate");
        }
        assert_eq!(
            site.pull_status("pynamic:1.3").unwrap().state,
            PullState::Ready
        );
        assert!(ticks > 1, "a real pull takes multiple worker ticks");
    }

    #[test]
    fn telemetry_is_off_by_default_and_wired_when_enabled() {
        let mut quiet = Site::builder().nodes(2).build().unwrap();
        quiet.pull("ubuntu:xenial").unwrap();
        quiet
            .launch(&JobSpec::new("ubuntu:xenial", &["true"], 2))
            .unwrap();
        assert!(!quiet.telemetry().enabled());
        assert_eq!(quiet.telemetry().span_count(), 0);
        assert_eq!(quiet.telemetry().counters().len(), 0);

        let mut traced =
            Site::builder().nodes(2).telemetry(true).build().unwrap();
        let pull = traced.pull("ubuntu:xenial").unwrap();
        let spans = traced.telemetry().spans();
        let pull_span = spans
            .iter()
            .find(|s| s.category == "pull")
            .expect("pull span");
        assert_eq!(pull_span.name, "pull:ubuntu:xenial");
        assert!(
            (pull_span.dur_secs - pull.turnaround_secs).abs() < 1e-9
        );
        traced
            .launch(&JobSpec::new("ubuntu:xenial", &["true"], 2))
            .unwrap();
        let tel = traced.telemetry();
        assert!(tel.counter("fabric.requests") >= 1);
        assert_eq!(tel.counter("launch.slots"), 2);
        assert_eq!(tel.counter("runtime.runs"), 2);
        assert!(tel
            .spans()
            .iter()
            .any(|s| s.category == "job" && s.parent.is_none()));
    }

    #[test]
    fn storm_spec_replay_matches_the_synthesized_form() {
        // replaying the pre-generated stream explicitly must reproduce
        // the synthesized run exactly — the equivalence the benches
        // rely on when they schedule one stream under many configs
        let build = || {
            Site::builder().nodes(8).seed(11).build().unwrap()
        };
        let mut a = build();
        let jobs =
            StormSpec::new().jobs(12).resolve_traffic(&a).generate(a.cluster());
        let replayed = a
            .run_storm(&StormSpec::new().job_stream(jobs))
            .unwrap();
        let mut b = build();
        let synthesized =
            b.run_storm(&StormSpec::new().jobs(12)).unwrap();
        assert_eq!(
            replayed.to_json().to_string(),
            synthesized.to_json().to_string()
        );
    }

    #[test]
    fn storm_spec_knobs_override_the_site_defaults() {
        let mut site =
            Site::builder().nodes(8).seed(3).build().unwrap();
        let resolved = StormSpec::new()
            .tenants(2)
            .jobs(9)
            .max_width(2)
            .seed(99)
            .resolve_traffic(&site);
        assert_eq!(resolved.tenants, 2);
        assert_eq!(resolved.jobs, 9);
        assert_eq!(resolved.max_width, 2);
        assert_eq!(resolved.seed, 99);
        // unset knobs keep the site shape: width = half of 8 unless set
        let shaped = StormSpec::new().resolve_traffic(&site);
        assert_eq!(shaped.max_width, 4);
        assert_eq!(shaped.seed, 3);

        let report = site
            .run_storm(&StormSpec::new().tenants(2).jobs(9).seed(99))
            .unwrap();
        assert_eq!(report.records.len(), 9);
    }

    #[test]
    fn prefetch_drives_the_whole_catalog_once() {
        let mut site =
            Site::builder().nodes(1).gateway_shards(4).build().unwrap();
        let refs = site.registry().list();
        let failures = site.prefetch(&refs);
        assert!(failures.is_empty());
        let coalescing = site.fabric().coalescing();
        assert_eq!(coalescing.jobs, refs.len());
        assert!(site.images().len() <= refs.len());
    }
}
