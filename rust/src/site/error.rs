//! The single error surface of the [`crate::Site`] facade.
//!
//! Every operation on a `Site` returns [`SiteError`]. Builder-validation
//! failures get their own typed variants (so a misconfigured site is a
//! matchable error, not a panic); failures from the layers underneath —
//! runtime, gateway, launch, config — are wrapped with their cause
//! preserved, so `std::error::Error::source()` walks the full chain:
//!
//! ```
//! use std::error::Error as _;
//! use shifter_rs::{JobSpec, Site};
//!
//! let mut site = Site::builder().nodes(2).build().unwrap();
//! // 99 nodes on a 2-node site: rejected by the WLM layer
//! let err = site
//!     .launch(&JobSpec::new("ubuntu:xenial", &["true"], 99))
//!     .unwrap_err();
//! let cause = err.source().expect("SiteError chains its cause");
//! assert!(cause.to_string().contains("99"));
//! ```

use crate::config::ConfigError;
use crate::gateway::GatewayError;
use crate::launch::LaunchError;
use crate::shifter::ShifterError;

/// Everything that can go wrong configuring or operating a [`crate::Site`].
///
/// Wrapping variants preserve their cause: `Error::source()` returns the
/// underlying `ShifterError` / `GatewayError` / `LaunchError` /
/// `ConfigError`, whose own `source()` chains continue downward.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum SiteError {
    /// Builder: `gateway_shards(0)` — the distribution fabric needs at
    /// least one gateway shard.
    #[error("a site needs at least one gateway shard")]
    NoShards,

    /// Builder: the site describes zero compute nodes overall.
    #[error("a site needs at least one compute node")]
    EmptyCluster,

    /// Builder: a named partition was declared with zero nodes.
    #[error("partition '{0}' has zero nodes")]
    EmptyPartition(String),

    /// Builder: a partition's base profile carries no node spec to
    /// replicate.
    #[error("profile '{0}' has no node spec to build a partition from")]
    NoNodeSpec(String),

    /// Builder: the per-node squashfs cache is too small to hold any
    /// catalog image, so every container start would thrash the cache.
    #[error(
        "node-cache capacity {bytes} B is below the {floor} B floor \
         (must hold at least one catalog squashfs)"
    )]
    NodeCacheTooSmall {
        /// The capacity that was requested.
        bytes: u64,
        /// The smallest capacity the builder accepts.
        floor: u64,
    },

    /// Builder: a retry policy that allows zero attempts can never run a
    /// node slot.
    #[error("retry policy must allow at least one attempt per slot")]
    BadRetryPolicy,

    /// Builder: `cascade(0, _)` — a cascade topology needs at least one
    /// node per cabinet.
    #[error("cascade cabinets need at least one node each")]
    EmptyCabinet,

    /// Builder: `cascade(_, 0)` — a spanning tree with fan-out zero
    /// never propagates past the gateway seed.
    #[error("cascade fan-out must be at least one")]
    BadCascadeFanout,

    /// Builder: the CAS chunk-size target is outside the accepted range
    /// (too small drowns in bookkeeping, too large degenerates to
    /// whole-layer blobs).
    #[error(
        "chunk target {bytes} B is outside the accepted range \
         [{floor} B, {ceiling} B]"
    )]
    BadChunkTarget {
        /// The chunk-size target that was requested.
        bytes: u64,
        /// Smallest accepted target
        /// ([`crate::distrib::chunk::MIN_CHUNK_TARGET_BYTES`]).
        floor: u64,
        /// Largest accepted target
        /// ([`crate::distrib::chunk::MAX_CHUNK_TARGET_BYTES`]).
        ceiling: u64,
    },

    /// Launch-time: the job requests GPUs but no partition of this site
    /// has GPU-capable nodes — failing fast here beats burning a WLM
    /// round trip per partition.
    #[error(
        "job requests {gpus_per_node} GPU(s) per node but no partition \
         of this site has GPU-capable nodes"
    )]
    GpuUnavailable {
        /// GPUs per node the job's GRES request asked for.
        gpus_per_node: u32,
    },

    /// An operation named a node id outside every partition.
    #[error("node {0} is outside every partition of this site")]
    UnknownNode(u32),

    /// The site `udiRoot.conf` text failed to parse.
    #[error("invalid udiRoot.conf")]
    Config(#[from] ConfigError),

    /// Enqueuing a pull on the distribution fabric failed.
    #[error("pull failed for {reference}")]
    Pull {
        /// The image reference whose pull failed.
        reference: String,
        /// The gateway-layer cause (chained via `source()`).
        #[source]
        source: GatewayError,
    },

    /// A pull ran but ended in the terminal FAILED state (the gateway
    /// job's own error text is carried verbatim).
    #[error("pull failed for {reference}: {detail}")]
    PullFailed {
        /// The image reference whose pull failed.
        reference: String,
        /// Terminal gateway-job error, verbatim.
        detail: String,
    },

    /// The container runtime failed on this node.
    #[error("shifter runtime failed")]
    Runtime(#[from] ShifterError),

    /// The cluster-scale launch orchestrator rejected or aborted the job.
    #[error("cluster launch failed")]
    Launch(#[from] LaunchError),

    /// Writing a storm's Chrome trace artifact failed
    /// (`StormSpec::trace_path`).
    #[error("failed to write trace artifact to {path}")]
    Trace {
        /// The path the trace could not be written to.
        path: String,
        /// The filesystem cause (chained via `source()`).
        #[source]
        source: std::io::Error,
    },
}
