//! The declarative site builder: every operator knob in one place,
//! validated once at [`SiteBuilder::build`].

use std::sync::Arc;

use crate::config::UdiRootConfig;
use crate::distrib::chunk::{
    MAX_CHUNK_TARGET_BYTES, MIN_CHUNK_TARGET_BYTES,
};
use crate::distrib::{
    CascadeConfig, DistributionFabric, DEFAULT_NODE_CACHE_BYTES,
};
use crate::hostenv::SystemProfile;
use crate::launch::{LaunchCluster, RetryPolicy};
use crate::pfs::LustreFs;
use crate::registry::Registry;
use crate::shifter::{ExtensionRegistry, HostExtension, ShifterRuntime};
use crate::telemetry::Telemetry;
use crate::tenancy::{FairShare, SchedulingPolicy};

use super::error::SiteError;
use super::Site;

/// Floor on the per-node squashfs cache: below this not even the
/// smallest catalog image fits, and every container start would thrash
/// the cache ([`SiteError::NodeCacheTooSmall`]).
pub const MIN_NODE_CACHE_BYTES: u64 = 50_000_000;

/// Declares a [`Site`]: the host profile or explicit partitions, the
/// gateway shard count, node-cache capacity, `udiRoot.conf`, the
/// launch retry policy, the storm scheduling policy, and the workload
/// seed. `build()` validates the combination and wires the full stack —
/// fabric, launch cluster, per-partition runtimes — exactly once.
///
/// ```
/// use shifter_rs::shifter::RunOptions;
/// use shifter_rs::{JobSpec, Site, SystemProfile};
///
/// let mut site = Site::builder()
///     .profile(SystemProfile::piz_daint())
///     .nodes(4)
///     .gateway_shards(2)
///     .build()
///     .unwrap();
///
/// // §III.B end-user workflow, all through the one handle:
/// let pull = site.pull("ubuntu:xenial").unwrap();
/// assert!(pull.turnaround_secs > 0.0);
/// let container = site
///     .run(&RunOptions::new("ubuntu:xenial", &["cat", "/etc/os-release"]))
///     .unwrap();
/// assert!(container.read_file("/etc/os-release").is_some());
/// let report = site
///     .launch(&JobSpec::new("ubuntu:xenial", &["true"], 4))
///     .unwrap();
/// assert_eq!(report.succeeded(), 4);
/// ```
pub struct SiteBuilder {
    base_profile: SystemProfile,
    nodes: u32,
    partitions: Vec<(String, SystemProfile, u32)>,
    shards: usize,
    node_cache_bytes: u64,
    config: Option<UdiRootConfig>,
    retry: Option<RetryPolicy>,
    policy: Box<dyn SchedulingPolicy>,
    registry: Option<Registry>,
    pfs: Option<LustreFs>,
    seed: u64,
    workers: Option<usize>,
    extensions: Vec<Box<dyn HostExtension>>,
    default_extensions: bool,
    telemetry: bool,
    recorder: Option<Arc<Telemetry>>,
    cascade: Option<(usize, usize)>,
    chunk_target: Option<u64>,
    lazy: bool,
}

impl Default for SiteBuilder {
    fn default() -> SiteBuilder {
        SiteBuilder::new()
    }
}

impl SiteBuilder {
    /// A single-node Piz Daint site with stock knobs: 4 gateway shards,
    /// the default node-cache capacity, per-profile `udiRoot.conf`, the
    /// default launch retry policy, fair-share + backfill scheduling,
    /// seed 7.
    pub fn new() -> SiteBuilder {
        SiteBuilder {
            base_profile: SystemProfile::piz_daint(),
            nodes: 1,
            partitions: Vec::new(),
            shards: 4,
            node_cache_bytes: DEFAULT_NODE_CACHE_BYTES,
            config: None,
            retry: None,
            policy: Box::new(FairShare::default()),
            registry: None,
            pfs: None,
            seed: 7,
            workers: None,
            extensions: Vec::new(),
            default_extensions: true,
            telemetry: false,
            recorder: None,
            cascade: None,
            chunk_target: None,
            lazy: false,
        }
    }

    /// Base host profile for a homogeneous site (ignored once explicit
    /// [`SiteBuilder::partition`]s are declared).
    pub fn profile(mut self, profile: SystemProfile) -> SiteBuilder {
        self.base_profile = profile;
        self
    }

    /// Node count of the homogeneous site (ignored once explicit
    /// [`SiteBuilder::partition`]s are declared).
    pub fn nodes(mut self, nodes: u32) -> SiteBuilder {
        self.nodes = nodes;
        self
    }

    /// Append an explicit partition of `nodes` identical nodes modeled
    /// on `base` — call repeatedly to describe a heterogeneous machine.
    pub fn partition(
        mut self,
        name: &str,
        base: &SystemProfile,
        nodes: u32,
    ) -> SiteBuilder {
        self.partitions
            .push((name.to_string(), base.clone(), nodes));
        self
    }

    /// The stock heterogeneous split the CLI's `--hetero` flag and the
    /// scale benches share — [`LaunchCluster::daint_linux_partitions`] is
    /// the single definition: half Piz Daint (P100, driver 375.66, Cray
    /// MPT), half Linux Cluster (K40m/K80, driver 367.48, MVAPICH2). A
    /// width below 2 surfaces as [`SiteError::EmptyPartition`] at
    /// `build()`, not a panic.
    pub fn hetero_daint_linux(mut self, nodes: u32) -> SiteBuilder {
        for (name, profile, share) in
            LaunchCluster::daint_linux_partitions(nodes)
        {
            self = self.partition(name, &profile, share);
        }
        self
    }

    /// Gateway shard count of the distribution fabric (>= 1).
    pub fn gateway_shards(mut self, shards: usize) -> SiteBuilder {
        self.shards = shards;
        self
    }

    /// Per-node squashfs cache capacity in bytes (>=
    /// [`MIN_NODE_CACHE_BYTES`]).
    pub fn node_cache_bytes(mut self, bytes: u64) -> SiteBuilder {
        self.node_cache_bytes = bytes;
        self
    }

    /// Site `udiRoot.conf` applied to every runtime and launch (the
    /// default derives one per partition from its profile).
    pub fn config(mut self, config: UdiRootConfig) -> SiteBuilder {
        self.config = Some(config);
        self
    }

    /// Parse a `udiRoot.conf` text (the `key = value` format a site
    /// administrator writes) and apply it like [`SiteBuilder::config`].
    pub fn config_conf(self, text: &str) -> Result<SiteBuilder, SiteError> {
        let config = UdiRootConfig::from_conf(text)?;
        Ok(self.config(config))
    }

    /// Straggler/retry policy for every launch and storm this site runs.
    /// When unset, each layer keeps its historical default: launches use
    /// `RetryPolicy::default()` (jitter + straggler relaunch), storms use
    /// `RetryPolicy::strict()` (deterministic per-node timings).
    pub fn retry_policy(mut self, retry: RetryPolicy) -> SiteBuilder {
        self.retry = Some(retry);
        self
    }

    /// Queue policy storms run under ([`crate::tenancy::FairShare`] by
    /// default; any [`SchedulingPolicy`] object plugs in).
    pub fn scheduling_policy(
        mut self,
        policy: Box<dyn SchedulingPolicy>,
    ) -> SiteBuilder {
        self.policy = policy;
        self
    }

    /// Resolve images against this registry instead of the stock Docker
    /// Hub catalog (e.g. after `registry.push(image)` of a locally built
    /// image).
    pub fn registry(mut self, registry: Registry) -> SiteBuilder {
        self.registry = Some(registry);
        self
    }

    /// Parallel filesystem the gateway shards store to (default: the
    /// primary partition profile's PFS, else the Piz Daint model).
    pub fn pfs(mut self, pfs: LustreFs) -> SiteBuilder {
        self.pfs = Some(pfs);
        self
    }

    /// Deterministic seed for synthesized workloads — the default a
    /// [`crate::site::StormSpec`] inherits when its own `seed` knob is
    /// left unset.
    pub fn seed(mut self, seed: u64) -> SiteBuilder {
        self.seed = seed;
        self
    }

    /// Historical knob from the wall-clock worker-pool era. Launch slots
    /// now execute on the virtual-time kernel (DESIGN.md S24), where
    /// results never depend on host parallelism, so this is a no-op kept
    /// for API compatibility.
    pub fn workers(mut self, workers: usize) -> SiteBuilder {
        self.workers = Some(workers);
        self
    }

    /// Register an additional [`HostExtension`] after the stock
    /// GPU/MPI/network set (or after nothing, when
    /// [`SiteBuilder::without_default_extensions`] was called). Order of
    /// registration is injection order; the registry reaches every
    /// `run`, `launch` and `storm` this site executes.
    pub fn with_extension(
        mut self,
        extension: Box<dyn HostExtension>,
    ) -> SiteBuilder {
        self.extensions.push(extension);
        self
    }

    /// Drop the stock GPU/MPI/network extensions — the registry then
    /// contains only what [`SiteBuilder::with_extension`] adds.
    pub fn without_default_extensions(mut self) -> SiteBuilder {
        self.default_extensions = false;
        self
    }

    /// Enable topology-aware cascade fills (DESIGN.md S25): nodes are
    /// grouped into cabinets of `cabinet_nodes`, and a cold pull storm
    /// fills spanning-tree-style — one gateway read per storm, every
    /// other node fetching from a warm peer, each warm node serving up
    /// to `fanout` cold peers. `cabinet_nodes` must be >= 1
    /// ([`SiteError::EmptyCabinet`]), `fanout` >= 1
    /// ([`SiteError::BadCascadeFanout`]).
    pub fn cascade(
        mut self,
        cabinet_nodes: usize,
        fanout: usize,
    ) -> SiteBuilder {
        self.cascade = Some((cabinet_nodes, fanout));
        self
    }

    /// Enable content-defined chunking in the cluster CAS with the given
    /// mean chunk size: derived images dedup below layer granularity and
    /// pulls transfer only missing chunks. Accepted range is
    /// [`MIN_CHUNK_TARGET_BYTES`]..=[`MAX_CHUNK_TARGET_BYTES`]
    /// ([`SiteError::BadChunkTarget`] otherwise).
    pub fn chunk_target_bytes(mut self, bytes: u64) -> SiteBuilder {
        self.chunk_target = Some(bytes);
        self
    }

    /// Enable lazy pulling (DESIGN.md S25): containers start once
    /// squashfs metadata + first-read chunks arrive, and the remaining
    /// image streams on demand during execution — the streamed tail is
    /// charged to the job's execute stage, not container start.
    pub fn lazy_pull(mut self, enabled: bool) -> SiteBuilder {
        self.lazy = enabled;
        self
    }

    /// Record structured spans, counters, and histograms for every
    /// operation this site runs (DESIGN.md S23). Off by default: a
    /// disabled [`Telemetry`] recorder is a single branch on the hot
    /// path and allocates nothing. When enabled, [`Site::telemetry`]
    /// exposes the recorder — Chrome-trace export via
    /// [`Telemetry::chrome_trace_jsonl`], counter/histogram snapshots
    /// via [`Telemetry::snapshot_json`].
    pub fn telemetry(mut self, enabled: bool) -> SiteBuilder {
        self.telemetry = enabled;
        self
    }

    /// Record into an existing [`Telemetry`] recorder instead of
    /// allocating a private one. A federation
    /// ([`crate::federation::Federation`]) passes the same recorder to
    /// every member site so cross-site storms produce one coherent
    /// span tree / Chrome trace; a bare site never needs this.
    /// Overrides [`SiteBuilder::telemetry`] — the shared recorder's
    /// own enabled/disabled state wins.
    pub fn telemetry_recorder(
        mut self,
        recorder: Arc<Telemetry>,
    ) -> SiteBuilder {
        self.recorder = Some(recorder);
        self
    }

    /// Validate the declared knobs and wire the stack. Conflicting or
    /// impossible combinations return typed [`SiteError`] variants —
    /// never panics.
    pub fn build(self) -> Result<Site, SiteError> {
        if self.shards == 0 {
            return Err(SiteError::NoShards);
        }
        if self.node_cache_bytes < MIN_NODE_CACHE_BYTES {
            return Err(SiteError::NodeCacheTooSmall {
                bytes: self.node_cache_bytes,
                floor: MIN_NODE_CACHE_BYTES,
            });
        }
        if self.retry.is_some_and(|r| r.max_attempts == 0) {
            return Err(SiteError::BadRetryPolicy);
        }
        if let Some((cabinet_nodes, fanout)) = self.cascade {
            if cabinet_nodes == 0 {
                return Err(SiteError::EmptyCabinet);
            }
            if fanout == 0 {
                return Err(SiteError::BadCascadeFanout);
            }
        }
        if let Some(bytes) = self.chunk_target {
            if !(MIN_CHUNK_TARGET_BYTES..=MAX_CHUNK_TARGET_BYTES)
                .contains(&bytes)
            {
                return Err(SiteError::BadChunkTarget {
                    bytes,
                    floor: MIN_CHUNK_TARGET_BYTES,
                    ceiling: MAX_CHUNK_TARGET_BYTES,
                });
            }
        }

        // -- partitions ---------------------------------------------------
        let cluster = if self.partitions.is_empty() {
            if self.nodes == 0 {
                return Err(SiteError::EmptyCluster);
            }
            if self.base_profile.nodes.is_empty() {
                return Err(SiteError::NoNodeSpec(
                    self.base_profile.name.to_string(),
                ));
            }
            LaunchCluster::homogeneous(&self.base_profile, self.nodes)
        } else {
            let mut cluster = LaunchCluster::new();
            for (name, profile, nodes) in &self.partitions {
                if *nodes == 0 {
                    return Err(SiteError::EmptyPartition(name.clone()));
                }
                if profile.nodes.is_empty() {
                    return Err(SiteError::NoNodeSpec(
                        profile.name.to_string(),
                    ));
                }
                cluster = cluster.with_partition(name, profile, *nodes);
            }
            cluster
        };

        // -- fabric -------------------------------------------------------
        let pfs = self.pfs.unwrap_or_else(|| {
            cluster.partitions()[0]
                .profile()
                .pfs
                .clone()
                .unwrap_or_else(LustreFs::piz_daint)
        });
        let telemetry = match self.recorder {
            Some(recorder) => recorder,
            None => Arc::new(Telemetry::new(self.telemetry)),
        };
        let mut fabric = DistributionFabric::new(self.shards, pfs)
            .with_node_cache_bytes(self.node_cache_bytes)
            .with_telemetry(Arc::clone(&telemetry));
        // chunking first: the chunker must be installed before any pull
        if let Some(bytes) = self.chunk_target {
            fabric = fabric.with_chunking(bytes);
        }
        if let Some((cabinet_nodes, fanout)) = self.cascade {
            fabric = fabric.with_cascade(CascadeConfig {
                cabinet_nodes,
                fanout,
            });
        }
        if self.lazy {
            fabric = fabric.with_lazy_pull(true);
        }

        // -- extension registry -------------------------------------------
        let mut registry = if self.default_extensions {
            ExtensionRegistry::defaults()
        } else {
            ExtensionRegistry::empty()
        };
        for extension in self.extensions {
            registry.register(extension);
        }
        let extensions = Arc::new(registry);

        // -- per-partition runtimes ---------------------------------------
        let runtimes: Vec<ShifterRuntime> = cluster
            .partitions()
            .iter()
            .map(|p| {
                p.runtime_with_extensions(
                    self.config.as_ref(),
                    Arc::clone(&extensions),
                )
                .with_telemetry(Arc::clone(&telemetry))
            })
            .collect();

        Ok(Site {
            cluster,
            registry: self.registry.unwrap_or_else(Registry::dockerhub),
            fabric,
            runtimes,
            config_override: self.config,
            retry: self.retry,
            policy: self.policy,
            seed: self.seed,
            workers: self.workers,
            extensions,
            telemetry,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::launch::JobSpec;

    #[test]
    fn zero_shards_is_typed() {
        assert!(matches!(
            Site::builder().gateway_shards(0).build(),
            Err(SiteError::NoShards)
        ));
    }

    #[test]
    fn zero_nodes_is_typed() {
        assert!(matches!(
            Site::builder().nodes(0).build(),
            Err(SiteError::EmptyCluster)
        ));
        assert!(matches!(
            Site::builder()
                .partition("empty", &SystemProfile::piz_daint(), 0)
                .build(),
            Err(SiteError::EmptyPartition(_))
        ));
    }

    #[test]
    fn tiny_node_cache_is_typed() {
        match Site::builder().node_cache_bytes(1_000).build() {
            Err(SiteError::NodeCacheTooSmall { bytes, floor }) => {
                assert_eq!(bytes, 1_000);
                assert_eq!(floor, MIN_NODE_CACHE_BYTES);
            }
            _ => panic!("expected NodeCacheTooSmall"),
        }
    }

    #[test]
    fn zero_attempt_retry_is_typed() {
        let retry = RetryPolicy {
            max_attempts: 0,
            ..RetryPolicy::default()
        };
        assert!(matches!(
            Site::builder().retry_policy(retry).build(),
            Err(SiteError::BadRetryPolicy)
        ));
    }

    #[test]
    fn bad_cascade_topology_is_typed() {
        assert!(matches!(
            Site::builder().cascade(0, 3).build(),
            Err(SiteError::EmptyCabinet)
        ));
        assert!(matches!(
            Site::builder().cascade(8, 0).build(),
            Err(SiteError::BadCascadeFanout)
        ));
        // a sane topology builds and reaches the fabric
        let site = Site::builder().nodes(4).cascade(8, 3).build().unwrap();
        let cfg = site.fabric().cascade_config().unwrap();
        assert_eq!((cfg.cabinet_nodes, cfg.fanout), (8, 3));
    }

    #[test]
    fn bad_chunk_target_is_typed() {
        match Site::builder().chunk_target_bytes(128).build() {
            Err(SiteError::BadChunkTarget {
                bytes,
                floor,
                ceiling,
            }) => {
                assert_eq!(bytes, 128);
                assert_eq!(floor, MIN_CHUNK_TARGET_BYTES);
                assert_eq!(ceiling, MAX_CHUNK_TARGET_BYTES);
            }
            _ => panic!("expected BadChunkTarget"),
        }
        assert!(matches!(
            Site::builder().chunk_target_bytes(1 << 40).build(),
            Err(SiteError::BadChunkTarget { .. })
        ));
        let site = Site::builder()
            .nodes(2)
            .chunk_target_bytes(1 << 20)
            .lazy_pull(true)
            .build()
            .unwrap();
        assert_eq!(site.fabric().chunk_target(), Some(1 << 20));
        assert!(site.fabric().lazy_pull_enabled());
        assert!(site.fabric().cluster().cas().chunked());
    }

    #[test]
    fn gpu_job_on_gpuless_site_is_typed() {
        let mut gpuless = SystemProfile::linux_cluster();
        gpuless.nodes[0].gpus.clear();
        let mut site = Site::builder()
            .profile(gpuless)
            .nodes(2)
            .build()
            .unwrap();
        let spec =
            JobSpec::new("nvidia/cuda-image:8.0", &["deviceQuery"], 2)
                .with_gpus(1);
        match site.launch(&spec) {
            Err(SiteError::GpuUnavailable { gpus_per_node }) => {
                assert_eq!(gpus_per_node, 1)
            }
            _ => panic!("expected GpuUnavailable"),
        }
        // CPU jobs on the same site are fine
        let cpu = JobSpec::new("ubuntu:xenial", &["true"], 2);
        assert_eq!(site.launch(&cpu).unwrap().succeeded(), 2);
    }

    #[test]
    fn bad_conf_text_is_typed() {
        assert!(matches!(
            Site::builder().config_conf("bogusKey = 1"),
            Err(SiteError::Config(_))
        ));
    }

    #[test]
    fn custom_conf_reaches_the_runtime() {
        let mut config =
            UdiRootConfig::for_profile(&SystemProfile::piz_daint());
        config.udi_mount_point = "/var/siteMount".to_string();
        let site = Site::builder()
            .config(config)
            .nodes(2)
            .build()
            .unwrap();
        assert_eq!(site.config().udi_mount_point, "/var/siteMount");
    }

    #[test]
    fn hetero_split_builds_both_partitions() {
        let site = Site::builder()
            .hetero_daint_linux(8)
            .build()
            .unwrap();
        let names: Vec<&str> = site
            .cluster()
            .partitions()
            .iter()
            .map(|p| p.name())
            .collect();
        assert_eq!(names, ["daint-xc50", "linux-cluster"]);
        assert_eq!(site.cluster().total_nodes(), 8);
        // an odd split below 2 nodes degenerates to a typed error, not a
        // panic
        assert!(matches!(
            Site::builder().hetero_daint_linux(1).build(),
            Err(SiteError::EmptyPartition(_))
        ));
    }
}
