//! Workload manager substrate (DESIGN.md S11): a SLURM-like allocator
//! with the Generic Resource (GRES) plugin behavior §IV.A relies on —
//! "some [workload managers] set the value of CUDA_VISIBLE_DEVICES upon
//! allocating jobs, providing fine-grained control over the resources
//! made available inside compute nodes".

pub mod alps;
pub mod fairshare;

pub use alps::{Alps, AprunRequest, SlurmWlm, WorkloadManager};
pub use fairshare::{ShareEntry, ShareLedger};

use std::collections::BTreeMap;

use crate::hostenv::SystemProfile;

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum WlmError {
    #[error("requested {requested} nodes but only {available} available")]
    NotEnoughNodes { requested: u32, available: u32 },
    #[error("requested gpu:{requested} but node {node} has {available} CUDA devices")]
    NotEnoughGpus {
        requested: u32,
        node: u32,
        available: u32,
    },
    #[error("ntasks {ntasks} exceeds allocation capacity {capacity}")]
    TooManyTasks { ntasks: u32, capacity: u32 },
}

/// `--gres=gpu:<N>` style request.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct GresRequest {
    pub gpus_per_node: u32,
}

impl GresRequest {
    /// Parse "gpu:N".
    pub fn parse(s: &str) -> Option<GresRequest> {
        let n = s.strip_prefix("gpu:")?.parse().ok()?;
        Some(GresRequest { gpus_per_node: n })
    }
}

/// `salloc -N <nodes>` result.
#[derive(Debug, Clone)]
pub struct Allocation {
    pub job_id: u64,
    pub nodes: Vec<u32>,
    pub cores_per_node: u32,
}

impl Allocation {
    pub fn capacity(&self) -> u32 {
        self.nodes.len() as u32 * self.cores_per_node
    }
}

/// Per-rank launch context produced by `srun`: where the rank runs and the
/// environment the WLM injects (CUDA_VISIBLE_DEVICES via GRES, PMI vars).
#[derive(Debug, Clone)]
pub struct RankContext {
    pub rank: u32,
    pub node: u32,
    pub local_rank: u32,
    pub env: BTreeMap<String, String>,
}

pub struct Slurm<'a> {
    system: &'a SystemProfile,
    next_job_id: u64,
}

impl<'a> Slurm<'a> {
    pub fn new(system: &'a SystemProfile) -> Slurm<'a> {
        Slurm {
            system,
            next_job_id: 1000,
        }
    }

    /// `salloc -N nodes`.
    pub fn salloc(&mut self, nodes: u32) -> Result<Allocation, WlmError> {
        let available = self.system.node_count();
        if nodes == 0 || nodes > available {
            return Err(WlmError::NotEnoughNodes {
                requested: nodes,
                available,
            });
        }
        let id = self.next_job_id;
        self.next_job_id += 1;
        Ok(Allocation {
            job_id: id,
            nodes: (0..nodes).collect(),
            cores_per_node: self.system.ranks_per_node(),
        })
    }

    /// `srun -n ntasks [--gres=gpu:N]`: place ranks block-wise over the
    /// allocation and build each rank's environment. With a GRES request
    /// the plugin sets CUDA_VISIBLE_DEVICES to the first N devices of each
    /// node; without one the variable is NOT set (§IV.A: Shifter then does
    /// not trigger GPU support).
    pub fn srun(
        &self,
        alloc: &Allocation,
        ntasks: u32,
        gres: Option<GresRequest>,
    ) -> Result<Vec<RankContext>, WlmError> {
        if ntasks == 0 || ntasks > alloc.capacity() {
            return Err(WlmError::TooManyTasks {
                ntasks,
                capacity: alloc.capacity(),
            });
        }
        // validate GRES against every allocated node
        if let Some(g) = gres {
            for &n in &alloc.nodes {
                let have = self
                    .system
                    .driver(n as usize)
                    .map(|d| d.cuda_device_count())
                    .unwrap_or(0);
                if g.gpus_per_node > have {
                    return Err(WlmError::NotEnoughGpus {
                        requested: g.gpus_per_node,
                        node: n,
                        available: have,
                    });
                }
            }
        }
        let per_node = ntasks.div_ceil(alloc.nodes.len() as u32);
        let mut out = Vec::with_capacity(ntasks as usize);
        for rank in 0..ntasks {
            let node_idx = (rank / per_node) as usize;
            let node = alloc.nodes[node_idx.min(alloc.nodes.len() - 1)];
            let local_rank = rank % per_node;
            let mut env = BTreeMap::new();
            env.insert("SLURM_JOB_ID".into(), alloc.job_id.to_string());
            env.insert("SLURM_PROCID".into(), rank.to_string());
            env.insert("SLURM_NTASKS".into(), ntasks.to_string());
            env.insert("SLURM_LOCALID".into(), local_rank.to_string());
            env.insert("PMI_RANK".into(), rank.to_string());
            if let Some(g) = gres {
                let devs: Vec<String> =
                    (0..g.gpus_per_node).map(|d| d.to_string()).collect();
                env.insert("CUDA_VISIBLE_DEVICES".into(), devs.join(","));
            }
            out.push(RankContext {
                rank,
                node,
                local_rank,
                env,
            });
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    #[test]
    fn gres_parse() {
        assert_eq!(
            GresRequest::parse("gpu:2"),
            Some(GresRequest { gpus_per_node: 2 })
        );
        assert_eq!(GresRequest::parse("gpu:"), None);
        assert_eq!(GresRequest::parse("mic:1"), None);
    }

    #[test]
    fn salloc_bounds() {
        let pd = SystemProfile::piz_daint();
        let mut s = Slurm::new(&pd);
        assert!(s.salloc(8).is_ok());
        assert!(s.salloc(0).is_err());
        assert!(s.salloc(10_000).is_err());
    }

    #[test]
    fn srun_sets_cuda_visible_devices_with_gres() {
        let pd = SystemProfile::piz_daint();
        let mut s = Slurm::new(&pd);
        let alloc = s.salloc(2).unwrap();
        let ranks = s
            .srun(&alloc, 2, Some(GresRequest { gpus_per_node: 1 }))
            .unwrap();
        assert_eq!(ranks.len(), 2);
        assert_eq!(
            ranks[0].env.get("CUDA_VISIBLE_DEVICES").map(|s| s.as_str()),
            Some("0")
        );
        // one rank per node
        assert_ne!(ranks[0].node, ranks[1].node);
    }

    #[test]
    fn srun_without_gres_leaves_cvd_unset() {
        let pd = SystemProfile::piz_daint();
        let mut s = Slurm::new(&pd);
        let alloc = s.salloc(1).unwrap();
        let ranks = s.srun(&alloc, 4, None).unwrap();
        assert!(ranks.iter().all(|r| !r.env.contains_key("CUDA_VISIBLE_DEVICES")));
    }

    #[test]
    fn gres_request_exceeding_node_gpus_fails() {
        let pd = SystemProfile::piz_daint(); // 1 P100 per node
        let mut s = Slurm::new(&pd);
        let alloc = s.salloc(1).unwrap();
        let err = s
            .srun(&alloc, 1, Some(GresRequest { gpus_per_node: 2 }))
            .unwrap_err();
        assert!(matches!(err, WlmError::NotEnoughGpus { .. }));
        // the cluster node has 3 CUDA devices (K40m + 2 K80 chips)
        let cl = SystemProfile::linux_cluster();
        let mut s = Slurm::new(&cl);
        let alloc = s.salloc(2).unwrap();
        assert!(s
            .srun(&alloc, 2, Some(GresRequest { gpus_per_node: 2 }))
            .is_ok());
    }

    #[test]
    fn block_placement_fills_nodes() {
        let pd = SystemProfile::piz_daint();
        let mut s = Slurm::new(&pd);
        let alloc = s.salloc(4).unwrap();
        let ranks = s.srun(&alloc, 48, None).unwrap();
        // 12 ranks per node, block-wise
        assert_eq!(ranks[0].node, ranks[11].node);
        assert_ne!(ranks[0].node, ranks[12].node);
        assert_eq!(ranks[47].node, 3);
        let err = s.srun(&alloc, 49, None).unwrap_err();
        assert!(matches!(err, WlmError::TooManyTasks { .. }));
    }
}
