//! ALPS (Application Level Placement Scheduler) — the Cray workload
//! manager the paper lists alongside SLURM ("workload manager integration
//! (e.g. SLURM and ALPS)", §III). `aprun -n <ranks> -N <per-node>` style
//! placement; GPU visibility comes from the `CRAY_CUDA_MPS`-era convention
//! of exporting CUDA_VISIBLE_DEVICES for the node's devices.

use std::collections::BTreeMap;

use crate::hostenv::SystemProfile;

use super::{RankContext, WlmError};

/// An `aprun` launch request.
#[derive(Debug, Clone, Copy)]
pub struct AprunRequest {
    /// -n: total ranks (PEs).
    pub ranks: u32,
    /// -N: ranks per node.
    pub per_node: u32,
    /// expose the node's GPUs to the application?
    pub gpus: bool,
}

pub struct Alps<'a> {
    system: &'a SystemProfile,
    next_apid: u64,
}

impl<'a> Alps<'a> {
    pub fn new(system: &'a SystemProfile) -> Alps<'a> {
        Alps {
            system,
            next_apid: 52000,
        }
    }

    /// Place an `aprun`: contiguous node range, block placement.
    pub fn aprun(&mut self, req: AprunRequest) -> Result<Vec<RankContext>, WlmError> {
        if req.per_node == 0 || req.per_node > self.system.ranks_per_node() {
            return Err(WlmError::TooManyTasks {
                ntasks: req.per_node,
                capacity: self.system.ranks_per_node(),
            });
        }
        let nodes_needed = req.ranks.div_ceil(req.per_node);
        if req.ranks == 0 || nodes_needed > self.system.node_count() {
            return Err(WlmError::NotEnoughNodes {
                requested: nodes_needed,
                available: self.system.node_count(),
            });
        }
        let apid = self.next_apid;
        self.next_apid += 1;

        let mut out = Vec::with_capacity(req.ranks as usize);
        for rank in 0..req.ranks {
            let node = rank / req.per_node;
            let local_rank = rank % req.per_node;
            let mut env = BTreeMap::new();
            env.insert("ALPS_APP_ID".into(), apid.to_string());
            env.insert("ALPS_APP_PE".into(), rank.to_string());
            env.insert("PMI_RANK".into(), rank.to_string());
            env.insert("PMI_SIZE".into(), req.ranks.to_string());
            if req.gpus {
                let have = self
                    .system
                    .driver(node as usize)
                    .map(|d| d.cuda_device_count())
                    .unwrap_or(0);
                if have == 0 {
                    return Err(WlmError::NotEnoughGpus {
                        requested: 1,
                        node,
                        available: 0,
                    });
                }
                let devs: Vec<String> = (0..have).map(|d| d.to_string()).collect();
                env.insert("CUDA_VISIBLE_DEVICES".into(), devs.join(","));
            }
            out.push(RankContext {
                rank,
                node,
                local_rank,
                env,
            });
        }
        Ok(out)
    }
}

/// The workload-manager abstraction the Shifter docs describe: both SLURM
/// and ALPS produce per-rank launch contexts the runtime consumes.
pub trait WorkloadManager {
    fn launch(
        &mut self,
        ranks: u32,
        per_node: u32,
        gpus_per_node: u32,
    ) -> Result<Vec<RankContext>, WlmError>;
}

impl<'a> WorkloadManager for Alps<'a> {
    fn launch(
        &mut self,
        ranks: u32,
        per_node: u32,
        gpus_per_node: u32,
    ) -> Result<Vec<RankContext>, WlmError> {
        self.aprun(AprunRequest {
            ranks,
            per_node,
            gpus: gpus_per_node > 0,
        })
    }
}

/// SLURM adapter over the same trait.
pub struct SlurmWlm<'a> {
    inner: super::Slurm<'a>,
}

impl<'a> SlurmWlm<'a> {
    pub fn new(system: &'a SystemProfile) -> SlurmWlm<'a> {
        SlurmWlm {
            inner: super::Slurm::new(system),
        }
    }
}

impl<'a> WorkloadManager for SlurmWlm<'a> {
    fn launch(
        &mut self,
        ranks: u32,
        per_node: u32,
        gpus_per_node: u32,
    ) -> Result<Vec<RankContext>, WlmError> {
        let nodes = ranks.div_ceil(per_node);
        let alloc = self.inner.salloc(nodes)?;
        let gres = (gpus_per_node > 0).then_some(super::GresRequest {
            gpus_per_node,
        });
        self.inner.srun(&alloc, ranks, gres)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    #[test]
    fn aprun_block_placement() {
        let pd = SystemProfile::piz_daint();
        let mut alps = Alps::new(&pd);
        let ranks = alps
            .aprun(AprunRequest {
                ranks: 24,
                per_node: 12,
                gpus: false,
            })
            .unwrap();
        assert_eq!(ranks.len(), 24);
        assert_eq!(ranks[0].node, 0);
        assert_eq!(ranks[11].node, 0);
        assert_eq!(ranks[12].node, 1);
        assert_eq!(ranks[23].local_rank, 11);
        assert!(ranks[0].env.contains_key("ALPS_APP_ID"));
        assert!(!ranks[0].env.contains_key("CUDA_VISIBLE_DEVICES"));
    }

    #[test]
    fn aprun_gpu_mode_exports_cvd() {
        let pd = SystemProfile::piz_daint();
        let mut alps = Alps::new(&pd);
        let ranks = alps
            .aprun(AprunRequest {
                ranks: 2,
                per_node: 1,
                gpus: true,
            })
            .unwrap();
        assert_eq!(ranks[0].env.get("CUDA_VISIBLE_DEVICES").unwrap(), "0");
    }

    #[test]
    fn aprun_bounds() {
        let pd = SystemProfile::piz_daint();
        let mut alps = Alps::new(&pd);
        assert!(alps
            .aprun(AprunRequest {
                ranks: 0,
                per_node: 1,
                gpus: false
            })
            .is_err());
        assert!(alps
            .aprun(AprunRequest {
                ranks: 1,
                per_node: 100,
                gpus: false
            })
            .is_err());
        assert!(alps
            .aprun(AprunRequest {
                ranks: 1_000_000,
                per_node: 12,
                gpus: false
            })
            .is_err());
    }

    #[test]
    fn trait_parity_between_slurm_and_alps() {
        // both WLMs produce equivalent launch contexts for the same job
        let pd = SystemProfile::piz_daint();
        let mut alps = Alps::new(&pd);
        let mut slurm = SlurmWlm::new(&pd);
        let a = alps.launch(8, 4, 1).unwrap();
        let s = slurm.launch(8, 4, 1).unwrap();
        assert_eq!(a.len(), s.len());
        for (ra, rs) in a.iter().zip(&s) {
            assert_eq!(ra.rank, rs.rank);
            assert_eq!(ra.node, rs.node);
            assert_eq!(
                ra.env.get("CUDA_VISIBLE_DEVICES"),
                rs.env.get("CUDA_VISIBLE_DEVICES")
            );
            assert_eq!(ra.env.get("PMI_RANK"), rs.env.get("PMI_RANK"));
        }
    }

    #[test]
    fn apids_increment() {
        let pd = SystemProfile::piz_daint();
        let mut alps = Alps::new(&pd);
        let a = alps
            .aprun(AprunRequest { ranks: 1, per_node: 1, gpus: false })
            .unwrap();
        let b = alps
            .aprun(AprunRequest { ranks: 1, per_node: 1, gpus: false })
            .unwrap();
        assert_ne!(
            a[0].env.get("ALPS_APP_ID"),
            b[0].env.get("ALPS_APP_ID")
        );
    }
}
