//! Fair-share accounting (DESIGN.md S20): the per-tenant usage ledger and
//! priority function the multi-tenant scheduler (`crate::tenancy`) orders
//! its queue with.
//!
//! The model is SLURM's classic fair-share formula: each tenant holds a
//! configured number of *shares*; consumed node-seconds accumulate as
//! *usage*; the fair-share factor is `2^(-U/S)` where `U` is the tenant's
//! fraction of total usage and `S` its fraction of total shares. A tenant
//! consuming exactly its share sits at 0.5, an idle tenant at 1.0, a hog
//! decays toward 0. Priority adds a linear *aging* term on top, so a job
//! that has waited long enough always overtakes any share imbalance —
//! the bounded-starvation guarantee `benches/tenancy_storm.rs` asserts.

use std::collections::BTreeMap;

/// One tenant's row in the ledger.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ShareEntry {
    /// Configured share weight (relative to the sum over all tenants).
    pub shares: f64,
    /// Node-seconds charged to this tenant so far.
    pub usage_node_secs: f64,
}

/// Per-tenant share and usage accounting.
///
/// Tenants are keyed by name; unknown tenants are created on first touch
/// with a default share weight of 1.0 (equal shares).
#[derive(Debug, Clone, Default)]
pub struct ShareLedger {
    entries: BTreeMap<String, ShareEntry>,
}

impl ShareLedger {
    /// Empty ledger.
    pub fn new() -> ShareLedger {
        ShareLedger::default()
    }

    /// Register `tenant` with an explicit share weight (builder-style).
    pub fn with_tenant(mut self, tenant: &str, shares: f64) -> ShareLedger {
        assert!(shares > 0.0, "shares must be positive");
        self.entries.insert(
            tenant.to_string(),
            ShareEntry {
                shares,
                usage_node_secs: 0.0,
            },
        );
        self
    }

    /// Make sure `tenant` exists (default weight 1.0).
    pub fn ensure(&mut self, tenant: &str) {
        self.entries
            .entry(tenant.to_string())
            .or_insert(ShareEntry {
                shares: 1.0,
                usage_node_secs: 0.0,
            });
    }

    /// Charge `node_secs` of cluster time to `tenant`.
    pub fn charge(&mut self, tenant: &str, node_secs: f64) {
        self.entries
            .entry(tenant.to_string())
            .or_insert(ShareEntry {
                shares: 1.0,
                usage_node_secs: 0.0,
            })
            .usage_node_secs += node_secs;
    }

    /// Node-seconds charged to `tenant` so far (0.0 if unknown).
    pub fn usage(&self, tenant: &str) -> f64 {
        self.entries
            .get(tenant)
            .map_or(0.0, |e| e.usage_node_secs)
    }

    /// Number of tenants the ledger knows about.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when no tenant is registered.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// `tenant`'s fraction of the total configured shares (0.0 if the
    /// ledger is empty or the tenant unknown).
    pub fn share_fraction(&self, tenant: &str) -> f64 {
        let total: f64 = self.entries.values().map(|e| e.shares).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.entries
            .get(tenant)
            .map_or(0.0, |e| e.shares / total)
    }

    /// `tenant`'s fraction of the total charged usage (0.0 while nothing
    /// has been charged anywhere — everyone starts even).
    pub fn usage_fraction(&self, tenant: &str) -> f64 {
        let total: f64 =
            self.entries.values().map(|e| e.usage_node_secs).sum();
        if total <= 0.0 {
            return 0.0;
        }
        self.entries
            .get(tenant)
            .map_or(0.0, |e| e.usage_node_secs / total)
    }

    /// SLURM-style fair-share factor `2^(-U/S)` in (0, 1]: 1.0 for an
    /// idle tenant, 0.5 for one consuming exactly its share, decaying
    /// toward 0 for a hog.
    pub fn fair_share_factor(&self, tenant: &str) -> f64 {
        let share = self.share_fraction(tenant);
        if share <= 0.0 {
            // a tenant with no shares configured ranks below everyone
            return 0.0;
        }
        let ratio = self.usage_fraction(tenant) / share;
        (-ratio).exp2()
    }

    /// Queue priority for a job of `tenant` that has waited `age_secs`:
    /// fair-share factor plus linear aging (`aging_per_hour` priority
    /// points per hour of wait). Because the share term is bounded by 1.0
    /// while aging grows without bound, any positive `aging_per_hour`
    /// guarantees a waiting job eventually outranks every fresher job.
    pub fn priority(
        &self,
        tenant: &str,
        age_secs: f64,
        aging_per_hour: f64,
    ) -> f64 {
        self.fair_share_factor(tenant)
            + aging_per_hour * age_secs.max(0.0) / 3600.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn even_ledger_starts_even() {
        let mut l = ShareLedger::new();
        l.ensure("a");
        l.ensure("b");
        assert_eq!(l.len(), 2);
        assert!((l.share_fraction("a") - 0.5).abs() < 1e-12);
        assert_eq!(l.usage_fraction("a"), 0.0);
        assert!((l.fair_share_factor("a") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn hog_decays_below_light_user() {
        let mut l = ShareLedger::new();
        l.ensure("hog");
        l.ensure("light");
        l.charge("hog", 9000.0);
        l.charge("light", 1000.0);
        let hog = l.fair_share_factor("hog");
        let light = l.fair_share_factor("light");
        assert!(hog < light, "hog {hog} must rank below light {light}");
        // consuming exactly your share sits at 0.5
        let mut even = ShareLedger::new();
        even.ensure("a");
        even.ensure("b");
        even.charge("a", 500.0);
        even.charge("b", 500.0);
        assert!((even.fair_share_factor("a") - 0.5).abs() < 1e-12);
    }

    #[test]
    fn weighted_shares_shift_the_factor() {
        let mut l = ShareLedger::new()
            .with_tenant("big", 3.0)
            .with_tenant("small", 1.0);
        // both consume the same absolute usage; "big" is entitled to 3x,
        // so its factor must stay higher
        l.charge("big", 500.0);
        l.charge("small", 500.0);
        assert!(l.fair_share_factor("big") > l.fair_share_factor("small"));
    }

    #[test]
    fn aging_overtakes_any_share_gap() {
        let mut l = ShareLedger::new();
        l.ensure("hog");
        l.ensure("idle");
        l.charge("hog", 1e9); // factor ~ 0
        let fresh_idle = l.priority("idle", 0.0, 2.0);
        // after half an hour of waiting, the hog's job outranks a fresh
        // job from the fully idle tenant (factor gap is at most 1.0)
        let aged_hog = l.priority("hog", 1800.0, 2.0);
        assert!(aged_hog > fresh_idle);
        // with zero age both orderings follow the factor alone
        assert!(l.priority("hog", 0.0, 2.0) < fresh_idle);
    }

    #[test]
    fn unknown_tenant_is_created_on_charge() {
        let mut l = ShareLedger::new();
        assert!(l.is_empty());
        l.charge("new", 10.0);
        assert_eq!(l.usage("new"), 10.0);
        assert_eq!(l.len(), 1);
    }
}
