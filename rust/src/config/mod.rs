//! Runtime configuration (DESIGN.md S17): the udiRoot.conf analog.
//!
//! §IV.B: "Shifter MPI support uses parameters that are set by the system
//! administrator on the Runtime configuration file which specify: the full
//! path of the host's MPI frontend shared libraries; the full paths to the
//! host's shared libraries upon which the host MPI libraries depend; the
//! full paths to any configuration files and folders used by the host's
//! MPI libraries." Plus the site mounts and GPU directories §III.A/§IV.A
//! use. Serializable to/from a simple `key = value` format.

use crate::hostenv::SystemProfile;

/// One site-configured bind mount grafted into every container
/// (`siteFs = /host:/container:rw|ro` in `udiRoot.conf`).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SiteMount {
    /// Host directory to bind.
    pub host_path: String,
    /// Mount target inside every container.
    pub container_path: String,
    /// Whether the bind is read-only.
    pub read_only: bool,
}

/// The site runtime configuration — the `udiRoot.conf` a site
/// administrator writes once (§IV.A/§IV.B site parameters), and the
/// config input of the [`crate::Site`] facade
/// ([`crate::SiteBuilder::config`] / [`crate::SiteBuilder::config_conf`]).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct UdiRootConfig {
    /// Where the container root is assembled on each compute node.
    pub udi_mount_point: String,
    /// Site-specific directories grafted into every container (§III.A:
    /// "parallel filesystem directories … site-specific tools").
    pub site_mounts: Vec<SiteMount>,
    /// Host MPI frontend libraries (libmpi/libmpicxx/libmpifort).
    pub mpi_frontend_paths: Vec<String>,
    /// Host libraries the MPI depends on.
    pub mpi_dependency_paths: Vec<String>,
    /// Host MPI config files/folders.
    pub mpi_config_paths: Vec<String>,
    /// Host fabric transport libraries the specialized-network extension
    /// bind-mounts (uGNI/DMAPP on Aries, verbs/RDMA on InfiniBand).
    pub net_transport_paths: Vec<String>,
    /// Fabric device files the specialized-network extension grafts
    /// (`/dev/kgni0`, `/dev/hugepages`, `/dev/infiniband/*`).
    pub net_device_paths: Vec<String>,
    /// Host directory with NVIDIA driver libraries.
    pub gpu_lib_dir: String,
    /// Host directory with NVIDIA binaries (nvidia-smi).
    pub gpu_bin_dir: String,
    /// Host env vars exported into containers (§III.A: "selected variables
    /// from the host system are also added").
    pub host_env_allowlist: Vec<String>,
}

/// `udiRoot.conf` parse failures, with 1-based line numbers.
#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum ConfigError {
    /// The line is neither `key = value`, a comment, nor blank — or a
    /// structured value (`siteFs`) is missing required fields.
    #[error("config line {0}: expected 'key = value'")]
    BadLine(usize),
    /// The key is not part of the `udiRoot.conf` schema.
    #[error("unknown config key: {0}")]
    UnknownKey(String),
}

impl UdiRootConfig {
    /// The configuration a site administrator would write for `profile`.
    pub fn for_profile(profile: &SystemProfile) -> UdiRootConfig {
        let mpi_lib_dir = format!("{}/lib", profile.mpi_prefix);
        UdiRootConfig {
            udi_mount_point: "/var/udiMount".to_string(),
            site_mounts: vec![
                SiteMount {
                    host_path: "/scratch".into(),
                    container_path: "/scratch".into(),
                    read_only: false,
                },
                SiteMount {
                    host_path: "/home".into(),
                    container_path: "/home".into(),
                    read_only: false,
                },
                SiteMount {
                    host_path: "/var/tmp".into(),
                    container_path: "/var/tmp".into(),
                    read_only: false,
                },
            ],
            mpi_frontend_paths: profile
                .host_mpi
                .frontend_libraries()
                .iter()
                .map(|l| format!("{mpi_lib_dir}/{l}"))
                .collect(),
            mpi_dependency_paths: profile.mpi_dependency_libs(),
            mpi_config_paths: profile.mpi_config_paths(),
            net_transport_paths: profile.net_transport_libs(),
            net_device_paths: profile.net_device_files(),
            gpu_lib_dir: profile.gpu_lib_dir.to_string(),
            gpu_bin_dir: profile.gpu_bin_dir.to_string(),
            host_env_allowlist: vec![
                "CUDA_VISIBLE_DEVICES".into(),
                "SHIFTER_NET".into(),
                "SLURM_JOB_ID".into(),
                "SLURM_PROCID".into(),
                "SLURM_NTASKS".into(),
                "SLURM_LOCALID".into(),
                "PMI_RANK".into(),
            ],
        }
    }

    /// Serialize to the `key = value` config-file format.
    pub fn to_conf(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!("udiMount = {}\n", self.udi_mount_point));
        for m in &self.site_mounts {
            out.push_str(&format!(
                "siteFs = {}:{}:{}\n",
                m.host_path,
                m.container_path,
                if m.read_only { "ro" } else { "rw" }
            ));
        }
        for p in &self.mpi_frontend_paths {
            out.push_str(&format!("mpiFrontend = {p}\n"));
        }
        for p in &self.mpi_dependency_paths {
            out.push_str(&format!("mpiDependency = {p}\n"));
        }
        for p in &self.mpi_config_paths {
            out.push_str(&format!("mpiConfig = {p}\n"));
        }
        for p in &self.net_transport_paths {
            out.push_str(&format!("netTransport = {p}\n"));
        }
        for p in &self.net_device_paths {
            out.push_str(&format!("netDevice = {p}\n"));
        }
        out.push_str(&format!("gpuLibDir = {}\n", self.gpu_lib_dir));
        out.push_str(&format!("gpuBinDir = {}\n", self.gpu_bin_dir));
        for v in &self.host_env_allowlist {
            out.push_str(&format!("hostEnv = {v}\n"));
        }
        out
    }

    /// Parse the `key = value` format (inverse of `to_conf`).
    pub fn from_conf(text: &str) -> Result<UdiRootConfig, ConfigError> {
        let mut cfg = UdiRootConfig::default();
        for (i, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .ok_or(ConfigError::BadLine(i + 1))?;
            let (k, v) = (k.trim(), v.trim());
            match k {
                "udiMount" => cfg.udi_mount_point = v.to_string(),
                "siteFs" => {
                    // strict: exactly host:container with an optional
                    // ro/rw mode — a typoed mode flag must not silently
                    // downgrade a read-only mount to read-write
                    let parts: Vec<&str> = v.split(':').collect();
                    let (host, cont, ro) = match parts.as_slice() {
                        [h, c] => (*h, *c, false),
                        [h, c, "ro"] => (*h, *c, true),
                        [h, c, "rw"] => (*h, *c, false),
                        _ => return Err(ConfigError::BadLine(i + 1)),
                    };
                    if host.is_empty() || cont.is_empty() {
                        return Err(ConfigError::BadLine(i + 1));
                    }
                    cfg.site_mounts.push(SiteMount {
                        host_path: host.to_string(),
                        container_path: cont.to_string(),
                        read_only: ro,
                    });
                }
                "mpiFrontend" => cfg.mpi_frontend_paths.push(v.to_string()),
                "mpiDependency" => cfg.mpi_dependency_paths.push(v.to_string()),
                "mpiConfig" => cfg.mpi_config_paths.push(v.to_string()),
                "netTransport" => {
                    cfg.net_transport_paths.push(v.to_string())
                }
                "netDevice" => cfg.net_device_paths.push(v.to_string()),
                "gpuLibDir" => cfg.gpu_lib_dir = v.to_string(),
                "gpuBinDir" => cfg.gpu_bin_dir = v.to_string(),
                "hostEnv" => cfg.host_env_allowlist.push(v.to_string()),
                other => return Err(ConfigError::UnknownKey(other.to_string())),
            }
        }
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::hostenv::SystemProfile;

    #[test]
    fn profile_config_lists_mpi_paths() {
        let pd = SystemProfile::piz_daint();
        let cfg = UdiRootConfig::for_profile(&pd);
        assert_eq!(cfg.mpi_frontend_paths.len(), 3);
        assert!(cfg.mpi_frontend_paths[0].contains("libmpi"));
        assert!(cfg
            .mpi_dependency_paths
            .iter()
            .any(|p| p.contains("libugni")));
        assert!(!cfg.mpi_config_paths.is_empty());
    }

    #[test]
    fn conf_roundtrip() {
        let cfg = UdiRootConfig::for_profile(&SystemProfile::linux_cluster());
        let text = cfg.to_conf();
        let back = UdiRootConfig::from_conf(&text).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn parse_emit_parse_is_a_fixpoint_for_every_profile() {
        // the facade's config input: parse -> emit -> parse must agree
        // both structurally and textually for all three §V.A profiles
        for profile in [
            SystemProfile::laptop(),
            SystemProfile::linux_cluster(),
            SystemProfile::piz_daint(),
        ] {
            let cfg = UdiRootConfig::for_profile(&profile);
            let text = cfg.to_conf();
            let parsed = UdiRootConfig::from_conf(&text).unwrap();
            assert_eq!(cfg, parsed, "{}", profile.name);
            assert_eq!(
                text,
                parsed.to_conf(),
                "{}: emit must be a fixpoint",
                profile.name
            );
        }
    }

    #[test]
    fn read_only_site_mounts_round_trip() {
        let mut cfg = UdiRootConfig::for_profile(&SystemProfile::laptop());
        cfg.site_mounts.push(SiteMount {
            host_path: "/opt/site-tools".into(),
            container_path: "/opt/tools".into(),
            read_only: true,
        });
        let back = UdiRootConfig::from_conf(&cfg.to_conf()).unwrap();
        assert_eq!(cfg, back);
        let ro = back
            .site_mounts
            .iter()
            .find(|m| m.container_path == "/opt/tools")
            .unwrap();
        assert!(ro.read_only);
        // and the emitted line carries the flag explicitly
        assert!(cfg.to_conf().contains("/opt/site-tools:/opt/tools:ro"));
    }

    #[test]
    fn whitespace_and_inline_spacing_are_tolerated() {
        let cfg = UdiRootConfig::from_conf(
            "  udiMount   =   /var/udiMount  \n\
             \tsiteFs = /scratch:/scratch:rw\n",
        )
        .unwrap();
        assert_eq!(cfg.udi_mount_point, "/var/udiMount");
        assert_eq!(cfg.site_mounts.len(), 1);
        assert!(!cfg.site_mounts[0].read_only);
    }

    #[test]
    fn malformed_lines_report_their_line_number() {
        // a bad line after valid ones must name its own (1-based) line
        let text = "udiMount = /var/udiMount\n# fine\n\nnot a pair\n";
        match UdiRootConfig::from_conf(text) {
            Err(ConfigError::BadLine(4)) => {}
            other => panic!("wrong: {other:?}"),
        }
        // a siteFs missing its container half is a bad line, not a
        // silently half-parsed mount
        match UdiRootConfig::from_conf("siteFs = /scratch") {
            Err(ConfigError::BadLine(1)) => {}
            other => panic!("wrong: {other:?}"),
        }
        // a typoed mode flag must be rejected, not silently parsed as rw
        for bad in [
            "siteFs = /a:/b:readonly",
            "siteFs = /a:/b:r0",
            "siteFs = /a:/b:ro:extra",
        ] {
            match UdiRootConfig::from_conf(bad) {
                Err(ConfigError::BadLine(1)) => {}
                other => panic!("{bad}: wrong: {other:?}"),
            }
        }
    }

    #[test]
    fn unknown_keys_name_the_offender() {
        match UdiRootConfig::from_conf("udiRoot = /x") {
            Err(ConfigError::UnknownKey(k)) => assert_eq!(k, "udiRoot"),
            other => panic!("wrong: {other:?}"),
        }
    }

    #[test]
    fn repeated_list_keys_accumulate_and_scalar_keys_overwrite() {
        let cfg = UdiRootConfig::from_conf(
            "hostEnv = A\nhostEnv = B\nudiMount = /first\nudiMount = /second\n",
        )
        .unwrap();
        assert_eq!(cfg.host_env_allowlist, vec!["A", "B"]);
        assert_eq!(cfg.udi_mount_point, "/second");
    }

    #[test]
    fn config_error_chains_through_the_site_facade() {
        // ConfigError implements std::error::Error and surfaces as the
        // source() of the facade's SiteError::Config wrapper
        use std::error::Error as _;
        let err = crate::Site::builder()
            .config_conf("bogusKey = 1")
            .unwrap_err();
        let source = err.source().expect("SiteError::Config chains");
        assert!(source.to_string().contains("bogusKey"), "{source}");
    }

    #[test]
    fn parse_rejects_unknown_keys_and_bad_lines() {
        assert!(matches!(
            UdiRootConfig::from_conf("bogusKey = 1"),
            Err(ConfigError::UnknownKey(_))
        ));
        assert!(matches!(
            UdiRootConfig::from_conf("no equals sign"),
            Err(ConfigError::BadLine(1))
        ));
        assert!(matches!(
            UdiRootConfig::from_conf("siteFs = onlyhost"),
            Err(ConfigError::BadLine(1))
        ));
    }

    #[test]
    fn comments_and_blanks_ignored() {
        let cfg =
            UdiRootConfig::from_conf("# comment\n\nudiMount = /var/udiMount\n")
                .unwrap();
        assert_eq!(cfg.udi_mount_point, "/var/udiMount");
    }

    #[test]
    fn allowlist_includes_cuda_visible_devices() {
        // §IV.A depends on the host env var reaching the container
        let cfg = UdiRootConfig::for_profile(&SystemProfile::laptop());
        assert!(cfg
            .host_env_allowlist
            .contains(&"CUDA_VISIBLE_DEVICES".to_string()));
    }
}
