//! Catalog of MPI implementations the paper involves (§IV.B, §V).
//!
//! Members of the MPICH ABI Compatibility Initiative (with the versions the
//! paper lists as the first conforming releases):
//!   MPICH v3.1 (Feb 2014), IBM MPI v2.1 (Dec 2014), Intel MPI v5.0
//!   (Jun 2014), Cray MPT v7.0.0 (Jun 2014), MVAPICH2 v2.0 (Jun 2014).

use super::abi::{LibtoolAbi, MPICH_ABI_SONAME, MPI_FRONTEND_LIBRARIES};
use crate::fabric::FabricKind;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum MpiVendor {
    Mpich,
    Mvapich2,
    IntelMpi,
    CrayMpt,
    IbmMpi,
    OpenMpi,
}

impl MpiVendor {
    pub fn name(&self) -> &'static str {
        match self {
            MpiVendor::Mpich => "MPICH",
            MpiVendor::Mvapich2 => "MVAPICH2",
            MpiVendor::IntelMpi => "Intel MPI",
            MpiVendor::CrayMpt => "Cray MPT",
            MpiVendor::IbmMpi => "IBM MPI",
            MpiVendor::OpenMpi => "Open MPI",
        }
    }
}

/// An installed MPI implementation (host-side or inside a container image).
#[derive(Debug, Clone, PartialEq)]
pub struct MpiImpl {
    pub vendor: MpiVendor,
    pub version: (u32, u32, u32),
    pub abi: LibtoolAbi,
    /// Fabrics this build has transport modules for. A stock container
    /// build (ch3:nemesis tcp) lists none of the HPC fabrics.
    pub native_fabrics: Vec<FabricKind>,
}

impl MpiImpl {
    fn new(
        vendor: MpiVendor,
        version: (u32, u32, u32),
        abi: LibtoolAbi,
        native_fabrics: Vec<FabricKind>,
    ) -> Self {
        MpiImpl {
            vendor,
            version,
            abi,
            native_fabrics,
        }
    }

    /// First initiative-conforming release per vendor; anything older is
    /// not ABI-swappable.
    pub fn mpich_abi_member(&self) -> bool {
        match self.vendor {
            MpiVendor::Mpich => self.version >= (3, 1, 0),
            MpiVendor::IbmMpi => self.version >= (2, 1, 0),
            MpiVendor::IntelMpi => self.version >= (5, 0, 0),
            MpiVendor::CrayMpt => self.version >= (7, 0, 0),
            MpiVendor::Mvapich2 => self.version >= (2, 0, 0),
            MpiVendor::OpenMpi => false, // never joined the initiative
        }
    }

    pub fn version_string(&self) -> String {
        format!(
            "{} {}.{}.{}",
            self.vendor.name(),
            self.version.0,
            self.version.1,
            self.version.2
        )
    }

    /// Frontend libraries this implementation ships (initiative names).
    pub fn frontend_libraries(&self) -> Vec<String> {
        if self.mpich_abi_member() {
            MPI_FRONTEND_LIBRARIES.iter().map(|s| s.to_string()).collect()
        } else {
            vec![format!("libmpi.so.{}", self.abi.soname_major())]
        }
    }

    /// Does this build drive `fabric` hardware directly?
    pub fn supports_fabric(&self, fabric: FabricKind) -> bool {
        fabric == FabricKind::Loopback || self.native_fabrics.contains(&fabric)
    }

    // ---- catalog: container-side builds (built from source on the laptop)

    /// MPICH 3.1.4 — container A of Tables III/IV, and the PyFR/Pynamic
    /// image MPI. Stock build: TCP only.
    pub fn mpich_3_1_4_container() -> MpiImpl {
        Self::new(
            MpiVendor::Mpich,
            (3, 1, 4),
            LibtoolAbi::new(12, 0, 0),
            vec![],
        )
    }

    /// MPICH 3.2 — the laptop host MPI (§V.A).
    pub fn mpich_3_2_host() -> MpiImpl {
        Self::new(
            MpiVendor::Mpich,
            (3, 2, 0),
            LibtoolAbi::new(12, 1, 0),
            vec![],
        )
    }

    /// MVAPICH2 2.2 — container B.
    pub fn mvapich2_2_2_container() -> MpiImpl {
        Self::new(
            MpiVendor::Mvapich2,
            (2, 2, 0),
            LibtoolAbi::new(12, 5, 0),
            vec![],
        )
    }

    /// Intel MPI 2017 update 1 — container C.
    pub fn intel_2017_1_container() -> MpiImpl {
        Self::new(
            MpiVendor::IntelMpi,
            (2017, 1, 0),
            LibtoolAbi::new(12, 6, 0),
            vec![],
        )
    }

    // ---- catalog: host-side builds

    /// MVAPICH2 2.1 over InfiniBand — the Linux Cluster host MPI.
    pub fn mvapich2_2_1_host_ib() -> MpiImpl {
        Self::new(
            MpiVendor::Mvapich2,
            (2, 1, 0),
            LibtoolAbi::new(12, 4, 0),
            vec![FabricKind::InfinibandEdr],
        )
    }

    /// MVAPICH2 2.2b over InfiniBand (the cluster's §V.A listing).
    pub fn mvapich2_2_2b_host_ib() -> MpiImpl {
        Self::new(
            MpiVendor::Mvapich2,
            (2, 2, 0),
            LibtoolAbi::new(12, 5, 0),
            vec![FabricKind::InfinibandEdr],
        )
    }

    /// Cray MPT 7.5.0 over Aries — the Piz Daint host MPI.
    pub fn cray_mpt_7_5_host() -> MpiImpl {
        Self::new(
            MpiVendor::CrayMpt,
            (7, 5, 0),
            LibtoolAbi::new(12, 7, 0),
            vec![FabricKind::CrayAries],
        )
    }

    /// Pre-initiative Cray MPT (for failure-injection tests).
    pub fn cray_mpt_6_legacy() -> MpiImpl {
        Self::new(
            MpiVendor::CrayMpt,
            (6, 3, 0),
            LibtoolAbi::new(10, 0, 0),
            vec![FabricKind::CrayAries],
        )
    }

    /// Open MPI 2.0 (non-member; §IV.B swap must refuse it).
    pub fn openmpi_2_0() -> MpiImpl {
        Self::new(
            MpiVendor::OpenMpi,
            (2, 0, 1),
            LibtoolAbi::new(40, 0, 20),
            vec![FabricKind::InfinibandEdr],
        )
    }
}

/// §IV.B swap precondition: both libraries are initiative members and the
/// host library's libtool ABI can serve the container-linked application.
pub fn swap_compatible(container: &MpiImpl, host: &MpiImpl) -> bool {
    container.mpich_abi_member()
        && host.mpich_abi_member()
        && host.abi.host_can_replace(&container.abi)
        && container.abi.soname_major() == MPICH_ABI_SONAME
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn initiative_membership_matches_paper_list() {
        assert!(MpiImpl::mpich_3_1_4_container().mpich_abi_member());
        assert!(MpiImpl::mvapich2_2_2_container().mpich_abi_member());
        assert!(MpiImpl::intel_2017_1_container().mpich_abi_member());
        assert!(MpiImpl::cray_mpt_7_5_host().mpich_abi_member());
        assert!(!MpiImpl::cray_mpt_6_legacy().mpich_abi_member());
        assert!(!MpiImpl::openmpi_2_0().mpich_abi_member());
    }

    #[test]
    fn all_three_containers_swap_onto_both_hosts() {
        // the core Tables III/IV property
        for container in [
            MpiImpl::mpich_3_1_4_container(),
            MpiImpl::mvapich2_2_2_container(),
            MpiImpl::intel_2017_1_container(),
        ] {
            for host in
                [MpiImpl::mvapich2_2_1_host_ib(), MpiImpl::cray_mpt_7_5_host()]
            {
                assert!(
                    swap_compatible(&container, &host),
                    "{} -> {}",
                    container.version_string(),
                    host.version_string()
                );
            }
        }
    }

    #[test]
    fn openmpi_never_swaps() {
        assert!(!swap_compatible(
            &MpiImpl::openmpi_2_0(),
            &MpiImpl::cray_mpt_7_5_host()
        ));
        assert!(!swap_compatible(
            &MpiImpl::mpich_3_1_4_container(),
            &MpiImpl::openmpi_2_0()
        ));
    }

    #[test]
    fn legacy_mpt_rejected() {
        assert!(!swap_compatible(
            &MpiImpl::mpich_3_1_4_container(),
            &MpiImpl::cray_mpt_6_legacy()
        ));
    }

    #[test]
    fn container_builds_have_no_hpc_fabric() {
        let c = MpiImpl::mpich_3_1_4_container();
        assert!(!c.supports_fabric(FabricKind::InfinibandEdr));
        assert!(!c.supports_fabric(FabricKind::CrayAries));
        assert!(c.supports_fabric(FabricKind::Loopback));
    }

    #[test]
    fn host_builds_drive_their_fabric() {
        assert!(MpiImpl::mvapich2_2_1_host_ib()
            .supports_fabric(FabricKind::InfinibandEdr));
        assert!(
            MpiImpl::cray_mpt_7_5_host().supports_fabric(FabricKind::CrayAries)
        );
        assert!(!MpiImpl::cray_mpt_7_5_host()
            .supports_fabric(FabricKind::InfinibandEdr));
    }

    #[test]
    fn frontend_library_names() {
        let libs = MpiImpl::intel_2017_1_container().frontend_libraries();
        assert_eq!(libs.len(), 3);
        assert!(libs.iter().all(|l| l.ends_with(".so.12")));
    }
}
