//! Point-to-point and collective communication time model over a fabric.
//!
//! The communicator binds an MPI implementation to the transport it can
//! actually reach from inside (or outside) a container: a vendor MPI with
//! hardware access uses the fabric's native path; a stock container MPI
//! falls back to TCP. osu_latency (Tables III/IV), PyFR halo exchange
//! (Table II) and Pynamic's MPI barrier all run through this model.

use crate::fabric::{link_for, FabricKind, LinkModel, Transport};
use crate::util::prng::Rng;

use super::impls::MpiImpl;

/// A communicator spanning `ranks` processes over a physical fabric.
#[derive(Debug, Clone)]
pub struct Communicator {
    pub ranks: u32,
    pub fabric: FabricKind,
    pub transport: Transport,
    link: LinkModel,
    /// Multiplicative measurement-noise sigma (log-normal) per operation.
    pub noise_sigma: f64,
}

impl Communicator {
    /// Build a communicator for `mpi` running on `fabric`.
    ///
    /// Transport selection is the crux of the paper's Tables III/IV:
    /// the implementation uses the hardware path only if this build has a
    /// transport module for the fabric (host builds; or container builds
    /// after Shifter's MPI swap replaced them with the host library).
    pub fn new(mpi: &MpiImpl, fabric: FabricKind, ranks: u32) -> Communicator {
        let transport = if mpi.supports_fabric(fabric) {
            Transport::Native
        } else {
            Transport::TcpFallback
        };
        Communicator {
            ranks,
            fabric,
            transport,
            link: link_for(fabric, transport),
            noise_sigma: 0.035,
        }
    }

    /// Deterministic zero-noise variant (unit tests, ablations).
    pub fn noiseless(mut self) -> Communicator {
        self.noise_sigma = 0.0;
        self
    }

    /// One-way pt2pt latency (µs) for a message of `size` bytes,
    /// noise-free model value.
    pub fn pt2pt_latency_us(&self, size: u64) -> f64 {
        self.link.latency_us(size)
    }

    /// One osu_latency-style sample: the average one-way latency observed
    /// by a ping-pong loop, with measurement noise drawn from `rng`.
    ///
    /// Noise is one-sided: the calibrated model value is the *best
    /// achievable* latency (the tables' best-of-30 protocol), so samples
    /// can only be slower — the min over 30 reps then recovers the
    /// calibration point, matching how the paper's numbers were produced.
    pub fn osu_latency_sample_us(&self, size: u64, rng: &mut Rng) -> f64 {
        let base = self.pt2pt_latency_us(size);
        if self.noise_sigma == 0.0 {
            base
        } else {
            base * (self.noise_sigma * rng.normal().abs()).exp()
        }
    }

    /// osu_bw-style streaming bandwidth (MB/s): a 64-message window
    /// pipelines transfers, hiding the per-message base latency; the
    /// floor is the small-message issue rate.
    pub fn osu_bw_mbps(&self, size: u64) -> f64 {
        let per_msg_us =
            (self.pt2pt_latency_us(size) - 0.85 * self.pt2pt_latency_us(32))
                .max(self.pt2pt_latency_us(32) * 0.15);
        size as f64 / per_msg_us // bytes/µs == MB/s
    }

    /// Halo exchange: every rank sends/receives `size` bytes to/from
    /// `neighbors` neighbors; exchanges overlap, so the cost is one
    /// round-trip times a small serialization factor.
    pub fn halo_exchange_us(&self, size: u64, neighbors: u32) -> f64 {
        let one = self.pt2pt_latency_us(size);
        // bidirectional + partial serialization across neighbor pairs
        2.0 * one * (1.0 + 0.25 * neighbors.saturating_sub(1) as f64)
    }

    /// Tree allreduce of `size` bytes across all ranks (µs).
    pub fn allreduce_us(&self, size: u64) -> f64 {
        if self.ranks <= 1 {
            return 0.0;
        }
        let rounds = (self.ranks as f64).log2().ceil();
        2.0 * rounds * self.pt2pt_latency_us(size)
    }

    /// Barrier (µs): allreduce of an empty payload.
    pub fn barrier_us(&self) -> f64 {
        self.allreduce_us(8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mpi::impls::MpiImpl;

    #[test]
    fn host_mpi_picks_native_transport() {
        let c = Communicator::new(
            &MpiImpl::cray_mpt_7_5_host(),
            FabricKind::CrayAries,
            2,
        );
        assert_eq!(c.transport, Transport::Native);
    }

    #[test]
    fn container_mpi_falls_back_to_tcp() {
        let c = Communicator::new(
            &MpiImpl::mpich_3_1_4_container(),
            FabricKind::CrayAries,
            2,
        );
        assert_eq!(c.transport, Transport::TcpFallback);
        // and is strictly slower than the host path at every OSU size
        let native = Communicator::new(
            &MpiImpl::cray_mpt_7_5_host(),
            FabricKind::CrayAries,
            2,
        );
        for s in crate::fabric::OSU_SIZES {
            assert!(c.pt2pt_latency_us(s) > native.pt2pt_latency_us(s));
        }
    }

    #[test]
    fn osu_sample_noise_is_bounded_and_deterministic() {
        let c = Communicator::new(
            &MpiImpl::mvapich2_2_1_host_ib(),
            FabricKind::InfinibandEdr,
            2,
        );
        let mut r1 = Rng::from_tags(&["t", "0"]);
        let mut r2 = Rng::from_tags(&["t", "0"]);
        let a = c.osu_latency_sample_us(32, &mut r1);
        let b = c.osu_latency_sample_us(32, &mut r2);
        assert_eq!(a, b);
        let base = c.pt2pt_latency_us(32);
        assert!((a / base - 1.0).abs() < 0.25);
    }

    #[test]
    fn allreduce_scales_logarithmically() {
        let mk = |ranks| {
            Communicator::new(
                &MpiImpl::cray_mpt_7_5_host(),
                FabricKind::CrayAries,
                ranks,
            )
            .noiseless()
        };
        let t2 = mk(2).allreduce_us(1024);
        let t16 = mk(16).allreduce_us(1024);
        let t1024 = mk(1024).allreduce_us(1024);
        assert!((t16 / t2 - 4.0).abs() < 1e-9); // log2(16)/log2(2) = 4
        assert!((t1024 / t2 - 10.0).abs() < 1e-9);
        assert_eq!(mk(1).allreduce_us(1024), 0.0);
    }

    #[test]
    fn osu_bw_monotone_and_transport_sensitive() {
        let native = Communicator::new(
            &MpiImpl::cray_mpt_7_5_host(),
            FabricKind::CrayAries,
            2,
        );
        let tcp = Communicator::new(
            &MpiImpl::mpich_3_1_4_container(),
            FabricKind::CrayAries,
            2,
        );
        // bandwidth grows with message size and native beats TCP
        assert!(native.osu_bw_mbps(1 << 20) > native.osu_bw_mbps(1 << 12));
        for s in [4096u64, 65536, 1 << 20] {
            assert!(native.osu_bw_mbps(s) > tcp.osu_bw_mbps(s), "size {s}");
        }
        // large-message native bandwidth approaches the wire rate (~10 GB/s)
        let bw = native.osu_bw_mbps(4 << 20);
        assert!((4_000.0..14_000.0).contains(&bw), "bw={bw}");
    }

    #[test]
    fn halo_exchange_grows_with_neighbors() {
        let c = Communicator::new(
            &MpiImpl::mvapich2_2_1_host_ib(),
            FabricKind::InfinibandEdr,
            4,
        );
        assert!(c.halo_exchange_us(65536, 6) > c.halo_exchange_us(65536, 1));
    }
}
