//! MPI substrate: ABI-compatibility model, implementation catalog and the
//! communication time model (DESIGN.md S9).

pub mod abi;
pub mod comm;
pub mod impls;

pub use abi::{LibtoolAbi, MPICH_ABI_SONAME, MPI_FRONTEND_LIBRARIES};
pub use comm::Communicator;
pub use impls::{swap_compatible, MpiImpl, MpiVendor};
