//! MPI ABI compatibility model (§II-B1, §IV.B).
//!
//! The MPICH ABI Compatibility Initiative (announced 2013) is what makes
//! Shifter's library swap sound: member implementations agree on
//!  * a specified libtool ABI string,
//!  * the library names `libmpi`, `libmpicxx`, `libmpifort`,
//!  * keeping non-standard functions and F08 bindings out of the ABI,
//!  * those three libraries being the only valid wrapper-compiler deps.
//!
//! Shifter "checks that the MPI library to be replaced is compatible with
//! the host's MPI library: this is done by comparing the libtool ABI string
//! of both libraries" — implemented by [`LibtoolAbi::host_can_replace`].

/// libtool `current:revision:age` version triple of a shared library.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct LibtoolAbi {
    pub current: u32,
    pub revision: u32,
    pub age: u32,
}

impl LibtoolAbi {
    pub fn new(current: u32, revision: u32, age: u32) -> Self {
        assert!(age <= current, "libtool requires age <= current");
        LibtoolAbi {
            current,
            revision,
            age,
        }
    }

    /// The ABI string as embedded in the library (`current:revision:age`).
    pub fn abi_string(&self) -> String {
        format!("{}:{}:{}", self.current, self.revision, self.age)
    }

    /// Parse "c:r:a".
    pub fn parse(s: &str) -> Option<LibtoolAbi> {
        let mut it = s.split(':').map(|p| p.parse::<u32>().ok());
        let (c, r, a) = (it.next()??, it.next()??, it.next()??);
        if it.next().is_some() || a > c {
            return None;
        }
        Some(LibtoolAbi {
            current: c,
            revision: r,
            age: a,
        })
    }

    /// SONAME major as the dynamic linker sees it (libmpi.so.{major}).
    pub fn soname_major(&self) -> u32 {
        self.current - self.age
    }

    /// Interface range this library implements: [current-age, current].
    pub fn implements(&self, interface: u32) -> bool {
        interface >= self.current - self.age && interface <= self.current
    }

    /// Can a host library with ABI `self` replace (be bind-mounted over) a
    /// container library with ABI `container`, for an application linked
    /// against the container library?
    ///
    /// The application references interfaces up to `container.current`; the
    /// host library must implement that interface *and* present the same
    /// SONAME, or the loader would not even resolve it.
    pub fn host_can_replace(&self, container: &LibtoolAbi) -> bool {
        self.soname_major() == container.soname_major()
            && self.implements(container.current)
    }
}

/// The MPICH-ABI libmpi libtool string family: every initiative member
/// ships libmpi.so.12 (libtool 12:x:0 or efficiently-compatible variants).
pub const MPICH_ABI_SONAME: u32 = 12;

/// Frontend shared libraries the initiative standardizes (§IV.B).
pub const MPI_FRONTEND_LIBRARIES: [&str; 3] =
    ["libmpi.so.12", "libmpicxx.so.12", "libmpifort.so.12"];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn abi_string_roundtrip() {
        let a = LibtoolAbi::new(12, 5, 0);
        assert_eq!(a.abi_string(), "12:5:0");
        assert_eq!(LibtoolAbi::parse("12:5:0"), Some(a));
    }

    #[test]
    fn parse_rejects_malformed() {
        assert_eq!(LibtoolAbi::parse("12:5"), None);
        assert_eq!(LibtoolAbi::parse("12:5:0:0"), None);
        assert_eq!(LibtoolAbi::parse("a:b:c"), None);
        assert_eq!(LibtoolAbi::parse("1:0:5"), None); // age > current
    }

    #[test]
    fn same_soname_newer_revision_replaces() {
        let container = LibtoolAbi::new(12, 0, 0); // MPICH 3.1.4's libmpi
        let host = LibtoolAbi::new(12, 5, 0); // host MVAPICH2
        assert!(host.host_can_replace(&container));
    }

    #[test]
    fn different_soname_cannot_replace() {
        let container = LibtoolAbi::new(12, 0, 0);
        let openmpi_style = LibtoolAbi::new(40, 0, 20); // soname 20
        assert!(!openmpi_style.host_can_replace(&container));
    }

    #[test]
    fn extended_interface_still_replaces_via_age() {
        // a host lib that extended the interface (current 14, age 2) still
        // serves an app linked against interface 12
        let host = LibtoolAbi::new(14, 0, 2);
        let container = LibtoolAbi::new(12, 1, 0);
        assert!(host.host_can_replace(&container));
    }

    #[test]
    fn host_older_than_container_interface_fails() {
        // container was built against a *newer* interface than host provides
        let host = LibtoolAbi::new(12, 9, 0);
        let container = LibtoolAbi::new(14, 0, 2); // soname 12, iface 14
        assert_eq!(host.soname_major(), container.soname_major());
        assert!(!host.host_can_replace(&container));
    }

    #[test]
    fn frontend_library_names_match_initiative() {
        assert_eq!(
            MPI_FRONTEND_LIBRARIES,
            ["libmpi.so.12", "libmpicxx.so.12", "libmpifort.so.12"]
        );
    }
}
