//! nvidia-docker runtime model — the *laptop* side of the paper's
//! evaluation: "The nvidia-docker program, an extension to the Docker
//! runtime developed by NVIDIA to provide Docker with access to the GPU,
//! was used on the Laptop system while Shifter was used on the HPC
//! systems" (§V.B.1).
//!
//! Architectural contrast with Shifter (§III's design goals):
//!  * Docker runs containers through a **root daemon** — Shifter
//!    deliberately avoids one (security goal 4);
//!  * images come from the **local layered store** (no flatten/squashfs,
//!    no parallel-filesystem placement);
//!  * GPU access goes through the nvidia-docker **volume driver**, which
//!    mounts the same driver-library set Shifter's §IV.A support injects —
//!    that equivalence is what makes the containers portable in both
//!    directions, and is asserted by `integration tests`.

use std::collections::BTreeMap;

use crate::gpu::{parse_cuda_visible_devices, DRIVER_BINARIES, DRIVER_LIBRARIES};
use crate::hostenv::SystemProfile;
use crate::image::Image;
use crate::vfs::{MountTable, VirtualFs};

#[derive(Debug, thiserror::Error)]
#[non_exhaustive]
pub enum DockerError {
    #[error("docker daemon not running")]
    DaemonDown,
    #[error("image not in local store: {0}")]
    NoSuchImage(String),
    #[error("nvidia-docker: driver volume unavailable")]
    DriverVolumeMissing,
    #[error("image flatten failed: {0}")]
    Flatten(#[from] crate::vfs::VfsError),
}

/// A running Docker container (daemon-managed).
#[derive(Debug)]
pub struct DockerContainer {
    pub image: String,
    pub rootfs: VirtualFs,
    pub mounts: MountTable,
    pub env: BTreeMap<String, String>,
    /// uid the container process runs as — Docker defaults to ROOT, one of
    /// the reasons HPC sites run Shifter instead.
    pub uid: u32,
    pub gpu_devices: Vec<u32>,
}

/// The Docker engine + nvidia-docker wrapper on a workstation.
pub struct DockerRuntime<'a> {
    profile: &'a SystemProfile,
    /// Local image store (docker build / docker pull results).
    store: BTreeMap<String, Image>,
    pub daemon_running: bool,
}

impl<'a> DockerRuntime<'a> {
    pub fn new(profile: &'a SystemProfile) -> DockerRuntime<'a> {
        DockerRuntime {
            profile,
            store: BTreeMap::new(),
            daemon_running: true,
        }
    }

    /// `docker build` / `docker pull` — put an image in the local store.
    pub fn load_image(&mut self, image: Image) {
        self.store.insert(image.reference.canonical(), image);
    }

    pub fn images(&self) -> Vec<String> {
        self.store.keys().cloned().collect()
    }

    /// `nvidia-docker run` — layered-store rootfs + driver-volume GPU
    /// injection keyed on CUDA_VISIBLE_DEVICES (parity with §IV.A).
    pub fn run(
        &self,
        reference: &str,
        env: &BTreeMap<String, String>,
    ) -> Result<DockerContainer, DockerError> {
        if !self.daemon_running {
            return Err(DockerError::DaemonDown);
        }
        let image = self
            .store
            .get(reference)
            .ok_or_else(|| DockerError::NoSuchImage(reference.to_string()))?;
        let mut rootfs = image.flatten()?;
        let mut mounts = MountTable::new();
        let mut cenv: BTreeMap<String, String> =
            image.manifest.env.iter().cloned().collect();

        // the nvidia-docker volume driver: mount the driver stack when the
        // host has a GPU and the container asks for one
        let mut gpu_devices = Vec::new();
        if let Some(value) = env.get("CUDA_VISIBLE_DEVICES") {
            if let Some(requested) = parse_cuda_visible_devices(value) {
                let driver = self
                    .profile
                    .driver(0)
                    .ok_or(DockerError::DriverVolumeMissing)?;
                let volume = "/var/lib/nvidia-docker/volumes/nvidia_driver";
                for (lib, versioned) in
                    DRIVER_LIBRARIES.iter().zip(driver.library_files())
                {
                    let target = format!("/usr/local/nvidia/lib64/{lib}");
                    rootfs
                        .add_file(&target, 8_000_000, 0x77)
                        .map_err(DockerError::Flatten)?;
                    mounts.bind(
                        &format!("{volume}/{versioned}"),
                        &target,
                        true,
                        "nvidia-docker",
                    );
                }
                for bin in DRIVER_BINARIES {
                    mounts.bind(
                        &format!("{volume}/bin/{bin}"),
                        &format!("/usr/local/nvidia/bin/{bin}"),
                        true,
                        "nvidia-docker",
                    );
                }
                for f in driver.device_files(&requested) {
                    rootfs
                        .insert(&f, crate::vfs::VNode::Device { major: 195, minor: 0 })
                        .ok();
                    mounts.bind(&f, &f, false, "nvidia-docker");
                }
                cenv.insert("CUDA_VISIBLE_DEVICES".into(), value.clone());
                gpu_devices = requested;
            }
        }

        Ok(DockerContainer {
            image: reference.to_string(),
            rootfs,
            mounts,
            env: cenv,
            uid: 0, // docker default: root inside the container
            gpu_devices,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::builder;

    fn laptop_docker() -> (SystemProfile, Vec<Image>) {
        (
            SystemProfile::laptop(),
            vec![builder::tensorflow_image(), builder::cuda_image()],
        )
    }

    #[test]
    fn nvidia_docker_injects_driver_volume() {
        let (profile, images) = laptop_docker();
        let mut docker = DockerRuntime::new(&profile);
        for i in images {
            docker.load_image(i);
        }
        let mut env = BTreeMap::new();
        env.insert("CUDA_VISIBLE_DEVICES".to_string(), "0".to_string());
        let c = docker.run("nvidia/cuda-image:8.0", &env).unwrap();
        assert_eq!(c.gpu_devices, vec![0]);
        assert!(c.rootfs.exists("/usr/local/nvidia/lib64/libcuda.so"));
        assert!(c.rootfs.exists("/dev/nvidia0"));
        assert_eq!(c.mounts.by_origin("nvidia-docker").len(), 7 + 1 + 3);
    }

    #[test]
    fn plain_docker_run_without_gpu() {
        let (profile, images) = laptop_docker();
        let mut docker = DockerRuntime::new(&profile);
        for i in images {
            docker.load_image(i);
        }
        let c = docker
            .run("tensorflow/tensorflow:1.0.0-devel-gpu-py3", &BTreeMap::new())
            .unwrap();
        assert!(c.gpu_devices.is_empty());
        assert_eq!(c.uid, 0); // the daemon model shifter avoids
    }

    #[test]
    fn daemon_down_refuses() {
        let (profile, _) = laptop_docker();
        let mut docker = DockerRuntime::new(&profile);
        docker.daemon_running = false;
        assert!(matches!(
            docker.run("x:y", &BTreeMap::new()),
            Err(DockerError::DaemonDown)
        ));
    }

    #[test]
    fn missing_image_reported() {
        let (profile, _) = laptop_docker();
        let docker = DockerRuntime::new(&profile);
        assert!(matches!(
            docker.run("ghost:latest", &BTreeMap::new()),
            Err(DockerError::NoSuchImage(_))
        ));
    }

    #[test]
    fn same_driver_set_as_shifter_gpu_support() {
        // the equivalence the paper's workflow rests on: both runtimes
        // inject the §IV.A library list
        let (profile, images) = laptop_docker();
        let mut docker = DockerRuntime::new(&profile);
        for i in images {
            docker.load_image(i);
        }
        let mut env = BTreeMap::new();
        env.insert("CUDA_VISIBLE_DEVICES".to_string(), "0".to_string());
        let c = docker.run("nvidia/cuda-image:8.0", &env).unwrap();
        let docker_libs: Vec<String> = c
            .mounts
            .by_origin("nvidia-docker")
            .iter()
            .filter(|m| m.target.contains("lib64"))
            .map(|m| {
                m.target.rsplit('/').next().unwrap().to_string()
            })
            .collect();
        for lib in DRIVER_LIBRARIES {
            assert!(docker_libs.iter().any(|l| l == lib), "{lib}");
        }
    }
}
