//! Distributed image distribution (DESIGN.md S18): the scaling layer that
//! turns the single synchronous Image Gateway (§III) into a subsystem able
//! to serve pull storms from thousands of compute nodes.
//!
//! Three pieces compose through the `DistributionFabric` facade:
//!
//! * [`cas::ContentStore`] — cluster-wide content-addressed layer store;
//!   images sharing base layers store them once (ref-counted).
//! * [`cluster::GatewayCluster`] — N gateway shards selected by rendezvous
//!   hashing; each runs the existing `PullQueue` worker, so concurrent
//!   pulls of one reference coalesce into a single job while distinct
//!   references process in parallel.
//! * [`node_cache::NodeCache`] — per-compute-node squashfs cache with LRU
//!   eviction; cold fills pay the Lustre broadcast cost, warm starts a
//!   local stat.
//!
//! Three opt-in mechanisms layer on top (DESIGN.md S25):
//!
//! * [`cascade`] — topology-aware cascade fills: cold nodes fetch from
//!   already-warm cabinet peers spanning-tree-style instead of each
//!   paying the Lustre broadcast, so storm fill time grows with tree
//!   depth (logarithmic), not node count.
//! * lazy pulling — `node_fetch_split` returns (start-ready, streamed
//!   tail): a container starts once squashfs metadata + first-read
//!   chunks arrive, and the tail is charged to the job's execute stage.
//! * [`chunk`] — content-defined chunking in the CAS, so derived images
//!   dedup below layer granularity and pulls only transfer new chunks.
//!
//! The fabric implements `gateway::ImageSource`, so
//! `ShifterRuntime::run(&fabric, …)` works exactly like the classic
//! single-gateway path — callers opt into distribution without touching
//! the stage pipeline.

pub mod cas;
pub mod cascade;
pub mod chunk;
pub mod cluster;
pub mod node_cache;

pub use cas::{BlobInfo, ContentStore, ImageReceipt};
pub use cascade::{CascadeConfig, CascadeStats};
pub use chunk::{Chunk, Chunker};
pub use cluster::{CoalescingStats, GatewayCluster, GatewayShard, ShardStatus};
pub use node_cache::{CacheOutcome, NodeCache};

use std::collections::{BTreeMap, BTreeSet};
use std::sync::{Arc, Mutex};

use crate::gateway::{GatewayError, GatewayImage, ImageSource, PullState};
use crate::metrics::Stats;
use crate::pfs::LustreFs;
use crate::registry::Registry;
use crate::sim::SimTime;
use crate::telemetry::Telemetry;
use crate::util::sync::lock_unpoisoned;

/// Default per-node squashfs cache: 32 GB of node-local storage (the
/// RAM-backed tmpfs / local SSD slice sites give Shifter).
pub const DEFAULT_NODE_CACHE_BYTES: u64 = 32_000_000_000;

/// Fraction of the cold fill a lazy pull must complete before a
/// container can start: squashfs superblock + metadata + the first-read
/// chunks (entrypoint binary, loader, initial libraries).
pub const LAZY_START_READY_FRACTION: f64 = 0.08;

/// Per-chunk round trip charged while streaming the lazy tail on demand.
pub const LAZY_CHUNK_RTT_SECS: f64 = 50e-6;

/// Chunk size used to price lazy-tail round trips when no CAS chunker is
/// installed.
const DEFAULT_LAZY_CHUNK_BYTES: u64 = 4_000_000;

/// Seed for the CAS chunker — fixed so chunk digests are stable across
/// runs, hosts, and thread counts (the determinism suite depends on it).
const CAS_CHUNK_SEED: u64 = 0xC0FFEE;

/// Aggregated node-cache counters across every node the fabric has seen.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CacheStats {
    /// Nodes that have fetched at least once.
    pub nodes: usize,
    /// Fetches satisfied from a node-local cache.
    pub hits: u64,
    /// Fetches that paid the Lustre broadcast cold fill.
    pub misses: u64,
    /// Cache entries evicted under capacity pressure.
    pub evictions: u64,
    /// Bytes lazy pulling deferred past container start (0 when lazy
    /// pull is off).
    pub lazy_deferred_bytes: u64,
}

/// The facade the runtime and CLI talk to.
///
/// ```
/// use shifter_rs::distrib::DistributionFabric;
/// use shifter_rs::gateway::PullState;
/// use shifter_rs::pfs::LustreFs;
/// use shifter_rs::Registry;
///
/// let registry = Registry::dockerhub();
/// let mut fabric = DistributionFabric::new(4, LustreFs::piz_daint());
/// let state = fabric
///     .pull_blocking(&registry, "ubuntu:xenial", "alice")
///     .unwrap();
/// assert_eq!(state, PullState::Ready);
/// assert!(fabric.cluster().cas().stored_bytes() > 0);
/// ```
pub struct DistributionFabric {
    cluster: GatewayCluster,
    /// Per-node caches, created lazily as nodes first fetch. Mutex (not
    /// RefCell): `ImageSource::node_fetch_secs` takes `&self` but a fetch
    /// updates LRU/hit state, and the launch orchestrator shares one
    /// fabric across its whole worker pool — the fabric must be `Sync`.
    caches: Mutex<BTreeMap<usize, NodeCache>>,
    node_cache_bytes: u64,
    pfs: LustreFs,
    /// Shared recorder (disabled by default): counts every request per
    /// shard, coalescing hits, cache hits / cold fills / evictions, and
    /// samples shard queue depth + node fetch times. See DESIGN.md S23.
    telemetry: Arc<Telemetry>,
    /// Cabinet topology for cascade fills; `None` keeps the classic
    /// Lustre broadcast cold-fill model.
    cascade: Option<CascadeConfig>,
    /// When true, `node_fetch_split` returns a (start-ready, streamed
    /// tail) pair instead of charging the whole fill up front.
    lazy_pull: bool,
    /// Target chunk size of the CAS chunker, when chunking is enabled.
    chunk_target_bytes: Option<u64>,
    /// Replayed cascade plans keyed by squashfs digest (one per image
    /// that stormed). Mutex for the same reason as `caches`.
    cascades: Mutex<BTreeMap<u64, cascade::CascadePlan>>,
    /// Nodes marked unresponsive: cascades route around them and their
    /// would-be children fall back to the gateway.
    dead_nodes: Mutex<BTreeSet<usize>>,
    /// (chunks_new, chunks_shared) already reported to telemetry — tick
    /// reports CAS chunk-counter deltas, not absolutes.
    chunk_watermark: Mutex<(u64, u64)>,
}

impl DistributionFabric {
    /// Fabric with `n_shards` gateway shards over the given parallel
    /// filesystem and default-sized node caches.
    pub fn new(n_shards: usize, pfs: LustreFs) -> DistributionFabric {
        DistributionFabric {
            cluster: GatewayCluster::new(n_shards, &pfs),
            caches: Mutex::new(BTreeMap::new()),
            node_cache_bytes: DEFAULT_NODE_CACHE_BYTES,
            pfs,
            telemetry: Arc::new(Telemetry::disabled()),
            cascade: None,
            lazy_pull: false,
            chunk_target_bytes: None,
            cascades: Mutex::new(BTreeMap::new()),
            dead_nodes: Mutex::new(BTreeSet::new()),
            chunk_watermark: Mutex::new((0, 0)),
        }
    }

    /// Override the per-node cache capacity (tests, small-node systems).
    pub fn with_node_cache_bytes(mut self, bytes: u64) -> DistributionFabric {
        self.node_cache_bytes = bytes;
        self
    }

    /// Enable topology-aware cascade fills (DESIGN.md S25): cold nodes
    /// fetch from warm cabinet peers spanning-tree-style instead of each
    /// paying the Lustre broadcast.
    pub fn with_cascade(mut self, cfg: CascadeConfig) -> DistributionFabric {
        self.cascade = Some(cfg);
        self
    }

    /// Enable lazy pulling: containers start once metadata + first-read
    /// chunks arrive; the rest of the image streams during execution.
    pub fn with_lazy_pull(mut self, enabled: bool) -> DistributionFabric {
        self.lazy_pull = enabled;
        self
    }

    /// Enable content-defined chunking in the cluster CAS with the given
    /// mean chunk size: derived images dedup below layer granularity and
    /// pulls only transfer chunks the store is missing. Call before the
    /// first pull.
    pub fn with_chunking(mut self, target_bytes: u64) -> DistributionFabric {
        self.chunk_target_bytes = Some(target_bytes);
        self.cluster
            .set_chunker(Chunker::new(target_bytes, CAS_CHUNK_SEED));
        self
    }

    /// Mark `node` unresponsive: cascade trees route around it and cold
    /// peers that would have fetched from it time out and fall back to
    /// the gateway. Affects plans built after the call.
    pub fn mark_node_dead(&mut self, node: usize) {
        lock_unpoisoned(&self.dead_nodes).insert(node);
    }

    /// The cascade topology, when cascade fills are enabled.
    pub fn cascade_config(&self) -> Option<CascadeConfig> {
        self.cascade
    }

    /// Whether lazy pulling is enabled.
    pub fn lazy_pull_enabled(&self) -> bool {
        self.lazy_pull
    }

    /// The CAS chunk-size target, when chunking is enabled.
    pub fn chunk_target(&self) -> Option<u64> {
        self.chunk_target_bytes
    }

    /// Share a telemetry recorder with the fabric (see DESIGN.md S23);
    /// [`crate::SiteBuilder`] wires the site-wide recorder here.
    pub fn with_telemetry(
        mut self,
        telemetry: Arc<Telemetry>,
    ) -> DistributionFabric {
        self.telemetry = telemetry;
        self
    }

    /// The recorder the fabric reports into (disabled unless installed
    /// via [`DistributionFabric::with_telemetry`]).
    pub fn telemetry(&self) -> &Telemetry {
        &self.telemetry
    }

    /// The sharded gateway cluster behind the facade.
    pub fn cluster(&self) -> &GatewayCluster {
        &self.cluster
    }

    /// The parallel filesystem the fabric broadcasts from.
    pub fn pfs(&self) -> &LustreFs {
        &self.pfs
    }

    /// Enqueue a pull (see `GatewayCluster::request`).
    pub fn request(
        &mut self,
        registry: &Registry,
        reference: &str,
        user: &str,
    ) -> Result<(usize, PullState), GatewayError> {
        // A request is a coalescing hit when the owning shard already
        // tracks a job for this reference (the new requester is absorbed
        // into it). Checked before the request mutates shard state, and
        // only while recording — the extra status probe costs nothing
        // when telemetry is off.
        let coalesced = self.telemetry.enabled()
            && self.cluster.status(reference).is_some();
        let result = self.cluster.request(registry, reference, user);
        if self.telemetry.enabled() {
            if let Ok((shard_id, _)) = &result {
                self.telemetry.count("fabric.requests", 1);
                self.telemetry
                    .count(&format!("shard.{shard_id}.requests"), 1);
                if coalesced {
                    self.telemetry.count("fabric.coalesced_hits", 1);
                    self.telemetry
                        .count(&format!("shard.{shard_id}.coalesced"), 1);
                }
                if let Some(shard) =
                    self.cluster.shards().find(|s| s.id == *shard_id)
                {
                    self.telemetry.observe(
                        &format!("shard.{shard_id}.queue_depth"),
                        shard.queue.backlog() as f64,
                    );
                }
            }
        }
        result
    }

    /// Advance all shard workers by `dt` simulated seconds.
    pub fn tick(&mut self, registry: &Registry, dt: f64) {
        self.cluster.tick(registry, dt);
        // report CAS chunk-counter deltas (new registrations this tick)
        if self.telemetry.enabled() && self.cluster.cas().chunked() {
            let mut mark = lock_unpoisoned(&self.chunk_watermark);
            let cas = self.cluster.cas();
            let (new, shared) = (cas.chunks_new(), cas.chunks_shared());
            if new > mark.0 {
                self.telemetry.count("cas.chunks_new", new - mark.0);
            }
            if shared > mark.1 {
                self.telemetry.count("cas.chunks_shared", shared - mark.1);
            }
            *mark = (new, shared);
        }
    }

    /// Current instant of the fabric's virtual clock (the lockstep
    /// shard-queue clock).
    pub fn now(&self) -> SimTime {
        self.cluster.now()
    }

    /// Advance the fabric's clock to the absolute instant `t` — how a
    /// virtual-time client (the tenancy kernel) aligns the shard clocks
    /// with its own before enqueuing work. A target at or before `now`
    /// is a no-op (clocks never move backward).
    pub fn advance_to(&mut self, registry: &Registry, t: SimTime) {
        let dt = t - self.now();
        if dt > 0.0 {
            self.tick(registry, dt);
        }
    }

    /// Run every shard worker until its backlog is terminal, ticking by
    /// the *exact* pending work (no magic huge-constant drains): shard
    /// clocks end at the true completion instant, so queue-wait and
    /// turnaround accounting stay on the one kernel timeline.
    pub fn drain(&mut self, registry: &Registry) {
        while !self.cluster.drained() {
            // f64 residue can leave a sliver of a stage behind; the
            // loop re-measures and finishes it on the next pass.
            let dt = self.cluster.pending_secs().max(f64::EPSILON);
            self.tick(registry, dt);
        }
    }

    /// Request and run the cluster until the job is terminal — the
    /// synchronous convenience the CLI uses. Returns the final state.
    pub fn pull_blocking(
        &mut self,
        registry: &Registry,
        reference: &str,
        user: &str,
    ) -> Result<PullState, GatewayError> {
        let (_, state) = self.request(registry, reference, user)?;
        if state.terminal() {
            return Ok(state);
        }
        self.drain(registry);
        Ok(self
            .cluster
            .status(reference)
            .map(|j| j.state)
            .unwrap_or(PullState::Failed))
    }

    /// Whether `node` already holds `reference`'s squashfs locally.
    pub fn node_has_image(&self, node: usize, reference: &str) -> bool {
        let Ok(image) = self.cluster.lookup(reference) else {
            return false;
        };
        lock_unpoisoned(&self.caches)
            .get(&node)
            .is_some_and(|c| c.contains(image.squashfs.digest))
    }

    /// Queue-wait statistics (enqueue → worker pickup) across every job
    /// the gateway shards have started, for `cluster-status` and the
    /// launch report.
    pub fn queue_wait_stats(&self) -> Option<Stats> {
        self.cluster.queue_wait_stats()
    }

    /// Cross-job coalescing accounting (see
    /// [`cluster::CoalescingStats`]): total pull requests ever absorbed
    /// vs unique pull jobs performed.
    pub fn coalescing(&self) -> CoalescingStats {
        self.cluster.coalescing()
    }

    /// Aggregated node-cache counters across every node cache the fabric
    /// has created.
    pub fn cache_stats(&self) -> CacheStats {
        let caches = lock_unpoisoned(&self.caches);
        CacheStats {
            nodes: caches.len(),
            hits: caches.values().map(|c| c.hits).sum(),
            misses: caches.values().map(|c| c.misses).sum(),
            evictions: caches.values().map(|c| c.evictions).sum(),
            lazy_deferred_bytes: caches
                .values()
                .map(|c| c.lazy_deferred_bytes)
                .sum(),
        }
    }

    /// Aggregated cascade accounting across every plan the fabric has
    /// built (one per squashfs digest that stormed cold).
    pub fn cascade_stats(&self) -> CascadeStats {
        let plans = lock_unpoisoned(&self.cascades);
        let mut stats = CascadeStats {
            cascades: plans.len() as u64,
            ..CascadeStats::default()
        };
        for plan in plans.values() {
            stats.gateway_fills += plan.gateway_fills;
            stats.gateway_fallbacks += plan.gateway_fallbacks;
            stats.peer_transfers += plan.peer_transfers;
            stats.max_depth = stats.max_depth.max(plan.max_depth);
        }
        stats
    }

    /// Cabinet → number of times image data entered it from outside
    /// (gateway reads + inter-cabinet transfers) for `reference`'s
    /// cascade, or `None` when no cascade has run for it. 1 everywhere
    /// when all peers are alive.
    pub fn cascade_cabinet_entries(
        &self,
        reference: &str,
    ) -> Option<BTreeMap<usize, u64>> {
        let image = self.cluster.lookup(reference).ok()?;
        let plans = lock_unpoisoned(&self.cascades);
        plans
            .get(&image.squashfs.digest)
            .map(|p| p.cabinet_entries().clone())
    }

    /// Expected cold-fill seconds for one node of a `width`-node storm
    /// pulling `reference` — the launch scheduler's pricing hook. Uses
    /// the linear Lustre broadcast model without cascade fills, the
    /// logarithmic spanning-tree estimate with them.
    pub fn cold_fill_estimate_secs(
        &self,
        reference: &str,
        width: u64,
    ) -> f64 {
        let bytes = self
            .cluster
            .lookup(reference)
            .map(|img| img.squashfs.compressed_bytes)
            .unwrap_or(0);
        match &self.cascade {
            None => NodeCache::cold_fill_secs(&self.pfs, bytes, width),
            Some(cfg) => cascade::estimate_fill_secs(
                cfg,
                width as usize,
                bytes,
                &self.pfs,
            ),
        }
    }
}

impl ImageSource for DistributionFabric {
    fn resolve(&self, reference: &str) -> Result<&GatewayImage, GatewayError> {
        self.cluster.lookup(reference)
    }

    /// Shard-index query: one MDS round trip, same as the classic path.
    fn resolve_latency_secs(&self) -> f64 {
        self.pfs.mds.base_latency_us * 1e-6
    }

    /// Cache-aware node fetch: a warm node stats its local copy; a cold
    /// node joins the fill storm and admits the blob. The sum of the
    /// split — one cache access, both halves charged.
    fn node_fetch_secs(
        &self,
        image: &GatewayImage,
        node: usize,
        concurrent_nodes: u64,
    ) -> Option<f64> {
        self.node_fetch_split(image, node, concurrent_nodes)
            .map(|(start, tail)| start + tail)
    }

    /// The fabric's fetch primitive. Warm nodes stat their local copy
    /// (no tail). Cold fills pay the Lustre broadcast, or — with cascade
    /// fills enabled — their slot in the spanning tree replayed on the
    /// sim kernel. With lazy pull enabled the cold cost splits into a
    /// start-ready head (metadata + first-read chunks) and a streamed
    /// tail charged to execution.
    fn node_fetch_split(
        &self,
        image: &GatewayImage,
        node: usize,
        concurrent_nodes: u64,
    ) -> Option<(f64, f64)> {
        let mut caches = lock_unpoisoned(&self.caches);
        let cache = caches
            .entry(node)
            .or_insert_with(|| NodeCache::new(self.node_cache_bytes));
        let bytes = image.squashfs.compressed_bytes;
        // stamp fills/evictions with the fabric's kernel-clock instant
        let now = self.cluster.now();
        let split = match cache.fetch_at(image.squashfs.digest, bytes, now) {
            CacheOutcome::Hit => {
                self.telemetry.count("fabric.cache_hits", 1);
                (cache.warm_hit_secs(), 0.0)
            }
            CacheOutcome::Miss { evicted } => {
                self.telemetry.count("fabric.cold_fills", 1);
                self.telemetry.count("fabric.evictions", evicted as u64);
                let fill = match &self.cascade {
                    None => NodeCache::cold_fill_secs(
                        &self.pfs,
                        bytes,
                        concurrent_nodes,
                    ),
                    Some(cfg) => {
                        let mut plans = lock_unpoisoned(&self.cascades);
                        let plan = plans
                            .entry(image.squashfs.digest)
                            .or_insert_with(|| {
                                let dead =
                                    lock_unpoisoned(&self.dead_nodes).clone();
                                let plan = cascade::plan(
                                    cfg,
                                    concurrent_nodes.max(1) as usize,
                                    bytes,
                                    &dead,
                                    &self.pfs,
                                );
                                self.telemetry.count("fabric.cascades", 1);
                                self.telemetry.count(
                                    "fabric.cascade_gateway_fills",
                                    plan.gateway_fills,
                                );
                                self.telemetry.count(
                                    "fabric.cascade_fallbacks",
                                    plan.gateway_fallbacks,
                                );
                                self.telemetry.count(
                                    "fabric.cascade_peer_transfers",
                                    plan.peer_transfers,
                                );
                                plan
                            });
                        let (fill, depth) = plan.fill_for(node);
                        self.telemetry.count("fabric.cascade_hops", depth);
                        self.telemetry
                            .observe("fabric.cascade_depth", depth as f64);
                        fill
                    }
                };
                if self.lazy_pull {
                    let start = self.resolve_latency_secs()
                        + LAZY_START_READY_FRACTION * fill;
                    let deferred = bytes
                        - (bytes as f64 * LAZY_START_READY_FRACTION) as u64;
                    let chunk_bytes = self
                        .chunk_target_bytes
                        .unwrap_or(DEFAULT_LAZY_CHUNK_BYTES)
                        .max(1);
                    let n_chunks = deferred.div_ceil(chunk_bytes).max(1);
                    let tail = (1.0 - LAZY_START_READY_FRACTION) * fill
                        + n_chunks as f64 * LAZY_CHUNK_RTT_SECS;
                    cache.note_lazy_deferral(deferred);
                    self.telemetry
                        .count("fabric.lazy_bytes_deferred", deferred);
                    (start, tail)
                } else {
                    (fill, 0.0)
                }
            }
        };
        self.telemetry
            .observe("fabric.fetch_secs", split.0 + split.1);
        Some(split)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fabric() -> (DistributionFabric, Registry) {
        (
            DistributionFabric::new(4, LustreFs::piz_daint()),
            Registry::dockerhub(),
        )
    }

    #[test]
    fn pull_blocking_materializes_the_image() {
        let (mut f, reg) = fabric();
        let state = f.pull_blocking(&reg, "ubuntu:xenial", "alice").unwrap();
        assert_eq!(state, PullState::Ready);
        let image = f.resolve("ubuntu:xenial").unwrap();
        assert!(image.squashfs.file_count() > 100);
        assert!(f.cluster().cas().stored_bytes() > 0);
    }

    #[test]
    fn missing_image_fails_terminal() {
        let (mut f, reg) = fabric();
        let state = f.pull_blocking(&reg, "nope:missing", "u").unwrap();
        assert_eq!(state, PullState::Failed);
        assert!(f.resolve("nope:missing").is_err());
    }

    #[test]
    fn second_node_fetch_is_a_cache_hit() {
        let (mut f, reg) = fabric();
        f.pull_blocking(&reg, "ubuntu:xenial", "u").unwrap();
        let image = f.resolve("ubuntu:xenial").unwrap();

        let cold = f.node_fetch_secs(image, 7, 1000).unwrap();
        let warm = f.node_fetch_secs(image, 7, 1000).unwrap();
        assert!(
            cold > 1000.0 * warm,
            "cold={cold}s warm={warm}s — the cache must collapse the cost"
        );
        assert!(f.node_has_image(7, "ubuntu:xenial"));
        assert!(!f.node_has_image(8, "ubuntu:xenial"));
        let stats = f.cache_stats();
        assert_eq!((stats.nodes, stats.hits, stats.misses), (1, 1, 1));
    }

    #[test]
    fn tiny_node_cache_evicts_under_pressure() {
        use crate::image::builder::{self, ImageBuilder};
        let base = builder::ubuntu_xenial();
        let mut registry = Registry::dockerhub();
        for name in ["app-a:1", "app-b:1"] {
            registry.push(
                ImageBuilder::from_image(&base, name)
                    .file("/opt/app.bin", 10_000_000)
                    .build(),
            );
        }
        // cache that fits exactly one derived squashfs (~34 MB) at a time
        let mut f = DistributionFabric::new(2, LustreFs::piz_daint())
            .with_node_cache_bytes(40_000_000);
        f.pull_blocking(&registry, "app-a:1", "u").unwrap();
        f.pull_blocking(&registry, "app-b:1", "u").unwrap();
        let app_a = f.resolve("app-a:1").unwrap().clone();
        let app_b = f.resolve("app-b:1").unwrap().clone();
        assert!(app_a.squashfs.compressed_bytes <= 40_000_000);
        assert!(
            app_a.squashfs.compressed_bytes
                + app_b.squashfs.compressed_bytes
                > 40_000_000
        );

        f.node_fetch_secs(&app_a, 0, 1);
        assert!(f.node_has_image(0, "app-a:1"));
        f.node_fetch_secs(&app_b, 0, 1);
        assert!(f.node_has_image(0, "app-b:1"));
        assert!(!f.node_has_image(0, "app-a:1"), "LRU evicted app-a");
        let stats = f.cache_stats();
        assert_eq!(stats.misses, 2);
        assert_eq!(stats.evictions, 1);
    }

    #[test]
    fn telemetry_counts_requests_coalescing_and_cache_traffic() {
        let tel = Arc::new(Telemetry::new(true));
        let reg = Registry::dockerhub();
        let mut f = DistributionFabric::new(4, LustreFs::piz_daint())
            .with_telemetry(Arc::clone(&tel));
        f.request(&reg, "ubuntu:xenial", "a").unwrap();
        f.request(&reg, "ubuntu:xenial", "b").unwrap();
        f.drain(&reg);
        let image = f.resolve("ubuntu:xenial").unwrap().clone();
        f.node_fetch_secs(&image, 0, 1);
        f.node_fetch_secs(&image, 0, 1);

        assert_eq!(tel.counter("fabric.requests"), 2);
        assert_eq!(tel.counter("fabric.coalesced_hits"), 1);
        assert_eq!(tel.counter("fabric.cold_fills"), 1);
        assert_eq!(tel.counter("fabric.cache_hits"), 1);
        let fetch = tel.histogram("fabric.fetch_secs").unwrap();
        assert_eq!(fetch.count, 2);
        // exactly one shard owns the reference and saw both requests
        let shard_counts: Vec<u64> = (0..4)
            .map(|s| tel.counter(&format!("shard.{s}.requests")))
            .collect();
        assert_eq!(shard_counts.iter().sum::<u64>(), 2);
        assert!(shard_counts.contains(&2));
    }
}
