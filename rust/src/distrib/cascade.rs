//! Topology-aware cascade fills (DESIGN.md S25): instead of every cold
//! node paying the Lustre broadcast (`NodeCache::cold_fill_secs` grows
//! linearly with storm width), nodes are grouped into cabinets and a
//! spanning tree distributes the squashfs peer-to-peer — one node pays
//! the gateway read, every other node fetches from an already-warm peer
//! over the cabinet backplane (or one inter-cabinet hop to seed a new
//! cabinet). Fill completion times come out of a [`SimKernel`] replay of
//! the tree, so cascades share the virtual-time model every other layer
//! schedules on and the storm makespan grows with the *depth* of the
//! tree (logarithmic in width), not the width itself.
//!
//! A dead peer never stalls the tree: children that would have fetched
//! from it time out ([`PEER_TIMEOUT_SECS`]) and fall back to the
//! gateway, and any node left stranded when the cascade drains is swept
//! into a gateway fallback as well.

use std::collections::{BTreeMap, BTreeSet, VecDeque};

use crate::pfs::LustreFs;
use crate::sim::{SimKernel, SimTime};

use super::node_cache::NodeCache;

/// Peer-to-peer bandwidth between nodes of one cabinet (backplane).
pub const INTRA_CABINET_BYTES_PER_SEC: f64 = 5e9;
/// Peer-to-peer bandwidth across cabinets (crossing the spine).
pub const INTER_CABINET_BYTES_PER_SEC: f64 = 1.25e9;
/// Fixed per-hop setup cost (peer handshake + squashfs open).
pub const CASCADE_HOP_SETUP_SECS: f64 = 200e-6;
/// How long a cold node waits on an unresponsive peer before falling
/// back to the gateway.
pub const PEER_TIMEOUT_SECS: f64 = 0.5;

/// Cabinet topology + fan-out of the cascade spanning tree.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CascadeConfig {
    /// Nodes per cabinet (node `n` lives in cabinet `n / cabinet_nodes`).
    pub cabinet_nodes: usize,
    /// Cold peers each warm node serves before going quiet.
    pub fanout: usize,
}

/// Aggregated cascade accounting across every plan a fabric has built.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CascadeStats {
    /// Distinct cascade plans (one per squashfs digest that stormed).
    pub cascades: u64,
    /// Fills served by the gateway/PFS (tree seeds + late joiners in
    /// unseeded cabinets).
    pub gateway_fills: u64,
    /// Fills that timed out on a dead peer and fell back to the gateway.
    pub gateway_fallbacks: u64,
    /// Fills served peer-to-peer instead of from the gateway.
    pub peer_transfers: u64,
    /// Longest peer-hop chain from the gateway seed to any node.
    pub max_depth: u64,
}

/// How a planned node receives the image.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Origin {
    /// The tree seed: a single gateway/PFS read.
    GatewaySeed,
    /// Timed out on a dead peer, re-fetched from the gateway.
    GatewayFallback,
    /// Served by a warm peer in the same cabinet.
    Intra,
    /// Served by a warm peer in another cabinet (seeding this one).
    Inter,
}

/// One replayed cascade: per-node fill durations plus the tree's
/// accounting. Built once per (squashfs digest, storm width) on first
/// cold miss; later fetches — including late joiners outside the
/// planned width — are answered from it.
#[derive(Debug, Clone)]
pub(crate) struct CascadePlan {
    cabinet_nodes: usize,
    /// Node → seconds from storm start until its copy is complete.
    ready_secs: BTreeMap<usize, f64>,
    /// Node → peer hops between the gateway seed and this node.
    depth: BTreeMap<usize, u64>,
    /// Cabinet → times image data entered it from outside (gateway
    /// reads + inter-cabinet transfers). 1 everywhere when all peers
    /// are alive: a cascade never fetches the image twice into one
    /// cabinet.
    cabinet_entries: BTreeMap<usize, u64>,
    pub(crate) gateway_fills: u64,
    pub(crate) gateway_fallbacks: u64,
    pub(crate) peer_transfers: u64,
    pub(crate) max_depth: u64,
    hop_intra_secs: f64,
    gateway_single_secs: f64,
}

impl CascadePlan {
    /// Fill duration and tree depth for `node`. Nodes inside the
    /// planned storm answer from the replay; late joiners take one
    /// intra-cabinet hop when their cabinet is already seeded, else a
    /// single-reader gateway fill (both recorded in the accounting).
    pub(crate) fn fill_for(&mut self, node: usize) -> (f64, u64) {
        if let Some(&secs) = self.ready_secs.get(&node) {
            return (secs, self.depth.get(&node).copied().unwrap_or(0));
        }
        let cabinet = node / self.cabinet_nodes;
        if self.cabinet_entries.contains_key(&cabinet) {
            self.peer_transfers += 1;
            self.max_depth = self.max_depth.max(1);
            self.ready_secs.insert(node, self.hop_intra_secs);
            self.depth.insert(node, 1);
            (self.hop_intra_secs, 1)
        } else {
            self.gateway_fills += 1;
            self.cabinet_entries.insert(cabinet, 1);
            self.ready_secs.insert(node, self.gateway_single_secs);
            self.depth.insert(node, 0);
            (self.gateway_single_secs, 0)
        }
    }

    /// Cabinet → outside-data entries (see the field doc).
    pub(crate) fn cabinet_entries(&self) -> &BTreeMap<usize, u64> {
        &self.cabinet_entries
    }

    /// Longest planned fill — the storm's fill makespan.
    pub(crate) fn makespan_secs(&self) -> f64 {
        self.ready_secs.values().copied().fold(0.0, f64::max)
    }
}

/// Replay one cascade over `width` nodes (ids `0..width`) on a private
/// [`SimKernel`]: events are "node became warm" pops, each warm node
/// serves up to `fanout` cold peers (own cabinet first, then the
/// lowest-indexed cabinet no transfer has entered yet), and dead nodes
/// turn their would-be children into timed-out gateway fallbacks.
pub(crate) fn plan(
    cfg: &CascadeConfig,
    width: usize,
    bytes: u64,
    dead: &BTreeSet<usize>,
    pfs: &LustreFs,
) -> CascadePlan {
    let cabinet_nodes = cfg.cabinet_nodes.max(1);
    let fanout = cfg.fanout.max(1);
    let width = width.max(1);
    let n_cabinets = width.div_ceil(cabinet_nodes);
    let gateway_single = NodeCache::cold_fill_secs(pfs, bytes, 1);
    let hop_intra =
        bytes as f64 / INTRA_CABINET_BYTES_PER_SEC + CASCADE_HOP_SETUP_SECS;
    let hop_inter =
        bytes as f64 / INTER_CABINET_BYTES_PER_SEC + CASCADE_HOP_SETUP_SECS;

    let mut plan = CascadePlan {
        cabinet_nodes,
        ready_secs: BTreeMap::new(),
        depth: BTreeMap::new(),
        cabinet_entries: BTreeMap::new(),
        gateway_fills: 0,
        gateway_fallbacks: 0,
        peer_transfers: 0,
        max_depth: 0,
        hop_intra_secs: hop_intra,
        gateway_single_secs: gateway_single,
    };

    // cold deques per cabinet, in node order
    let mut cold: Vec<VecDeque<usize>> = (0..n_cabinets)
        .map(|c| {
            (c * cabinet_nodes..((c + 1) * cabinet_nodes).min(width))
                .collect()
        })
        .collect();
    let mut seeded = vec![false; n_cabinets];
    let mut origin: BTreeMap<usize, Origin> = BTreeMap::new();
    let mut depth: BTreeMap<usize, u64> = BTreeMap::new();

    let mut kernel: SimKernel<usize> = SimKernel::new();
    // width >= 1, so cabinet 0 always has a node to seed from; an empty
    // deque would mean no nodes at all, where the empty plan is correct.
    let Some(seed) = cold[0].pop_front() else {
        return plan;
    };
    seeded[0] = true;
    origin.insert(seed, Origin::GatewaySeed);
    depth.insert(seed, 0);
    kernel.schedule_at(SimTime::from_secs(gateway_single), seed);

    while let Some((at, node)) = kernel.pop() {
        let t = at.as_secs_f64();
        let cabinet = node / cabinet_nodes;
        if dead.contains(&node) {
            // the node never answers: the cold peers it would have
            // served time out and re-fetch from the gateway directly
            for _ in 0..fanout {
                let Some(child) = cold[cabinet].pop_front() else {
                    break;
                };
                origin.insert(child, Origin::GatewayFallback);
                depth.insert(child, 0);
                kernel.schedule_at(
                    SimTime::from_secs(
                        t + PEER_TIMEOUT_SECS + gateway_single,
                    ),
                    child,
                );
            }
            continue;
        }
        // the node is warm at `t`: book its fill and accounting
        plan.ready_secs.insert(node, t);
        let d = depth.get(&node).copied().unwrap_or(0);
        plan.depth.insert(node, d);
        plan.max_depth = plan.max_depth.max(d);
        match origin.get(&node) {
            Some(Origin::GatewaySeed) => {
                plan.gateway_fills += 1;
                *plan.cabinet_entries.entry(cabinet).or_insert(0) += 1;
            }
            Some(Origin::GatewayFallback) => {
                plan.gateway_fills += 1;
                plan.gateway_fallbacks += 1;
                *plan.cabinet_entries.entry(cabinet).or_insert(0) += 1;
            }
            Some(Origin::Intra) => plan.peer_transfers += 1,
            Some(Origin::Inter) => {
                plan.peer_transfers += 1;
                *plan.cabinet_entries.entry(cabinet).or_insert(0) += 1;
            }
            None => {}
        }
        // serve up to `fanout` cold peers sequentially
        let mut cursor = t;
        for _ in 0..fanout {
            if let Some(child) = cold[cabinet].pop_front() {
                cursor += hop_intra;
                origin.insert(child, Origin::Intra);
                depth.insert(child, d + 1);
                kernel.schedule_at(SimTime::from_secs(cursor), child);
            } else if let Some(target) = (0..n_cabinets)
                .find(|&c| !seeded[c] && !cold[c].is_empty())
            {
                // the find above checked !cold[target].is_empty()
                let Some(child) = cold[target].pop_front() else {
                    break;
                };
                seeded[target] = true;
                cursor += hop_inter;
                origin.insert(child, Origin::Inter);
                depth.insert(child, d + 1);
                kernel.schedule_at(SimTime::from_secs(cursor), child);
            } else {
                break;
            }
        }
    }

    // sweep: nodes stranded by dead peers (never scheduled) fall back
    // to the gateway after the cascade's horizon — the tree never
    // stalls waiting on them
    let horizon = kernel.now().as_secs_f64();
    for queue in &mut cold {
        while let Some(node) = queue.pop_front() {
            let secs = horizon + PEER_TIMEOUT_SECS + gateway_single;
            plan.ready_secs.insert(node, secs);
            plan.depth.insert(node, 0);
            plan.gateway_fills += 1;
            plan.gateway_fallbacks += 1;
            *plan
                .cabinet_entries
                .entry(node / cabinet_nodes)
                .or_insert(0) += 1;
        }
    }
    plan
}

/// Closed-form estimate of one node's cold-fill duration in a
/// `width`-node cascade storm: the single gateway read plus a
/// logarithmic number of peer hops. The launch scheduler prices failed
/// cold fills with this instead of the linear broadcast cost.
pub(crate) fn estimate_fill_secs(
    cfg: &CascadeConfig,
    width: usize,
    bytes: u64,
    pfs: &LustreFs,
) -> f64 {
    let gateway = NodeCache::cold_fill_secs(pfs, bytes, 1);
    let hop =
        bytes as f64 / INTRA_CABINET_BYTES_PER_SEC + CASCADE_HOP_SETUP_SECS;
    let branching = (cfg.fanout.max(1) + 1) as f64;
    let depth = (width.max(1) as f64).ln() / branching.ln();
    gateway + depth.ceil() * hop
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> CascadeConfig {
        CascadeConfig {
            cabinet_nodes: 8,
            fanout: 2,
        }
    }

    #[test]
    fn all_live_tree_covers_every_node_once() {
        let pfs = LustreFs::piz_daint();
        let mut p =
            plan(&cfg(), 64, 1_000_000_000, &BTreeSet::new(), &pfs);
        assert_eq!(p.ready_secs.len(), 64);
        assert_eq!(p.gateway_fills, 1, "one gateway read for the storm");
        assert_eq!(p.peer_transfers, 63);
        assert_eq!(p.gateway_fallbacks, 0);
        // data enters each of the 8 cabinets exactly once
        assert_eq!(p.cabinet_entries.len(), 8);
        assert!(p.cabinet_entries.values().all(|&e| e == 1));
        // every fill is at least the seed's gateway read
        let seed_fill = p.fill_for(0).0;
        assert!(p.ready_secs.values().all(|&s| s >= seed_fill));
        assert!(p.max_depth >= 3, "64 nodes at fanout 2: a real tree");
    }

    #[test]
    fn makespan_grows_sublinearly_with_width() {
        let pfs = LustreFs::piz_daint();
        let bytes = 1_000_000_000;
        let narrow =
            plan(&cfg(), 64, bytes, &BTreeSet::new(), &pfs).makespan_secs();
        let wide = plan(&cfg(), 1024, bytes, &BTreeSet::new(), &pfs)
            .makespan_secs();
        assert!(
            wide < narrow * 4.0,
            "16x the nodes must cost < 4x the fill: {narrow}s -> {wide}s"
        );
        // the broadcast keeps up while the OST array (80 GB/s aggregate)
        // outruns the storm; the tree merely beats it at 1024 nodes and
        // wins decisively once the broadcast saturates
        let broadcast = NodeCache::cold_fill_secs(&pfs, bytes, 1024);
        assert!(
            wide < broadcast,
            "cascade {wide}s vs broadcast {broadcast}s at 1024 nodes"
        );
        let storm = plan(&cfg(), 4096, bytes, &BTreeSet::new(), &pfs)
            .makespan_secs();
        let saturated = NodeCache::cold_fill_secs(&pfs, bytes, 4096);
        assert!(
            storm * 4.0 < saturated,
            "cascade {storm}s vs saturated broadcast {saturated}s \
             at 4096 nodes"
        );
    }

    #[test]
    fn dead_seed_falls_back_without_stalling() {
        let pfs = LustreFs::piz_daint();
        let dead = BTreeSet::from([0usize, 9]);
        let p = plan(&cfg(), 32, 500_000_000, &dead, &pfs);
        // every live node still gets a finite fill
        for node in 0..32 {
            if dead.contains(&node) {
                assert!(!p.ready_secs.contains_key(&node));
            } else {
                assert!(p.ready_secs[&node].is_finite());
            }
        }
        assert!(p.gateway_fallbacks >= 1, "dead peers force fallbacks");
        assert_eq!(p.ready_secs.len(), 30);
    }

    #[test]
    fn late_joiner_uses_warm_cabinet_or_gateway() {
        let pfs = LustreFs::piz_daint();
        let mut p =
            plan(&cfg(), 16, 100_000_000, &BTreeSet::new(), &pfs);
        // node 100 is outside the planned width and its cabinet: a
        // fresh gateway fill, entering its cabinet once
        let (gw_fill, d) = p.fill_for(100);
        assert_eq!(d, 0);
        assert!((gw_fill - p.gateway_single_secs).abs() < 1e-12);
        // node 101 shares cabinet 12 with the now-warm node 100: one
        // intra-cabinet hop, not another gateway read (an uncontended
        // gateway read is cheaper than a 5 GB/s backplane hop — the
        // point of the peer fetch is sparing the PFS, not this node)
        let (peer_fill, d) = p.fill_for(101);
        assert_eq!(d, 1);
        assert!((peer_fill - p.hop_intra_secs).abs() < 1e-12);
        assert_eq!(p.cabinet_entries[&12], 1);
    }

    #[test]
    fn estimate_tracks_the_replayed_makespan() {
        let pfs = LustreFs::piz_daint();
        let bytes = 1_000_000_000;
        let replay = plan(&cfg(), 512, bytes, &BTreeSet::new(), &pfs)
            .makespan_secs();
        let est = estimate_fill_secs(&cfg(), 512, bytes, &pfs);
        // same order of magnitude: the estimate is a pricing model,
        // not a replay
        assert!(est > replay * 0.1 && est < replay * 10.0);
    }
}
