//! Content-addressed blob store (DESIGN.md S18): the cluster-wide layer
//! store behind the gateway shards. Every image layer is a blob keyed by
//! its content digest; images that share base layers (the common
//! `FROM ubuntu` case) store those layers exactly once. Ref-counting keeps
//! a blob alive as long as any registered image still references it, and
//! the logical-vs-stored accounting is what the `gateway_scale` bench
//! reports as the dedup ratio.

use std::collections::BTreeMap;

use crate::image::Image;

/// One stored blob: size plus the number of registered images using it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlobInfo {
    /// Compressed size of the blob on the store.
    pub bytes: u64,
    /// Registered images currently referencing the blob.
    pub refcount: u32,
}

/// Receipt of registering one image: how much was new vs deduplicated.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageReceipt {
    /// Canonical reference of the registered image.
    pub reference: String,
    /// Layers stored for the first time.
    pub new_layers: usize,
    /// Layers that were already present (refcount bumped only).
    pub shared_layers: usize,
    /// Bytes newly written to the store.
    pub new_bytes: u64,
    /// Bytes satisfied by blobs already present.
    pub shared_bytes: u64,
}

/// The content-addressed store.
#[derive(Debug, Default)]
pub struct ContentStore {
    blobs: BTreeMap<u64, BlobInfo>,
    /// Sum of blob sizes weighted by refcount — what naive per-image
    /// storage would have cost.
    logical_bytes: u64,
    /// Actual bytes on disk (each blob once).
    stored_bytes: u64,
}

impl ContentStore {
    /// Empty store.
    pub fn new() -> ContentStore {
        ContentStore::default()
    }

    /// Add one reference to `digest`, storing the blob if it is new.
    /// Returns true when the blob was newly stored.
    pub fn insert(&mut self, digest: u64, bytes: u64) -> bool {
        self.logical_bytes += bytes;
        match self.blobs.get_mut(&digest) {
            Some(blob) => {
                blob.refcount += 1;
                false
            }
            None => {
                self.blobs.insert(digest, BlobInfo { bytes, refcount: 1 });
                self.stored_bytes += bytes;
                true
            }
        }
    }

    /// Drop one reference; the blob is evicted when its refcount reaches
    /// zero. Returns false if the digest was unknown.
    pub fn release(&mut self, digest: u64) -> bool {
        let Some(blob) = self.blobs.get_mut(&digest) else {
            return false;
        };
        self.logical_bytes -= blob.bytes;
        blob.refcount -= 1;
        if blob.refcount == 0 {
            self.stored_bytes -= blob.bytes;
            self.blobs.remove(&digest);
        }
        true
    }

    /// Whether a blob with `digest` is currently stored.
    pub fn contains(&self, digest: u64) -> bool {
        self.blobs.contains_key(&digest)
    }

    /// Current reference count of `digest` (0 if unknown).
    pub fn refcount(&self, digest: u64) -> u32 {
        self.blobs.get(&digest).map_or(0, |b| b.refcount)
    }

    /// Register every layer of `image`. Idempotence is the caller's
    /// concern (the cluster registers each reference once).
    pub fn add_image(&mut self, image: &Image) -> ImageReceipt {
        let mut receipt = ImageReceipt {
            reference: image.reference.canonical(),
            new_layers: 0,
            shared_layers: 0,
            new_bytes: 0,
            shared_bytes: 0,
        };
        for layer in &image.layers {
            let bytes = layer.compressed_bytes();
            if self.insert(layer.digest, bytes) {
                receipt.new_layers += 1;
                receipt.new_bytes += bytes;
            } else {
                receipt.shared_layers += 1;
                receipt.shared_bytes += bytes;
            }
        }
        receipt
    }

    /// Unregister an image, releasing each of its layers once.
    pub fn remove_image(&mut self, image: &Image) {
        for layer in &image.layers {
            self.release(layer.digest);
        }
    }

    /// Distinct blobs currently stored.
    pub fn blob_count(&self) -> usize {
        self.blobs.len()
    }

    /// Actual bytes on disk (each blob counted once).
    pub fn stored_bytes(&self) -> u64 {
        self.stored_bytes
    }

    /// Bytes naive per-image storage would have cost (blob sizes weighted
    /// by refcount).
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// Bytes dedup saved versus storing every image's layers separately.
    pub fn saved_bytes(&self) -> u64 {
        self.logical_bytes - self.stored_bytes
    }

    /// logical / stored; 1.0 means no sharing at all.
    pub fn dedup_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.stored_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::builder::{self, ImageBuilder};

    #[test]
    fn insert_release_refcounting() {
        let mut cas = ContentStore::new();
        assert!(cas.insert(42, 1000));
        assert!(!cas.insert(42, 1000)); // second ref, not a second copy
        assert_eq!(cas.refcount(42), 2);
        assert_eq!(cas.stored_bytes(), 1000);
        assert_eq!(cas.logical_bytes(), 2000);

        assert!(cas.release(42));
        assert!(cas.contains(42)); // still referenced
        assert!(cas.release(42));
        assert!(!cas.contains(42)); // refcount hit zero -> evicted
        assert_eq!(cas.stored_bytes(), 0);
        assert_eq!(cas.logical_bytes(), 0);
        assert!(!cas.release(42)); // unknown digest
    }

    #[test]
    fn derived_images_dedup_base_layers() {
        let base = builder::ubuntu_xenial();
        let app_a = ImageBuilder::from_image(&base, "app-a:1.0")
            .file("/opt/a/app.bin", 50_000_000)
            .build();
        let app_b = ImageBuilder::from_image(&base, "app-b:1.0")
            .file("/opt/b/app.bin", 50_000_000)
            .build();

        let mut cas = ContentStore::new();
        let ra = cas.add_image(&app_a);
        assert_eq!(ra.shared_layers, 0); // first image: everything is new
        assert_eq!(ra.new_layers, app_a.layers.len());

        let rb = cas.add_image(&app_b);
        assert_eq!(rb.shared_layers, base.layers.len());
        assert_eq!(rb.new_layers, 1); // only the app layer

        // the dedup criterion: bytes stored < sum of per-image bytes
        let per_image_sum = app_a.transfer_bytes() + app_b.transfer_bytes();
        assert_eq!(cas.logical_bytes(), per_image_sum);
        assert!(cas.stored_bytes() < per_image_sum);
        assert!(cas.dedup_ratio() > 1.2, "ratio={}", cas.dedup_ratio());
        assert_eq!(
            cas.saved_bytes(),
            per_image_sum - cas.stored_bytes()
        );
    }

    #[test]
    fn removing_one_image_keeps_shared_layers_alive() {
        let base = builder::ubuntu_xenial();
        let app = ImageBuilder::from_image(&base, "app:1.0")
            .file("/opt/app.bin", 10_000_000)
            .build();
        let mut cas = ContentStore::new();
        cas.add_image(&base);
        cas.add_image(&app);

        cas.remove_image(&app);
        // base layers survive (still referenced by `base`)
        for layer in &base.layers {
            assert!(cas.contains(layer.digest));
        }
        assert_eq!(cas.logical_bytes(), base.transfer_bytes());

        cas.remove_image(&base);
        assert_eq!(cas.blob_count(), 0);
        assert_eq!(cas.stored_bytes(), 0);
    }

    #[test]
    fn unrelated_images_share_nothing() {
        let mut cas = ContentStore::new();
        cas.add_image(&builder::ubuntu_xenial());
        let before = cas.stored_bytes();
        let receipt = cas.add_image(&builder::pynamic_image());
        assert_eq!(receipt.shared_layers, 0);
        assert!(cas.stored_bytes() > before);
        assert!((cas.dedup_ratio() - 1.0).abs() < 1e-12);
    }
}
