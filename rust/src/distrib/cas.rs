//! Content-addressed blob store (DESIGN.md S18): the cluster-wide layer
//! store behind the gateway shards. Every image layer is a blob keyed by
//! its content digest; images that share base layers (the common
//! `FROM ubuntu` case) store those layers exactly once. Ref-counting keeps
//! a blob alive as long as any registered image still references it, and
//! the logical-vs-stored accounting is what the `gateway_scale` bench
//! reports as the dedup ratio.
//!
//! With a [`Chunker`] installed (DESIGN.md S25) the blob granularity
//! drops below layers: every file of a layer is cut into content-defined
//! chunks, so a derived image whose layer differs by one file still
//! shares every chunk of the unchanged files with its parent — the
//! layer-digest mismatch no longer forces a full re-store.

use std::collections::BTreeMap;

use crate::image::{Image, Layer};
use crate::vfs::tree::VNode;

use super::chunk::Chunker;

/// One stored blob: size plus the number of registered images using it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlobInfo {
    /// Compressed size of the blob on the store.
    pub bytes: u64,
    /// Registered images currently referencing the blob.
    pub refcount: u32,
}

/// Receipt of registering one image: how much was new vs deduplicated.
#[derive(Debug, Clone, PartialEq)]
pub struct ImageReceipt {
    /// Canonical reference of the registered image.
    pub reference: String,
    /// Layers stored for the first time.
    pub new_layers: usize,
    /// Layers that were already present (refcount bumped only).
    pub shared_layers: usize,
    /// Bytes newly written to the store.
    pub new_bytes: u64,
    /// Bytes satisfied by blobs already present.
    pub shared_bytes: u64,
    /// Chunks stored for the first time (0 unless chunking is enabled).
    pub new_chunks: usize,
    /// Chunks deduplicated against blobs already present (0 unless
    /// chunking is enabled).
    pub shared_chunks: usize,
}

/// The content-addressed store.
#[derive(Debug, Default)]
pub struct ContentStore {
    blobs: BTreeMap<u64, BlobInfo>,
    /// Sum of blob sizes weighted by refcount — what naive per-image
    /// storage would have cost.
    logical_bytes: u64,
    /// Actual bytes on disk (each blob once).
    stored_bytes: u64,
    /// When set, blobs are content-defined chunks of layer files rather
    /// than whole layers (DESIGN.md S25).
    chunker: Option<Chunker>,
    /// Layer digest → its chunk list as (chunk digest, bytes), computed
    /// once per distinct layer; chunk lists are derived purely from file
    /// content identities, so they are stable across images.
    layer_chunks: BTreeMap<u64, Vec<(u64, u64)>>,
    /// Layer digest → registered images carrying that layer (chunked
    /// mode bookkeeping so `remove_image` releases chunks exactly once
    /// per image).
    layer_refs: BTreeMap<u64, u32>,
    /// Chunks stored for the first time, across all registrations.
    chunks_new: u64,
    /// Chunk insertions satisfied by an already-stored chunk.
    chunks_shared: u64,
}

impl ContentStore {
    /// Empty store.
    pub fn new() -> ContentStore {
        ContentStore::default()
    }

    /// Switch the store to content-defined chunk granularity. Call
    /// before any image is registered: existing whole-layer blobs are
    /// not re-chunked.
    pub fn with_chunker(mut self, chunker: Chunker) -> ContentStore {
        self.chunker = Some(chunker);
        self
    }

    /// Whether the store dedups at chunk (vs whole-layer) granularity.
    pub fn chunked(&self) -> bool {
        self.chunker.is_some()
    }

    /// Chunks stored for the first time across all registrations
    /// (0 unless chunking is enabled).
    pub fn chunks_new(&self) -> u64 {
        self.chunks_new
    }

    /// Chunk insertions satisfied by an already-stored chunk.
    pub fn chunks_shared(&self) -> u64 {
        self.chunks_shared
    }

    /// Fraction of chunk insertions that hit an existing chunk
    /// (0.0 when nothing has been chunked yet).
    pub fn chunk_hit_ratio(&self) -> f64 {
        let total = self.chunks_new + self.chunks_shared;
        if total == 0 {
            0.0
        } else {
            self.chunks_shared as f64 / total as f64
        }
    }

    /// The chunk list of one layer: every file in the layer's tree cut
    /// into content-defined chunks keyed by the file's content digest,
    /// so identical files in different layers yield identical chunks.
    fn chunk_layer(chunker: &Chunker, layer: &Layer) -> Vec<(u64, u64)> {
        let mut chunks = Vec::new();
        let files = layer.tree.walk("/").unwrap_or_default();
        for (_, node) in files {
            let VNode::File { size, digest, .. } = node else {
                continue;
            };
            // chunk the transfer representation of the file
            let compressed = (size as f64 * 0.5) as u64;
            if compressed == 0 {
                continue;
            }
            chunks.extend(
                chunker
                    .synthetic_chunks(digest, compressed)
                    .into_iter()
                    .map(|c| (c.digest, c.length)),
            );
        }
        chunks
    }

    /// Non-mutating estimate of how much of `image` is already stored:
    /// the byte fraction its blobs (chunks when chunking is enabled,
    /// whole layers otherwise) would dedup against the current store.
    /// The gateway scales the download/PFS stages of a pull by the miss
    /// fraction.
    pub fn preview_shared_fraction(&self, image: &Image) -> f64 {
        let mut total = 0u64;
        let mut shared = 0u64;
        match &self.chunker {
            Some(chunker) => {
                for layer in &image.layers {
                    let owned;
                    let chunks = match self.layer_chunks.get(&layer.digest)
                    {
                        Some(known) => known,
                        None => {
                            owned = Self::chunk_layer(chunker, layer);
                            &owned
                        }
                    };
                    for &(digest, bytes) in chunks {
                        total += bytes;
                        if self.contains(digest) {
                            shared += bytes;
                        }
                    }
                }
            }
            None => {
                for layer in &image.layers {
                    let bytes = layer.compressed_bytes();
                    total += bytes;
                    if self.contains(layer.digest) {
                        shared += bytes;
                    }
                }
            }
        }
        if total == 0 {
            0.0
        } else {
            shared as f64 / total as f64
        }
    }

    /// Add one reference to `digest`, storing the blob if it is new.
    /// Returns true when the blob was newly stored.
    pub fn insert(&mut self, digest: u64, bytes: u64) -> bool {
        self.logical_bytes += bytes;
        match self.blobs.get_mut(&digest) {
            Some(blob) => {
                blob.refcount += 1;
                false
            }
            None => {
                self.blobs.insert(digest, BlobInfo { bytes, refcount: 1 });
                self.stored_bytes += bytes;
                true
            }
        }
    }

    /// Drop one reference; the blob is evicted when its refcount reaches
    /// zero. Returns false if the digest was unknown.
    pub fn release(&mut self, digest: u64) -> bool {
        let Some(blob) = self.blobs.get_mut(&digest) else {
            return false;
        };
        self.logical_bytes -= blob.bytes;
        blob.refcount -= 1;
        if blob.refcount == 0 {
            self.stored_bytes -= blob.bytes;
            self.blobs.remove(&digest);
        }
        true
    }

    /// Whether a blob with `digest` is currently stored.
    pub fn contains(&self, digest: u64) -> bool {
        self.blobs.contains_key(&digest)
    }

    /// Current reference count of `digest` (0 if unknown).
    pub fn refcount(&self, digest: u64) -> u32 {
        self.blobs.get(&digest).map_or(0, |b| b.refcount)
    }

    /// Register every layer of `image`. Idempotence is the caller's
    /// concern (the cluster registers each reference once).
    pub fn add_image(&mut self, image: &Image) -> ImageReceipt {
        let mut receipt = ImageReceipt {
            reference: image.reference.canonical(),
            new_layers: 0,
            shared_layers: 0,
            new_bytes: 0,
            shared_bytes: 0,
            new_chunks: 0,
            shared_chunks: 0,
        };
        if let Some(chunker) = self.chunker.clone() {
            for layer in &image.layers {
                let first = !self.layer_refs.contains_key(&layer.digest);
                *self.layer_refs.entry(layer.digest).or_insert(0) += 1;
                if first {
                    receipt.new_layers += 1;
                    let chunks = Self::chunk_layer(&chunker, layer);
                    self.layer_chunks.insert(layer.digest, chunks);
                } else {
                    receipt.shared_layers += 1;
                }
                let chunks = self
                    .layer_chunks
                    .get(&layer.digest)
                    .cloned()
                    .unwrap_or_default();
                for (digest, bytes) in chunks {
                    if self.insert(digest, bytes) {
                        receipt.new_chunks += 1;
                        receipt.new_bytes += bytes;
                        self.chunks_new += 1;
                    } else {
                        receipt.shared_chunks += 1;
                        receipt.shared_bytes += bytes;
                        self.chunks_shared += 1;
                    }
                }
            }
            return receipt;
        }
        for layer in &image.layers {
            let bytes = layer.compressed_bytes();
            if self.insert(layer.digest, bytes) {
                receipt.new_layers += 1;
                receipt.new_bytes += bytes;
            } else {
                receipt.shared_layers += 1;
                receipt.shared_bytes += bytes;
            }
        }
        receipt
    }

    /// Unregister an image, releasing each of its layers (or, in chunked
    /// mode, each of its layers' chunks) once.
    pub fn remove_image(&mut self, image: &Image) {
        if self.chunked() {
            for layer in &image.layers {
                let chunks = self
                    .layer_chunks
                    .get(&layer.digest)
                    .cloned()
                    .unwrap_or_default();
                for (digest, _) in chunks {
                    self.release(digest);
                }
                if let Some(refs) = self.layer_refs.get_mut(&layer.digest) {
                    *refs -= 1;
                    if *refs == 0 {
                        self.layer_refs.remove(&layer.digest);
                        self.layer_chunks.remove(&layer.digest);
                    }
                }
            }
            return;
        }
        for layer in &image.layers {
            self.release(layer.digest);
        }
    }

    /// Distinct blobs currently stored.
    pub fn blob_count(&self) -> usize {
        self.blobs.len()
    }

    /// Actual bytes on disk (each blob counted once).
    pub fn stored_bytes(&self) -> u64 {
        self.stored_bytes
    }

    /// Bytes naive per-image storage would have cost (blob sizes weighted
    /// by refcount).
    pub fn logical_bytes(&self) -> u64 {
        self.logical_bytes
    }

    /// Bytes dedup saved versus storing every image's layers separately.
    pub fn saved_bytes(&self) -> u64 {
        self.logical_bytes - self.stored_bytes
    }

    /// logical / stored; 1.0 means no sharing at all.
    pub fn dedup_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.stored_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::builder::{self, ImageBuilder};

    #[test]
    fn insert_release_refcounting() {
        let mut cas = ContentStore::new();
        assert!(cas.insert(42, 1000));
        assert!(!cas.insert(42, 1000)); // second ref, not a second copy
        assert_eq!(cas.refcount(42), 2);
        assert_eq!(cas.stored_bytes(), 1000);
        assert_eq!(cas.logical_bytes(), 2000);

        assert!(cas.release(42));
        assert!(cas.contains(42)); // still referenced
        assert!(cas.release(42));
        assert!(!cas.contains(42)); // refcount hit zero -> evicted
        assert_eq!(cas.stored_bytes(), 0);
        assert_eq!(cas.logical_bytes(), 0);
        assert!(!cas.release(42)); // unknown digest
    }

    #[test]
    fn derived_images_dedup_base_layers() {
        let base = builder::ubuntu_xenial();
        let app_a = ImageBuilder::from_image(&base, "app-a:1.0")
            .file("/opt/a/app.bin", 50_000_000)
            .build();
        let app_b = ImageBuilder::from_image(&base, "app-b:1.0")
            .file("/opt/b/app.bin", 50_000_000)
            .build();

        let mut cas = ContentStore::new();
        let ra = cas.add_image(&app_a);
        assert_eq!(ra.shared_layers, 0); // first image: everything is new
        assert_eq!(ra.new_layers, app_a.layers.len());

        let rb = cas.add_image(&app_b);
        assert_eq!(rb.shared_layers, base.layers.len());
        assert_eq!(rb.new_layers, 1); // only the app layer

        // the dedup criterion: bytes stored < sum of per-image bytes
        let per_image_sum = app_a.transfer_bytes() + app_b.transfer_bytes();
        assert_eq!(cas.logical_bytes(), per_image_sum);
        assert!(cas.stored_bytes() < per_image_sum);
        assert!(cas.dedup_ratio() > 1.2, "ratio={}", cas.dedup_ratio());
        assert_eq!(
            cas.saved_bytes(),
            per_image_sum - cas.stored_bytes()
        );
    }

    #[test]
    fn removing_one_image_keeps_shared_layers_alive() {
        let base = builder::ubuntu_xenial();
        let app = ImageBuilder::from_image(&base, "app:1.0")
            .file("/opt/app.bin", 10_000_000)
            .build();
        let mut cas = ContentStore::new();
        cas.add_image(&base);
        cas.add_image(&app);

        cas.remove_image(&app);
        // base layers survive (still referenced by `base`)
        for layer in &base.layers {
            assert!(cas.contains(layer.digest));
        }
        assert_eq!(cas.logical_bytes(), base.transfer_bytes());

        cas.remove_image(&base);
        assert_eq!(cas.blob_count(), 0);
        assert_eq!(cas.stored_bytes(), 0);
    }

    #[test]
    fn unrelated_images_share_nothing() {
        let mut cas = ContentStore::new();
        cas.add_image(&builder::ubuntu_xenial());
        let before = cas.stored_bytes();
        let receipt = cas.add_image(&builder::pynamic_image());
        assert_eq!(receipt.shared_layers, 0);
        assert!(cas.stored_bytes() > before);
        assert!((cas.dedup_ratio() - 1.0).abs() < 1e-12);
        assert_eq!((receipt.new_chunks, receipt.shared_chunks), (0, 0));
        assert!(!cas.chunked());
        assert_eq!(cas.chunk_hit_ratio(), 0.0);
    }

    /// Two images whose top layers differ by one small file: the layer
    /// digests diverge, so whole-layer dedup re-stores everything — but
    /// chunked dedup shares every chunk of the unchanged files.
    fn near_identical_pair() -> (crate::image::Image, crate::image::Image) {
        let base = builder::ubuntu_xenial();
        let v1 = ImageBuilder::from_image(&base, "app:1.0")
            .file("/opt/app/bin", 80_000_000)
            .file("/opt/app/data", 40_000_000)
            .build();
        let mut v2 = v1.clone();
        let mut tree = v2.layers.last().unwrap().tree.clone();
        tree.add_file("/opt/app/patch.cfg", 4_096, 0xFEED_FACE).unwrap();
        *v2.layers.last_mut().unwrap() =
            crate::image::Layer::new(tree, vec![]);
        v2.reference = crate::image::ImageRef::parse("app:2.0").unwrap();
        v2.manifest.layer_digests =
            v2.layers.iter().map(|l| l.digest).collect();
        (v1, v2)
    }

    #[test]
    fn chunked_store_dedups_below_layer_granularity() {
        let (v1, v2) = near_identical_pair();
        assert_ne!(
            v1.layers.last().unwrap().digest,
            v2.layers.last().unwrap().digest,
            "the edit must change the layer digest"
        );

        let mut cas = ContentStore::new()
            .with_chunker(Chunker::new(1 << 20, 9));
        let r1 = cas.add_image(&v1);
        assert!(r1.new_chunks > 0);
        assert_eq!(r1.shared_layers, 0);

        let r2 = cas.add_image(&v2);
        // the derived image's top layer is "new" at layer granularity…
        assert_eq!(r2.new_layers, 1);
        // …yet almost all of its bytes dedup chunk-by-chunk
        assert!(
            r2.shared_bytes > 9 * r2.new_bytes,
            "shared={} new={}",
            r2.shared_bytes,
            r2.new_bytes
        );
        assert!(r2.shared_chunks > r2.new_chunks);
        assert!(cas.chunk_hit_ratio() > 0.4);
        assert!(cas.stored_bytes() < cas.logical_bytes());

        // the preview the gateway prices dedup with agrees
        let frac = cas.preview_shared_fraction(&v2);
        assert!(frac > 0.9, "preview fraction {frac}");
    }

    #[test]
    fn chunked_remove_is_symmetric() {
        let (v1, v2) = near_identical_pair();
        let mut cas = ContentStore::new()
            .with_chunker(Chunker::new(1 << 20, 9));
        cas.add_image(&v1);
        cas.add_image(&v2);
        cas.remove_image(&v2);
        // v1's chunks all survive; the preview sees it fully stored
        assert!(cas.preview_shared_fraction(&v1) > 0.999);
        cas.remove_image(&v1);
        assert_eq!(cas.blob_count(), 0);
        assert_eq!(cas.stored_bytes(), 0);
        assert_eq!(cas.logical_bytes(), 0);
    }

    #[test]
    fn preview_matches_layer_dedup_when_not_chunked() {
        let base = builder::ubuntu_xenial();
        let app = ImageBuilder::from_image(&base, "app:1.0")
            .file("/opt/app.bin", 10_000_000)
            .build();
        let mut cas = ContentStore::new();
        assert_eq!(cas.preview_shared_fraction(&app), 0.0);
        cas.add_image(&base);
        let frac = cas.preview_shared_fraction(&app);
        // every base layer is present, only the app layer is missing
        let shared: u64 =
            base.layers.iter().map(|l| l.compressed_bytes()).sum();
        let total: u64 =
            app.layers.iter().map(|l| l.compressed_bytes()).sum();
        assert!((frac - shared as f64 / total as f64).abs() < 1e-12);
        cas.add_image(&app);
        assert!(cas.preview_shared_fraction(&app) > 0.999);
    }
}
