//! Content-defined chunking (DESIGN.md S25): the sub-layer granularity
//! of the content-addressed store. A gear rolling hash cuts a byte
//! stream at content-determined boundaries, so an edit in the middle of
//! a layer only changes the chunks overlapping the edit — everything
//! before and after re-aligns to the same cut points and dedups against
//! the previous version (the eStargz/CDC property lazy pulling builds
//! on).
//!
//! Two entry points share one boundary model:
//!
//! * [`Chunker::chunk`] — real byte-level chunking, used by the
//!   property suite to prove round-trip reassembly, boundary stability
//!   under edits, and per-seed determinism;
//! * [`Chunker::synthetic_chunks`] — the simulation-side equivalent:
//!   given a file's content digest and size it derives the same chunk
//!   sequence every time, so two images carrying an identical file
//!   (same digest, same size) produce identical chunk digests and dedup
//!   below layer granularity in [`super::cas::ContentStore`].

use crate::util::prng::Rng;

/// Smallest chunk-size target the site builder accepts (4 KB — below
/// this the per-chunk bookkeeping dwarfs the payload).
pub const MIN_CHUNK_TARGET_BYTES: u64 = 4_096;
/// Largest chunk-size target the site builder accepts (64 MB — above
/// this chunking degenerates to whole-layer blobs).
pub const MAX_CHUNK_TARGET_BYTES: u64 = 67_108_864;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

/// One content-defined chunk of a byte stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// Byte offset of the chunk within the stream.
    pub offset: u64,
    /// Chunk length in bytes (always > 0).
    pub length: u64,
    /// FNV-1a content digest of the chunk's bytes (for synthetic
    /// chunks: of the owning file's content identity).
    pub digest: u64,
}

/// Gear-hash content-defined chunker. Cut points depend only on the
/// bytes in a 64-byte rolling window, so identical content produces
/// identical chunks regardless of what surrounds it (after one chunk of
/// resynchronization). Deterministic per `(target, seed)`.
#[derive(Clone)]
pub struct Chunker {
    target_bytes: u64,
    min_bytes: u64,
    max_bytes: u64,
    /// Boundary mask: a cut where `(hash & mask) == mask`, giving an
    /// expected spacing of `target` past the minimum length.
    mask: u64,
    seed: u64,
    /// Per-byte gear table derived from the seed.
    gear: Box<[u64; 256]>,
}

impl std::fmt::Debug for Chunker {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Chunker")
            .field("target_bytes", &self.target_bytes)
            .field("min_bytes", &self.min_bytes)
            .field("max_bytes", &self.max_bytes)
            .field("seed", &self.seed)
            .finish()
    }
}

impl Chunker {
    /// A chunker with mean chunk size `target_bytes` (clamped to at
    /// least 64) and cut points keyed by `seed`. Minimum chunk length is
    /// `target / 4`, maximum `target * 4`.
    pub fn new(target_bytes: u64, seed: u64) -> Chunker {
        let target = target_bytes.max(64);
        let min = (target / 4).max(1);
        let max = target.saturating_mul(4);
        // expected run past `min` before a boundary fires is 2^bits;
        // aim it at the remaining distance to the target
        let span = (target - min).max(2);
        let mask = (1u64 << span.ilog2()) - 1;
        let mut rng = Rng::new(seed ^ 0x9e3779b97f4a7c15);
        let mut gear = Box::new([0u64; 256]);
        for g in gear.iter_mut() {
            *g = rng.next_u64();
        }
        Chunker {
            target_bytes: target,
            min_bytes: min,
            max_bytes: max,
            mask,
            seed,
            gear,
        }
    }

    /// Mean chunk size this chunker aims for.
    pub fn target_bytes(&self) -> u64 {
        self.target_bytes
    }

    /// Smallest chunk the boundary model can emit (except a short tail).
    pub fn min_bytes(&self) -> u64 {
        self.min_bytes
    }

    /// Forced-cut ceiling on chunk length.
    pub fn max_bytes(&self) -> u64 {
        self.max_bytes
    }

    /// The seed the gear table and synthetic boundaries derive from.
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Chunk `data` at content-defined boundaries. The chunks partition
    /// the input exactly (offsets are contiguous, lengths sum to
    /// `data.len()`), so concatenating the slices reassembles the input
    /// byte for byte. Empty input yields no chunks.
    pub fn chunk(&self, data: &[u8]) -> Vec<Chunk> {
        let mut chunks = Vec::new();
        let mut start = 0usize;
        let mut hash = 0u64;
        for (i, &b) in data.iter().enumerate() {
            // gear roll: old bytes age out of the hash after 64 shifts,
            // so boundaries depend on a 64-byte window of content only
            hash = (hash << 1).wrapping_add(self.gear[b as usize]);
            let len = (i + 1 - start) as u64;
            let boundary = len >= self.min_bytes
                && (hash & self.mask) == self.mask;
            if boundary || len >= self.max_bytes {
                chunks.push(self.cut(data, start, i + 1));
                start = i + 1;
                hash = 0;
            }
        }
        if start < data.len() {
            chunks.push(self.cut(data, start, data.len()));
        }
        chunks
    }

    fn cut(&self, data: &[u8], start: usize, end: usize) -> Chunk {
        Chunk {
            offset: start as u64,
            length: (end - start) as u64,
            digest: fnv1a(FNV_OFFSET, &data[start..end]),
        }
    }

    /// The simulation-side chunk sequence for a file identified by
    /// `content_digest` holding `bytes` bytes: chunk lengths are drawn
    /// deterministically from `(seed, content_digest)` with the same
    /// min/target spacing the byte-level model produces, and each chunk
    /// digest mixes the content identity with its position — two files
    /// with the same content digest and size always yield identical
    /// chunks, files differing in either never collide.
    pub fn synthetic_chunks(
        &self,
        content_digest: u64,
        bytes: u64,
    ) -> Vec<Chunk> {
        let mut chunks = Vec::new();
        if bytes == 0 {
            return chunks;
        }
        let mut rng = Rng::from_tags(&[
            "cdc-synthetic",
            &self.seed.to_string(),
            &content_digest.to_string(),
        ]);
        let spread = 2 * (self.target_bytes - self.min_bytes) + 1;
        let mut offset = 0u64;
        while offset < bytes {
            let drawn = self.min_bytes + rng.below(spread);
            let length = drawn.min(bytes - offset);
            let digest = fnv1a_words(
                FNV_OFFSET,
                &[content_digest, offset, length, self.seed],
            );
            chunks.push(Chunk {
                offset,
                length,
                digest,
            });
            offset += length;
        }
        chunks
    }
}

/// FNV-1a over raw bytes.
fn fnv1a(init: u64, data: &[u8]) -> u64 {
    let mut h = init;
    for &b in data {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// FNV-1a over the little-endian bytes of each word.
fn fnv1a_words(init: u64, words: &[u64]) -> u64 {
    let mut h = init;
    for w in words {
        for b in w.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(FNV_PRIME);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    fn data(len: usize, seed: u64) -> Vec<u8> {
        let mut rng = Rng::new(seed);
        (0..len).map(|_| (rng.next_u64() & 0xff) as u8).collect()
    }

    #[test]
    fn chunks_partition_the_input() {
        let chunker = Chunker::new(4_096, 7);
        let buf = data(100_000, 1);
        let chunks = chunker.chunk(&buf);
        assert!(chunks.len() > 10, "expected many chunks: {}", chunks.len());
        let mut cursor = 0u64;
        for c in &chunks {
            assert_eq!(c.offset, cursor);
            assert!(c.length > 0);
            cursor += c.length;
        }
        assert_eq!(cursor, buf.len() as u64);
    }

    #[test]
    fn length_bounds_hold() {
        let chunker = Chunker::new(4_096, 7);
        let buf = data(300_000, 2);
        let chunks = chunker.chunk(&buf);
        for c in &chunks[..chunks.len() - 1] {
            assert!(c.length >= chunker.min_bytes());
            assert!(c.length <= chunker.max_bytes());
        }
        // mean lands within a factor of 4 of the target
        let mean = buf.len() as f64 / chunks.len() as f64;
        assert!(
            mean > chunker.target_bytes() as f64 / 4.0
                && mean < chunker.target_bytes() as f64 * 4.0,
            "mean chunk {mean} vs target {}",
            chunker.target_bytes()
        );
    }

    #[test]
    fn empty_and_tiny_inputs() {
        let chunker = Chunker::new(4_096, 7);
        assert!(chunker.chunk(&[]).is_empty());
        let one = chunker.chunk(&[42]);
        assert_eq!(one.len(), 1);
        assert_eq!((one[0].offset, one[0].length), (0, 1));
    }

    #[test]
    fn synthetic_chunks_cover_and_repeat() {
        let chunker = Chunker::new(1 << 20, 3);
        let a = chunker.synthetic_chunks(0xABCD, 10_000_000);
        let b = chunker.synthetic_chunks(0xABCD, 10_000_000);
        assert_eq!(a, b, "same content identity, same chunks");
        let total: u64 = a.iter().map(|c| c.length).sum();
        assert_eq!(total, 10_000_000);
        let other = chunker.synthetic_chunks(0xABCE, 10_000_000);
        assert_ne!(
            a.iter().map(|c| c.digest).collect::<Vec<_>>(),
            other.iter().map(|c| c.digest).collect::<Vec<_>>(),
            "different content must not share chunk digests"
        );
        assert!(chunker.synthetic_chunks(0xABCD, 0).is_empty());
    }
}
