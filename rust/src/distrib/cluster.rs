//! Sharded gateway cluster (DESIGN.md S18): N gateway workers, each the
//! existing single-worker `PullQueue` + `ImageGateway` pair, with image
//! references spread across shards by rendezvous (highest-random-weight)
//! hashing. Concurrent pulls of the same reference from many nodes
//! coalesce into one job on the owning shard — the queue's dedup — while
//! distinct images process in parallel across shards. Completed images
//! register their layers in the cluster-wide content-addressed store.

use std::collections::BTreeSet;

use crate::gateway::{
    GatewayError, GatewayImage, ImageGateway, PullJob, PullQueue, PullState,
};
use crate::image::ImageRef;
use crate::metrics::Stats;
use crate::pfs::LustreFs;
use crate::registry::Registry;
use crate::sim::SimTime;
use crate::util::prng::Rng;

use super::cas::ContentStore;
use super::chunk::Chunker;

/// One gateway worker: a synchronous gateway plus its job queue.
pub struct GatewayShard {
    /// Shard index in `0..shard_count`.
    pub id: usize,
    /// The shard's synchronous gateway (where its images materialize).
    pub gateway: ImageGateway,
    /// The shard's FIFO pull queue (one worker).
    pub queue: PullQueue,
}

/// Point-in-time view of one shard, for `shifterimg cluster-status`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardStatus {
    /// Shard index.
    pub shard: usize,
    /// Jobs not yet terminal.
    pub backlog: usize,
    /// Jobs that reached READY.
    pub ready: usize,
    /// Jobs that reached FAILED.
    pub failed: usize,
    /// Images materialized on this shard's gateway.
    pub images: usize,
    /// Longest enqueue-to-pickup wait any job on this shard has seen.
    pub max_queue_wait_secs: f64,
    /// Reference the worker is advancing right now.
    pub active: Option<String>,
}

/// Cross-job coalescing accounting: every pull request the cluster has
/// absorbed (across all jobs and launches that ever hit it) vs the unique
/// pull jobs actually performed. The multi-tenant report surfaces this to
/// show that N concurrent jobs sharing an image still cost one pull.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CoalescingStats {
    /// Pull requests received across all shards, absorbed ones included.
    pub requests: u64,
    /// Unique pull jobs that exist across all shards (one per distinct
    /// image reference ever requested).
    pub jobs: usize,
}

impl CoalescingStats {
    /// Requests per job: 1.0 means no sharing at all; N means N
    /// requesters coalesced onto each pull job on average.
    pub fn ratio(&self) -> f64 {
        if self.jobs == 0 {
            1.0
        } else {
            self.requests as f64 / self.jobs as f64
        }
    }
}

/// The cluster.
pub struct GatewayCluster {
    shards: Vec<GatewayShard>,
    cas: ContentStore,
    /// References whose layers are already in the CAS.
    registered: BTreeSet<ImageRef>,
}

impl GatewayCluster {
    /// `n_shards` workers, each storing to (a striped slice of) the same
    /// parallel filesystem.
    pub fn new(n_shards: usize, pfs: &LustreFs) -> GatewayCluster {
        assert!(n_shards >= 1, "a cluster needs at least one shard");
        GatewayCluster {
            shards: (0..n_shards)
                .map(|id| GatewayShard {
                    id,
                    gateway: ImageGateway::new(pfs.clone()),
                    queue: PullQueue::new(),
                })
                .collect(),
            cas: ContentStore::new(),
            registered: BTreeSet::new(),
        }
    }

    /// Number of shards in the cluster.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Switch the cluster's content store to content-defined chunk
    /// granularity (DESIGN.md S25). Call before the first pull: images
    /// already registered as whole-layer blobs are not re-chunked.
    pub fn set_chunker(&mut self, chunker: Chunker) {
        self.cas = std::mem::take(&mut self.cas).with_chunker(chunker);
    }

    /// Iterate over the shards in id order.
    pub fn shards(&self) -> impl Iterator<Item = &GatewayShard> {
        self.shards.iter()
    }

    /// Rendezvous hashing: the owning shard for a reference is the one
    /// with the highest keyed weight. Deterministic, uniform, and adding a
    /// shard only remaps ~1/N of the references.
    pub fn shard_for(&self, reference: &ImageRef) -> usize {
        let canonical = reference.canonical();
        let mut best = 0;
        let mut best_weight = 0u64;
        for id in 0..self.shards.len() {
            let weight =
                Rng::from_tags(&["shard", &id.to_string(), &canonical])
                    .next_u64();
            if id == 0 || weight > best_weight {
                best = id;
                best_weight = weight;
            }
        }
        best
    }

    /// Enqueue a pull on the owning shard. Requests for the same reference
    /// from any number of users coalesce into one job. Returns the shard
    /// id and the job state as observed by this requester.
    pub fn request(
        &mut self,
        registry: &Registry,
        reference: &str,
        user: &str,
    ) -> Result<(usize, PullState), GatewayError> {
        let r = ImageRef::parse(reference)
            .ok_or_else(|| GatewayError::NotPulled(reference.to_string()))?;
        let id = self.shard_for(&r);
        // With a chunked CAS, price the pull by how much of the image is
        // already stored: only missing chunks pay download/PFS transfer.
        // Whole-layer mode keeps the classic full-cost pull.
        let shared = if self.cas.chunked() {
            registry
                .lookup(reference)
                .map(|img| self.cas.preview_shared_fraction(img))
                .unwrap_or(0.0)
        } else {
            0.0
        };
        let shard = &mut self.shards[id];
        let state = shard.queue.request_with_dedup(
            &shard.gateway,
            registry,
            reference,
            user,
            shared,
        )?;
        Ok((id, state))
    }

    /// Advance every shard's worker by `dt` simulated seconds (the workers
    /// run in parallel — same wall clock for all), then register newly
    /// completed images in the content store.
    pub fn tick(&mut self, registry: &Registry, dt: f64) {
        for shard in &mut self.shards {
            shard.queue.tick(&mut shard.gateway, registry, dt);
        }
        let mut newly_ready: Vec<ImageRef> = Vec::new();
        for shard in &self.shards {
            for job in shard.queue.in_state(PullState::Ready) {
                if !self.registered.contains(&job.reference) {
                    newly_ready.push(job.reference.clone());
                }
            }
        }
        for r in newly_ready {
            if let Ok(image) = registry.lookup(&r.canonical()) {
                self.cas.add_image(image);
            }
            self.registered.insert(r);
        }
    }

    /// True when no shard has in-flight work.
    pub fn drained(&self) -> bool {
        self.shards.iter().all(|s| s.queue.drained())
    }

    /// Exact simulated seconds until every shard's backlog is terminal
    /// — the shards tick in lockstep (parallel workers), so the cluster
    /// drains in the time of its most-loaded shard.
    pub fn pending_secs(&self) -> f64 {
        self.shards
            .iter()
            .map(|s| s.queue.pending_secs())
            .fold(0.0, f64::max)
    }

    /// Simulated time when the last completed job finished — the storm
    /// makespan once `drained()`.
    pub fn makespan_secs(&self) -> f64 {
        self.shards
            .iter()
            .flat_map(|s| s.queue.jobs())
            .filter_map(|j| j.completed_at)
            .map(SimTime::as_secs_f64)
            .fold(0.0, f64::max)
    }

    /// Current simulated clock instant (all shard queues tick in
    /// lockstep).
    pub fn now(&self) -> SimTime {
        self.shards
            .first()
            .map_or(SimTime::ZERO, |s| s.queue.now())
    }

    /// Job status for a reference (routed to the owning shard).
    pub fn status(&self, reference: &str) -> Option<&PullJob> {
        let r = ImageRef::parse(reference)?;
        self.shards[self.shard_for(&r)].queue.status(reference)
    }

    /// Look up a processed image on its owning shard.
    pub fn lookup(
        &self,
        reference: &str,
    ) -> Result<&GatewayImage, GatewayError> {
        let r = ImageRef::parse(reference)
            .ok_or_else(|| GatewayError::NotPulled(reference.to_string()))?;
        self.shards[self.shard_for(&r)].gateway.lookup(reference)
    }

    /// The cluster-wide content-addressed layer store.
    pub fn cas(&self) -> &ContentStore {
        &self.cas
    }

    /// Coalescing accounting summed over every shard queue.
    pub fn coalescing(&self) -> CoalescingStats {
        CoalescingStats {
            requests: self
                .shards
                .iter()
                .map(|s| s.queue.request_count())
                .sum(),
            jobs: self.shards.iter().map(|s| s.queue.jobs().count()).sum(),
        }
    }

    /// Queue-wait (enqueue → worker pickup) distribution across every job
    /// any shard has started. None until at least one job started.
    pub fn queue_wait_stats(&self) -> Option<Stats> {
        let samples: Vec<f64> = self
            .shards
            .iter()
            .flat_map(|s| s.queue.jobs())
            .filter_map(|j| j.queue_wait_secs())
            .collect();
        if samples.is_empty() {
            None
        } else {
            Some(Stats::from_samples(&samples))
        }
    }

    /// Point-in-time status row per shard (for `cluster-status`).
    pub fn cluster_status(&self) -> Vec<ShardStatus> {
        self.shards
            .iter()
            .map(|s| ShardStatus {
                shard: s.id,
                backlog: s.queue.backlog(),
                ready: s.queue.in_state(PullState::Ready).len(),
                failed: s.queue.in_state(PullState::Failed).len(),
                images: s.gateway.list().len(),
                max_queue_wait_secs: s
                    .queue
                    .jobs()
                    .filter_map(|j| j.queue_wait_secs())
                    .fold(0.0, f64::max),
                active: s
                    .queue
                    .active()
                    .map(|j| j.reference.canonical()),
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::image::builder::{self, ImageBuilder};

    fn derived_catalog(n: usize) -> (Registry, Vec<String>) {
        let base = builder::ubuntu_xenial();
        let mut registry = Registry::dockerhub();
        let refs: Vec<String> = (0..n)
            .map(|i| {
                let name = format!("svc-{i:02}:1.0");
                registry.push(
                    ImageBuilder::from_image(&base, &name)
                        .file("/opt/svc/app.bin", 80_000_000)
                        .build(),
                );
                name
            })
            .collect();
        (registry, refs)
    }

    #[test]
    fn routing_is_deterministic_and_spreads() {
        let cluster = GatewayCluster::new(16, &LustreFs::piz_daint());
        let (_, refs) = derived_catalog(32);
        let mut used = BTreeSet::new();
        for name in &refs {
            let r = ImageRef::parse(name).unwrap();
            let a = cluster.shard_for(&r);
            assert_eq!(a, cluster.shard_for(&r)); // stable
            used.insert(a);
        }
        assert!(
            used.len() >= 8,
            "32 refs over 16 shards must spread: {used:?}"
        );
    }

    #[test]
    fn coalescing_many_users_one_job() {
        let mut cluster = GatewayCluster::new(4, &LustreFs::piz_daint());
        let registry = Registry::dockerhub();
        let mut shard_ids = BTreeSet::new();
        for user in 0..50 {
            let (id, _) = cluster
                .request(&registry, "ubuntu:xenial", &format!("node-{user}"))
                .unwrap();
            shard_ids.insert(id);
        }
        assert_eq!(shard_ids.len(), 1, "same ref always routes to one shard");
        let job = cluster.status("ubuntu:xenial").unwrap();
        assert_eq!(job.requesters.len(), 50);
        cluster.tick(&registry, 1e6);
        assert!(cluster.drained());
        assert!(cluster.lookup("ubuntu:xenial").is_ok());
        // exactly one shard materialized it
        let total: usize =
            cluster.shards().map(|s| s.gateway.list().len()).sum();
        assert_eq!(total, 1);
    }

    #[test]
    fn sharding_shrinks_the_storm_makespan() {
        let (registry, refs) = derived_catalog(32);
        let mut makespans = Vec::new();
        for n_shards in [1usize, 16] {
            let mut cluster =
                GatewayCluster::new(n_shards, &LustreFs::piz_daint());
            for name in &refs {
                cluster.request(&registry, name, "storm").unwrap();
            }
            cluster.tick(&registry, 1e9);
            assert!(cluster.drained());
            makespans.push(cluster.makespan_secs());
        }
        let (serial, sharded) = (makespans[0], makespans[1]);
        assert!(
            serial > 4.0 * sharded,
            "16 shards must beat 1 by >= 4x: serial={serial}s sharded={sharded}s"
        );
    }

    #[test]
    fn completed_images_register_layers_in_cas() {
        let (registry, refs) = derived_catalog(8);
        let mut cluster = GatewayCluster::new(4, &LustreFs::piz_daint());
        for name in &refs {
            cluster.request(&registry, name, "u").unwrap();
        }
        cluster.tick(&registry, 1e9);
        let cas = cluster.cas();
        let per_image_sum: u64 = refs
            .iter()
            .map(|n| registry.lookup(n).unwrap().transfer_bytes())
            .sum();
        assert_eq!(cas.logical_bytes(), per_image_sum);
        assert!(
            cas.stored_bytes() < per_image_sum,
            "shared base layers must dedup"
        );
        assert!(cas.dedup_ratio() > 1.5, "ratio={}", cas.dedup_ratio());
        // re-ticking must not double-register
        cluster.tick(&registry, 1.0);
        assert_eq!(cas_logical(&cluster), per_image_sum);
    }

    fn cas_logical(c: &GatewayCluster) -> u64 {
        c.cas().logical_bytes()
    }

    #[test]
    fn queue_wait_surfaces_in_stats_and_status() {
        let (registry, refs) = derived_catalog(8);
        let mut cluster = GatewayCluster::new(1, &LustreFs::piz_daint());
        for name in &refs {
            cluster.request(&registry, name, "u").unwrap();
        }
        assert!(cluster.queue_wait_stats().is_none(), "nothing started yet");
        cluster.tick(&registry, 1e9);
        let stats = cluster.queue_wait_stats().unwrap();
        assert_eq!(stats.n, 8);
        // one worker, identical jobs: the last job waits ~7 jobs' worth,
        // the first none — the spread must be visible in the percentiles
        assert!(stats.best.abs() < 1e-9);
        assert!(stats.worst > 0.0);
        assert!(stats.p99 >= stats.p50);
        let status = cluster.cluster_status();
        let max_wait = status
            .iter()
            .map(|s| s.max_queue_wait_secs)
            .fold(0.0, f64::max);
        assert!((max_wait - stats.worst).abs() < 1e-9);
    }

    #[test]
    fn coalescing_accounting_spans_jobs() {
        let mut cluster = GatewayCluster::new(4, &LustreFs::piz_daint());
        let registry = Registry::dockerhub();
        for user in 0..10 {
            cluster
                .request(&registry, "ubuntu:xenial", &format!("n{user}"))
                .unwrap();
        }
        cluster.tick(&registry, 1e9);
        // a later job pulls the same reference again, plus a new one —
        // the counter keeps accumulating across jobs and drains
        for user in 0..5 {
            cluster
                .request(&registry, "ubuntu:xenial", &format!("m{user}"))
                .unwrap();
            cluster
                .request(&registry, "pynamic:1.3", &format!("m{user}"))
                .unwrap();
        }
        cluster.tick(&registry, 1e9);
        let c = cluster.coalescing();
        assert_eq!(c.requests, 20);
        assert_eq!(c.jobs, 2, "one pull job per unique reference");
        assert!((c.ratio() - 10.0).abs() < 1e-12);
    }

    #[test]
    fn failed_pull_reports_on_owning_shard() {
        let mut cluster = GatewayCluster::new(4, &LustreFs::piz_daint());
        let registry = Registry::dockerhub();
        let (_, state) =
            cluster.request(&registry, "nope:missing", "u").unwrap();
        assert_eq!(state, PullState::Failed);
        let status = cluster.cluster_status();
        assert_eq!(status.iter().map(|s| s.failed).sum::<usize>(), 1);
        assert!(cluster.lookup("nope:missing").is_err());
    }

    #[test]
    fn cluster_status_reflects_backlog_and_active() {
        let (registry, refs) = derived_catalog(6);
        let mut cluster = GatewayCluster::new(2, &LustreFs::piz_daint());
        for name in &refs {
            cluster.request(&registry, name, "u").unwrap();
        }
        let before: usize =
            cluster.cluster_status().iter().map(|s| s.backlog).sum();
        assert_eq!(before, 6);
        cluster.tick(&registry, 0.5); // mid-flight: someone is active
        assert!(cluster
            .cluster_status()
            .iter()
            .any(|s| s.active.is_some()));
        cluster.tick(&registry, 1e9);
        let after = cluster.cluster_status();
        assert_eq!(after.iter().map(|s| s.backlog).sum::<usize>(), 0);
        assert_eq!(after.iter().map(|s| s.ready).sum::<usize>(), 6);
    }
}
