//! Per-compute-node squashfs cache (DESIGN.md S18): once a node has
//! fetched an image's squashfs from the PFS, subsequent container starts
//! on that node resolve against the local copy — a dcache stat instead of
//! a parallel-filesystem broadcast. Bounded capacity with LRU eviction;
//! the cold-fill cost reuses the `pfs::LustreFs` contention model.

use std::collections::BTreeMap;

use crate::pfs::{LustreFs, NodeLocalFs};
use crate::sim::SimTime;

/// Outcome of asking the cache for a squashfs blob.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CacheOutcome {
    /// Blob already local.
    Hit,
    /// Blob fetched from the PFS and (capacity permitting) admitted,
    /// evicting `evicted` older blobs.
    Miss {
        /// Older blobs evicted to admit this one.
        evicted: usize,
    },
}

#[derive(Debug, Clone, Copy)]
struct CacheEntry {
    bytes: u64,
    last_used: u64,
    /// Virtual-time instant of the cold fill that admitted this blob.
    filled_at: SimTime,
}

/// One node's cache.
#[derive(Debug)]
pub struct NodeCache {
    capacity_bytes: u64,
    used_bytes: u64,
    /// LRU clock: bumped on every access.
    clock: u64,
    entries: BTreeMap<u64, CacheEntry>,
    local: NodeLocalFs,
    /// Fetches satisfied locally.
    pub hits: u64,
    /// Fetches that had to fill from the PFS.
    pub misses: u64,
    /// Entries evicted to make room.
    pub evictions: u64,
    /// Bytes whose transfer was deferred past container start by lazy
    /// pulling (DESIGN.md S25) — streamed during execution instead of
    /// blocking the prepare stage.
    pub lazy_deferred_bytes: u64,
    /// Virtual-time instant of the most recent eviction, if any — the
    /// unified kernel clock, not a private counter (DESIGN.md S24).
    last_eviction_at: Option<SimTime>,
}

impl NodeCache {
    /// Empty cache with `capacity_bytes` of node-local storage.
    pub fn new(capacity_bytes: u64) -> NodeCache {
        NodeCache {
            capacity_bytes,
            used_bytes: 0,
            clock: 0,
            entries: BTreeMap::new(),
            local: NodeLocalFs::squashfs_loop_mount(),
            hits: 0,
            misses: 0,
            evictions: 0,
            lazy_deferred_bytes: 0,
            last_eviction_at: None,
        }
    }

    /// Record that `bytes` of this node's cold fill were deferred past
    /// container start by lazy pulling.
    pub fn note_lazy_deferral(&mut self, bytes: u64) {
        self.lazy_deferred_bytes += bytes;
    }

    /// Whether the squashfs blob `digest` is resident.
    pub fn contains(&self, digest: u64) -> bool {
        self.entries.contains_key(&digest)
    }

    /// Bytes currently resident.
    pub fn used_bytes(&self) -> u64 {
        self.used_bytes
    }

    /// Configured capacity.
    pub fn capacity_bytes(&self) -> u64 {
        self.capacity_bytes
    }

    /// Resident blob count.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing is resident.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Look up `digest`, admitting it on miss. A blob larger than the whole
    /// cache is streamed, never admitted (it would evict everything for a
    /// single use). Fill/eviction instants stamp as virtual time zero —
    /// callers on the unified kernel clock use [`NodeCache::fetch_at`].
    pub fn fetch(&mut self, digest: u64, bytes: u64) -> CacheOutcome {
        self.fetch_at(digest, bytes, SimTime::ZERO)
    }

    /// [`NodeCache::fetch`] with the fabric's virtual-time instant, so
    /// cold fills and evictions are stamped on the one kernel clock
    /// every other layer schedules on. LRU *ordering* still uses the
    /// access counter (strictly monotone — simultaneous virtual-time
    /// accesses would tie).
    pub fn fetch_at(
        &mut self,
        digest: u64,
        bytes: u64,
        now: SimTime,
    ) -> CacheOutcome {
        self.clock += 1;
        if let Some(entry) = self.entries.get_mut(&digest) {
            entry.last_used = self.clock;
            self.hits += 1;
            return CacheOutcome::Hit;
        }
        self.misses += 1;
        if bytes > self.capacity_bytes {
            return CacheOutcome::Miss { evicted: 0 };
        }
        let mut evicted = 0;
        while self.used_bytes + bytes > self.capacity_bytes {
            // used_bytes > 0 implies entries exist; if the accounting ever
            // drifted, stopping eviction is safer than panicking.
            let Some(lru) = self
                .entries
                .iter()
                .min_by_key(|(_, e)| e.last_used)
                .map(|(d, e)| (*d, e.bytes))
            else {
                break;
            };
            self.entries.remove(&lru.0);
            self.used_bytes -= lru.1;
            evicted += 1;
        }
        self.entries.insert(
            digest,
            CacheEntry {
                bytes,
                last_used: self.clock,
                filled_at: now,
            },
        );
        self.used_bytes += bytes;
        self.evictions += evicted as u64;
        if evicted > 0 {
            self.last_eviction_at = Some(now);
        }
        CacheOutcome::Miss { evicted }
    }

    /// Virtual-time instant the resident blob `digest` was cold-filled
    /// at, if resident.
    pub fn filled_at(&self, digest: u64) -> Option<SimTime> {
        self.entries.get(&digest).map(|e| e.filled_at)
    }

    /// Virtual-time instant of the most recent eviction, if any ever
    /// happened.
    pub fn last_eviction_at(&self) -> Option<SimTime> {
        self.last_eviction_at
    }

    /// Cost of a warm start: the squashfs is already local, so resolution
    /// is a kernel dcache stat — no PFS traffic at all.
    pub fn warm_hit_secs(&self) -> f64 {
        self.local.stat_latency_us * 1e-6
    }

    /// Cost of a cold fill under a broadcast storm: `concurrent_nodes`
    /// nodes open the image on the PFS (MDS storm) and stream it over the
    /// shared OST array.
    pub fn cold_fill_secs(
        pfs: &LustreFs,
        bytes: u64,
        concurrent_nodes: u64,
    ) -> f64 {
        let nodes = concurrent_nodes.max(1);
        pfs.mds.storm_secs(nodes, 1) + pfs.bulk_read_secs(bytes, nodes)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const MB: u64 = 1_000_000;

    #[test]
    fn hit_after_miss() {
        let mut c = NodeCache::new(100 * MB);
        assert_eq!(c.fetch(1, 10 * MB), CacheOutcome::Miss { evicted: 0 });
        assert_eq!(c.fetch(1, 10 * MB), CacheOutcome::Hit);
        assert_eq!((c.hits, c.misses), (1, 1));
        assert_eq!(c.used_bytes(), 10 * MB);
        assert!(c.contains(1));
    }

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c = NodeCache::new(30 * MB);
        c.fetch(1, 10 * MB);
        c.fetch(2, 10 * MB);
        c.fetch(3, 10 * MB);
        c.fetch(1, 10 * MB); // touch 1 -> 2 is now the LRU
        assert_eq!(
            c.fetch_at(4, 10 * MB, SimTime::from_secs(7.5)),
            CacheOutcome::Miss { evicted: 1 }
        );
        assert!(!c.contains(2), "LRU entry should be evicted");
        assert!(c.contains(1) && c.contains(3) && c.contains(4));
        assert_eq!(c.evictions, 1);
        assert_eq!(c.used_bytes(), 30 * MB);
        // fills and evictions are stamped on the kernel clock
        assert_eq!(c.filled_at(4), Some(SimTime::from_secs(7.5)));
        assert_eq!(c.filled_at(1), Some(SimTime::ZERO));
        assert_eq!(c.last_eviction_at(), Some(SimTime::from_secs(7.5)));
        assert_eq!(c.filled_at(2), None);
    }

    #[test]
    fn oversized_blob_streams_without_admission() {
        let mut c = NodeCache::new(10 * MB);
        c.fetch(1, 5 * MB);
        assert_eq!(c.fetch(9, 50 * MB), CacheOutcome::Miss { evicted: 0 });
        assert!(!c.contains(9));
        assert!(c.contains(1)); // resident entries untouched
        assert_eq!(c.fetch(9, 50 * MB), CacheOutcome::Miss { evicted: 0 });
    }

    #[test]
    fn multi_entry_eviction_frees_enough_space() {
        let mut c = NodeCache::new(30 * MB);
        c.fetch(1, 10 * MB);
        c.fetch(2, 10 * MB);
        c.fetch(3, 10 * MB);
        assert_eq!(c.fetch(4, 25 * MB), CacheOutcome::Miss { evicted: 3 });
        assert_eq!(c.len(), 1);
        assert_eq!(c.used_bytes(), 25 * MB);
    }

    #[test]
    fn cold_fill_dwarfs_warm_hit() {
        let pfs = LustreFs::piz_daint();
        let c = NodeCache::new(1000 * MB);
        let cold = NodeCache::cold_fill_secs(&pfs, 400 * MB, 10_000);
        let warm = c.warm_hit_secs();
        assert!(
            cold > 1000.0 * warm,
            "cold={cold}s warm={warm}s — broadcast must dominate"
        );
        // and the broadcast cost grows with the storm width
        let narrow = NodeCache::cold_fill_secs(&pfs, 400 * MB, 16);
        assert!(cold > 50.0 * narrow);
    }
}
