//! End-to-end telemetry (DESIGN.md S23): structured spans, monotonic
//! counters, and bounded histograms across pull → stage → launch →
//! tenancy.
//!
//! Every per-subsystem report struct (`LaunchReport`, `TenancyReport`,
//! `StageLog`) hand-rolls its own timing, which answers "how long did
//! stage X take on average" but not "where did *this* job's 4.2 s go" —
//! the cross-layer attribution question the paper's performance-
//! portability claim ultimately rests on. This module is the shared
//! instrumentation substrate: one [`Telemetry`] recorder, created by
//! [`crate::SiteBuilder::telemetry`] and threaded (behind an `Arc`)
//! through the [`crate::distrib::DistributionFabric`], every
//! [`crate::ShifterRuntime`], the [`crate::launch::LaunchScheduler`] and
//! the [`crate::tenancy::FairShareScheduler`], so one recording covers a
//! whole storm.
//!
//! Three primitives:
//!
//! * **Spans** — hierarchical intervals in *simulated* seconds
//!   ([`SpanRecord`]: id, optional parent, name, category, track, attrs,
//!   start + duration). Layers that know an operation's wall placement
//!   emit them post-hoc; layers that only know relative costs receive
//!   their placement through a [`TraceCtx`] (tenancy → launch) or the
//!   trace fields on `RunOptions` (launch → runtime).
//! * **Counters** — monotonic `u64` event counts (`fabric.requests`,
//!   `launch.retries`, `tenancy.backfills`, …).
//! * **Histograms** — bounded sample reservoirs with percentile
//!   snapshots (queue depths, fetch times, waits), sharing the
//!   nearest-rank [`crate::metrics::percentile_sorted`] path the report
//!   structs use.
//!
//! Export surfaces: [`Telemetry::chrome_trace_jsonl`] writes Chrome
//! trace-event JSONL loadable in Perfetto / `chrome://tracing` (the
//! `--trace <path>` flag on both CLIs and the `shifterimg trace`
//! subcommand), and [`Telemetry::snapshot_json`] serializes the
//! counter/histogram state into the `BENCH_*` artifacts.
//!
//! The recorder is `Sync` (spans/counters behind a `Mutex`, ids from an
//! `AtomicU64`) because the launch orchestrator's worker threads record
//! concurrently. A disabled recorder (the default) rejects every record
//! with a single branch and no allocation, so instrumented hot paths pay
//! ~nothing when tracing is off.
//!
//! Span starts are [`SimTime`] instants from the virtual-time kernel
//! (DESIGN.md S24), so a recording is bit-identical across runs and
//! host thread counts; durations stay `f64` seconds.
//!
//! ```
//! use shifter_rs::sim::SimTime;
//! use shifter_rs::telemetry::{SpanDraft, Telemetry};
//!
//! let tel = Telemetry::new(true);
//! let job = tel.span(SpanDraft {
//!     parent: None,
//!     category: "job",
//!     name: "job:ubuntu:xenial",
//!     track: "jobs",
//!     start: SimTime::ZERO,
//!     dur_secs: 4.2,
//! });
//! tel.span(SpanDraft {
//!     parent: job,
//!     category: "pull",
//!     name: "pull:ubuntu:xenial",
//!     track: "gateway",
//!     start: SimTime::ZERO,
//!     dur_secs: 3.1,
//! });
//! tel.count("fabric.requests", 1);
//! assert_eq!(tel.spans().len(), 2);
//! assert!(tel.chrome_trace_jsonl().lines().count() >= 3);
//! ```

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use crate::metrics::percentile_sorted;
use crate::sim::SimTime;
use crate::util::json::Json;
use crate::util::sync::lock_unpoisoned;

/// Cap on retained histogram samples: the first this many observations
/// are kept for percentile snapshots (count/sum/min/max stay exact
/// beyond it). Deterministic — no reservoir randomness.
pub const HISTOGRAM_SAMPLE_CAP: usize = 2048;

/// One recorded span: a named interval of simulated time, optionally
/// parented into a tree.
#[derive(Debug, Clone, PartialEq)]
pub struct SpanRecord {
    /// Recorder-unique id (dense, allocation order).
    pub id: u64,
    /// Parent span id, `None` for a root.
    pub parent: Option<u64>,
    /// Taxonomy bucket (`"job"`, `"pull"`, `"node"`, `"run"`,
    /// `"stage"`, `"ext"`, `"wait"`, `"app"`, `"sched"`, `"fault"`).
    pub category: &'static str,
    /// Human-readable span name (`"job:ubuntu:xenial"`,
    /// `"ext:gpu:inject"`, …).
    pub name: String,
    /// Display lane the Chrome export maps to a thread
    /// (`"node-00042"`, `"tenant:tenant-03"`, `"gateway"`, …).
    pub track: String,
    /// Simulated start instant, from the virtual-time kernel.
    pub start: SimTime,
    /// Simulated duration, in seconds (0 for instant events).
    pub dur_secs: f64,
    /// Key/value annotations, in insertion order.
    pub attrs: Vec<(String, String)>,
}

impl SpanRecord {
    /// Simulated start time, in seconds (JSON/report compatibility
    /// accessor over [`SpanRecord::start`]).
    pub fn start_secs(&self) -> f64 {
        self.start.as_secs_f64()
    }

    /// Simulated end time (`start + dur`).
    pub fn end_secs(&self) -> f64 {
        self.start.as_secs_f64() + self.dur_secs
    }
}

/// The borrowed form a caller hands to [`Telemetry::span`] /
/// [`Telemetry::span_as`]. Building one allocates nothing, so a
/// disabled recorder can reject it for the cost of a branch.
#[derive(Debug, Clone, Copy)]
pub struct SpanDraft<'a> {
    /// Parent span id, `None` for a root.
    pub parent: Option<u64>,
    /// Taxonomy bucket (see [`SpanRecord::category`]).
    pub category: &'static str,
    /// Span name.
    pub name: &'a str,
    /// Display lane (see [`SpanRecord::track`]).
    pub track: &'a str,
    /// Simulated start instant.
    pub start: SimTime,
    /// Simulated duration, in seconds.
    pub dur_secs: f64,
}

/// The trace placement one layer hands the next when the callee only
/// knows *relative* costs: the parent span to attach to, and the
/// absolute simulated time the callee's work begins. The tenancy
/// scheduler passes one to
/// [`crate::launch::LaunchScheduler::launch_on_traced`]; the launch
/// scheduler forwards the same idea to the runtime through the
/// `trace_parent` / `trace_start` fields on
/// [`crate::RunOptions`].
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct TraceCtx {
    /// Span the callee's spans should parent under.
    pub parent: Option<u64>,
    /// Absolute simulated instant the callee's interval starts at.
    pub start: SimTime,
}

impl TraceCtx {
    /// The start instant in seconds (compatibility accessor over
    /// [`TraceCtx::start`]).
    pub fn start_secs(&self) -> f64 {
        self.start.as_secs_f64()
    }
}

/// A bounded histogram: exact count/sum/min/max plus the first
/// [`HISTOGRAM_SAMPLE_CAP`] samples for percentile snapshots.
#[derive(Debug, Clone, Default)]
struct Histogram {
    count: u64,
    sum: f64,
    min: f64,
    max: f64,
    samples: Vec<f64>,
}

impl Histogram {
    fn observe(&mut self, sample: f64) {
        if self.count == 0 {
            self.min = sample;
            self.max = sample;
        } else {
            self.min = self.min.min(sample);
            self.max = self.max.max(sample);
        }
        self.count += 1;
        self.sum += sample;
        if self.samples.len() < HISTOGRAM_SAMPLE_CAP {
            self.samples.push(sample);
        }
    }

    fn snapshot(&self) -> HistogramSnapshot {
        let mut sorted = self.samples.clone();
        sorted.sort_by(f64::total_cmp);
        let pct = |q: f64| -> f64 {
            if sorted.is_empty() {
                0.0
            } else {
                percentile_sorted(&sorted, q)
            }
        };
        HistogramSnapshot {
            count: self.count,
            sum: self.sum,
            min: self.min,
            max: self.max,
            mean: if self.count > 0 {
                self.sum / self.count as f64
            } else {
                0.0
            },
            p50: pct(0.50),
            p95: pct(0.95),
            p99: pct(0.99),
            retained: self.samples.len(),
        }
    }
}

/// Point-in-time view of one histogram (see [`Telemetry::histogram`]).
/// Percentiles are nearest-rank over the retained sample prefix;
/// count/sum/min/max/mean are exact over every observation.
#[derive(Debug, Clone, PartialEq)]
pub struct HistogramSnapshot {
    /// Observations ever recorded.
    pub count: u64,
    /// Sum of every observation.
    pub sum: f64,
    /// Smallest observation.
    pub min: f64,
    /// Largest observation.
    pub max: f64,
    /// Mean over every observation.
    pub mean: f64,
    /// Median over the retained samples.
    pub p50: f64,
    /// 95th percentile over the retained samples.
    pub p95: f64,
    /// 99th percentile over the retained samples.
    pub p99: f64,
    /// Samples retained for the percentile estimates (capped at
    /// [`HISTOGRAM_SAMPLE_CAP`]).
    pub retained: usize,
}

impl HistogramSnapshot {
    /// JSON object for the `BENCH_*` artifacts.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("count", Json::Num(self.count as f64)),
            ("sum", Json::Num(self.sum)),
            ("min", Json::Num(self.min)),
            ("max", Json::Num(self.max)),
            ("mean", Json::Num(self.mean)),
            ("p50", Json::Num(self.p50)),
            ("p95", Json::Num(self.p95)),
            ("p99", Json::Num(self.p99)),
        ])
    }
}

#[derive(Default)]
struct Inner {
    spans: Vec<SpanRecord>,
    counters: BTreeMap<String, u64>,
    histograms: BTreeMap<String, Histogram>,
}

/// The recorder: one per [`crate::Site`], shared by every layer behind
/// an `Arc`. See the [module docs](self) for the data model and an
/// example.
pub struct Telemetry {
    enabled: bool,
    next_id: AtomicU64,
    inner: Mutex<Inner>,
}

impl Default for Telemetry {
    /// A disabled recorder (every record call is a no-op).
    fn default() -> Telemetry {
        Telemetry::new(false)
    }
}

impl Telemetry {
    /// A recorder; when `enabled` is false every record call no-ops at
    /// the cost of one branch.
    pub fn new(enabled: bool) -> Telemetry {
        Telemetry {
            enabled,
            next_id: AtomicU64::new(1),
            inner: Mutex::new(Inner::default()),
        }
    }

    /// A permanently disabled recorder.
    pub fn disabled() -> Telemetry {
        Telemetry::new(false)
    }

    /// Whether record calls do anything.
    pub fn enabled(&self) -> bool {
        self.enabled
    }

    /// Allocate a span id *without* recording anything yet — for layers
    /// that must hand the id to children before the parent's duration
    /// is known (record it later with [`Telemetry::span_as`]). `None`
    /// when disabled.
    pub fn reserve_id(&self) -> Option<u64> {
        self.enabled
            .then(|| self.next_id.fetch_add(1, Ordering::Relaxed))
    }

    /// Record a span with a fresh id; returns the id, or `None` when
    /// disabled.
    pub fn span(&self, draft: SpanDraft<'_>) -> Option<u64> {
        let id = self.reserve_id()?;
        self.span_as(id, draft);
        Some(id)
    }

    /// Record a span under a previously [reserved](Telemetry::reserve_id)
    /// id. No-op when disabled.
    pub fn span_as(&self, id: u64, draft: SpanDraft<'_>) {
        if !self.enabled {
            return;
        }
        let record = SpanRecord {
            id,
            parent: draft.parent,
            category: draft.category,
            name: draft.name.to_string(),
            track: draft.track.to_string(),
            start: draft.start,
            dur_secs: draft.dur_secs,
            attrs: Vec::new(),
        };
        lock_unpoisoned(&self.inner)
            .spans
            .push(record);
    }

    /// Attach a key/value annotation to an already recorded span.
    /// No-op when disabled or when `id` was never recorded.
    pub fn annotate(&self, id: u64, key: &str, value: &str) {
        if !self.enabled {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        if let Some(span) = inner.spans.iter_mut().rev().find(|s| s.id == id)
        {
            span.attrs.push((key.to_string(), value.to_string()));
        }
    }

    /// Add `delta` to the monotonic counter `name` (created at 0 on
    /// first touch). No-op when disabled.
    pub fn count(&self, name: &str, delta: u64) {
        if !self.enabled {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        *inner.counters.entry(name.to_string()).or_insert(0) += delta;
    }

    /// Record one observation into the histogram `name` (created on
    /// first touch). No-op when disabled.
    pub fn observe(&self, name: &str, sample: f64) {
        if !self.enabled {
            return;
        }
        let mut inner = lock_unpoisoned(&self.inner);
        inner
            .histograms
            .entry(name.to_string())
            .or_default()
            .observe(sample);
    }

    /// Current value of counter `name` (0 if never touched).
    pub fn counter(&self, name: &str) -> u64 {
        lock_unpoisoned(&self.inner)
            .counters
            .get(name)
            .copied()
            .unwrap_or(0)
    }

    /// Every counter, in name order.
    pub fn counters(&self) -> Vec<(String, u64)> {
        lock_unpoisoned(&self.inner)
            .counters
            .iter()
            .map(|(k, v)| (k.clone(), *v))
            .collect()
    }

    /// Snapshot of histogram `name`, if it was ever observed.
    pub fn histogram(&self, name: &str) -> Option<HistogramSnapshot> {
        lock_unpoisoned(&self.inner)
            .histograms
            .get(name)
            .map(Histogram::snapshot)
    }

    /// Every recorded span, sorted by `(start, id)` — a deterministic
    /// view regardless of the order layers recorded in.
    pub fn spans(&self) -> Vec<SpanRecord> {
        let mut spans = lock_unpoisoned(&self.inner).spans.clone();
        spans.sort_by(|a, b| a.start.cmp(&b.start).then(a.id.cmp(&b.id)));
        spans
    }

    /// Number of spans recorded so far.
    pub fn span_count(&self) -> usize {
        lock_unpoisoned(&self.inner).spans.len()
    }

    /// Latest end time (`start + dur`) over the recorded spans whose
    /// `parent` is `parent` — how a caller closes a parent span around
    /// children emitted by deeper layers. `None` when no child exists.
    pub fn child_span_end(&self, parent: u64) -> Option<f64> {
        lock_unpoisoned(&self.inner)
            .spans
            .iter()
            .filter(|s| s.parent == Some(parent))
            .map(SpanRecord::end_secs)
            .max_by(f64::total_cmp)
    }

    /// Serialize the whole recording as Chrome trace-event JSONL: one
    /// JSON event per line — `ph:"M"` thread-name metadata per track,
    /// `ph:"X"` complete events per span (`ts`/`dur` in microseconds of
    /// simulated time), and `ph:"C"` counter events at the trace end.
    /// Load the file in Perfetto (<https://ui.perfetto.dev>) or
    /// `chrome://tracing`.
    pub fn chrome_trace_jsonl(&self) -> String {
        let spans = self.spans();
        let mut tracks: Vec<&str> = Vec::new();
        for s in &spans {
            if !tracks.contains(&s.track.as_str()) {
                tracks.push(&s.track);
            }
        }
        tracks.sort_unstable();
        let tid_of = |track: &str| -> f64 {
            (tracks.iter().position(|t| *t == track).unwrap_or(0) + 1)
                as f64
        };
        let mut out = String::new();
        for track in &tracks {
            let meta = Json::obj(vec![
                ("name", Json::str("thread_name")),
                ("ph", Json::str("M")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid_of(track))),
                ("args", Json::obj(vec![("name", Json::str(*track))])),
            ]);
            out.push_str(&meta.to_string());
            out.push('\n');
        }
        let mut trace_end_us = 0.0f64;
        for s in &spans {
            trace_end_us = trace_end_us.max(s.end_secs() * 1e6);
            let mut args = vec![
                ("id", Json::Num(s.id as f64)),
                (
                    "parent",
                    s.parent.map_or(Json::Null, |p| Json::Num(p as f64)),
                ),
            ];
            for (k, v) in &s.attrs {
                args.push((k.as_str(), Json::str(v.as_str())));
            }
            let event = Json::obj(vec![
                ("name", Json::str(s.name.as_str())),
                ("cat", Json::str(s.category)),
                ("ph", Json::str("X")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(tid_of(&s.track))),
                ("ts", Json::Num(s.start.as_secs_f64() * 1e6)),
                ("dur", Json::Num(s.dur_secs * 1e6)),
                ("args", Json::obj(args)),
            ]);
            out.push_str(&event.to_string());
            out.push('\n');
        }
        for (name, value) in self.counters() {
            let event = Json::obj(vec![
                ("name", Json::str(name.as_str())),
                ("ph", Json::str("C")),
                ("pid", Json::Num(1.0)),
                ("tid", Json::Num(0.0)),
                ("ts", Json::Num(trace_end_us)),
                (
                    "args",
                    Json::obj(vec![("value", Json::Num(value as f64))]),
                ),
            ]);
            out.push_str(&event.to_string());
            out.push('\n');
        }
        out
    }

    /// Counter + histogram state as one JSON object — the shape the
    /// `BENCH_*` artifacts embed under their `"telemetry"` key:
    /// `{"spans": N, "counters": {...}, "histograms": {name: {...}}}`.
    pub fn snapshot_json(&self) -> Json {
        let inner = lock_unpoisoned(&self.inner);
        let counters = Json::Obj(
            inner
                .counters
                .iter()
                .map(|(k, v)| (k.clone(), Json::Num(*v as f64)))
                .collect(),
        );
        let histograms = Json::Obj(
            inner
                .histograms
                .iter()
                .map(|(k, h)| (k.clone(), h.snapshot().to_json()))
                .collect(),
        );
        Json::obj(vec![
            ("spans", Json::Num(inner.spans.len() as f64)),
            ("counters", counters),
            ("histograms", histograms),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn draft<'a>(
        parent: Option<u64>,
        name: &'a str,
        start: f64,
        dur: f64,
    ) -> SpanDraft<'a> {
        SpanDraft {
            parent,
            category: "test",
            name,
            track: "t0",
            start: SimTime::from_secs(start),
            dur_secs: dur,
        }
    }

    #[test]
    fn disabled_recorder_records_nothing() {
        let tel = Telemetry::disabled();
        assert!(!tel.enabled());
        assert_eq!(tel.reserve_id(), None);
        assert_eq!(tel.span(draft(None, "x", 0.0, 1.0)), None);
        tel.count("c", 3);
        tel.observe("h", 1.0);
        assert_eq!(tel.span_count(), 0);
        assert_eq!(tel.counter("c"), 0);
        assert!(tel.histogram("h").is_none());
        assert_eq!(tel.chrome_trace_jsonl(), "");
    }

    #[test]
    fn span_tree_and_child_end() {
        let tel = Telemetry::new(true);
        let root = tel.reserve_id().unwrap();
        let a = tel.span(draft(Some(root), "a", 0.0, 2.0)).unwrap();
        let b = tel.span(draft(Some(root), "b", 2.0, 3.0)).unwrap();
        assert_ne!(a, b);
        assert_eq!(tel.child_span_end(root), Some(5.0));
        tel.span_as(root, draft(None, "root", 0.0, 5.0));
        tel.annotate(root, "k", "v");
        let spans = tel.spans();
        assert_eq!(spans.len(), 3);
        // sorted by (start, id): root and a start together, root has the
        // smaller id because it was reserved first
        assert_eq!(spans[0].name, "root");
        assert_eq!(spans[0].attrs, vec![("k".into(), "v".into())]);
        assert_eq!(spans[2].parent, Some(root));
        assert_eq!(tel.child_span_end(a), None);
    }

    #[test]
    fn counters_accumulate() {
        let tel = Telemetry::new(true);
        tel.count("fabric.requests", 1);
        tel.count("fabric.requests", 2);
        tel.count("other", 0);
        assert_eq!(tel.counter("fabric.requests"), 3);
        assert_eq!(tel.counter("other"), 0);
        assert_eq!(
            tel.counters(),
            vec![
                ("fabric.requests".to_string(), 3),
                ("other".to_string(), 0)
            ]
        );
    }

    #[test]
    fn histogram_snapshot_percentiles() {
        let tel = Telemetry::new(true);
        for i in 1..=100 {
            tel.observe("h", f64::from(i));
        }
        let snap = tel.histogram("h").unwrap();
        assert_eq!(snap.count, 100);
        assert_eq!(snap.min, 1.0);
        assert_eq!(snap.max, 100.0);
        assert_eq!(snap.p50, 50.0);
        assert_eq!(snap.p99, 99.0);
        assert!((snap.mean - 50.5).abs() < 1e-12);
        assert_eq!(snap.retained, 100);
    }

    #[test]
    fn histogram_caps_retained_samples_but_counts_all() {
        let tel = Telemetry::new(true);
        for i in 0..(HISTOGRAM_SAMPLE_CAP + 100) {
            tel.observe("h", i as f64);
        }
        let snap = tel.histogram("h").unwrap();
        assert_eq!(snap.count as usize, HISTOGRAM_SAMPLE_CAP + 100);
        assert_eq!(snap.retained, HISTOGRAM_SAMPLE_CAP);
        assert_eq!(snap.max, (HISTOGRAM_SAMPLE_CAP + 99) as f64);
    }

    #[test]
    fn chrome_trace_lines_parse_and_carry_the_tree() {
        let tel = Telemetry::new(true);
        let root = tel.span(draft(None, "root", 0.0, 4.0)).unwrap();
        tel.span(draft(Some(root), "child", 1.0, 2.0));
        tel.count("launch.slots", 4);
        let jsonl = tel.chrome_trace_jsonl();
        let lines: Vec<&str> = jsonl.lines().collect();
        // 1 thread-name metadata + 2 spans + 1 counter
        assert_eq!(lines.len(), 4);
        for line in &lines {
            Json::parse(line).expect("every line is one JSON event");
        }
        let child = Json::parse(lines[2]).unwrap();
        assert_eq!(child.get("ph").unwrap().as_str(), Some("X"));
        assert_eq!(child.get("ts").unwrap().as_f64(), Some(1e6));
        assert_eq!(child.get("dur").unwrap().as_f64(), Some(2e6));
        assert_eq!(
            child.at(&["args", "parent"]).unwrap().as_u64(),
            Some(root)
        );
        let counter = Json::parse(lines[3]).unwrap();
        assert_eq!(counter.get("ph").unwrap().as_str(), Some("C"));
        assert_eq!(
            counter.at(&["args", "value"]).unwrap().as_u64(),
            Some(4)
        );
    }

    #[test]
    fn snapshot_json_round_trips() {
        let tel = Telemetry::new(true);
        tel.span(draft(None, "s", 0.0, 1.0));
        tel.count("c", 7);
        tel.observe("h", 2.0);
        let snap = tel.snapshot_json();
        assert_eq!(snap.get("spans").unwrap().as_u64(), Some(1));
        assert_eq!(
            snap.at(&["counters", "c"]).unwrap().as_u64(),
            Some(7)
        );
        assert_eq!(
            snap.at(&["histograms", "h", "count"]).unwrap().as_u64(),
            Some(1)
        );
        let back = Json::parse(&snap.to_string()).unwrap();
        assert_eq!(back.at(&["counters", "c"]).unwrap().as_u64(), Some(7));
    }

    #[test]
    fn recorder_is_shareable_across_threads() {
        use std::sync::Arc;
        let tel = Arc::new(Telemetry::new(true));
        std::thread::scope(|scope| {
            for w in 0..4 {
                let tel = Arc::clone(&tel);
                scope.spawn(move || {
                    for i in 0..25 {
                        tel.span(SpanDraft {
                            parent: None,
                            category: "test",
                            name: &format!("w{w}-{i}"),
                            track: "t",
                            start: SimTime::from_secs(f64::from(i)),
                            dur_secs: 1.0,
                        });
                        tel.count("n", 1);
                    }
                });
            }
        });
        assert_eq!(tel.span_count(), 100);
        assert_eq!(tel.counter("n"), 100);
        // ids are unique
        let mut ids: Vec<u64> =
            tel.spans().iter().map(|s| s.id).collect();
        ids.sort_unstable();
        ids.dedup();
        assert_eq!(ids.len(), 100);
    }
}
