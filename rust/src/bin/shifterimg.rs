//! `shifterimg` — the Image Gateway CLI (§III.B).
//!
//! ```text
//! shifterimg [--system=daint] pull docker:ubuntu:xenial
//! shifterimg [--system=daint] images
//! ```

use shifter_rs::util::cli::CliSpec;
use shifter_rs::{ImageGateway, Registry, SystemProfile};

fn usage() -> ! {
    eprintln!("usage: shifterimg [--system=laptop|cluster|daint] <pull <ref> | images | lookup <ref>>");
    std::process::exit(2);
}

fn main() {
    let spec = CliSpec::new(&[("system", true)], false);
    let parsed = match spec.parse(std::env::args().skip(1)) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("shifterimg: {e}");
            usage();
        }
    };
    let profile = match parsed.get("system").unwrap_or("daint") {
        "laptop" => SystemProfile::laptop(),
        "cluster" => SystemProfile::linux_cluster(),
        "daint" => SystemProfile::piz_daint(),
        _ => usage(),
    };
    let registry = Registry::dockerhub();
    let mut gateway = ImageGateway::new(
        profile
            .pfs
            .clone()
            .unwrap_or_else(shifter_rs::pfs::LustreFs::piz_daint),
    );

    match parsed.positionals.as_slice() {
        [cmd, reference] if cmd == "pull" => {
            match gateway.pull(&registry, reference) {
                Ok(rep) => {
                    println!(
                        "{}: pulled in {:.1}s (download {:.1}s, expand {:.1}s, \
                         squashfs {:.1}s, store {:.1}s){}",
                        rep.reference,
                        rep.total_secs(),
                        rep.download_secs,
                        rep.expand_secs,
                        rep.convert_secs,
                        rep.store_secs,
                        if rep.cached { " [cached]" } else { "" }
                    );
                }
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        [cmd] if cmd == "images" => {
            // a fresh gateway has nothing pulled; list the registry too so
            // the demo binary is useful on its own
            println!("registry ({}):", registry.len());
            for r in registry.list() {
                println!("  {r}");
            }
            println!("gateway ({}):", gateway.list().len());
            for r in gateway.list() {
                println!("  {r}");
            }
        }
        [cmd, reference] if cmd == "lookup" => {
            match gateway
                .pull(&registry, reference)
                .and_then(|_| gateway.lookup(reference).map(|g| g.pfs_path.clone()))
            {
                Ok(path) => println!("{reference} -> {path}"),
                Err(e) => {
                    eprintln!("shifterimg: {e}");
                    std::process::exit(1);
                }
            }
        }
        _ => usage(),
    }
}
